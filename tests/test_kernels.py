"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle,
exactly as specified — assert_allclose per cell (exact for int compare)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buckets, hashing
from repro.kernels import ops, ref


def _table(capacity, n_items, seed, max_probes=32, deletes=0):
    rng = np.random.default_rng(seed)
    t = buckets.linear_make(capacity, hashing.fresh("mix32", seed),
                            max_probes=max_probes)
    keys = jnp.asarray(rng.choice(10_000_000, size=n_items, replace=False)
                       .astype(np.int32))
    t, ok = jax.jit(buckets.linear_insert)(t, keys, keys * 3,
                                           jnp.ones(keys.shape, bool))
    if deletes:
        t, _ = jax.jit(buckets.linear_delete)(t, keys[:deletes],
                                              jnp.ones(deletes, bool))
    return t, keys, np.asarray(ok)


@pytest.mark.parametrize("capacity,n_items,n_queries", [
    (1 << 10, 500, 333),          # small, non-tile-aligned query count
    (1 << 14, 9_000, 4_096),      # multi-tile
    (1 << 15, 20_000, 10_001),    # odd query count, several slabs
])
def test_probe_lookup_matches_ref(capacity, n_items, n_queries):
    t, keys, ok = _table(capacity, n_items, seed=capacity % 97)
    rng = np.random.default_rng(1)
    qs = jnp.concatenate([
        keys[: min(n_items, n_queries // 2)],
        jnp.asarray(rng.integers(10_000_000, 2**31 - 1, n_queries)
                    .astype(np.int32))])[:n_queries]
    h0 = hashing.bucket_of(t.hfn, qs, t.capacity)
    f_ref, v_ref = ref.probe_lookup_ref(t.key, t.val, t.state, h0, qs, 32)
    f_k, v_k = ops.probe_lookup(t.key, t.val, t.state, h0, qs, max_probes=32)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))


def test_probe_lookup_with_tombstones():
    t, keys, _ = _table(1 << 13, 4_000, seed=3, deletes=1_000)
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    f_ref, v_ref = ref.probe_lookup_ref(t.key, t.val, t.state, h0, keys, 64)
    f_k, v_k = ops.probe_lookup(t.key, t.val, t.state, h0, keys, max_probes=64)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    assert int(f_k.sum()) == 3_000


def test_probe_lookup_adversarial_skew():
    """All queries hash into one region (the paper's collision attack):
    the slab fallback path must stay exact."""
    t = buckets.linear_make(1 << 14, hashing.fresh("mix32", 0), max_probes=64)
    # force a dense contiguous run by inserting colliding-by-construction keys
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.choice(1_000_000, 3000, replace=False).astype(np.int32))
    t, _ = jax.jit(buckets.linear_insert)(t, keys, keys, jnp.ones(3000, bool))
    qs = jnp.tile(keys[:128], 32)                     # heavy duplicate queries
    h0 = hashing.bucket_of(t.hfn, qs, t.capacity)
    f_ref, v_ref = ref.probe_lookup_ref(t.key, t.val, t.state, h0, qs, 64)
    f_k, v_k = ops.probe_lookup(t.key, t.val, t.state, h0, qs, max_probes=64)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))


def test_ordered_lookup_fused_matches_ref():
    """The fused old->hazard->new kernel path == ordered_lookup_ref."""
    rng = np.random.default_rng(7)
    told, keys, _ = _table(1 << 12, 1_500, seed=11)
    tnew, keys2, _ = _table(1 << 12, 1_200, seed=12)
    hk = jnp.asarray(rng.choice(10_000_000, 64, replace=False).astype(np.int32))
    hv = hk * 7
    hl = jnp.asarray(rng.random(64) < 0.7)
    qs = jnp.concatenate([keys[:500], keys2[:500], hk,
                          jnp.asarray(rng.integers(2**30, 2**31 - 1, 300)
                                      .astype(np.int32))])
    h0_old = hashing.bucket_of(told.hfn, qs, told.capacity)
    h0_new = hashing.bucket_of(tnew.hfn, qs, tnew.capacity)
    args = ((told.key, told.val, told.state), (tnew.key, tnew.val, tnew.state),
            hk, hv, hl, h0_old, h0_new, qs)
    f_ref, v_ref = ref.ordered_lookup_ref(*args, max_probes=32)
    f_k, v_k = ops.ordered_lookup(*args, max_probes=32)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))
