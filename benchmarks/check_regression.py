"""CI perf-regression gate: fresh BENCH_*.json vs the committed baselines.

Every benchmark that writes a ``BENCH_*.json`` artifact (bench_rebuild's
fused-probe, fused-writes, chain-fused, and growth-escape comparisons
today) commits its result at the repo root; CI snapshots those committed
files, re-runs ``benchmarks.run --quick``, and calls this script to diff
the fresh artifacts against the snapshot.

Gate semantics, per leaf key:

* **pass counts** (``sort``, ``pallas_call``, ``passes``) are STRUCTURAL:
  they come from jaxpr inspection, are machine-independent, and any
  increase is a regression — the fused paths grew an extra sort or kernel
  launch, or a jnp probe loop crept back in.  Compared exactly.  The
  elastic-burst scenario's **resize counts** (``grows``, ``shrinks``,
  ``flaps``) are STRUCTURAL for the same reason: the policy's watermark
  decisions are deterministic arithmetic over a pinned workload, so an
  extra resize — and above all a nonzero flap count, a resize fired
  inside a constant-population hold window — is a hysteresis regression,
  not noise.  ``attack_probe_bound`` (BENCH_attack) joins this class:
  the cuckoo arm's measured worst-case probe depth under the collision
  flood, capped at ``width - 1`` by the two-table layout — any increase
  is a layout regression, exact by construction.  ``adversarial_sorts`` /
  ``adversarial_pallas_calls`` (routed-stack bench) pin the single-pass
  spill-slab guarantee where it matters most: a 100%-one-tenant batch
  must still lower to 1 sort + 1 pallas_call — an increase means the
  full-width retry (or any second pass) crept back in.
  A gated key that is MISSING from the fresh artifact, or present with a
  non-numeric type, is itself a failure: a gate that silently skips what
  it cannot read is no gate.
* **pass ratios** (``pass_ratio``, ``send_bytes_ratio``,
  ``cliff_ratio``) must not drop by more than ``--ratio-tolerance``
  (default 15%): the fused-vs-jnp advantage, the slab router's wire-bytes
  reduction (full-width buffer bytes over primary+slab,
  Q/(cap + spill_cap) — the routed-stack bench, slab columns counted),
  and the elastic scenario's worst-phase-over-steady
  throughput floor are acceptance criteria.  ``cliff_ratio`` divides two
  min-of-steps walls from the SAME run, so host contention largely
  cancels out of it.  The attack/serving recovery ratios join this class:
  ``recover_ratio`` (BENCH_attack — post-rebuild over under-attack
  throughput, CAPPED at the bench's ``RECOVER_CAP`` so the O(chain)
  raw factor's jitter never gates; a drop means live-rebuild recovery
  broke), and the serving macro-bench's ``attack_p50_ratio`` /
  ``recovered_p50_ratio`` (steady-phase decode MEDIAN over attack- and
  recovered-phase medians, same-run numerators and denominators — decode
  must stay flat through a fingerprint-index collision attack and after
  the live rehash).  The macro-bench's p99 figures are reported but NOT
  gated: an extreme quantile of ~200 samples swings ~2x run-to-run on
  shared runners, which no fixed tolerance separates from regression.
* **escape rates** (``escape_rate``, ``overflow_rate``, ``miss_rate``,
  ``alloc_fail_rate``, ``dropped_rate``) are lower-is-better fractions —
  rebuild-epoch queries overflowing to the jnp fallback (growth-escape
  bench), zipf-batch keys past their tenant's primary cap (routed-stack
  bench; slab pressure, deterministic for the pinned seed), keys past
  primary AND spill slab (``dropped_rate``, baseline 0.0: the slab is
  sized to serve the whole zipf spill — nonzero means the slab shrank or
  the accounting broke), the serving macro-bench's per-phase prefix-cache
  miss rate, and its page-allocation failure rate (baseline 0.0:
  eviction, not alloc failure, must absorb pool pressure).  They
  must not exceed the baseline by more than ``--rate-tolerance`` ABSOLUTE
  (default 0.02 — a 0.00 baseline allows up to 0.02, so benign hash-seed
  jitter passes but a coverage regression in the two-level tile map
  fails).
* **timings** (``wall_us``) must not grow by more than the artifact's
  wall-clock band.  All wall clocks follow the MIN-OF-5 protocol
  (``common.timeit``: five individually-synced repeats, minimum reported)
  — contention only ever adds time, so the min is the noise-robust
  estimator and the committed baselines carry far less run-to-run jitter
  than the old mean-of-N numbers.  The baselines are produced by a
  CI-runner-class container (same pinned deps, CPU interpret mode), and
  the workflow passes a CALIBRATED cross-runner band of 2.0: measured
  jitter of the interpreted kernels is <1.3x run-to-run on an idle
  machine and up to ~2.6x worst-case under scheduler contention, so a
  genuine slowdown past 3x fails while runner noise does not.  (The band
  was 3.0 — a >4x allowance — before the baselines were regenerated on
  runner-class hardware; min-of-5 is the ROADMAP's tightening step on
  top.)  **Per-artifact bands**: a baseline BENCH_*.json may carry a
  top-level ``"band"`` key overriding the global ``--time-tolerance`` for
  just that artifact — benchmarks whose measured jitter is tighter (or
  looser, e.g. host-dispatch-bound loops) than the fleet-wide 2.0 declare
  their own calibration where the number is produced, instead of holding
  every artifact to the worst common denominator.  The analogous
  top-level ``"ratio_band"`` key overrides ``--ratio-tolerance`` for one
  artifact's higher-is-better ratios: the serving macro-bench uses it
  because its per-phase p50 ratios common-mode out hardware speed but
  still swing ~±15% run to run in interpret mode (measured range
  0.81–1.08 on an idle box), while the regression it guards against —
  a blocking rehash — moves the ratio by ~50x, far past any band.

Exit status: 0 clean, 1 regression(s) found, 2 usage/setup error.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

STRUCTURAL = ("sort", "pallas_call", "passes", "grows", "shrinks", "flaps",
              "attack_probe_bound", "adversarial_sorts",
              "adversarial_pallas_calls")
RATIOS = ("pass_ratio", "send_bytes_ratio", "cliff_ratio", "recover_ratio",
          "attack_p50_ratio", "recovered_p50_ratio")
TIMINGS = ("wall_us",)
RATES = ("escape_rate", "overflow_rate", "miss_rate", "alloc_fail_rate",
         "dropped_rate")


def _compare(base, cur, path: str, failures: list[str], *,
             time_tol: float, ratio_tol: float, rate_tol: float) -> None:
    if isinstance(base, dict):
        if not isinstance(cur, dict):
            failures.append(f"{path}: expected object, got {type(cur).__name__}")
            return
        for k, v in base.items():
            if k not in cur:
                failures.append(f"{path}/{k}: missing from current run")
                continue
            _compare(v, cur[k], f"{path}/{k}", failures,
                     time_tol=time_tol, ratio_tol=ratio_tol,
                     rate_tol=rate_tol)
        return
    if isinstance(base, bool) or not isinstance(base, (int, float)):
        return  # strings/bools are descriptive, not gated
    key = path.rsplit("/", 1)[-1]
    gated = key in STRUCTURAL + RATIOS + RATES + TIMINGS
    if gated and (isinstance(cur, bool) or not isinstance(cur, (int, float))):
        # a gated metric that changed TYPE (a bench emitting "n/a"/null/a
        # nested object where the baseline has a number) must fail, not
        # skip: silently passing here is how a gate rots
        failures.append(
            f"{path}: gated metric is {type(cur).__name__} in the current "
            f"run, expected a number")
        return
    if key in STRUCTURAL:
        if cur > base:
            failures.append(
                f"{path}: pass count increased {base} -> {cur}")
    elif key in RATIOS:
        if cur < base * (1 - ratio_tol):
            failures.append(
                f"{path}: ratio regressed {base:.2f} -> {cur:.2f} "
                f"(tolerance {ratio_tol:.0%})")
    elif key in RATES:
        if cur > base + rate_tol:
            failures.append(
                f"{path}: escape rate regressed {base:.4f} -> {cur:.4f} "
                f"(absolute tolerance {rate_tol})")
    elif key in TIMINGS:
        if cur > base * (1 + time_tol):
            failures.append(
                f"{path}: timing regressed {base:.0f}us -> {cur:.0f}us "
                f"(tolerance {time_tol:.0%})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline-dir", required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--current-dir", required=True,
                    help="directory holding the freshly emitted BENCH_*.json")
    ap.add_argument("--time-tolerance", type=float, default=0.15,
                    help="allowed relative wall-clock growth (default 0.15)")
    ap.add_argument("--ratio-tolerance", type=float, default=0.15,
                    help="allowed relative pass-ratio drop (default 0.15)")
    ap.add_argument("--rate-tolerance", type=float, default=0.02,
                    help="allowed ABSOLUTE escape-rate increase "
                         "(default 0.02)")
    args = ap.parse_args(argv)

    baseline_dir = pathlib.Path(args.baseline_dir)
    current_dir = pathlib.Path(args.current_dir)
    baselines = sorted(baseline_dir.glob("BENCH_*.json"))
    if not baselines:
        print(f"error: no BENCH_*.json baselines in {baseline_dir}",
              file=sys.stderr)
        return 2

    failures: list[str] = []
    for base_path in baselines:
        cur_path = current_dir / base_path.name
        if not cur_path.exists():
            failures.append(f"{base_path.name}: artifact not produced by the "
                            f"current run")
            continue
        base = json.loads(base_path.read_text())
        cur = json.loads(cur_path.read_text())
        band = base.get("band") if isinstance(base, dict) else None
        rband = base.get("ratio_band") if isinstance(base, dict) else None
        time_tol = float(band) if band is not None else args.time_tolerance
        ratio_tol = float(rband) if rband is not None \
            else args.ratio_tolerance
        _compare(base, cur, base_path.stem, failures,
                 time_tol=time_tol,
                 ratio_tol=ratio_tol,
                 rate_tol=args.rate_tolerance)
        suffix = "".join([f" (band {time_tol:.2f})" if band is not None
                          else "",
                          f" (ratio band {ratio_tol:.2f})"
                          if rband is not None else ""])
        print(f"checked {base_path.name}{suffix}")

    if failures:
        print(f"\nPERF REGRESSION: {len(failures)} failure(s)",
              file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print(f"perf gate clean: {len(baselines)} artifact(s) within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
