"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

I32 = jnp.int32
EMPTY, LIVE, TOMB, MIGRATED = 0, 1, 2, 3


def probe_lookup_ref(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                     h0: jax.Array, qkey: jax.Array, max_probes: int):
    """Linear-probe lookup oracle.

    Probes slots h0, h0+1, ... (mod C): stop on LIVE match (found) or EMPTY
    (absent); skip TOMB/MIGRATED.  Returns (found[Q] bool, val[Q] i32).
    """
    c = tkey.shape[0]
    q = qkey.shape[0]

    def body(i, carry):
        active, found, val = carry
        pos = (h0 + i) % c
        st = tstate[pos]
        hit = active & (st == LIVE) & (tkey[pos] == qkey)
        stop = active & (st == EMPTY)
        val = jnp.where(hit, tval[pos], val)
        found = found | hit
        active = active & ~hit & ~stop
        return active, found, val

    init = (jnp.ones((q,), bool), jnp.zeros((q,), bool), jnp.zeros((q,), I32))
    _, found, val = jax.lax.fori_loop(0, max_probes, body, init)
    return found, val


def probe_insert_ref(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                     h0: jax.Array, keys: jax.Array, vals: jax.Array,
                     mask: jax.Array, max_probes: int):
    """Linear-probe insert oracle on raw table arrays (claim-first-non-LIVE,
    lowest batch index wins a contested slot — the same linearization as
    ``buckets.linear_insert``).

    Caller contract: ``mask`` is winner-filtered (at most one True per
    distinct key; use ``buckets.batch_winners``).  Returns
    (tkey', tval', tstate', ok[Q]).
    """
    c = tkey.shape[0]
    q = keys.shape[0]
    present, _ = probe_lookup_ref(tkey, tval, tstate, h0, keys, max_probes)
    pending0 = mask & ~present
    idx = jnp.arange(q, dtype=I32)

    def body(p, carry):
        key, val, state, pending, done = carry
        pos = (h0 + p) % c
        free = pending & (state[pos] != LIVE)
        wpos = jnp.where(free, pos, c)
        claim = jnp.full((c,), q, I32).at[wpos].min(idx, mode="drop")
        won = free & (claim[pos] == idx)
        wp = jnp.where(won, pos, c)
        key = key.at[wp].set(keys, mode="drop")
        val = val.at[wp].set(vals, mode="drop")
        state = state.at[wp].set(LIVE, mode="drop")
        return key, val, state, pending & ~won, done | won

    init = (tkey, tval, tstate, pending0, jnp.zeros((q,), bool))
    tkey, tval, tstate, _, done = jax.lax.fori_loop(0, max_probes, body, init)
    return tkey, tval, tstate, done


def ordered_lookup_ref(old_t, new_t, hazard_key, hazard_val, hazard_live,
                       h0_old, h0_new, qkey, max_probes: int):
    """The paper's ordered three-way check: old -> hazard -> new."""
    f_old, v_old = probe_lookup_ref(*old_t, h0_old, qkey, max_probes)
    eq = (qkey[:, None] == hazard_key[None, :]) & hazard_live[None, :]
    f_hz = eq.any(-1)
    v_hz = jnp.take(hazard_val, jnp.argmax(eq, axis=-1))
    f_new, v_new = probe_lookup_ref(*new_t, h0_new, qkey, max_probes)
    found = f_old | f_hz | f_new
    val = jnp.where(f_old, v_old, jnp.where(f_hz, v_hz, v_new))
    return found, val
