"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

_MODULES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "gemma3-27b": "gemma3_27b",
    "deepseek-67b": "deepseek_67b",
    "qwen3-8b": "qwen3_8b",
    "gemma2-2b": "gemma2_2b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "rwkv6-3b": "rwkv6_3b",
    "arctic-480b": "arctic_480b",
    "llama4-scout-17b-a16e": "llama4_scout_17b",
    "hubert-xlarge": "hubert_xlarge",
    "dhash-paper": "dhash_paper",
}

ARCH_IDS = tuple(k for k in _MODULES if k != "dhash-paper")
ALL_IDS = tuple(_MODULES)


def _mod(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ALL_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str):
    return _mod(arch_id).CONFIG


def get_smoke(arch_id: str):
    return _mod(arch_id).smoke()
