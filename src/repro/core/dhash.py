"""DHash: a dynamic hash table whose hash function can be rebuilt live.

This is the paper's core contribution (§3-§4) mapped to the SPMD/XLA model:

* The table state is a pytree carrying the *old* table, the *new* table
  (pre-allocated with the replacement hash function), and a **hazard buffer**
  — the batched analogue of the paper's ``rebuild_cur`` global pointer.  A
  rebuild migrates a *chunk* of entries per transition instead of one node
  (single-node granularity would waste the vector units; the hazard period is
  a chunk-sized window).

* ``rebuild_extract`` removes a chunk from the old table into the hazard
  buffer (entries are then in *neither* table — the hazard period, Fig 1c);
  ``rebuild_land`` inserts the hazard entries into the new table and clears
  the buffer (Fig 1d).  The engine interleaves full-rate lookup/insert/delete
  batches between these transitions, which is exactly the concurrency
  structure of the paper; dataflow ordering plays the role of the paper's
  smp_wmb/smp_rmb pairs.

* Every operation performs the paper's **ordered check** (Lemma 4.1/4.2):
      old table  →  hazard buffer  →  new table.
  Lookup priority is old > hazard > new; delete tries old, then marks hazard
  entries dead (the LOGICALLY_REMOVED bit on an in-flight node, Alg. 5 line
  75 — a killed hazard entry is silently dropped at landing), then tries new.
  Insert targets the new table iff a rebuild is in progress (Lemma 4.3/4.4);
  duplicate keys discovered at landing are dropped in favour of the new
  table's copy (Alg. 3 lines 34-36).

* The epoch swap (Alg. 3 lines 41-46) is a host-level transition
  (``rebuild_finish``) because old/new may differ in static shape; for
  shape-preserving rebuilds there is a fully-jitted ``finish_same_shape``.
  The paper's ``synchronize_rcu`` grace periods are step boundaries: a
  transition consumes state_t and produces state_{t+1}, so no reader of
  state_t can observe state_{t+1} — the grace period is free.

* **Backend dispatch is the descriptor registry** (core/backend.py): every
  op below resolves ``DHashState.backend`` to a frozen ``BucketBackend``
  entry and calls its plain/fused/ordered callables — this module contains
  zero per-backend branches, which is what keeps the paper's modularity
  claim real (a new backend is one ``backend.register()`` call).

* **Table stacks** (``make_stack`` + the ``stack_*`` ops): because each
  backend's state is a uniform pytree with all statics held by the
  descriptor, a stack of T independent tables is just the same pytree with
  a leading [T] axis, and every op ``jax.vmap``s over it — T tables served
  by ONE kernel launch per op, each table free to run its own rebuild epoch
  (multi-tenant serving: per-tenant page tables in serving/kvcache.py).

Progress-guarantee analogue (DESIGN.md §2): a step's latency is bounded and
independent of rebuild progress — rebuild costs O(chunk) per transition,
never a stop-the-world O(N) pause.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backends
from repro.core import buckets
from repro.core.struct_utils import pytree_dataclass, replace

I32 = jnp.int32


@pytree_dataclass(meta_fields=("backend", "chunk", "fwd_hazard", "fused",
                               "nres_cap"))
class DHashState:
    backend: str                # registry key (core/backend.py)
    chunk: int                  # hazard buffer capacity (entries per rebuild chunk)
    fwd_hazard: bool            # backends with a lookup_fwd hook (linear):
                                # resolve hazard hits via MIGRATED-slot
                                # forwarding (zero extra passes)
    fused: bool                 # route the FULL op surface (lookup/insert/
                                # delete + rebuild extract and land) through
                                # the descriptor's Pallas adapters; every
                                # backend's rebuild-epoch lookup AND delete
                                # is ONE sort + ONE pallas_call
    nres_cap: int               # resident new-table blocks per query tile in
                                # the rebuild-epoch probe (two-level tile
                                # map) — descriptor default, overridable per
                                # table at make()
    old: Any                    # active table (backend pytree)
    new: Any                    # target table; meaningful only while rebuilding
    hazard_key: jax.Array       # [chunk] i32
    hazard_val: jax.Array       # [chunk] i32
    hazard_live: jax.Array      # [chunk] bool
    cursor: jax.Array           # scalar i32 - scan position in old table
    rebuilding: jax.Array       # scalar bool
    epoch: jax.Array            # scalar i32
    lookups: jax.Array          # scalar i32 - queries sampled by
                                # lookup_counted since the last policy
                                # action / epoch swap (probe telemetry)
    expensive: jax.Array        # scalar i32 - sampled queries whose probe
                                # cost crossed the policy threshold
                                # (small_hash.c expensive_lookup_count)


def _be(d: DHashState) -> backends.BucketBackend:
    """The descriptor every op dispatches through (static registry lookup —
    ``d.backend`` is aux data, so this is free under jit)."""
    return backends.get(d.backend)


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------

def _make_table(backend: str, capacity: int, seed, **kw):
    """Build an empty backend table sized for ``capacity`` live entries
    (the descriptor's sizing policy)."""
    return backends.get(backend).make(capacity, seed, **kw)


def _fused_default(backend: str) -> bool:
    """Resolve ``fused=None``: the DHASH_FUSED env var (``on``/``1``/``true``)
    turns the Pallas kernels on for every backend whose descriptor carries
    the fused op set — the hook CI's fused=on|off test matrix uses to drive
    the whole suite through the fused paths without touching call sites."""
    flag = os.environ.get("DHASH_FUSED", "off").lower()
    return flag in ("1", "on", "true") and backends.get(backend).fused


def make(backend: str = "linear", capacity: int = 1024, *, chunk: int = 256,
         seed: int = 0, fwd_hazard: bool = False, fused: bool | None = None,
         nres_cap: int | None = None, **kw) -> DHashState:
    be = backends.get(backend)
    if fused is None:
        # fwd_hazard is the alternative (jnp) hazard-resolution strategy; the
        # env default must not silently shadow it with the fused branch
        fused = _fused_default(backend) and not fwd_hazard
    if fused and not be.fused:
        raise ValueError(
            f"fused kernels are not implemented for backend {backend!r}; "
            f"fused-capable: "
            f"{tuple(n for n in backends.names() if backends.get(n).fused)}")
    if nres_cap is None:
        nres_cap = be.nres_cap
    old = be.make(capacity, seed, **kw)
    new = be.make(capacity, seed + 1, **kw)
    # distinct buffers per field (aliased leaves break jit buffer donation)
    return DHashState(backend=backend, chunk=chunk, fwd_hazard=fwd_hazard,
                      fused=fused, nres_cap=nres_cap, old=old, new=new,
                      hazard_key=jnp.zeros((chunk,), I32),
                      hazard_val=jnp.zeros((chunk,), I32),
                      hazard_live=jnp.zeros((chunk,), bool),
                      cursor=jnp.asarray(0, I32), rebuilding=jnp.asarray(False),
                      epoch=jnp.asarray(0, I32),
                      lookups=jnp.asarray(0, I32),
                      expensive=jnp.asarray(0, I32))


# ---------------------------------------------------------------------------
# the ordered check: old -> hazard -> new (Lemma 4.1)
# ---------------------------------------------------------------------------

def _hazard_probe(d: DHashState, keys: jax.Array):
    eq = (keys[:, None] == d.hazard_key[None, :]) & d.hazard_live[None, :]
    found = eq.any(-1)
    val, _ = buckets._argpick(eq, jnp.broadcast_to(d.hazard_val[None, :], eq.shape))
    return found, jnp.where(found, val, 0)


def _slow_lookup(dd: DHashState, keys: jax.Array):
    """Rebuild-epoch lookup body: the full old -> hazard -> new ordered
    check (shared by ``lookup`` and ``lookup_counted``)."""
    be = _be(dd)
    if dd.fused:
        return be.ordered_lookup_fused(
            dd.old, dd.new, dd.hazard_key, dd.hazard_val,
            dd.hazard_live, keys, nres_cap=dd.nres_cap)
    if dd.fwd_hazard and be.lookup_fwd is not None:
        # beyond-paper: the old-table probe already passes over the
        # MIGRATED slots of the in-flight chunk, so the hazard check is
        # a forwarding index, not a second pass (§Perf dhash-service)
        f_old, v_old, _, mig = be.lookup_fwd(dd.old, keys)
        base = dd.cursor - dd.chunk
        hz_idx = mig - base
        inwin = (mig >= 0) & (hz_idx >= 0) & (hz_idx < dd.chunk)
        safe = jnp.clip(hz_idx, 0, dd.chunk - 1)
        f_hz = inwin & dd.hazard_live[safe] & (dd.hazard_key[safe] == keys)
        v_hz = dd.hazard_val[safe]
    else:
        f_old, v_old, _ = be.lookup(dd.old, keys)        # (1) old table
        f_hz, v_hz = _hazard_probe(dd, keys)             # (2) rebuild_cur
    f_new, v_new, _ = be.lookup(dd.new, keys)            # (3) new table
    found = f_old | f_hz | f_new
    val = jnp.where(f_old, v_old, jnp.where(f_hz, v_hz, v_new))
    return found, val


def lookup(d: DHashState, keys: jax.Array):
    """Batched lookup honouring the rebuild protocol. Returns (found, vals).

    With ``fused`` both branches run the descriptor's Pallas adapters; the
    rebuild-epoch branch is the backend's single-pass ordered probe: ONE
    argsort + ONE pallas_call cover the whole old -> hazard -> new ordered
    check, with the two-level tile map keeping grown new tables resident."""
    be = _be(d)

    def fast(dd: DHashState, kk):
        if dd.fused:
            return be.lookup_fused(dd.old, kk)
        f, v, _ = be.lookup(dd.old, kk)
        return f, v

    return jax.lax.cond(d.rebuilding, _slow_lookup, fast, d, keys)


def lookup_counted(d: DHashState, keys: jax.Array, *,
                   probe_hi: int = 7):
    """Lookup that also feeds the elastic policy's probe telemetry.
    Returns ``(state', (found, vals))``.

    The steady-state branch runs the backend's loc-emitting probe (the same
    single kernel pass — ``loc`` is an extra output, not an extra pass),
    converts ``loc`` to a probe cost through the descriptor's
    ``probe_cost``, and bumps ``DHashState.lookups`` / ``.expensive``
    (queries whose cost crossed ``probe_hi``, small_hash.c's
    EXPENSIVE_LOOKUP_THRESHOLD).  The rebuild-epoch branch answers through
    the ordered check WITHOUT sampling: the fused ordered probe has no loc
    output, and mid-epoch probe lengths reflect the dying table anyway —
    the policy resets the counters at every action/epoch, so the sample
    window is always steady-state."""
    be = _be(d)

    def fast(dd: DHashState, kk):
        if dd.fused and be.lookup_fused_loc is not None:
            f, v, loc = be.lookup_fused_loc(dd.old, kk)
        else:
            f, v, loc = be.lookup(dd.old, kk)
        cost = be.probe_cost(dd.old, kk, f, loc)
        exp = (f & (cost >= probe_hi)).sum(dtype=I32)
        dd = replace(dd, lookups=dd.lookups + I32(kk.size),
                     expensive=dd.expensive + exp)
        return dd, (f, v)

    def slow(dd: DHashState, kk):
        return dd, _slow_lookup(dd, kk)

    return jax.lax.cond(d.rebuilding, slow, fast, d, keys)


def _ins_table(dd: DHashState, t, kk, vv, mm):
    """Descriptor-dispatched insert (shared by user inserts and hazard
    landing, so a fused state's rebuild landing runs the claim kernel).
    The descriptor's ``insert_fused`` folds any post-insert maintenance —
    a fused chain table re-sorts its arena when the insert pushes the dirty
    tail past the dense-window coverage (cond-gated, free on the clean
    steady state)."""
    be = _be(dd)
    if dd.fused:
        return be.insert_fused(t, kk, vv, mm)
    return be.insert(t, kk, vv, mm)


def insert(d: DHashState, keys: jax.Array, vals: jax.Array, mask: jax.Array | None = None):
    """Batched insert (set semantics: ok=False if key already present in the
    *target* table — Alg. 6). Returns (state', ok)."""
    if mask is None:
        mask = jnp.ones(keys.shape, bool)

    def fast(dd: DHashState):
        t, ok = _ins_table(dd, dd.old, keys, vals, mask)
        return replace(dd, old=t), ok

    def slow(dd: DHashState):
        t, ok = _ins_table(dd, dd.new, keys, vals, mask)
        return replace(dd, new=t), ok

    return jax.lax.cond(d.rebuilding, slow, fast, d)


def delete(d: DHashState, keys: jax.Array, mask: jax.Array | None = None):
    """Batched delete honouring the ordered check (Alg. 5). Returns (state', ok).

    With ``fused`` the write path is kernel-backed end to end: the fast
    branch tombstones via the descriptor's location-emitting probe adapter,
    and the rebuild-epoch branch is the backend's single-pass
    ``ordered_delete_fused`` — ONE argsort + ONE pallas_call whose
    slot/hazard-index outputs drive the old tombstone, the hazard kill, and
    the new tombstone."""
    if mask is None:
        mask = jnp.ones(keys.shape, bool)
    be = _be(d)

    def _del(dd: DHashState, t, kk, mm):
        if dd.fused:
            return be.delete_fused(t, kk, mm)
        return be.delete(t, kk, mm)

    def fast(dd: DHashState):
        t, ok = _del(dd, dd.old, keys, mask)
        return replace(dd, old=t), ok

    def slow_fused(dd: DHashState):
        os_, ns_, hl, ok = be.ordered_delete_fused(
            dd.old, dd.new, dd.hazard_key, dd.hazard_val, dd.hazard_live,
            keys, mask, nres_cap=dd.nres_cap)
        return replace(dd, old=be.with_state(dd.old, os_),
                       new=be.with_state(dd.new, ns_), hazard_live=hl), ok

    def slow(dd: DHashState):
        if dd.fused:
            return slow_fused(dd)
        t_old, ok_old = _del(dd, dd.old, keys, mask)                   # (1) old
        pending = mask & ~ok_old
        # (2) hazard buffer: clear the live bit (LOGICALLY_REMOVED on the
        # in-flight node) - landing will drop it.
        eq = (keys[:, None] == dd.hazard_key[None, :]) & dd.hazard_live[None, :]
        hit_hz = eq.any(-1) & pending
        win_hz = buckets.batch_winners(keys, hit_hz) & hit_hz
        kill = (eq & win_hz[:, None]).any(0)
        hazard_live = dd.hazard_live & ~kill
        pending2 = pending & ~hit_hz
        t_new, ok_new = _del(dd, dd.new, keys, pending2)               # (3) new
        ok = ok_old | win_hz | ok_new
        return replace(dd, old=t_old, new=t_new, hazard_live=hazard_live), ok

    return jax.lax.cond(d.rebuilding, slow, fast, d)


# ---------------------------------------------------------------------------
# rebuild protocol
# ---------------------------------------------------------------------------

def rebuild_start(d: DHashState, new_table=None, *, seed: int | None = None) -> DHashState:
    """Host-level: begin a rebuild into ``new_table`` (fresh hash function).

    Caller contract (paper's rebuild_lock): no rebuild may be in progress.
    """
    be = _be(d)
    if new_table is None:
        if seed is None:
            seed = int(np.random.default_rng().integers(1 << 31))
        new_table = be.fresh_like(d.old, seed)
    if d.fused and be.freeze_old is not None:
        # pre-epoch maintenance hook (chain: freeze the old arena fully
        # sorted and tombstone-reclaimed before the cursor scan starts — the
        # old side stays dirt-free for the whole epoch since inserts target
        # the new table).  Safe exactly here: the cursor resets to 0, so
        # node movement cannot skip the scan.
        d = replace(d, old=be.freeze_old(d.old))
    return replace(d, new=new_table, cursor=jnp.asarray(0, I32),
                   rebuilding=jnp.asarray(True))


def rebuild_extract(d: DHashState) -> DHashState:
    """Pull the next chunk out of the old table into the hazard buffer.

    No-op unless rebuilding with an empty hazard buffer.  With ``fused`` the
    scan is the extract kernel (one pallas_call over the resident slab
    window + one MIGRATED scatter; hazard entries compacted on-device)
    instead of the jnp gather scan."""
    be = _be(d)

    def go(dd: DHashState):
        if dd.fused:
            t, hk, hv, hl, cur = be.extract_chunk_fused(dd.old, dd.cursor,
                                                        dd.chunk)
        else:
            t, hk, hv, hl, cur = be.extract_chunk(dd.old, dd.cursor,
                                                  dd.chunk)
        return replace(dd, old=t, hazard_key=hk, hazard_val=hv,
                       hazard_live=hl, cursor=cur)

    can = d.rebuilding & ~d.hazard_live.any()
    return jax.lax.cond(can, go, lambda dd: dd, d)


def rebuild_land(d: DHashState) -> DHashState:
    """Insert hazard entries into the new table; duplicates lose to the copy
    already in the new table (Alg. 3 lines 34-36); entries killed while in
    hazard (delete during the hazard period) are dropped.

    With ``fused`` the landing runs through the SAME claim kernel as user
    inserts, so the whole rebuild epoch — extract -> land -> swap — stays
    on-device inside the jitted engine step.

    A landing insert can fail two ways and they MUST be told apart: the key
    is already in the new table (a user re-inserted it during the hazard
    window — the new copy wins, drop the hazard entry), or the new table
    had no slot within the probe bound (a burst filling the target
    mid-migration — the hazard entry is the ONLY copy of an acknowledged
    insert, so it stays live and the next transition retries).  The
    disambiguating presence check is the plain jnp probe — elementwise, no
    extra sort or kernel pass — and cond-gated so clean landings never pay
    it."""
    be = _be(d)

    def go(dd: DHashState):
        t, ok = _ins_table(dd, dd.new, dd.hazard_key, dd.hazard_val,
                           dd.hazard_live)
        failed = dd.hazard_live & ~ok

        def reconcile(args):
            t_, failed_ = args
            present, _, _ = be.lookup(t_, dd.hazard_key)
            return failed_ & ~present          # keep only the capacity fails

        keep = jax.lax.cond(failed.any(), reconcile,
                            lambda args: jnp.zeros_like(failed), (t, failed))
        return replace(dd, new=t, hazard_live=keep)

    return jax.lax.cond(d.rebuilding, go, lambda dd: dd, d)


def rebuild_chunk(d: DHashState) -> DHashState:
    """extract + land in one transition (hazard window not externally visible).
    Engines that want the observable hazard period call the two halves."""
    return rebuild_land(rebuild_extract(d))


def rebuild_done(d: DHashState) -> jax.Array:
    """Scalar bool: all chunks migrated and landed."""
    return d.rebuilding & (d.cursor >= _be(d).capacity_of(d.old)) \
        & ~d.hazard_live.any()


def rebuild_finish(d: DHashState) -> DHashState:
    """Host-level epoch swap (Alg. 3 lines 41-46). old/new may differ in
    static shape, so this is not jittable in general; O(1) pytree shuffle."""
    assert bool(jax.device_get(rebuild_done(d))), "rebuild not complete"
    # probe telemetry is per-table-generation: a fresh epoch samples afresh
    return replace(d, old=d.new, new=d.old, cursor=jnp.asarray(0, I32),
                   rebuilding=jnp.asarray(False), epoch=d.epoch + 1,
                   lookups=jnp.asarray(0, I32), expensive=jnp.asarray(0, I32))


def finish_same_shape(d: DHashState) -> DHashState:
    """Fully-jitted epoch swap, valid when old/new share static shapes
    (continuous-rebuild benchmarks; router rebalancing)."""
    done = rebuild_done(d)
    old_leaves, treedef = jax.tree_util.tree_flatten(d.old)
    new_leaves = jax.tree_util.tree_leaves(d.new)
    sw_old = [jnp.where(done, n, o) for o, n in zip(old_leaves, new_leaves)]
    sw_new = [jnp.where(done, o, n) for o, n in zip(old_leaves, new_leaves)]
    return replace(d,
                   old=jax.tree_util.tree_unflatten(treedef, sw_old),
                   new=jax.tree_util.tree_unflatten(treedef, sw_new),
                   cursor=jnp.where(done, 0, d.cursor).astype(I32),
                   rebuilding=d.rebuilding & ~done,
                   epoch=d.epoch + done.astype(I32),
                   lookups=jnp.where(done, 0, d.lookups).astype(I32),
                   expensive=jnp.where(done, 0, d.expensive).astype(I32))


def rebuild_step(d: DHashState) -> DHashState:
    """One rebuild transition per call: land if hazard pending, else extract.
    Interleave with op batches for concurrent-rebuild execution."""
    return jax.lax.cond(d.hazard_live.any(), rebuild_land, rebuild_extract, d)


def rebuild_autostart(d: DHashState) -> DHashState:
    """Fully-jitted rebuild start: when NOT rebuilding, clear the (drained)
    standby table, reseed its hash function on-device from the epoch counter
    (hashing.reseed — no host RNG), and raise ``rebuilding``.

    This is the continuous-rebuild engine's device-side replacement for the
    host-level ``rebuild_start``: combined with ``finish_same_shape`` the
    steady state never leaves the accelerator.  Valid when old/new share
    static shapes (same-capacity rebuilds)."""
    be = _be(d)

    def go(dd: DHashState):
        new = be.clear(dd.new)
        new = be.reseed(new, dd.epoch + 1)
        old = dd.old
        if dd.fused and be.freeze_old is not None:
            # same pre-epoch maintenance as the host-level rebuild_start:
            # sort + reclaim once per epoch, before the cursor scan begins
            old = be.freeze_old(old)
        return replace(dd, old=old, new=new, cursor=jnp.asarray(0, I32),
                       rebuilding=jnp.asarray(True))

    return jax.lax.cond(d.rebuilding, lambda dd: dd, go, d)


# ---------------------------------------------------------------------------
# convenience drivers
# ---------------------------------------------------------------------------

def rebuild_all(d: DHashState, *, finish: bool = True) -> DHashState:
    """Run a complete rebuild to quiescence (host loop; used by tests/benches
    that don't care about interleaving)."""
    cap = _be(d).capacity_of(d.old)
    steps = -(-cap // d.chunk) + 1  # +1 in case a hazard chunk is already pending
    chunk_fn = jax.jit(rebuild_chunk)
    done_fn = jax.jit(rebuild_done)
    for _ in range(steps):
        if bool(jax.device_get(done_fn(d))):
            break
        d = chunk_fn(d)
    return rebuild_finish(d) if finish else d


def count_items(d: DHashState) -> jax.Array:
    be = _be(d)
    return (be.count_live(d.old) + be.count_live(d.new)
            + d.hazard_live.sum(dtype=I32))


# ---------------------------------------------------------------------------
# table stacks: T independent tables batched over a leading axis
# ---------------------------------------------------------------------------
#
# A stack is an ordinary DHashState whose every array leaf carries a leading
# [T] axis (the static meta — backend, chunk, fused, nres_cap — is shared).
# The stack_* ops are jax.vmap over the single-table ops, so T tables cost
# ONE kernel launch per op (the fused 1-sort/1-pallas_call budget holds per
# table step) and each table runs its own rebuild epoch — the multi-tenant
# seam serving/kvcache.py builds per-tenant page tables on.

def make_stack(n_tables: int, backend: str = "linear", capacity: int = 1024,
               *, chunk: int = 256, seed: int = 0, **kw) -> DHashState:
    """Build ``n_tables`` independent tables (decorrelated hash seeds)
    stacked on a leading [T] axis.  All static metadata is shared — that is
    what makes the stack one uniform pytree ``jax.vmap`` can batch."""
    if n_tables < 1:
        raise ValueError(f"need at least one table, got {n_tables}")
    tables = [make(backend, capacity, chunk=chunk, seed=seed + i, **kw)
              for i in range(n_tables)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *tables)


def stack_size(d: DHashState) -> int:
    """Static T of a stacked state (the leading axis of any scalar leaf)."""
    return d.cursor.shape[0]


def unstack(d: DHashState) -> list[DHashState]:
    """Split a stack back into its T independent single-table states."""
    return [jax.tree_util.tree_map(lambda x: x[i], d)
            for i in range(stack_size(d))]


def stack_lookup(d: DHashState, keys: jax.Array,
                 mask: jax.Array | None = None):
    """Batched lookup over the stack: keys [T, Q] -> (found, vals) [T, Q].

    ``mask`` ([T, Q] bool) squelches ``found`` for padding slots — the
    routed entry point: capped send buffers (core/distributed.py,
    serving/kvcache.py) zero-pad each owner's segment, and a zero padding
    key must never report a hit even if some table legitimately holds key
    0.  The vmapped kernel launch is unchanged (mask is applied to the
    result, not the probe)."""
    found, vals = jax.vmap(lookup)(d, keys)
    if mask is not None:
        found = found & mask
    return found, vals


def stack_insert(d: DHashState, keys: jax.Array, vals: jax.Array,
                 mask: jax.Array | None = None):
    """Batched insert over the stack ([T, Q] operands). Returns (state', ok)."""
    if mask is None:
        mask = jnp.ones(keys.shape, bool)
    return jax.vmap(insert)(d, keys, vals, mask)


def stack_delete(d: DHashState, keys: jax.Array,
                 mask: jax.Array | None = None):
    """Batched delete over the stack ([T, Q] operands). Returns (state', ok)."""
    if mask is None:
        mask = jnp.ones(keys.shape, bool)
    return jax.vmap(delete)(d, keys, mask)


def stack_rebuild_step(d: DHashState) -> DHashState:
    """One rebuild transition on every (rebuilding) table of the stack —
    epochs advance independently; idle tables are untouched."""
    return jax.vmap(rebuild_step)(d)


def stack_finish_same_shape(d: DHashState) -> DHashState:
    """Per-table jitted epoch swap: each table swaps exactly when ITS
    rebuild completes (staggered epochs across the stack)."""
    return jax.vmap(finish_same_shape)(d)


def stack_autostart(d: DHashState, start: jax.Array | None = None) -> DHashState:
    """Begin a rebuild on the tables selected by ``start`` [T] bool (all by
    default); tables already rebuilding are untouched.  Fully jitted — the
    per-tenant analogue of ``rebuild_autostart``."""
    if start is None:
        start = jnp.ones((stack_size(d),), bool)

    def one(dd, s):
        return jax.lax.cond(s, rebuild_autostart, lambda x: x, dd)

    return jax.vmap(one)(d, start)


def stack_rebuild_done(d: DHashState) -> jax.Array:
    """[T] bool: which tables have a completed-but-unswapped rebuild."""
    return jax.vmap(rebuild_done)(d)


def stack_count_items(d: DHashState) -> jax.Array:
    """[T] i32: live entries per table (old + new + hazard)."""
    return jax.vmap(count_items)(d)
