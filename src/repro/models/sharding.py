"""Declarative sharding rules: parameter-name patterns -> mesh axes.

Rules are (negative_dim, mesh_axis) preferences applied with divisibility
checks, so the same table serves stacked ([L, ...]) and unstacked leaves and
degrades gracefully (e.g. 8 kv heads on a 16-way model axis -> replicate
instead of invalid sharding).  ``fsdp=True`` adds a "data"-axis shard on a
second dimension of the big matrices (GSPMD then emits the FSDP all-gathers).
"""
from __future__ import annotations

import re
from contextlib import contextmanager
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# activation-sharding context
#
# FSDP-style weight sharding gives GSPMD a choice: all-gather the weights
# (correct) or reshard the activations (catastrophic - observed: arctic
# replicated its whole attention).  Explicit activation constraints at layer
# boundaries remove the bad option.  The context is installed by the step
# builders (dryrun/train/serve) around tracing; without it `constrain` is a
# no-op so smoke tests and single-device runs are untouched.
# ---------------------------------------------------------------------------

_CTX: dict[str, Any] = {"mesh": None, "dp": None, "tp": None}


@contextmanager
def activation_ctx(mesh: Mesh, *, tp: str = "model"):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    old = dict(_CTX)
    _CTX.update(mesh=mesh, dp=dp, tp=tp if tp in mesh.axis_names else None)
    try:
        yield
    finally:
        _CTX.update(old)


def constrain(x: jax.Array, *pattern: str | None) -> jax.Array:
    """pattern entries: "dp" | "tp" | None per axis.  Axes whose size does
    not divide the mesh axis degrade to None (e.g. 8 kv heads on 16-way TP).
    """
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = []
    for dim, p in zip(x.shape, pattern):
        if p == "dp" and _CTX["dp"]:
            n = 1
            for a in _CTX["dp"]:
                n *= sizes[a]
            if dim % n == 0:
                spec.append(_CTX["dp"] if len(_CTX["dp"]) > 1 else _CTX["dp"][0])
            else:
                spec.append(None)
        elif p == "tp" and _CTX["tp"]:
            spec.append(_CTX["tp"] if dim % sizes[_CTX["tp"]] == 0 else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))

# name-pattern -> list of (neg_dim, axis_kind) preferences; axis_kind in
# {"model", "fsdp"} ("fsdp" entries only apply when cfg.fsdp)
_RULES: list[tuple[str, list[tuple[int, str]]]] = [
    (r"embed$",            [(-2, "model"), (-1, "fsdp")]),
    (r"unembed$",          [(-1, "model"), (-2, "fsdp")]),
    (r"\bwq$|\bwk$|\bwv$|\bwqkv$", [(-2, "model"), (-3, "fsdp")]),
    (r"\bwgu$",            [(-1, "model"), (-2, "fsdp")]),
    (r"\bwo$",             [(-3, "model"), (-1, "fsdp")]),
    (r"we_g$|we_u$",       [(-3, "model"), (-1, "fsdp")]),
    (r"we_d$",             [(-3, "model"), (-2, "fsdp")]),
    (r"\bwg$|\bwu$|c_k$",  [(-1, "model"), (-2, "fsdp")]),
    (r"\bwd$|c_v$",        [(-2, "model"), (-1, "fsdp")]),
    (r"router$",           [(-1, "model")]),
    (r"in_proj$",          [(-1, "model"), (-2, "fsdp")]),
    (r"out_proj$",         [(-2, "model"), (-1, "fsdp")]),
    (r"w_r$|w_k$|w_v$|w_g$|w_o$|c_r$|w_rkvg$", [(-1, "model"), (-2, "fsdp")]),
    (r"conv_w$",           [(-1, "model")]),
]


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def leaf_spec(path, shape: tuple[int, ...], *, model_axis: str = "model",
              dp_axes: tuple[str, ...] = ("data",), fsdp: bool = False,
              axis_sizes: dict[str, int] | None = None) -> P:
    """PartitionSpec for one parameter leaf."""
    name = _path_str(path)
    ndim = len(shape)
    spec: list[Any] = [None] * ndim
    sizes = axis_sizes or {}

    def ax_size(kind):
        if kind == "model":
            return sizes.get(model_axis, 1), model_axis
        total = 1
        for a in dp_axes:
            total *= sizes.get(a, 1)
        return total, (dp_axes if len(dp_axes) > 1 else dp_axes[0])

    for pat, prefs in _RULES:
        if re.search(pat, name):
            for neg_dim, kind in prefs:
                if kind == "fsdp" and not fsdp:
                    continue
                dim = ndim + neg_dim
                if dim < 0 or spec[dim] is not None:
                    continue
                n, axis = ax_size(kind)
                if n > 1 and shape[dim] % n == 0 and shape[dim] >= n:
                    spec[dim] = axis
            break
    return P(*spec)


def param_shardings(params_shape: Any, mesh: Mesh, *, fsdp: bool = False,
                    dp_axes: tuple[str, ...] | None = None) -> Any:
    """NamedShardings for a (shape-)pytree of parameters."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if dp_axes is None:
        dp_axes = tuple(a for a in ("pod", "data") if a in sizes)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, leaf_spec(path, leaf.shape, dp_axes=dp_axes, fsdp=fsdp,
                            axis_sizes=sizes)),
        params_shape)


def batch_spec(mesh: Mesh, ndim: int, *, seq_axis: int | None = None,
               batch_sharded: bool = True) -> P:
    """Activations/batch: leading dim over the DP axes; optionally a sequence
    axis over 'data' (long-context single-sequence shapes)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in ("pod", "data") if a in sizes)
    spec: list[Any] = [None] * ndim
    if batch_sharded:
        spec[0] = dp if len(dp) > 1 else dp[0]
    if seq_axis is not None:
        spec[seq_axis] = "data" if batch_sharded is False else None
    return P(*spec)


def opt_state_shardings(param_shardings_tree, params_shape, mesh: Mesh) -> Any:
    """ZeRO-1: moments shard like their parameter (FSDP'd params already carry
    a data-axis shard; replicated params keep their spec — documented)."""
    return param_shardings_tree
