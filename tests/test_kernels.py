"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle,
exactly as specified — assert_allclose per cell (exact for int compare)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import count_primitives as _count_primitives
from repro.core import buckets, dhash, hashing
from repro.kernels import ops, ref


def _table(capacity, n_items, seed, max_probes=32, deletes=0):
    rng = np.random.default_rng(seed)
    t = buckets.linear_make(capacity, hashing.fresh("mix32", seed),
                            max_probes=max_probes)
    keys = jnp.asarray(rng.choice(10_000_000, size=n_items, replace=False)
                       .astype(np.int32))
    t, ok = jax.jit(buckets.linear_insert)(t, keys, keys * 3,
                                           jnp.ones(keys.shape, bool))
    if deletes:
        t, _ = jax.jit(buckets.linear_delete)(t, keys[:deletes],
                                              jnp.ones(deletes, bool))
    return t, keys, np.asarray(ok)


@pytest.mark.parametrize("capacity,n_items,n_queries", [
    (1 << 10, 500, 333),          # small, non-tile-aligned query count
    (1 << 14, 9_000, 4_096),      # multi-tile
    (1 << 15, 20_000, 10_001),    # odd query count, several slabs
])
def test_probe_lookup_matches_ref(capacity, n_items, n_queries):
    t, keys, ok = _table(capacity, n_items, seed=capacity % 97)
    rng = np.random.default_rng(1)
    qs = jnp.concatenate([
        keys[: min(n_items, n_queries // 2)],
        jnp.asarray(rng.integers(10_000_000, 2**31 - 1, n_queries)
                    .astype(np.int32))])[:n_queries]
    h0 = hashing.bucket_of(t.hfn, qs, t.capacity)
    f_ref, v_ref = ref.probe_lookup_ref(t.key, t.val, t.state, h0, qs, 32)
    f_k, v_k = ops.probe_lookup(t.key, t.val, t.state, h0, qs, max_probes=32)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))


def test_probe_lookup_with_tombstones():
    t, keys, _ = _table(1 << 13, 4_000, seed=3, deletes=1_000)
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    f_ref, v_ref = ref.probe_lookup_ref(t.key, t.val, t.state, h0, keys, 64)
    f_k, v_k = ops.probe_lookup(t.key, t.val, t.state, h0, keys, max_probes=64)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    assert int(f_k.sum()) == 3_000


def test_probe_lookup_adversarial_skew():
    """All queries hash into one region (the paper's collision attack):
    the slab fallback path must stay exact."""
    t = buckets.linear_make(1 << 14, hashing.fresh("mix32", 0), max_probes=64)
    # force a dense contiguous run by inserting colliding-by-construction keys
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.choice(1_000_000, 3000, replace=False).astype(np.int32))
    t, _ = jax.jit(buckets.linear_insert)(t, keys, keys, jnp.ones(3000, bool))
    qs = jnp.tile(keys[:128], 32)                     # heavy duplicate queries
    h0 = hashing.bucket_of(t.hfn, qs, t.capacity)
    f_ref, v_ref = ref.probe_lookup_ref(t.key, t.val, t.state, h0, qs, 64)
    f_k, v_k = ops.probe_lookup(t.key, t.val, t.state, h0, qs, max_probes=64)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))


def _ordered_args(n_old=1_500, n_new=1_200, n_q=4_096, hazard=64, seed=7):
    rng = np.random.default_rng(seed)
    told, keys, _ = _table(1 << 12, n_old, seed=11)
    tnew, keys2, _ = _table(1 << 12, n_new, seed=12)
    hk = jnp.asarray(rng.choice(10_000_000, hazard, replace=False).astype(np.int32))
    hv = hk * 7
    hl = jnp.asarray(rng.random(hazard) < 0.7)
    qs = jnp.concatenate([keys, keys2, hk,
                          jnp.asarray(rng.integers(2**30, 2**31 - 1, n_q)
                                      .astype(np.int32))])[:n_q]
    h0_old = hashing.bucket_of(told.hfn, qs, told.capacity)
    h0_new = hashing.bucket_of(tnew.hfn, qs, tnew.capacity)
    return ((told.key, told.val, told.state), (tnew.key, tnew.val, tnew.state),
            hk, hv, hl, h0_old, h0_new, qs)


def test_fused_rebuild_lookup_single_sort_single_pallas_call():
    """Acceptance: during an active rebuild the fused lookup path executes
    exactly ONE argsort and ONE pallas_call per batch; the unfused path pays
    at least two of each (old pass + new pass)."""
    args = _ordered_args(n_q=4_096)
    fused = jax.make_jaxpr(
        lambda *a: ops.ordered_lookup_fused(*a, max_probes=32))(*args)
    unfused = jax.make_jaxpr(
        lambda *a: ops.ordered_lookup(*a, max_probes=32))(*args)
    nf = _count_primitives(fused, ("sort", "pallas_call"))
    nu = _count_primitives(unfused, ("sort", "pallas_call"))
    assert nf == {"sort": 1, "pallas_call": 1}, nf
    assert nu["sort"] >= 2 and nu["pallas_call"] >= 2, nu
    # pass-count reduction is the interpret-mode proxy for the >=1.5x
    # rebuild-epoch throughput criterion (see bench_rebuild --fused)
    passes_u = nu["sort"] + nu["pallas_call"]
    passes_f = nf["sort"] + nf["pallas_call"]
    assert passes_u / passes_f >= 1.5


def test_probe2_matches_ref():
    """Fused two-table+hazard kernel == ordered oracle (multi-tile batch with
    duplicates and hazard hits)."""
    args = _ordered_args(n_q=4_096)
    f_ref, v_ref = ref.ordered_lookup_ref(*args, max_probes=32)
    f_k, v_k = ops.ordered_lookup_fused(*args, max_probes=32)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))


def test_probe2_skew_forced_fallback():
    """A large new table makes per-tile new-slab windows miss (h0_new is
    scattered while the shared sort is keyed on h0_old): complete=False
    queries must be recovered exactly by the gated fallback; duplicate query
    keys ride along."""
    rng = np.random.default_rng(3)
    told, keys, _ = _table(1 << 12, 1_000, seed=21)
    tnew = buckets.linear_make(1 << 15, hashing.fresh("mix32", 22), max_probes=32)
    k2 = jnp.asarray(rng.choice(10_000_000, 5_000, replace=False).astype(np.int32))
    tnew, _ = jax.jit(buckets.linear_insert)(tnew, k2, k2 * 9,
                                             jnp.ones(k2.shape, bool))
    hz = jnp.zeros(32, jnp.int32)
    qs = jnp.concatenate([k2[:2000], jnp.tile(k2[:128], 8), keys])
    h0_old = hashing.bucket_of(told.hfn, qs, told.capacity)
    h0_new = hashing.bucket_of(tnew.hfn, qs, tnew.capacity)
    args = ((told.key, told.val, told.state), (tnew.key, tnew.val, tnew.state),
            hz, hz, jnp.zeros(32, bool), h0_old, h0_new, qs)
    f_ref, v_ref = ref.ordered_lookup_ref(*args, max_probes=32)
    f_k, v_k = ops.ordered_lookup_fused(*args, max_probes=32)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))


def test_probe_insert_matches_oracle_low_load():
    """Claim kernel == insert oracle at low load: identical ok flags, every
    inserted key readable with its value, live-count conserved."""
    rng = np.random.default_rng(5)
    t = buckets.linear_make(1 << 13, hashing.fresh("mix32", 5), max_probes=32)
    keys = jnp.asarray(rng.choice(1_000_000, 3_000, replace=False).astype(np.int32))
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    mask = jnp.ones(keys.shape, bool)
    tk, tv, ts, ok = ops.probe_insert(t.key, t.val, t.state, h0, keys,
                                      keys * 5, mask, max_probes=32)
    _, _, ts_ref, ok_ref = ref.probe_insert_ref(t.key, t.val, t.state, h0,
                                                keys, keys * 5, mask, 32)
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(ok_ref))
    assert bool(ok.all())
    assert int((ts == 1).sum()) == int((ts_ref == 1).sum()) == 3_000
    f, v = ref.probe_lookup_ref(tk, tv, ts, h0, keys, 32)
    assert bool(f.all()) and bool((v == keys * 5).all())


def test_probe_insert_duplicates_and_existing():
    """buckets.linear_insert_fused (winner dedup + kernel) must agree with
    the jnp linear_insert on every observable: ok counts per key, final
    membership, values."""
    rng = np.random.default_rng(9)
    base = jnp.asarray(rng.choice(1_000_000, 500, replace=False).astype(np.int32))
    t0 = buckets.linear_make(1 << 12, hashing.fresh("mix32", 1), max_probes=32)
    t0, _ = jax.jit(buckets.linear_insert)(t0, base, base * 2,
                                           jnp.ones(base.shape, bool))
    # batch: duplicates of new keys, re-inserts of existing keys, masked-out
    fresh = jnp.asarray(rng.choice(np.arange(2_000_000, 3_000_000), 400,
                                   replace=False).astype(np.int32))
    batch = jnp.concatenate([fresh, fresh[:200], base[:100]])
    vals = batch * 3
    mask = jnp.ones(batch.shape, bool).at[-50:].set(False)
    t_j, ok_j = jax.jit(buckets.linear_insert)(t0, batch, vals, mask)
    t_k, ok_k = jax.jit(buckets.linear_insert_fused)(t0, batch, vals, mask)
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_j))
    assert int(buckets.linear_count_live(t_k)) == int(buckets.linear_count_live(t_j))
    probe = jnp.concatenate([base, fresh])
    f_j, v_j, _ = buckets.linear_lookup(t_j, probe)
    f_k, v_k, _ = buckets.linear_lookup(t_k, probe)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_j))
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_j))


def test_probe_insert_full_table_pressure():
    """Near-capacity insert with a short probe bound: successful claims are
    readable, failures genuinely exhausted their windows, no slot double-
    claimed (live count == ok count)."""
    rng = np.random.default_rng(4)
    t = buckets.linear_make(1 << 10, hashing.fresh("mix32", 5), max_probes=16)
    keys = jnp.asarray(rng.choice(1_000_000, 1_200, replace=False).astype(np.int32))
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    mask = jnp.ones(keys.shape, bool)
    tk, tv, ts, ok = ops.probe_insert(t.key, t.val, t.state, h0, keys, keys,
                                      mask, max_probes=16)
    _, _, _, ok_ref = ref.probe_insert_ref(t.key, t.val, t.state, h0, keys,
                                           keys, mask, 16)
    # claim order is a different (equally legal) linearization than the
    # oracle's, so the totals may differ by a whisker under contention
    assert abs(int(ok.sum()) - int(ok_ref.sum())) <= 5
    assert int((ts == 1).sum()) == int(ok.sum())       # no double-claims
    f, v = ref.probe_lookup_ref(tk, tv, ts, h0, keys, 16)
    assert bool(f[ok].all()) and bool((v[ok] == keys[ok]).all())
    assert not bool(f[~ok].any())                       # failures not inserted


def test_ordered_lookup_fused_matches_ref():
    """The fused old->hazard->new kernel path == ordered_lookup_ref."""
    rng = np.random.default_rng(7)
    told, keys, _ = _table(1 << 12, 1_500, seed=11)
    tnew, keys2, _ = _table(1 << 12, 1_200, seed=12)
    hk = jnp.asarray(rng.choice(10_000_000, 64, replace=False).astype(np.int32))
    hv = hk * 7
    hl = jnp.asarray(rng.random(64) < 0.7)
    qs = jnp.concatenate([keys[:500], keys2[:500], hk,
                          jnp.asarray(rng.integers(2**30, 2**31 - 1, 300)
                                      .astype(np.int32))])
    h0_old = hashing.bucket_of(told.hfn, qs, told.capacity)
    h0_new = hashing.bucket_of(tnew.hfn, qs, tnew.capacity)
    args = ((told.key, told.val, told.state), (tnew.key, tnew.val, tnew.state),
            hk, hv, hl, h0_old, h0_new, qs)
    f_ref, v_ref = ref.ordered_lookup_ref(*args, max_probes=32)
    f_k, v_k = ops.ordered_lookup(*args, max_probes=32)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))


# ---------------------------------------------------------------------------
# write-path kernels: delete / extract / land (PR 2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("capacity,n_items,n_del", [
    (1 << 10, 500, 333),          # small, non-tile-aligned delete count
    (1 << 14, 9_000, 4_097),      # multi-tile, odd count
])
def test_probe_delete_matches_jnp(capacity, n_items, n_del):
    """Fused delete == jnp delete on every observable: ok flags, final
    states, membership — batch mixes present keys, absent keys, duplicates,
    and masked-out entries; batch size is not a tile multiple."""
    t, keys, _ = _table(capacity, n_items, seed=capacity % 89)
    rng = np.random.default_rng(2)
    absent = jnp.asarray(rng.integers(20_000_000, 2**31 - 1, n_del // 3)
                         .astype(np.int32))
    batch = jnp.concatenate([keys[:n_del], absent, keys[:64]])[:n_del]
    mask = jnp.ones(batch.shape, bool).at[-17:].set(False)
    t_j, ok_j = jax.jit(buckets.linear_delete)(t, batch, mask)
    t_k, ok_k = jax.jit(buckets.linear_delete_fused)(t, batch, mask)
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_j))
    np.testing.assert_array_equal(np.asarray(t_k.state), np.asarray(t_j.state))
    f_j, _, _ = buckets.linear_lookup(t_j, keys)
    f_k, _, _ = buckets.linear_lookup(t_k, keys)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_j))


def test_probe_delete_tombstone_reuse():
    """Slots freed by the fused delete are reclaimed by the fused insert:
    live count conserved, and every re-inserted key readable."""
    t = buckets.linear_make(256, hashing.fresh("mix32", 0), max_probes=32)
    k = jnp.arange(1, 181, dtype=jnp.int32)
    t, _ = jax.jit(buckets.linear_insert)(t, k, k * 2, jnp.ones(180, bool))
    t, ok_d = jax.jit(buckets.linear_delete_fused)(t, k[:90],
                                                   jnp.ones(90, bool))
    assert bool(ok_d.all())
    assert int((t.state == 2).sum()) == 90          # TOMB
    k2 = jnp.arange(1000, 1090, dtype=jnp.int32)
    t, ok_i = jax.jit(buckets.linear_insert_fused)(t, k2, k2 * 3,
                                                   jnp.ones(90, bool))
    assert bool(ok_i.all())                          # tombstones reused
    assert int(buckets.linear_count_live(t)) == 180
    f, v, _ = buckets.linear_lookup(t, k2)
    assert bool(f.all()) and bool((v == k2 * 3).all())


def test_write_kernels_budget():
    """Budget: each new write-path op is ONE argsort + ONE pallas_call
    (extract needs no sort at all — the chunk window is already
    contiguous)."""
    t, keys, _ = _table(1 << 12, 1_000, seed=13)
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    mask = jnp.ones(keys.shape, bool)

    jx = jax.make_jaxpr(
        lambda *a: ops.probe_delete(*a, max_probes=32))(
        t.key, t.val, t.state, h0, keys, mask)
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}

    args = _ordered_args(n_q=2_048)
    jx = jax.make_jaxpr(
        lambda *a: ops.ordered_delete_fused(*a, max_probes=32))(
        *args, jnp.ones(args[-1].shape, bool))
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}

    jx = jax.make_jaxpr(
        lambda k, v, s, c: ops.extract_chunk_fused(k, v, s, c, chunk=256))(
        t.key, t.val, t.state, jnp.asarray(0, jnp.int32))
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 0, "pallas_call": 1}

    tc = buckets.twochoice_make(1 << 9, hashing.fresh("mix32", 1),
                                hashing.fresh("mix32", 2), width=8)
    ba, bb = buckets._tc_rows(tc, keys)
    jx = jax.make_jaxpr(ops.twochoice_lookup)(
        tc.key, tc.val, tc.state, ba, bb, keys)
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}

    jx = jax.make_jaxpr(
        lambda *a: ops.twochoice_insert(*a, max_rounds=8))(
        tc.key, tc.val, tc.state, ba, bb, keys, keys * 2, mask)
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}

    # cuckoo rides the SAME two-row kernels with side-offset rows, and its
    # conflict-escape kick loop lives behind a cond: the fused lookup holds
    # the identical 1-sort / 1-pallas_call budget, and the fused insert's
    # counts EQUAL the twochoice adapter's (batch_winners' lexsort + the
    # claim kernel's sort) — the kick adds zero sorts and zero launches
    from repro.core import backend as _backend
    ckt = buckets.cuckoo_make(1 << 8, hashing.fresh("mix32", 3),
                              hashing.fresh("mix32", 4), width=8)
    jx = jax.make_jaxpr(_backend.cuckoo_lookup_fused)(ckt, keys)
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}

    jx = jax.make_jaxpr(
        lambda t, k, v, m: _backend.twochoice_insert_fused(t, k, v, m))(
        tc, keys, keys * 2, mask)
    tc_budget = _count_primitives(jx, ("sort", "pallas_call"))
    jx = jax.make_jaxpr(
        lambda t, k, v, m: _backend.cuckoo_insert_fused(t, k, v, m))(
        ckt, keys, keys * 2, mask)
    assert _count_primitives(jx, ("sort", "pallas_call")) == tc_budget
    assert tc_budget["pallas_call"] == 1


@pytest.mark.parametrize("cursor", [0, 100, 4_000, 4_090, 8_100])
def test_extract_chunk_fused_matches_jnp(cursor):
    """Fused extract == jnp extract as a SET (the fused hazard buffer is
    compacted on-device), with identical MIGRATED markings and cursor
    advance — cursor positions cover the slab seam and the table edge."""
    t, keys, _ = _table(1 << 13, 4_000, seed=5, deletes=500)
    cur = jnp.asarray(cursor, jnp.int32)
    t_j, hk_j, hv_j, hl_j, cur_j = jax.jit(
        lambda t, c: buckets.linear_extract_chunk(t, c, 256))(t, cur)
    t_k, hk_k, hv_k, hl_k, cur_k = jax.jit(
        lambda t, c: buckets.linear_extract_chunk_fused(t, c, 256))(t, cur)
    np.testing.assert_array_equal(np.asarray(t_k.state),
                                  np.asarray(t_j.state))
    assert int(cur_j) == int(cur_k)
    lj, lk = np.asarray(hl_j), np.asarray(hl_k)
    set_j = set(zip(np.asarray(hk_j)[lj].tolist(),
                    np.asarray(hv_j)[lj].tolist()))
    set_k = set(zip(np.asarray(hk_k)[lk].tolist(),
                    np.asarray(hv_k)[lk].tolist()))
    assert set_j == set_k
    # compaction: live entries are a prefix
    assert (np.flatnonzero(lk) == np.arange(lk.sum())).all()


def test_ordered_delete_fused_matches_staged():
    """Mid-rebuild fused delete (ONE probe2 pass) == the staged jnp ordered
    delete on ok flags, remaining membership, and item counts — the batch
    hits old-table keys, hazard keys, new-table keys, and absent keys."""
    rng = np.random.default_rng(8)
    d_j = dhash.make("linear", capacity=1024, chunk=128, seed=5, fused=False)
    d_k = dhash.make("linear", capacity=1024, chunk=128, seed=5, fused=True)
    keys = jnp.asarray(rng.choice(100_000, 800, replace=False)
                       .astype(np.int32))
    ins = jax.jit(dhash.insert)
    d_j, _ = ins(d_j, keys, keys * 2)
    d_k, _ = ins(d_k, keys, keys * 2)
    d_j = dhash.rebuild_start(d_j, seed=9)
    d_k = dhash.rebuild_start(d_k, seed=9)
    step = jax.jit(dhash.rebuild_step)
    for _ in range(3):   # extract, land, extract -> populated hazard window
        d_j, d_k = step(d_j), step(d_k)
    assert bool(d_k.hazard_live.any())
    batch = jnp.concatenate([
        keys[::3], jnp.asarray(rng.integers(200_000, 300_000, 101)
                               .astype(np.int32))])
    dl = jax.jit(dhash.delete)
    d_j2, ok_j = dl(d_j, batch)
    d_k2, ok_k = dl(d_k, batch)
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_j))
    assert int(dhash.count_items(d_j2)) == int(dhash.count_items(d_k2))
    look = jax.jit(dhash.lookup)
    f_j, v_j = look(d_j2, keys)
    f_k, v_k = look(d_k2, keys)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_j))
    fm = np.asarray(f_j)
    np.testing.assert_array_equal(np.asarray(v_k)[fm], np.asarray(v_j)[fm])


@pytest.mark.parametrize("backend,fused", [
    ("linear", True), ("twochoice", True), ("chain", True), ("cuckoo", True),
    ("chain", False),
])
def test_delete_extract_land_parity_all_backends(backend, fused):
    """The full write surface (delete + extract + land + swap) against a
    dict oracle for every backend — all four on the fused kernels, plus
    chain on the jnp reference path (the fused chain's fallback target)."""
    rng = np.random.default_rng(3)
    d = dhash.make(backend, capacity=512, chunk=64, seed=7, fused=fused)
    oracle: dict[int, int] = {}
    keys = rng.choice(100_000, 301, replace=False).astype(np.int32)  # odd N
    d, ok = jax.jit(dhash.insert)(d, jnp.asarray(keys), jnp.asarray(keys * 2))
    assert bool(ok.all())
    oracle.update({int(k): int(k) * 2 for k in keys})
    d = dhash.rebuild_start(d, seed=31)
    step = jax.jit(dhash.rebuild_step)
    dl = jax.jit(dhash.delete)
    look = jax.jit(dhash.lookup)
    i = 0
    while bool(jax.device_get(d.rebuilding)) and i < 64:
        d = step(d)                       # extract or land
        dels = keys[i::16][:5]            # delete during the hazard window
        d, ok_d = dl(d, jnp.asarray(dels))
        expect = np.array([int(k) in oracle for k in dels])
        np.testing.assert_array_equal(np.asarray(ok_d), expect)
        for k in dels:
            oracle.pop(int(k), None)
        if bool(jax.device_get(dhash.rebuild_done(d))):
            d = dhash.rebuild_finish(d)
        i += 1
    assert int(d.epoch) == 1, "rebuild did not complete"
    assert int(dhash.count_items(d)) == len(oracle)
    f, v = look(d, jnp.asarray(keys))
    expect_f = np.array([int(k) in oracle for k in keys])
    np.testing.assert_array_equal(np.asarray(f), expect_f)
    np.testing.assert_array_equal(np.asarray(v)[expect_f],
                                  np.array([oracle[int(k)] for k in keys
                                            if int(k) in oracle]))


# (The per-backend fused-vs-jnp parity copies that lived here —
# test_tc_lookup_fused_matches_jnp, test_tc_insert_delete_fused_matches_jnp —
# are subsumed by the registry-parameterized op-contract checklist in
# tests/test_backend_protocol.py, which runs the same assertions for EVERY
# BucketBackend entry x fused on/off.)


def test_land_fused_uses_insert_kernel():
    """rebuild_land on a fused state routes through the claim kernel: the
    landed epoch conserves membership, and the jaxpr of the fused landing
    contains a pallas_call (the jnp landing has none)."""
    d = dhash.make("linear", capacity=512, chunk=64, seed=2, fused=True)
    d_j = dhash.make("linear", capacity=512, chunk=64, seed=2, fused=False)
    jx_f = jax.make_jaxpr(dhash.rebuild_land)(d)
    jx_j = jax.make_jaxpr(dhash.rebuild_land)(d_j)
    assert _count_primitives(jx_f, ("pallas_call",))["pallas_call"] >= 1
    assert _count_primitives(jx_j, ("pallas_call",))["pallas_call"] == 0


# ---------------------------------------------------------------------------
# chain backend: arena-sorted fused path (PR 4)
# ---------------------------------------------------------------------------

def _chain_table(nbuckets=64, arena=2048, n_items=600, seed=1, max_chain=64,
                 compact=True):
    rng = np.random.default_rng(seed)
    t = buckets.chain_make(nbuckets, arena, hashing.fresh("mix32", seed),
                           max_chain=max_chain)
    keys = jnp.asarray(rng.choice(1_000_000, n_items, replace=False)
                       .astype(np.int32))
    t, ok = jax.jit(buckets.chain_insert)(t, keys, keys * 3,
                                          jnp.ones(keys.shape, bool))
    assert bool(ok.all())
    if compact:
        t = buckets.chain_compact_fused(t)
    return t, keys


def test_chain_compact_fused_invariants():
    """Compaction produces bucket-sorted, tombstone-compacted segments with
    valid pointers: per-bucket (start, len) tiles the live prefix, chains
    walk each segment in order, membership is preserved, and dead nodes are
    physically reclaimed."""
    t, keys = _chain_table(compact=False)
    t, _ = jax.jit(buckets.chain_delete)(t, keys[:150], jnp.ones(150, bool))
    tc = buckets.chain_compact_fused(t)
    live = 600 - 150
    assert int(buckets.chain_dirty(tc)) == 0
    assert int(tc.sorted_upto) == live
    assert int(tc.free_top) == tc.arena - live          # tombstones reclaimed
    bstart, blen = np.asarray(tc.bstart), np.asarray(tc.blen)
    assert blen.sum() == live
    np.testing.assert_array_equal(bstart, np.concatenate([[0],
                                                          blen.cumsum()[:-1]]))
    # every node's key hashes to the bucket whose segment holds it
    b_of = np.asarray(hashing.bucket_of(tc.hfn, tc.akey, tc.nbuckets))
    for b in range(tc.nbuckets):
        seg = slice(int(bstart[b]), int(bstart[b] + blen[b]))
        assert (b_of[seg] == b).all()
    # jnp pointer path still sees exactly the surviving keys
    f, v, _ = buckets.chain_lookup(tc, keys)
    np.testing.assert_array_equal(np.asarray(f),
                                  np.arange(600) >= 150)
    np.testing.assert_array_equal(np.asarray(v)[150:],
                                  np.asarray(keys * 3)[150:])


def test_chain_fused_matches_jnp():
    """Fused chain lookup/insert/delete == the jnp pointer-chasing path on
    EVERY observable — including the exact arena state for insert (same
    allocation and link order), with duplicates, re-inserts, masked-out
    entries, and an odd batch size."""
    rng = np.random.default_rng(4)
    t, keys = _chain_table()
    qs = jnp.concatenate([keys, jnp.asarray(
        rng.integers(2_000_000, 3_000_000, 333).astype(np.int32))])
    f_j, v_j, l_j = jax.jit(buckets.chain_lookup)(t, qs)
    f_k, v_k, l_k = jax.jit(buckets.chain_lookup_fused)(t, qs)
    fm = np.asarray(f_j)
    np.testing.assert_array_equal(np.asarray(f_k), fm)
    np.testing.assert_array_equal(np.asarray(v_k)[fm], np.asarray(v_j)[fm])
    np.testing.assert_array_equal(np.asarray(l_k)[fm], np.asarray(l_j)[fm])
    assert (np.asarray(l_k)[~fm] == -1).all()

    fresh = jnp.asarray(rng.choice(np.arange(3_000_000, 4_000_000), 200,
                                   replace=False).astype(np.int32))
    batch = jnp.concatenate([fresh, fresh[:50], keys[:50]])
    mask = jnp.ones(batch.shape, bool).at[-10:].set(False)
    t_j, ok_j = jax.jit(buckets.chain_insert)(t, batch, batch * 7, mask)
    t_k, ok_k = jax.jit(buckets.chain_insert_fused)(t, batch, batch * 7,
                                                    mask)
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_j))
    for fld in ("akey", "aval", "astate", "anext", "heads", "free_top"):
        np.testing.assert_array_equal(np.asarray(getattr(t_k, fld)),
                                      np.asarray(getattr(t_j, fld)),
                                      err_msg=fld)

    dels = jnp.concatenate([keys[:100], fresh[:40], jnp.asarray(
        rng.integers(5_000_000, 6_000_000, 31).astype(np.int32))])
    dm = jnp.ones(dels.shape, bool)
    td_j, okd_j = jax.jit(buckets.chain_delete)(t_j, dels, dm)
    td_k, okd_k = jax.jit(buckets.chain_delete_fused)(t_k, dels, dm)
    np.testing.assert_array_equal(np.asarray(okd_k), np.asarray(okd_j))
    np.testing.assert_array_equal(np.asarray(td_k.astate),
                                  np.asarray(td_j.astate))


def test_chain_kernels_budget():
    """Budget: every fused chain batch op is ONE argsort + ONE pallas_call
    (the dirty-tail window is a dynamic_slice compare, the insert relink is
    a pair of prefix/suffix scans — neither adds a sort), and the
    compaction pass is exactly ONE segmented sort with no kernel launch."""
    t, keys = _chain_table()
    t2, _ = _chain_table(seed=2)
    rng = np.random.default_rng(0)
    hk = jnp.asarray(rng.choice(10_000_000, 64, replace=False)
                     .astype(np.int32))
    hl = jnp.asarray(rng.random(64) < 0.7)
    mask = jnp.ones(keys.shape, bool)
    b = hashing.bucket_of(t.hfn, keys, t.nbuckets)
    b2 = hashing.bucket_of(t2.hfn, keys, t2.nbuckets)
    parts, parts2 = buckets._chain_parts(t), buckets._chain_parts(t2)

    jx = jax.make_jaxpr(lambda *a: ops.chain_lookup_fused(*a, max_chain=64))(
        *parts, b, keys)
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}

    jx = jax.make_jaxpr(lambda *a: ops.chain_delete_fused(*a, max_chain=64))(
        *parts, b, keys, mask)
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}

    jx = jax.make_jaxpr(lambda *a: ops.chain_insert_fused(*a, max_chain=64))(
        parts[0], parts[1], parts[2], t.free_stack, t.free_top, b,
        keys, keys * 2, mask)
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}

    jx = jax.make_jaxpr(
        lambda *a: ops.chain_ordered_lookup(*a, max_chain=64))(
        *parts, *parts2, hk, hk * 7, hl, b, b2, keys)
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}

    jx = jax.make_jaxpr(
        lambda *a: ops.chain_ordered_delete(*a, max_chain=64))(
        *parts, *parts2, hk, hk * 7, hl, b, b2, keys, mask)
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}

    jx = jax.make_jaxpr(
        lambda *a: ops.chain_compact_fused(*a, nbuckets=t.nbuckets))(
        t.akey, t.aval, t.astate, hashing.bucket_of(t.hfn, t.akey,
                                                    t.nbuckets))
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 0}


def test_chain_staleness_forces_fallback_parity():
    """Compaction staleness: a dirty tail grown past ops.DIRTY_CAP makes
    absence unprovable in-pass, so the fused ops must route through the
    gated pointer-chasing fallback — and stay exact across BOTH sides of
    the compaction transition."""
    rng = np.random.default_rng(9)
    t = buckets.chain_make(64, 4096, hashing.fresh("mix32", 9), max_chain=96)
    keys = jnp.asarray(rng.choice(1_000_000, ops.DIRTY_CAP + 188,
                                  replace=False).astype(np.int32))
    t, ok = jax.jit(buckets.chain_insert_fused)(t, keys, keys * 2,
                                                jnp.ones(keys.shape, bool))
    assert bool(ok.all())
    assert int(buckets.chain_dirty(t)) > ops.DIRTY_CAP   # stale: past the window
    qs = jnp.concatenate([keys, jnp.asarray(
        rng.integers(2_000_000, 3_000_000, 101).astype(np.int32))])
    f_j, v_j, _ = jax.jit(buckets.chain_lookup)(t, qs)
    f_k, v_k, _ = jax.jit(buckets.chain_lookup_fused)(t, qs)
    fm = np.asarray(f_j)
    np.testing.assert_array_equal(np.asarray(f_k), fm)
    np.testing.assert_array_equal(np.asarray(v_k)[fm], np.asarray(v_j)[fm])
    # the trigger restores the sorted invariant at exactly this threshold...
    t2 = jax.jit(buckets.chain_maybe_compact)(t)
    assert int(buckets.chain_dirty(t2)) == 0
    # ...and a below-threshold table is left untouched (cond not taken)
    t3 = jax.jit(buckets.chain_maybe_compact)(t2)
    np.testing.assert_array_equal(np.asarray(t3.akey), np.asarray(t2.akey))
    f_c, v_c, _ = jax.jit(buckets.chain_lookup_fused)(t2, qs)
    np.testing.assert_array_equal(np.asarray(f_c), fm)
    np.testing.assert_array_equal(np.asarray(v_c)[fm], np.asarray(v_j)[fm])


def test_chain_ordered_matches_ref_grown_arena():
    """Fused chain rebuild-epoch lookup/delete == the pointer-chasing
    ordered oracle with a 4x-grown, partially-landed new arena carrying a
    dirty tail, live hazard entries, duplicates, and absent keys."""
    rng = np.random.default_rng(1)
    told, k1 = _chain_table(seed=2)
    tnew = buckets.chain_make(256, 8192, hashing.fresh("mix32", 3),
                              max_chain=64)
    k2 = jnp.asarray(rng.choice(np.arange(1_000_000, 2_000_000), 400,
                                replace=False).astype(np.int32))
    tnew, _ = jax.jit(buckets.chain_insert)(tnew, k2, k2 * 5,
                                            jnp.ones(400, bool))
    tnew = buckets.chain_compact_fused(tnew)
    k3 = jnp.asarray(rng.choice(np.arange(4_000_000, 5_000_000), 120,
                                replace=False).astype(np.int32))
    tnew, _ = jax.jit(buckets.chain_insert_fused)(tnew, k3, k3 * 9,
                                                  jnp.ones(120, bool))
    assert int(buckets.chain_dirty(tnew)) == 120
    hk = jnp.asarray(rng.choice(np.arange(6_000_000, 7_000_000), 64,
                                replace=False).astype(np.int32))
    hv, hl = hk * 7, jnp.asarray(rng.random(64) < 0.7)
    qs = jnp.concatenate([k1[:200], k2[:200], k3[:60], hk, jnp.tile(k1[:64], 2),
                          jnp.asarray(rng.integers(8_000_000, 9_000_000, 333)
                                      .astype(np.int32))])
    f_k, v_k = jax.jit(buckets.chain_ordered_lookup_fused)(
        told, tnew, hk, hv, hl, qs)
    bqo = hashing.bucket_of(told.hfn, qs, told.nbuckets)
    bqn = hashing.bucket_of(tnew.hfn, qs, tnew.nbuckets)
    f_r, v_r = ref.chain_ordered_lookup_ref(
        (told.akey, told.aval, told.astate), (told.anext, told.heads),
        (tnew.akey, tnew.aval, tnew.astate), (tnew.anext, tnew.heads),
        hk, hv, hl, bqo, bqn, qs, 64)
    fm = np.asarray(f_r)
    np.testing.assert_array_equal(np.asarray(f_k), fm)
    np.testing.assert_array_equal(np.asarray(v_k)[fm], np.asarray(v_r)[fm])

    dels = jnp.concatenate([k1[::5], k2[::5], k3[::5], hk[:20], jnp.asarray(
        rng.integers(8_000_000, 9_000_000, 41).astype(np.int32))])
    dm = jnp.ones(dels.shape, bool)
    os_, ns_, hl2, ok = jax.jit(buckets.chain_ordered_delete_fused)(
        told, tnew, hk, hv, hl, dels, dm)
    # staged jnp oracle: old -> hazard kill -> new
    winner = buckets.batch_winners(dels, dm)
    t_o2, ok_o = jax.jit(buckets.chain_delete)(told, dels, dm)
    pend = dm & ~ok_o
    eq = (dels[:, None] == hk[None, :]) & hl[None, :]
    hz_hit = eq.any(-1) & pend & winner
    kill = (eq & hz_hit[:, None]).any(0)
    t_n2, ok_n = jax.jit(buckets.chain_delete)(tnew, dels, pend & ~hz_hit)
    np.testing.assert_array_equal(np.asarray(ok),
                                  np.asarray(ok_o | hz_hit | ok_n))
    np.testing.assert_array_equal(np.asarray(os_), np.asarray(t_o2.astate))
    np.testing.assert_array_equal(np.asarray(ns_), np.asarray(t_n2.astate))
    np.testing.assert_array_equal(np.asarray(hl2), np.asarray(hl & ~kill))


def test_nres_cap_overflow_graceful():
    """NRES_CAP overflow coverage: a 32x-growth rebuild target overflows
    the two-level tile map (more new-table blocks than NRES_CAP residents
    per tile), so SOME queries escape to the gated fallback — the contract
    is graceful degradation: the escape rate stays bounded, results stay
    exactly correct, and the structural budget never grows.  Pinning the
    precondition makes future NRES_CAP raises observable (retune this test
    when the cap covers 32x)."""
    rng = np.random.default_rng(3)
    told, keys, _ = _table(1 << 12, 3_000, seed=21)
    c_new = (1 << 12) * 32                      # 131072 slots = 32 slabs
    tnew = buckets.linear_make(c_new, hashing.fresh("mix32", 22),
                               max_probes=32)
    k2 = jnp.asarray(rng.choice(np.arange(10_000_000, 20_000_000), 3_000,
                                replace=False).astype(np.int32))
    tnew, _ = jax.jit(buckets.linear_insert)(tnew, k2, k2 * 9,
                                             jnp.ones(k2.shape, bool))
    hz = jnp.zeros(32, jnp.int32)
    qs = jnp.concatenate([keys[:1_500], k2[:1_500], jnp.asarray(
        rng.integers(2**30, 2**31 - 1, 1_096).astype(np.int32))])
    h0_old = hashing.bucket_of(told.hfn, qs, told.capacity)
    h0_new = hashing.bucket_of(tnew.hfn, qs, tnew.capacity)
    args = ((told.key, told.val, told.state), (tnew.key, tnew.val, tnew.state),
            hz, hz, jnp.zeros(32, bool), h0_old, h0_new, qs)
    # precondition: this growth genuinely exceeds the tile map's coverage
    nblocks_new = (-(-(c_new + 32) // ops.SLAB) + 1)
    assert nblocks_new - 1 > ops.NRES_CAP, \
        "NRES_CAP was raised; grow this test's target past the new coverage"
    rate = float(ops.rebuild_escape_rate(*args, max_probes=32))
    assert 0.0 < rate < 0.5, f"escape rate at 32x growth out of band: {rate}"
    # graceful: every escaped query is recovered exactly by the fallback
    f_ref, v_ref = ref.ordered_lookup_ref(*args, max_probes=32)
    f_k, v_k = ops.ordered_lookup_fused(*args, max_probes=32)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))
    # and the budget is unchanged — overflow never buys extra passes
    jx = jax.make_jaxpr(
        lambda *a: ops.ordered_lookup_fused(*a, max_probes=32))(*args)
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}


# ---------------------------------------------------------------------------
# compile-mode readiness (real-TPU lowering, CI-skippable)
# ---------------------------------------------------------------------------

def _tpu_available() -> bool:
    try:
        return any(d.platform == "tpu" for d in jax.devices())
    except RuntimeError:
        return False


@pytest.mark.skipif(not _tpu_available(),
                    reason="compile-mode (interpret=False) lowering needs a "
                           "TPU backend; CPU CI validates interpret mode")
def test_compile_mode_lowering_smoke():
    """Lower (do NOT execute) the probe-insert kernel and the new chain
    kernels with interpret=False: catches Mosaic lowering failures — the
    ROADMAP's known suspects are 1-D broadcasted_iota and bool block
    outputs — before real-TPU work starts."""
    import functools
    t, keys, _ = _table(1 << 12, 1_000, seed=13)
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    mask = jnp.ones(keys.shape, bool)
    jax.jit(functools.partial(ops.probe_insert, max_probes=32,
                              interpret=False)).lower(
        t.key, t.val, t.state, h0, keys, keys * 5, mask)

    tc, ckeys = _chain_table()
    tc2, _ = _chain_table(seed=2)
    b = hashing.bucket_of(tc.hfn, ckeys, tc.nbuckets)
    b2 = hashing.bucket_of(tc2.hfn, ckeys, tc2.nbuckets)
    parts, parts2 = buckets._chain_parts(tc), buckets._chain_parts(tc2)
    jax.jit(functools.partial(ops.chain_lookup_fused, max_chain=64,
                              interpret=False)).lower(*parts, b, ckeys)
    hk = jnp.zeros(64, jnp.int32)
    jax.jit(functools.partial(ops.chain_ordered_lookup, max_chain=64,
                              interpret=False)).lower(
        *parts, *parts2, hk, hk, jnp.zeros(64, bool), b, b2, ckeys)
