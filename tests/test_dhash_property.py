"""Property tests: DHash vs a dict oracle under randomized interleavings.

This is the SPMD analogue of the paper's linearizability argument (§5):
arbitrary batched lookup/insert/delete traffic interleaved at every point of
the rebuild protocol (start / extract / hazard-window / land / finish) must
observe exactly the oracle's membership and values — Lemmas 4.1/4.2/4.4.

The generator never re-inserts a currently-live key; the paper's own insert
has set-semantics in that corner (duplicate across old/new resolved at
migration, new copy wins) which is covered by an explicit unit test in
test_dhash_unit.py instead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import dhash

Q = 8            # fixed batch width (padded with mask) to avoid recompiles
KEYS = list(range(1, 33))

_op = st.sampled_from(["insert", "delete", "lookup", "extract", "land",
                       "start", "finish"])
_script = st.lists(st.tuples(_op, st.lists(st.sampled_from(KEYS), min_size=1,
                                           max_size=Q)),
                   min_size=4, max_size=40)


def _pad(keys: list[int]):
    ks = np.zeros(Q, np.int32)
    mask = np.zeros(Q, bool)
    ks[: len(keys)] = keys
    mask[: len(keys)] = True
    return jnp.asarray(ks), jnp.asarray(mask)


@pytest.fixture(scope="module")
def fns():
    return {
        "insert": jax.jit(dhash.insert),
        "delete": jax.jit(dhash.delete),
        "lookup": jax.jit(dhash.lookup),
        "extract": jax.jit(dhash.rebuild_extract),
        "land": jax.jit(dhash.rebuild_land),
        "done": jax.jit(dhash.rebuild_done),
    }


@pytest.mark.parametrize("backend", ["linear", "twochoice", "chain",
                                     "linear+fwd_hazard"])
@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(script=_script, seed=st.integers(0, 2**16))
def test_oracle_interleaved_rebuild(fns, backend, script, seed):
    fwd = backend.endswith("+fwd_hazard")
    backend = backend.split("+")[0]
    d = dhash.make(backend, capacity=128, chunk=16, seed=seed, fwd_hazard=fwd)
    oracle: dict[int, int] = {}
    vcounter = 100
    rebuilding = False

    for op, keys in script:
        if op == "insert":
            fresh = [k for k in dict.fromkeys(keys) if k not in oracle]
            if not fresh:
                continue
            ks, mask = _pad(fresh)
            vals = ks * 0 + jnp.arange(Q, dtype=jnp.int32) + vcounter
            d, ok = fns["insert"](d, ks, vals, mask)
            okn = np.asarray(ok)
            for i, k in enumerate(fresh):
                assert okn[i], (backend, "insert failed", k)
                oracle[k] = vcounter + i
            vcounter += Q
        elif op == "delete":
            ks, mask = _pad(list(dict.fromkeys(keys)))
            d, ok = fns["delete"](d, ks, mask)
            okn = np.asarray(ok)
            for i, k in enumerate(dict.fromkeys(keys)):
                assert okn[i] == (k in oracle), (backend, "delete", k)
                oracle.pop(k, None)
        elif op == "lookup":
            ks, mask = _pad(keys)
            found, vals = fns["lookup"](d, ks)
            fn_, vn = np.asarray(found), np.asarray(vals)
            for i, k in enumerate(keys):
                assert fn_[i] == (k in oracle), (backend, "lookup", k, oracle)
                if k in oracle:
                    assert vn[i] == oracle[k], (backend, "value", k)
        elif op == "start" and not rebuilding:
            d = dhash.rebuild_start(d, seed=seed + vcounter)
            rebuilding = True
        elif op == "extract" and rebuilding:
            d = fns["extract"](d)
        elif op == "land" and rebuilding:
            d = fns["land"](d)
        elif op == "finish" and rebuilding:
            if bool(jax.device_get(fns["done"](d))):
                d = dhash.rebuild_finish(d)
                rebuilding = False

    # quiesce and verify the complete final state
    if rebuilding:
        d = dhash.rebuild_all(d)
    ks, _ = _pad(KEYS[:Q])
    for chunk_start in range(0, len(KEYS), Q):
        group = KEYS[chunk_start: chunk_start + Q]
        ks, _ = _pad(group)
        found, vals = fns["lookup"](d, ks)
        for i, k in enumerate(group):
            assert bool(found[i]) == (k in oracle), (backend, "final", k)
            if k in oracle:
                assert int(vals[i]) == oracle[k]
    assert int(jax.device_get(dhash.count_items(d))) == len(oracle)


@pytest.mark.parametrize("backend", ["linear", "twochoice", "chain"])
@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(keys=st.lists(st.sampled_from(KEYS), min_size=2, max_size=Q),
       seed=st.integers(0, 999))
def test_batch_duplicate_inserts_one_winner(fns, backend, keys, seed):
    """Within one batch, duplicate keys: exactly one insert wins (the
    deterministic linearization of the paper's concurrent threads)."""
    d = dhash.make(backend, capacity=64, chunk=8, seed=seed)
    ks, mask = _pad(keys)
    vals = jnp.arange(Q, dtype=jnp.int32) * 10
    d, ok = fns["insert"](d, ks, vals, mask)
    okn = np.asarray(ok)[: len(keys)]
    from collections import Counter
    c = Counter(keys)
    # one winner per distinct key
    assert okn.sum() == len(c)
    # winner is the first occurrence
    seen = set()
    for i, k in enumerate(keys):
        if k not in seen:
            assert okn[i], (backend, i, keys)
            seen.add(k)
        else:
            assert not okn[i], (backend, i, keys)
    # and the stored value is the winner's value
    found, vals_out = fns["lookup"](d, ks)
    first_idx = {}
    for i, k in enumerate(keys):
        first_idx.setdefault(k, i)
    for k, i in first_idx.items():
        j = keys.index(k)
        assert int(vals_out[j]) == j * 10
