"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle,
exactly as specified — assert_allclose per cell (exact for int compare)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import count_primitives as _count_primitives
from repro.core import buckets, dhash, hashing
from repro.kernels import ops, ref


def _table(capacity, n_items, seed, max_probes=32, deletes=0):
    rng = np.random.default_rng(seed)
    t = buckets.linear_make(capacity, hashing.fresh("mix32", seed),
                            max_probes=max_probes)
    keys = jnp.asarray(rng.choice(10_000_000, size=n_items, replace=False)
                       .astype(np.int32))
    t, ok = jax.jit(buckets.linear_insert)(t, keys, keys * 3,
                                           jnp.ones(keys.shape, bool))
    if deletes:
        t, _ = jax.jit(buckets.linear_delete)(t, keys[:deletes],
                                              jnp.ones(deletes, bool))
    return t, keys, np.asarray(ok)


@pytest.mark.parametrize("capacity,n_items,n_queries", [
    (1 << 10, 500, 333),          # small, non-tile-aligned query count
    (1 << 14, 9_000, 4_096),      # multi-tile
    (1 << 15, 20_000, 10_001),    # odd query count, several slabs
])
def test_probe_lookup_matches_ref(capacity, n_items, n_queries):
    t, keys, ok = _table(capacity, n_items, seed=capacity % 97)
    rng = np.random.default_rng(1)
    qs = jnp.concatenate([
        keys[: min(n_items, n_queries // 2)],
        jnp.asarray(rng.integers(10_000_000, 2**31 - 1, n_queries)
                    .astype(np.int32))])[:n_queries]
    h0 = hashing.bucket_of(t.hfn, qs, t.capacity)
    f_ref, v_ref = ref.probe_lookup_ref(t.key, t.val, t.state, h0, qs, 32)
    f_k, v_k = ops.probe_lookup(t.key, t.val, t.state, h0, qs, max_probes=32)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))


def test_probe_lookup_with_tombstones():
    t, keys, _ = _table(1 << 13, 4_000, seed=3, deletes=1_000)
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    f_ref, v_ref = ref.probe_lookup_ref(t.key, t.val, t.state, h0, keys, 64)
    f_k, v_k = ops.probe_lookup(t.key, t.val, t.state, h0, keys, max_probes=64)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    assert int(f_k.sum()) == 3_000


def test_probe_lookup_adversarial_skew():
    """All queries hash into one region (the paper's collision attack):
    the slab fallback path must stay exact."""
    t = buckets.linear_make(1 << 14, hashing.fresh("mix32", 0), max_probes=64)
    # force a dense contiguous run by inserting colliding-by-construction keys
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.choice(1_000_000, 3000, replace=False).astype(np.int32))
    t, _ = jax.jit(buckets.linear_insert)(t, keys, keys, jnp.ones(3000, bool))
    qs = jnp.tile(keys[:128], 32)                     # heavy duplicate queries
    h0 = hashing.bucket_of(t.hfn, qs, t.capacity)
    f_ref, v_ref = ref.probe_lookup_ref(t.key, t.val, t.state, h0, qs, 64)
    f_k, v_k = ops.probe_lookup(t.key, t.val, t.state, h0, qs, max_probes=64)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))


def _ordered_args(n_old=1_500, n_new=1_200, n_q=4_096, hazard=64, seed=7):
    rng = np.random.default_rng(seed)
    told, keys, _ = _table(1 << 12, n_old, seed=11)
    tnew, keys2, _ = _table(1 << 12, n_new, seed=12)
    hk = jnp.asarray(rng.choice(10_000_000, hazard, replace=False).astype(np.int32))
    hv = hk * 7
    hl = jnp.asarray(rng.random(hazard) < 0.7)
    qs = jnp.concatenate([keys, keys2, hk,
                          jnp.asarray(rng.integers(2**30, 2**31 - 1, n_q)
                                      .astype(np.int32))])[:n_q]
    h0_old = hashing.bucket_of(told.hfn, qs, told.capacity)
    h0_new = hashing.bucket_of(tnew.hfn, qs, tnew.capacity)
    return ((told.key, told.val, told.state), (tnew.key, tnew.val, tnew.state),
            hk, hv, hl, h0_old, h0_new, qs)


def test_fused_rebuild_lookup_single_sort_single_pallas_call():
    """Acceptance: during an active rebuild the fused lookup path executes
    exactly ONE argsort and ONE pallas_call per batch; the unfused path pays
    at least two of each (old pass + new pass)."""
    args = _ordered_args(n_q=4_096)
    fused = jax.make_jaxpr(
        lambda *a: ops.ordered_lookup_fused(*a, max_probes=32))(*args)
    unfused = jax.make_jaxpr(
        lambda *a: ops.ordered_lookup(*a, max_probes=32))(*args)
    nf = _count_primitives(fused, ("sort", "pallas_call"))
    nu = _count_primitives(unfused, ("sort", "pallas_call"))
    assert nf == {"sort": 1, "pallas_call": 1}, nf
    assert nu["sort"] >= 2 and nu["pallas_call"] >= 2, nu
    # pass-count reduction is the interpret-mode proxy for the >=1.5x
    # rebuild-epoch throughput criterion (see bench_rebuild --fused)
    passes_u = nu["sort"] + nu["pallas_call"]
    passes_f = nf["sort"] + nf["pallas_call"]
    assert passes_u / passes_f >= 1.5


def test_probe2_matches_ref():
    """Fused two-table+hazard kernel == ordered oracle (multi-tile batch with
    duplicates and hazard hits)."""
    args = _ordered_args(n_q=4_096)
    f_ref, v_ref = ref.ordered_lookup_ref(*args, max_probes=32)
    f_k, v_k = ops.ordered_lookup_fused(*args, max_probes=32)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))


def test_probe2_skew_forced_fallback():
    """A large new table makes per-tile new-slab windows miss (h0_new is
    scattered while the shared sort is keyed on h0_old): complete=False
    queries must be recovered exactly by the gated fallback; duplicate query
    keys ride along."""
    rng = np.random.default_rng(3)
    told, keys, _ = _table(1 << 12, 1_000, seed=21)
    tnew = buckets.linear_make(1 << 15, hashing.fresh("mix32", 22), max_probes=32)
    k2 = jnp.asarray(rng.choice(10_000_000, 5_000, replace=False).astype(np.int32))
    tnew, _ = jax.jit(buckets.linear_insert)(tnew, k2, k2 * 9,
                                             jnp.ones(k2.shape, bool))
    hz = jnp.zeros(32, jnp.int32)
    qs = jnp.concatenate([k2[:2000], jnp.tile(k2[:128], 8), keys])
    h0_old = hashing.bucket_of(told.hfn, qs, told.capacity)
    h0_new = hashing.bucket_of(tnew.hfn, qs, tnew.capacity)
    args = ((told.key, told.val, told.state), (tnew.key, tnew.val, tnew.state),
            hz, hz, jnp.zeros(32, bool), h0_old, h0_new, qs)
    f_ref, v_ref = ref.ordered_lookup_ref(*args, max_probes=32)
    f_k, v_k = ops.ordered_lookup_fused(*args, max_probes=32)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))


def test_probe_insert_matches_oracle_low_load():
    """Claim kernel == insert oracle at low load: identical ok flags, every
    inserted key readable with its value, live-count conserved."""
    rng = np.random.default_rng(5)
    t = buckets.linear_make(1 << 13, hashing.fresh("mix32", 5), max_probes=32)
    keys = jnp.asarray(rng.choice(1_000_000, 3_000, replace=False).astype(np.int32))
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    mask = jnp.ones(keys.shape, bool)
    tk, tv, ts, ok = ops.probe_insert(t.key, t.val, t.state, h0, keys,
                                      keys * 5, mask, max_probes=32)
    _, _, ts_ref, ok_ref = ref.probe_insert_ref(t.key, t.val, t.state, h0,
                                                keys, keys * 5, mask, 32)
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(ok_ref))
    assert bool(ok.all())
    assert int((ts == 1).sum()) == int((ts_ref == 1).sum()) == 3_000
    f, v = ref.probe_lookup_ref(tk, tv, ts, h0, keys, 32)
    assert bool(f.all()) and bool((v == keys * 5).all())


def test_probe_insert_duplicates_and_existing():
    """buckets.linear_insert_fused (winner dedup + kernel) must agree with
    the jnp linear_insert on every observable: ok counts per key, final
    membership, values."""
    rng = np.random.default_rng(9)
    base = jnp.asarray(rng.choice(1_000_000, 500, replace=False).astype(np.int32))
    t0 = buckets.linear_make(1 << 12, hashing.fresh("mix32", 1), max_probes=32)
    t0, _ = jax.jit(buckets.linear_insert)(t0, base, base * 2,
                                           jnp.ones(base.shape, bool))
    # batch: duplicates of new keys, re-inserts of existing keys, masked-out
    fresh = jnp.asarray(rng.choice(np.arange(2_000_000, 3_000_000), 400,
                                   replace=False).astype(np.int32))
    batch = jnp.concatenate([fresh, fresh[:200], base[:100]])
    vals = batch * 3
    mask = jnp.ones(batch.shape, bool).at[-50:].set(False)
    t_j, ok_j = jax.jit(buckets.linear_insert)(t0, batch, vals, mask)
    t_k, ok_k = jax.jit(buckets.linear_insert_fused)(t0, batch, vals, mask)
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_j))
    assert int(buckets.linear_count_live(t_k)) == int(buckets.linear_count_live(t_j))
    probe = jnp.concatenate([base, fresh])
    f_j, v_j, _ = buckets.linear_lookup(t_j, probe)
    f_k, v_k, _ = buckets.linear_lookup(t_k, probe)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_j))
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_j))


def test_probe_insert_full_table_pressure():
    """Near-capacity insert with a short probe bound: successful claims are
    readable, failures genuinely exhausted their windows, no slot double-
    claimed (live count == ok count)."""
    rng = np.random.default_rng(4)
    t = buckets.linear_make(1 << 10, hashing.fresh("mix32", 5), max_probes=16)
    keys = jnp.asarray(rng.choice(1_000_000, 1_200, replace=False).astype(np.int32))
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    mask = jnp.ones(keys.shape, bool)
    tk, tv, ts, ok = ops.probe_insert(t.key, t.val, t.state, h0, keys, keys,
                                      mask, max_probes=16)
    _, _, _, ok_ref = ref.probe_insert_ref(t.key, t.val, t.state, h0, keys,
                                           keys, mask, 16)
    # claim order is a different (equally legal) linearization than the
    # oracle's, so the totals may differ by a whisker under contention
    assert abs(int(ok.sum()) - int(ok_ref.sum())) <= 5
    assert int((ts == 1).sum()) == int(ok.sum())       # no double-claims
    f, v = ref.probe_lookup_ref(tk, tv, ts, h0, keys, 16)
    assert bool(f[ok].all()) and bool((v[ok] == keys[ok]).all())
    assert not bool(f[~ok].any())                       # failures not inserted


def test_ordered_lookup_fused_matches_ref():
    """The fused old->hazard->new kernel path == ordered_lookup_ref."""
    rng = np.random.default_rng(7)
    told, keys, _ = _table(1 << 12, 1_500, seed=11)
    tnew, keys2, _ = _table(1 << 12, 1_200, seed=12)
    hk = jnp.asarray(rng.choice(10_000_000, 64, replace=False).astype(np.int32))
    hv = hk * 7
    hl = jnp.asarray(rng.random(64) < 0.7)
    qs = jnp.concatenate([keys[:500], keys2[:500], hk,
                          jnp.asarray(rng.integers(2**30, 2**31 - 1, 300)
                                      .astype(np.int32))])
    h0_old = hashing.bucket_of(told.hfn, qs, told.capacity)
    h0_new = hashing.bucket_of(tnew.hfn, qs, tnew.capacity)
    args = ((told.key, told.val, told.state), (tnew.key, tnew.val, tnew.state),
            hk, hv, hl, h0_old, h0_new, qs)
    f_ref, v_ref = ref.ordered_lookup_ref(*args, max_probes=32)
    f_k, v_k = ops.ordered_lookup(*args, max_probes=32)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))


# ---------------------------------------------------------------------------
# write-path kernels: delete / extract / land (PR 2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("capacity,n_items,n_del", [
    (1 << 10, 500, 333),          # small, non-tile-aligned delete count
    (1 << 14, 9_000, 4_097),      # multi-tile, odd count
])
def test_probe_delete_matches_jnp(capacity, n_items, n_del):
    """Fused delete == jnp delete on every observable: ok flags, final
    states, membership — batch mixes present keys, absent keys, duplicates,
    and masked-out entries; batch size is not a tile multiple."""
    t, keys, _ = _table(capacity, n_items, seed=capacity % 89)
    rng = np.random.default_rng(2)
    absent = jnp.asarray(rng.integers(20_000_000, 2**31 - 1, n_del // 3)
                         .astype(np.int32))
    batch = jnp.concatenate([keys[:n_del], absent, keys[:64]])[:n_del]
    mask = jnp.ones(batch.shape, bool).at[-17:].set(False)
    t_j, ok_j = jax.jit(buckets.linear_delete)(t, batch, mask)
    t_k, ok_k = jax.jit(buckets.linear_delete_fused)(t, batch, mask)
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_j))
    np.testing.assert_array_equal(np.asarray(t_k.state), np.asarray(t_j.state))
    f_j, _, _ = buckets.linear_lookup(t_j, keys)
    f_k, _, _ = buckets.linear_lookup(t_k, keys)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_j))


def test_probe_delete_tombstone_reuse():
    """Slots freed by the fused delete are reclaimed by the fused insert:
    live count conserved, and every re-inserted key readable."""
    t = buckets.linear_make(256, hashing.fresh("mix32", 0), max_probes=32)
    k = jnp.arange(1, 181, dtype=jnp.int32)
    t, _ = jax.jit(buckets.linear_insert)(t, k, k * 2, jnp.ones(180, bool))
    t, ok_d = jax.jit(buckets.linear_delete_fused)(t, k[:90],
                                                   jnp.ones(90, bool))
    assert bool(ok_d.all())
    assert int((t.state == 2).sum()) == 90          # TOMB
    k2 = jnp.arange(1000, 1090, dtype=jnp.int32)
    t, ok_i = jax.jit(buckets.linear_insert_fused)(t, k2, k2 * 3,
                                                   jnp.ones(90, bool))
    assert bool(ok_i.all())                          # tombstones reused
    assert int(buckets.linear_count_live(t)) == 180
    f, v, _ = buckets.linear_lookup(t, k2)
    assert bool(f.all()) and bool((v == k2 * 3).all())


def test_write_kernels_budget():
    """Budget: each new write-path op is ONE argsort + ONE pallas_call
    (extract needs no sort at all — the chunk window is already
    contiguous)."""
    t, keys, _ = _table(1 << 12, 1_000, seed=13)
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    mask = jnp.ones(keys.shape, bool)

    jx = jax.make_jaxpr(
        lambda *a: ops.probe_delete(*a, max_probes=32))(
        t.key, t.val, t.state, h0, keys, mask)
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}

    args = _ordered_args(n_q=2_048)
    jx = jax.make_jaxpr(
        lambda *a: ops.ordered_delete_fused(*a, max_probes=32))(
        *args, jnp.ones(args[-1].shape, bool))
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}

    jx = jax.make_jaxpr(
        lambda k, v, s, c: ops.extract_chunk_fused(k, v, s, c, chunk=256))(
        t.key, t.val, t.state, jnp.asarray(0, jnp.int32))
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 0, "pallas_call": 1}

    tc = buckets.twochoice_make(1 << 9, hashing.fresh("mix32", 1),
                                hashing.fresh("mix32", 2), width=8)
    ba, bb = buckets._tc_rows(tc, keys)
    jx = jax.make_jaxpr(ops.twochoice_lookup)(
        tc.key, tc.val, tc.state, ba, bb, keys)
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}

    jx = jax.make_jaxpr(
        lambda *a: ops.twochoice_insert(*a, max_rounds=8))(
        tc.key, tc.val, tc.state, ba, bb, keys, keys * 2, mask)
    assert _count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}


@pytest.mark.parametrize("cursor", [0, 100, 4_000, 4_090, 8_100])
def test_extract_chunk_fused_matches_jnp(cursor):
    """Fused extract == jnp extract as a SET (the fused hazard buffer is
    compacted on-device), with identical MIGRATED markings and cursor
    advance — cursor positions cover the slab seam and the table edge."""
    t, keys, _ = _table(1 << 13, 4_000, seed=5, deletes=500)
    cur = jnp.asarray(cursor, jnp.int32)
    t_j, hk_j, hv_j, hl_j, cur_j = jax.jit(
        lambda t, c: buckets.linear_extract_chunk(t, c, 256))(t, cur)
    t_k, hk_k, hv_k, hl_k, cur_k = jax.jit(
        lambda t, c: buckets.linear_extract_chunk_fused(t, c, 256))(t, cur)
    np.testing.assert_array_equal(np.asarray(t_k.state),
                                  np.asarray(t_j.state))
    assert int(cur_j) == int(cur_k)
    lj, lk = np.asarray(hl_j), np.asarray(hl_k)
    set_j = set(zip(np.asarray(hk_j)[lj].tolist(),
                    np.asarray(hv_j)[lj].tolist()))
    set_k = set(zip(np.asarray(hk_k)[lk].tolist(),
                    np.asarray(hv_k)[lk].tolist()))
    assert set_j == set_k
    # compaction: live entries are a prefix
    assert (np.flatnonzero(lk) == np.arange(lk.sum())).all()


def test_ordered_delete_fused_matches_staged():
    """Mid-rebuild fused delete (ONE probe2 pass) == the staged jnp ordered
    delete on ok flags, remaining membership, and item counts — the batch
    hits old-table keys, hazard keys, new-table keys, and absent keys."""
    rng = np.random.default_rng(8)
    d_j = dhash.make("linear", capacity=1024, chunk=128, seed=5, fused=False)
    d_k = dhash.make("linear", capacity=1024, chunk=128, seed=5, fused=True)
    keys = jnp.asarray(rng.choice(100_000, 800, replace=False)
                       .astype(np.int32))
    ins = jax.jit(dhash.insert)
    d_j, _ = ins(d_j, keys, keys * 2)
    d_k, _ = ins(d_k, keys, keys * 2)
    d_j = dhash.rebuild_start(d_j, seed=9)
    d_k = dhash.rebuild_start(d_k, seed=9)
    step = jax.jit(dhash.rebuild_step)
    for _ in range(3):   # extract, land, extract -> populated hazard window
        d_j, d_k = step(d_j), step(d_k)
    assert bool(d_k.hazard_live.any())
    batch = jnp.concatenate([
        keys[::3], jnp.asarray(rng.integers(200_000, 300_000, 101)
                               .astype(np.int32))])
    dl = jax.jit(dhash.delete)
    d_j2, ok_j = dl(d_j, batch)
    d_k2, ok_k = dl(d_k, batch)
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_j))
    assert int(dhash.count_items(d_j2)) == int(dhash.count_items(d_k2))
    look = jax.jit(dhash.lookup)
    f_j, v_j = look(d_j2, keys)
    f_k, v_k = look(d_k2, keys)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_j))
    fm = np.asarray(f_j)
    np.testing.assert_array_equal(np.asarray(v_k)[fm], np.asarray(v_j)[fm])


@pytest.mark.parametrize("backend,fused", [
    ("linear", True), ("twochoice", True), ("chain", False),
])
def test_delete_extract_land_parity_all_backends(backend, fused):
    """The full write surface (delete + extract + land + swap) against a
    dict oracle for every backend — linear/twochoice on the fused kernels,
    chain as the documented jnp reference."""
    rng = np.random.default_rng(3)
    d = dhash.make(backend, capacity=512, chunk=64, seed=7, fused=fused)
    oracle: dict[int, int] = {}
    keys = rng.choice(100_000, 301, replace=False).astype(np.int32)  # odd N
    d, ok = jax.jit(dhash.insert)(d, jnp.asarray(keys), jnp.asarray(keys * 2))
    assert bool(ok.all())
    oracle.update({int(k): int(k) * 2 for k in keys})
    d = dhash.rebuild_start(d, seed=31)
    step = jax.jit(dhash.rebuild_step)
    dl = jax.jit(dhash.delete)
    look = jax.jit(dhash.lookup)
    i = 0
    while bool(jax.device_get(d.rebuilding)) and i < 64:
        d = step(d)                       # extract or land
        dels = keys[i::16][:5]            # delete during the hazard window
        d, ok_d = dl(d, jnp.asarray(dels))
        expect = np.array([int(k) in oracle for k in dels])
        np.testing.assert_array_equal(np.asarray(ok_d), expect)
        for k in dels:
            oracle.pop(int(k), None)
        if bool(jax.device_get(dhash.rebuild_done(d))):
            d = dhash.rebuild_finish(d)
        i += 1
    assert int(d.epoch) == 1, "rebuild did not complete"
    assert int(dhash.count_items(d)) == len(oracle)
    f, v = look(d, jnp.asarray(keys))
    expect_f = np.array([int(k) in oracle for k in keys])
    np.testing.assert_array_equal(np.asarray(f), expect_f)
    np.testing.assert_array_equal(np.asarray(v)[expect_f],
                                  np.array([oracle[int(k)] for k in keys
                                            if int(k) in oracle]))


def test_tc_lookup_fused_matches_jnp():
    """Fused twochoice lookup == jnp on found/loc everywhere and val where
    found (the jnp path leaves val undefined for misses); odd batch size."""
    rng = np.random.default_rng(4)
    tc = buckets.twochoice_make(1 << 9, hashing.fresh("mix32", 1),
                                hashing.fresh("mix32", 2), width=8)
    k = jnp.asarray(rng.choice(1_000_000, 1_500, replace=False)
                    .astype(np.int32))
    tc, _ = jax.jit(buckets.twochoice_insert)(tc, k, k * 5,
                                              jnp.ones(1_500, bool))
    qs = jnp.concatenate([k, jnp.asarray(
        rng.integers(2_000_000, 3_000_000, 501).astype(np.int32))])
    f_j, v_j, l_j = jax.jit(buckets.twochoice_lookup)(tc, qs)
    f_k, v_k, l_k = jax.jit(buckets.twochoice_lookup_fused)(tc, qs)
    fm = np.asarray(f_j)
    np.testing.assert_array_equal(np.asarray(f_k), fm)
    np.testing.assert_array_equal(np.asarray(v_k)[fm], np.asarray(v_j)[fm])
    np.testing.assert_array_equal(np.asarray(l_k)[fm], np.asarray(l_j)[fm])
    assert (np.asarray(l_k)[~fm] == -1).all()


def test_tc_insert_delete_fused_matches_jnp():
    """Fused twochoice insert/delete == jnp on ok flags, live counts, and
    membership, with duplicate keys, re-inserts, and masked-out entries;
    the fused delete reuses the lookup kernel's loc output (no re-probe)."""
    rng = np.random.default_rng(9)
    tc = buckets.twochoice_make(1 << 9, hashing.fresh("mix32", 1),
                                hashing.fresh("mix32", 2), width=8)
    base = jnp.asarray(rng.choice(1_000_000, 900, replace=False)
                       .astype(np.int32))
    tc, _ = jax.jit(buckets.twochoice_insert)(tc, base, base * 5,
                                              jnp.ones(900, bool))
    fresh = jnp.asarray(rng.choice(np.arange(2_000_000, 3_000_000), 400,
                                   replace=False).astype(np.int32))
    batch = jnp.concatenate([fresh, fresh[:100], base[:100]])
    mask = jnp.ones(batch.shape, bool).at[-30:].set(False)
    t_j, ok_j = jax.jit(buckets.twochoice_insert)(tc, batch, batch * 7, mask)
    t_k, ok_k = jax.jit(buckets.twochoice_insert_fused)(tc, batch,
                                                        batch * 7, mask)
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_j))
    assert int(buckets.twochoice_count_live(t_k)) == \
        int(buckets.twochoice_count_live(t_j))
    probe = jnp.concatenate([base, fresh])
    f_j, v_j, _ = buckets.twochoice_lookup(t_j, probe)
    f_k, v_k, _ = buckets.twochoice_lookup(t_k, probe)
    fm = np.asarray(f_j)
    np.testing.assert_array_equal(np.asarray(f_k), fm)
    np.testing.assert_array_equal(np.asarray(v_k)[fm], np.asarray(v_j)[fm])

    dels = jnp.concatenate([base[:300], jnp.asarray(
        rng.integers(4_000_000, 5_000_000, 101).astype(np.int32))])
    dm = jnp.ones(dels.shape, bool)
    td_j, okd_j = jax.jit(buckets.twochoice_delete)(t_j, dels, dm)
    td_k, okd_k = jax.jit(buckets.twochoice_delete_fused)(t_k, dels, dm)
    np.testing.assert_array_equal(np.asarray(okd_k), np.asarray(okd_j))
    assert int(buckets.twochoice_count_live(td_k)) == \
        int(buckets.twochoice_count_live(td_j))


def test_land_fused_uses_insert_kernel():
    """rebuild_land on a fused state routes through the claim kernel: the
    landed epoch conserves membership, and the jaxpr of the fused landing
    contains a pallas_call (the jnp landing has none)."""
    d = dhash.make("linear", capacity=512, chunk=64, seed=2, fused=True)
    d_j = dhash.make("linear", capacity=512, chunk=64, seed=2, fused=False)
    jx_f = jax.make_jaxpr(dhash.rebuild_land)(d)
    jx_j = jax.make_jaxpr(dhash.rebuild_land)(d_j)
    assert _count_primitives(jx_f, ("pallas_call",))["pallas_call"] >= 1
    assert _count_primitives(jx_j, ("pallas_call",))["pallas_call"] == 0
