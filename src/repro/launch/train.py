"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

Production posture (documented here, exercised at laptop scale):
* deterministic stateless data pipeline -> a restart at step N replays the
  exact stream on any host count (elasticity without iterator state);
* atomic checkpoints every --ckpt-every steps; on boot the driver restores
  the newest checkpoint if present (crash/preemption recovery path);
* straggler watchdog: per-step wall time is tracked against a rolling
  median; steps > --straggler-factor x median are logged with the step
  payload so a hung host is visible immediately (on a real cluster this is
  where you fence the slow worker and let the elastic restore re-mesh);
* hash-router MoE archs: expert-load skew triggers a LIVE DHash rebuild of
  the router override table (the paper's attack response) — training never
  pauses.

On a real multi-host TPU cluster this same file runs under
``jax.distributed.initialize()`` with the production mesh from mesh.py; on
CPU it uses a host mesh over however many devices exist.
"""
from __future__ import annotations

import argparse
import statistics
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import DataConfig, synth_batch, synth_embeds
from repro.launch.mesh import make_host_mesh
from repro.models.sharding import activation_ctx, param_shardings
from repro.optim.optimizer import OptConfig
from repro.train import checkpoint as ckpt_lib
from repro.train import train_step as ts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(args.steps // 20, 1),
                        grad_compression=args.grad_compression)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)
    mesh = make_host_mesh()

    state = ts.init_state(cfg, opt_cfg, jax.random.PRNGKey(args.seed))
    start_step = 0
    if args.ckpt_dir and ckpt_lib.latest_step(args.ckpt_dir) is not None:
        shards = {"params": param_shardings(state["params"], mesh, fsdp=cfg.fsdp)}
        state, start_step = ckpt_lib.restore(args.ckpt_dir, state)
        print(f"[restore] resumed from step {start_step}")

    with mesh, activation_ctx(mesh):
        step_fn = jax.jit(partial(ts.train_step, cfg=cfg, opt_cfg=opt_cfg),
                          donate_argnums=0)

        times: list[float] = []
        for step in range(start_step, args.steps):
            batch = synth_batch(dcfg, step, mrope=cfg.mrope_sections is not None)
            if cfg.frontend == "stub_embed":
                batch["embeds"] = synth_embeds(dcfg, step, cfg.d_model,
                                               dtype=jnp.dtype(cfg.dtype))
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.time() - t0
            times.append(dt)
            med = statistics.median(times[-20:])
            if len(times) > 5 and dt > args.straggler_factor * med:
                print(f"[straggler] step {step}: {dt:.2f}s vs median {med:.2f}s")
            if cfg.use_hash_router:
                state = ts.rebalance_router(state, metrics["expert_load"], cfg)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(jax.device_get(metrics['grad_norm'])):.3f} "
                      f"({dt:.2f}s)")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                path = ckpt_lib.save(args.ckpt_dir, step + 1, state,
                                     extra={"arch": cfg.arch_id,
                                            "mesh": list(mesh.devices.shape)})
                print(f"[ckpt] {path}")
    print("done.")
    return state


if __name__ == "__main__":
    main()
