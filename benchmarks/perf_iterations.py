import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> re-analyse.

Three cells (selection rationale in EXPERIMENTS.md §Perf):
  * rwkv6-3b  x train_4k   — worst roofline fraction (time-step scan stash)
  * gemma3-27b x train_4k  — most collective-bound (t_coll/t_mem highest)
  * dhash-paper x service  — the paper's own technique at scale

Each iteration lowers the SAME cell with one config change on the single-pod
mesh and reports the three roofline terms; results append to
benchmarks/results/perf_iterations.json.  The baseline rows come from the
full sweep (paper-faithful configs).
"""
import json
import time


from repro import configs
from repro.launch import analysis, hlo_cost, shapes as shp
from repro.launch.dryrun import lower_train, lower_dhash_service
from repro.launch.mesh import make_production_mesh

OUT = os.path.join(os.path.dirname(__file__), "results",
                   "perf_iterations.json")


def roofline_of(lowered, chips, model_flops):
    compiled = lowered.compile()
    cost = hlo_cost.analyze(compiled.as_text())
    rl = analysis.Roofline(chips=chips, hlo_flops=cost.flops * chips,
                           hlo_bytes=cost.bytes * chips,
                           coll_bytes=cost.coll_bytes * chips,
                           model_flops=model_flops)
    return rl, cost


def train_cell(arch, overrides, sp_name="train_4k"):
    cfg = configs.get_config(arch).scaled(**overrides)
    sp = shp.SHAPES[sp_name]
    mesh = make_production_mesh()
    lowered = lower_train(cfg, sp, mesh)
    mf = 6 * cfg.param_count(active_only=True) * sp.global_batch * sp.seq_len
    return roofline_of(lowered, mesh.devices.size, mf)


def service_cell(overrides):
    import dataclasses
    scfg = dataclasses.replace(configs.get_config("dhash-paper"), **overrides)
    mesh = make_production_mesh()
    lowered = lower_dhash_service(mesh, scfg)
    return roofline_of(lowered, mesh.devices.size, 0.0)


ITERATIONS = [
    # --- cell 1: rwkv6-3b train_4k (memory-bound: per-step scan stash) -----
    dict(cell="rwkv6-3b/train_4k", name="baseline",
         hypothesis="paper-faithful per-step wkv scan; bwd stashes one "
                    "f32[B,NH,HS,HS] state per timestep -> memory term "
                    "dominated by 4096-deep stash + per-step buffers",
         fn=lambda: train_cell("rwkv6-3b", {})),
    dict(cell="rwkv6-3b/train_4k", name="wkv_chunk128",
         hypothesis="remat the recurrence in 128-step chunks: stash shrinks "
                    "S/chunk=32x on states; per-step bwd buffers recomputed; "
                    "predict t_mem down >10x for ~1.5x extra recompute flops",
         fn=lambda: train_cell("rwkv6-3b", {"rwkv_chunk": 128})),
    dict(cell="rwkv6-3b/train_4k", name="wkv_chunk512",
         hypothesis="bigger chunks: fewer boundary states (8 saves) but "
                    "inner recompute span 512 -> more live per-chunk temps; "
                    "predict mild further t_mem change, direction unclear",
         fn=lambda: train_cell("rwkv6-3b", {"rwkv_chunk": 512})),
    # --- cell 2: gemma3-27b train_4k (collective-bound) ---------------------
    dict(cell="gemma3-27b/train_4k", name="baseline",
         hypothesis="3 separate q/k/v projections -> 3 bwd dx all-reduces of "
                    "[B,S,D] per layer; 2 more from gate/up; plus "
                    "remat-recomputed fwd psums",
         fn=lambda: train_cell("gemma3-27b", {})),
    dict(cell="gemma3-27b/train_4k", name="fused_qkv",
         hypothesis="one QKV matmul -> one dx AR instead of 3: predict "
                    "qkv-bwd AR bytes (2.6e11/chip, 37%% of coll) drop ~3x",
         fn=lambda: train_cell("gemma3-27b", {"fused_qkv": True})),
    dict(cell="gemma3-27b/train_4k", name="fused_qkv+gate_up",
         hypothesis="also fuse gate|up -> one dx AR instead of 2: predict "
                    "another ~8.7e10/chip off the collective term",
         fn=lambda: train_cell("gemma3-27b", {"fused_qkv": True,
                                              "fused_gate_up": True})),
    dict(cell="gemma3-27b/train_4k", name="fused+dots_remat",
         hypothesis="remat policy saves einsum outputs: kills the "
                    "recomputed fwd psums (1 AR/layer) and recompute flops, "
                    "trading activation memory; predict t_coll down ~15%%, "
                    "t_comp down ~25%%, t_mem up",
         fn=lambda: train_cell("gemma3-27b", {"fused_qkv": True,
                                              "fused_gate_up": True,
                                              "remat_policy": "dots"})),
    # --- cell 3: dhash-paper service (the paper's technique) ---------------
    dict(cell="dhash-paper/service", name="baseline",
         hypothesis="overflow-proof routing buffers [S,Q]: every shard "
                    "receives S*Q candidate slots though only Q/S are real "
                    "-> S x wasted probe work and wire bytes",
         fn=lambda: service_cell({})),
    dict(cell="dhash-paper/service", name="route_cap4",
         hypothesis="cap routing buffers at 4*Q/S: wire bytes and remote "
                    "batch sizes drop S/4=4x; predict t_mem ~4x down "
                    "(probe work scales with received batch)",
         fn=lambda: service_cell({"route_cap_factor": 4.0})),
    dict(cell="dhash-paper/service", name="route_cap2",
         hypothesis="tighter cap 2*Q/S: another 2x on buffers; overflow "
                    "probability still negligible for the uniform owner "
                    "hash (binomial tail)",
         fn=lambda: service_cell({"route_cap_factor": 2.0})),
]


def main():
    rows = []
    for it in ITERATIONS:
        t0 = time.time()
        rl, cost = it["fn"]()
        rec = {"cell": it["cell"], "iter": it["name"],
               "hypothesis": it["hypothesis"], **rl.to_dict(),
               "compile_s": round(time.time() - t0, 1),
               "top_bytes": cost.top_bytes(6)}
        rows.append(rec)
        print(f"[{it['cell']:24s}] {it['name']:20s} "
              f"t_comp={rl.t_compute:8.3f} t_mem={rl.t_memory:8.3f} "
              f"t_coll={rl.t_collective:8.3f} mfu={rl.mfu:.4f} "
              f"({rec['compile_s']:.0f}s)", flush=True)
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(rows, f, indent=1, default=float)
    print("wrote", OUT)


if __name__ == "__main__":
    main()
