"""Unit tests for the DHash core: rebuild protocol invariants (the paper's
Fig 1 walkthrough), the hazard window, the ordered check, and the
set-semantics corner the paper resolves at migration time."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buckets, dhash

BACKENDS = ["linear", "twochoice", "chain"]


def _ins(d, keys, vals=None):
    keys = jnp.asarray(keys, jnp.int32)
    vals = keys * 10 if vals is None else jnp.asarray(vals, jnp.int32)
    return jax.jit(dhash.insert)(d, keys, vals)


@pytest.mark.parametrize("backend", BACKENDS)
def test_fig1_walkthrough(backend):
    """Reproduce the paper's Figure 1 state sequence with observable states."""
    d = dhash.make(backend, capacity=64, chunk=64, seed=1)
    d, ok = _ins(d, [1, 2, 3, 4, 5])           # {a..e}
    assert bool(ok.all())

    d = dhash.rebuild_start(d, seed=99)        # Fig 1b: new table exists
    d = jax.jit(dhash.rebuild_extract)(d)      # Fig 1c: chunk in hazard period
    assert bool(jax.device_get(d.hazard_live.any()))
    # nodes in hazard are in NEITHER table but lookup still finds them
    f_old, _, _ = buckets.lookup(d.old, jnp.asarray([1, 2, 3, 4, 5], jnp.int32))
    f_new, _, _ = buckets.lookup(d.new, jnp.asarray([1, 2, 3, 4, 5], jnp.int32))
    hz = np.asarray(d.hazard_live).sum()
    assert hz > 0
    found, vals = jax.jit(dhash.lookup)(d, jnp.asarray([1, 2, 3, 4, 5], jnp.int32))
    assert bool(found.all()) and bool((vals == jnp.asarray([10, 20, 30, 40, 50])).all())

    # Fig 1c: concurrent insert lands in the NEW table
    d, ok = _ins(d, [7])
    assert bool(ok.all())
    f_new, _, _ = buckets.lookup(d.new, jnp.asarray([7], jnp.int32))
    assert bool(f_new.all())

    d = jax.jit(dhash.rebuild_land)(d)         # Fig 1d: hazard lands in new
    assert not bool(jax.device_get(d.hazard_live.any()))
    d = dhash.rebuild_all(d)                   # Fig 1e/f: swap + reclaim
    found, vals = jax.jit(dhash.lookup)(d, jnp.asarray([1, 2, 3, 4, 5, 7], jnp.int32))
    assert bool(found.all())
    assert int(d.epoch) == 1


@pytest.mark.parametrize("backend", BACKENDS)
def test_delete_during_hazard_window(backend):
    """Alg. 5 line 75: delete of an in-flight node kills the hazard entry and
    the node never lands."""
    d = dhash.make(backend, capacity=64, chunk=64, seed=2)
    d, _ = _ins(d, list(range(1, 11)))
    d = dhash.rebuild_start(d, seed=5)
    d = jax.jit(dhash.rebuild_extract)(d)
    # all ten live entries are now in hazard (chunk covers the table)
    tgt = jnp.asarray([3, 7], jnp.int32)
    d, ok = jax.jit(dhash.delete)(d, tgt)
    assert bool(ok.all())
    d = dhash.rebuild_all(d)
    found, _ = jax.jit(dhash.lookup)(d, jnp.asarray(range(1, 11), jnp.int32))
    exp = np.array([k not in (3, 7) for k in range(1, 11)])
    np.testing.assert_array_equal(np.asarray(found), exp)
    assert int(jax.device_get(dhash.count_items(d))) == 8


@pytest.mark.parametrize("backend", BACKENDS)
def test_duplicate_insert_during_rebuild_new_wins(backend):
    """The paper's migration rule (Alg. 3 lines 34-36): a key re-inserted
    into the new table while its old copy awaits migration -> the migrated
    duplicate is dropped; the new copy's value survives the epoch."""
    d = dhash.make(backend, capacity=64, chunk=4, seed=3)
    d, _ = _ins(d, [1, 2, 3])
    d = dhash.rebuild_start(d, seed=11)
    # before key 1 migrates: delete it then insert a fresh value - the fresh
    # insert targets the new table
    d, ok = jax.jit(dhash.delete)(d, jnp.asarray([1], jnp.int32))
    assert bool(ok.all())
    d, ok = _ins(d, [1], [999])
    assert bool(ok.all())
    d = dhash.rebuild_all(d)
    found, vals = jax.jit(dhash.lookup)(d, jnp.asarray([1], jnp.int32))
    assert bool(found.all()) and int(vals[0]) == 999


@pytest.mark.parametrize("backend", BACKENDS)
def test_rebuild_changes_hash_function(backend):
    """The point of the paper: post-rebuild, the same keys map through a
    different seeded function (collision attack dispersed)."""
    d = dhash.make(backend, capacity=256, chunk=32, seed=4)
    keys = jnp.asarray(np.arange(1, 101, dtype=np.int32))
    d, _ = jax.jit(dhash.insert)(d, keys, keys)
    if backend == "linear":
        seeds_before = np.asarray(d.old.hfn.seeds)
    d = dhash.rebuild_all(dhash.rebuild_start(d, seed=12345))
    if backend == "linear":
        assert not np.array_equal(seeds_before, np.asarray(d.old.hfn.seeds))
    found, vals = jax.jit(dhash.lookup)(d, keys)
    assert bool(found.all()) and bool((vals == keys).all())


def test_engine_continuous_rebuild_matches_oracle():
    """The paper's Fig 2 workload shape: full-rate mixed traffic with a
    continuous rebuild; final state must match the oracle exactly."""
    from repro.core.engine import DHashEngine
    rng = np.random.default_rng(0)
    eng = DHashEngine(dhash.make("linear", capacity=512, chunk=32, seed=7),
                      continuous_rebuild=True)
    oracle: dict[int, int] = {}
    universe = np.arange(1, 400)
    for step in range(60):
        ins = rng.choice(universe, 8, replace=False)
        ins = np.array([k for k in ins if k not in oracle] or [0])
        dels = np.array([k for k in rng.choice(list(oracle) or [0], 4)
                         if k in oracle] or [0])
        dels = np.unique(dels)
        look = rng.choice(universe, 16, replace=False)
        pre = dict(oracle)      # the fused step looks up BEFORE its updates
        found, vals, ok_i, ok_d = eng.step(look, ins, ins * 3,
                                           dels, ins_mask=ins > 0,
                                           del_mask=dels > 0)
        for k in ins[ins > 0]:
            oracle[int(k)] = int(k) * 3
        for k in dels[dels > 0]:
            oracle.pop(int(k), None)
        fn, vn = np.asarray(found), np.asarray(vals)
        for i, k in enumerate(look):
            assert fn[i] == (int(k) in pre), (step, k)
            if int(k) in pre:
                assert vn[i] == pre[int(k)]
    assert eng.stats.rebuilds_completed >= 1, "rebuild never cycled"
    assert eng.count() == len(oracle)
