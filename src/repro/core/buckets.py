"""Modular bucket backends (the paper's pluggable "set algorithms", §3 goal 2).

The paper chains nodes in lock-free linked lists; pointer chasing is hostile
to TPUs, so each backend here is an *array-native* reformulation with the same
observable set semantics:

* ``linear``    — open-addressing, linear probing.  The TPU-native default:
                  bounded vectorized probe sequences, no pointers at all.
* ``twochoice`` — bucketed 2-choice hashing (cuckoo family without eviction):
                  exactly two vector-width bucket reads per lookup.
* ``cuckoo``    — two-table multilevel double hashing with bounded kick-out:
                  the twochoice layout split into two hash-function sides,
                  plus insert-side relocation bounded by ``max_kick`` — the
                  worst-case-bounded lookup backend (probe depth <= lane
                  width even under a collision attack).
* ``chain``     — arena-based chained buckets: the faithful analogue of the
                  paper's Michael-list buckets (insert-at-head, logical
                  deletion via state tags, deferred physical reclamation).
                  jnp traversal is lock-step across the query batch: one
                  gather per hop, bounded by ``max_chain``.  The FUSED path
                  never chases pointers: the arena is kept bucket-sorted
                  and tombstone-compacted (``chain_compact_fused``), so
                  probes are per-bucket ``(start, len)`` segment windows —
                  the same slab reductions as the other backends — with a
                  dense-window dirty tail for post-compaction inserts.

Slot/node states mirror the paper's two flag bits:
  LIVE                ~ reachable node
  TOMB                ~ LOGICALLY_REMOVED      (delete; reclaim deferred)
  MIGRATED            ~ IS_BEING_DISTRIBUTED   (rebuild pulled it into hazard)

All operations are *batched*: a batch of Q independent operations is the SPMD
analogue of Q concurrent threads.  Intra-batch conflicts are resolved
deterministically (lowest original index wins), which is one legal
linearization of the paper's concurrent execution.

Every backend exposes:
  make(...) -> Table
  lookup(t, keys)                -> (found[Q], vals[Q], loc[Q])
  insert(t, keys, vals, mask)    -> (t', ok[Q])     # ok=False if present/full
  delete(t, keys, mask)          -> (t', ok[Q])
  extract_chunk(t, cursor, n)    -> (t', hkeys, hvals, hlive, new_cursor)
  count_live(t) -> scalar
  capacity_of(t) -> int (static)

This module holds the table pytrees and the plain jnp reference ops.  The
Pallas-kernel (``*_fused``) adapters and the per-backend dispatch both live
in ``core/backend.py``: one frozen ``BucketBackend`` descriptor per backend
bundles constructors, plain/fused/ordered op callables, and layout caps —
the generic facades at the bottom of this file dispatch through that
registry, keyed on the table type.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core.struct_utils import pytree_dataclass, replace

I32 = jnp.int32
EMPTY, LIVE, TOMB, MIGRATED = I32(0), I32(1), I32(2), I32(3)

BACKENDS = ("linear", "twochoice", "chain", "cuckoo")


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def batch_winners(keys: jax.Array, mask: jax.Array) -> jax.Array:
    """First masked occurrence of each distinct key wins (deterministic
    linearization of intra-batch duplicate ops)."""
    q = keys.shape[0]
    idx = jnp.arange(q, dtype=I32)
    order = jnp.lexsort((idx, (~mask).astype(I32), keys))
    ks, ms = keys[order], mask[order]
    first = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    win_sorted = ms & first
    return jnp.zeros((q,), bool).at[order].set(win_sorted)


def _argpick(hit: jax.Array, vals: jax.Array, axis: int = -1):
    """Select value at the first True along axis (undefined if none)."""
    i = jnp.argmax(hit, axis=axis)
    return jnp.take_along_axis(vals, i[..., None], axis=axis)[..., 0], i


# ---------------------------------------------------------------------------
# linear: open addressing with linear probing
# ---------------------------------------------------------------------------

@pytree_dataclass(meta_fields=("capacity", "max_probes"))
class LinearTable:
    capacity: int
    max_probes: int
    hfn: hashing.HashFn
    key: jax.Array    # [C] i32
    val: jax.Array    # [C] i32
    state: jax.Array  # [C] i32 (EMPTY/LIVE/TOMB/MIGRATED)


def linear_make(capacity: int, hfn: hashing.HashFn, max_probes: int = 64) -> LinearTable:
    # distinct buffers per field (aliased leaves break jit buffer donation)
    def z():
        return jnp.zeros((capacity,), I32)
    return LinearTable(capacity=capacity, max_probes=max_probes, hfn=hfn,
                       key=z(), val=z(), state=z())


def linear_lookup(t: LinearTable, keys: jax.Array):
    found, val, loc, _ = linear_lookup_fwd(t, keys)
    return found, val, loc


def linear_lookup_fwd(t: LinearTable, keys: jax.Array):
    """Lookup that ALSO reports a MIGRATED-slot key match ("tombstone
    forwarding"): a slot whose entry was pulled into the rebuild's hazard
    buffer still holds its key, so the probe that passes over it identifies
    the hazard entry at zero extra cost — the beyond-paper replacement for
    the O(Q x chunk) hazard broadcast compare (EXPERIMENTS.md §Perf).
    Returns (found, val, loc, mig_loc) with mig_loc = -1 if none."""
    c = t.capacity
    h0 = hashing.bucket_of(t.hfn, keys, c)
    q = keys.shape[0]

    def cond(carry):
        active, i = carry[0], carry[5]
        return active.any() & (i < t.max_probes)

    def body(carry):
        active, found, val, loc, mig, i = carry
        pos = (h0 + i) % c
        st = t.state[pos]
        kmatch = t.key[pos] == keys
        hit = active & (st == LIVE) & kmatch
        mig = jnp.where(active & (st == MIGRATED) & kmatch & (mig < 0),
                        pos, mig)
        stop = active & (st == EMPTY)
        val = jnp.where(hit, t.val[pos], val)
        loc = jnp.where(hit, pos, loc)
        found = found | hit
        active = active & ~hit & ~stop
        return active, found, val, loc, mig, i + 1

    init = (jnp.ones((q,), bool), jnp.zeros((q,), bool),
            jnp.zeros((q,), I32), jnp.full((q,), -1, I32),
            jnp.full((q,), -1, I32), jnp.asarray(0, I32))
    _, found, val, loc, mig, _ = jax.lax.while_loop(cond, body, init)
    return found, val, loc, mig


def linear_insert(t: LinearTable, keys: jax.Array, vals: jax.Array, mask: jax.Array):
    c, q = t.capacity, keys.shape[0]
    winner = batch_winners(keys, mask)
    present, _, _ = linear_lookup(t, keys)
    pending0 = winner & ~present
    h0 = hashing.bucket_of(t.hfn, keys, c)
    idx = jnp.arange(q, dtype=I32)

    def body(_, carry):
        key, val, state, pending, off, done = carry
        pos = (h0 + off) % c
        free = pending & (state[pos] != LIVE)
        wpos = jnp.where(free, pos, c)
        claim = jnp.full((c,), q, I32).at[wpos].min(idx, mode="drop")
        won = free & (claim[pos % c] == idx) & (wpos < c)
        wp = jnp.where(won, pos, c)
        key = key.at[wp].set(keys, mode="drop")
        val = val.at[wp].set(vals, mode="drop")
        state = state.at[wp].set(LIVE, mode="drop")
        done = done | won
        pending = pending & ~won
        off = jnp.where(pending, off + 1, off)
        return key, val, state, pending, off, done

    init = (t.key, t.val, t.state, pending0, jnp.zeros((q,), I32), jnp.zeros((q,), bool))
    key, val, state, _, _, done = jax.lax.fori_loop(0, t.max_probes, body, init)
    t = LinearTable(capacity=c, max_probes=t.max_probes, hfn=t.hfn, key=key, val=val, state=state)
    return t, done


def linear_delete(t: LinearTable, keys: jax.Array, mask: jax.Array):
    winner = batch_winners(keys, mask)
    found, _, loc = linear_lookup(t, keys)
    ok = winner & found
    wloc = jnp.where(ok, loc, t.capacity)
    state = t.state.at[wloc].set(TOMB, mode="drop")
    return LinearTable(capacity=t.capacity, max_probes=t.max_probes, hfn=t.hfn,
                       key=t.key, val=t.val, state=state), ok


def linear_extract_chunk(t: LinearTable, cursor: jax.Array, n: int):
    pos = cursor + jnp.arange(n, dtype=I32)
    valid = pos < t.capacity
    cpos = jnp.where(valid, pos, 0)
    live = valid & (t.state[cpos] == LIVE)
    hkeys = jnp.where(live, t.key[cpos], 0)
    hvals = jnp.where(live, t.val[cpos], 0)
    state = t.state.at[jnp.where(live, cpos, t.capacity)].set(MIGRATED, mode="drop")
    new_cursor = jnp.minimum(cursor + n, t.capacity)
    t = LinearTable(capacity=t.capacity, max_probes=t.max_probes, hfn=t.hfn,
                    key=t.key, val=t.val, state=state)
    return t, hkeys, hvals, live, new_cursor


def linear_count_live(t: LinearTable):
    return jnp.sum(t.state == LIVE)


def linear_clear(t: LinearTable) -> LinearTable:
    z = jnp.zeros((t.capacity,), I32)
    return LinearTable(capacity=t.capacity, max_probes=t.max_probes, hfn=t.hfn,
                       key=z, val=z, state=z)


# ---------------------------------------------------------------------------
# twochoice: bucketed 2-choice hashing (W-wide vector buckets)
# ---------------------------------------------------------------------------

@pytree_dataclass(meta_fields=("nbuckets", "width", "max_rounds"))
class TwoChoiceTable:
    nbuckets: int
    width: int
    max_rounds: int
    hfn_a: hashing.HashFn
    hfn_b: hashing.HashFn
    key: jax.Array    # [B, W] i32
    val: jax.Array    # [B, W] i32
    state: jax.Array  # [B, W] i32


def twochoice_make(nbuckets: int, hfn_a: hashing.HashFn, hfn_b: hashing.HashFn,
                   width: int = 8, max_rounds: int = 8) -> TwoChoiceTable:
    def z():
        return jnp.zeros((nbuckets, width), I32)
    return TwoChoiceTable(nbuckets=nbuckets, width=width, max_rounds=max_rounds,
                          hfn_a=hfn_a, hfn_b=hfn_b, key=z(), val=z(), state=z())


def _tc_rows(t: TwoChoiceTable, keys: jax.Array):
    ba = hashing.bucket_of(t.hfn_a, keys, t.nbuckets)
    bb = hashing.bucket_of(t.hfn_b, keys, t.nbuckets)
    return ba, bb


def twochoice_lookup(t: TwoChoiceTable, keys: jax.Array):
    ba, bb = _tc_rows(t, keys)
    hit_a = (t.key[ba] == keys[:, None]) & (t.state[ba] == LIVE)   # [Q, W]
    hit_b = (t.key[bb] == keys[:, None]) & (t.state[bb] == LIVE)
    fa, fb = hit_a.any(-1), hit_b.any(-1)
    va, sa = _argpick(hit_a, t.val[ba])
    vb, sb = _argpick(hit_b, t.val[bb])
    found = fa | fb
    val = jnp.where(fa, va, vb)
    loc = jnp.where(fa, ba * t.width + sa, jnp.where(fb, bb * t.width + sb, -1))
    return found, val, loc


def twochoice_insert(t: TwoChoiceTable, keys: jax.Array, vals: jax.Array, mask: jax.Array):
    b, w, q = t.nbuckets, t.width, keys.shape[0]
    winner = batch_winners(keys, mask)
    present, _, _ = twochoice_lookup(t, keys)
    pending0 = winner & ~present
    ba, bb = _tc_rows(t, keys)
    idx = jnp.arange(q, dtype=I32)
    nslots = b * w

    def body(r, carry):
        key, val, state, pending, done = carry
        bkt = jnp.where(r % 2 == 0, ba, bb)
        row_free = state[bkt] != LIVE                       # [Q, W]
        has_free = pending & row_free.any(-1)
        slot = jnp.argmax(row_free, axis=-1)
        flat = bkt * w + slot
        wflat = jnp.where(has_free, flat, nslots)
        claim = jnp.full((nslots,), q, I32).at[wflat].min(idx, mode="drop")
        won = has_free & (claim[flat % nslots] == idx) & (wflat < nslots)
        wp = jnp.where(won, flat, nslots)
        key = key.reshape(-1).at[wp].set(keys, mode="drop").reshape(b, w)
        val = val.reshape(-1).at[wp].set(vals, mode="drop").reshape(b, w)
        state = state.reshape(-1).at[wp].set(LIVE, mode="drop").reshape(b, w)
        done = done | won
        pending = pending & ~won
        return key, val, state, pending, done

    init = (t.key, t.val, t.state, pending0, jnp.zeros((q,), bool))
    key, val, state, _, done = jax.lax.fori_loop(0, t.max_rounds, body, init)
    t = TwoChoiceTable(nbuckets=b, width=w, max_rounds=t.max_rounds,
                       hfn_a=t.hfn_a, hfn_b=t.hfn_b, key=key, val=val, state=state)
    return t, done


def twochoice_delete(t: TwoChoiceTable, keys: jax.Array, mask: jax.Array):
    winner = batch_winners(keys, mask)
    found, _, loc = twochoice_lookup(t, keys)
    ok = winner & found
    wloc = jnp.where(ok, loc, t.nbuckets * t.width)
    state = t.state.reshape(-1).at[wloc].set(TOMB, mode="drop").reshape(t.nbuckets, t.width)
    return TwoChoiceTable(nbuckets=t.nbuckets, width=t.width, max_rounds=t.max_rounds,
                          hfn_a=t.hfn_a, hfn_b=t.hfn_b, key=t.key, val=t.val, state=state), ok


def twochoice_extract_chunk(t: TwoChoiceTable, cursor: jax.Array, n: int):
    nslots = t.nbuckets * t.width
    pos = cursor + jnp.arange(n, dtype=I32)
    valid = pos < nslots
    cpos = jnp.where(valid, pos, 0)
    ks, vs, ss = t.key.reshape(-1), t.val.reshape(-1), t.state.reshape(-1)
    live = valid & (ss[cpos] == LIVE)
    hkeys = jnp.where(live, ks[cpos], 0)
    hvals = jnp.where(live, vs[cpos], 0)
    ss = ss.at[jnp.where(live, cpos, nslots)].set(MIGRATED, mode="drop")
    new_cursor = jnp.minimum(cursor + n, nslots)
    t = TwoChoiceTable(nbuckets=t.nbuckets, width=t.width, max_rounds=t.max_rounds,
                       hfn_a=t.hfn_a, hfn_b=t.hfn_b, key=t.key, val=t.val,
                       state=ss.reshape(t.nbuckets, t.width))
    return t, hkeys, hvals, live, new_cursor


def twochoice_count_live(t: TwoChoiceTable):
    return jnp.sum(t.state == LIVE)


def twochoice_clear(t: TwoChoiceTable) -> TwoChoiceTable:
    z = jnp.zeros((t.nbuckets, t.width), I32)
    return TwoChoiceTable(nbuckets=t.nbuckets, width=t.width,
                          max_rounds=t.max_rounds, hfn_a=t.hfn_a,
                          hfn_b=t.hfn_b, key=z, val=z, state=z)


# ---------------------------------------------------------------------------
# cuckoo: two-table multilevel double hashing with bounded kick-out
# ---------------------------------------------------------------------------
#
# The worst-case-bounded backend ("Cascade hash tables" in PAPERS.md;
# MAX_KICK_OUT/HASH_FUNC_NUM in SNIPPETS.md snippet 1): one [2B, W] slot
# array split into side A (rows [0, B), addressed by hfn_a) and side B
# (rows [B, 2B), addressed by hfn_b).  A key lives in exactly one of its two
# candidate rows, so EVERY lookup is two W-wide row gathers — probe depth is
# bounded by the lane width no matter how adversarial the key set, which is
# the defense DURING a collision attack (bench_attack.py); the insert-side
# relocation is bounded by ``max_kick`` (kernels/ref.py::cuckoo_kick_ref).
# Because the candidate rows are plain row indices, the fused path reuses
# the twochoice row-gather kernels VERBATIM with side-offset rows — same
# 1-sort/1-pallas_call budget, nothing new to lower.

@pytree_dataclass(meta_fields=("nbuckets", "width", "max_kick"))
class CuckooTable:
    nbuckets: int     # rows PER SIDE: the slot arrays are [2 * nbuckets, W]
    width: int
    max_kick: int     # bounded kick-out iterations (insert relocation)
    hfn_a: hashing.HashFn
    hfn_b: hashing.HashFn
    key: jax.Array    # [2B, W] i32
    val: jax.Array    # [2B, W] i32
    state: jax.Array  # [2B, W] i32


def cuckoo_make(nbuckets: int, hfn_a: hashing.HashFn, hfn_b: hashing.HashFn,
                width: int = 8, max_kick: int = 32) -> CuckooTable:
    def z():
        return jnp.zeros((2 * nbuckets, width), I32)
    return CuckooTable(nbuckets=nbuckets, width=width, max_kick=max_kick,
                       hfn_a=hfn_a, hfn_b=hfn_b, key=z(), val=z(), state=z())


def _ck_rows(t: CuckooTable, keys: jax.Array):
    """The two candidate rows of each key, side-offset into the [2B, W]
    array: a-rows in [0, B), b-rows in [B, 2B).  Disjoint row ranges are
    what let every twochoice row-indexed op drive this table unchanged."""
    ra = hashing.bucket_of(t.hfn_a, keys, t.nbuckets)
    rb = t.nbuckets + hashing.bucket_of(t.hfn_b, keys, t.nbuckets)
    return ra, rb


def cuckoo_lookup(t: CuckooTable, keys: jax.Array):
    ra, rb = _ck_rows(t, keys)
    hit_a = (t.key[ra] == keys[:, None]) & (t.state[ra] == LIVE)   # [Q, W]
    hit_b = (t.key[rb] == keys[:, None]) & (t.state[rb] == LIVE)
    fa, fb = hit_a.any(-1), hit_b.any(-1)
    va, sa = _argpick(hit_a, t.val[ra])
    vb, sb = _argpick(hit_b, t.val[rb])
    found = fa | fb
    val = jnp.where(fa, va, vb)
    loc = jnp.where(fa, ra * t.width + sa, jnp.where(fb, rb * t.width + sb, -1))
    return found, val, loc


def cuckoo_insert(t: CuckooTable, keys: jax.Array, vals: jax.Array, mask: jax.Array):
    """Set-semantic insert: the bounded kick-out loop (plan-A free-lane
    claim / plan-B victim relocation, per-row arbitration) IS the whole
    placement — its first iterations are exactly the twochoice direct
    claims, and only genuinely contended rows pay relocation iterations.
    ok=False iff present or the kick budget exhausts (no resident is ever
    displaced without a landing slot)."""
    from repro.kernels import ref
    winner = batch_winners(keys, mask)
    present, _, _ = cuckoo_lookup(t, keys)
    pending = winner & ~present
    ra, rb = _ck_rows(t, keys)

    def kick(op):
        k, v, s, done0 = op
        k2, v2, s2, done = ref.cuckoo_kick_ref(
            k, v, s, ra, rb, t.hfn_a, t.hfn_b, t.nbuckets,
            keys, vals, pending, t.max_kick)
        return k2, v2, s2, done0 | done

    key, val, state, done = jax.lax.cond(
        pending.any(), kick, lambda op: op,
        (t.key, t.val, t.state, jnp.zeros(keys.shape, bool)))
    return replace(t, key=key, val=val, state=state), done


def cuckoo_delete(t: CuckooTable, keys: jax.Array, mask: jax.Array):
    winner = batch_winners(keys, mask)
    found, _, loc = cuckoo_lookup(t, keys)
    ok = winner & found
    nslots = 2 * t.nbuckets * t.width
    wloc = jnp.where(ok, loc, nslots)
    state = t.state.reshape(-1).at[wloc].set(TOMB, mode="drop").reshape(
        2 * t.nbuckets, t.width)
    return replace(t, state=state), ok


def cuckoo_extract_chunk(t: CuckooTable, cursor: jax.Array, n: int):
    nslots = 2 * t.nbuckets * t.width
    pos = cursor + jnp.arange(n, dtype=I32)
    valid = pos < nslots
    cpos = jnp.where(valid, pos, 0)
    ks, vs, ss = t.key.reshape(-1), t.val.reshape(-1), t.state.reshape(-1)
    live = valid & (ss[cpos] == LIVE)
    hkeys = jnp.where(live, ks[cpos], 0)
    hvals = jnp.where(live, vs[cpos], 0)
    ss = ss.at[jnp.where(live, cpos, nslots)].set(MIGRATED, mode="drop")
    new_cursor = jnp.minimum(cursor + n, nslots)
    return replace(t, state=ss.reshape(2 * t.nbuckets, t.width)), \
        hkeys, hvals, live, new_cursor


def cuckoo_count_live(t: CuckooTable):
    return jnp.sum(t.state == LIVE)


def cuckoo_clear(t: CuckooTable) -> CuckooTable:
    z = jnp.zeros((2 * t.nbuckets, t.width), I32)
    return replace(t, key=z, val=z, state=z)


# ---------------------------------------------------------------------------
# chain: arena-based chained buckets (paper-faithful Michael-list analogue)
# ---------------------------------------------------------------------------

@pytree_dataclass(meta_fields=("nbuckets", "arena", "max_chain", "dirty_cap"))
class ChainTable:
    nbuckets: int
    arena: int        # node capacity N
    max_chain: int    # traversal bound (>= max expected chain incl. tombstones)
    dirty_cap: int    # dense-window budget for the post-compaction dirty
                      # tail (the fused path's coverage bound; the
                      # ``BucketBackend`` descriptor supplies the default)
    hfn: hashing.HashFn
    akey: jax.Array   # [N] i32
    aval: jax.Array   # [N] i32
    anext: jax.Array  # [N] i32 (-1 terminates)
    astate: jax.Array # [N] i32
    heads: jax.Array  # [B] i32 (-1 empty)
    free_stack: jax.Array  # [N] i32 - free node indices live at [0, free_top)
    free_top: jax.Array    # scalar i32
    # arena-sorted layout metadata (the fused path's view of the same arena):
    # [0, sorted_upto) holds the bucket-sorted, tombstone-compacted segments
    # (bucket b's nodes at [bstart[b], bstart[b]+blen[b])), and nodes
    # allocated SINCE the last compaction occupy the contiguous "dirty" tail
    # [sorted_upto, arena - free_top).  ``chain_dirty(t)`` derives the dirty
    # count; ``chain_compact_fused`` restores dirty == 0.
    bstart: jax.Array      # [B] i32 - sorted-segment start per bucket
    blen: jax.Array        # [B] i32 - sorted-segment length per bucket
    sorted_upto: jax.Array # scalar i32 - arena prefix in bucket-sorted order


def chain_make(nbuckets: int, arena: int, hfn: hashing.HashFn,
               max_chain: int = 64, dirty_cap: int | None = None) -> ChainTable:
    n = arena
    if dirty_cap is None:
        # resolve from the chain descriptor (core/backend.py) so tables
        # built directly through chain_make agree with registry-built ones
        # — the descriptor field is the single source of truth for the cap
        from repro.core import backend
        dirty_cap = backend.get("chain").dirty_cap
    # free_stack is DESCENDING so pops allocate ascending positions: the
    # allocated region is always the contiguous prefix [0, n - free_top),
    # which is what keeps the fused path's dirty tail a dense window.
    return ChainTable(
        nbuckets=nbuckets, arena=n, max_chain=max_chain, dirty_cap=dirty_cap,
        hfn=hfn,
        akey=jnp.zeros((n,), I32), aval=jnp.zeros((n,), I32),
        anext=jnp.full((n,), -1, I32), astate=jnp.zeros((n,), I32),
        heads=jnp.full((nbuckets,), -1, I32),
        free_stack=n - 1 - jnp.arange(n, dtype=I32),
        free_top=jnp.asarray(n, I32),
        bstart=jnp.zeros((nbuckets,), I32), blen=jnp.zeros((nbuckets,), I32),
        sorted_upto=jnp.asarray(0, I32))


def chain_dirty(t: ChainTable) -> jax.Array:
    """Scalar i32: nodes allocated since the last compaction (they live at
    [sorted_upto, arena - free_top) — allocation is always a prefix)."""
    return t.arena - t.free_top - t.sorted_upto


def chain_lookup(t: ChainTable, keys: jax.Array, bucket: jax.Array | None = None):
    """Lock-step batched traversal with DYNAMIC termination: the step cost is
    the longest still-active chain in the batch, not the static bound — so
    collision attacks show up in wall time exactly as they do on the paper's
    pointer-chasing implementations."""
    q = keys.shape[0]
    b = hashing.bucket_of(t.hfn, keys, t.nbuckets) if bucket is None else bucket
    cur0 = t.heads[b]

    def cond(carry):
        cur, found, _, _, fuel = carry
        return ((cur >= 0) & ~found).any() & (fuel > 0)

    def body(carry):
        cur, found, val, loc, fuel = carry
        valid = cur >= 0
        c = jnp.where(valid, cur, 0)
        hit = valid & (t.astate[c] == LIVE) & (t.akey[c] == keys) & ~found
        val = jnp.where(hit, t.aval[c], val)
        loc = jnp.where(hit, cur, loc)
        found = found | hit
        step = valid & ~found
        cur = jnp.where(step, t.anext[c], jnp.where(found, cur, -1))
        return cur, found, val, loc, fuel - 1

    init = (cur0, jnp.zeros((q,), bool), jnp.zeros((q,), I32),
            jnp.full((q,), -1, I32), jnp.asarray(t.max_chain, I32))
    _, found, val, loc, _ = jax.lax.while_loop(cond, body, init)
    return found, val, loc


def _chain_link(t: ChainTable, keys, node, can, bucket: jax.Array | None = None):
    """Insert nodes ``node`` (where can) at the heads of their buckets,
    preserving original-index order within each bucket group."""
    q = keys.shape[0]
    b = hashing.bucket_of(t.hfn, keys, t.nbuckets) if bucket is None else bucket
    sortkey = jnp.where(can, b, t.nbuckets)
    idx = jnp.arange(q, dtype=I32)
    order = jnp.lexsort((idx, sortkey))
    sb, snode, scan = sortkey[order], node[order], can[order]
    nxt_same = jnp.concatenate([snode[1:], jnp.full((1,), -1, I32)])
    same_bucket = jnp.concatenate([sb[1:] == sb[:-1], jnp.zeros((1,), bool)])
    old_head = t.heads[jnp.where(scan, sb, 0)]
    nxt = jnp.where(same_bucket, nxt_same, jnp.where(scan, old_head, -1))
    anext = t.anext.at[jnp.where(scan, snode, t.arena)].set(nxt, mode="drop")
    is_start = jnp.concatenate([jnp.ones((1,), bool), sb[1:] != sb[:-1]])
    heads = t.heads.at[jnp.where(scan & is_start, sb, t.nbuckets)].set(snode, mode="drop")
    return anext, heads


def chain_insert(t: ChainTable, keys: jax.Array, vals: jax.Array, mask: jax.Array,
                 bucket: jax.Array | None = None):
    q, n = keys.shape[0], t.arena
    winner = batch_winners(keys, mask)
    present, _, _ = chain_lookup(t, keys, bucket)
    want = winner & ~present
    rank = jnp.cumsum(want.astype(I32)) - 1
    can = want & (rank < t.free_top)
    node = t.free_stack[jnp.where(can, t.free_top - 1 - rank, 0)]
    wnode = jnp.where(can, node, n)
    akey = t.akey.at[wnode].set(keys, mode="drop")
    aval = t.aval.at[wnode].set(vals, mode="drop")
    astate = t.astate.at[wnode].set(LIVE, mode="drop")
    t1 = replace(t, akey=akey, aval=aval, astate=astate)
    anext, heads = _chain_link(t1, keys, node, can, bucket)
    free_used = jnp.sum(can.astype(I32))
    # new nodes extend the dirty tail; the sorted segments are untouched
    t2 = replace(t1, anext=anext, heads=heads,
                 free_top=t.free_top - free_used)
    return t2, can


def chain_delete(t: ChainTable, keys: jax.Array, mask: jax.Array,
                 bucket: jax.Array | None = None):
    winner = batch_winners(keys, mask)
    found, _, loc = chain_lookup(t, keys, bucket)
    ok = winner & found
    wloc = jnp.where(ok, loc, t.arena)
    astate = t.astate.at[wloc].set(TOMB, mode="drop")
    return replace(t, astate=astate), ok


def chain_extract_chunk(t: ChainTable, cursor: jax.Array, n: int):
    pos = cursor + jnp.arange(n, dtype=I32)
    valid = pos < t.arena
    cpos = jnp.where(valid, pos, 0)
    live = valid & (t.astate[cpos] == LIVE)
    hkeys = jnp.where(live, t.akey[cpos], 0)
    hvals = jnp.where(live, t.aval[cpos], 0)
    astate = t.astate.at[jnp.where(live, cpos, t.arena)].set(MIGRATED, mode="drop")
    new_cursor = jnp.minimum(cursor + n, t.arena)
    return replace(t, astate=astate), hkeys, hvals, live, new_cursor


def chain_compact(t: ChainTable) -> ChainTable:
    """Physically reclaim tombstones: rebuild all chains from live nodes.

    The paper defers physical unlinking to later traversals / call_rcu; the
    batched analogue is a periodic vectorized compaction (also doubles as the
    post-rebuild reclamation of the old arena)."""
    live = t.astate == LIVE
    fresh = chain_make(t.nbuckets, t.arena, t.hfn, t.max_chain, t.dirty_cap)
    t2, _ = chain_insert(fresh, jnp.where(live, t.akey, 0), t.aval, live)
    return t2


def chain_count_live(t: ChainTable):
    return jnp.sum(t.astate == LIVE)


def chain_clear(t: ChainTable) -> ChainTable:
    n = t.arena
    return replace(
        t, akey=jnp.zeros((n,), I32), aval=jnp.zeros((n,), I32),
        anext=jnp.full((n,), -1, I32), astate=jnp.zeros((n,), I32),
        heads=jnp.full((t.nbuckets,), -1, I32),
        free_stack=n - 1 - jnp.arange(n, dtype=I32),
        free_top=jnp.asarray(n, I32),
        bstart=jnp.zeros((t.nbuckets,), I32),
        blen=jnp.zeros((t.nbuckets,), I32),
        sorted_upto=jnp.asarray(0, I32))


# -- The Pallas-accelerated (``*_fused``) chain paths moved to
# core/backend.py with every other backend's fused adapters: the arena-
# sorted layout itself (and its jnp maintenance) stays here ----------------

def _chain_parts(t: ChainTable):
    """The raw-array views the chain ops consume: arena triple, link pair
    (for the pointer-chasing fallback), segment quad."""
    return ((t.akey, t.aval, t.astate), (t.anext, t.heads),
            (t.bstart, t.blen, t.sorted_upto, chain_dirty(t)))


# ---------------------------------------------------------------------------
# dispatch facade: generic table-typed entry points over the descriptor
# registry (core/backend.py) — the jnp ops above are what the registry
# binds; these facades are for callers holding a bare table pytree
# ---------------------------------------------------------------------------

def _be(t):
    from repro.core import backend
    return backend.of_table(t)


def backend_of(table) -> str:
    """Registry name of a table pytree ("linear"/"twochoice"/"chain"/...)."""
    return _be(table).name


def lookup(t, keys):
    return _be(t).lookup(t, keys)


def insert(t, keys, vals, mask):
    return _be(t).insert(t, keys, vals, mask)


def delete(t, keys, mask):
    return _be(t).delete(t, keys, mask)


def extract_chunk(t, cursor, n):
    return _be(t).extract_chunk(t, cursor, n)


def count_live(t):
    return _be(t).count_live(t)


def clear(t):
    """Empty the table in place (shape/hash-function preserving, jittable) —
    the on-device reset of a drained table before it becomes the next rebuild
    target."""
    return _be(t).clear(t)


def capacity_of(t) -> int:
    return _be(t).capacity_of(t)


# Legacy import surface: the fused adapters lived here before the
# descriptor-protocol refactor collapsed them into core/backend.py.
_MOVED_TO_BACKEND = (
    "linear_lookup_fused", "linear_insert_fused", "linear_delete_fused",
    "linear_extract_chunk_fused",
    "twochoice_lookup_fused", "twochoice_insert_fused",
    "twochoice_delete_fused", "twochoice_ordered_lookup_fused",
    "twochoice_ordered_delete_fused", "twochoice_extract_chunk_fused",
    "cuckoo_lookup_fused", "cuckoo_insert_fused", "cuckoo_delete_fused",
    "cuckoo_ordered_lookup_fused", "cuckoo_ordered_delete_fused",
    "cuckoo_extract_chunk_fused",
    "chain_lookup_fused", "chain_insert_fused", "chain_delete_fused",
    "chain_ordered_lookup_fused", "chain_ordered_delete_fused",
    "chain_extract_chunk_fused", "chain_compact_fused",
    "chain_maybe_compact",
)


def __getattr__(name: str):
    if name in _MOVED_TO_BACKEND:
        from repro.core import backend
        return getattr(backend, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
