"""End-to-end training: loss decreases, checkpoint-resume reproduces the
continuous run bit-for-bit (fault-tolerance contract), grad compression
trains, hash-router rebalance runs live."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import DataConfig, synth_batch
from repro.optim.optimizer import OptConfig
from repro.train import checkpoint as ck
from repro.train import train_step as ts
from functools import partial


def _run(cfg, opt_cfg, dcfg, state, start, steps, step_fn):
    losses = []
    for s in range(start, steps):
        batch = synth_batch(dcfg, s)
        state, m = step_fn(state, batch)
        losses.append(float(jax.device_get(m["loss"])))
    return state, losses


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke("qwen3-8b")
    opt_cfg = OptConfig(lr=3e-3, total_steps=30, warmup_steps=2)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4,
                      seed=1)
    step_fn = jax.jit(partial(ts.train_step, cfg=cfg, opt_cfg=opt_cfg))
    return cfg, opt_cfg, dcfg, step_fn


def test_loss_decreases(setup):
    cfg, opt_cfg, dcfg, step_fn = setup
    state = ts.init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    state, losses = _run(cfg, opt_cfg, dcfg, state, 0, 25, step_fn)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_checkpoint_resume_bitexact(setup, tmp_path):
    cfg, opt_cfg, dcfg, step_fn = setup
    state = ts.init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    # continuous 12-step run
    cont, losses_cont = _run(cfg, opt_cfg, dcfg, state, 0, 12, step_fn)
    # run 6, checkpoint, restore, run 6 more
    state2 = ts.init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    state2, _ = _run(cfg, opt_cfg, dcfg, state2, 0, 6, step_fn)
    ck.save(str(tmp_path), 6, state2)
    template = ts.init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    restored, step = ck.restore(str(tmp_path), template)
    assert step == 6
    resumed, losses_res = _run(cfg, opt_cfg, dcfg, restored, 6, 12, step_fn)
    for a, b in zip(jax.tree_util.tree_leaves(cont["params"]),
                    jax.tree_util.tree_leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_compression_trains():
    cfg = configs.get_smoke("gemma2-2b")
    opt_cfg = OptConfig(lr=3e-3, total_steps=20, warmup_steps=2,
                        grad_compression=True)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4,
                      seed=2)
    step_fn = jax.jit(partial(ts.train_step, cfg=cfg, opt_cfg=opt_cfg))
    state = ts.init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    state, losses = _run(cfg, opt_cfg, dcfg, state, 0, 15, step_fn)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_router_rebalance_live():
    cfg = configs.get_smoke("llama4-scout-17b-a16e")
    opt_cfg = OptConfig(lr=1e-3, total_steps=10, warmup_steps=1)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4,
                      seed=3, zipf_a=1.1)
    step_fn = jax.jit(partial(ts.train_step, cfg=cfg, opt_cfg=opt_cfg))
    state = ts.init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    assert "router_table" in state
    rebuild_seen = False
    for s in range(8):
        batch = synth_batch(dcfg, s)
        state, m = step_fn(state, batch)
        assert np.isfinite(float(jax.device_get(m["loss"])))
        state = ts.rebalance_router(state, m["expert_load"], cfg,
                                    hot_frac=1.01)  # force a trigger
        rebuild_seen |= bool(jax.device_get(state["router_table"].rebuilding))
    assert rebuild_seen, "router rebuild never triggered"
