"""Differential op-sequence fuzz for the prefix-cache eviction policy:
random interleavings of publish / match / acquire / release / evict /
rehash-start / rehash-step (``serving/eviction.py``) checked against a
dict + LRU oracle, across backends x fused on/off.

The oracle is the obvious Python model: ``mapping: fp -> page``, a cached
set, per-page pin counts, and per-page stamps with a global clock.  Victim
selection sorts candidates by ``(stamp, page)`` ascending — exactly what
the kernel side guarantees (``lax.top_k`` over negated stamps is
index-stable, so ties break to the lowest page id).  Every op checks ok
flags and membership; every step checks the module invariant: the cached
count, the forward index, and the reverse index agree in lockstep.

Rehash ops fuzz the "eviction while the fingerprint index is mid-rebuild"
corner: victim deletes must go through the ordered old->hazard->new check
and the oracle must never notice.

Encoding is shrink-friendly (small opcodes + small fp indices) and the
pinned ``CORPUS`` replays without hypothesis installed — grow it by
pasting any failing ``script`` repr here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # the corpus replay below runs even without hypothesis installed
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev containers without dev deps
    HAVE_HYPOTHESIS = False

from repro.core import backend as backends
from repro.core import dhash
from repro.serving import eviction

I32 = jnp.int32
Q = 4                        # fixed batch width (masked), no recompiles
FPS = list(range(100, 112))  # small fingerprint universe -> dup pressure
N_PAGES = 8

OP_PUBLISH, OP_MATCH, OP_ACQUIRE, OP_RELEASE, OP_EVICT, OP_START, OP_STEP = \
    range(7)

if HAVE_HYPOTHESIS:
    _op = st.tuples(st.integers(0, 6),
                    st.lists(st.sampled_from(FPS), min_size=1, max_size=Q))
    _script = st.lists(_op, min_size=3, max_size=20)

_FNS = {
    "publish": jax.jit(eviction.publish),
    "touch": jax.jit(eviction.touch),
    "acquire": jax.jit(eviction.acquire),
    "release": jax.jit(eviction.release),
    "evict": jax.jit(eviction.evict, static_argnums=1),
    "lookup": jax.jit(dhash.lookup),
    "step": jax.jit(lambda t: dhash.finish_same_shape(dhash.rebuild_step(t))),
}

# Previously-found failing sequences (shrunk), replayed on every run.
CORPUS = [
    # evict then republish the same fingerprint onto a fresh page
    [(OP_PUBLISH, [100, 101, 102]), (OP_EVICT, [100, 100]),
     (OP_PUBLISH, [100]), (OP_MATCH, [100, 101, 102])],
    # pinned page must be skipped; victim order falls to the next-coldest
    [(OP_PUBLISH, [100, 101]), (OP_PUBLISH, [102, 103]),
     (OP_ACQUIRE, [100, 101]), (OP_EVICT, [100, 100, 100]),
     (OP_RELEASE, [100]), (OP_EVICT, [100]), (OP_MATCH, [100, 101, 102])],
    # duplicate publish (in-batch and cross-batch) keeps the first mapping
    [(OP_PUBLISH, [104, 104, 105]), (OP_PUBLISH, [104, 106]),
     (OP_MATCH, [104, 105, 106]), (OP_EVICT, [100, 100])],
    # eviction mid-rebuild: ordered deletes on the forward index
    [(OP_PUBLISH, [100, 101, 102, 103]), (OP_START, [100]), (OP_STEP, [100]),
     (OP_EVICT, [100, 100]), (OP_STEP, [100]), (OP_MATCH, [100, 101, 102]),
     (OP_STEP, [100]), (OP_STEP, [100]), (OP_PUBLISH, [107]),
     (OP_MATCH, [100, 101, 102, 103])],
    # touch re-warms: matched pages must drop to the BACK of the LRU order
    [(OP_PUBLISH, [100, 101]), (OP_PUBLISH, [102]), (OP_MATCH, [100]),
     (OP_EVICT, [100, 100]), (OP_MATCH, [100, 101, 102])],
    # found by fuzz (twochoice, seed 913, shrunk): re-publish of a
    # still-cached fp mid-rebuild must lose even though its entry has not
    # migrated to the new table yet — dhash.insert only checks the TARGET
    # table, so publish must pre-screen with a full ordered lookup
    [(OP_PUBLISH, [100, 101, 102]), (OP_START, [100]),
     (OP_PUBLISH, [100, 103]), (OP_MATCH, [100, 101, 103]),
     (OP_STEP, [100]), (OP_EVICT, [100, 100]),
     (OP_MATCH, [100, 101, 102, 103])],
]

BACKEND_PARAMS = [(b, f) for b in ("linear", "twochoice", "chain")
                  for f in (False, True)]


def _pad(fps: list[int]):
    ks = np.zeros(Q, np.int32)
    mask = np.zeros(Q, bool)
    ks[: len(fps)] = fps[:Q]
    mask[: len(fps)] = True
    return ks, mask


class _Oracle:
    def __init__(self):
        self.mapping: dict[int, int] = {}   # fp -> page
        self.refcnt = [0] * N_PAGES
        self.stamp = [0] * N_PAGES
        self.clock = 1

    @property
    def cached(self):
        return set(self.mapping.values())

    def publish(self, fps, pages, mask):
        ok, seen = [], set()
        for f, p, m in zip(fps, pages, mask):
            good = bool(m) and f not in self.mapping and f not in seen
            ok.append(good)
            seen.add(f)
            if good:
                self.mapping[f] = p
                self.stamp[p] = self.clock
        self.clock += 1
        return ok

    def touch(self, pages, mask):
        for p, m in zip(pages, mask):
            if m:
                self.stamp[p] = self.clock
        self.clock += 1

    def evict(self, want):
        cand = sorted((p for p in self.cached if self.refcnt[p] == 0),
                      key=lambda p: (self.stamp[p], p))
        victims = cand[:want]
        for p in victims:
            fp = next(f for f, pp in self.mapping.items() if pp == p)
            del self.mapping[fp]
        return victims


def _check_invariants(ps, oracle, ctx):
    cached = np.asarray(jax.device_get(ps.cached))
    assert set(np.where(cached)[0].tolist()) == oracle.cached, ctx
    n = len(oracle.mapping)
    assert int(jax.device_get(dhash.count_items(ps.table))) == n, ctx
    assert int(jax.device_get(dhash.count_items(ps.rev))) == n, ctx
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(ps.refcnt)), np.asarray(oracle.refcnt),
        err_msg=str(ctx))


def run_script(backend: str, fused: bool, script, seed: int):
    ps = eviction.make(N_PAGES, backend=backend, capacity=32, chunk=16,
                       seed=seed % 5, fused=fused)
    oracle = _Oracle()
    free = list(range(N_PAGES))          # harness-owned page allocator
    rb_seed = seed

    for step_no, (opcode, payload) in enumerate(script):
        ctx = (backend, fused, step_no, opcode, payload)
        if opcode == OP_PUBLISH:
            payload = payload[: len(free)]
            if not payload:
                continue
            ks, mask = _pad(payload)
            pages = np.zeros(Q, np.int32)
            pages[: len(payload)] = free[: len(payload)]
            ps, ok = _FNS["publish"](ps, jnp.asarray(ks),
                                     jnp.asarray(pages), jnp.asarray(mask))
            exp = oracle.publish(ks.tolist(), pages.tolist(), mask.tolist())
            assert np.asarray(ok).tolist() == exp, ctx
            # pages that actually published leave the free pool
            free = [p for p in free
                    if p not in {pg for pg, o in zip(pages, ok) if o}]
        elif opcode == OP_MATCH:
            ks, mask = _pad(payload)
            found, pages = _FNS["lookup"](ps.table, jnp.asarray(ks))
            hits, hit_pages = [], []
            for f, m, fn, pg in zip(ks.tolist(), mask.tolist(),
                                    np.asarray(found).tolist(),
                                    np.asarray(pages).tolist()):
                if not m:
                    continue
                assert fn == (f in oracle.mapping), ctx
                if fn:
                    assert pg == oracle.mapping[f], ctx
                    hits.append(True), hit_pages.append(pg)
            pad_pg = np.zeros(Q, np.int32)
            pad_m = np.zeros(Q, bool)
            pad_pg[: len(hit_pages)] = hit_pages
            pad_m[: len(hit_pages)] = hits
            ps = _FNS["touch"](ps, jnp.asarray(pad_pg), jnp.asarray(pad_m))
            oracle.touch(pad_pg.tolist(), pad_m.tolist())
        elif opcode in (OP_ACQUIRE, OP_RELEASE):
            # pin/unpin the pages of mapped fingerprints; releases are only
            # issued against pins the harness actually holds (the kvcache
            # caller contract)
            pgs = []
            for f in payload:
                p = oracle.mapping.get(f)
                if p is None:
                    continue
                if opcode == OP_RELEASE and oracle.refcnt[p] - \
                        pgs.count(p) <= 0:
                    continue
                pgs.append(p)
            pad_pg = np.zeros(Q, np.int32)
            pad_m = np.zeros(Q, bool)
            pad_pg[: len(pgs)] = pgs
            pad_m[: len(pgs)] = True
            name = "acquire" if opcode == OP_ACQUIRE else "release"
            ps = _FNS[name](ps, jnp.asarray(pad_pg), jnp.asarray(pad_m))
            for p in pgs:
                oracle.refcnt[p] += 1 if opcode == OP_ACQUIRE else -1
        elif opcode == OP_EVICT:
            want = len(payload)
            ps, victims, ok = _FNS["evict"](ps, Q, jnp.asarray(want, I32))
            got = np.asarray(victims)[np.asarray(ok)].tolist()
            exp_v = oracle.evict(min(want, Q))
            assert got == exp_v, (ctx, got, exp_v)
            free += got
        elif opcode == OP_START:
            if not bool(jax.device_get(ps.table.rebuilding)):
                rb_seed += 1
                ps = eviction.replace(
                    ps, table=dhash.rebuild_start(ps.table, seed=rb_seed))
        elif opcode == OP_STEP:
            ps = eviction.replace(ps, table=_FNS["step"](ps.table))
        _check_invariants(ps, oracle, ctx)

    # drain any in-flight rebuild, then final membership over the universe
    for _ in range(2 * (32 // 16) + 8):
        if not bool(jax.device_get(ps.table.rebuilding)):
            break
        ps = eviction.replace(ps, table=_FNS["step"](ps.table))
    assert not bool(jax.device_get(ps.table.rebuilding)), (backend, fused)
    ks = jnp.asarray(np.asarray(FPS, np.int32))
    found, pages = _FNS["lookup"](ps.table, ks)
    for i, f in enumerate(FPS):
        assert bool(found[i]) == (f in oracle.mapping), (backend, fused, f)
        if f in oracle.mapping:
            assert int(pages[i]) == oracle.mapping[f], (backend, fused, f)


if HAVE_HYPOTHESIS:
    @pytest.mark.parametrize("backend,fused", BACKEND_PARAMS)
    @settings(max_examples=4, deadline=None)
    @given(script=_script, seed=st.integers(0, 2**16))
    def test_prefix_differential_op_sequences(backend, fused, script, seed):
        if fused and not backends.get(backend).fused:
            pytest.skip(f"{backend} has no fused kernels")
        run_script(backend, fused, script, seed)


@pytest.mark.parametrize("backend,fused", BACKEND_PARAMS)
def test_prefix_differential_regression_corpus(backend, fused):
    """Replay every pinned sequence against every backend config — runs
    with or without hypothesis installed."""
    if fused and not backends.get(backend).fused:
        pytest.skip(f"{backend} has no fused kernels")
    for i, script in enumerate(CORPUS):
        run_script(backend, fused, script, seed=500 + i)
