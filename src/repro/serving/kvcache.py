"""Paged KV cache with a DHash page table (vLLM-style, TPU-native).

The page table is the paper's structure in its natural serving role:
``(seq_id, block_idx) -> physical page`` lives in a DHash instance, so the
cache can be *rehashed/resized live* (bursty admission, fragmentation, or
adversarial request patterns) while decode steps keep resolving pages at
full rate — lookups follow the ordered old->hazard->new check and never
block on the rebuild.

Attention over pages is flash-decoding style: a scan over blocks with a
running (max, denominator) accumulator — no materialization of the gathered
KV, so the memory roofline term stays at one pass over the live pages.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dhash
from repro.core.struct_utils import pytree_dataclass, replace

F32 = jnp.float32
I32 = jnp.int32
NEG_INF = -2.0e38


def block_key(seq_id: jax.Array, block_idx: jax.Array) -> jax.Array:
    """Pack the page-table key; 15 bits of block index."""
    return (seq_id.astype(I32) << 15) | block_idx.astype(I32)


@pytree_dataclass(meta_fields=("layers", "page_size", "n_pages", "kv_heads",
                               "head_dim", "max_blocks"))
class PagedKV:
    layers: int
    page_size: int
    n_pages: int
    kv_heads: int
    head_dim: int
    max_blocks: int              # blocks per sequence bound
    pool_k: jax.Array            # [L, n_pages, page, KV, HD]
    pool_v: jax.Array
    table: dhash.DHashState      # block_key -> page id
    free_stack: jax.Array        # [n_pages] i32
    free_top: jax.Array          # scalar i32


def make(layers: int, page_size: int, n_pages: int, kv_heads: int,
         head_dim: int, *, max_blocks: int = 4096, dtype=jnp.bfloat16,
         table_chunk: int = 256, seed: int = 3) -> PagedKV:
    shp = (layers, n_pages, page_size, kv_heads, head_dim)
    return PagedKV(
        layers=layers, page_size=page_size, n_pages=n_pages, kv_heads=kv_heads,
        head_dim=head_dim, max_blocks=max_blocks,
        pool_k=jnp.zeros(shp, dtype), pool_v=jnp.zeros(shp, dtype),
        table=dhash.make("linear", capacity=2 * n_pages, chunk=table_chunk,
                         seed=seed),
        free_stack=jnp.arange(n_pages, dtype=I32),
        free_top=jnp.asarray(n_pages, I32))


def resolve_blocks(kv: PagedKV, seq_ids: jax.Array, n_blocks: int):
    """DHash-resolve the page of every (seq, block) pair.
    seq_ids: [B] -> (pages [B, n_blocks] i32, found [B, n_blocks])."""
    b = seq_ids.shape[0]
    blk = jnp.arange(n_blocks, dtype=I32)
    keys = block_key(seq_ids[:, None], blk[None, :]).reshape(-1)
    found, page = dhash.lookup(kv.table, keys)
    return page.reshape(b, n_blocks), found.reshape(b, n_blocks)


def alloc_pages(kv: PagedKV, seq_ids: jax.Array, block_idx: jax.Array,
                mask: jax.Array):
    """Allocate one page per masked (seq, block) and insert into the table.
    Idempotent: pairs already mapped keep their page (no leak).
    Returns (kv', pages [B])."""
    keys = block_key(seq_ids, block_idx)
    present, _ = dhash.lookup(kv.table, keys)
    want = mask & ~present
    rank = jnp.cumsum(want.astype(I32)) - 1
    can = want & (rank < kv.free_top)
    page = kv.free_stack[jnp.where(can, kv.free_top - 1 - rank, 0)]
    table, ok = dhash.insert(kv.table, keys, page, can)
    used = jnp.sum((can & ok).astype(I32))
    return replace(kv, table=table, free_top=kv.free_top - used), \
        jnp.where(can, page, -1)


def append_token(kv: PagedKV, seq_ids: jax.Array, positions: jax.Array,
                 k_new: jax.Array, v_new: jax.Array):
    """Write one token's K/V for every layer.

    k_new/v_new: [L, B, KV, HD]; positions: [B] (0-based index of the new
    token). Allocates a fresh page when the position opens a new block."""
    ps = kv.page_size
    blk, off = positions // ps, positions % ps
    kv, pages_new = alloc_pages(kv, seq_ids, blk, off == 0)
    pages, found = resolve_blocks_at(kv, seq_ids, blk)
    page = jnp.where(found, pages, pages_new)
    lidx = jnp.arange(kv.layers, dtype=I32)[:, None]
    pool_k = kv.pool_k.at[lidx, page[None, :], off[None, :]].set(k_new)
    pool_v = kv.pool_v.at[lidx, page[None, :], off[None, :]].set(v_new)
    return replace(kv, pool_k=pool_k, pool_v=pool_v)


def resolve_blocks_at(kv: PagedKV, seq_ids: jax.Array, block_idx: jax.Array):
    keys = block_key(seq_ids, block_idx)
    found, page = dhash.lookup(kv.table, keys)
    return page, found


def paged_decode_attention(kv: PagedKV, layer: jax.Array, q1: jax.Array,
                           seq_ids: jax.Array, cache_len: jax.Array,
                           n_blocks: int, *, window=0, softcap: float = 0.0):
    """Flash-decoding over pages for ONE layer slice of the pool.

    q1: [B, Hq, HD]; returns [B, Hq, HD].  ``layer`` may be traced (scan).
    """
    b, hq, hd = q1.shape
    hkv, ps = kv.kv_heads, kv.page_size
    g = hq // hkv
    scale = 1.0 / np.sqrt(hd)
    pages, found = resolve_blocks(kv, seq_ids, n_blocks)    # [B, n_blocks]
    qg = q1.reshape(b, hkv, g, hd)
    pool_k = jax.lax.dynamic_index_in_dim(kv.pool_k, layer, 0, keepdims=False)
    pool_v = jax.lax.dynamic_index_in_dim(kv.pool_v, layer, 0, keepdims=False)

    def body(carry, blk):
        m, l, acc = carry
        pg = pages[:, blk]                                   # [B]
        kb = pool_k[jnp.where(pg >= 0, pg, 0)]               # [B, ps, KV, HD]
        vb = pool_v[jnp.where(pg >= 0, pg, 0)]
        s = jnp.einsum("bhgd,bphd->bhgp", qg, kb).astype(F32) * scale
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        pos = blk * ps + jnp.arange(ps, dtype=I32)[None, :]  # [1, ps]
        ok = (pos < cache_len[:, None]) & found[:, blk][:, None] & (pg >= 0)[:, None]
        ok &= (window <= 0) | (pos >= cache_len[:, None] - window)
        s = jnp.where(ok[:, None, None, :], s, NEG_INF)
        m2 = jnp.maximum(m, s.max(-1))
        w = jnp.exp(s - m2[..., None])
        corr = jnp.exp(m - m2)
        l2 = l * corr + w.sum(-1)
        acc2 = acc * corr[..., None] + jnp.einsum(
            "bhgp,bphd->bhgd", w.astype(vb.dtype), vb).astype(F32)
        return (m2, l2, acc2), None

    m0 = jnp.full((b, hkv, g), -jnp.inf, F32)
    l0 = jnp.zeros((b, hkv, g), F32)
    a0 = jnp.zeros((b, hkv, g, hd), F32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  jnp.arange(n_blocks, dtype=I32))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, hd).astype(q1.dtype)


def free_sequences(kv: PagedKV, seq_ids: jax.Array, max_blocks: int):
    """Release all pages of finished sequences back to the free list and
    delete their table entries (batched)."""
    b = seq_ids.shape[0]
    blk = jnp.arange(max_blocks, dtype=I32)
    keys = block_key(seq_ids[:, None], blk[None, :]).reshape(-1)
    found, pages = dhash.lookup(kv.table, keys)
    table, ok = dhash.delete(kv.table, keys, found)
    # push freed pages (deterministic order)
    rank = jnp.cumsum(ok.astype(I32)) - 1
    dst = jnp.where(ok, kv.free_top + rank, kv.n_pages)
    free_stack = kv.free_stack.at[dst].set(pages, mode="drop")
    freed = jnp.sum(ok.astype(I32))
    return replace(kv, table=table, free_stack=free_stack,
                   free_top=kv.free_top + freed)


def rehash_step(kv: PagedKV) -> PagedKV:
    """One live rebuild transition on the page table (engine interleaves)."""
    return replace(kv, table=dhash.rebuild_step(kv.table))
