"""Clock/LRU eviction for the prefix cache — itself a DHash client.

``prefix_cache.publish_prefix`` only inserts, so any replay longer than the
page pool saturates it.  This module adds the missing production piece: a
batched LRU policy over *pages* whose bookkeeping lives in DHash tables, so
eviction keeps working (and keeps its latency profile) while either index
is being rehashed live.

State (``PrefixState``):

* ``table`` — the forward prefix index, ``fingerprint -> page`` (what
  ``prefix_cache.match_prefix`` queries).  Backend-parameterised: the
  macro-bench runs it on ``chain`` to mirror ``bench_attack``'s
  collision-attack surface.
* ``rev`` — the REVERSE index, ``page_key(page) = page + 1 -> fingerprint``
  (a linear DHash).  Eviction picks victim *pages*; the reverse index is
  how a victim page finds the fingerprint it must delete from ``table``
  (via the existing fused delete path) without scanning the table.
* ``refcnt`` — pin counts per page.  Pages adopted by live sequences are
  acquired; ``refcnt > 0`` pages are NEVER victims, so decode can keep
  reading a shared page while the policy churns around it.
* ``cached``/``stamp``/``clock`` — clock-LRU bookkeeping: every publish or
  touch stamps the page with the current clock tick; victims are the
  coldest stamps among ``cached & refcnt == 0``.

Invariant (checked by the differential suite): every ``cached`` page has
exactly one forward entry and one reverse entry — ``publish`` rolls back
the forward insert if the reverse insert fails, and ``evict`` deletes both
or neither.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import dhash
from repro.core.struct_utils import pytree_dataclass, replace

I32 = jnp.int32
STAMP_MAX = jnp.iinfo(jnp.int32).max


def page_key(pages: jax.Array) -> jax.Array:
    """Reverse-index key of a page id (shifted so page 0 and the invalid
    marker -1 stay distinct key values)."""
    return pages.astype(I32) + 1


@pytree_dataclass(meta_fields=("n_pages",))
class PrefixState:
    n_pages: int
    table: dhash.DHashState      # fingerprint -> page (forward prefix index)
    rev: dhash.DHashState        # page_key(page) -> fingerprint
    refcnt: jax.Array            # [n_pages] i32 pin counts
    cached: jax.Array            # [n_pages] bool: page holds a published block
    stamp: jax.Array             # [n_pages] i32 last-touch clock tick
    clock: jax.Array             # scalar i32
    evictions: jax.Array         # scalar i32 cumulative victim count


def make(n_pages: int, *, backend: str = "linear", capacity: int | None = None,
         chunk: int = 256, seed: int = 11, fused: bool | None = None,
         **backend_kw) -> PrefixState:
    """Build the eviction state.  ``capacity`` sizes the forward index
    (default ``4 * n_pages`` — room for tombstone churn); the reverse index
    is always linear at ``2 * n_pages`` (one entry per cached page)."""
    if capacity is None:
        capacity = 4 * n_pages
    table = dhash.make(backend, capacity=capacity, chunk=chunk, seed=seed,
                       fused=fused, **backend_kw)
    rev = dhash.make("linear", capacity=2 * n_pages, chunk=chunk,
                     seed=seed + 7)
    return PrefixState(
        n_pages=n_pages, table=table, rev=rev,
        refcnt=jnp.zeros((n_pages,), I32),
        cached=jnp.zeros((n_pages,), bool),
        stamp=jnp.zeros((n_pages,), I32),
        clock=jnp.asarray(1, I32),
        evictions=jnp.asarray(0, I32))


def _scatter_hit(ps: PrefixState, pages: jax.Array, mask: jax.Array):
    """[n_pages] bool: pages named by the masked batch (dup-safe)."""
    tgt = jnp.clip(pages, 0, ps.n_pages - 1)
    return jnp.zeros((ps.n_pages,), I32).at[tgt].add(mask.astype(I32)) > 0


def publish(ps: PrefixState, fps: jax.Array, pages: jax.Array,
            mask: jax.Array):
    """Publish ``fingerprint -> page`` mappings and mark the pages cached.

    Set semantics: a fingerprint that is already published keeps its
    EXISTING page — the duplicate's page is not marked cached (the caller's
    page simply stays un-shared).  ``dhash.insert`` only enforces this
    within the TARGET table (Alg. 6), so mid-rebuild it would happily
    duplicate a fingerprint whose entry has not migrated out of the old
    table yet — and evicting either copy's page would then corrupt the
    other's mapping.  The epoch-consistent pre-lookup (old -> hazard -> new)
    screens those out.  Returns ``(ps', ok)`` where ``ok`` marks mappings
    that landed in BOTH indexes.
    """
    already, _ = dhash.lookup(ps.table, fps)
    table, ok = dhash.insert(ps.table, fps, pages, mask & ~already)
    rev, okr = dhash.insert(ps.rev, page_key(pages), fps, ok)
    # keep the invariant "cached => discoverable from both sides": a forward
    # entry whose reverse insert failed is rolled back (cond-gated — the
    # healthy path never pays the extra delete)
    bad = ok & ~okr
    table = lax.cond(bad.any(),
                     lambda t: dhash.delete(t, fps, bad)[0],
                     lambda t: t, table)
    ok = ok & okr
    hit = _scatter_hit(ps, pages, ok)
    return replace(ps, table=table, rev=rev,
                   cached=ps.cached | hit,
                   stamp=jnp.where(hit, ps.clock, ps.stamp),
                   clock=ps.clock + 1), ok


def touch(ps: PrefixState, pages: jax.Array, mask: jax.Array) -> PrefixState:
    """Stamp pages with the current clock tick (a cache hit re-warms its
    pages so the LRU scan skips them)."""
    hit = _scatter_hit(ps, pages, mask)
    return replace(ps, stamp=jnp.where(hit, ps.clock, ps.stamp),
                   clock=ps.clock + 1)


def acquire(ps: PrefixState, pages: jax.Array, mask: jax.Array) -> PrefixState:
    """Pin pages (+1 refcnt each masked reference; duplicates accumulate).
    A pinned page is never an eviction victim."""
    tgt = jnp.clip(pages, 0, ps.n_pages - 1)
    return replace(ps, refcnt=ps.refcnt.at[tgt].add(
        jnp.where(mask, 1, 0).astype(I32)))


def release(ps: PrefixState, pages: jax.Array, mask: jax.Array) -> PrefixState:
    """Unpin pages (-1 refcnt per masked reference)."""
    tgt = jnp.clip(pages, 0, ps.n_pages - 1)
    return replace(ps, refcnt=ps.refcnt.at[tgt].add(
        jnp.where(mask, -1, 0).astype(I32)))


def evictable(ps: PrefixState) -> jax.Array:
    """[n_pages] bool: cached and unpinned — the victim candidate set."""
    return ps.cached & (ps.refcnt == 0)


def evict(ps: PrefixState, k: int, want: jax.Array):
    """Evict up to ``want`` (dynamic, ``<= k`` static) coldest unpinned
    cached pages.

    The victim scan is one ``top_k`` over negated stamps (pinned and
    uncached pages are masked to ``STAMP_MAX``; ties break to the lowest
    page id — ``lax.top_k`` is index-stable).  Each victim resolves its
    fingerprint through the reverse index, deletes it from the forward
    index (the fused delete path when the backend is fused — this is the
    op-budget the macro-bench pins), deletes its reverse entry, and drops
    ``cached``.  Returns ``(ps', pages[k], ok[k])``: ``ok`` marks pages
    actually evicted — they are safe to hand back to the page pool.
    """
    ev = evictable(ps)
    coldness = jnp.where(ev, ps.stamp, STAMP_MAX)
    neg, idx = lax.top_k(-coldness, k)                 # coldest first
    pick = (-neg != STAMP_MAX) & (jnp.arange(k, dtype=I32) < want)
    found, fps = dhash.lookup(ps.rev, page_key(idx))
    # a cached page with no reverse entry would leave a live forward mapping
    # to a freed page — never free it (the invariant makes this unreachable;
    # the differential suite checks it stays that way)
    ok = pick & found
    table, _ = dhash.delete(ps.table, fps, ok)
    rev, _ = dhash.delete(ps.rev, page_key(idx), ok)
    hit = _scatter_hit(ps, idx, ok)
    return replace(ps, table=table, rev=rev,
                   cached=ps.cached & ~hit,
                   evictions=ps.evictions + ok.sum(dtype=I32)), idx, ok
