"""Pallas TPU kernels: batched probing over VMEM-resident table slabs.

TPU adaptation of the paper's hot paths.  On CPUs the per-op cost at load
factor alpha is pointer chasing; on TPU the equivalent hot loop is the probe
sequence, and the roofline term is HBM traffic: a naive gather streams table
lines per query.  Every kernel here restructures the access pattern the same
way (HashGraph-style sorted/coalesced probing):

  1. ops.py sorts the query batch by start slot h0 (ONE XLA sort per batch),
     so each query tile touches a *contiguous slab* of the table;
  2. a scalar-prefetch BlockSpec (`pltpu.PrefetchScalarGridSpec`) picks the
     two consecutive table blocks covering the tile's slab — data-dependent
     block indexing, the canonical TPU pattern for sorted gathers;
  3. the probe loop then runs entirely in VMEM on the VPU: each of the
     ``max_probes`` rounds is a vectorized compare of the query tile against
     dynamically-indexed slab lanes.

Nine kernels share that skeleton:

* ``_probe_kernel``        — single-table lookup (steady state, no rebuild).
  Emits per-query slot LOCATIONS alongside found/val, so the delete path
  (``ops.probe_delete``) tombstones with one scatter — lookup and delete are
  the same single pass.
* ``_probe2_kernel``       — the fused **rebuild-epoch** lookup: ONE pass
  emits the paper's Lemma-4.1-ordered result (old table -> hazard buffer ->
  new table).  One shared query sort keyed on ``h0_old`` drives the
  old-table slab selection; the new table gets a **two-level tile map**
  instead of a second sort: a first-level jnp pass (ops.py) buckets each
  tile's new-table windows into ``nres`` resident blocks (per-tile
  histogram + top_k — no extra sort), the scalar-prefetch operand becomes a
  ``[1 + nres, tiles]`` block map (row 0 = old-table slab, rows 1.. = the
  tile's resident new-table blocks), and the kernel grid is
  ``(tiles, nres)``: iteration ``(i, r)`` probes tile ``i`` against resident
  new block ``r`` and REDUCES hits into the revisited output block
  (``r == 0`` initialises old + hazard + first new window, ``r > 0`` merges
  further new windows).  Growth-heavy rebuilds (new table many slabs long)
  therefore stay fused instead of escaping to the jnp fallback.  The hazard
  buffer is broadcast whole into VMEM for a dense tile-vs-chunk compare.
  The same pass also emits the ordered DELETE outputs — old hit flag +
  slot, hazard index, new slot — so ``ops.ordered_delete_fused`` lands
  old-tombstone / hazard-kill / new-tombstone without a second probe.
* ``_tc_probe2_kernel``    — the same treatment for ``twochoice``: each
  query's two row choices expand into two entries of ONE batch sorted by the
  OLD table's row index; iteration ``(i, r)`` gathers each entry's resident
  old row, runs the dense hazard compare, and merges the entry's new-table
  row from the tile's ``nres`` resident new row-blocks — the whole
  rebuild-epoch ordered lookup/delete for twochoice is one sort + one
  pallas_call (it previously composed two fused single-table passes).
* ``_probe_insert_kernel`` — batched linear-probe INSERT (claim-first-empty):
  phase 1 re-proves absence against the original slab states, phase 2 runs
  the claim loop on a local VMEM copy of the slab states (lowest in-tile
  query index wins a contested slot; claimed slots flip LIVE locally so later
  rounds skip them).  The kernel emits *claim positions*; ops.py applies them
  with one scatter and resolves cross-tile collisions there.  The rebuild's
  hazard LANDING is this same kernel (dhash routes it through the fused
  insert), so the whole epoch stays on-device.
* ``_extract_kernel``      — the rebuild chunk scan: reads the 2-block slab
  window holding ``cursor``, COMPACTS the live entries of the chunk to the
  front of the hazard outputs on-device (cumsum rank + local scatter), and
  emits the position-aligned MIGRATED mask that ops.py lands with one
  scatter.  Contract: ``chunk <= SLAB``; slots at/past the unpadded capacity
  never migrate; no sort needed (the window is already contiguous).
* ``_tc_lookup_kernel`` / ``_tc_insert_kernel`` — the ``twochoice`` backend
  on the same treatment: each query's TWO row choices expand into two
  entries of ONE batch sorted by row index; row blocks are ``[SLAB_R, W]``
  with ``SLAB_R * W = SLAB`` words.  Lookup gathers each entry's resident
  row and compares all W lanes at once (emitting flat slot locations the
  fused twochoice delete reuses — never a second probe); insert runs the
  same local-claim protocol as the linear kernel, one lane per round, and
  ops.py drops b-claims shadowed by a-claims before resolving cross-tile
  collisions.
* ``_chain_probe_kernel`` / ``_chain_probe2_kernel`` — the ``chain``
  backend over its ARENA-SORTED layout (the last backend onto the fused
  path): ``ops.chain_compact_fused`` keeps the node arena bucket-sorted and
  tombstone-compacted, so a chain probe is a segment window
  ``[bstart[b], bstart[b] + blen[b])`` — the same slab reduction as a
  linear probe, terminated by the per-query segment LENGTH instead of an
  EMPTY sentinel (the packed arena has none; cross-segment reads cannot
  false-match because a key's bucket is a function of the key).
  ``_chain_probe2_kernel`` is the rebuild-epoch single pass (old segments +
  dense hazard compare + new segments on the same ``(tiles, nres)``
  reduction grid, component outputs for the ordered delete).  Nodes
  inserted since the last compaction live in a contiguous dirty tail that
  ops.py resolves with a dense window compare — the hazard-buffer
  treatment — and a tail grown past ``ops.DIRTY_CAP`` escapes to the
  pointer-chasing jnp reference via the gated fallback.

Exactness contract (all kernels): a query whose probe window escapes its
2-block slab (hash skew), or whose new-table window misses ALL of its
tile's resident blocks (new table grown past the tile map's ``nres``
coverage), or whose claimed slot collides across tiles, raises
``complete=False`` / a conflict flag and is re-run by the jnp fallback in
ops.py — the kernels are exact, never wrong, occasionally partial.

VMEM budget (v5e ~16 MiB/core): query tile QT=1024 (8x128 vregs, 3 x 4 KiB),
slab block SLAB=4096 i32 words.  Single-table lookup holds 2 blocks x 3
arrays x 16 KiB = 96 KiB.  The fused probe2 doubles the table residency
(old + new = 192 KiB) and adds the hazard buffer (3 x chunk x 4 B; 48 KiB at
chunk=4096) plus the dense compare intermediate QT x chunk bools (4 MiB at
chunk=4096 before vreg tiling) — keep ``chunk <= 4096`` to stay well inside
VMEM.  The two-level tile map does NOT grow residency: only one resident
new-table block pair is in VMEM per ``(tile, r)`` grid step; the ``nres``
axis trades grid steps (and VPU probe rounds) for coverage.  Insert holds 2 key blocks + 2 state blocks + a 2*SLAB local state
copy = 96 KiB.  The MXU is idle throughout (VPU/memory kernels), so the
matmul pipeline of a co-scheduled layer is undisturbed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

I32 = jnp.int32
EMPTY, LIVE = 0, 1

QT = 1024     # queries per tile
SLAB = 4096   # table words per block (2 consecutive blocks resident)


def _window_probe(base_blk, h0, qk, k0, k1, v0, v1, s0, s1, max_probes: int):
    """Shared probe loop over one 2-block VMEM window.

    Returns (found, val, loc, complete); found/val/loc are gated to
    False/0/-1 for incomplete queries (probe window escapes the resident
    window).  ``loc`` is the padded-table coordinate of the LIVE hit (the
    slot the delete path tombstones), -1 when the key was not found.
    """
    base = base_blk * SLAB
    off = h0 - base                               # [QT] offset into 2*SLAB
    keys = jnp.concatenate([k0[...], k1[...]])    # [2*SLAB]
    vals = jnp.concatenate([v0[...], v1[...]])
    stat = jnp.concatenate([s0[...], s1[...]])

    # a probe sequence is complete iff it fits the resident window
    complete = (off >= 0) & (off + max_probes <= 2 * SLAB)
    safe_off = jnp.clip(off, 0, 2 * SLAB - max_probes)

    def body(p, carry):
        active, found, val, loc = carry
        idx = safe_off + p
        k = jnp.take(keys, idx, axis=0)
        v = jnp.take(vals, idx, axis=0)
        s = jnp.take(stat, idx, axis=0)
        hit = active & (s == LIVE) & (k == qk)
        stop = active & (s == EMPTY)
        val = jnp.where(hit, v, val)
        loc = jnp.where(hit, base + idx, loc)
        found = found | hit
        active = active & ~hit & ~stop
        return active, found, val, loc

    q = h0.shape[0]
    init = (jnp.ones((q,), bool), jnp.zeros((q,), bool),
            jnp.zeros((q,), I32), jnp.full((q,), -1, I32))
    _, found, val, loc = jax.lax.fori_loop(0, max_probes, body, init)
    return (found & complete, jnp.where(complete, val, 0),
            jnp.where(complete, loc, -1), complete)


def _probe_kernel(slab_ref,              # scalar-prefetch: [tiles] block index
                  h0_ref, qk_ref,        # [QT] query start slots / keys
                  tk0, tk1, tv0, tv1, ts0, ts1,   # [SLAB] table key/val/state
                  found_ref, val_ref, loc_ref, complete_ref,
                  *, max_probes: int):
    i = pl.program_id(0)
    found, val, loc, complete = _window_probe(
        slab_ref[i], h0_ref[...], qk_ref[...],
        tk0, tk1, tv0, tv1, ts0, ts1, max_probes)
    found_ref[...] = found
    val_ref[...] = val
    loc_ref[...] = loc
    complete_ref[...] = complete


def _probe2_kernel(slab2_ref,            # scalar-prefetch: [1 + nres, tiles]
                   h0o_ref, h0n_ref, qk_ref,           # [QT]
                   ok0, ok1, ov0, ov1, os0, os1,       # old table blocks
                   nk0, nk1, nv0, nv1, ns0, ns1,       # new resident blocks
                   hk_ref, hv_ref, hl_ref,             # [CH] hazard buffer
                   found_ref, val_ref, complete_ref,
                   fold_ref, locold_ref, hzidx_ref, locnew_ref, cold_ref,
                   *, max_probes: int):
    """Fused rebuild-epoch lookup: Lemma 4.1 order old -> hazard -> new in a
    single pass.  ``complete`` is refined: a query resolved by the old table
    or the hazard buffer is complete even if its new-table window escaped —
    the answer is already determined by the ordered-check priority.

    Grid is ``(tiles, nres)``: the second axis walks the tile's resident
    new-table blocks (two-level tile map, rows 1.. of ``slab2``).  The
    output block index depends only on the tile, so iterations ``r > 0``
    revisit the same output block and REDUCE into it: ``r == 0`` computes
    the old-table probe, the hazard compare, and the first new window;
    later iterations merge further new windows (a query's window matches at
    most one distinct resident block, so OR/max/where merges are exact).
    ``c_old`` is emitted so the merge rounds (and ops.py) can extend
    ``complete`` without re-probing the old table.

    Beyond found/val the kernel emits the WRITE-PATH outputs the ordered
    delete needs to tombstone in the same pass: the old-table hit flag and
    slot location, the hazard-buffer index of a live key match (-1 if none),
    and the new-table slot location (-1 when absent or the new-table window
    escaped).  found/val are NOT gated by ``complete`` here — ops.py's gated
    fallback overwrites every incomplete query anyway."""
    i = pl.program_id(0)
    r = pl.program_id(1)
    qk = qk_ref[...]
    f_new, v_new, l_new, c_new = _window_probe(
        slab2_ref[1 + r, i], h0n_ref[...], qk,
        nk0, nk1, nv0, nv1, ns0, ns1, max_probes)

    @pl.when(r == 0)
    def _init():
        f_old, v_old, l_old, c_old = _window_probe(
            slab2_ref[0, i], h0o_ref[...], qk,
            ok0, ok1, ov0, ov1, os0, os1, max_probes)
        # hazard buffer: dense [QT, CH] compare, whole chunk resident in VMEM
        eq = (qk[:, None] == hk_ref[...][None, :]) & (hl_ref[...][None, :] != 0)
        f_hz = eq.any(-1)
        hz_i = jnp.argmax(eq, axis=-1)
        v_hz = jnp.take(hv_ref[...], hz_i, axis=0)

        found_ref[...] = f_old | f_hz | f_new
        val_ref[...] = jnp.where(
            f_old, v_old, jnp.where(f_hz, v_hz, jnp.where(f_new, v_new, 0)))
        complete_ref[...] = c_old & (f_old | f_hz | c_new)
        fold_ref[...] = f_old
        locold_ref[...] = l_old
        hzidx_ref[...] = jnp.where(f_hz, hz_i.astype(I32), -1)
        locnew_ref[...] = l_new   # already -1 when absent or window escaped
        cold_ref[...] = c_old

    @pl.when(r > 0)
    def _merge():
        resolved = found_ref[...]
        found_ref[...] = resolved | f_new
        val_ref[...] = jnp.where(f_new & ~resolved, v_new, val_ref[...])
        complete_ref[...] = complete_ref[...] | (cold_ref[...] & c_new)
        locnew_ref[...] = jnp.maximum(locnew_ref[...], l_new)


def _probe_insert_kernel(slab_ref,           # scalar-prefetch: [tiles]
                         h0_ref, qk_ref, qm_ref,       # [QT] (qm: i32 mask)
                         tk0, tk1, ts0, ts1,           # [SLAB] key/state
                         present_ref, claim_ref, complete_ref,
                         *, max_probes: int):
    """Claim-first-EMPTY batched insert.  Emits per-query claim positions
    (padded-table coordinates; -1 = no claim) instead of mutating the table;
    ops.py scatters the claims and sends cross-tile conflicts to the jnp
    fallback.  Caller contract: ``qm`` is winner-filtered (at most one True
    per distinct key in the whole batch)."""
    i = pl.program_id(0)
    base = slab_ref[i] * SLAB
    off = h0_ref[...] - base
    qk = qk_ref[...]
    qm = qm_ref[...] != 0
    keys = jnp.concatenate([tk0[...], tk1[...]])
    stat = jnp.concatenate([ts0[...], ts1[...]])

    complete = (off >= 0) & (off + max_probes <= 2 * SLAB)
    safe_off = jnp.clip(off, 0, 2 * SLAB - max_probes)

    # phase 1: re-prove absence on the ORIGINAL slab states (same semantics
    # as buckets.linear_insert's presence lookup before its claim loop)
    def probe(p, carry):
        active, present = carry
        idx = safe_off + p
        s = jnp.take(stat, idx, axis=0)
        hit = active & (s == LIVE) & (jnp.take(keys, idx, axis=0) == qk)
        stop = active & (s == EMPTY)
        present = present | hit
        active = active & ~hit & ~stop
        return active, present

    qn = off.shape[0]
    _, present = jax.lax.fori_loop(0, max_probes, probe,
                                   (qm, jnp.zeros((qn,), bool)))

    # phase 2: claim loop on a LOCAL copy of the slab states; claimed slots
    # flip LIVE locally so later rounds skip them (matches the evolving-state
    # semantics of buckets.linear_insert); lowest in-tile index wins a slot.
    qidx = jax.lax.broadcasted_iota(I32, (qn,), 0)
    pending0 = qm & complete & ~present

    def claim_round(p, carry):
        st, pending, claim_rel = carry
        pos = safe_off + p
        free = pending & (jnp.take(st, pos, axis=0) != LIVE)
        tgt = jnp.where(free, pos, 2 * SLAB)
        cl = jnp.full((2 * SLAB,), qn, I32).at[tgt].min(qidx, mode="drop")
        won = free & (jnp.take(cl, pos, axis=0) == qidx)
        st = st.at[jnp.where(won, pos, 2 * SLAB)].set(LIVE, mode="drop")
        claim_rel = jnp.where(won, pos, claim_rel)
        return st, pending & ~won, claim_rel

    _, _, claim_rel = jax.lax.fori_loop(
        0, max_probes, claim_round,
        (stat, pending0, jnp.full((qn,), -1, I32)))

    present_ref[...] = present & complete
    claim_ref[...] = jnp.where(claim_rel >= 0, base + claim_rel, -1)
    complete_ref[...] = complete


def probe_lookup_tiles(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                       h0_sorted: jax.Array, qk_sorted: jax.Array,
                       slab_base: jax.Array, *, max_probes: int,
                       interpret: bool = True):
    """Run the kernel over pre-sorted, pre-tiled queries.

    tkey/tval/tstate: padded table arrays, length a multiple of SLAB and at
    least ``max(h0)+max_probes`` (ops.py pads with a wrapped copy so probes
    never wrap inside the kernel).
    h0_sorted/qk_sorted: [Q] sorted by h0, Q a multiple of QT.
    slab_base: [Q/QT] block index (h0_min of the tile // SLAB), clipped so
    block+1 stays in range.

    Returns (found[Q], val[Q], loc[Q], complete[Q]); ``loc`` is the hit's
    padded-table coordinate (-1 if absent) — the delete path tombstones
    ``loc % C`` with one scatter, no second probe pass.
    """
    q = h0_sorted.shape[0]
    assert q % QT == 0 and tkey.shape[0] % SLAB == 0
    tiles = q // QT

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((QT,), lambda i, s: (i,)),
            pl.BlockSpec((QT,), lambda i, s: (i,)),
            pl.BlockSpec((SLAB,), lambda i, s: (s[i],)),
            pl.BlockSpec((SLAB,), lambda i, s: (s[i] + 1,)),
            pl.BlockSpec((SLAB,), lambda i, s: (s[i],)),
            pl.BlockSpec((SLAB,), lambda i, s: (s[i] + 1,)),
            pl.BlockSpec((SLAB,), lambda i, s: (s[i],)),
            pl.BlockSpec((SLAB,), lambda i, s: (s[i] + 1,)),
        ],
        out_specs=[
            pl.BlockSpec((QT,), lambda i, s: (i,)),
            pl.BlockSpec((QT,), lambda i, s: (i,)),
            pl.BlockSpec((QT,), lambda i, s: (i,)),
            pl.BlockSpec((QT,), lambda i, s: (i,)),
        ],
    )
    out_shape = [
        jax.ShapeDtypeStruct((q,), jnp.bool_),
        jax.ShapeDtypeStruct((q,), I32),
        jax.ShapeDtypeStruct((q,), I32),
        jax.ShapeDtypeStruct((q,), jnp.bool_),
    ]
    kernel = functools.partial(_probe_kernel, max_probes=max_probes)
    # each table array is passed twice: block s and block s+1 of the same
    # buffer (XLA aliases the operand; no copy)
    return pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(
        slab_base, h0_sorted, qk_sorted, tkey, tkey, tval, tval, tstate, tstate)


def probe2_tiles(old_padded, new_padded,
                 hazard_key: jax.Array, hazard_val: jax.Array,
                 hazard_live_i32: jax.Array,
                 h0o_sorted: jax.Array, h0n_sorted: jax.Array,
                 qk_sorted: jax.Array, slab2: jax.Array, *,
                 max_probes: int, interpret: bool = True):
    """Fused rebuild-epoch probe over pre-sorted, pre-tiled queries.

    old_padded/new_padded: (key, val, state) triples padded as in
    ``probe_lookup_tiles`` (each table padded independently).
    slab2: [1 + nres, tiles] i32 — row 0 old-table block, rows 1.. the
    tile's resident new-table blocks (two-level tile map; repeat the last
    entry to pad).  hazard_live_i32: hazard liveness as i32
    (pallas-friendly).

    Returns (found, val, complete, f_old, loc_old, hz_idx, loc_new, c_old);
    f_old/loc_old/hz_idx/loc_new are the ordered-delete outputs (see
    ``_probe2_kernel``).  found/val are ungated — mask with ``complete``
    (the gated fallback in ops.py does this implicitly).
    """
    q = qk_sorted.shape[0]
    (okk, ovv, oss), (nkk, nvv, nss) = old_padded, new_padded
    assert q % QT == 0 and okk.shape[0] % SLAB == 0 and nkk.shape[0] % SLAB == 0
    tiles = q // QT
    nres = slab2.shape[0] - 1
    assert nres >= 1
    ch = hazard_key.shape[0]

    qspec = pl.BlockSpec((QT,), lambda i, r, s: (i,))
    oblk0 = pl.BlockSpec((SLAB,), lambda i, r, s: (s[0, i],))
    oblk1 = pl.BlockSpec((SLAB,), lambda i, r, s: (s[0, i] + 1,))
    nblk0 = pl.BlockSpec((SLAB,), lambda i, r, s: (s[1 + r, i],))
    nblk1 = pl.BlockSpec((SLAB,), lambda i, r, s: (s[1 + r, i] + 1,))
    hspec = pl.BlockSpec((ch,), lambda i, r, s: (0,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(tiles, nres),
        in_specs=[qspec, qspec, qspec,
                  oblk0, oblk1, oblk0, oblk1, oblk0, oblk1,
                  nblk0, nblk1, nblk0, nblk1, nblk0, nblk1,
                  hspec, hspec, hspec],
        out_specs=[qspec] * 8,
    )
    out_shape = [
        jax.ShapeDtypeStruct((q,), jnp.bool_),    # found
        jax.ShapeDtypeStruct((q,), I32),          # val
        jax.ShapeDtypeStruct((q,), jnp.bool_),    # complete
        jax.ShapeDtypeStruct((q,), jnp.bool_),    # f_old
        jax.ShapeDtypeStruct((q,), I32),          # loc_old (padded coords)
        jax.ShapeDtypeStruct((q,), I32),          # hazard index (-1 = none)
        jax.ShapeDtypeStruct((q,), I32),          # loc_new (padded coords)
        jax.ShapeDtypeStruct((q,), jnp.bool_),    # c_old (old window covered)
    ]
    kernel = functools.partial(_probe2_kernel, max_probes=max_probes)
    return pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(
        slab2, h0o_sorted, h0n_sorted, qk_sorted,
        okk, okk, ovv, ovv, oss, oss,
        nkk, nkk, nvv, nvv, nss, nss,
        hazard_key, hazard_val, hazard_live_i32)


def probe_insert_tiles(tkey: jax.Array, tstate: jax.Array,
                       h0_sorted: jax.Array, qk_sorted: jax.Array,
                       qm_sorted_i32: jax.Array, slab_base: jax.Array, *,
                       max_probes: int, interpret: bool = True):
    """Claim pass of the batched insert over pre-sorted, pre-tiled queries.

    Returns (present[Q], claim[Q] padded-table position or -1, complete[Q]).
    """
    q = h0_sorted.shape[0]
    assert q % QT == 0 and tkey.shape[0] % SLAB == 0
    tiles = q // QT

    qspec = pl.BlockSpec((QT,), lambda i, s: (i,))
    blk0 = pl.BlockSpec((SLAB,), lambda i, s: (s[i],))
    blk1 = pl.BlockSpec((SLAB,), lambda i, s: (s[i] + 1,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(tiles,),
        in_specs=[qspec, qspec, qspec, blk0, blk1, blk0, blk1],
        out_specs=[qspec, qspec, qspec],
    )
    out_shape = [
        jax.ShapeDtypeStruct((q,), jnp.bool_),
        jax.ShapeDtypeStruct((q,), I32),
        jax.ShapeDtypeStruct((q,), jnp.bool_),
    ]
    kernel = functools.partial(_probe_insert_kernel, max_probes=max_probes)
    return pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(
        slab_base, h0_sorted, qk_sorted, qm_sorted_i32,
        tkey, tkey, tstate, tstate)


# ---------------------------------------------------------------------------
# rebuild chunk extraction: slab window scan + on-device compaction
# ---------------------------------------------------------------------------

def _extract_kernel(info_ref,            # scalar-prefetch: [2] (block, cursor)
                    tk0, tk1, tv0, tv1, ts0, ts1,   # [SLAB] key/val/state
                    hk_ref, hv_ref, hl_ref, mig_ref,
                    *, chunk: int, capacity: int):
    """Rebuild chunk scan: read the ``chunk`` slots at ``cursor`` from the
    resident 2-block slab window, COMPACT the live entries to the front of
    the hazard outputs on-device (cumsum ranking + one local scatter), and
    emit the position-aligned MIGRATED mask ``mig`` that ops.py applies to
    the table state with a single scatter.

    Contract: ``chunk <= SLAB`` so the window always fits the two resident
    blocks, and ``capacity`` is the UNPADDED table length (slots at or past
    it never migrate).  Replaces the jnp gather scan in ``rebuild_extract``:
    one pallas_call + one scatter instead of three table gathers + scatter.
    """
    base = info_ref[0] * SLAB
    cur = info_ref[1]
    keys = jnp.concatenate([tk0[...], tk1[...]])
    vals = jnp.concatenate([tv0[...], tv1[...]])
    stat = jnp.concatenate([ts0[...], ts1[...]])

    lane = jax.lax.broadcasted_iota(I32, (chunk,), 0)
    off = jnp.clip(cur - base, 0, 2 * SLAB - chunk) + lane
    pos = cur + lane                               # absolute table position
    live = (pos < capacity) & (jnp.take(stat, off, axis=0) == LIVE)

    # compact: live entry j lands at rank(j) = #live entries before it
    rank = jnp.cumsum(live.astype(I32)) - 1
    dest = jnp.where(live, rank, chunk)
    hk_ref[...] = jnp.zeros((chunk,), I32).at[dest].set(
        jnp.take(keys, off, axis=0), mode="drop")
    hv_ref[...] = jnp.zeros((chunk,), I32).at[dest].set(
        jnp.take(vals, off, axis=0), mode="drop")
    hl_ref[...] = (lane < live.sum()).astype(I32)
    mig_ref[...] = live.astype(I32)


def extract_tiles(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                  block: jax.Array, cursor: jax.Array, *, chunk: int,
                  capacity: int, interpret: bool = True):
    """Run the extract kernel once over the slab window holding ``cursor``.

    tkey/tval/tstate: padded to a SLAB multiple with one spare block (pad is
    EMPTY, so padding never migrates).  block: scalar i32 slab block index
    (cursor // SLAB clipped so block+1 stays in range).  Returns
    (hkeys[chunk], hvals[chunk], hlive_i32[chunk], migrated_i32[chunk]) with
    the hazard outputs compacted and ``migrated`` aligned to slot positions.
    """
    assert chunk <= SLAB and tkey.shape[0] % SLAB == 0
    info = jnp.stack([block.astype(I32), cursor.astype(I32)])

    blk0 = pl.BlockSpec((SLAB,), lambda i, s: (s[0],))
    blk1 = pl.BlockSpec((SLAB,), lambda i, s: (s[0] + 1,))
    cspec = pl.BlockSpec((chunk,), lambda i, s: (0,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=[blk0, blk1, blk0, blk1, blk0, blk1],
        out_specs=[cspec, cspec, cspec, cspec],
    )
    out_shape = [jax.ShapeDtypeStruct((chunk,), I32)] * 4
    kernel = functools.partial(_extract_kernel, chunk=chunk,
                               capacity=capacity)
    return pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(
        info, tkey, tkey, tval, tval, tstate, tstate)


# ---------------------------------------------------------------------------
# twochoice: W-wide two-row gather kernels over row slabs
# ---------------------------------------------------------------------------
#
# The twochoice table is [B, W]; a query touches exactly rows ha(k), hb(k).
# The fused path expands each query into TWO row-entries (entry e < Q is the
# a-row of query e, entry e >= Q the b-row of query e - Q), applies the SAME
# sort + scalar-prefetch slab treatment keyed on the row index — ONE argsort
# + ONE pallas_call cover both choices — and recombines per query after the
# unsort.  Row blocks are [SLAB_R, W] with SLAB_R * W = SLAB words, so the
# VMEM budget matches the linear kernels.

def _tc_rowslab(width: int) -> int:
    return max(SLAB // max(width, 1), 8)


def _tc_row_probe(base_blk, row, qk, k0, k1, v0, v1, s0, s1, width: int):
    """Shared W-wide row probe over one 2-row-block VMEM window.

    Returns (found, val, loc, complete); ``loc`` is the flat TABLE slot
    index row*W + lane of the LIVE hit (-1 when absent or the row escaped
    the resident window)."""
    slab_r = _tc_rowslab(width)
    off = row - base_blk * slab_r
    keys = jnp.concatenate([k0[...], k1[...]], axis=0)     # [2*SLAB_R, W]
    vals = jnp.concatenate([v0[...], v1[...]], axis=0)
    stat = jnp.concatenate([s0[...], s1[...]], axis=0)

    complete = (off >= 0) & (off < 2 * slab_r)
    safe = jnp.clip(off, 0, 2 * slab_r - 1)
    krow = jnp.take(keys, safe, axis=0)                    # [QT, W]
    vrow = jnp.take(vals, safe, axis=0)
    srow = jnp.take(stat, safe, axis=0)

    hit = (krow == qk[:, None]) & (srow == LIVE)
    found = hit.any(-1) & complete
    lane = jnp.argmax(hit, axis=-1)
    val = jnp.take_along_axis(vrow, lane[:, None], axis=-1)[:, 0]
    return (found, jnp.where(found, val, 0),
            jnp.where(found, row * width + lane.astype(I32), -1), complete)


def _tc_lookup_kernel(slab_ref,            # scalar-prefetch: [tiles]
                      row_ref, qk_ref,     # [QT] row index / key per entry
                      tk0, tk1, tv0, tv1, ts0, ts1,   # [SLAB_R, W] blocks
                      found_ref, val_ref, loc_ref, complete_ref,
                      *, width: int):
    """W-wide two-row gather lookup: each entry reads its single resident
    row, compares all W lanes at once, and emits (found, val, loc) with
    ``loc`` the flat slot index row*W + lane (-1 if absent)."""
    i = pl.program_id(0)
    found, val, loc, complete = _tc_row_probe(
        slab_ref[i], row_ref[...], qk_ref[...],
        tk0, tk1, tv0, tv1, ts0, ts1, width)
    found_ref[...] = found
    val_ref[...] = val
    loc_ref[...] = loc
    complete_ref[...] = complete


def _tc_insert_kernel(slab_ref,            # scalar-prefetch: [tiles]
                      row_ref, qk_ref, qm_ref,           # [QT] (qm: i32)
                      tk0, tk1, ts0, ts1,                # [SLAB_R, W] blocks
                      present_ref, claim_ref, complete_ref,
                      *, width: int):
    """Claim-a-lane batched twochoice insert.  Each entry (one row choice of
    one query) re-proves absence against its row, then joins a local claim
    loop on a VMEM copy of the resident row states: per round an entry picks
    its row's lowest non-LIVE lane, the lowest in-tile entry index wins a
    contested lane, and winners flip the lane LIVE locally.  Emits flat slot
    claims (row*W + lane in TABLE coordinates; -1 = none); ops.py drops
    shadowed b-claims, resolves cross-tile collisions, and routes conflicts
    to the jnp fallback — exact, never wrong, occasionally partial."""
    i = pl.program_id(0)
    slab_r = _tc_rowslab(width)
    base = slab_ref[i] * slab_r
    off = row_ref[...] - base
    qk = qk_ref[...]
    qm = qm_ref[...] != 0
    keys = jnp.concatenate([tk0[...], tk1[...]], axis=0)
    stat = jnp.concatenate([ts0[...], ts1[...]], axis=0)

    complete = (off >= 0) & (off < 2 * slab_r)
    safe = jnp.clip(off, 0, 2 * slab_r - 1)
    krow = jnp.take(keys, safe, axis=0)
    srow = jnp.take(stat, safe, axis=0)
    present = ((krow == qk[:, None]) & (srow == LIVE)).any(-1) & complete

    qn = off.shape[0]
    qidx = jax.lax.broadcasted_iota(I32, (qn,), 0)
    nloc = 2 * slab_r * width
    pending0 = qm & complete & ~present

    def claim_round(r, carry):
        st, pending, claim = carry
        srow_now = jnp.take(st, safe, axis=0)              # [QT, W]
        free = srow_now != LIVE
        has = pending & free.any(-1)
        lane = jnp.argmax(free, axis=-1)
        flat = safe * width + lane.astype(I32)             # local coords
        tgt = jnp.where(has, flat, nloc)
        cl = jnp.full((nloc,), qn, I32).at[tgt].min(qidx, mode="drop")
        won = has & (jnp.take(cl, flat, axis=0) == qidx)
        st = st.reshape(-1).at[jnp.where(won, flat, nloc)].set(
            LIVE, mode="drop").reshape(2 * slab_r, width)
        claim = jnp.where(won, flat, claim)
        return st, pending & ~won, claim

    _, _, claim_loc = jax.lax.fori_loop(
        0, width, claim_round,
        (stat, pending0, jnp.full((qn,), -1, I32)))

    present_ref[...] = present
    claim_ref[...] = jnp.where(claim_loc >= 0,
                               base * width + claim_loc, -1)
    complete_ref[...] = complete


def _tc_specs(width: int):
    """Entry-tile and row-block BlockSpecs shared by the twochoice kernels."""
    slab_r = _tc_rowslab(width)
    qspec = pl.BlockSpec((QT,), lambda i, s: (i,))
    blk0 = pl.BlockSpec((slab_r, width), lambda i, s: (s[i], 0))
    blk1 = pl.BlockSpec((slab_r, width), lambda i, s: (s[i] + 1, 0))
    return qspec, blk0, blk1


def tc_lookup_tiles(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                    row_sorted: jax.Array, qk_sorted: jax.Array,
                    slab_base: jax.Array, *, interpret: bool = True):
    """Run the twochoice lookup kernel over pre-sorted, pre-tiled entries.

    tkey/tval/tstate: [Bpad, W] row-padded tables (Bpad a SLAB_R multiple
    plus one spare block, pad rows EMPTY).  row_sorted/qk_sorted: [E] entry
    rows/keys sorted by row, E a multiple of QT.  slab_base: [E/QT] row-block
    index.  Returns (found[E], val[E], loc[E], complete[E]).
    """
    e = row_sorted.shape[0]
    width = tkey.shape[1]
    slab_r = _tc_rowslab(width)
    assert e % QT == 0 and tkey.shape[0] % slab_r == 0
    tiles = e // QT
    qspec, blk0, blk1 = _tc_specs(width)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(tiles,),
        in_specs=[qspec, qspec, blk0, blk1, blk0, blk1, blk0, blk1],
        out_specs=[qspec] * 4,
    )
    out_shape = [
        jax.ShapeDtypeStruct((e,), jnp.bool_),
        jax.ShapeDtypeStruct((e,), I32),
        jax.ShapeDtypeStruct((e,), I32),
        jax.ShapeDtypeStruct((e,), jnp.bool_),
    ]
    kernel = functools.partial(_tc_lookup_kernel, width=width)
    return pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(
        slab_base, row_sorted, qk_sorted,
        tkey, tkey, tval, tval, tstate, tstate)


def tc_insert_tiles(tkey: jax.Array, tstate: jax.Array,
                    row_sorted: jax.Array, qk_sorted: jax.Array,
                    qm_sorted_i32: jax.Array, slab_base: jax.Array, *,
                    interpret: bool = True):
    """Claim pass of the twochoice insert over pre-sorted, pre-tiled entries.

    Returns (present[E], claim[E] flat table slot or -1, complete[E]).
    """
    e = row_sorted.shape[0]
    width = tkey.shape[1]
    slab_r = _tc_rowslab(width)
    assert e % QT == 0 and tkey.shape[0] % slab_r == 0
    tiles = e // QT
    qspec, blk0, blk1 = _tc_specs(width)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(tiles,),
        in_specs=[qspec, qspec, qspec, blk0, blk1, blk0, blk1],
        out_specs=[qspec] * 3,
    )
    out_shape = [
        jax.ShapeDtypeStruct((e,), jnp.bool_),
        jax.ShapeDtypeStruct((e,), I32),
        jax.ShapeDtypeStruct((e,), jnp.bool_),
    ]
    kernel = functools.partial(_tc_insert_kernel, width=width)
    return pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(
        slab_base, row_sorted, qk_sorted, qm_sorted_i32,
        tkey, tkey, tstate, tstate)


# ---------------------------------------------------------------------------
# twochoice rebuild-epoch probe2: old row + hazard + new row in ONE pass
# ---------------------------------------------------------------------------

def _tc_probe2_kernel(slab2_ref,           # scalar-prefetch: [1 + nres, tiles]
                      orow_ref, nrow_ref, qk_ref,        # [QT] per entry
                      ok0, ok1, ov0, ov1, os0, os1,      # old row blocks
                      nk0, nk1, nv0, nv1, ns0, ns1,      # new resident blocks
                      hk_ref, hv_ref, hl_ref,            # [CH] hazard buffer
                      fold_ref, vold_ref, lold_ref, cold_ref, hzidx_ref,
                      fnew_ref, vnew_ref, lnew_ref, cnew_ref,
                      *, width: int):
    """Fused twochoice rebuild-epoch probe: per entry (one row choice of one
    query) the OLD row gather, the dense hazard compare, and the NEW row
    gather land in a single pass — the same ``(tiles, nres)`` reduction grid
    as ``_probe2_kernel`` (row 0 of ``slab2`` anchors the sorted old
    row-blocks; rows 1.. are the tile's resident new row-blocks, and
    iterations ``r > 0`` merge further new windows into the revisited
    outputs).  The kernel emits per-entry COMPONENTS (old hit/val/flat
    slot/coverage, hazard index, new hit/val/flat slot/coverage); ops.py
    recombines the two entries of each query with a-row priority and applies
    the Lemma-4.1 ordering — so the same outputs serve both the ordered
    lookup and the ordered delete."""
    i = pl.program_id(0)
    r = pl.program_id(1)
    qk = qk_ref[...]
    f_n, v_n, l_n, c_n = _tc_row_probe(
        slab2_ref[1 + r, i], nrow_ref[...], qk,
        nk0, nk1, nv0, nv1, ns0, ns1, width)

    @pl.when(r == 0)
    def _init():
        f_o, v_o, l_o, c_o = _tc_row_probe(
            slab2_ref[0, i], orow_ref[...], qk,
            ok0, ok1, ov0, ov1, os0, os1, width)
        eq = (qk[:, None] == hk_ref[...][None, :]) & (hl_ref[...][None, :] != 0)
        f_hz = eq.any(-1)
        hz_i = jnp.argmax(eq, axis=-1)
        fold_ref[...] = f_o
        vold_ref[...] = v_o
        lold_ref[...] = l_o
        cold_ref[...] = c_o
        hzidx_ref[...] = jnp.where(f_hz, hz_i.astype(I32), -1)
        fnew_ref[...] = f_n
        vnew_ref[...] = v_n
        lnew_ref[...] = l_n
        cnew_ref[...] = c_n

    @pl.when(r > 0)
    def _merge():
        seen = fnew_ref[...]
        fnew_ref[...] = seen | f_n
        vnew_ref[...] = jnp.where(f_n & ~seen, v_n, vnew_ref[...])
        lnew_ref[...] = jnp.maximum(lnew_ref[...], l_n)
        cnew_ref[...] = cnew_ref[...] | c_n


def tc_probe2_tiles(old_padded, new_padded,
                    hazard_key: jax.Array, hazard_val: jax.Array,
                    hazard_live_i32: jax.Array,
                    orow_sorted: jax.Array, nrow_sorted: jax.Array,
                    qk_sorted: jax.Array, slab2: jax.Array, *,
                    interpret: bool = True):
    """Run the twochoice rebuild-epoch kernel over pre-sorted entries.

    old_padded/new_padded: (key, val, state) triples of row-padded [Bpad, W]
    tables (pad rows EMPTY; widths must match).  orow_sorted/nrow_sorted/
    qk_sorted: [E] entry old-rows / new-rows / keys sorted by OLD row, E a
    QT multiple.  slab2: [1 + nres, tiles] row-block map (row 0 old, rows
    1.. resident new blocks).

    Returns (f_old, v_old, loc_old, c_old, hz_idx, f_new, v_new, loc_new,
    c_new) per entry; locations are flat table slots (-1 = none).
    """
    e = orow_sorted.shape[0]
    (okk, ovv, oss), (nkk, nvv, nss) = old_padded, new_padded
    width = okk.shape[1]
    assert nkk.shape[1] == width, "old/new twochoice widths must match"
    slab_r = _tc_rowslab(width)
    assert e % QT == 0 and okk.shape[0] % slab_r == 0 and \
        nkk.shape[0] % slab_r == 0
    tiles = e // QT
    nres = slab2.shape[0] - 1
    assert nres >= 1
    ch = hazard_key.shape[0]

    qspec = pl.BlockSpec((QT,), lambda i, r, s: (i,))
    oblk0 = pl.BlockSpec((slab_r, width), lambda i, r, s: (s[0, i], 0))
    oblk1 = pl.BlockSpec((slab_r, width), lambda i, r, s: (s[0, i] + 1, 0))
    nblk0 = pl.BlockSpec((slab_r, width), lambda i, r, s: (s[1 + r, i], 0))
    nblk1 = pl.BlockSpec((slab_r, width), lambda i, r, s: (s[1 + r, i] + 1, 0))
    hspec = pl.BlockSpec((ch,), lambda i, r, s: (0,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(tiles, nres),
        in_specs=[qspec, qspec, qspec,
                  oblk0, oblk1, oblk0, oblk1, oblk0, oblk1,
                  nblk0, nblk1, nblk0, nblk1, nblk0, nblk1,
                  hspec, hspec, hspec],
        out_specs=[qspec] * 9,
    )
    out_shape = [
        jax.ShapeDtypeStruct((e,), jnp.bool_),    # f_old
        jax.ShapeDtypeStruct((e,), I32),          # v_old
        jax.ShapeDtypeStruct((e,), I32),          # loc_old (flat slot)
        jax.ShapeDtypeStruct((e,), jnp.bool_),    # c_old
        jax.ShapeDtypeStruct((e,), I32),          # hazard index (-1 = none)
        jax.ShapeDtypeStruct((e,), jnp.bool_),    # f_new
        jax.ShapeDtypeStruct((e,), I32),          # v_new
        jax.ShapeDtypeStruct((e,), I32),          # loc_new (flat slot)
        jax.ShapeDtypeStruct((e,), jnp.bool_),    # c_new
    ]
    kernel = functools.partial(_tc_probe2_kernel, width=width)
    return pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(
        slab2, orow_sorted, nrow_sorted, qk_sorted,
        okk, okk, ovv, ovv, oss, oss,
        nkk, nkk, nvv, nvv, nss, nss,
        hazard_key, hazard_val, hazard_live_i32)


# ---------------------------------------------------------------------------
# chain: segment-window probes over the arena-sorted node layout
# ---------------------------------------------------------------------------
#
# The chain arena, once compacted by ops.chain_compact_fused, is bucket-
# sorted: bucket b's nodes occupy the contiguous segment
# [bstart[b], bstart[b] + blen[b]).  A chain probe is then the SAME slab-
# window reduction as a linear probe — h0 = bstart[b] — except termination is
# the segment length (the packed arena has no EMPTY sentinels between
# segments), so the kernels take a per-query ``qlen`` bound instead of
# stopping at EMPTY.  Cross-segment reads cannot false-match: a key's bucket
# is a function of the key, so a LIVE node with a matching key in another
# bucket's segment is impossible.  Nodes inserted after the compaction (the
# dirty tail) are resolved OUTSIDE the kernel by a dense window compare in
# ops.py — the hazard-buffer treatment — and a tail grown past DIRTY_CAP
# escapes to the pointer-chasing jnp reference via the gated fallback.

def _chain_window_probe(base_blk, h0, qlen, qk, k0, k1, v0, v1, s0, s1,
                        max_probes: int):
    """Segment-bounded probe loop over one 2-block VMEM window.

    Like ``_window_probe`` but the probe run is [h0, h0 + qlen) — absence is
    proven by exhausting the segment, not by an EMPTY sentinel.  ``complete``
    additionally requires ``qlen <= max_probes`` (a segment longer than the
    probe bound cannot prove absence).  Returns (found, val, loc, complete);
    ``loc`` is the padded-arena node coordinate of the LIVE hit, -1 if none.
    """
    base = base_blk * SLAB
    off = h0 - base
    keys = jnp.concatenate([k0[...], k1[...]])
    vals = jnp.concatenate([v0[...], v1[...]])
    stat = jnp.concatenate([s0[...], s1[...]])

    complete = ((off >= 0) & (off + max_probes <= 2 * SLAB)
                & (qlen <= max_probes))
    safe_off = jnp.clip(off, 0, 2 * SLAB - max_probes)

    def body(p, carry):
        found, val, loc = carry
        idx = safe_off + p
        k = jnp.take(keys, idx, axis=0)
        v = jnp.take(vals, idx, axis=0)
        s = jnp.take(stat, idx, axis=0)
        hit = (p < qlen) & ~found & (s == LIVE) & (k == qk)
        val = jnp.where(hit, v, val)
        loc = jnp.where(hit, base + idx, loc)
        return found | hit, val, loc

    q = h0.shape[0]
    init = (jnp.zeros((q,), bool), jnp.zeros((q,), I32),
            jnp.full((q,), -1, I32))
    found, val, loc = jax.lax.fori_loop(0, max_probes, body, init)
    return (found & complete, jnp.where(complete, val, 0),
            jnp.where(complete, loc, -1), complete)


def _chain_probe_kernel(slab_ref,            # scalar-prefetch: [tiles]
                        h0_ref, qlen_ref, qk_ref,        # [QT]
                        tk0, tk1, tv0, tv1, ts0, ts1,    # [SLAB] arena blocks
                        found_ref, val_ref, loc_ref, complete_ref,
                        *, max_probes: int):
    """Single-arena chain lookup over the sorted segments (steady state).
    Emits per-query node LOCATIONS alongside found/val so the fused chain
    delete tombstones with one scatter — same contract as ``_probe_kernel``.
    """
    i = pl.program_id(0)
    found, val, loc, complete = _chain_window_probe(
        slab_ref[i], h0_ref[...], qlen_ref[...], qk_ref[...],
        tk0, tk1, tv0, tv1, ts0, ts1, max_probes)
    found_ref[...] = found
    val_ref[...] = val
    loc_ref[...] = loc
    complete_ref[...] = complete


def _chain_probe2_kernel(slab2_ref,          # scalar-prefetch: [1+nres, tiles]
                         h0o_ref, qlo_ref, h0n_ref, qln_ref, qk_ref,  # [QT]
                         ok0, ok1, ov0, ov1, os0, os1,   # old arena blocks
                         nk0, nk1, nv0, nv1, ns0, ns1,   # new resident blocks
                         hk_ref, hv_ref, hl_ref,         # [CH] hazard buffer
                         fold_ref, vold_ref, lold_ref, cold_ref, hzidx_ref,
                         fnew_ref, vnew_ref, lnew_ref, cnew_ref,
                         *, max_probes: int):
    """Fused chain rebuild-epoch probe: the OLD segment probe, the dense
    hazard compare, and the NEW segment probe land in one pass on the same
    ``(tiles, nres)`` reduction grid as ``_probe2_kernel`` (row 0 of
    ``slab2`` anchors the old-arena slabs the shared sort produced; rows 1..
    are the tile's resident new-arena blocks, and iterations ``r > 0`` merge
    further new windows into the revisited outputs).  Emits per-query
    COMPONENTS — ops.py merges the dirty-tail windows of both arenas and
    applies the Lemma-4.1 ordering, so the same outputs serve both the
    ordered lookup and the ordered delete."""
    i = pl.program_id(0)
    r = pl.program_id(1)
    qk = qk_ref[...]
    f_n, v_n, l_n, c_n = _chain_window_probe(
        slab2_ref[1 + r, i], h0n_ref[...], qln_ref[...], qk,
        nk0, nk1, nv0, nv1, ns0, ns1, max_probes)

    @pl.when(r == 0)
    def _init():
        f_o, v_o, l_o, c_o = _chain_window_probe(
            slab2_ref[0, i], h0o_ref[...], qlo_ref[...], qk,
            ok0, ok1, ov0, ov1, os0, os1, max_probes)
        eq = (qk[:, None] == hk_ref[...][None, :]) & (hl_ref[...][None, :] != 0)
        f_hz = eq.any(-1)
        hz_i = jnp.argmax(eq, axis=-1)
        fold_ref[...] = f_o
        vold_ref[...] = v_o
        lold_ref[...] = l_o
        cold_ref[...] = c_o
        hzidx_ref[...] = jnp.where(f_hz, hz_i.astype(I32), -1)
        fnew_ref[...] = f_n
        vnew_ref[...] = v_n
        lnew_ref[...] = l_n
        cnew_ref[...] = c_n

    @pl.when(r > 0)
    def _merge():
        seen = fnew_ref[...]
        fnew_ref[...] = seen | f_n
        vnew_ref[...] = jnp.where(f_n & ~seen, v_n, vnew_ref[...])
        lnew_ref[...] = jnp.maximum(lnew_ref[...], l_n)
        cnew_ref[...] = cnew_ref[...] | c_n


def chain_probe_tiles(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                      h0_sorted: jax.Array, qlen_sorted: jax.Array,
                      qk_sorted: jax.Array, slab_base: jax.Array, *,
                      max_probes: int, interpret: bool = True):
    """Run the chain lookup kernel over pre-sorted, pre-tiled queries.

    tkey/tval/tstate: padded arena arrays (``ops._pad_table``-style).
    h0_sorted: per-query segment starts (``bstart[bucket]``), sorted
    ascending; qlen_sorted: matching segment lengths.  Returns
    (found[Q], val[Q], loc[Q], complete[Q]); ``loc`` is the padded-arena
    node coordinate (-1 if absent).
    """
    q = h0_sorted.shape[0]
    assert q % QT == 0 and tkey.shape[0] % SLAB == 0
    tiles = q // QT

    qspec = pl.BlockSpec((QT,), lambda i, s: (i,))
    blk0 = pl.BlockSpec((SLAB,), lambda i, s: (s[i],))
    blk1 = pl.BlockSpec((SLAB,), lambda i, s: (s[i] + 1,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(tiles,),
        in_specs=[qspec, qspec, qspec,
                  blk0, blk1, blk0, blk1, blk0, blk1],
        out_specs=[qspec] * 4,
    )
    out_shape = [
        jax.ShapeDtypeStruct((q,), jnp.bool_),
        jax.ShapeDtypeStruct((q,), I32),
        jax.ShapeDtypeStruct((q,), I32),
        jax.ShapeDtypeStruct((q,), jnp.bool_),
    ]
    kernel = functools.partial(_chain_probe_kernel, max_probes=max_probes)
    return pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(
        slab_base, h0_sorted, qlen_sorted, qk_sorted,
        tkey, tkey, tval, tval, tstate, tstate)


def chain_probe2_tiles(old_padded, new_padded,
                       hazard_key: jax.Array, hazard_val: jax.Array,
                       hazard_live_i32: jax.Array,
                       h0o_sorted: jax.Array, qlo_sorted: jax.Array,
                       h0n_sorted: jax.Array, qln_sorted: jax.Array,
                       qk_sorted: jax.Array, slab2: jax.Array, *,
                       max_probes: int, interpret: bool = True):
    """Run the chain rebuild-epoch kernel over pre-sorted queries.

    old_padded/new_padded: (key, val, state) arena triples padded
    independently.  h0o/qlo and h0n/qln: per-query segment (start, len) for
    the old and new arenas, sorted by the OLD start.  slab2:
    [1 + nres, tiles] block map (row 0 old, rows 1.. resident new blocks).

    Returns (f_old, v_old, loc_old, c_old, hz_idx, f_new, v_new, loc_new,
    c_new) per query; locations are padded-arena coordinates (-1 = none).
    """
    q = qk_sorted.shape[0]
    (okk, ovv, oss), (nkk, nvv, nss) = old_padded, new_padded
    assert q % QT == 0 and okk.shape[0] % SLAB == 0 and \
        nkk.shape[0] % SLAB == 0
    tiles = q // QT
    nres = slab2.shape[0] - 1
    assert nres >= 1
    ch = hazard_key.shape[0]

    qspec = pl.BlockSpec((QT,), lambda i, r, s: (i,))
    oblk0 = pl.BlockSpec((SLAB,), lambda i, r, s: (s[0, i],))
    oblk1 = pl.BlockSpec((SLAB,), lambda i, r, s: (s[0, i] + 1,))
    nblk0 = pl.BlockSpec((SLAB,), lambda i, r, s: (s[1 + r, i],))
    nblk1 = pl.BlockSpec((SLAB,), lambda i, r, s: (s[1 + r, i] + 1,))
    hspec = pl.BlockSpec((ch,), lambda i, r, s: (0,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(tiles, nres),
        in_specs=[qspec, qspec, qspec, qspec, qspec,
                  oblk0, oblk1, oblk0, oblk1, oblk0, oblk1,
                  nblk0, nblk1, nblk0, nblk1, nblk0, nblk1,
                  hspec, hspec, hspec],
        out_specs=[qspec] * 9,
    )
    out_shape = [
        jax.ShapeDtypeStruct((q,), jnp.bool_),    # f_old
        jax.ShapeDtypeStruct((q,), I32),          # v_old
        jax.ShapeDtypeStruct((q,), I32),          # loc_old (padded coords)
        jax.ShapeDtypeStruct((q,), jnp.bool_),    # c_old
        jax.ShapeDtypeStruct((q,), I32),          # hazard index (-1 = none)
        jax.ShapeDtypeStruct((q,), jnp.bool_),    # f_new
        jax.ShapeDtypeStruct((q,), I32),          # v_new
        jax.ShapeDtypeStruct((q,), I32),          # loc_new (padded coords)
        jax.ShapeDtypeStruct((q,), jnp.bool_),    # c_new
    ]
    kernel = functools.partial(_chain_probe2_kernel, max_probes=max_probes)
    return pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(
        slab2, h0o_sorted, qlo_sorted, h0n_sorted, qln_sorted, qk_sorted,
        okk, okk, ovv, ovv, oss, oss,
        nkk, nkk, nvv, nvv, nss, nss,
        hazard_key, hazard_val, hazard_live_i32)
