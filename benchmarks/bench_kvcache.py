"""DHash inside serving: decode latency THROUGH a live page-table rehash.

The paper's non-blocking guarantee, measured where it matters: per-step
decode latency of the paged serving engine while the page table rebuilds.
A blocking rehash would spike p99; DHash's chunked rebuild holds the step
time flat (bounded O(chunk) extra per step).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import dhash
from repro.models import transformer
from repro.serving import kvcache
from repro.serving.engine import ServeConfig, ServingEngine


def run(*, quiet=False):
    cfg = ArchConfig("bench-serve", "dense", n_layers=2, d_model=128,
                     n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512,
                     dtype="float32", attn_chunk=32, loss_chunk=32)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(params, cfg, ServeConfig(
        max_seqs=8, page_size=8, n_pages=512, max_blocks=16,
        max_new_tokens=160, rehash_load_factor=2.0))  # manual rehash below
    rng = np.random.default_rng(0)
    for _ in range(8):
        eng.submit(list(rng.integers(1, 500, size=8)))
    eng._admit()

    def one_step():
        t0 = time.perf_counter()
        eng._run_slots(sample=True)
        return time.perf_counter() - t0

    for _ in range(5):
        one_step()                               # warmup/compile
    baseline = [one_step() for _ in range(30)]

    # kick a rehash; keep decoding through it
    eng.kv = kvcache.replace(eng.kv, table=dhash.rebuild_start(
        eng.kv.table, seed=99))
    during = []
    while not bool(jax.device_get(dhash.rebuild_done(eng.kv.table))):
        during.append(one_step())
    eng.kv = kvcache.replace(eng.kv, table=dhash.rebuild_finish(eng.kv.table))
    after = [one_step() for _ in range(30)]

    def p(xs, q):
        return float(np.percentile(np.asarray(xs) * 1e3, q))
    if not quiet:
        print(f"decode step p50/p95 (ms): baseline {p(baseline,50):.1f}/{p(baseline,95):.1f}  "
              f"during rehash {p(during,50):.1f}/{p(during,95):.1f}  "
              f"after {p(after,50):.1f}/{p(after,95):.1f}  "
              f"({len(during)} rehash steps)")
        print(f"[summary] rehash latency overhead p50: "
              f"{p(during,50)/p(baseline,50):.2f}x (non-blocking; a "
              f"stop-the-world rehash would be one step of "
              f"~{sum(during)*1e3:.0f} ms)")
    return {"baseline_p50": p(baseline, 50), "during_p50": p(during, 50),
            "after_p50": p(after, 50), "rehash_steps": len(during)}


if __name__ == "__main__":
    run()
