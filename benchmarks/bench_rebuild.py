"""Paper Figure 3: rebuild time vs number of nodes.

Claims reproduced:
  * HT-Split resize is cheapest (bucket pointers only, no node movement);
  * HT-Xu rebuilds in one traversal (two-pointer-set advantage);
  * DHash and HT-RHT distribute every node -> time linear in N;
  * DHash beats HT-RHT because RHT re-walks each chain to its TAIL per node
    distributed (O(len^2) per bucket) while DHash distributes scan-order
    chunks;
  * the op mix running concurrently does not materially change rebuild time
    (predictability claim, §6.3).
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

from benchmarks.common import ALGOS, UNIVERSE, count_primitives, timeit

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def run(ns=(2_000, 8_000, 32_000), alpha=20, *, quiet=False):
    rows = []
    for n in ns:
        nbuckets = max(n // alpha, 16)
        rng = np.random.default_rng(0)
        present = rng.choice(UNIVERSE, size=n, replace=False).astype(np.int32)
        for name, cls in ALGOS.items():
            drv = cls(nbuckets, n, seed=1)
            drv.populate(present)
            drv.full_rebuild()            # warmup (compile)
            dt = min(drv.full_rebuild() for _ in range(2))
            rows.append((drv.name, n, dt))
            if not quiet:
                print(f"{drv.name:14s} N={n:<8d} rebuild {dt*1e3:9.1f} ms")
    # linearity check for DHash (paper: predictable, linear in N)
    ds = [(n, dt) for nm, n, dt in rows if nm.startswith("DHash")]
    if len(ds) >= 2:
        r = (ds[-1][1] / ds[0][1]) / (ds[-1][0] / ds[0][0])
        print(f"[summary] DHash rebuild-time linearity ratio "
              f"(time-growth / N-growth): {r:.2f} (1.0 = perfectly linear)")
    return rows


def run_fused_probe(batch=4096, n_items=3_000, *, iters=5, quiet=False,
                    out_path=None):
    """fused=on|off rebuild-epoch lookup comparison for the linear backend.

    The hot-path claim under test: with a rebuild in flight, the FUSED path
    executes ONE argsort + ONE pallas_call per batch where the unfused path
    pays one sort + one pallas_call per table plus a separate hazard pass.
    In interpret mode (no real TPU) the pass-count reduction is the
    acceptance metric (wall clock of interpreted Pallas is not meaningful);
    both are recorded in BENCH_fused_probe.json for the perf trajectory.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import buckets, dhash, hashing
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    d = dhash.make("linear", capacity=n_items, chunk=256, seed=1)
    present = rng.choice(UNIVERSE, size=n_items, replace=False).astype(np.int32)
    keys = jnp.asarray(present)
    ins = jax.jit(dhash.insert)
    for i in range(0, n_items, 4096):
        d, _ = ins(d, keys[i:i + 4096], keys[i:i + 4096])
    # put the table mid-rebuild with a populated hazard window
    d = dhash.rebuild_start(d, seed=9)
    d = jax.jit(dhash.rebuild_chunk)(d)
    d = jax.jit(dhash.rebuild_extract)(d)

    qs = jnp.asarray(np.concatenate([
        rng.choice(present, batch // 2),
        rng.integers(1, UNIVERSE, batch - batch // 2)]).astype(np.int32))
    h0o = hashing.bucket_of(d.old.hfn, qs, d.old.capacity)
    h0n = hashing.bucket_of(d.new.hfn, qs, d.new.capacity)
    args = ((d.old.key, d.old.val, d.old.state),
            (d.new.key, d.new.val, d.new.state),
            d.hazard_key, d.hazard_val, d.hazard_live, h0o, h0n, qs)

    mp = d.old.max_probes
    fused_fn = lambda *a: ops.ordered_lookup_fused(*a, max_probes=mp)   # noqa: E731
    unfused_fn = lambda *a: ops.ordered_lookup(*a, max_probes=mp)       # noqa: E731
    passes = {}
    for name, fn in (("fused", fused_fn), ("unfused", unfused_fn)):
        counts = count_primitives(jax.make_jaxpr(fn)(*args),
                                  ("sort", "pallas_call"))
        dt = timeit(fn, *args, warmup=1, iters=iters)
        passes[name] = dict(counts, wall_us=dt * 1e6)
        if not quiet:
            print(f"fused_probe/{name:8s} Q={batch} sorts={counts['sort']} "
                  f"pallas_calls={counts['pallas_call']} {dt*1e6:9.0f} us")
    # exactness cross-check while we're here
    f_f, v_f = fused_fn(*args)
    f_u, v_u = unfused_fn(*args)
    assert bool((f_f == f_u).all()) and bool((v_f == v_u).all())

    ratio = ((passes["unfused"]["sort"] + passes["unfused"]["pallas_call"])
             / (passes["fused"]["sort"] + passes["fused"]["pallas_call"]))
    result = {"batch": batch, "n_items": n_items, "interpret": True,
              "fused": passes["fused"], "unfused": passes["unfused"],
              "pass_ratio": ratio}
    assert passes["fused"]["sort"] == 1 and passes["fused"]["pallas_call"] == 1
    assert ratio >= 1.5, f"pass-count reduction regressed: {ratio:.2f}x"
    out = pathlib.Path(out_path) if out_path else _REPO_ROOT / "BENCH_fused_probe.json"
    out.write_text(json.dumps(result, indent=2) + "\n")
    if not quiet:
        print(f"[summary] fused pass-count reduction {ratio:.2f}x "
              f"(>=1.5x required) -> {out}")
    return result


def run_growth_escape(batch=4096, n_items=3_000, growths=(1, 4, 16), *,
                      iters=5, quiet=False, out_path=None):
    """Fallback-escape rate of the fused rebuild-epoch probe vs new-table
    GROWTH factor — the two-level tile-map acceptance.

    The fused probe's one sort is keyed on the old table's start slots, so a
    grown new table scatters each query tile's new-table windows across many
    slabs.  Before the tile map the per-tile slab was anchored at the tile's
    min ``h0_new`` and growth-heavy rebuilds sent a MAJORITY of rebuild-epoch
    queries to the gated jnp fallback; with the map (per-tile resident
    blocks, ``ops.NRES_CAP`` of them) the acceptance bar is <5% escapes at
    16x growth.  The structural 1-sort/1-pallas_call budget is asserted at
    every growth factor; escape rates and wall clock land in
    BENCH_growth_escape.json and the CI perf gate fails if a rate creeps
    back up (``check_regression`` treats ``escape_rate`` as
    lower-is-better with an absolute band).
    """
    import jax
    import jax.numpy as jnp

    from repro.core import buckets, dhash, hashing
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    d = dhash.make("linear", capacity=n_items, chunk=256, seed=1)
    c_old = d.old.capacity
    present = rng.choice(UNIVERSE, size=n_items, replace=False).astype(np.int32)
    keys = jnp.asarray(present)
    ins = jax.jit(dhash.insert)
    for i in range(0, n_items, 4096):
        d, _ = ins(d, keys[i:i + 4096], keys[i:i + 4096])
    # a populated hazard window, shared across growth factors
    d = dhash.rebuild_start(d, seed=9)
    d = jax.jit(dhash.rebuild_extract)(d)

    qs = jnp.asarray(np.concatenate([
        rng.choice(present, batch // 2),
        rng.integers(1, UNIVERSE, batch - batch // 2)]).astype(np.int32))
    h0o = hashing.bucket_of(d.old.hfn, qs, c_old)
    mp = d.old.max_probes
    old_t = (d.old.key, d.old.val, d.old.state)

    result = {"batch": batch, "n_items": n_items, "c_old": c_old,
              "interpret": True}
    for g in growths:
        c_new = c_old * g
        tnew = buckets.linear_make(c_new, hashing.fresh("mix32", 100 + g),
                                   max_probes=mp)
        landed = jnp.asarray(rng.choice(
            np.arange(UNIVERSE, UNIVERSE + 10 * n_items), n_items // 4,
            replace=False).astype(np.int32))
        tnew, _ = jax.jit(buckets.linear_insert)(
            tnew, landed, landed * 3, jnp.ones(landed.shape, bool))
        h0n = hashing.bucket_of(tnew.hfn, qs, c_new)
        args = (old_t, (tnew.key, tnew.val, tnew.state), d.hazard_key,
                d.hazard_val, d.hazard_live, h0o, h0n, qs)
        rate = float(ops.rebuild_escape_rate(*args, max_probes=mp))
        fn = lambda *a: ops.ordered_lookup_fused(*a, max_probes=mp)  # noqa: E731
        counts = count_primitives(jax.make_jaxpr(fn)(*args),
                                  ("sort", "pallas_call"))
        assert counts == {"sort": 1, "pallas_call": 1}, counts
        dt = timeit(fn, *args, warmup=1, iters=iters)
        result[f"growth_{g}x"] = dict(escape_rate=rate, **counts,
                                      wall_us=dt * 1e6)
        if not quiet:
            print(f"growth_escape/{g:2d}x Q={batch} C_new={c_new:<8d} "
                  f"escape={rate:7.4f} {dt*1e6:9.0f} us")

    top = max(growths)
    assert result[f"growth_{top}x"]["escape_rate"] < 0.05, \
        f"escape rate at {top}x growth regressed: " \
        f"{result[f'growth_{top}x']['escape_rate']:.3f}"
    out = (pathlib.Path(out_path) if out_path
           else _REPO_ROOT / "BENCH_growth_escape.json")
    out.write_text(json.dumps(result, indent=2) + "\n")
    if not quiet:
        print(f"[summary] escape at {top}x growth "
              f"{result[f'growth_{top}x']['escape_rate']:.4f} "
              f"(<0.05 required) -> {out}")
    return result


def _count_passes(closed_jaxpr):
    """Serialized table-pass proxy for the write-path comparison.

    A "pass" is one serialized table-touching round: sorts, pallas_calls,
    and top-level gathers/scatters count 1 each; a scan whose body touches
    the table (the jnp probe/claim loops — ``fori_loop`` lowers to scan)
    counts its static ``length``, because each round is a *dependent* HBM
    gather that must land before the next slot can be probed.  A kernel's
    internal probe rounds run on a VMEM-resident slab inside its single
    pallas pass, and ``lax.cond`` branches are runtime-gated fallbacks the
    steady state never executes — neither is descended into.  This is the
    roofline distinction (see kernels/probe.py) the fused write path exists
    to exploit.
    """
    TABLE_OPS = ("sort", "gather", "scatter")

    def has_table_ops(jaxpr):
        for eq in jaxpr.eqns:
            if any(s in eq.primitive.name for s in TABLE_OPS):
                return True
            for p in eq.params.values():
                if hasattr(p, "jaxpr") and has_table_ops(
                        p.jaxpr if hasattr(p.jaxpr, "eqns") else p.jaxpr.jaxpr):
                    return True
        return False

    def rec(jaxpr):
        total = 0
        for eq in jaxpr.eqns:
            name = eq.primitive.name
            if name == "pallas_call":
                total += 1
                continue
            if name == "cond":
                continue
            if name == "scan":
                body = eq.params["jaxpr"].jaxpr
                if has_table_ops(body):
                    total += int(eq.params.get("length", 1))
                continue
            if name == "while":
                body = eq.params["body_jaxpr"].jaxpr
                total += 1 + rec(body)
                continue
            if any(s in name for s in TABLE_OPS):
                total += 1
            for p in eq.params.values():
                if hasattr(p, "jaxpr"):
                    total += rec(p.jaxpr if hasattr(p.jaxpr, "eqns")
                                 else p.jaxpr.jaxpr)
        return total

    return rec(closed_jaxpr.jaxpr)


def run_fused_writes(batch=4096, n_items=3_000, *, iters=5, quiet=False,
                     out_path=None):
    """fused=on vs jnp write-path comparison on the delete+rebuild mixed
    workload (PR 2 acceptance).

    One mid-rebuild step of the mixed workload = ordered lookup + insert
    (new table) + ordered DELETE + rebuild chunk EXTRACT + hazard LANDING.
    The fused arm runs the Pallas write kernels (``ordered_delete_fused``,
    ``extract_chunk_fused``, ``probe_insert`` for the landing); the jnp arm
    is the reference-oracle composition the unfused path executes.  The
    acceptance metric is the serialized table-pass reduction
    (``_count_passes``); interpreted-kernel wall clock is recorded for the
    trajectory but not asserted (interpret mode is not representative).
    Results land in BENCH_fused_writes.json; exactness of the fused arm is
    cross-checked against the jnp arm in-run.

    Baseline note: the two-level tile map costs the fused arm 2 extra
    proxy passes (43 -> 45): the rebuild-epoch lookup AND delete each
    gained the level-1 histogram scatter of ``ops._resident_blockmap``.
    That is the deliberate price of keeping grown new tables fused (see
    BENCH_growth_escape.json) — the committed baseline was refreshed with
    the same change, and the gate pins the new count exactly.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import buckets, dhash, hashing
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    d = dhash.make("linear", capacity=n_items, chunk=256, seed=1, fused=True)
    present = rng.choice(UNIVERSE, size=n_items, replace=False).astype(np.int32)
    keys = jnp.asarray(present)
    ins = jax.jit(dhash.insert)
    for i in range(0, n_items, 4096):
        d, _ = ins(d, keys[i:i + 4096], keys[i:i + 4096])
    d = dhash.rebuild_start(d, seed=9)
    d = jax.jit(dhash.rebuild_chunk)(d)
    d = jax.jit(dhash.rebuild_extract)(d)   # populated hazard window

    mp = d.old.max_probes
    ch = d.chunk
    c_old, c_new = d.old.capacity, d.new.capacity
    qs = jnp.asarray(np.concatenate([
        rng.choice(present, batch // 2),
        rng.integers(1, UNIVERSE, batch - batch // 2)]).astype(np.int32))
    dk = jnp.asarray(np.concatenate([
        rng.choice(present, batch // 8),
        rng.integers(1, UNIVERSE, batch // 8)]).astype(np.int32))
    ik = jnp.asarray(rng.choice(
        np.arange(UNIVERSE, UNIVERSE + 10 * batch), batch // 4,
        replace=False).astype(np.int32))
    iv = ik * 3
    win_d = buckets.batch_winners(dk, jnp.ones(dk.shape, bool))
    win_i = buckets.batch_winners(ik, jnp.ones(ik.shape, bool))
    h0o_q = hashing.bucket_of(d.old.hfn, qs, c_old)
    h0n_q = hashing.bucket_of(d.new.hfn, qs, c_new)
    h0o_d = hashing.bucket_of(d.old.hfn, dk, c_old)
    h0n_d = hashing.bucket_of(d.new.hfn, dk, c_new)
    h0n_i = hashing.bucket_of(d.new.hfn, ik, c_new)
    hfn_new = d.new.hfn

    def fused_step(old_t, new_t, hk, hv, hl, cursor):
        f, v = ops.ordered_lookup_fused(old_t, new_t, hk, hv, hl,
                                        h0o_q, h0n_q, qs, max_probes=mp)
        os_, ns_, hl, ok_d = ops.ordered_delete_fused(
            old_t, new_t, hk, hv, hl, h0o_d, h0n_d, dk, win_d, max_probes=mp)
        old_t = (old_t[0], old_t[1], os_)
        new_t = (new_t[0], new_t[1], ns_)
        nk, nv, ns2, ok_i = ops.probe_insert(*new_t, h0n_i, ik, iv, win_i,
                                             max_probes=mp)
        new_t = (nk, nv, ns2)
        os2, hk2, hv2, hl2, cur2 = ops.extract_chunk_fused(
            old_t[0], old_t[1], old_t[2], cursor, chunk=ch)
        old_t = (old_t[0], old_t[1], os2)
        h0_h = hashing.bucket_of(hfn_new, hk2, c_new)
        lk2, lv2, ls2, _ = ops.probe_insert(*new_t, h0_h, hk2, hv2, hl2,
                                            max_probes=mp)
        return f, v, ok_d, ok_i, old_t[2], (lk2, lv2, ls2), cur2

    def jnp_step(old_t, new_t, hk, hv, hl, cursor):
        f, v = ref.ordered_lookup_ref(old_t, new_t, hk, hv, hl,
                                      h0o_q, h0n_q, qs, mp)
        os_, ok_o = ref.probe_delete_ref(old_t[0], old_t[1], old_t[2],
                                         h0o_d, dk, win_d, mp)
        pend = win_d & ~ok_o
        eq = (dk[:, None] == hk[None, :]) & hl[None, :]
        hz_hit = eq.any(-1) & pend
        kill = jnp.zeros_like(hl).at[
            jnp.where(hz_hit, jnp.argmax(eq, axis=-1), ch)].set(
            True, mode="drop")
        hl = hl & ~kill
        ns_, ok_n = ref.probe_delete_ref(new_t[0], new_t[1], new_t[2],
                                         h0n_d, dk, pend & ~hz_hit, mp)
        ok_d = ok_o | hz_hit | ok_n
        nk, nv, ns2, ok_i = ref.probe_insert_ref(
            new_t[0], new_t[1], ns_, h0n_i, ik, iv, win_i, mp)
        # extract (the jnp gather scan of linear_extract_chunk)
        pos = cursor + jnp.arange(ch, dtype=jnp.int32)
        valid = pos < c_old
        cpos = jnp.where(valid, pos, 0)
        live = valid & (os_[cpos] == 1)
        hk2 = jnp.where(live, old_t[0][cpos], 0)
        hv2 = jnp.where(live, old_t[1][cpos], 0)
        os2 = os_.at[jnp.where(live, cpos, c_old)].set(3, mode="drop")
        cur2 = jnp.minimum(cursor + ch, c_old)
        h0_h = hashing.bucket_of(hfn_new, hk2, c_new)
        lk2, lv2, ls2, _ = ref.probe_insert_ref(nk, nv, ns2, h0_h, hk2, hv2,
                                                live, mp)
        return f, v, ok_d, ok_i, os2, (lk2, lv2, ls2), cur2

    old_t = (d.old.key, d.old.val, d.old.state)
    new_t = (d.new.key, d.new.val, d.new.state)
    args = (old_t, new_t, d.hazard_key, d.hazard_val, d.hazard_live, d.cursor)

    passes, walls = {}, {}
    for name, fn in (("fused", fused_step), ("jnp", jnp_step)):
        passes[name] = _count_passes(jax.make_jaxpr(fn)(*args))
        walls[name] = timeit(jax.jit(fn), *args, warmup=1, iters=iters) * 1e6
        if not quiet:
            print(f"fused_writes/{name:5s} Q={batch} passes={passes[name]:4d} "
                  f"{walls[name]:9.0f} us")

    # exactness cross-check: both arms agree on every observable
    out_f = jax.jit(fused_step)(*args)
    out_j = jax.jit(jnp_step)(*args)
    assert bool((out_f[0] == out_j[0]).all())            # lookup found
    assert bool((out_f[1] == out_j[1]).all())            # lookup vals
    assert bool((out_f[2] == out_j[2]).all())            # delete ok
    assert bool((out_f[3] == out_j[3]).all())            # insert ok
    assert bool((out_f[4] == out_j[4]).all())            # old states
    assert int((out_f[5][2] == 1).sum()) == int((out_j[5][2] == 1).sum())
    assert int(out_f[6]) == int(out_j[6])                # cursor

    ratio = passes["jnp"] / passes["fused"]
    result = {"batch": batch, "n_items": n_items, "chunk": ch,
              "interpret": True,
              "workload": "lookup+insert+delete+extract+land (mid-rebuild)",
              "fused": {"passes": passes["fused"], "wall_us": walls["fused"]},
              "jnp": {"passes": passes["jnp"], "wall_us": walls["jnp"]},
              "pass_ratio": ratio}
    assert ratio >= 1.5, f"write-path pass reduction regressed: {ratio:.2f}x"
    out = (pathlib.Path(out_path) if out_path
           else _REPO_ROOT / "BENCH_fused_writes.json")
    out.write_text(json.dumps(result, indent=2) + "\n")
    if not quiet:
        print(f"[summary] fused write-path pass reduction {ratio:.2f}x "
              f"(>=1.5x required) -> {out}")
    return result


def run_chain_fused(batch=4096, n_items=3_000, *, iters=5, quiet=False,
                    out_path=None):
    """Arena-sorted chain backend, fused vs the pointer-chasing reference,
    on the mid-rebuild mixed workload (the PR 4 tentpole acceptance: the
    LAST backend onto the fused path).

    One mid-rebuild step = ordered lookup + ordered DELETE + insert (new
    table) + rebuild chunk EXTRACT + hazard LANDING.  The fused arm runs
    the chain kernels (``chain_ordered_lookup`` / ``chain_ordered_delete``
    / ``chain_insert_fused`` / ``extract_chunk_fused``) over the
    bucket-sorted arena; the jnp arm is the reference-oracle composition
    the unfused path executes (``ref.chain_*_ref`` — each pointer hop is a
    dependent arena gather, which is exactly what ``_count_passes`` charges
    for).  The acceptance metric is the serialized table-pass reduction
    (>= 1.5x gated); the per-op 1-sort/1-pallas_call budget is asserted as
    exact structural counts over the whole step (4 sorts + 5 pallas_calls:
    extract needs no sort).  Results land in BENCH_chain_fused.json;
    exactness of the fused arm is cross-checked against the jnp arm in-run.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import buckets, dhash, hashing
    from repro.core.struct_utils import replace
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    d = dhash.make("chain", capacity=int(n_items * 1.5), chunk=256, seed=1,
                   fused=True)
    present = rng.choice(UNIVERSE, size=n_items, replace=False).astype(np.int32)
    keys = jnp.asarray(present)
    ins = jax.jit(dhash.insert)
    for i in range(0, n_items, 4096):
        d, _ = ins(d, keys[i:i + 4096], keys[i:i + 4096])
    d = dhash.rebuild_start(d, seed=9)   # compacts the old arena
    d = jax.jit(dhash.rebuild_chunk)(d)
    d = jax.jit(dhash.rebuild_extract)(d)   # populated hazard window

    mc = d.old.max_chain
    ch = d.chunk
    nb_new = d.new.nbuckets
    arena_old = d.old.arena
    hfn_new = d.new.hfn
    qs = jnp.asarray(np.concatenate([
        rng.choice(present, batch // 2),
        rng.integers(1, UNIVERSE, batch - batch // 2)]).astype(np.int32))
    dk = jnp.asarray(np.concatenate([
        rng.choice(present, batch // 8),
        rng.integers(1, UNIVERSE, batch // 8)]).astype(np.int32))
    ik = jnp.asarray(rng.choice(
        np.arange(UNIVERSE, UNIVERSE + 10 * batch), batch // 4,
        replace=False).astype(np.int32))
    iv = ik * 3
    win_d = buckets.batch_winners(dk, jnp.ones(dk.shape, bool))
    win_i = buckets.batch_winners(ik, jnp.ones(ik.shape, bool))
    bqo_q = hashing.bucket_of(d.old.hfn, qs, d.old.nbuckets)
    bqn_q = hashing.bucket_of(hfn_new, qs, nb_new)
    bqo_d = hashing.bucket_of(d.old.hfn, dk, d.old.nbuckets)
    bqn_d = hashing.bucket_of(hfn_new, dk, nb_new)
    bqn_i = hashing.bucket_of(hfn_new, ik, nb_new)

    def fused_step(told, tnew, hk, hv, hl, cursor):
        po, pn = buckets._chain_parts(told), buckets._chain_parts(tnew)
        f, v = ops.chain_ordered_lookup(*po, *pn, hk, hv, hl, bqo_q, bqn_q,
                                        qs, max_chain=mc)
        os_, ns_, hl, ok_d = ops.chain_ordered_delete(
            *po, *pn, hk, hv, hl, bqo_d, bqn_d, dk, win_d, max_chain=mc)
        told = replace(told, astate=os_)
        tnew = replace(tnew, astate=ns_)
        pn = buckets._chain_parts(tnew)
        ak, av, ast, an, hd, ft, ok_i = ops.chain_insert_fused(
            pn[0], pn[1], pn[2], tnew.free_stack, tnew.free_top, bqn_i,
            ik, iv, win_i, max_chain=mc)
        tnew = replace(tnew, akey=ak, aval=av, astate=ast, anext=an,
                       heads=hd, free_top=ft)
        os2, hk2, hv2, hl2, cur2 = ops.extract_chunk_fused(
            told.akey, told.aval, told.astate, cursor, chunk=ch)
        told = replace(told, astate=os2)
        bq_h = hashing.bucket_of(hfn_new, hk2, nb_new)
        pn = buckets._chain_parts(tnew)
        ak, av, ast, an, hd, ft, _ = ops.chain_insert_fused(
            pn[0], pn[1], pn[2], tnew.free_stack, tnew.free_top, bq_h,
            hk2, hv2, hl2, max_chain=mc)
        tnew = replace(tnew, akey=ak, aval=av, astate=ast, anext=an,
                       heads=hd, free_top=ft)
        return f, v, ok_d, ok_i, told, tnew, cur2

    def jnp_step(told, tnew, hk, hv, hl, cursor):
        ol = (told.akey, told.aval, told.astate)
        olk = (told.anext, told.heads)
        nl = (tnew.akey, tnew.aval, tnew.astate)
        nlk = (tnew.anext, tnew.heads)
        f, v = ref.chain_ordered_lookup_ref(ol, olk, nl, nlk, hk, hv, hl,
                                            bqo_q, bqn_q, qs, mc)
        os_, ok_o = ref.chain_delete_ref(told.akey, told.aval, told.astate,
                                         told.anext, told.heads, bqo_d, dk,
                                         win_d, mc)
        pend = win_d & ~ok_o
        eq = (dk[:, None] == hk[None, :]) & hl[None, :]
        hz_hit = eq.any(-1) & pend
        kill = jnp.zeros_like(hl).at[
            jnp.where(hz_hit, jnp.argmax(eq, axis=-1), ch)].set(
            True, mode="drop")
        hl = hl & ~kill
        ns_, ok_n = ref.chain_delete_ref(tnew.akey, tnew.aval, tnew.astate,
                                         tnew.anext, tnew.heads, bqn_d, dk,
                                         pend & ~hz_hit, mc)
        ok_d = ok_o | hz_hit | ok_n
        ak, av, ast, an, hd, ft, ok_i = ref.chain_insert_ref(
            tnew.akey, tnew.aval, ns_, tnew.anext, tnew.heads,
            tnew.free_stack, tnew.free_top, bqn_i, ik, iv, win_i, mc)
        # extract (the jnp gather scan of chain_extract_chunk)
        pos = cursor + jnp.arange(ch, dtype=jnp.int32)
        valid = pos < arena_old
        cpos = jnp.where(valid, pos, 0)
        live = valid & (os_[cpos] == 1)
        hk2 = jnp.where(live, told.akey[cpos], 0)
        hv2 = jnp.where(live, told.aval[cpos], 0)
        os2 = os_.at[jnp.where(live, cpos, arena_old)].set(3, mode="drop")
        cur2 = jnp.minimum(cursor + ch, arena_old)
        told = replace(told, astate=os2)
        bq_h = hashing.bucket_of(hfn_new, hk2, nb_new)
        ak, av, ast, an, hd, ft, _ = ref.chain_insert_ref(
            ak, av, ast, an, hd, tnew.free_stack, ft, bq_h, hk2, hv2,
            live, mc)
        tnew = replace(tnew, akey=ak, aval=av, astate=ast, anext=an,
                       heads=hd, free_top=ft)
        return f, v, ok_d, ok_i, told, tnew, cur2

    args = (d.old, d.new, d.hazard_key, d.hazard_val, d.hazard_live,
            d.cursor)
    passes, walls, counts = {}, {}, {}
    for name, fn in (("fused", fused_step), ("jnp", jnp_step)):
        jx = jax.make_jaxpr(fn)(*args)
        passes[name] = _count_passes(jx)
        counts[name] = count_primitives(jx, ("sort", "pallas_call"))
        walls[name] = timeit(jax.jit(fn), *args, warmup=1, iters=iters) * 1e6
        if not quiet:
            print(f"chain_fused/{name:5s} Q={batch} passes={passes[name]:4d} "
                  f"{walls[name]:9.0f} us")

    # structural budget over the whole fused step: one sort + one
    # pallas_call per batch op (lookup, delete, insert, land), extract is
    # sort-free — 4 sorts + 5 pallas_calls, pinned exactly by the perf gate
    assert counts["fused"] == {"sort": 4, "pallas_call": 5}, counts["fused"]
    assert counts["jnp"]["pallas_call"] == 0

    # exactness cross-check: both arms agree on every per-query observable
    # and on the surviving membership (arena layouts differ only in the
    # landing order of the compacted vs position-aligned hazard chunk)
    out_f = jax.jit(fused_step)(*args)
    out_j = jax.jit(jnp_step)(*args)
    assert bool((out_f[0] == out_j[0]).all())            # lookup found
    assert bool((out_f[1] == out_j[1]).all())            # lookup vals
    assert bool((out_f[2] == out_j[2]).all())            # delete ok
    assert bool((out_f[3] == out_j[3]).all())            # insert ok
    assert bool((out_f[4].astate == out_j[4].astate).all())   # old arena
    assert int(out_f[6]) == int(out_j[6])                # cursor
    assert int(buckets.chain_count_live(out_f[5])) == \
        int(buckets.chain_count_live(out_j[5]))
    probe = jnp.concatenate([ik, qs[:512]])
    bq_p = hashing.bucket_of(hfn_new, probe, nb_new)

    def new_membership(tn):
        return ref.chain_lookup_ref(tn.akey, tn.aval, tn.astate, tn.anext,
                                    tn.heads, bq_p, probe, mc)

    f_f, v_f, _ = new_membership(out_f[5])
    f_j, v_j, _ = new_membership(out_j[5])
    np.testing.assert_array_equal(np.asarray(f_f), np.asarray(f_j))
    fm = np.asarray(f_j)
    np.testing.assert_array_equal(np.asarray(v_f)[fm], np.asarray(v_j)[fm])

    ratio = passes["jnp"] / passes["fused"]
    result = {"batch": batch, "n_items": n_items, "chunk": ch,
              "interpret": True,
              "workload": "lookup+insert+delete+extract+land (mid-rebuild, "
                          "chain backend)",
              "fused": {"passes": passes["fused"],
                        "wall_us": walls["fused"], **counts["fused"]},
              "jnp": {"passes": passes["jnp"], "wall_us": walls["jnp"]},
              "pass_ratio": ratio}
    assert ratio >= 1.5, f"chain fused pass reduction regressed: {ratio:.2f}x"
    out = (pathlib.Path(out_path) if out_path
           else _REPO_ROOT / "BENCH_chain_fused.json")
    out.write_text(json.dumps(result, indent=2) + "\n")
    if not quiet:
        print(f"[summary] chain fused pass reduction {ratio:.2f}x "
              f"(>=1.5x required) -> {out}")
    return result


def run_table_stack(n_tables=8, capacity=2048, batch=512, *, iters=5,
                    quiet=False, out_path=None):
    """``dhash.make_stack`` + vmapped ops vs a Python loop of independent
    tables (the multi-tenant serving seam; PR 5 tentpole acceptance).

    One engine step of T mid-rebuild tables = per table: lookup + insert +
    delete + one rebuild transition + the on-device epoch swap.  The
    STACKED arm runs it as ONE jitted program (``dhash.stack_*`` — every op
    is one vmapped kernel launch covering all T tables); the LOOPED arm
    dispatches T independent jitted single-table programs, which is what a
    multi-tenant server without the stack would do.

    The acceptance metric is the per-step LAUNCH-COUNT reduction: the
    looped arm issues T x (sorts + pallas_calls) of serialized launch
    traffic where the stacked arm issues the single-table count ONCE
    (vmap batches each sort/pallas_call over the [T] axis instead of
    re-issuing it), so the ratio is ~T and is gated >= 1.5.  On real
    accelerators per-launch cost is the multi-tenant throughput lever;
    interpreted-kernel wall clock is NOT representative (vmapped
    ``lax.cond`` executes both branches and interpret-mode Pallas cannot
    amortize launches), so both walls are recorded for the trajectory
    under this artifact's own wall band (``"band"`` key — the per-artifact
    calibration hook of check_regression) but the gate is structural.
    The fused per-table-step budget is asserted exactly: the vmapped
    rebuild-epoch ordered lookup stays ONE sort + ONE pallas_call for the
    whole stack.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import backend, dhash

    rng = np.random.default_rng(0)
    t, half = n_tables, capacity // 2
    st = dhash.make_stack(t, "linear", capacity, chunk=256, seed=1,
                          fused=True)
    keys = jnp.asarray(rng.choice(UNIVERSE, size=(t, capacity),
                                  replace=False).astype(np.int32)) + 1
    st, _ = jax.jit(dhash.stack_insert)(st, keys[:, :half],
                                        keys[:, :half] * 3)
    st = jax.jit(dhash.stack_autostart)(st)          # every table mid-rebuild
    singles = dhash.unstack(st)
    lk = keys[:, :batch]
    ik = keys[:, half:half + batch]
    dk = keys[:, batch:2 * batch]

    def stacked_step(d, lk, ik, iv, dk):
        f, v = dhash.stack_lookup(d, lk)
        d, ok_i = dhash.stack_insert(d, ik, iv)
        d, ok_d = dhash.stack_delete(d, dk)
        d = dhash.stack_finish_same_shape(dhash.stack_rebuild_step(d))
        return d, (f, v, ok_i, ok_d)

    def single_step(d, lk, ik, iv, dk):
        f, v = dhash.lookup(d, lk)
        d, ok_i = dhash.insert(d, ik, iv)
        d, ok_d = dhash.delete(d, dk)
        d = dhash.finish_same_shape(dhash.rebuild_step(d))
        return d, (f, v, ok_i, ok_d)

    jstack = jax.jit(stacked_step)
    jsingle = jax.jit(single_step)

    # per-step launch traffic: the stacked arm's one program vs T programs
    names = ("sort", "pallas_call")
    c_stack = count_primitives(
        jax.make_jaxpr(stacked_step)(st, lk, ik, ik * 3, dk), names)
    c_single = count_primitives(
        jax.make_jaxpr(single_step)(singles[0], lk[0], ik[0], ik[0] * 3,
                                    dk[0]), names)
    launches_stacked = sum(c_stack.values())
    launches_looped = t * sum(c_single.values())
    ratio = launches_looped / launches_stacked

    # fused per-table-step budget, unchanged under vmap: the whole stack's
    # rebuild-epoch ordered lookup is ONE sort + ONE pallas_call
    be = backend.get("linear")
    ordered = jax.vmap(lambda d, k: be.ordered_lookup_fused(
        d.old, d.new, d.hazard_key, d.hazard_val, d.hazard_live, k,
        nres_cap=d.nres_cap))
    c_ordered = count_primitives(jax.make_jaxpr(ordered)(st, lk), names)
    assert c_ordered == {"sort": 1, "pallas_call": 1}, c_ordered

    def run_stacked():
        _d, out = jstack(st, lk, ik, ik * 3, dk)
        return out

    def run_looped():
        return [jsingle(singles[i], lk[i], ik[i], ik[i] * 3, dk[i])[1]
                for i in range(t)]

    wall_stacked = timeit(run_stacked, warmup=2, iters=iters) * 1e6
    wall_looped = timeit(run_looped, warmup=2, iters=iters) * 1e6

    # exactness: the stacked step and the looped steps agree per table
    out_s = jax.device_get(run_stacked())
    out_l = jax.device_get(run_looped())
    for i in range(t):
        for a, b in zip(out_s, out_l[i]):
            np.testing.assert_array_equal(np.asarray(a[i]), np.asarray(b))

    if not quiet:
        print(f"table_stack/stacked T={t} launches={launches_stacked:3d} "
              f"{wall_stacked:9.0f} us")
        print(f"table_stack/looped  T={t} launches={launches_looped:3d} "
              f"{wall_looped:9.0f} us")
    result = {"n_tables": t, "capacity": capacity, "batch": batch,
              "interpret": True, "band": 2.5,
              "workload": "lookup+insert+delete+rebuild_step+swap "
                          "(T mid-rebuild tables)",
              "stacked": {"passes": launches_stacked,
                          "wall_us": wall_stacked, **c_stack},
              "looped": {"passes": launches_looped, "wall_us": wall_looped},
              "ordered_lookup_budget": c_ordered,
              "pass_ratio": ratio}
    assert ratio >= 1.5, f"stack launch reduction regressed: {ratio:.2f}x"
    out = (pathlib.Path(out_path) if out_path
           else _REPO_ROOT / "BENCH_table_stack.json")
    out.write_text(json.dumps(result, indent=2) + "\n")
    if not quiet:
        print(f"[summary] stacked launch reduction {ratio:.2f}x over "
              f"{t}-table loop (>=1.5x required) -> {out}")
    return result


def run_routed_stack(batch=1024, capacity=1024, cap_factor=2.0, *, iters=5,
                     quiet=False, out_path=None):
    """Single-pass spill-slab tenant routing under zipf skew, T in {8, 64}.

    A flat [Q] key batch with zipf-distributed tenants (the suite's shared
    skew source, ``common.zipf_owners``) is grouped by the counting-sort
    router into ONE ``[T, cap + spill_cap]`` buffer — a per-tenant primary
    of ``cap = ceil(c*Q/T)`` columns plus a compact shared spill slab of
    ``spill_cap = ceil(slack*Q)`` columns — and served by ONE vmapped
    fused stack lookup.  There is no retry pass any more: spilled keys
    ride the slab in the same pass.  Gated in BENCH_routed_stack.json:

    * **send_bytes_ratio** (gated as a ratio, >= 1.5): full-width buffer
      bytes over the slab layout, Q/(cap + spill_cap) — the wire-bytes and
      scatter-work win.  The slab IS counted in the wire bytes; the win
      comes from a compact per-arm ``spill_slack`` sized so the zipf spill
      still fits (dropped_rate stays 0.0).
    * **per-op budget** (gated structurally): the slab-routed fused lookup
      lowers to exactly 1 ``sort`` + 1 ``pallas_call`` TOTAL — the router
      itself is sort-free (histogram + cumsum + 2-D scatter), the slab
      adds no pass, and the cond-gated retry is gone.
    * **adversarial budget** (``adversarial_sorts`` /
      ``adversarial_pallas_calls``, gated structurally): the SAME 1+1
      budget on a 100%-one-tenant batch served bit-identically to the
      full-width route through the overflow-proof slab.
    * **overflow_rate** (gated as a rate): fraction of the zipf batch past
      its tenant's primary cap — slab pressure, the signal the serving
      layer's RouteCapController consumes.  **dropped_rate** (gated as a
      rate): fraction past primary AND slab — exactly accounted, 0.0 for
      these arms by construction.

    Wall clocks are interpret-mode (recorded for the trajectory under this
    artifact's band, not the acceptance); correctness is asserted inline —
    the slab route serves EVERY key here (no drops) and agrees with the
    full-width route bit-for-bit.
    """
    import jax
    import jax.numpy as jnp

    from benchmarks.common import zipf_owners
    from repro.core import backend, dhash
    from repro.core import distributed as dd

    rng = np.random.default_rng(0)
    be = backend.get("linear")
    keys = jnp.asarray(rng.choice(UNIVERSE, size=batch,
                                  replace=False).astype(np.int32)) + 1
    # per-arm compact slack: sized so the deterministic zipf spill fits the
    # slab (dropped_rate 0.0) while the total width stays >= 1.5x under
    # full width.  t8 zipf spill = 333 <= 384; t64 spill = 592 <= 640.
    slack = {8: 0.375, 64: 0.625}
    result = {"batch": batch, "cap_factor": cap_factor, "interpret": True,
              "band": 2.5,
              "workload": "zipf(a=1.2)-skewed tenant lookups through the "
                          "single-pass spill-slab router, fused linear "
                          "stacks"}
    names = ("sort", "pallas_call")
    for t in (8, 64):
        tenant = jnp.asarray(zipf_owners(rng, batch, t))
        cap = dd.route_cap(cap_factor, batch, t)
        spill_cap = dd.route_spill_cap(batch, cap, slack[t])
        st = dhash.make_stack(t, "linear", capacity, chunk=256, seed=1,
                              fused=True)
        full = dd._route(keys, tenant, t)
        st, _ = jax.jit(dhash.stack_insert)(st, full.send, full.send * 3,
                                            full.smask)

        def routed(st, k, tn, sc):
            rt = dd._route(k, tn, t, cap, sc)
            f, v = jax.vmap(lambda d, kk: be.lookup_fused(d.old, kk))(
                st, rt.send)
            return (dd._unroute(f & rt.smask, rt, fill=False),
                    dd._unroute(v, rt, fill=0), rt.served, rt.overflow,
                    rt.dropped)

        # the acceptance budget: slab router + fused stack lookup = ONE
        # sort + ONE pallas_call total (the kernel's own bucket sort is
        # the only sort in the whole routed op; no cond retry exists)
        budget = count_primitives(
            jax.make_jaxpr(lambda s, k, tn: routed(s, k, tn, spill_cap))(
                st, keys, tenant), names)
        assert budget == {"sort": 1, "pallas_call": 1}, (t, budget)

        jrouted = jax.jit(routed, static_argnums=3)
        wall = timeit(lambda: jrouted(st, keys, tenant, spill_cap),
                      warmup=2, iters=iters) * 1e6
        f, v, served, overflow, dropped = (
            np.asarray(x) for x in jax.device_get(
                jrouted(st, keys, tenant, spill_cap)))
        # exact spill/drop accounting vs a host-side histogram
        hist = np.bincount(np.asarray(tenant), minlength=t)
        np.testing.assert_array_equal(overflow, np.maximum(hist - cap, 0))
        assert int(dropped.sum()) == max(int(overflow.sum()) - spill_cap, 0)
        # the slab serves every spilled key for these arms: all found,
        # values bit-identical to the full-width route
        assert served.all() and f.all(), (t, int(served.sum()))
        np.testing.assert_array_equal(v, np.asarray(keys) * 3)
        send_bytes_ratio = batch / (cap + spill_cap)
        overflow_rate = float(overflow.sum()) / batch
        dropped_rate = float(dropped.sum()) / batch
        assert send_bytes_ratio >= 1.5, \
            f"slab routing buffer win regressed: {send_bytes_ratio:.2f}x"
        assert dropped_rate == 0.0, \
            f"zipf arm must not drop: {dropped_rate:.4f}"

        # adversarial arm: 100% one-tenant skew through the overflow-proof
        # slab — same 1 sort + 1 pallas_call, bit-identical to full width
        atn = jnp.zeros((batch,), jnp.int32)
        adv_budget = count_primitives(
            jax.make_jaxpr(lambda s, k, tn: routed(s, k, tn, batch - cap))(
                st, keys, atn), names)
        assert adv_budget == {"sort": 1, "pallas_call": 1}, (t, adv_budget)
        fa, va, sa, _, da = (np.asarray(x) for x in jax.device_get(
            jrouted(st, keys, atn, batch - cap)))
        assert sa.all() and int(da.sum()) == 0
        # full-width reference: cap=Q serves everything in the primary
        rt_fw = dd._route(keys, atn, t, batch)
        f_fw, v_fw = jax.vmap(lambda d, kk: be.lookup_fused(d.old, kk))(
            st, rt_fw.send)
        f_fw = np.asarray(dd._unroute(f_fw & rt_fw.smask, rt_fw,
                                      fill=False))
        v_fw = np.asarray(dd._unroute(v_fw, rt_fw, fill=0))
        np.testing.assert_array_equal(fa, f_fw)
        np.testing.assert_array_equal(va[fa], v_fw[fa])

        if not quiet:
            print(f"routed_stack T={t:<3d} cap={cap:<5d} slab={spill_cap:<5d} "
                  f"send_bytes_ratio={send_bytes_ratio:5.2f}x "
                  f"overflow_rate={overflow_rate:.4f} "
                  f"dropped_rate={dropped_rate:.4f} {wall:9.0f} us")
        result[f"t{t}"] = {"n_tenants": t, "cap": cap,
                           "spill_cap": spill_cap, "spill_slack": slack[t],
                           "send_bytes_ratio": send_bytes_ratio,
                           "overflow_rate": overflow_rate,
                           "dropped_rate": dropped_rate,
                           "wall_us": wall, **budget,
                           "adversarial_sorts": adv_budget["sort"],
                           "adversarial_pallas_calls":
                               adv_budget["pallas_call"]}
    out = (pathlib.Path(out_path) if out_path
           else _REPO_ROOT / "BENCH_routed_stack.json")
    out.write_text(json.dumps(result, indent=2) + "\n")
    if not quiet:
        print(f"[summary] spill-slab routing: "
              f"{result['t8']['send_bytes_ratio']:.2f}x fewer wire bytes "
              f"at T=8, {result['t64']['send_bytes_ratio']:.2f}x at T=64, "
              f"0 drops, 1 sort + 1 pallas_call per routed op (adversarial "
              f"skew included, no retry) -> {out}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ns", type=int, nargs="*", default=[2_000, 8_000, 32_000])
    ap.add_argument("--alpha", type=int, default=20)
    ap.add_argument("--fused", action="store_true",
                    help="also run the fused=on|off rebuild-epoch probe, "
                         "write-path, chain-backend, growth-escape, "
                         "table-stack, and routed-stack comparisons (writes "
                         "BENCH_fused_probe.json + BENCH_fused_writes.json "
                         "+ BENCH_chain_fused.json + "
                         "BENCH_growth_escape.json + "
                         "BENCH_table_stack.json + "
                         "BENCH_routed_stack.json)")
    args = ap.parse_args(argv)
    rows = run(tuple(args.ns), args.alpha)
    if args.fused:
        run_fused_probe()
        run_fused_writes()
        run_chain_fused()
        run_growth_escape()
        run_table_stack()
        run_routed_stack()
    return rows


if __name__ == "__main__":
    main()
