"""Paper Figure 3: rebuild time vs number of nodes.

Claims reproduced:
  * HT-Split resize is cheapest (bucket pointers only, no node movement);
  * HT-Xu rebuilds in one traversal (two-pointer-set advantage);
  * DHash and HT-RHT distribute every node -> time linear in N;
  * DHash beats HT-RHT because RHT re-walks each chain to its TAIL per node
    distributed (O(len^2) per bucket) while DHash distributes scan-order
    chunks;
  * the op mix running concurrently does not materially change rebuild time
    (predictability claim, §6.3).
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

from benchmarks.common import ALGOS, UNIVERSE, count_primitives, timeit

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def run(ns=(2_000, 8_000, 32_000), alpha=20, *, quiet=False):
    rows = []
    for n in ns:
        nbuckets = max(n // alpha, 16)
        rng = np.random.default_rng(0)
        present = rng.choice(UNIVERSE, size=n, replace=False).astype(np.int32)
        for name, cls in ALGOS.items():
            drv = cls(nbuckets, n, seed=1)
            drv.populate(present)
            drv.full_rebuild()            # warmup (compile)
            dt = min(drv.full_rebuild() for _ in range(2))
            rows.append((drv.name, n, dt))
            if not quiet:
                print(f"{drv.name:14s} N={n:<8d} rebuild {dt*1e3:9.1f} ms")
    # linearity check for DHash (paper: predictable, linear in N)
    ds = [(n, dt) for nm, n, dt in rows if nm.startswith("DHash")]
    if len(ds) >= 2:
        r = (ds[-1][1] / ds[0][1]) / (ds[-1][0] / ds[0][0])
        print(f"[summary] DHash rebuild-time linearity ratio "
              f"(time-growth / N-growth): {r:.2f} (1.0 = perfectly linear)")
    return rows


def run_fused_probe(batch=4096, n_items=3_000, *, iters=3, quiet=False,
                    out_path=None):
    """fused=on|off rebuild-epoch lookup comparison for the linear backend.

    The hot-path claim under test: with a rebuild in flight, the FUSED path
    executes ONE argsort + ONE pallas_call per batch where the unfused path
    pays one sort + one pallas_call per table plus a separate hazard pass.
    In interpret mode (no real TPU) the pass-count reduction is the
    acceptance metric (wall clock of interpreted Pallas is not meaningful);
    both are recorded in BENCH_fused_probe.json for the perf trajectory.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import buckets, dhash, hashing
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    d = dhash.make("linear", capacity=n_items, chunk=256, seed=1)
    present = rng.choice(UNIVERSE, size=n_items, replace=False).astype(np.int32)
    keys = jnp.asarray(present)
    ins = jax.jit(dhash.insert)
    for i in range(0, n_items, 4096):
        d, _ = ins(d, keys[i:i + 4096], keys[i:i + 4096])
    # put the table mid-rebuild with a populated hazard window
    d = dhash.rebuild_start(d, seed=9)
    d = jax.jit(dhash.rebuild_chunk)(d)
    d = jax.jit(dhash.rebuild_extract)(d)

    qs = jnp.asarray(np.concatenate([
        rng.choice(present, batch // 2),
        rng.integers(1, UNIVERSE, batch - batch // 2)]).astype(np.int32))
    h0o = hashing.bucket_of(d.old.hfn, qs, d.old.capacity)
    h0n = hashing.bucket_of(d.new.hfn, qs, d.new.capacity)
    args = ((d.old.key, d.old.val, d.old.state),
            (d.new.key, d.new.val, d.new.state),
            d.hazard_key, d.hazard_val, d.hazard_live, h0o, h0n, qs)

    mp = d.old.max_probes
    fused_fn = lambda *a: ops.ordered_lookup_fused(*a, max_probes=mp)   # noqa: E731
    unfused_fn = lambda *a: ops.ordered_lookup(*a, max_probes=mp)       # noqa: E731
    passes = {}
    for name, fn in (("fused", fused_fn), ("unfused", unfused_fn)):
        counts = count_primitives(jax.make_jaxpr(fn)(*args),
                                  ("sort", "pallas_call"))
        dt = timeit(fn, *args, warmup=1, iters=iters)
        passes[name] = dict(counts, wall_us=dt * 1e6)
        if not quiet:
            print(f"fused_probe/{name:8s} Q={batch} sorts={counts['sort']} "
                  f"pallas_calls={counts['pallas_call']} {dt*1e6:9.0f} us")
    # exactness cross-check while we're here
    f_f, v_f = fused_fn(*args)
    f_u, v_u = unfused_fn(*args)
    assert bool((f_f == f_u).all()) and bool((v_f == v_u).all())

    ratio = ((passes["unfused"]["sort"] + passes["unfused"]["pallas_call"])
             / (passes["fused"]["sort"] + passes["fused"]["pallas_call"]))
    result = {"batch": batch, "n_items": n_items, "interpret": True,
              "fused": passes["fused"], "unfused": passes["unfused"],
              "pass_ratio": ratio}
    assert passes["fused"]["sort"] == 1 and passes["fused"]["pallas_call"] == 1
    assert ratio >= 1.5, f"pass-count reduction regressed: {ratio:.2f}x"
    out = pathlib.Path(out_path) if out_path else _REPO_ROOT / "BENCH_fused_probe.json"
    out.write_text(json.dumps(result, indent=2) + "\n")
    if not quiet:
        print(f"[summary] fused pass-count reduction {ratio:.2f}x "
              f"(>=1.5x required) -> {out}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ns", type=int, nargs="*", default=[2_000, 8_000, 32_000])
    ap.add_argument("--alpha", type=int, default=20)
    ap.add_argument("--fused", action="store_true",
                    help="also run the fused=on|off rebuild-epoch probe "
                         "comparison (writes BENCH_fused_probe.json)")
    args = ap.parse_args(argv)
    rows = run(tuple(args.ns), args.alpha)
    if args.fused:
        run_fused_probe()
    return rows


if __name__ == "__main__":
    main()
