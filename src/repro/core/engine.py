"""Engine: interleaves full-rate op batches with rebuild transitions.

This is the SPMD rendering of the paper's concurrency: "worker threads"
(batched lookup/insert/delete steps) run at full rate while a rebuild makes
incremental progress — one extract or land transition per engine step, with
the hazard window genuinely observable by the ops interleaved between the two
halves.

The steady state is **fully on-device**: the jitted step performs the op
batch, one rebuild transition, the epoch swap (``finish_same_shape``, valid
whenever old/new share static shapes — every default rebuild), and, in
continuous-rebuild mode, the next rebuild start (``rebuild_autostart``, which
reseeds the hash function on-device).  With a ``fused`` DHashState the whole
surface inside that step is kernel-backed — lookup, insert, DELETE, the
rebuild chunk extraction, and the hazard landing all run through the Pallas
probe/claim/extract kernels, so a complete rebuild epoch (extract -> land ->
swap) with interleaved reads and writes never leaves the device between
polls ("fused reads, jnp writes" was PR 1; this is fully fused).  The
rebuild-epoch ordered lookup/delete are single-pass for ALL THREE fused
backends (linear probe2, its twochoice analogue, and the chain backend's
arena-sorted chain_probe2), and the two-level tile map keeps them
single-pass even when the rebuild target is a grown table — so a
capacity-increasing rehash sustains the same step rate as a same-size one
(see docs/KERNELS.md).  A fused chain state folds its arena maintenance
into the same loop: inserts and hazard landings re-sort the arena
(cond-gated ``chain_maybe_compact``) only when the dirty tail outgrows the
dense window, and each epoch's ``rebuild_autostart`` freezes the old arena
fully sorted before the cursor scan.  State
buffers are **donated**
(``donate_argnums``) so XLA updates tables in place instead of copying them
every step, and the host polls ``rebuild_done`` only every ``poll_every``
steps (default 32) — zero ``device_get`` round-trips on the other K-1 steps,
so dispatch is never serialized on a device->host sync.

Only a *shape-changing* rebuild (a user-supplied ``new_table`` with a
different capacity) still needs the host: its epoch swap happens at the next
poll via ``rebuild_finish`` — up to K-1 steps late, which is safe because a
completed-but-unswapped rebuild still answers every op correctly through the
ordered check.

Ownership note: the engine donates its state buffers to the jitted step, so
after the first ``step()`` the ``DHashState`` passed to the constructor must
not be used elsewhere.

Used by the benchmarks (continuous-rebuild mode reproduces the paper's Fig 2
setup) and by the serving engine for live cache rehash.

``DHashStackEngine`` is the multi-table variant: it drives a
``dhash.make_stack`` state — T independent tables vmapped inside one jitted
step, each with its OWN rebuild epoch (staggered live rehashes across
tenants) — through the same donation + K-step polling treatment.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backends
from repro.core import dhash
from repro.core import policy as elastic
from repro.core.struct_utils import replace

I32 = jnp.int32

DEFAULT_POLL_EVERY = 32


@dataclass
class EngineStats:
    steps: int = 0
    ops: int = 0
    hits: int = 0
    rebuilds_completed: int = 0
    rebuild_transitions: int = 0
    host_syncs: int = 0         # engine-internal device_get round-trips
    grows: int = 0              # policy-applied capacity increases
    shrinks: int = 0            # policy-applied capacity decreases


@partial(jax.jit, static_argnames=("swap_on_device",), donate_argnums=(0, 1))
def _policy_engine_step(d, pol, lk, ik, iv, dk, imask, dmask, *,
                        swap_on_device: bool):
    """The policy-driven engine step (module level so every engine instance
    shares ONE jit cache — a resize retrace warms the cache for all engines
    with the same geometry, e.g. a bench's warmup and timed engines).

    Identical op sequence to the plain step, with the lookup routed through
    ``lookup_counted`` (probe telemetry is a kernel output, not an extra
    pass) and one ``policy_step`` evaluation appended.  While old/new are
    shape-mismatched mid-resize (``swap_on_device=False``) the policy is
    plan-only: no on-device autostart against the wrong geometry."""
    d, (found, vals) = dhash.lookup_counted(d, lk, probe_hi=pol.probe_hi)
    d, ok_i = dhash.insert(d, ik, iv, imask)
    d, ok_d = dhash.delete(d, dk, dmask)
    d = dhash.rebuild_step(d)
    if swap_on_device:
        d = dhash.finish_same_shape(d)
    pol, d = elastic.policy_step(pol, d, allow_autostart=swap_on_device)
    return d, pol, (found, vals, ok_i, ok_d)


@dataclass
class DHashEngine:
    """Drives a DHashState: user op batches + background rebuild progress."""

    state: dhash.DHashState
    continuous_rebuild: bool = False   # paper Fig 2: rebuild forever
    rebuild_seed: int = 1234
    poll_every: int = DEFAULT_POLL_EVERY   # host polls 1 of every K steps
    policy: elastic.ElasticPolicy | None = None   # elastic capacity decisions
    _stats: EngineStats = field(default_factory=EngineStats, repr=False)
    _step_fns: dict = field(default_factory=dict, init=False, repr=False)
    _poll_fn: Callable | None = field(default=None, init=False, repr=False)
    _lookup_fn: Callable | None = field(default=None, init=False, repr=False)
    _count_fn: Callable | None = field(default=None, init=False, repr=False)
    _epoch0: int = field(default=0, init=False, repr=False)
    _last_poll_step: int = field(default=-1, init=False, repr=False)

    def __post_init__(self):
        if self.policy is not None and self.continuous_rebuild:
            raise ValueError("policy and continuous_rebuild are exclusive: "
                             "the policy decides when to rebuild")
        # take ownership: copy so donation never sees aliased or shared
        # buffers (e.g. a caller-held reference or zeros reused across leaves)
        self.state = jax.tree_util.tree_map(jnp.copy, self.state)
        if self.policy is not None:
            self.policy = jax.tree_util.tree_map(jnp.copy, self.policy)
            self._poll_fn = jax.jit(
                lambda d, p: (d.epoch, d.rebuilding, dhash.rebuild_done(d),
                              p.want_grow, p.want_shrink, p.target_capacity))
        else:
            self._poll_fn = jax.jit(
                lambda d: (d.epoch, d.rebuilding, dhash.rebuild_done(d)))
        self._lookup_fn = jax.jit(dhash.lookup)
        self._count_fn = jax.jit(dhash.count_items)
        self._epoch0 = int(jax.device_get(self.state.epoch))

    # -- jitted step ---------------------------------------------------------

    def _get_step_fn(self, swap_on_device: bool):
        key = swap_on_device
        if key not in self._step_fns:
            autostart = swap_on_device and self.continuous_rebuild

            def fused(d, lk, ik, iv, dk, imask, dmask):
                found, vals = dhash.lookup(d, lk)
                d, ok_i = dhash.insert(d, ik, iv, imask)
                d, ok_d = dhash.delete(d, dk, dmask)
                d = dhash.rebuild_step(d)
                if swap_on_device:
                    d = dhash.finish_same_shape(d)   # on-device epoch swap
                    if autostart:
                        d = dhash.rebuild_autostart(d)
                return d, (found, vals, ok_i, ok_d)

            # donate the state: tables update in place, no per-step copy
            self._step_fns[key] = jax.jit(fused, donate_argnums=(0,))
        return self._step_fns[key]

    def _swap_on_device(self) -> bool:
        """True iff old/new share static shapes, so the epoch swap can run
        inside the jitted step (host metadata only — no device sync)."""
        old, new = self.state.old, self.state.new
        if (jax.tree_util.tree_structure(old)
                != jax.tree_util.tree_structure(new)):
            return False
        return all(
            getattr(a, "shape", None) == getattr(b, "shape", None)
            and getattr(a, "dtype", None) == getattr(b, "dtype", None)
            for a, b in zip(jax.tree_util.tree_leaves(old),
                            jax.tree_util.tree_leaves(new)))

    def step(self, lookup_keys, ins_keys, ins_vals, del_keys,
             ins_mask=None, del_mask=None):
        lk = jnp.asarray(lookup_keys, I32)
        ik = jnp.asarray(ins_keys, I32)
        iv = jnp.asarray(ins_vals, I32)
        dk = jnp.asarray(del_keys, I32)
        im = jnp.ones(ik.shape, bool) if ins_mask is None else jnp.asarray(ins_mask)
        dm = jnp.ones(dk.shape, bool) if del_mask is None else jnp.asarray(del_mask)
        if self.policy is not None:
            self.state, self.policy, out = _policy_engine_step(
                self.state, self.policy, lk, ik, iv, dk, im, dm,
                swap_on_device=self._swap_on_device())
        else:
            fn = self._get_step_fn(self._swap_on_device())
            self.state, out = fn(self.state, lk, ik, iv, dk, im, dm)
        self._stats.steps += 1
        self._stats.ops += lk.size + ik.size + dk.size
        if self.poll_every <= 1 or self._stats.steps % self.poll_every == 0:
            self._poll()
        return out

    # -- host-side polling (1 of every K steps) ------------------------------

    def _poll(self):
        """One batched device_get: refresh stats; finish a shape-changing
        rebuild; (re)start a rebuild in continuous mode if the on-device
        autostart could not (shape-changing tables); apply the policy's
        published resize plan (policy engines)."""
        if self.policy is not None:
            epoch, rebuilding, done, wg, ws, tgt = (
                int(x) for x in
                jax.device_get(self._poll_fn(self.state, self.policy)))
        else:
            epoch, rebuilding, done = (
                int(x) for x in jax.device_get(self._poll_fn(self.state)))
            wg = ws = 0
        self._stats.host_syncs += 1
        self._last_poll_step = self._stats.steps
        if done:
            # only reachable when the on-device swap wasn't applicable
            self.state = dhash.rebuild_finish(self.state)
            epoch += 1
            rebuilding = False
            # the published plan predates the swap we just applied — drop
            # it; the device policy re-evaluates against the new geometry
            # before the next poll can act
            wg = ws = 0
            if self.policy is not None:
                # a finished shape-changing resize leaves the dead table as
                # the standby; restore a same-shape standby so the epoch
                # swap (and tombstone-reclaim autostarts) return on-device
                be = backends.get(self.state.backend)
                self.state = replace(
                    self.state, new=be.fresh_like(self.state.old,
                                                  self.rebuild_seed))
                self.rebuild_seed += 1
        self._stats.rebuilds_completed = epoch - self._epoch0
        if self.continuous_rebuild and not rebuilding:
            self.request_rebuild()
        if self.policy is not None and not rebuilding and (wg or ws):
            self._apply_resize(grow=bool(wg), target_entries=tgt)

    def _apply_resize(self, *, grow: bool, target_entries: int):
        """Materialize the policy's published plan: size the new table,
        adapt the tile-map residency to the slot ratio, and begin the live
        migration.  Skips plans that round to the CURRENT slot count (the
        power-of-two sizing makes repeated wants at a capacity floor free) —
        except a probe-triggered grow, which is force-bumped to the next
        size up: clustering wants more slots even when the load does not."""
        be = backends.get(self.state.backend)
        cur_slots = int(be.capacity_of(self.state.old))
        tgt = int(target_entries)
        new_slots = elastic.resolve_slots(be, tgt)
        if grow and new_slots <= cur_slots:
            tgt = int(cur_slots * 0.75) + 1
            new_slots = elastic.resolve_slots(be, tgt)
        if new_slots == cur_slots or (not grow and new_slots > cur_slots):
            return
        nres = elastic.adapt_nres_cap(self.policy, cur_slots, new_slots,
                                      base=be.nres_cap)
        new_table = be.make(tgt, self.rebuild_seed)
        if not self.request_rebuild(new_table=new_table):
            return   # lost the trylock (a reclaim rehash is mid-flight)
        # the resize consumes the plan and the probe sample window
        self.state = replace(self.state, nres_cap=nres,
                             lookups=jnp.asarray(0, I32),
                             expensive=jnp.asarray(0, I32))
        self.policy = replace(self.policy,
                              want_grow=jnp.asarray(False),
                              want_shrink=jnp.asarray(False))
        if grow:
            self._stats.grows += 1
        else:
            self._stats.shrinks += 1

    @property
    def stats(self) -> EngineStats:
        """Engine statistics.  Reading them performs a refresh-only device
        read if the engine stepped since the last poll (so
        ``rebuilds_completed`` is current) — it never finishes or starts a
        rebuild (those happen only on ``step()``'s K-step poll), and
        steady-state ``step()`` calls themselves stay sync-free."""
        if self._stats.steps != self._last_poll_step:
            epoch = int(jax.device_get(self.state.epoch))
            self._stats.host_syncs += 1
            self._last_poll_step = self._stats.steps
            self._stats.rebuilds_completed = epoch - self._epoch0
        return self._stats

    def request_rebuild(self, *, seed: int | None = None, new_table=None):
        """Begin a live rebuild (fails like the paper's trylock if one is
        already in progress)."""
        self._stats.host_syncs += 1
        if bool(jax.device_get(self.state.rebuilding)):
            return False  # -EBUSY
        if new_table is not None:
            new_table = jax.tree_util.tree_map(jnp.copy, new_table)  # own it
        self.state = dhash.rebuild_start(
            self.state, new_table,
            seed=self.rebuild_seed if seed is None else seed)
        self.rebuild_seed += 1
        return True

    def lookup(self, keys):
        return self._lookup_fn(self.state, jnp.asarray(keys, I32))

    def count(self) -> int:
        self._stats.host_syncs += 1
        return int(jax.device_get(self._count_fn(self.state)))

    def _step_cache_size(self) -> int:
        """Total jit cache entries across step variants (retrace detector)."""
        return sum(f._cache_size() for f in self._step_fns.values())


@dataclass
class DHashStackEngine:
    """Drives a ``dhash.make_stack`` state: T independent tables batched by
    ``jax.vmap`` inside ONE jitted step (multi-tenant serving loop).

    Per step, every table runs its op batch ([T, Q] operands), one rebuild
    transition, and its own on-device epoch swap — epochs are fully
    INDEPENDENT across the stack: ``request_rebuild(mask)`` starts rebuilds
    on any subset of tables (device-side ``rebuild_autostart`` under the
    mask, so a stack engine never needs the host-level ``rebuild_start``),
    and in ``continuous_rebuild`` mode every table that finishes an epoch
    immediately opens the next.  The same donation + K-step polling
    treatment as ``DHashEngine`` applies; stacks only support same-shape
    rebuilds (the vmapped swap is ``finish_same_shape``)."""

    state: dhash.DHashState                # stacked: every leaf leads with [T]
    continuous_rebuild: bool = False
    poll_every: int = DEFAULT_POLL_EVERY
    policy: elastic.ElasticPolicy | None = None   # in-place mode; [T]-stacked
    _stats: EngineStats = field(default_factory=EngineStats, repr=False)
    _step_fn: Callable | None = field(default=None, init=False, repr=False)
    _start_fn: Callable | None = field(default=None, init=False, repr=False)
    _lookup_fn: Callable | None = field(default=None, init=False, repr=False)
    _count_fn: Callable | None = field(default=None, init=False, repr=False)
    _epoch0: jnp.ndarray | None = field(default=None, init=False, repr=False)
    _last_poll_step: int = field(default=-1, init=False, repr=False)

    def __post_init__(self):
        self.state = jax.tree_util.tree_map(jnp.copy, self.state)
        self.n_tables = dhash.stack_size(self.state)
        autostart = self.continuous_rebuild
        if self.policy is not None:
            if self.continuous_rebuild:
                raise ValueError("policy and continuous_rebuild are "
                                 "exclusive: the policy decides when to "
                                 "rebuild")
            if not self.policy.in_place:
                raise ValueError("stack engines need an in_place policy: "
                                 "vmapped tables cannot change static shape")
            # accept a single (unstacked) policy and broadcast it
            if self.policy.armed.ndim == 0:
                self.policy = elastic.stack(self.policy, self.n_tables)
            self.policy = jax.tree_util.tree_map(jnp.copy, self.policy)
        probe_hi = None if self.policy is None else self.policy.probe_hi

        def fused(d, lk, ik, iv, dk, imask, dmask):
            found, vals = dhash.stack_lookup(d, lk)
            d, ok_i = dhash.stack_insert(d, ik, iv, imask)
            d, ok_d = dhash.stack_delete(d, dk, dmask)
            d = dhash.stack_rebuild_step(d)
            d = dhash.stack_finish_same_shape(d)
            if autostart:
                d = dhash.stack_autostart(d)
            return d, (found, vals, ok_i, ok_d)

        def fused_policy(d, pol, lk, ik, iv, dk, imask, dmask):
            d, (found, vals) = jax.vmap(
                lambda dd, kk: dhash.lookup_counted(dd, kk,
                                                    probe_hi=probe_hi))(d, lk)
            d, ok_i = dhash.stack_insert(d, ik, iv, imask)
            d, ok_d = dhash.stack_delete(d, dk, dmask)
            d = dhash.stack_rebuild_step(d)
            d = dhash.stack_finish_same_shape(d)
            # per-table triggers: each tenant fires its own same-shape
            # rehash independently (in-place mode), latched by its own
            # armed hysteresis
            pol, d = elastic.stack_policy_step(pol, d)
            return d, pol, (found, vals, ok_i, ok_d)

        if self.policy is not None:
            self._step_fn = jax.jit(fused_policy, donate_argnums=(0, 1))
        else:
            self._step_fn = jax.jit(fused, donate_argnums=(0,))
        self._start_fn = jax.jit(dhash.stack_autostart)
        self._lookup_fn = jax.jit(dhash.stack_lookup)
        self._count_fn = jax.jit(dhash.stack_count_items)
        self._epoch0 = np.asarray(jax.device_get(self.state.epoch))

    def step(self, lookup_keys, ins_keys, ins_vals, del_keys,
             ins_mask=None, del_mask=None):
        """One batched step for all T tables: operands are [T, Q]."""
        lk = jnp.asarray(lookup_keys, I32)
        ik = jnp.asarray(ins_keys, I32)
        iv = jnp.asarray(ins_vals, I32)
        dk = jnp.asarray(del_keys, I32)
        im = jnp.ones(ik.shape, bool) if ins_mask is None else jnp.asarray(ins_mask)
        dm = jnp.ones(dk.shape, bool) if del_mask is None else jnp.asarray(del_mask)
        if self.policy is not None:
            self.state, self.policy, out = self._step_fn(
                self.state, self.policy, lk, ik, iv, dk, im, dm)
        else:
            self.state, out = self._step_fn(self.state, lk, ik, iv, dk, im, dm)
        self._stats.steps += 1
        self._stats.ops += lk.size + ik.size + dk.size
        if self.poll_every <= 1 or self._stats.steps % self.poll_every == 0:
            self._poll()
        return out

    def _poll(self):
        epochs = np.asarray(jax.device_get(self.state.epoch))
        self._stats.host_syncs += 1
        self._last_poll_step = self._stats.steps
        self._stats.rebuilds_completed = int((epochs - self._epoch0).sum())

    @property
    def stats(self) -> EngineStats:
        """Reading stats performs a refresh-only device read ONLY when the
        engine stepped since the last poll (same contract as
        ``DHashEngine.stats`` — repeated reads in a step loop stay
        sync-free)."""
        if self._stats.steps != self._last_poll_step:
            self._poll()
        return self._stats

    def request_rebuild(self, mask=None) -> None:
        """Start a rebuild on the selected tables ([T] bool; all by default).
        Tables mid-rebuild are untouched (the paper's trylock: the request
        is simply lost for them)."""
        m = (jnp.ones((self.n_tables,), bool) if mask is None
             else jnp.asarray(mask, bool))
        self.state = self._start_fn(self.state, m)

    def lookup(self, keys):
        return self._lookup_fn(self.state, jnp.asarray(keys, I32))

    def counts(self) -> np.ndarray:
        """[T] live-entry counts (one host sync)."""
        self._stats.host_syncs += 1
        return np.asarray(jax.device_get(self._count_fn(self.state)))
