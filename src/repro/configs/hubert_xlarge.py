"""hubert-xlarge [audio]: encoder-only, bidirectional, masked-prediction to
a 504-unit codebook; CNN frame frontend STUBBED to precomputed frame
embeddings per spec [arXiv:2106.07447; unverified].
decode_32k/long_500k SKIPPED (encoder-only: no decode step)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504,
    causal=False, encoder_only=True, frontend="stub_embed",
    tie_embeddings=False,
)

def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                         head_dim=16, d_ff=128, vocab_size=64,
                         dtype="float32", attn_chunk=32, loss_chunk=32)
