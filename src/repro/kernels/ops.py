"""Jit'd wrappers around the Pallas kernels: padding, sorting, fallback.

``probe_lookup`` is a drop-in accelerated equivalent of
``ref.probe_lookup_ref`` (and of ``buckets.linear_lookup``'s inner loop);
``ordered_lookup_fused`` is the accelerated rebuild-epoch path (one sort +
one pallas_call for the whole old->hazard->new ordered check);
``probe_insert`` / ``probe_delete`` are the accelerated write paths (claim
or location kernel + one scatter); ``ordered_delete_fused`` is the
rebuild-epoch delete (the same probe2 kernel's location outputs drive the
old/new tombstones and the hazard kill); ``extract_chunk_fused`` is the
rebuild chunk scan; ``twochoice_lookup`` / ``twochoice_insert`` /
``twochoice_delete`` bring the 2-choice backend onto the same
sort + scalar-prefetch treatment (both row choices of a query expand into
two entries of ONE sorted batch), and ``twochoice_ordered_lookup`` /
``twochoice_ordered_delete`` are its rebuild-epoch single-pass analogues
(one sort + one tc_probe2 pallas_call for old -> hazard -> new); the
``chain_*`` family brings the last backend onto the same treatment via the
arena-sorted node layout (``chain_compact_fused`` + per-bucket segment
windows + dirty-tail dense compare — see the chain section below).

The rebuild-epoch ops cover arbitrarily grown new tables via a **two-level
tile map**: a first-level jnp pass (``_resident_blockmap`` — histogram +
top_k, no extra sort) picks up to ``NRES_CAP`` resident new-table blocks
per query tile, and the probe2 kernels reduce over them on a
``(tiles, nres)`` grid.  ``rebuild_escape_rate`` reports the fraction of
queries that still overflow to the fallback (the growth-escape benchmark
gates it).

Exactness contract shared by all of them: queries whose probe window escapes
the VMEM-resident slab (hash skew), or whose insert claim collides across
tiles, are recomputed by the jnp oracle fallback — which is gated behind
``jax.lax.cond`` so the steady state (no escapes) never pays for it.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.probe import (QT, SLAB, _tc_rowslab, chain_probe2_tiles,
                                 chain_probe_tiles, extract_tiles,
                                 probe2_tiles, probe_insert_tiles,
                                 probe_lookup_tiles, tc_insert_tiles,
                                 tc_lookup_tiles, tc_probe2_tiles)

I32 = jnp.int32
EMPTY, LIVE, TOMB, MIGRATED = 0, 1, 2, 3

# Resident new-table blocks per query tile in the rebuild-epoch probe (the
# second level of the two-level tile map).  16 block pairs cover a new table
# of up to ~16 SLABs (64K slots) COMPLETELY — a 16x growth rebuild of the
# default benchmark tables stays fully fused; beyond that, the least-
# populated blocks of a tile overflow to the gated jnp fallback.  This is
# the DEFAULT of the ``nres_cap`` parameter the rebuild-epoch ops accept;
# the per-backend value lives on the ``BucketBackend`` descriptor
# (core/backend.py) and is threaded here through ``dhash.make()``.
NRES_CAP = 16

# Dirty-tail window of the arena-sorted chain backend: nodes inserted since
# the last compaction live in a contiguous tail, resolved by a dense window
# compare (the hazard-buffer treatment).  A tail grown past DIRTY_CAP is no
# longer fully visible to the window, so the fused chain ops escape to the
# pointer-chasing jnp reference — ``backend.chain_maybe_compact`` re-sorts
# the arena at exactly this threshold to keep the steady state on-kernel.
# Like NRES_CAP this is only the DEFAULT of the ``dirty_cap`` parameter;
# the live value is a ``BucketBackend`` descriptor field.
DIRTY_CAP = 512


def _pad_to(x: jax.Array, n: int, fill=0):
    return jnp.pad(x, (0, n - x.shape[0]), constant_values=fill)


def _pad_table(arrays, c: int, max_probes: int):
    """Pad table arrays with a wrapped copy (probes never wrap in-kernel),
    then to a SLAB multiple plus one spare block (block s+1 always valid);
    padding slots are EMPTY so probes terminate there."""
    cpad = -(-(c + max_probes) // SLAB) * SLAB + SLAB
    return tuple(_pad_to(jnp.concatenate([a, a[:max_probes]]), cpad)
                 for a in arrays)


def _sort_pad_queries(order, qpad, *arrays):
    """Apply the shared sort and pad to a QT multiple by REPLICATING the last
    sorted element (edge padding).  Padding with a constant sentinel would
    break the slab math: an h0=0 pad in a tile whose slab base is > 0 reads
    complete=False and drags min-based tile bases to block 0, firing the
    oracle fallback on every non-QT-multiple batch.  Edge pads stay inside
    their tile's slab, and their results land in the discarded tail of the
    unsort (positions >= q)."""
    return tuple(jnp.pad(a[order], (0, qpad - a.shape[0]), mode="edge")
                 for a in arrays)


def _tile_base(h0_sorted: jax.Array, tiles: int, cpad: int) -> jax.Array:
    """Per-tile slab block index of a SORTED start-slot array (the tile's
    first element is its min), clipped so block s+1 stays in range."""
    base = h0_sorted.reshape(tiles, QT)[:, 0] // SLAB
    return jnp.minimum(base.astype(I32), cpad // SLAB - 2)


def _resident_blockmap(blk_sorted: jax.Array, tiles: int, nblocks: int,
                       nres: int) -> jax.Array:
    """First level of the two-level tile map: per tile, the ``nres``
    most-populated target blocks of the tile's queries (a vectorized
    histogram + ``top_k`` — no sort primitive, so the 1-sort/1-pallas_call
    budget is untouched).  ``blk_sorted`` is each query's target block index
    in the sorted batch order.  A query whose block is not among its tile's
    residents keeps ``complete=False`` in the kernel and is recovered by the
    gated jnp fallback.  Entries are clipped to ``nblocks - 2`` so the
    resident pair ``(b, b+1)`` stays in range; a window anchored at the
    query's own block always covers it (``max_probes <= SLAB``).
    Returns [nres, tiles]."""
    blk = blk_sorted.reshape(tiles, QT)
    hist = jnp.zeros((tiles, nblocks), I32).at[
        jnp.arange(tiles, dtype=I32)[:, None], blk].add(1)
    _, top = jax.lax.top_k(hist, nres)
    return jnp.minimum(top.astype(I32), nblocks - 2).T


@partial(jax.jit, static_argnames=("max_probes", "with_loc", "interpret"))
def probe_lookup(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                 h0: jax.Array, qkey: jax.Array, *, max_probes: int = 64,
                 with_loc: bool = False, interpret: bool = True):
    """Batched linear-probe lookup. Returns (found[Q], val[Q]), or
    (found, val, loc[Q]) when ``with_loc`` — ``loc`` is the hit's
    padded-table coordinate (unwrapped, >= h0; -1 on miss), the probe
    telemetry input for the elastic policy's expensive-lookup counter.

    Args:
      tkey/tval/tstate: table arrays [C].
      h0: start slot per query (hash(key) % C), [Q].
      qkey: query keys [Q].
    """
    c = tkey.shape[0]
    q = qkey.shape[0]
    tk, tv, ts = _pad_table((tkey, tval, tstate), c, max_probes)

    # ONE sort: queries ordered by start slot so tiles hit contiguous slabs
    order = jnp.argsort(h0)
    qpad = -(-q // QT) * QT
    h0s, qks = _sort_pad_queries(order, qpad, h0, qkey)
    tiles = qpad // QT
    slab_base = _tile_base(h0s, tiles, tk.shape[0])

    found_s, val_s, loc_s, complete_s = probe_lookup_tiles(
        tk, tv, ts, h0s, qks, slab_base, max_probes=max_probes,
        interpret=interpret)

    # fallback: recompute incomplete queries with the jnp oracle — gated so
    # the no-skew steady state skips the oracle pass entirely (h0s is already
    # in [0, C), so no re-mod either; the oracle wraps internally).
    need = ~complete_s

    if with_loc:
        def fallback(fvl):
            f0, v0, l0 = fvl
            fb_f, fb_v = ref.probe_lookup_ref(tkey, tval, tstate, h0s, qks,
                                              max_probes)
            # a query that escaped the resident window genuinely probed past
            # it: report max cost so the policy sees it as expensive
            fb_l = jnp.where(fb_f, h0s + (max_probes - 1), -1).astype(I32)
            return (jnp.where(need, fb_f, f0), jnp.where(need, fb_v, v0),
                    jnp.where(need, fb_l, l0))

        found_s, val_s, loc_s = jax.lax.cond(need.any(), fallback,
                                             lambda fvl: fvl,
                                             (found_s, val_s, loc_s))
        found = jnp.zeros((q,), jnp.bool_).at[order].set(found_s[:q])
        val = jnp.zeros((q,), I32).at[order].set(val_s[:q])
        loc = jnp.full((q,), -1, I32).at[order].set(loc_s[:q].astype(I32))
        return found, val, loc

    def fallback(fv):
        f0, v0 = fv
        fb_f, fb_v = ref.probe_lookup_ref(tkey, tval, tstate, h0s, qks,
                                          max_probes)
        return jnp.where(need, fb_f, f0), jnp.where(need, fb_v, v0)

    found_s, val_s = jax.lax.cond(need.any(), fallback, lambda fv: fv,
                                  (found_s, val_s))

    # unsort (order permutes [0, q); tail positions are padding)
    found = jnp.zeros((q,), jnp.bool_).at[order].set(found_s[:q])
    val = jnp.zeros((q,), I32).at[order].set(val_s[:q])
    return found, val


@partial(jax.jit, static_argnames=("max_probes", "interpret"))
def ordered_lookup(old_tables, new_tables, hazard_key, hazard_val, hazard_live,
                   h0_old, h0_new, qkey, *, max_probes: int = 64,
                   interpret: bool = True):
    """UNFUSED rebuild-epoch lookup: old table -> hazard buffer -> new table
    (the paper's Lemma 4.1 order), each table pass via its own sort +
    pallas_call.  Kept as the comparison baseline for ``ordered_lookup_fused``
    (see bench_rebuild's fused=on|off axis)."""
    f_old, v_old = probe_lookup(*old_tables, h0_old, qkey,
                                max_probes=max_probes, interpret=interpret)
    eq = (qkey[:, None] == hazard_key[None, :]) & hazard_live[None, :]
    f_hz = eq.any(-1)
    v_hz = jnp.take(hazard_val, jnp.argmax(eq, axis=-1))
    f_new, v_new = probe_lookup(*new_tables, h0_new, qkey,
                                max_probes=max_probes, interpret=interpret)
    found = f_old | f_hz | f_new
    val = jnp.where(f_old, v_old, jnp.where(f_hz, v_hz, v_new))
    return found, val


def _probe2_run(old_tables, new_tables, hazard_key, hazard_val, hazard_live,
                h0_old, h0_new, keys, max_probes: int, interpret: bool,
                nres_cap: int = NRES_CAP):
    """Shared prep + launch for the fused rebuild-epoch ops: the ONE argsort
    (keyed on the old table's start slot), the two-level new-table tile map
    (per-tile resident blocks, no second sort), and the ONE probe2
    pallas_call.  Returns (order, (h0os, h0ns, qks), kernel outputs)."""
    c_old = old_tables[0].shape[0]
    c_new = new_tables[0].shape[0]
    q = keys.shape[0]
    old_p = _pad_table(old_tables, c_old, max_probes)
    new_p = _pad_table(new_tables, c_new, max_probes)

    order = jnp.argsort(h0_old)
    qpad = -(-q // QT) * QT
    h0os, h0ns, qks = _sort_pad_queries(order, qpad, h0_old, h0_new, keys)
    tiles = qpad // QT
    nblocks_new = new_p[0].shape[0] // SLAB
    nres = min(nres_cap, nblocks_new - 1)
    slab2 = jnp.concatenate([
        _tile_base(h0os, tiles, old_p[0].shape[0])[None],
        _resident_blockmap(h0ns // SLAB, tiles, nblocks_new, nres)])

    outs = probe2_tiles(
        old_p, new_p, hazard_key, hazard_val, hazard_live.astype(I32),
        h0os, h0ns, qks, slab2, max_probes=max_probes, interpret=interpret)
    return order, (h0os, h0ns, qks), outs


@partial(jax.jit, static_argnames=("max_probes", "interpret", "nres_cap"))
def ordered_lookup_fused(old_tables, new_tables, hazard_key, hazard_val,
                         hazard_live, h0_old, h0_new, qkey, *,
                         max_probes: int = 64, interpret: bool = True,
                         nres_cap: int = NRES_CAP):
    """FUSED rebuild-epoch lookup: ONE argsort (keyed on h0_old) and ONE
    pallas_call emit the Lemma-4.1-ordered result for both tables plus the
    hazard buffer.  New-table residency is the two-level tile map: each
    tile's windows are bucketed into up to ``NRES_CAP`` resident blocks by a
    cheap jnp histogram pass, so growth-heavy rebuilds stay fused; a query
    whose block overflows the residents AND that the old table / hazard
    buffer did not resolve falls back to the jnp oracle (gated — free when
    nothing escapes)."""
    q = qkey.shape[0]
    order, (h0os, h0ns, qks), outs = _probe2_run(
        old_tables, new_tables, hazard_key, hazard_val, hazard_live,
        h0_old, h0_new, qkey, max_probes, interpret, nres_cap)
    found_s, val_s, complete_s = outs[0], outs[1], outs[2]

    need = ~complete_s

    def fallback(fv):
        f0, v0 = fv
        fb_f, fb_v = ref.ordered_lookup_ref(
            old_tables, new_tables, hazard_key, hazard_val, hazard_live,
            h0os, h0ns, qks, max_probes)
        return jnp.where(need, fb_f, f0), jnp.where(need, fb_v, v0)

    found_s, val_s = jax.lax.cond(need.any(), fallback, lambda fv: fv,
                                  (found_s, val_s))

    found = jnp.zeros((q,), jnp.bool_).at[order].set(found_s[:q])
    val = jnp.zeros((q,), I32).at[order].set(val_s[:q])
    return found, val


@partial(jax.jit, static_argnames=("max_probes", "interpret", "nres_cap"))
def rebuild_escape_rate(old_tables, new_tables, hazard_key, hazard_val,
                        hazard_live, h0_old, h0_new, qkey, *,
                        max_probes: int = 64, interpret: bool = True,
                        nres_cap: int = NRES_CAP):
    """Diagnostic for the growth-escape benchmark: the fraction of
    rebuild-epoch queries the fused probe2 pass could NOT resolve in-kernel
    (``complete=False`` — the gated jnp oracle recomputes exactly these).
    Runs the identical prep + kernel as ``ordered_lookup_fused``, so the
    rate it reports is the rate the fused path actually pays."""
    q = qkey.shape[0]
    order, _sorted, outs = _probe2_run(
        old_tables, new_tables, hazard_key, hazard_val, hazard_live,
        h0_old, h0_new, qkey, max_probes, interpret, nres_cap)
    complete_s = outs[2]
    escaped = jnp.zeros((q,), jnp.bool_).at[order].set((~complete_s)[:q])
    return escaped.mean()


@partial(jax.jit, static_argnames=("max_probes", "interpret"))
def probe_insert(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                 h0: jax.Array, keys: jax.Array, vals: jax.Array,
                 mask: jax.Array, *, max_probes: int = 64,
                 interpret: bool = True):
    """Batched linear-probe INSERT via the claim kernel + one scatter.

    Caller contract: ``mask`` is winner-filtered (at most one True per
    distinct key; use ``buckets.batch_winners``).  Set semantics: ok=False if
    the key is already LIVE or no free slot exists within ``max_probes``.

    Escape hatches (all exact, resolved by the gated jnp fallback):
      * probe window escapes the 2-block slab (``complete=False``);
      * two tiles claim the same physical slot (the padded table holds a
        wrapped copy of the first ``max_probes`` slots, so the same physical
        slot can be claimed under two padded positions) — first claimant in
        sort order keeps it, the loser escapes.

    Returns (tkey', tval', tstate', ok[Q]).
    """
    c = tkey.shape[0]
    q = keys.shape[0]
    tk, ts = _pad_table((tkey, tstate), c, max_probes)

    order = jnp.argsort(h0)
    qpad = -(-q // QT) * QT
    h0s, qks, qvs = _sort_pad_queries(order, qpad, h0, keys, vals)
    qms = _pad_to(mask[order], qpad, fill=False)
    tiles = qpad // QT
    slab_base = _tile_base(h0s, tiles, tk.shape[0])

    present_s, claim_s, complete_s = probe_insert_tiles(
        tk, ts, h0s, qks, qms.astype(I32), slab_base,
        max_probes=max_probes, interpret=interpret)

    # resolve claims globally: claims live in padded coordinates within
    # [h0, h0 + max_probes) ⊂ [0, C + max_probes), so % C maps the wrapped
    # region back onto the physical table; first claimant (sort order) wins.
    claimed = complete_s & (claim_s >= 0)
    phys = jnp.where(claimed, claim_s % c, c)
    sidx = jnp.arange(qpad, dtype=I32)
    first = jnp.full((c,), qpad, I32).at[phys].min(sidx, mode="drop")
    keep = claimed & (first[jnp.clip(phys, 0, c - 1)] == sidx)
    conflict = claimed & ~keep

    wp = jnp.where(keep, phys, c)
    tkey2 = tkey.at[wp].set(qks, mode="drop")
    tval2 = tval.at[wp].set(qvs, mode="drop")
    tstate2 = tstate.at[wp].set(LIVE, mode="drop")
    ok_s = keep

    need = qms & (~complete_s | conflict)

    def fallback(op):
        k, v, s, ok = op
        fb_k, fb_v, fb_s, fb_ok = ref.probe_insert_ref(
            k, v, s, h0s, qks, qvs, need, max_probes)
        return fb_k, fb_v, fb_s, ok | fb_ok

    tkey2, tval2, tstate2, ok_s = jax.lax.cond(
        need.any(), fallback, lambda op: op, (tkey2, tval2, tstate2, ok_s))

    ok = jnp.zeros((q,), jnp.bool_).at[order].set(ok_s[:q])
    return tkey2, tval2, tstate2, ok


@partial(jax.jit, static_argnames=("max_probes", "interpret"))
def probe_delete(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                 h0: jax.Array, keys: jax.Array, mask: jax.Array, *,
                 max_probes: int = 64, interpret: bool = True):
    """Batched linear-probe DELETE: the location-emitting lookup kernel +
    ONE tombstone scatter (no second probe pass).

    Caller contract: ``mask`` is winner-filtered (at most one True per
    distinct key; use ``buckets.batch_winners``), so distinct masked keys
    occupy distinct slots and the scatter cannot conflict.  Queries whose
    probe window escapes the resident slab fall back to the jnp oracle
    (gated — free when nothing escapes).

    Returns (tstate', ok[Q]).
    """
    c = tkey.shape[0]
    q = keys.shape[0]
    tk, tv, ts = _pad_table((tkey, tval, tstate), c, max_probes)

    order = jnp.argsort(h0)
    qpad = -(-q // QT) * QT
    h0s, qks = _sort_pad_queries(order, qpad, h0, keys)
    qms = _pad_to(mask[order], qpad, fill=False)
    tiles = qpad // QT
    slab_base = _tile_base(h0s, tiles, tk.shape[0])

    found_s, _val_s, loc_s, complete_s = probe_lookup_tiles(
        tk, tv, ts, h0s, qks, slab_base, max_probes=max_probes,
        interpret=interpret)

    # loc is in padded coordinates within [h0, h0 + max_probes); % C maps the
    # wrapped region back onto the physical table
    ok_s = qms & found_s
    tstate2 = tstate.at[jnp.where(ok_s, loc_s % c, c)].set(TOMB, mode="drop")

    need = qms & ~complete_s

    def fallback(op):
        s, ok = op
        fb_s, fb_ok = ref.probe_delete_ref(tkey, tval, s, h0s, qks, need,
                                           max_probes)
        return fb_s, ok | fb_ok

    tstate2, ok_s = jax.lax.cond(need.any(), fallback, lambda op: op,
                                 (tstate2, ok_s))

    ok = jnp.zeros((q,), jnp.bool_).at[order].set(ok_s[:q])
    return tstate2, ok


@partial(jax.jit, static_argnames=("max_probes", "interpret", "nres_cap"))
def ordered_delete_fused(old_tables, new_tables, hazard_key, hazard_val,
                         hazard_live, h0_old, h0_new, keys, mask, *,
                         max_probes: int = 64, interpret: bool = True,
                         nres_cap: int = NRES_CAP):
    """FUSED rebuild-epoch delete (paper Alg. 5): ONE argsort + ONE
    pallas_call (the probe2 kernel's location outputs) resolve the ordered
    check, then three scatters land the result — tombstone the old-table
    slot, or clear the hazard live bit (LOGICALLY_REMOVED on an in-flight
    entry; landing drops it), or tombstone the new-table slot.

    Caller contract: ``mask`` is winner-filtered.  Returns
    (old_state', new_state', hazard_live', ok[Q]).
    """
    c_old = old_tables[0].shape[0]
    c_new = new_tables[0].shape[0]
    ch = hazard_key.shape[0]
    q = keys.shape[0]
    qpad = -(-q // QT) * QT
    order, (h0os, h0ns, qks), outs = _probe2_run(
        old_tables, new_tables, hazard_key, hazard_val, hazard_live,
        h0_old, h0_new, keys, max_probes, interpret, nres_cap)
    (_found_s, _val_s, complete_s, fold_s, locold_s, hzidx_s,
     locnew_s, _cold_s) = outs
    qms = _pad_to(mask[order], qpad, fill=False)

    # ordered landing: old hit > hazard hit > new hit (at most one fires)
    f_hz = hzidx_s >= 0
    ok_old = qms & fold_s
    ok_hz = qms & complete_s & ~fold_s & f_hz
    ok_new = qms & complete_s & ~fold_s & ~f_hz & (locnew_s >= 0)

    old_state = old_tables[2].at[
        jnp.where(ok_old, locold_s % c_old, c_old)].set(TOMB, mode="drop")
    new_state = new_tables[2].at[
        jnp.where(ok_new, locnew_s % c_new, c_new)].set(TOMB, mode="drop")
    kill = jnp.zeros_like(hazard_live).at[
        jnp.where(ok_hz, hzidx_s, ch)].set(True, mode="drop")
    hz_live = hazard_live & ~kill
    ok_s = ok_old | ok_hz | ok_new

    need = qms & ~complete_s

    def fallback(op):
        os_, ns_, hl_, ok = op
        fb_os, ok_o = ref.probe_delete_ref(old_tables[0], old_tables[1],
                                           os_, h0os, qks, need, max_probes)
        pend = need & ~ok_o
        eq = (qks[:, None] == hazard_key[None, :]) & hl_[None, :]
        hz_hit = eq.any(-1) & pend
        kill2 = jnp.zeros_like(hl_).at[
            jnp.where(hz_hit, jnp.argmax(eq, axis=-1), ch)].set(
            True, mode="drop")
        fb_ns, ok_n = ref.probe_delete_ref(new_tables[0], new_tables[1],
                                           ns_, h0ns, qks, pend & ~hz_hit,
                                           max_probes)
        return fb_os, fb_ns, hl_ & ~kill2, ok | ok_o | hz_hit | ok_n

    old_state, new_state, hz_live, ok_s = jax.lax.cond(
        need.any(), fallback, lambda op: op,
        (old_state, new_state, hz_live, ok_s))

    ok = jnp.zeros((q,), jnp.bool_).at[order].set(ok_s[:q])
    return old_state, new_state, hz_live, ok


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def extract_chunk_fused(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                        cursor: jax.Array, *, chunk: int,
                        interpret: bool = True):
    """Rebuild chunk scan via the extract kernel: ONE pallas_call reads the
    slab window at ``cursor`` and compacts the live entries on-device; ONE
    scatter marks them MIGRATED.  Requires ``chunk <= SLAB`` (the caller
    gates; dhash chunks default to 256).

    Returns (tstate', hkeys[chunk], hvals[chunk], hlive[chunk] bool,
    new_cursor) — identical set contents to the jnp scan, with the hazard
    entries compacted to the front.
    """
    assert chunk <= SLAB, f"chunk {chunk} exceeds slab window {SLAB}"
    c = tkey.shape[0]
    cpad = -(-c // SLAB) * SLAB + SLAB
    tk, tv, ts = (_pad_to(a, cpad) for a in (tkey, tval, tstate))
    block = jnp.minimum(cursor // SLAB, cpad // SLAB - 2).astype(I32)

    hk, hv, hl, mig = extract_tiles(tk, tv, ts, block, cursor, chunk=chunk,
                                    capacity=c, interpret=interpret)

    pos = cursor + jnp.arange(chunk, dtype=I32)
    tstate2 = tstate.at[jnp.where(mig != 0, pos, c)].set(
        MIGRATED, mode="drop")
    new_cursor = jnp.minimum(cursor + chunk, c).astype(I32)
    return tstate2, hk, hv, hl != 0, new_cursor


# ---------------------------------------------------------------------------
# twochoice: both row choices expand into one sorted entry batch
# ---------------------------------------------------------------------------

def _tc_pad_rows(arrays, b: int, slab_r: int):
    """Row-pad [B, W] tables to a SLAB_R multiple plus one spare block
    (pad rows are EMPTY, so they can never satisfy a lookup or a claim)."""
    bpad = -(-b // slab_r) * slab_r + slab_r
    return tuple(jnp.pad(a, ((0, bpad - b), (0, 0))) for a in arrays)


def _tc_expand_sort(rows_a, rows_b, bpad: int, slab_r: int, *arrays):
    """Expand per-query arrays into the [2Q] entry batch (a-rows first, then
    b-rows), apply the ONE shared row-index sort + edge pad, and derive the
    per-tile row-block map.  Returns (order, epad, rows_sorted,
    sorted_arrays, slab_base) — the lookup and insert paths share this so
    their slab math can never diverge."""
    rows = jnp.concatenate([rows_a, rows_b])
    dup = [jnp.concatenate([a, a]) for a in arrays]
    e = rows.shape[0]
    order = jnp.argsort(rows)
    epad = -(-e // QT) * QT
    rs, *sorted_arrays = _sort_pad_queries(order, epad, rows, *dup)
    tiles = epad // QT
    base = rs.reshape(tiles, QT)[:, 0] // slab_r
    slab_base = jnp.minimum(base.astype(I32), bpad // slab_r - 2)
    return order, epad, rs, sorted_arrays, slab_base


@partial(jax.jit, static_argnames=("interpret",))
def twochoice_lookup(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                     rows_a: jax.Array, rows_b: jax.Array, qkey: jax.Array,
                     *, interpret: bool = True):
    """Fused twochoice lookup: the 2Q entry expansion (each query's two row
    choices), ONE argsort keyed on the row index, ONE pallas_call of the
    W-wide row-gather kernel, then a per-query recombine (a-row priority —
    the same tie-break as ``buckets.twochoice_lookup``).

    Returns (found[Q], val[Q], loc[Q] flat slot index or -1) — ``loc`` is
    reused by ``twochoice_delete`` so deleting never probes twice.
    """
    b, w = tkey.shape
    q = qkey.shape[0]
    e = 2 * q
    slab_r = _tc_rowslab(w)
    tk, tv, ts = _tc_pad_rows((tkey, tval, tstate), b, slab_r)
    order, epad, rs, (qks,), slab_base = _tc_expand_sort(
        rows_a, rows_b, tk.shape[0], slab_r, qkey)

    found_s, val_s, loc_s, complete_s = tc_lookup_tiles(
        tk, tv, ts, rs, qks, slab_base, interpret=interpret)

    need = ~complete_s

    def fallback(fvl):
        f0, v0, l0 = fvl
        fb_f, fb_v, fb_l = ref.tc_row_lookup_ref(tkey, tval, tstate, rs, qks)
        return (jnp.where(need, fb_f, f0), jnp.where(need, fb_v, v0),
                jnp.where(need, fb_l, l0))

    found_s, val_s, loc_s = jax.lax.cond(need.any(), fallback, lambda x: x,
                                         (found_s, val_s, loc_s))

    fe = jnp.zeros((e,), jnp.bool_).at[order].set(found_s[:e])
    ve = jnp.zeros((e,), I32).at[order].set(val_s[:e])
    le = jnp.full((e,), -1, I32).at[order].set(loc_s[:e])
    f_a, f_b = fe[:q], fe[q:]
    found = f_a | f_b
    val = jnp.where(f_a, ve[:q], ve[q:])
    loc = jnp.where(f_a, le[:q], jnp.where(f_b, le[q:], -1))
    return found, val, loc


@partial(jax.jit, static_argnames=("max_rounds", "interpret"))
def twochoice_insert(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                     rows_a: jax.Array, rows_b: jax.Array, keys: jax.Array,
                     vals: jax.Array, mask: jax.Array, *,
                     max_rounds: int = 8, interpret: bool = True):
    """Batched twochoice INSERT via the claim kernel + one scatter.

    Caller contract: ``mask`` is winner-filtered.  Set semantics: ok=False
    if the key is LIVE in either row or both rows are full.  The kernel
    claims per row-entry; here the a-claim shadows the b-claim of the same
    query, cross-tile slot collisions keep the first claimant (batch order),
    and everything else — escaped windows, lost claims, locally-full rows —
    re-runs on the jnp oracle (gated).

    Returns (tkey', tval', tstate', ok[Q]).
    """
    b, w = tkey.shape
    q = keys.shape[0]
    e = 2 * q
    nslots = b * w
    slab_r = _tc_rowslab(w)
    tk, ts = _tc_pad_rows((tkey, tstate), b, slab_r)
    order, epad, rs, (qks,), slab_base = _tc_expand_sort(
        rows_a, rows_b, tk.shape[0], slab_r, keys)
    qms = _pad_to(jnp.concatenate([mask, mask])[order], epad, fill=False)

    present_s, claim_s, complete_s = tc_insert_tiles(
        tk, ts, rs, qks, qms.astype(I32), slab_base, interpret=interpret)

    pe = jnp.zeros((e,), jnp.bool_).at[order].set(present_s[:e])
    ce = jnp.full((e,), -1, I32).at[order].set(claim_s[:e])
    cpl = jnp.zeros((e,), jnp.bool_).at[order].set(complete_s[:e])
    present = pe[:q] | pe[q:]
    compl2 = cpl[:q] & cpl[q:]     # presence known for BOTH rows
    c_a, c_b = ce[:q], ce[q:]
    cand = jnp.where(compl2 & ~present,
                     jnp.where(c_a >= 0, c_a, c_b), -1)

    claimed = cand >= 0
    phys = jnp.where(claimed, cand, nslots)
    idx = jnp.arange(q, dtype=I32)
    first = jnp.full((nslots,), q, I32).at[phys].min(idx, mode="drop")
    keep = claimed & (first[jnp.clip(phys, 0, nslots - 1)] == idx)

    wp = jnp.where(keep, phys, nslots)
    tkey2 = tkey.reshape(-1).at[wp].set(keys, mode="drop").reshape(b, w)
    tval2 = tval.reshape(-1).at[wp].set(vals, mode="drop").reshape(b, w)
    tstate2 = tstate.reshape(-1).at[wp].set(LIVE, mode="drop").reshape(b, w)
    ok = keep

    need = mask & ~keep & ~present

    def fallback(op):
        k, v, s, ok0 = op
        fb_k, fb_v, fb_s, fb_ok = ref.tc_insert_ref(
            k, v, s, rows_a, rows_b, keys, vals, need, max_rounds)
        return fb_k, fb_v, fb_s, ok0 | fb_ok

    tkey2, tval2, tstate2, ok = jax.lax.cond(
        need.any(), fallback, lambda op: op, (tkey2, tval2, tstate2, ok))
    return tkey2, tval2, tstate2, ok


@partial(jax.jit, static_argnames=("interpret",))
def twochoice_delete(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                     rows_a: jax.Array, rows_b: jax.Array, keys: jax.Array,
                     mask: jax.Array, *, interpret: bool = True):
    """Batched twochoice DELETE: reuses the fused lookup's location output —
    one kernel pass, one tombstone scatter, never a second probe (the jnp
    ``twochoice_delete`` re-gathers both rows to find the slot again).

    Caller contract: ``mask`` is winner-filtered.  Returns (tstate', ok[Q]).
    """
    b, w = tkey.shape
    found, _val, loc = twochoice_lookup(tkey, tval, tstate, rows_a, rows_b,
                                        keys, interpret=interpret)
    ok = mask & found
    tstate2 = tstate.reshape(-1).at[jnp.where(ok, loc, b * w)].set(
        TOMB, mode="drop").reshape(b, w)
    return tstate2, ok


# ---------------------------------------------------------------------------
# twochoice rebuild-epoch ops: ONE sort + ONE probe2-style pallas_call
# ---------------------------------------------------------------------------

def _tc_probe2_run(old_t, new_t, hazard_key, hazard_val, hazard_live,
                   rows_a_old, rows_b_old, rows_a_new, rows_b_new, keys,
                   interpret: bool, nres_cap: int = NRES_CAP):
    """Shared prep + launch for the fused twochoice rebuild-epoch ops: the
    2Q entry expansion (each query's two row choices, paired old/new), ONE
    argsort keyed on the OLD row, the two-level resident map for the new
    table's row-blocks, and ONE ``tc_probe2`` pallas_call.  Returns the
    per-entry kernel outputs unsorted back to entry order."""
    b_old, w = old_t[0].shape
    b_new = new_t[0].shape[0]
    slab_r = _tc_rowslab(w)
    old_p = _tc_pad_rows(old_t, b_old, slab_r)
    new_p = _tc_pad_rows(new_t, b_new, slab_r)

    orow = jnp.concatenate([rows_a_old, rows_b_old])
    nrow = jnp.concatenate([rows_a_new, rows_b_new])
    qk2 = jnp.concatenate([keys, keys])
    e = orow.shape[0]
    order = jnp.argsort(orow)
    epad = -(-e // QT) * QT
    ors, nrs, qks = _sort_pad_queries(order, epad, orow, nrow, qk2)
    tiles = epad // QT
    obase = jnp.minimum(
        (ors.reshape(tiles, QT)[:, 0] // slab_r).astype(I32),
        old_p[0].shape[0] // slab_r - 2)
    nblocks_new = new_p[0].shape[0] // slab_r
    nres = min(nres_cap, nblocks_new - 1)
    slab2 = jnp.concatenate([
        obase[None], _resident_blockmap(nrs // slab_r, tiles, nblocks_new,
                                        nres)])

    outs = tc_probe2_tiles(old_p, new_p, hazard_key, hazard_val,
                           hazard_live.astype(I32), ors, nrs, qks, slab2,
                           interpret=interpret)
    unsorted = tuple(jnp.zeros((e,), o.dtype).at[order].set(o[:e])
                     for o in outs)
    return unsorted


def _tc_ordered_combine(outs, hazard_key, hazard_val, q: int):
    """Recombine the per-entry probe2 components into per-query ordered
    results (a-row priority within each table, old > hazard > new across
    them).  Returns (f_old, v_old, l_old, f_hz, hz_idx, v_hz, f_new, v_new,
    l_new, complete)."""
    f_o, v_o, l_o, c_o, hz, f_n, v_n, l_n, c_n = outs
    fo = f_o[:q] | f_o[q:]
    vo = jnp.where(f_o[:q], v_o[:q], v_o[q:])
    lo = jnp.where(f_o[:q], l_o[:q], l_o[q:])
    co = c_o[:q] & c_o[q:]              # absence needs BOTH rows covered
    hzq = hz[:q]                        # both entries carry the same key
    f_hz = hzq >= 0
    v_hz = jnp.take(hazard_val, jnp.clip(hzq, 0, hazard_key.shape[0] - 1))
    fn = f_n[:q] | f_n[q:]
    vn = jnp.where(f_n[:q], v_n[:q], v_n[q:])
    ln = jnp.where(f_n[:q], l_n[:q], l_n[q:])
    cn = c_n[:q] & c_n[q:]
    complete = co & (fo | f_hz | cn)
    return fo, vo, lo, f_hz, hzq, v_hz, fn, vn, ln, complete


@partial(jax.jit, static_argnames=("interpret", "nres_cap"))
def twochoice_ordered_lookup(old_t, new_t, hazard_key, hazard_val,
                             hazard_live, rows_a_old, rows_b_old,
                             rows_a_new, rows_b_new, qkey, *,
                             interpret: bool = True,
                             nres_cap: int = NRES_CAP):
    """FUSED twochoice rebuild-epoch lookup: ONE argsort (the 2Q entry batch
    keyed on the old table's row index) + ONE pallas_call emit the
    Lemma-4.1-ordered result — previously this composed TWO fused
    single-table passes around a separate hazard compare.  Queries the
    kernel could not determine (either row's window escaped) fall back to
    the jnp oracle (gated — free when nothing escapes).

    Returns (found[Q], val[Q])."""
    q = qkey.shape[0]
    outs = _tc_probe2_run(old_t, new_t, hazard_key, hazard_val, hazard_live,
                          rows_a_old, rows_b_old, rows_a_new, rows_b_new,
                          qkey, interpret, nres_cap)
    (fo, vo, _lo, f_hz, _hzq, v_hz, fn, vn, _ln,
     complete) = _tc_ordered_combine(outs, hazard_key, hazard_val, q)
    found = (fo | f_hz | fn) & complete
    val = jnp.where(
        complete,
        jnp.where(fo, vo, jnp.where(f_hz, v_hz, jnp.where(fn, vn, 0))), 0)

    need = ~complete

    def fallback(fv):
        f0, v0 = fv
        fa, va, _ = ref.tc_row_lookup_ref(*old_t, rows_a_old, qkey)
        fb, vb, _ = ref.tc_row_lookup_ref(*old_t, rows_b_old, qkey)
        f_oldr, v_oldr = fa | fb, jnp.where(fa, va, vb)
        eq = (qkey[:, None] == hazard_key[None, :]) & hazard_live[None, :]
        fh = eq.any(-1)
        vh = jnp.take(hazard_val, jnp.argmax(eq, axis=-1))
        fna, vna, _ = ref.tc_row_lookup_ref(*new_t, rows_a_new, qkey)
        fnb, vnb, _ = ref.tc_row_lookup_ref(*new_t, rows_b_new, qkey)
        f_newr, v_newr = fna | fnb, jnp.where(fna, vna, vnb)
        fb_f = f_oldr | fh | f_newr
        fb_v = jnp.where(f_oldr, v_oldr,
                         jnp.where(fh, vh, jnp.where(f_newr, v_newr, 0)))
        return jnp.where(need, fb_f, f0), jnp.where(need, fb_v, v0)

    return jax.lax.cond(need.any(), fallback, lambda fv: fv, (found, val))


@partial(jax.jit, static_argnames=("interpret", "nres_cap"))
def twochoice_ordered_delete(old_t, new_t, hazard_key, hazard_val,
                             hazard_live, rows_a_old, rows_b_old,
                             rows_a_new, rows_b_new, keys, mask, *,
                             interpret: bool = True,
                             nres_cap: int = NRES_CAP):
    """FUSED twochoice rebuild-epoch delete (paper Alg. 5): the SAME single
    probe2-style pass as the ordered lookup resolves old-slot / hazard-index
    / new-slot, then three scatters land the tombstones and the hazard kill.

    Caller contract: ``mask`` is winner-filtered.  Returns
    (old_state', new_state', hazard_live', ok[Q])."""
    b_old, w = old_t[0].shape
    b_new = new_t[0].shape[0]
    ch = hazard_key.shape[0]
    q = keys.shape[0]
    outs = _tc_probe2_run(old_t, new_t, hazard_key, hazard_val, hazard_live,
                          rows_a_old, rows_b_old, rows_a_new, rows_b_new,
                          keys, interpret, nres_cap)
    (fo, _vo, lo, f_hz, hzq, _vhz, fn, _vn, ln,
     complete) = _tc_ordered_combine(outs, hazard_key, hazard_val, q)

    # ordered landing: old hit > hazard hit > new hit.  An old hit is
    # trusted even when ``complete`` is False (priority already determined);
    # such queries are excluded from the fallback so they cannot double-
    # delete a second instance downstream.
    ok_old = mask & fo
    ok_hz = mask & complete & ~fo & f_hz
    ok_new = mask & complete & ~fo & ~f_hz & fn

    old_state = old_t[2].reshape(-1).at[
        jnp.where(ok_old, lo, b_old * w)].set(TOMB, mode="drop").reshape(
        b_old, w)
    new_state = new_t[2].reshape(-1).at[
        jnp.where(ok_new, ln, b_new * w)].set(TOMB, mode="drop").reshape(
        b_new, w)
    kill = jnp.zeros_like(hazard_live).at[
        jnp.where(ok_hz, hzq, ch)].set(True, mode="drop")
    hz_live = hazard_live & ~kill
    ok = ok_old | ok_hz | ok_new

    need = mask & ~fo & ~complete

    def fallback(op):
        os_, ns_, hl_, ok0 = op
        fb_os, ok_o = ref.tc_delete_ref(old_t[0], old_t[1], os_,
                                        rows_a_old, rows_b_old, keys, need)
        pend = need & ~ok_o
        eq = (keys[:, None] == hazard_key[None, :]) & hl_[None, :]
        hz_hit = eq.any(-1) & pend
        kill2 = jnp.zeros_like(hl_).at[
            jnp.where(hz_hit, jnp.argmax(eq, axis=-1), ch)].set(
            True, mode="drop")
        fb_ns, ok_n = ref.tc_delete_ref(new_t[0], new_t[1], ns_,
                                        rows_a_new, rows_b_new, keys,
                                        pend & ~hz_hit)
        return fb_os, fb_ns, hl_ & ~kill2, ok0 | ok_o | hz_hit | ok_n

    old_state, new_state, hz_live, ok = jax.lax.cond(
        need.any(), fallback, lambda op: op,
        (old_state, new_state, hz_live, ok))
    return old_state, new_state, hz_live, ok


# ---------------------------------------------------------------------------
# chain: segment-window ops over the arena-sorted node layout
# ---------------------------------------------------------------------------
#
# The chain arena is kept bucket-sorted and tombstone-compacted by
# ``chain_compact_fused``: bucket b's nodes occupy [bstart[b],
# bstart[b]+blen[b]), so a chain probe is the same slab-window reduction as
# a linear probe with h0 = bstart[b] and the segment length as the
# termination bound.  Nodes inserted since the last compaction form a
# contiguous DIRTY tail resolved by a dense window compare (static
# ``DIRTY_CAP`` window — the hazard-buffer treatment); a tail grown past the
# window escapes to the pointer-chasing jnp reference (``ref.chain_*_ref``)
# via the same gated-fallback pattern as every other fused op.  Argument
# convention: ``arena = (akey, aval, astate)``, ``links = (anext, heads)``
# (consumed only by the fallback), ``seg = (bstart, blen, sorted_upto,
# dirty)``.

def _chain_dirty_window(arena, sorted_upto, dirty, qkey,
                        dirty_cap: int = DIRTY_CAP):
    """Dense compare of the query batch against the arena's dirty tail.

    The window is the static-size slice [base, base + size) with
    ``base = min(sorted_upto, N - size)`` — clamping keeps the slice in
    bounds while still covering the whole tail whenever ``dirty`` fits.
    Positions below ``sorted_upto`` (clamp overlap with the sorted region)
    are excluded; the kernel owns those.  Returns (found, val, loc_abs,
    covered) with ``covered`` a scalar: False iff the tail outgrew the
    window and absence can no longer be proven here.
    """
    akey, aval, astate = arena
    n = akey.shape[0]
    size = min(dirty_cap, n)
    base = jnp.minimum(sorted_upto, n - size).astype(I32)
    wk = jax.lax.dynamic_slice(akey, (base,), (size,))
    wv = jax.lax.dynamic_slice(aval, (base,), (size,))
    ws = jax.lax.dynamic_slice(astate, (base,), (size,))
    pos = base + jnp.arange(size, dtype=I32)
    valid = (ws == LIVE) & (pos >= sorted_upto)
    eq = (qkey[:, None] == wk[None, :]) & valid[None, :]
    hit = eq.any(-1)
    i = jnp.argmax(eq, axis=-1).astype(I32)
    val = jnp.where(hit, jnp.take(wv, i), 0)
    loc = jnp.where(hit, base + i, -1)
    covered = sorted_upto + dirty <= base + size
    return hit, val, loc, covered


def _chain_run(arena, seg, bq, qkey, max_chain: int, interpret: bool,
               dirty_cap: int = DIRTY_CAP):
    """Shared prep + launch for the single-arena chain ops: the ONE sort
    (stable argsort on the bucket — ``bstart`` is nondecreasing in the
    bucket, so segment starts sort with it, and the insert path reuses the
    same order for its head relink), the ONE chain-probe pallas_call, and
    the dirty-tail window merge.  Returns (order, sorted (keys, buckets),
    (found, val, loc_physical, need)) — all in sorted coordinates."""
    bstart, blen, sorted_upto, dirty = seg
    n = arena[0].shape[0]
    q = qkey.shape[0]
    h0 = bstart[bq]
    qlen = blen[bq]
    tk, tv, ts = _pad_table(arena, n, max_chain)

    order = jnp.argsort(bq)
    qpad = -(-q // QT) * QT
    h0s, qls, qks, bqs = _sort_pad_queries(order, qpad, h0, qlen, qkey, bq)
    tiles = qpad // QT
    slab_base = _tile_base(h0s, tiles, tk.shape[0])

    f_s, v_s, l_s, c_s = chain_probe_tiles(
        tk, tv, ts, h0s, qls, qks, slab_base, max_probes=max_chain,
        interpret=interpret)

    fw, vw, lw, covered = _chain_dirty_window(arena, sorted_upto, dirty, qks,
                                              dirty_cap)
    found_s = f_s | fw
    val_s = jnp.where(f_s, v_s, vw)
    loc_s = jnp.where(f_s, l_s % n, lw)   # physical node index (-1 = absent)
    # unresolved: not found anywhere AND absence not proven (segment window
    # escaped / segment longer than max_chain / dirty tail past the window)
    need_s = ~found_s & (~c_s | ~covered)
    return order, (qks, bqs), (found_s, val_s, loc_s, need_s)


@partial(jax.jit, static_argnames=("max_chain", "interpret", "dirty_cap"))
def chain_lookup_fused(arena, links, seg, bq, qkey, *, max_chain: int = 64,
                       interpret: bool = True, dirty_cap: int = DIRTY_CAP):
    """Fused chain lookup: ONE argsort + ONE chain-probe pallas_call over
    the bucket-sorted segments, a dense dirty-tail window, and the
    pointer-chasing jnp reference as the gated fallback for unresolved
    queries.  Returns (found[Q], val[Q], loc[Q] node index or -1) — ``loc``
    is reused by the fused delete so deleting never probes twice."""
    q = qkey.shape[0]
    order, (qks, bqs), (found_s, val_s, loc_s, need_s) = _chain_run(
        arena, seg, bq, qkey, max_chain, interpret, dirty_cap)

    def fallback(fvl):
        f0, v0, l0 = fvl
        fb_f, fb_v, fb_l = ref.chain_lookup_ref(*arena, *links, bqs, qks,
                                                max_chain)
        return (jnp.where(need_s, fb_f, f0), jnp.where(need_s, fb_v, v0),
                jnp.where(need_s, fb_l, l0))

    found_s, val_s, loc_s = jax.lax.cond(need_s.any(), fallback, lambda x: x,
                                         (found_s, val_s, loc_s))

    found = jnp.zeros((q,), jnp.bool_).at[order].set(found_s[:q])
    val = jnp.zeros((q,), I32).at[order].set(val_s[:q])
    loc = jnp.full((q,), -1, I32).at[order].set(loc_s[:q])
    return found, val, loc


@partial(jax.jit, static_argnames=("max_chain", "interpret", "dirty_cap"))
def chain_delete_fused(arena, links, seg, bq, keys, mask, *,
                       max_chain: int = 64, interpret: bool = True,
                       dirty_cap: int = DIRTY_CAP):
    """Fused chain delete: the location-emitting probe run + ONE tombstone
    scatter (logical deletion; compaction reclaims).  Caller contract:
    ``mask`` is winner-filtered.  Returns (astate', ok[Q])."""
    n = arena[0].shape[0]
    q = keys.shape[0]
    qpad = -(-q // QT) * QT
    order, (qks, bqs), (found_s, _val_s, loc_s, need_s) = _chain_run(
        arena, seg, bq, keys, max_chain, interpret, dirty_cap)
    qms = _pad_to(mask[order], qpad, fill=False)

    ok_s = qms & found_s
    astate2 = arena[2].at[jnp.where(ok_s, loc_s, n)].set(TOMB, mode="drop")

    need = qms & need_s

    def fallback(op):
        s, ok = op
        fb_s, fb_ok = ref.chain_delete_ref(arena[0], arena[1], s, *links,
                                           bqs, qks, need, max_chain)
        return fb_s, ok | fb_ok

    astate2, ok_s = jax.lax.cond(need.any(), fallback, lambda op: op,
                                 (astate2, ok_s))

    ok = jnp.zeros((q,), jnp.bool_).at[order].set(ok_s[:q])
    return astate2, ok


@partial(jax.jit, static_argnames=("max_chain", "interpret", "dirty_cap"))
def chain_insert_fused(arena, links, seg, free_stack, free_top, bq, keys,
                       vals, mask, *, max_chain: int = 64,
                       interpret: bool = True, dirty_cap: int = DIRTY_CAP):
    """Fused chain insert: the presence probe (kernel + dirty window +
    gated pointer fallback) and the head relink share the SAME stable sort
    keyed on the bucket, so the whole op is ONE argsort + ONE pallas_call.
    New nodes are allocated from the free-stack tail (positions ascend, so
    they extend the dirty window) and linked at their buckets' heads in
    original-index order — the identical linearization, node placement, and
    pointer structure as ``buckets.chain_insert``.

    Caller contract: ``mask`` is winner-filtered.  Returns
    (akey', aval', astate', anext', heads', free_top', ok[Q]).
    """
    akey, aval, astate = arena
    anext, heads = links
    n = akey.shape[0]
    nb = heads.shape[0]
    q = keys.shape[0]
    order, (qks, bqs), (found_s, _v, _l, need_s) = _chain_run(
        arena, seg, bq, keys, max_chain, interpret, dirty_cap)

    def fb_present(p):
        fb_f, _, _ = ref.chain_lookup_ref(akey, aval, astate, anext, heads,
                                          bqs, qks, max_chain)
        return jnp.where(need_s, fb_f, p)

    present_s = jax.lax.cond(need_s.any(), fb_present, lambda p: p, found_s)
    present = jnp.zeros((q,), jnp.bool_).at[order].set(present_s[:q])

    # allocation: identical linearization to buckets.chain_insert (want-rank
    # in original order pops ascending arena positions)
    want = mask & ~present
    rank = jnp.cumsum(want.astype(I32)) - 1
    can = want & (rank < free_top)
    node = free_stack[jnp.where(can, free_top - 1 - rank, 0)]
    wnode = jnp.where(can, node, n)
    akey2 = akey.at[wnode].set(keys, mode="drop")
    aval2 = aval.at[wnode].set(vals, mode="drop")
    astate2 = astate.at[wnode].set(LIVE, mode="drop")

    # head relink in the SAME sorted order (bucket asc, original index asc):
    # each inserted node chains to the NEXT inserted node of its bucket
    # (suffix-min scan — no second sort), the last one to the old head, and
    # the FIRST inserted node of each bucket becomes the new head
    # (prefix-max scan).
    can_s = can[order]
    node_s = node[order]
    b_s = bqs[:q]
    pos = jnp.arange(q, dtype=I32)
    w = jnp.where(can_s, pos, q)
    m = jnp.flip(jax.lax.cummin(jnp.flip(
        jnp.concatenate([w[1:], jnp.full((1,), q, I32)]))))
    nxt_idx = jnp.minimum(m, q - 1)
    same_b = (m < q) & (b_s[nxt_idx] == b_s)
    nxt_node = jnp.where(same_b, node_s[nxt_idx], heads[b_s])
    anext2 = anext.at[jnp.where(can_s, node_s, n)].set(nxt_node, mode="drop")
    wp = jnp.where(can_s, pos, -1)
    pm = jax.lax.cummax(jnp.concatenate([jnp.full((1,), -1, I32), wp[:-1]]))
    prev_idx = jnp.maximum(pm, 0)
    is_first = can_s & ((pm < 0) | (b_s[prev_idx] != b_s))
    heads2 = heads.at[jnp.where(is_first, b_s, nb)].set(node_s, mode="drop")

    free_top2 = free_top - jnp.sum(can.astype(I32))
    return akey2, aval2, astate2, anext2, heads2, free_top2, can


@partial(jax.jit, static_argnames=("nbuckets",))
def chain_compact_fused(akey, aval, astate, bq_nodes, *, nbuckets: int):
    """The arena-sorted compaction pass: ONE segmented sort keyed on
    (bucket, arena index) with dead nodes pushed past every bucket, then the
    compaction gather (the sort's permutation IS the `_extract_kernel`-style
    rank compaction, applied globally), per-bucket (start, len) offsets via
    a histogram + exclusive cumsum, and a vectorized pointer rebuild so the
    jnp reference paths stay valid (node i chains to i + 1 within its
    bucket).  Tombstoned/migrated nodes are physically reclaimed — the
    batched analogue of the paper's deferred call_rcu free.

    Returns (akey', aval', astate', anext', heads', free_stack', free_top',
    bstart, blen, sorted_upto).
    """
    n = akey.shape[0]
    idx = jnp.arange(n, dtype=I32)
    live = astate == LIVE
    sortkey = jnp.where(live, bq_nodes, nbuckets)
    order = jnp.argsort(sortkey)          # stable: (bucket, arena index)
    ls = live[order]
    akey2 = jnp.where(ls, akey[order], 0)
    aval2 = jnp.where(ls, aval[order], 0)
    astate2 = jnp.where(ls, LIVE, EMPTY).astype(I32)
    lcount = jnp.sum(live.astype(I32))
    counts = jnp.zeros((nbuckets,), I32).at[
        jnp.where(live, bq_nodes, nbuckets)].add(1, mode="drop")
    bstart = jnp.concatenate(
        [jnp.zeros((1,), I32), jnp.cumsum(counts)[:-1].astype(I32)])
    sb = sortkey[order]
    chain_on = ls & jnp.concatenate([sb[1:] == sb[:-1],
                                     jnp.zeros((1,), bool)])
    anext2 = jnp.where(chain_on, idx + 1, -1)
    heads2 = jnp.where(counts > 0, bstart, -1)
    free_stack2 = n - 1 - idx
    free_top2 = n - lcount
    return (akey2, aval2, astate2, anext2, heads2, free_stack2, free_top2,
            bstart, counts, lcount)


def _chain_probe2_run(old_arena, old_seg, new_arena, new_seg, hazard_key,
                      hazard_val, hazard_live, bq_old, bq_new, keys,
                      max_chain: int, interpret: bool,
                      nres_cap: int = NRES_CAP, dirty_cap: int = DIRTY_CAP):
    """Shared prep + launch for the fused chain rebuild-epoch ops: the ONE
    argsort (keyed on the old arena's segment starts), the two-level tile
    map for the new arena's blocks, ONE chain_probe2 pallas_call, and the
    dirty-tail window merges for BOTH arenas.  Returns (order, sorted
    (keys, old buckets, new buckets), per-query Lemma-4.1 components)."""
    n_old = old_arena[0].shape[0]
    n_new = new_arena[0].shape[0]
    q = keys.shape[0]
    old_p = _pad_table(old_arena, n_old, max_chain)
    new_p = _pad_table(new_arena, n_new, max_chain)
    h0o = old_seg[0][bq_old]
    qlo = old_seg[1][bq_old]
    h0n = new_seg[0][bq_new]
    qln = new_seg[1][bq_new]

    order = jnp.argsort(h0o)
    qpad = -(-q // QT) * QT
    h0os, qlos, h0ns, qlns, qks, bqos, bqns = _sort_pad_queries(
        order, qpad, h0o, qlo, h0n, qln, keys, bq_old, bq_new)
    tiles = qpad // QT
    nblocks_new = new_p[0].shape[0] // SLAB
    nres = min(nres_cap, nblocks_new - 1)
    slab2 = jnp.concatenate([
        _tile_base(h0os, tiles, old_p[0].shape[0])[None],
        _resident_blockmap(h0ns // SLAB, tiles, nblocks_new, nres)])

    (f_o, v_o, l_o, c_o, hz, f_n, v_n, l_n, c_n) = chain_probe2_tiles(
        old_p, new_p, hazard_key, hazard_val, hazard_live.astype(I32),
        h0os, qlos, h0ns, qlns, qks, slab2, max_probes=max_chain,
        interpret=interpret)

    fwo, vwo, lwo, cov_o = _chain_dirty_window(old_arena, old_seg[2],
                                               old_seg[3], qks, dirty_cap)
    fwn, vwn, lwn, cov_n = _chain_dirty_window(new_arena, new_seg[2],
                                               new_seg[3], qks, dirty_cap)
    fo = f_o | fwo
    vo = jnp.where(f_o, v_o, vwo)
    lo = jnp.where(f_o, l_o % n_old, lwo)
    f_hz = hz >= 0
    v_hz = jnp.take(hazard_val, jnp.clip(hz, 0, hazard_key.shape[0] - 1))
    fn = f_n | fwn
    vn = jnp.where(f_n, v_n, vwn)
    ln = jnp.where(f_n, l_n % n_new, lwn)
    co = c_o & cov_o
    cn = c_n & cov_n
    # ordered-check refinement: an old hit settles the query outright (any
    # hit is real — windows and kernel both only report LIVE matches); absent
    # from old is only trusted with full old coverage, after which the dense
    # hazard compare and the new side (hit, or proven absent) settle it.
    complete = fo | (co & (f_hz | fn | cn))
    return order, (qks, bqos, bqns), (fo, vo, lo, f_hz, hz, v_hz, fn, vn,
                                      ln, complete)


@partial(jax.jit, static_argnames=("max_chain", "interpret", "nres_cap",
                                   "dirty_cap"))
def chain_ordered_lookup(old_arena, old_links, old_seg, new_arena, new_links,
                         new_seg, hazard_key, hazard_val, hazard_live,
                         bq_old, bq_new, qkey, *, max_chain: int = 64,
                         interpret: bool = True, nres_cap: int = NRES_CAP,
                         dirty_cap: int = DIRTY_CAP):
    """FUSED chain rebuild-epoch lookup: ONE argsort + ONE chain_probe2
    pallas_call emit the Lemma-4.1-ordered result (old arena -> hazard
    buffer -> new arena), with the two-level tile map keeping a grown new
    arena resident and both arenas' dirty tails merged by dense windows.
    Unresolved queries fall back to the pointer-chasing jnp ordered check
    (gated — free when nothing escapes).  Returns (found[Q], val[Q])."""
    q = qkey.shape[0]
    order, (qks, bqos, bqns), comps = _chain_probe2_run(
        old_arena, old_seg, new_arena, new_seg, hazard_key, hazard_val,
        hazard_live, bq_old, bq_new, qkey, max_chain, interpret,
        nres_cap, dirty_cap)
    (fo, vo, _lo, f_hz, _hz, v_hz, fn, vn, _ln, complete) = comps
    found_s = (fo | f_hz | fn) & complete
    val_s = jnp.where(
        complete,
        jnp.where(fo, vo, jnp.where(f_hz, v_hz, jnp.where(fn, vn, 0))), 0)

    need = ~complete

    def fallback(fv):
        f0, v0 = fv
        fb_f, fb_v = ref.chain_ordered_lookup_ref(
            old_arena, old_links, new_arena, new_links, hazard_key,
            hazard_val, hazard_live, bqos, bqns, qks, max_chain)
        return jnp.where(need, fb_f, f0), jnp.where(need, fb_v, v0)

    found_s, val_s = jax.lax.cond(need.any(), fallback, lambda fv: fv,
                                  (found_s, val_s))

    found = jnp.zeros((q,), jnp.bool_).at[order].set(found_s[:q])
    val = jnp.zeros((q,), I32).at[order].set(val_s[:q])
    return found, val


@partial(jax.jit, static_argnames=("max_chain", "interpret", "nres_cap",
                                   "dirty_cap"))
def chain_ordered_delete(old_arena, old_links, old_seg, new_arena, new_links,
                         new_seg, hazard_key, hazard_val, hazard_live,
                         bq_old, bq_new, keys, mask, *, max_chain: int = 64,
                         interpret: bool = True, nres_cap: int = NRES_CAP,
                         dirty_cap: int = DIRTY_CAP):
    """FUSED chain rebuild-epoch delete (paper Alg. 5): the SAME single
    chain_probe2 pass resolves old-node / hazard-index / new-node, then
    three scatters land the tombstones and the hazard kill.

    Caller contract: ``mask`` is winner-filtered.  Returns
    (old_astate', new_astate', hazard_live', ok[Q])."""
    n_old = old_arena[0].shape[0]
    n_new = new_arena[0].shape[0]
    ch = hazard_key.shape[0]
    q = keys.shape[0]
    qpad = -(-q // QT) * QT
    order, (qks, bqos, bqns), comps = _chain_probe2_run(
        old_arena, old_seg, new_arena, new_seg, hazard_key, hazard_val,
        hazard_live, bq_old, bq_new, keys, max_chain, interpret,
        nres_cap, dirty_cap)
    (fo, _vo, lo, f_hz, hz, _vhz, fn, _vn, ln, complete) = comps
    qms = _pad_to(mask[order], qpad, fill=False)

    # ordered landing: old hit > hazard hit > new hit.  An old hit is
    # trusted even when ``complete`` is False (priority already determined);
    # such queries are excluded from the fallback so they cannot double-
    # delete a second instance downstream.
    ok_old = qms & fo
    ok_hz = qms & complete & ~fo & f_hz
    ok_new = qms & complete & ~fo & ~f_hz & fn

    old_state = old_arena[2].at[
        jnp.where(ok_old, lo, n_old)].set(TOMB, mode="drop")
    new_state = new_arena[2].at[
        jnp.where(ok_new, ln, n_new)].set(TOMB, mode="drop")
    kill = jnp.zeros_like(hazard_live).at[
        jnp.where(ok_hz, hz, ch)].set(True, mode="drop")
    hz_live = hazard_live & ~kill
    ok_s = ok_old | ok_hz | ok_new

    need = qms & ~fo & ~complete

    def fallback(op):
        os_, ns_, hl_, ok = op
        fb_os, ok_o = ref.chain_delete_ref(old_arena[0], old_arena[1], os_,
                                           *old_links, bqos, qks, need,
                                           max_chain)
        pend = need & ~ok_o
        eq = (qks[:, None] == hazard_key[None, :]) & hl_[None, :]
        hz_hit = eq.any(-1) & pend
        kill2 = jnp.zeros_like(hl_).at[
            jnp.where(hz_hit, jnp.argmax(eq, axis=-1), ch)].set(
            True, mode="drop")
        fb_ns, ok_n = ref.chain_delete_ref(new_arena[0], new_arena[1], ns_,
                                           *new_links, bqns, qks,
                                           pend & ~hz_hit, max_chain)
        return fb_os, fb_ns, hl_ & ~kill2, ok | ok_o | hz_hit | ok_n

    old_state, new_state, hz_live, ok_s = jax.lax.cond(
        need.any(), fallback, lambda op: op,
        (old_state, new_state, hz_live, ok_s))

    ok = jnp.zeros((q,), jnp.bool_).at[order].set(ok_s[:q])
    return old_state, new_state, hz_live, ok
