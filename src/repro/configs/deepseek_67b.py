"""deepseek-67b [dense]: llama-architecture, GQA kv=8
[arXiv:2401.02954; hf]. long_500k SKIPPED (pure full attention)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=102400,
    rope_theta=10_000.0, fsdp=True,
)

def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, vocab_size=512,
                         dtype="float32", attn_chunk=32, loss_chunk=32,
                         fsdp=False)
