"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONs.

    PYTHONPATH=src:. python -m benchmarks.report > /tmp/tables.md
"""
from __future__ import annotations

import glob
import json
import os

RES = os.path.join(os.path.dirname(__file__), "results", "dryrun")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k", "service"]
ARCH_ORDER = ["zamba2-1.2b", "gemma3-27b", "deepseek-67b", "qwen3-8b",
              "gemma2-2b", "qwen2-vl-2b", "rwkv6-3b", "arctic-480b",
              "llama4-scout-17b-a16e", "hubert-xlarge", "dhash-paper"]


def load(mesh):
    out = {}
    for f in glob.glob(os.path.join(RES, f"{mesh}_*.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if b < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table():
    single, multi = load("single"), load("multi")
    print("| arch | shape | 16x16 | 2x16x16 | per-chip bytes (args+temp) | "
          "collectives/step (per chip) |")
    print("|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = single.get((a, s))
            m = multi.get((a, s))
            if r is None:
                continue
            if r["status"] == "skip":
                print(f"| {a} | {s} | skip | skip | — | {r['reason']} |")
                continue
            mem = r.get("memory", {})
            per_chip = (mem.get("argument_size_in_bytes", 0)
                        + mem.get("temp_size_in_bytes", 0)) / 256
            cc = r["cost"]["coll_counts"]
            cstr = ", ".join(f"{k}:{int(v)}" for k, v in cc.items() if v)
            ok_m = "ok" if (m and m["status"] == "ok") else (m or {}).get("status", "?")
            print(f"| {a} | {s} | ok ({r['compile_s']:.0f}s) | {ok_m} "
                  f"({(m or {}).get('compile_s', 0):.0f}s) | "
                  f"{fmt_bytes(per_chip)} | {cstr or '—'} |")


def roofline_table():
    single = load("single")
    print("| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
          "| bottleneck | MODEL_FLOPS | useful (6ND/HLO) | MFU@roofline | "
          "what moves the dominant term |")
    print("|---|---|---|---|---|---|---|---|---|---|" .replace("|---|---|---|---|---|---|---|---|---|---|", "|---|---|---|---|---|---|---|---|---|"))
    notes = {
        ("rwkv6-3b", "train_4k"): "chunk the wkv recurrence (stash S/chunk states) — §Perf cell 1",
        ("gemma3-27b", "train_4k"): "fuse qkv + gate/up projections (fewer bwd dx ARs) — §Perf cell 2",
        ("dhash-paper", "service"): "cap routing buffers at c*Q/S — §Perf cell 3",
        ("arctic-480b", "train_4k"): "score-buffer traffic: flash-fused attention kernel on TPU",
        ("deepseek-67b", "train_4k"): "same qkv/gate-up fusions as gemma3 apply",
    }
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = single.get((a, s))
            if r is None:
                continue
            if r["status"] == "skip":
                print(f"| {a} | {s} | — | — | — | skip | — | — | — | {r['reason']} |")
                continue
            rl = r["roofline"]
            note = notes.get((a, s), "reduce materialized activation buffers (fusion)")
            mf = rl["model_flops"]
            print(f"| {a} | {s} | {rl['t_compute']:.3f} | {rl['t_memory']:.3f} | "
                  f"{rl['t_collective']:.3f} | {rl['bottleneck']} | "
                  f"{mf:.2e} | {rl['useful_flop_frac']:.3f} | {rl['mfu']:.4f} | {note} |")


if __name__ == "__main__":
    print("### §Dry-run (compile proof, both meshes)\n")
    dryrun_table()
    print("\n### §Roofline (single-pod 16x16, per step)\n")
    roofline_table()
