"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; everything else
sees the real single-device CPU).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)} — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py "
            "sets this itself)")
    return jax.sharding.Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_host_mesh(model_parallel: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (smoke tests, examples)."""
    devs = jax.devices()
    m = min(model_parallel, len(devs))
    d = len(devs) // m
    return jax.sharding.Mesh(np.asarray(devs[: d * m]).reshape(d, m),
                             ("data", "model"))
