"""Structural invariants: no two live slots share a key, the jitted
same-shape epoch swap, and conservation of items across the full protocol."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import buckets, dhash


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16),
       nkeys=st.integers(1, 200),
       ndel=st.integers(0, 100))
def test_linear_no_duplicate_live_keys(seed, nkeys, ndel):
    """The claim-round batched insert must never produce two LIVE slots with
    the same key, under any interleaving of inserts and deletes."""
    rng = np.random.default_rng(seed)
    t = buckets.linear_make(512, __import__("repro.core.hashing", fromlist=["fresh"]).fresh("mix32", seed), max_probes=64)
    keys = jnp.asarray(rng.integers(1, 500, nkeys).astype(np.int32))
    t, _ = jax.jit(buckets.linear_insert)(t, keys, keys, jnp.ones(nkeys, bool))
    if ndel:
        dk = jnp.asarray(rng.integers(1, 500, ndel).astype(np.int32))
        t, _ = jax.jit(buckets.linear_delete)(t, dk, jnp.ones(ndel, bool))
        t, _ = jax.jit(buckets.linear_insert)(t, dk, dk * 2, jnp.ones(ndel, bool))
    live = np.asarray(t.state) == 1
    lk = np.asarray(t.key)[live]
    assert len(lk) == len(np.unique(lk)), "duplicate live key"


def test_finish_same_shape_jitted_swap():
    """The fully-jitted epoch swap (same-capacity rebuild) is a no-op until
    done, then swaps tables and bumps the epoch — inside jit."""
    d = dhash.make("linear", capacity=128, chunk=128, seed=0)
    keys = jnp.arange(1, 51, dtype=jnp.int32)
    d, _ = jax.jit(dhash.insert)(d, keys, keys * 2)
    d = dhash.rebuild_start(d, seed=9)
    fin = jax.jit(dhash.finish_same_shape)
    d2 = fin(d)                       # not done yet -> unchanged epoch
    assert int(d2.epoch) == 0 and bool(d2.rebuilding)
    d2 = jax.jit(dhash.rebuild_chunk)(d2)
    d2 = jax.jit(dhash.rebuild_chunk)(d2)  # land any pending hazard
    d2 = fin(d2)
    assert int(d2.epoch) == 1 and not bool(d2.rebuilding)
    f, v = jax.jit(dhash.lookup)(d2, keys)
    assert bool(f.all()) and bool((v == keys * 2).all())


@pytest.mark.parametrize("backend", ["linear", "twochoice", "chain"])
def test_item_conservation_across_protocol(backend):
    """count_items is invariant across extract/land/finish (nothing is lost
    or duplicated by the hazard window)."""
    d = dhash.make(backend, capacity=256, chunk=16, seed=3)
    keys = jnp.arange(1, 101, dtype=jnp.int32)
    d, _ = jax.jit(dhash.insert)(d, keys, keys)
    d = dhash.rebuild_start(d, seed=5)
    step = jax.jit(dhash.rebuild_step)
    for _ in range(80):
        assert int(jax.device_get(dhash.count_items(d))) == 100
        if bool(jax.device_get(dhash.rebuild_done(d))):
            break
        d = step(d)
    d = dhash.rebuild_finish(d)
    assert int(jax.device_get(dhash.count_items(d))) == 100
