"""Process-level environment setup shared by every test.

Must run before jax creates its CPU client, which is why this lives in
conftest (imported by pytest ahead of any test module) and touches
os.environ before importing jax.

The full suite drives several hundred in-process XLA compilations, most
of them wrapping interpret-mode pallas kernels (i.e. host callbacks).
Under jaxlib 0.4.36's new CPU *thunk runtime* that combination is
fragile: deep into a single-process run the next compile of a
callback-carrying `lax.cond` segfaults inside `backend_compile` — the
same test passes in isolation, and the crash site moves to whichever
eager cond compiles next once the cumulative threshold is crossed.
Opting back into the legacy CPU runtime makes the whole suite stable.
Revisit when jaxlib is upgraded (the thunk runtime is the long-term
default and this flag will eventually disappear).
"""
from __future__ import annotations

import os

_FLAG = "--xla_cpu_use_thunk_runtime=false"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " +
                               _FLAG).strip()
