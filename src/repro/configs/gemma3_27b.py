"""gemma3-27b [dense]: 5:1 local:global attention, 1024-token window,
dual rope theta, 262k vocab [hf:google/gemma-3; unverified].
long_500k SKIPPED: global layers are full attention (DESIGN.md)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab_size=262144,
    block_pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024, rope_theta=10_000.0, rope_theta_global=1_000_000.0,
    embed_scale=True, fsdp=True,
)

def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, vocab_size=512, window=16,
                         dtype="float32", attn_chunk=32, loss_chunk=32,
                         fsdp=False)
