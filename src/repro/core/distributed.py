"""Distributed DHash: the table sharded over a mesh axis.

Ownership is by a *fixed* owner hash (never rebuilt): shard s owns key k iff
``owner_hash(k) % S == s``.  Rebuilds swap each shard's *local* hash function;
because every shard executes the same transition stream (SPMD), the epoch
swap is collectively synchronized for free — the multi-host analogue of the
paper's ``synchronize_rcu`` grace period.

Query routing is one all_to_all pair (there and back), the same dispatch
pattern as MoE token routing.  The send-buffer layout is a **two-pass
counting sort** (HashGraph's idiom): pass 1 histograms keys per owner and
ranks each key within its owner; pass 2 scatters keys into exactly-sized
per-owner segments of a ``[S, cap]`` buffer.  With a fixed per-owner cap
the exclusive prefix sum over the capped histogram is the affine map
``base[s] = s * cap`` — i.e. the row offsets of the 2-D buffer — so no
argsort is ever needed: the router contributes ZERO ``sort`` primitives
and the owner-grouped buffer feeds the fused kernels' own bucket sort
directly (a routed fused ``stack_lookup`` stays at ONE sort + ONE
pallas_call total, the same budget as an unrouted op).

``cap=None`` (baseline) uses cap=Q — overflow-proof even under a fully
adversarial key set (every key owned by one shard — the paper's collision
attack) at S x the wire bytes.  The capped path uses
``cap = ceil(c·Q/S)`` plus a **two-level spill slab**: keys past an
owner's cap are re-routed — in the SAME single pass — into ``spill_cap``
extra columns of the same buffer, shared across owners by global spill
rank (HashGraph's counting layout applied one level down: the exact
histogram already sizes the overflow region, so no second dispatch is
ever needed).  Because total spill over any batch is bounded by
``Q - cap`` (k overflowing owners spill at most ``Q - k*cap`` keys),
``spill_cap = Q - cap`` makes the capped layout overflow-PROOF; smaller
slabs (``route_spill_cap`` with a ``slack`` budget) trade width for an
exactly-accounted ``dropped`` count — keys beyond primary+slab are
reported per owner, never silently lost.  The cond-gated full-width
retry this replaces is gone from the contract: a spilling batch costs
the same ONE routed op as a balanced one.

These functions are written to be called INSIDE ``jax.shard_map`` with the
table sharded (one leaf-shard per device along ``axis``) and queries sharded
along their batch dim.  Every shard-local table op dispatches through the
``BucketBackend`` descriptor registry (core/backend.py), so any registered
backend — fused or jnp — shards without changes here.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import dhash, hashing

I32 = jnp.int32


def _axis_size(axis) -> int:
    """Static mesh-axis size, tolerant of the jax API move: ``lax.axis_size``
    arrived after 0.5; on older releases ``psum(1, axis)`` constant-folds to
    the same Python int."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return lax.psum(1, axis)


class Route(NamedTuple):
    """The routing layout of one batch: the [S, cap + spill_cap] send
    buffer (primary columns + shared spill-slab columns) plus the per-key
    coordinates that invert it, and exact overflow/drop accounting."""
    send: jax.Array      # [S, cap + spill_cap] keys: owner-grouped primary
                         # columns, then slab columns shared by spill rank
    smask: jax.Array     # [S, cap + spill_cap] bool: slot carries a key
    owner: jax.Array     # [Q] i32 owner of each key (batch order)
    rank: jax.Array      # [Q] i32 arrival rank within its owner (stable)
    kept: jax.Array      # [Q] bool: rank < cap (primary columns)
    overflow: jax.Array  # [S] i32 EXACT per-owner spill: max(hist - cap, 0)
    cap: int             # static primary width
    spill_cap: int       # static slab width
    spill_rank: jax.Array  # [Q] i32 global rank among spilled keys (stable;
                           # meaningless where ``kept``)
    served: jax.Array    # [Q] bool: kept | (spilled & spill_rank < spill_cap)
    slab_owner: jax.Array  # [spill_cap] i32 explicit owner id of each slab
                           # column (-1: column empty this batch)
    dropped: jax.Array   # [S] i32 EXACT per-owner keys beyond primary+slab


def route_cap(cap_factor: float, q: int, nshards: int) -> int:
    """The capped-dispatch buffer width ``cap = ceil(c·Q/S)``, clamped to
    [1, Q].  ``cap_factor <= 0`` means the overflow-proof full width.

    The ceil is taken on the full product ``c·Q/S`` (``math.ceil``, the one
    place this is computed) — truncating the float product to int first
    (the old ``int(c*q)`` idiom) understates the cap by 1 whenever the
    product carries a fractional part into the division."""
    if cap_factor <= 0:
        return q
    return min(q, max(1, math.ceil(cap_factor * q / nshards)))


def route_spill_cap(q: int, cap: int, slack: float | None = None) -> int:
    """Spill-slab width for a [Q] batch routed at ``cap`` per owner.

    Total spill over ANY batch is bounded by ``Q - cap``: if k owners
    overflow they keep ``k*cap`` keys in primary columns, spilling at most
    ``Q - k*cap <= Q - cap`` (k >= 1).  So the default (``slack=None``)
    returns ``Q - cap`` — an overflow-PROOF slab: every spilled key of
    every possible batch lands in the buffer and the router never drops.

    A ``slack`` budget in (0, 1) sizes a compact slab ``ceil(slack·Q)``
    instead (clamped to the overflow-proof bound): width shrinks to
    ``cap + slack·Q``, and keys whose global spill rank exceeds the slab
    are counted EXACTLY in ``Route.dropped`` — callers choosing a compact
    slab observe every key it cannot carry.  ``slack >= 1`` is the
    overflow-proof bound again; ``slack <= 0`` disables the slab (pure
    capped layout)."""
    worst = max(q - cap, 0)
    if slack is None:
        return worst
    if slack <= 0:
        return 0
    return min(worst, math.ceil(slack * q))


def _route(keys: jax.Array, owner: jax.Array, nshards: int,
           cap: int | None = None, spill_cap: int = 0) -> Route:
    """Group keys by owner into a [S, cap + spill_cap] send buffer — a
    two-level single-pass counting sort, no ``sort`` primitive:

    * pass 1: per-owner histogram + stable rank-within-owner via a running
      one-hot count (O(Q·S) vectorized work, the MoE dispatch idiom —
      cheap for mesh/tenant-scale S, and it removes the router's argsort
      from every routed op's budget), plus a global rank among spilled
      keys (one more cumsum) for the slab;
    * pass 2: ONE scatter places key i at column ``rank[i]`` of its
      owner's row if ``rank < cap`` (primary), else at column
      ``cap + spill_rank[i]`` (slab).  Slab columns are SHARED across
      owners by global spill rank — the exact histogram bounds total
      spill at ``Q - cap``, so ``spill_cap = Q - cap`` (the
      ``route_spill_cap`` default) carries every possible overflow —
      and each slab column's owner is recorded in ``slab_owner``.

    Primary and slab are concatenated columns of ONE buffer, so a routed
    op on a spilling batch costs exactly what a balanced batch costs —
    there is no second pass to retry into.  Keys beyond primary+slab
    (compact slabs only) are NOT silently zeroed: ``served`` marks every
    key the buffer carries, ``overflow[s] = max(hist[s] - cap, 0)`` counts
    spill exactly, and ``dropped[s]`` counts the slab's exact per-owner
    shortfall."""
    q = keys.shape[0]
    cap = q if cap is None else cap
    spill_cap = 0 if cap >= q else min(spill_cap, q - cap)
    owner = owner.astype(I32)
    onehot = (owner[:, None] == jnp.arange(nshards, dtype=I32)[None, :]
              ).astype(I32)
    hist = onehot.sum(axis=0)                                     # [S]
    rank = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                               owner[:, None], axis=1)[:, 0]      # [Q]
    kept = rank < cap
    spilled = ~kept
    spill_rank = jnp.cumsum(spilled.astype(I32)) - 1              # [Q]
    served = kept | (spilled & (spill_rank < spill_cap))
    # primary keys land at their owner rank, spilled keys at the shared
    # slab column for their global spill rank; anything past the slab
    # scatters out of bounds and mode="drop" discards it
    col = jnp.where(kept, rank, cap + spill_rank)
    send = jnp.zeros((nshards, cap + spill_cap), keys.dtype).at[
        owner, col].set(keys, mode="drop")
    smask = jnp.zeros((nshards, cap + spill_cap), bool).at[
        owner, col].set(served, mode="drop")
    overflow = jnp.maximum(hist - cap, 0)
    slab_owner = jnp.full((spill_cap,), -1, I32).at[
        jnp.where(spilled, spill_rank, spill_cap)].set(owner, mode="drop")
    dropped = (onehot * (spilled & ~served).astype(I32)[:, None]).sum(axis=0)
    return Route(send, smask, owner, rank, kept, overflow,
                 cap, spill_cap, spill_rank, served, slab_owner, dropped)


def _route_col(rt: Route) -> jax.Array:
    """Per-key column in the [S, cap + spill_cap] layout (out of bounds for
    keys the buffer does not carry — pair with mode="drop" / ``served``)."""
    return jnp.where(rt.kept, rt.rank, rt.cap + rt.spill_rank)


def _route_payload(payload: jax.Array, rt: Route) -> jax.Array:
    """Scatter a per-key payload (values, masks) into the
    [S, cap + spill_cap] layout of a ``Route`` computed for the same batch
    — primary AND slab slots are populated; dropped keys (compact slabs
    only) stay zero.  Shared by the distributed router and the serving
    tenant router."""
    nshards, width = rt.send.shape
    return jnp.zeros((nshards, width), payload.dtype).at[
        rt.owner, _route_col(rt)].set(payload, mode="drop")


def _unroute(resp_local: jax.Array, rt: Route, fill=None) -> jax.Array:
    """Invert a ``Route`` for a [S, cap + spill_cap] response: gather each
    key's slot (primary or slab) back to batch order.  Dropped keys take
    ``fill`` — by default 0 for integer/bool responses and NaN for floats,
    so a dropped float payload can never be mistaken for a real 0.0
    value."""
    if fill is None:
        fill = jnp.nan if jnp.issubdtype(resp_local.dtype, jnp.floating) else 0
    gathered = resp_local[rt.owner, jnp.where(rt.served, _route_col(rt), 0)]
    return jnp.where(rt.served, gathered,
                     jnp.asarray(fill, resp_local.dtype))


def shard_of(keys: jax.Array, nshards: int,
             owner_hfn: hashing.HashFn) -> jax.Array:
    """Owning shard of each key under the FIXED (never-rebuilt) owner hash."""
    return (hashing.hash_u32(owner_hfn, keys) % jnp.uint32(nshards)).astype(I32)


def routed_lookup(d: dhash.DHashState, keys: jax.Array, axis: str,
                  owner_hfn: hashing.HashFn, cap: int | None = None):
    """DHash lookup across shards. Call inside shard_map."""
    s = _axis_size(axis)
    owner = shard_of(keys, s, owner_hfn)
    rt = _route(keys, owner, s, cap)
    c = rt.send.shape[1]
    rk = lax.all_to_all(rt.send, axis, split_axis=0, concat_axis=0)
    rm = lax.all_to_all(rt.smask, axis, split_axis=0, concat_axis=0)
    found, vals = dhash.lookup(d, rk.reshape(-1))
    found = found & rm.reshape(-1)
    rf = lax.all_to_all(found.reshape(s, c), axis, split_axis=0, concat_axis=0)
    rv = lax.all_to_all(vals.reshape(s, c), axis, split_axis=0, concat_axis=0)
    return _unroute(rf, rt, fill=False).astype(bool), _unroute(rv, rt, fill=0)


def routed_update(d: dhash.DHashState, keys: jax.Array, vals: jax.Array,
                  mask: jax.Array, axis: str, owner_hfn: hashing.HashFn,
                  op: Callable = dhash.insert, cap: int | None = None):
    """DHash insert/delete across shards. Returns (d', ok). Call inside shard_map."""
    s = _axis_size(axis)
    owner = shard_of(keys, s, owner_hfn)
    rt = _route(keys, owner, s, cap)
    c = rt.send.shape[1]
    sendv = _route_payload(vals, rt)
    sm2 = _route_payload(mask, rt)
    rk = lax.all_to_all(rt.send, axis, split_axis=0, concat_axis=0)
    rv = lax.all_to_all(sendv, axis, split_axis=0, concat_axis=0)
    rm = lax.all_to_all(sm2, axis, split_axis=0, concat_axis=0)
    if op is dhash.insert:
        d, ok = op(d, rk.reshape(-1), rv.reshape(-1), rm.reshape(-1))
    else:
        d, ok = op(d, rk.reshape(-1), rm.reshape(-1))
    rok = lax.all_to_all(ok.reshape(s, c), axis, split_axis=0, concat_axis=0)
    return d, _unroute(rok, rt, fill=False).astype(bool)


def routed_rebuild_step(d: dhash.DHashState, axis: str) -> dhash.DHashState:
    """One rebuild transition on every shard (SPMD-synchronized epochs)."""
    return dhash.rebuild_step(d)


# -- mesh x stack: the [S shards x T tenants] grid ---------------------------
#
# Owner of a key is the PAIR (shard_of(key), tenant): flat owner id
# ``shard * T + tenant`` routes through ONE capped all_to_all pair into
# per-shard tenant stacks.  Each shard holds a ``dhash.make_stack(T, ...)``
# whose per-tenant rebuild epochs stay fully independent (the stack ops
# don't change under routing); the received buffer is reshaped
# tenant-major so one vmapped stack op serves every (source shard, tenant)
# cell at once.  The router itself is sort-free, so the whole routed fused
# stack op keeps the single-op kernel budget: ONE sort + ONE pallas_call.


def grid_owner(keys: jax.Array, tenant: jax.Array, nshards: int,
               ntenants: int, owner_hfn: hashing.HashFn) -> jax.Array:
    """Flat [S·T] owner id of each key: ``shard_of(key) * T + tenant``."""
    return shard_of(keys, nshards, owner_hfn) * ntenants + tenant.astype(I32)


def _grid_exchange(buf: jax.Array, axis: str, s: int, t: int, cap: int):
    """all_to_all a [S*T, cap] owner-major buffer and return it tenant-major
    [T, S*cap] for the stack op (each row = one tenant's queries from every
    source shard)."""
    rx = lax.all_to_all(buf.reshape(s, t, cap), axis,
                        split_axis=0, concat_axis=0)      # [src S, T, cap]
    return rx.transpose(1, 0, 2).reshape(t, s * cap)


def _grid_return(resp: jax.Array, axis: str, s: int, t: int, cap: int):
    """Inverse of ``_grid_exchange`` for a [T, S*cap] response: back to the
    querying shards, owner-major [S*T, cap]."""
    tx = resp.reshape(t, s, cap).transpose(1, 0, 2)       # [src S, T, cap]
    return lax.all_to_all(tx, axis, split_axis=0,
                          concat_axis=0).reshape(s * t, cap)


def routed_stack_lookup(d: dhash.DHashState, keys: jax.Array,
                        tenant: jax.Array, axis: str,
                        owner_hfn: hashing.HashFn,
                        cap_factor: float = 2.0,
                        spill_slack: float | None = None):
    """Lookup a [Q] batch against the S×T grid.  ``d`` is THIS shard's
    T-table tenant stack; call inside shard_map.  Returns
    (found[Q], vals[Q], overflow[S·T]).

    Keys past ``cap = ceil(c·Q/(S·T))`` ride the spill slab — extra
    columns of the SAME buffer through the SAME one all_to_all pair — so
    with the default overflow-proof slab (``spill_slack=None``) every key
    is served even under 100% skew.  A slab column lives only in its
    owner's row ``shard·T + tenant``, so the exchange delivers it to the
    right shard with no extra machinery.  ``overflow`` stays the exact
    per-owner spill telemetry (slab pressure, feeds the cap controller);
    under a compact ``spill_slack`` the slab can run out, and only then do
    keys come back not-found (counted in ``Route.dropped``, never silently
    zeroed)."""
    s = _axis_size(axis)
    t = dhash.stack_size(d)
    q = keys.shape[0]
    cap = route_cap(cap_factor, q, s * t)
    spill_cap = route_spill_cap(q, cap, spill_slack)
    rt = _route(keys, grid_owner(keys, tenant, s, t, owner_hfn), s * t, cap,
                spill_cap)
    w = rt.send.shape[1]
    qk = _grid_exchange(rt.send, axis, s, t, w)
    qm = _grid_exchange(rt.smask, axis, s, t, w)
    f, v = dhash.stack_lookup(d, qk, qm)
    rf = _grid_return(f, axis, s, t, w)
    rv = _grid_return(v, axis, s, t, w)
    return (_unroute(rf, rt, fill=False).astype(bool),
            _unroute(rv, rt, fill=0), rt.overflow)


def routed_stack_update(d: dhash.DHashState, keys: jax.Array,
                        vals: jax.Array, mask: jax.Array, tenant: jax.Array,
                        axis: str, owner_hfn: hashing.HashFn,
                        op: Callable = dhash.stack_insert,
                        cap_factor: float = 2.0,
                        spill_slack: float | None = None):
    """Insert/delete a [Q] batch into the S×T grid (``op`` is
    ``dhash.stack_insert`` or ``dhash.stack_delete``).  Returns
    (d', ok[Q], overflow[S·T]).  Spilled keys ride the slab columns of the
    same buffer / same all_to_all pair (see ``routed_stack_lookup``): with
    the default overflow-proof slab every key is applied; under a compact
    ``spill_slack`` only slab-exhausted keys report ok=False.  Call inside
    shard_map."""
    s = _axis_size(axis)
    t = dhash.stack_size(d)
    q = keys.shape[0]
    cap = route_cap(cap_factor, q, s * t)
    spill_cap = route_spill_cap(q, cap, spill_slack)
    rt = _route(keys, grid_owner(keys, tenant, s, t, owner_hfn), s * t, cap,
                spill_cap)
    w = rt.send.shape[1]
    qk = _grid_exchange(rt.send, axis, s, t, w)
    qm = _grid_exchange(_route_payload(mask, rt) & rt.smask, axis, s, t, w)
    if op is dhash.stack_insert:
        qv = _grid_exchange(_route_payload(vals, rt), axis, s, t, w)
        d, ok = op(d, qk, qv, qm)
    else:
        d, ok = op(d, qk, qm)
    rok = _grid_return(ok, axis, s, t, w)
    return d, _unroute(rok, rt, fill=False).astype(bool), rt.overflow


def make_stacked(nshards: int, backend: str = "linear", capacity: int = 1024,
                 *, chunk: int = 256, seed: int = 0, **kw) -> dhash.DHashState:
    """Build ``nshards`` independent shard tables stacked on a leading axis
    (``dhash.make_stack`` — the same uniform-pytree stack the vmap ops
    batch; here the leading axis is sharded over the mesh instead).

    Shard the leading axis over the mesh axis, then inside shard_map peel it
    with ``tree_map(lambda x: x[0], stacked)`` — see ``shardwise``.
    """
    return dhash.make_stack(nshards, backend, capacity, chunk=chunk,
                            seed=seed, **kw)


def peel(stacked):
    """Inside shard_map: view this shard's table (leading axis is size 1)."""
    return jax.tree_util.tree_map(lambda x: x[0], stacked)


def unpeel(d):
    """Inverse of peel for returning the updated shard."""
    return jax.tree_util.tree_map(lambda x: x[None], d)


def routed_service_step(d: dhash.DHashState, lookup_keys: jax.Array,
                        ins_keys: jax.Array, ins_vals: jax.Array,
                        del_keys: jax.Array, axis: str,
                        owner_hfn: hashing.HashFn, cap_factor: float = 0.0):
    """The paper's steady-state workload as one fused distributed step:
    a lookup batch + insert batch + delete batch + one rebuild transition.
    This is what the dry-run lowers for the dhash_paper 'architecture'.

    cap_factor > 0 bounds the routing buffers at cap = ceil(cap_factor*Q/S)
    (§Perf lever: S x fewer wire bytes and S x smaller remote batches)."""
    s = _axis_size(axis)
    capof = (lambda q: route_cap(cap_factor, q, s)) if cap_factor > 0 \
        else (lambda q: None)
    found, vals = routed_lookup(d, lookup_keys, axis, owner_hfn,
                                cap=capof(lookup_keys.shape[0]))
    d, ok_i = routed_update(d, ins_keys, ins_vals,
                            jnp.ones(ins_keys.shape, bool), axis, owner_hfn,
                            op=dhash.insert, cap=capof(ins_keys.shape[0]))
    d, ok_d = routed_update(d, del_keys, del_keys,
                            jnp.ones(del_keys.shape, bool), axis, owner_hfn,
                            op=dhash.delete, cap=capof(del_keys.shape[0]))
    d = dhash.rebuild_step(d)
    stats = jnp.stack([found.sum(dtype=I32), ok_i.sum(dtype=I32), ok_d.sum(dtype=I32)])
    return d, (found, vals, stats)
