"""DHash inside the framework: hash-router rebalancing (beyond-paper client).

A zipf-skewed token stream makes hash-routed experts hot (the paper's
collision/burst scenario materialized in MoE).  The engine inserts override
assignments for the hottest token ids (steering them to cold experts) via
the DHash table — LIVE, while steps keep routing.  Reports load imbalance
(max/mean) before and after, and the router-step overhead of the table
lookup.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dhash
from repro.models import moe as moe_lib

I32 = jnp.int32


def run(*, n_experts=32, k=2, tokens=1 << 15, vocab=50_000, zipf_a=1.1,
        quiet=False):
    rng = np.random.default_rng(0)
    seeds = jnp.asarray(rng.integers(0, 2**31, (k, 2)), jnp.uint32)
    raw = rng.zipf(zipf_a, tokens * 8) - 1
    toks = raw[raw < vocab][:tokens].astype(np.int32)   # rejection, not clamp
    tj = jnp.asarray(toks)

    route = jax.jit(lambda t, tbl: moe_lib.hash_route(t, tbl, seeds, n_experts, k))
    route_plain = jax.jit(lambda t: moe_lib.hash_route(t, None, seeds, n_experts, k))

    eid, _, _ = route_plain(tj)
    load = np.bincount(np.asarray(eid).reshape(-1), minlength=n_experts)
    imb_before = load.max() / load.mean()

    # rebalance: greedy re-pack of the hottest token ids onto the
    # least-loaded experts, from MEASURED load (the paper's "rebuild in
    # response to observed collisions")
    table = dhash.make("linear", capacity=8192, chunk=512, seed=5)
    counts = np.bincount(toks, minlength=vocab)
    hot_tokens = np.argsort(-counts)[:1024].astype(np.int32)
    hot_set = set(hot_tokens.tolist())
    eid_np = np.asarray(eid)
    resid = np.zeros(n_experts)
    flat_tok = np.repeat(toks, k)
    mask_cold = ~np.isin(flat_tok, hot_tokens)
    resid = np.bincount(eid_np.reshape(-1)[mask_cold], minlength=n_experts
                        ).astype(np.float64)
    e1s, e2s = [], []
    for t_ in hot_tokens:
        order = np.argsort(resid)
        a, b_ = int(order[0]), int(order[1])
        e1s.append(a)
        e2s.append(b_)
        # top-k routing sends EVERY occurrence to both assigned experts
        resid[a] += counts[t_]
        resid[b_] += counts[t_]
    packed = moe_lib.pack_assignment(jnp.asarray(e1s, I32), jnp.asarray(e2s, I32))
    table, ok = jax.jit(dhash.insert)(table, jnp.asarray(hot_tokens), packed)
    assert bool(np.asarray(ok).all())

    eid2, _, _ = route(tj, table)
    load2 = np.bincount(np.asarray(eid2).reshape(-1), minlength=n_experts)
    imb_after = load2.max() / load2.mean()

    # router-step overhead of the table lookup
    def t(f, *a):
        out = f(*a); jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(10):
            out = f(*a)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / 10

    t_plain, t_tbl = t(route_plain, tj), t(route, tj, table)

    # the rebalance can also run as a REBUILD while routing continues
    table = dhash.rebuild_start(table, seed=77)
    step = jax.jit(dhash.rebuild_chunk)
    while not bool(jax.device_get(dhash.rebuild_done(table))):
        table = step(table)
        eid3, _, _ = route(tj, table)     # full-rate routing mid-rebuild
    table = dhash.rebuild_finish(table)
    eid4, _, _ = route(tj, table)
    assert bool((np.asarray(eid4) == np.asarray(eid2)).all()), \
        "override assignments must survive the rebuild epoch"

    if not quiet:
        print(f"imbalance (max/mean) before: {imb_before:.2f}  after overrides: {imb_after:.2f}")
        print(f"route step: plain {t_plain*1e3:.2f} ms, with DHash overrides "
              f"{t_tbl*1e3:.2f} ms ({t_tbl/t_plain:.2f}x)")
        print(f"[summary] live rebalance cut imbalance {imb_before/imb_after:.2f}x; "
              "assignments identical across a full rebuild epoch")
    return {"imb_before": imb_before, "imb_after": imb_after,
            "t_plain": t_plain, "t_table": t_tbl}


if __name__ == "__main__":
    run()
