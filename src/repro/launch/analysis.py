"""Compiled-artifact analysis: collective bytes from HLO text + the
three-term roofline (TPU v5e constants).

cost_analysis() has no collective accounting, so we parse the post-SPMD
optimized HLO and sum the result-shape bytes of every collective op
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute).
Result-shape bytes is the standard first-order proxy for wire bytes
(exact for all-reduce ring cost within 2x, exact for all-gather output).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict

# ---- TPU v5e hardware constants (per chip) --------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (spec-provided figure)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum result bytes + counts per collective kind."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        for op in _COLL_OPS:
            # "  %x = bf16[...] all-gather(...)" / fusion lines excluded
            m = re.search(rf"=\s*((?:\([^)]*\))|(?:\S+))\s+{op}(-start|-done)?\(", line)
            if m:
                if m.group(2) == "-done":   # counted at -start
                    continue
                out[op]["count"] += 1
                out[op]["bytes"] += _shape_bytes(m.group(1))
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


@dataclass
class Roofline:
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time = max term (perfect overlap assumption)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        """MODEL_FLOPS utilization at the roofline step time."""
        if self.model_flops and self.step_time > 0:
            return self.model_flops / (self.chips * PEAK_FLOPS * self.step_time)
        return 0.0

    @property
    def useful_flop_frac(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d |= {"t_compute": self.t_compute, "t_memory": self.t_memory,
              "t_collective": self.t_collective, "bottleneck": self.bottleneck,
              "step_time": self.step_time, "mfu": self.mfu,
              "useful_flop_frac": self.useful_flop_frac}
        return d


def cost_of(compiled) -> tuple[float, float]:
    """(flops, bytes) from compiled.cost_analysis(), tolerant of backends."""
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))
    except Exception:
        return 0.0, 0.0


def memory_of(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        keys = ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
        return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}
    except Exception:
        return {}
