"""Paper Figure 4 analogue: DHash across implementation variants.

The paper varies the hardware architecture (x86/POWER9/ARMv8); this
container has exactly one CPU, so the portability axis becomes the
*implementation* matrix the modular design promises (§3 goal 2): bucket
backend (chain = paper-faithful lists, linear / twochoice = TPU-native
array forms) x hash family (multiply_shift / mix32 / tabulation).
The claim preserved from Fig 4 is shape, not constants: DHash throughput
scales with batch width and does not degrade past saturation, for every
variant.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import UNIVERSE, DHashDriver, Workload, run_throughput
from repro.core import hashing


def run(alpha=20, qs=(256, 1024, 4096), *, quiet=False):
    nbuckets = 256
    n = alpha * nbuckets
    rng = np.random.default_rng(0)
    present = rng.choice(UNIVERSE, size=n, replace=False).astype(np.int32)
    rows = []
    for backend in ("chain", "linear", "twochoice"):
        drv = DHashDriver(nbuckets, n, backend=backend, seed=1)
        drv.populate(present)
        last = None
        for q in qs:
            wl = Workload(q=q, mix=(90, 5, 5))
            mops = run_throughput(drv, wl, present, steps=5,
                                  rng=np.random.default_rng(q)) / 1e6
            rows.append((f"dhash-{backend}", q, mops))
            if not quiet:
                print(f"DHash-{backend:10s} Q={q:<6d} {mops:8.3f} Mops/s")
            last = mops
    # hash-family axis (lookup-only microbench)
    keys = jnp.asarray(rng.integers(1, UNIVERSE, 1 << 16).astype(np.int32))
    for kind in hashing.HASH_KINDS:
        fn = hashing.fresh(kind, 7)
        f = jax.jit(lambda k, fn=fn: hashing.bucket_of(fn, k, 1 << 20))
        from benchmarks.common import timeit
        dt = timeit(f, keys)
        rows.append((f"hash-{kind}", keys.size, keys.size / dt / 1e6))
        if not quiet:
            print(f"hash {kind:16s} {keys.size / dt / 1e6:9.1f} Mhash/s")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--alpha", type=int, default=20)
    args = ap.parse_args(argv)
    return run(args.alpha)


if __name__ == "__main__":
    main()
