"""ElasticPolicy test suite: the small_hash.c trigger set on DHash tables.

Covers the four behaviours the policy layer promises (core/policy.py):

* hysteresis — a table sitting exactly AT the high watermark never fires,
  one past it fires exactly once, and the fired latch stays down while the
  load holds (no flap at the boundary), across all registered backends;
* the expensive-lookup counter — host-precomputed colliding keys drive the
  probe-length telemetry past ``enlarge_after / report_every`` and trigger
  growth with the load far BELOW the watermark (fused on and off);
* engine-level shrink — a drained ``DHashEngine`` resizes down and the
  remaining keys survive the migration;
* per-tenant independence — on an 8-table stack only the overloaded
  tenants fire, their latches drop independently, and every tenant's keys
  stay readable (all registered backends, fused on and off);
* the in-place liveness guard — a bounded-placement backend above the
  placement headroom holds the same-shape rehash trigger (still
  publishing the resize plan) until the load drains, closing the PR 7
  stranded-hazard-key caveat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend as backends
from repro.core import dhash, engine, hashing
from repro.core import policy as elastic

ALL_BACKENDS = backends.names()
FUSED_AXIS = [(b, f) for b in ALL_BACKENDS
              for f in ((False, True) if backends.get(b).fused else (False,))]


def _live(d):
    return int(jax.device_get(backends.get(d.backend).count_live(d.old)))


def _fill_to(d, n, *, start=1):
    """Insert sequential keys until the old table holds exactly ``n`` live
    entries (retries around backend insert failure, e.g. a full twochoice
    row pair).  Returns (state, inserted_keys)."""
    inserted = []
    nxt = start
    for _ in range(50):
        need = n - _live(d)
        if need == 0:
            break
        ks = jnp.arange(nxt, nxt + need, dtype=jnp.int32)
        nxt += need
        d, ok = dhash.insert(d, ks, ks)
        inserted.extend(np.asarray(ks)[np.asarray(ok)].tolist())
    assert _live(d) == n, f"could not reach {n} live entries"
    return d, inserted


def _complete_rebuild(d, max_steps=200):
    for _ in range(max_steps):
        if not bool(jax.device_get(d.rebuilding)):
            return d
        d = dhash.rebuild_step(d)
        d = dhash.finish_same_shape(d)
    raise AssertionError("same-shape rebuild did not finish")


# ---------------------------------------------------------------------------
# hysteresis at the watermark boundary
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_no_flap_at_watermark_boundary(name):
    """live == high: never fires.  live == high + 1: fires exactly once,
    and the consumed latch keeps it down while the load holds — then
    re-arms (without firing) once the load drains below high/headroom."""
    d = dhash.make(name, capacity=64, chunk=32, seed=0, fused=False)
    slots = backends.get(d.backend).capacity_of(d.old)
    pol = elastic.make(in_place=True, tomb_load=1.0)   # isolate the watermark
    high, low = elastic.watermarks(pol, slots)
    assert 0 < low < high < slots

    d, keys = _fill_to(d, high)
    for _ in range(5):
        pol, d = elastic.policy_step(pol, d)
    assert int(pol.fires) == 0 and bool(pol.armed)

    d, more = _fill_to(d, high + 1, start=1_000_000)
    keys += more
    pol, d = elastic.policy_step(pol, d)
    assert int(pol.fires) == 1 and bool(jax.device_get(d.rebuilding))
    d = _complete_rebuild(d)
    for _ in range(10):
        pol, d = elastic.policy_step(pol, d)
    assert int(pol.fires) == 1, "latch flapped while the load held"
    assert not bool(pol.armed)

    # drain below the re-arm watermark: latch returns, still no fire
    rearm_at = int(high / pol.expand_headroom)
    drop = jnp.asarray(keys[:len(keys) - rearm_at], jnp.int32)
    d, ok = dhash.delete(d, drop)
    assert bool(ok.all()) and _live(d) == rearm_at
    pol, d = elastic.policy_step(pol, d)
    assert bool(pol.armed) and int(pol.fires) == 1

    kept = jnp.asarray(keys[len(keys) - rearm_at:], jnp.int32)
    found, vals = dhash.lookup(d, kept)
    assert bool(found.all()) and bool((vals == kept).all())


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_latch_holds_across_epoch_under_sustained_load(name):
    """policy_step interleaved with the rehash (the engine's step order):
    mid-epoch extraction empties the OLD table, and that transient low
    count must not re-arm the latch — a still-hot table fires ONCE per
    excursion, not once per completed epoch."""
    d = dhash.make(name, capacity=64, chunk=32, seed=0, fused=False)
    slots = backends.get(d.backend).capacity_of(d.old)
    pol = elastic.make(in_place=True, tomb_load=1.0)
    high, _ = elastic.watermarks(pol, slots)

    d, keys = _fill_to(d, high + 1)
    epochs = 0
    for _ in range(120):   # load never drains: epoch completes, no refire
        d = dhash.rebuild_step(d)
        d = dhash.finish_same_shape(d)
        pol, d = elastic.policy_step(pol, d)
    assert int(jax.device_get(d.epoch)) == 1, "first fire must complete"
    assert int(pol.fires) == 1, "latch re-armed mid-epoch and refired"
    assert not bool(pol.armed)
    found, _ = dhash.lookup(d, jnp.asarray(keys, jnp.int32))
    assert bool(found.all())


def test_stack_engine_latch_holds_across_epoch():
    """The same guarantee through DHashStackEngine: a tenant held past the
    watermark rebuilds exactly once over a long idle drive."""
    stk = dhash.make_stack(4, "linear", 64, chunk=32, fused=True)
    seng = engine.DHashStackEngine(
        stk, policy=elastic.make(grow_load=0.5, in_place=True, tomb_load=1.0))
    T, Q = 4, 65   # linear cap 64 -> 128 slots, high = 64 at grow_load 0.5
    kq = jnp.zeros((T, Q), jnp.uint32)
    nomask = jnp.zeros((T, Q), bool)
    ins = kq.at[2].set(jnp.arange(1, Q + 1, dtype=jnp.uint32))
    seng.step(kq, ins, ins * 2, kq,
              ins_mask=nomask.at[2].set(True), del_mask=nomask)
    for _ in range(60):
        seng.step(kq, kq, kq, kq, ins_mask=nomask, del_mask=nomask)
    ep = np.asarray(jax.device_get(seng.state.epoch))
    assert ep.tolist() == [0, 0, 1, 0], ep
    found, vals = seng.lookup(ins)
    fn = np.asarray(jax.device_get(found))
    assert fn[2].all() and not fn[[0, 1, 3]].any()
    assert (np.asarray(jax.device_get(vals))[2]
            == np.arange(1, Q + 1) * 2).all()


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_resize_target_lands_inside_band(name):
    """target = ceil(live * headroom) entries puts the post-resize load
    strictly between the watermarks for every slot rounding ``be.make``
    applies — grow/shrink cannot flap at a boundary by construction."""
    be = backends.get(name)
    pol = elastic.make()
    for live in (64, 100, 200, 500, 1000, 5000, 20000):
        target = int(np.clip(int(np.ceil(live * pol.expand_headroom)),
                             pol.min_capacity, pol.max_capacity))
        slots = elastic.resolve_slots(be, target)
        high, low = elastic.watermarks(pol, slots)
        assert low < live < high, (name, live, slots, low, high)


def test_tombstone_pressure_fires_reclaim_inside_band():
    """Resize mode: deletes leave the live load inside the band but the
    tombstone fraction past ``tomb_load`` — fires a same-shape reclaim,
    once, and stays quiet after the rebuild scrubs the tombs."""
    d = dhash.make("linear", capacity=256, chunk=64, seed=1, fused=False)
    d, keys = _fill_to(d, 300)
    d, ok = dhash.delete(d, jnp.asarray(keys[:200], jnp.int32))
    assert bool(ok.all())
    pol = elastic.make()
    pol, d = elastic.policy_step(pol, d)
    assert int(pol.fires) == 1 and bool(jax.device_get(d.rebuilding))
    assert not bool(pol.want_grow) and not bool(pol.want_shrink)
    d = _complete_rebuild(d)
    assert int(jax.device_get(backends.get(d.backend).count_tomb(d.old))) == 0
    for _ in range(5):
        pol, d = elastic.policy_step(pol, d)
    assert int(pol.fires) == 1


# ---------------------------------------------------------------------------
# in-place liveness guard for bounded-placement backends
# ---------------------------------------------------------------------------

def test_in_place_rehash_deferred_past_placement_headroom():
    """The PR 7 liveness caveat, closed: in in-place mode a bounded-
    placement backend (twochoice here; cuckoo gets the same guard) sitting
    above ``place_headroom`` must NOT fire a same-shape rehash — reloading
    a near-saturated table under fresh hash functions can strand
    unplaceable keys in the hazard buffer indefinitely.  The held trigger
    fires once the load drains below the headroom, and the epoch then
    completes with an EMPTY hazard buffer."""
    d = dhash.make("twochoice", capacity=600, chunk=128, seed=2, fused=False)
    be = backends.get(d.backend)
    assert be.bounded_placement
    slots = be.capacity_of(d.old)
    pol = elastic.make(grow_load=0.3, in_place=True, tomb_load=1.0)
    headroom = int(slots * pol.place_headroom)
    high, _ = elastic.watermarks(pol, slots)
    target = headroom + 30        # past the watermark AND the guard
    assert high < headroom < target < slots
    d, keys = _fill_to(d, target)

    for _ in range(5):            # hot but unsafe: held, never fired
        pol, d = elastic.policy_step(pol, d)
    assert int(pol.fires) == 0
    assert not bool(jax.device_get(d.rebuilding))
    assert bool(pol.want_grow), "the resize plan must still publish"

    # drain below the headroom (but not below the watermark): the held
    # trigger fires and the reload now COMPLETES
    safe = high + 33
    d, ok = dhash.delete(d, jnp.asarray(keys[:target - safe], jnp.int32))
    assert bool(ok.all()) and _live(d) == safe
    pol, d = elastic.policy_step(pol, d)
    assert int(pol.fires) == 1 and bool(jax.device_get(d.rebuilding))
    d = _complete_rebuild(d)      # raises if the epoch stalls
    assert int(jax.device_get(d.epoch)) == 1
    assert not bool(jax.device_get(d.hazard_live.any())), \
        "same-shape rehash parked keys in the hazard buffer"
    kept = jnp.asarray(keys[target - safe:], jnp.int32)
    found, vals = dhash.lookup(d, kept)
    assert bool(found.all()) and bool((vals == kept).all())


def test_unbounded_backend_unaffected_by_placement_guard():
    """Open-addressing placement cannot fail below physical capacity, so
    the linear backend fires in-place rehashes above the headroom exactly
    as before the guard."""
    d = dhash.make("linear", capacity=64, chunk=32, seed=0, fused=False)
    be = backends.get(d.backend)
    assert not be.bounded_placement
    slots = be.capacity_of(d.old)
    pol = elastic.make(grow_load=0.5, in_place=True, tomb_load=1.0)
    target = int(slots * pol.place_headroom) + 5
    d, _ = _fill_to(d, target)
    pol, d = elastic.policy_step(pol, d)
    assert int(pol.fires) == 1 and bool(jax.device_get(d.rebuilding))
    d = _complete_rebuild(d)
    assert not bool(jax.device_get(d.hazard_live.any()))


# ---------------------------------------------------------------------------
# expensive-lookup trigger (probe-length telemetry)
# ---------------------------------------------------------------------------

def _colliding_keys(t, want):
    """Host-precompute ``want`` keys that hash to one linear bucket — the
    probe chain the load factor alone cannot see."""
    cand = np.arange(1, 20_001, dtype=np.int32)
    h0 = np.asarray(jax.device_get(
        hashing.bucket_of(t.hfn, jnp.asarray(cand), t.capacity)))
    vals, counts = np.unique(h0, return_counts=True)
    assert counts.max() >= want, "universe too small for the collision set"
    return cand[h0 == vals[np.argmax(counts)]][:want]


@pytest.mark.parametrize("fused", (False, True))
def test_expensive_lookups_grow_below_watermark(fused):
    d = dhash.make("linear", capacity=256, chunk=64, seed=3, fused=fused)
    pol = elastic.make(min_lookups=32)
    slots = backends.get(d.backend).capacity_of(d.old)
    high, _ = elastic.watermarks(pol, slots)

    keys = _colliding_keys(d.old, 12)   # probe distances 0..11 at one bucket
    d, ok = dhash.insert(d, jnp.asarray(keys), jnp.asarray(keys))
    assert bool(ok.all()) and _live(d) == 12 < high

    q = jnp.asarray(np.tile(keys, 3))   # 36 >= min_lookups samples
    d, (found, vals) = dhash.lookup_counted(d, q, probe_hi=pol.probe_hi)
    assert bool(found.all()) and bool((vals == q).all())
    assert int(jax.device_get(d.lookups)) == 36
    assert int(jax.device_get(d.expensive)) == 15   # distances 7..11, tiled

    pol, d = elastic.policy_step(pol, d)
    assert bool(pol.want_grow), "probe trigger must fire below the watermark"
    assert not bool(pol.want_shrink)

    # in-place flavour: same telemetry fires the on-device rehash and
    # consumes the sample window
    d2 = dhash.make("linear", capacity=256, chunk=64, seed=3, fused=fused)
    d2, _ = dhash.insert(d2, jnp.asarray(keys), jnp.asarray(keys))
    p2 = elastic.make(min_lookups=32, in_place=True)
    d2, _ = dhash.lookup_counted(d2, q, probe_hi=p2.probe_hi)
    p2, d2 = elastic.policy_step(p2, d2)
    assert int(p2.fires) == 1 and bool(jax.device_get(d2.rebuilding))
    assert int(jax.device_get(d2.lookups)) == 0

    # control: the same population spread over distinct buckets stays quiet
    d3 = dhash.make("linear", capacity=256, chunk=64, seed=3, fused=fused)
    spread, picked = [], set()
    for k in range(1, 20_001):
        b = int(jax.device_get(hashing.bucket_of(
            d3.old.hfn, jnp.asarray([k], jnp.int32), d3.old.capacity))[0])
        if b not in picked:
            picked.add(b)
            spread.append(k)
        if len(spread) == 12:
            break
    d3, _ = dhash.insert(d3, jnp.asarray(spread, jnp.int32),
                         jnp.asarray(spread, jnp.int32))
    p3 = elastic.make(min_lookups=32)
    d3, _ = dhash.lookup_counted(d3, jnp.asarray(np.tile(spread, 3),
                                                 jnp.int32),
                                 probe_hi=p3.probe_hi)
    assert int(jax.device_get(d3.expensive)) == 0
    p3, d3 = elastic.policy_step(p3, d3)
    assert not bool(p3.want_grow)


# ---------------------------------------------------------------------------
# engine-level shrink after a drain
# ---------------------------------------------------------------------------

def test_engine_shrinks_after_drain():
    eng = engine.DHashEngine(
        dhash.make("linear", capacity=256, chunk=64, seed=1, fused=False),
        policy=elastic.make(tomb_load=1.0), poll_every=1)
    be = backends.get(eng.state.backend)
    slots0 = int(be.capacity_of(eng.state.old))

    keys = np.arange(1, 301, dtype=np.int32)
    none = np.zeros(64, np.int32)
    nm = np.zeros(64, bool)
    for i in range(0, 300, 64):
        k = np.resize(keys[i:i + 64], 64)
        eng.step(none, k, k, none, np.arange(64) < min(64, 300 - i), nm)
    assert eng.stats.grows == 0          # 300 live sits below the watermark

    for i in range(0, 280, 64):          # drain to 20 live (< low watermark)
        k = np.resize(keys[i:i + 64], 64)
        eng.step(none, none, none, k, nm, np.arange(64) < min(64, 280 - i))
    for _ in range(120):                 # let the shrink start + migrate
        eng.step(none, none, none, none, nm, nm)
        if eng.stats.shrinks >= 1 and not bool(
                jax.device_get(eng.state.rebuilding)):
            break
    assert eng.stats.shrinks == 1 and eng.stats.grows == 0
    slots1 = int(be.capacity_of(eng.state.old))
    assert slots1 < slots0

    survivors = jnp.asarray(keys[280:], jnp.int32)
    found, vals = eng.lookup(survivors)
    assert bool(found.all()) and bool((vals == survivors).all())

    resizes = eng.stats.grows + eng.stats.shrinks
    for _ in range(20):                  # inside the new band: no flapping
        eng.step(none, none, none, none, nm, nm)
    assert eng.stats.grows + eng.stats.shrinks == resizes


# ---------------------------------------------------------------------------
# per-tenant independence on a stack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,fused", FUSED_AXIS)
def test_stack_tenants_fire_independently(name, fused):
    """8 tenants, two loaded past the watermark: exactly those fire, each
    under its own latch, and every tenant's keys survive its rehash."""
    T, cap = 8, 64
    d = dhash.make_stack(T, name, capacity=cap, chunk=32, seed=0, fused=fused)
    be = backends.get(name)
    slots = int(be.capacity_of(jax.tree_util.tree_map(lambda x: x[0], d).old))
    # grow_load=0.5: past-the-watermark tenants must complete a SAME-SHAPE
    # rehash.  The in-place placement-headroom guard (place_headroom) holds
    # the trigger for bounded-placement backends above 85% load, and a
    # reload can strand keys well below that (see docs/KERNELS.md) — the
    # behaviour under test here is per-tenant independence, so keep the
    # reload comfortably placeable AND below the guard
    cfg = elastic.make(grow_load=0.5, in_place=True, tomb_load=1.0)
    pol = elastic.stack(cfg, T)
    high, low = elastic.watermarks(cfg, slots)

    hot = np.array([False, True, False, False, False, True, False, False])
    target = np.where(hot, high + 1, max(low + 2, 8))
    held: list[list[int]] = [[] for _ in range(T)]
    nxt = 1
    for _ in range(12):   # top up with FRESH keys: an unplaceable key (full
        live = np.asarray(jax.device_get(jax.vmap(be.count_live)(d.old)))
        need = target - live                # twochoice/cuckoo row pair)
        if (need <= 0).all():               # never lands however retried
            break
        q = int(need.max())
        keys = np.zeros((T, q), np.int32)
        mask = np.zeros((T, q), bool)
        for t in range(T):
            if need[t] > 0:
                keys[t, :need[t]] = np.arange(nxt, nxt + need[t]) + 100_000 * t
                mask[t, :need[t]] = True
        nxt += q
        d, ok = dhash.stack_insert(d, jnp.asarray(keys), jnp.asarray(keys),
                                   jnp.asarray(mask))
        okn = np.asarray(jax.device_get(ok)) & mask
        for t in range(T):
            held[t].extend(keys[t][okn[t]].tolist())
    live0 = np.asarray(jax.device_get(jax.vmap(be.count_live)(d.old)))
    assert (live0 == target).all(), live0

    pol, d = elastic.stack_policy_step(pol, d)
    fires = np.asarray(jax.device_get(pol.fires))
    assert (fires == hot.astype(np.int32)).all(), fires
    assert (np.asarray(jax.device_get(d.rebuilding)) == hot).all()

    for _ in range(50):                  # run the masked rehashes to done
        if not bool(jax.device_get(d.rebuilding.any())):
            break
        d = dhash.stack_rebuild_step(d)
        d = dhash.stack_finish_same_shape(d)
    assert not bool(jax.device_get(d.rebuilding.any()))
    epochs = np.asarray(jax.device_get(d.epoch))
    assert (epochs == hot.astype(np.int32)).all(), epochs

    # latches are independent: the fired tenants stay down (load unchanged),
    # the light tenants stay armed, and nobody re-fires
    pol, d = elastic.stack_policy_step(pol, d)
    assert (np.asarray(jax.device_get(pol.fires))
            == hot.astype(np.int32)).all()
    armed = np.asarray(jax.device_get(pol.armed))
    assert (armed == ~hot).all(), armed

    qf = max(len(h) for h in held)
    keys = np.zeros((T, qf), np.int32)
    mask = np.zeros((T, qf), bool)
    for t, h in enumerate(held):
        keys[t, :len(h)] = h
        mask[t, :len(h)] = True
    found, vals = dhash.stack_lookup(d, jnp.asarray(keys), jnp.asarray(mask))
    found = np.asarray(jax.device_get(found))
    vals = np.asarray(jax.device_get(vals))
    assert (found == mask).all()
    assert (vals[mask] == keys[mask]).all()


# ---------------------------------------------------------------------------
# nres_cap adaptation
# ---------------------------------------------------------------------------

def test_adapt_nres_cap():
    pol = elastic.make()
    # same-size / small growth: the descriptor default already covers it
    assert elastic.adapt_nres_cap(pol, 1024, 1024, base=16) == 16
    assert elastic.adapt_nres_cap(pol, 1024, 4096, base=16) == 16
    # past base: residency follows ceil(new/old) + 1 window-straddle slab
    assert elastic.adapt_nres_cap(pol, 1024, 32 * 1024, base=16) == 33
    assert elastic.adapt_nres_cap(pol, 1000, 32 * 1024, base=16) == 34
    # bounded by the policy ceiling
    assert elastic.adapt_nres_cap(pol, 64, 1 << 20, base=16) == pol.nres_cap_max
    # shrink rebuilds concentrate: never below the descriptor default
    assert elastic.adapt_nres_cap(pol, 4096, 512, base=16) == 16


# -- RouteCapController: spill-feedback adaptive routing caps ---------------


def _spill_drops_for(cap_factor, q, s, slack, owner_counts):
    """Host model of one routed batch: (total spill, dropped) at the cap."""
    from repro.core.distributed import route_cap, route_spill_cap
    cap = route_cap(cap_factor, q, s)
    slab = route_spill_cap(q, cap, slack)
    spill = sum(max(c - cap, 0) for c in owner_counts)
    return spill, max(spill - slab, 0)


def test_route_cap_controller_burst_converges_in_band_without_flapping():
    """The acceptance loop: an elastic-style burst (sustained hot-tenant
    skew against a compact slab) drives the controller up the ladder —
    first on drops, then on slab occupancy — until the occupancy EWMA sits
    inside the watermark band; it then HOLDS (no flapping), and the
    post-burst drain walks it back down — still without a flap."""
    s, q, slack = 8, 1024, 0.5
    ctl = elastic.RouteCapController(n_shards=s, q_ref=q, cap_factor=2.0,
                                     spill_slack=slack)
    # ~88% of traffic on one tenant (an elastic-style noisy neighbour)
    counts = [900, 24, 20, 20, 20, 20, 10, 10]
    assert sum(counts) == q
    spill = drop = 0
    caps = []
    for _ in range(40):
        dsp, ddr = _spill_drops_for(ctl.cap_factor, q, s, slack, counts)
        spill, drop = spill + dsp, drop + ddr
        caps.append(ctl.update(spill, drop))
    assert ctl.in_band(), (ctl.occ, ctl.cap_factor)
    assert ctl.flaps == 0
    assert ctl.grows >= 1 and ctl.shrinks == 0
    # converged: the tail of the burst holds one cap value
    assert len(set(caps[-10:])) == 1
    grown = ctl.cap_factor
    assert grown > 2.0
    # ...and the cap stays on the geometric ladder
    k = round(np.log(grown / 2.0) / np.log(1.5))
    assert grown == pytest.approx(2.0 * 1.5 ** k)
    # at the converged cap the compact slab serves everything: no drops
    _, ddr = _spill_drops_for(grown, q, s, slack, counts)
    assert ddr == 0
    # drain: balanced traffic, zero spill -> walk back down, still no flap
    # (a reversal after a long in-band stretch is a workload change)
    for _ in range(60):
        ctl.update(spill, drop)
    assert ctl.cap_factor < grown
    assert ctl.flaps == 0
    assert ctl.shrinks >= 1


def test_route_cap_controller_drops_grow_immediately():
    """A compact slab's drop is the one signal that bypasses the cooldown:
    the very next poll grows the cap."""
    ctl = elastic.RouteCapController(n_shards=8, q_ref=64, cap_factor=2.0,
                                     spill_slack=0.25, cooldown=10)
    before = ctl.cap_factor
    got = ctl.update(10, 0)       # spill but no drop: cooldown holds...
    got = ctl.update(20, 4)       # ...a DROP does not wait
    assert got == before * 1.5
    assert ctl.grows == 1
    # repeated drops keep climbing, clamped at the full-width ceiling
    spill, drops = 20, 4
    for _ in range(20):
        spill, drops = spill + 10, drops + 1
        ctl.update(spill, drops)
    assert ctl.cap_factor == ctl.cap_max == 8.0


def test_route_cap_controller_ladder_is_clamped_and_finite():
    ctl = elastic.RouteCapController(n_shards=4, q_ref=64, cap_factor=1.0,
                                     cap_min=1.0, cooldown=0)
    # idle traffic can never push the cap below cap_min
    for _ in range(30):
        ctl.update(0, 0)
    assert ctl.cap_factor == 1.0
    assert ctl.shrinks == 0
    # the watermark band must be wider than the ladder step (no-flap
    # construction) — a degenerate configuration is rejected outright
    with pytest.raises(ValueError):
        elastic.RouteCapController(n_shards=4, q_ref=64,
                                   occ_hi=0.5, occ_lo=0.4)
    with pytest.raises(ValueError):
        elastic.RouteCapController(n_shards=4, q_ref=64, step=0.9)
