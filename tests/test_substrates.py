"""Substrate tests: optimizer, checkpointing, data pipeline, sharding rules."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, dedup_batch, synth_batch
from repro.optim import optimizer as opt_lib


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _quadratic_fit(cfg, steps=200):
    target = jnp.asarray([1.5, -2.0, 0.5, 3.0])
    params = {"w": jnp.zeros((4,))}
    state = opt_lib.init_opt_state(params, cfg)

    @jax.jit
    def step(params, state):
        def loss(p):
            return jnp.sum((p["w"] - target) ** 2)
        lv, g = jax.value_and_grad(loss)(params)
        params, state, m = opt_lib.apply_updates(params, g, state, cfg)
        return params, state, lv

    for _ in range(steps):
        params, state, l_last = step(params, state)
    return float(jnp.max(jnp.abs(params["w"] - target))), float(l_last)


def test_adamw_converges():
    cfg = opt_lib.OptConfig(lr=5e-2, weight_decay=0.0, total_steps=200,
                            warmup_steps=5, schedule="const")
    err, _ = _quadratic_fit(cfg)
    assert err < 0.05, err


def test_grad_compression_error_feedback_converges():
    """int8 error-feedback compression must not break convergence (the
    feedback buffer recovers the quantization error across steps)."""
    cfg = opt_lib.OptConfig(lr=5e-2, weight_decay=0.0, total_steps=300,
                            warmup_steps=5, schedule="const",
                            grad_compression=True)
    err, _ = _quadratic_fit(cfg, steps=300)
    assert err < 0.1, err


def test_lr_schedule_shapes():
    cfg = opt_lib.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(opt_lib.lr_at(cfg, jnp.asarray(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0 < lrs[3] < 1.0 and lrs[4] == pytest.approx(0.0, abs=1e-6)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_atomic_gc(tmp_path):
    from repro.train import checkpoint as ck
    state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
             "opt": {"step": jnp.asarray(7)}}
    for step in (10, 20, 30, 40):
        ck.save(str(tmp_path), step, state, keep=2)
    assert ck.latest_step(str(tmp_path)) == 40
    # gc kept only 2
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(dirs) == 2
    restored, step = ck.restore(str(tmp_path), state)
    assert step == 40
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_detects_corruption(tmp_path):
    from repro.train import checkpoint as ck
    state = {"w": jnp.ones((4,))}
    path = ck.save(str(tmp_path), 1, state)
    fn = os.path.join(path, "w.npy")
    arr = np.load(fn)
    arr[0] = 999.0
    np.save(fn, arr)
    with pytest.raises(IOError, match="corrupt"):
        ck.restore(str(tmp_path), state)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_elastic():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=8, seed=3)
    a = synth_batch(cfg, step=5)
    b = synth_batch(cfg, step=5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    # elastic: 2 shards concatenated == 1 shard global
    s0 = synth_batch(cfg, step=5, shard=0, nshards=2)
    s1 = synth_batch(cfg, step=5, shard=1, nshards=2)
    both = np.concatenate([np.asarray(s0["tokens"]), np.asarray(s1["tokens"])])
    np.testing.assert_array_equal(both, np.asarray(a["tokens"]))
    # different steps differ
    c = synth_batch(cfg, step=6)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(a["labels"][:, :-1]),
                                  np.asarray(a["tokens"][:, 1:]))


def test_dedup_batch_drops_repeats():
    from repro.core import dhash
    cfg = DataConfig(vocab_size=1000, seq_len=256, global_batch=4, seed=1)
    table = dhash.make("linear", capacity=4096, chunk=64, seed=0)
    batch = synth_batch(cfg, 0)
    table, keep1 = dedup_batch(table, batch["tokens"], block=64)
    assert bool(np.asarray(keep1).all()), "first sight: all kept"
    # same batch again -> all blocks are duplicates
    table, keep2 = dedup_batch(table, batch["tokens"], block=64)
    assert not bool(np.asarray(keep2).any()), "second sight: all dropped"


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_leaf_spec_rules():
    from jax.sharding import PartitionSpec as P
    from repro.models.sharding import leaf_spec

    class K:  # fake DictKey
        def __init__(self, key):
            self.key = key

    sizes = {"data": 16, "model": 16}
    # heads divisible -> model on head axis
    assert leaf_spec((K("attn_stack"), K("wq")), (26, 2304, 32, 128),
                     axis_sizes=sizes) == P(None, None, "model", None)
    # heads NOT divisible -> replicated (no invalid sharding)
    assert leaf_spec((K("attn_stack"), K("wq")), (26, 2304, 8, 256),
                     axis_sizes=sizes) == P(None, None, None, None)
    # fsdp adds a data shard on D
    assert leaf_spec((K("attn_stack"), K("wq")), (26, 2304, 8, 256),
                     axis_sizes=sizes, fsdp=True) == P(None, "data", None, None)
    # experts over model
    assert leaf_spec((K("attn_stack"), K("we_g")), (35, 128, 7168, 4864),
                     axis_sizes=sizes) == P(None, "model", None, None)
    # vocab over model
    assert leaf_spec((K("embed"),), (256000, 2304),
                     axis_sizes=sizes) == P("model", None)
    # norms replicated
    assert leaf_spec((K("final_norm"),), (2304,), axis_sizes=sizes) == P(None)
