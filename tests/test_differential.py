"""Differential op-sequence fuzz suite: random interleavings of
insert / delete / lookup / rebuild-start / rebuild-step checked against a
Python dict oracle, across ALL FOUR backends x fused on/off x growth
factors 1x/4x.

This is the acceptance harness for the fused chain backend (the last
backend onto the Pallas path): every op sequence must observe exactly the
oracle's membership, values, and ok flags, through arbitrary rebuild
interleavings — including capacity-GROWING rebuilds, whose epoch swap runs
through the host `rebuild_finish` path and whose fused probes exercise the
two-level tile map.

Encoding is shrink-friendly: a script is a list of ``(opcode, [key-index,
...])`` tuples with small-integer opcodes and key indices, so hypothesis
shrinks toward short scripts over low keys.  Sequences that failed during
development are pinned in ``CORPUS`` and replayed against every backend
config on every run (the regression corpus the suite grows by: paste a
failing ``script`` repr here).

Like the property suite (test_dhash_property.py), the generator never
re-inserts a currently-live key: the paper's own insert has set semantics
whose duplicate-across-tables corner (new copy wins at migration) is pinned
by explicit unit tests instead — a dict oracle cannot time the mid-epoch
value switch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # the corpus replay below runs even without hypothesis installed
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev containers without dev deps
    HAVE_HYPOTHESIS = False

from repro.core import dhash

I32 = jnp.int32
Q = 8                      # fixed batch width (masked) to avoid recompiles
KEYS = list(range(1, 25))  # small universe -> plenty of collisions/dups
CAPACITY = 48              # comfortably holds the whole universe
CHUNK = 16

OP_INSERT, OP_DELETE, OP_LOOKUP, OP_START, OP_STEP = range(5)

if HAVE_HYPOTHESIS:
    _op = st.tuples(st.integers(0, 4),
                    st.lists(st.sampled_from(KEYS), min_size=1, max_size=Q))
    _script = st.lists(_op, min_size=3, max_size=24)

_FNS = {
    "insert": jax.jit(dhash.insert),
    "delete": jax.jit(dhash.delete),
    "lookup": jax.jit(dhash.lookup),
    "step": jax.jit(dhash.rebuild_step),
    "done": jax.jit(dhash.rebuild_done),
}

# Previously-found failing sequences (shrunk), replayed on every run against
# every backend config.  Grow this list whenever the fuzzer finds a new one.
CORPUS = [
    # delete during the hazard window, then re-insert the same key mid-epoch
    [(OP_INSERT, [1, 2, 3, 4, 5]), (OP_START, [1]), (OP_STEP, [1]),
     (OP_DELETE, [2, 3]), (OP_INSERT, [2]), (OP_LOOKUP, [1, 2, 3, 4, 5])],
    # duplicate keys inside one batch, masked tails, rebuild straddling
    [(OP_INSERT, [7, 7, 7, 8]), (OP_STEP, [1]), (OP_START, [2]),
     (OP_DELETE, [7, 7]), (OP_STEP, [1]), (OP_STEP, [1]),
     (OP_LOOKUP, [7, 8, 9])],
    # back-to-back rebuild starts (second must be a no-op while in flight)
    [(OP_INSERT, [10, 11, 12]), (OP_START, [1]), (OP_START, [2]),
     (OP_STEP, [1]), (OP_INSERT, [13]), (OP_STEP, [1]),
     (OP_DELETE, [10, 13]), (OP_LOOKUP, [10, 11, 12, 13])],
    # churn: every key inserted, deleted, and re-inserted across two epochs
    [(OP_INSERT, [1, 2, 3, 4, 5, 6, 7, 8]), (OP_START, [1]),
     (OP_STEP, [1]), (OP_STEP, [1]), (OP_DELETE, [1, 2, 3, 4]),
     (OP_STEP, [1]), (OP_INSERT, [1, 2]), (OP_STEP, [1]), (OP_STEP, [1]),
     (OP_START, [2]), (OP_STEP, [1]), (OP_DELETE, [5, 1]),
     (OP_LOOKUP, [1, 2, 3, 4, 5, 6, 7, 8])],
]

BACKEND_PARAMS = [(b, f) for b in ("linear", "twochoice", "chain", "cuckoo")
                  for f in (False, True)]


def _pad(keys: list[int]):
    ks = np.zeros(Q, np.int32)
    mask = np.zeros(Q, bool)
    ks[: len(keys)] = keys[:Q]
    mask[: len(keys)] = True
    return jnp.asarray(ks), jnp.asarray(mask)


def _grown_table(backend: str, growth: int, seed: int):
    """A rebuild target sized ``growth``x the base capacity (same backend
    shape rules as dhash.make)."""
    return dhash._make_table(backend, CAPACITY * growth, seed)


def run_script(backend: str, fused: bool, growth: int, script, seed: int):
    """Execute one encoded op sequence against dhash and a dict oracle,
    checking lookups, values, and ok flags at every step; then drain any
    in-flight rebuild and verify final membership of the whole universe."""
    d = dhash.make(backend, capacity=CAPACITY, chunk=CHUNK,
                   seed=seed % 7, fused=fused)
    oracle: dict[int, int] = {}
    rebuilding = False
    rb_seed = seed

    for step_no, (opcode, payload) in enumerate(script):
        if opcode == OP_INSERT:
            # never re-insert a live key (see module docstring); dedupe is
            # exercised via in-batch duplicates instead
            ks, mask = _pad(payload)
            mask = mask & jnp.asarray(
                [k not in oracle for k in np.asarray(ks)])
            vals = ks * 1000 + step_no
            d, ok = _FNS["insert"](d, ks, vals, mask)
            seen: set[int] = set()
            for i, k in enumerate(np.asarray(ks).tolist()):
                expect = bool(mask[i]) and k not in seen
                assert bool(ok[i]) == expect, \
                    (backend, fused, growth, step_no, "insert ok", k)
                if expect:
                    oracle[k] = k * 1000 + step_no
                seen.add(k)
        elif opcode == OP_DELETE:
            ks, mask = _pad(payload)
            d, ok = _FNS["delete"](d, ks, mask)
            seen = set()
            for i, k in enumerate(np.asarray(ks).tolist()):
                expect = bool(mask[i]) and k in oracle and k not in seen
                assert bool(ok[i]) == expect, \
                    (backend, fused, growth, step_no, "delete ok", k)
                if expect:
                    del oracle[k]
                seen.add(k)
        elif opcode == OP_LOOKUP:
            ks, mask = _pad(payload)
            found, vals = _FNS["lookup"](d, ks)
            for i, k in enumerate(np.asarray(ks).tolist()):
                if not bool(mask[i]):
                    continue
                assert bool(found[i]) == (k in oracle), \
                    (backend, fused, growth, step_no, "lookup found", k)
                if k in oracle:
                    assert int(vals[i]) == oracle[k], \
                        (backend, fused, growth, step_no, "lookup val", k)
        elif opcode == OP_START:
            if not rebuilding:
                rb_seed += 1
                d = dhash.rebuild_start(
                    d, new_table=_grown_table(backend, growth, rb_seed),
                    seed=rb_seed)
                rebuilding = True
            # a second start while in flight is the paper's trylock -EBUSY:
            # modelled as a no-op (the engine's request_rebuild declines)
        elif opcode == OP_STEP:
            d = _FNS["step"](d)
            if rebuilding and bool(jax.device_get(_FNS["done"](d))):
                d = dhash.rebuild_finish(d)
                rebuilding = False

    # drain: finish any in-flight rebuild, then check the whole universe
    # (bound derives from the PHYSICAL slot count — backends round the
    # logical capacity up, e.g. twochoice allocates nbuckets*width slots)
    from repro.core import buckets
    max_slots = max(buckets.capacity_of(d.old), buckets.capacity_of(d.new))
    for _ in range(2 * (max_slots // CHUNK) + 6):
        if not rebuilding:
            break
        d = _FNS["step"](d)
        if bool(jax.device_get(_FNS["done"](d))):
            d = dhash.rebuild_finish(d)
            rebuilding = False
    assert not rebuilding, (backend, fused, growth, "rebuild never drained")

    ks = jnp.asarray(np.asarray(KEYS, np.int32))
    found, vals = _FNS["lookup"](d, ks)
    for i, k in enumerate(KEYS):
        assert bool(found[i]) == (k in oracle), \
            (backend, fused, growth, "final membership", k)
        if k in oracle:
            assert int(vals[i]) == oracle[k], \
                (backend, fused, growth, "final val", k)
    assert int(dhash.count_items(d)) == len(oracle), (backend, fused, growth)


if HAVE_HYPOTHESIS:
    @pytest.mark.parametrize("backend,fused", BACKEND_PARAMS)
    @settings(max_examples=6, deadline=None)
    @given(script=_script, growth=st.sampled_from([1, 4]),
           seed=st.integers(0, 2**16))
    def test_differential_op_sequences(backend, fused, script, growth, seed):
        run_script(backend, fused, growth, script, seed)


@pytest.mark.parametrize("backend,fused", BACKEND_PARAMS)
def test_differential_regression_corpus(backend, fused):
    """Replay every previously-found failing sequence against every backend
    config, at the spicier 4x growth."""
    for i, script in enumerate(CORPUS):
        run_script(backend, fused, 4, script, seed=1000 + i)
