"""Paper §6.2 robustness claim: throughput past core saturation.

"When the number of worker threads exceeds the number of CPU cores, the
performance of DHASH increases slightly ... The performance of other
alternatives becomes flat or decreases due to the increased contention on
bucket locks."

SPMD mapping: batch width Q grows far beyond any fixed parallel resource;
DHash's per-op cost amortizes (vectorization), while the lock-modelled
tables' serialization rounds grow with Q/B and their throughput flattens or
falls.

``skew > 0`` draws lookup/delete keys from the suite's SHARED zipf skew
source (``common.zipf_owners`` — the same generator the routed-stack bench
uses for tenant load): hot-key concentration models the adversarial
popularity distribution the capped tenant router is gated under.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks.common import ALGOS, UNIVERSE, Workload, run_throughput

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def run(alpha=200, qs=(512, 2048, 8192, 16384), *, skew=0.0, quiet=False):
    nbuckets = 64
    n = alpha * nbuckets
    rng = np.random.default_rng(0)
    present = rng.choice(UNIVERSE, size=n, replace=False).astype(np.int32)
    tag = f" zipf(a={skew})" if skew > 0 else ""
    rows = []
    for name in ("DHash", "HT-RHT", "HT-Xu"):
        drv = ALGOS[name](nbuckets, n, seed=1)
        drv.populate(present)
        series = []
        for q in qs:
            wl = Workload(q=q, mix=(80, 10, 10), skew=skew)
            mops = run_throughput(drv, wl, present, steps=4,
                                  rng=np.random.default_rng(q)) / 1e6
            series.append(mops)
            rows.append((drv.name, q, mops))
            if not quiet:
                print(f"{drv.name:14s} Q={q:<6d}{tag} {mops:8.3f} Mops/s")
        trend = series[-1] / series[0]
        if not quiet:
            print(f"[summary] {drv.name}{tag}: Q x{qs[-1]//qs[0]} -> "
                  f"throughput x{trend:.2f} "
                  f"({'scales' if trend > 1.5 else 'flat/degrades'})")
    return rows


def run_elastic(*, q=512, capacity0=1024, phase_steps=10, quiet=False,
                out_path=None):
    """Elastic burst scenario: steady -> burst -> drain -> recovered on one
    policy-driven ``DHashEngine``.

    The acceptance story from small_hash.c's trigger set: the load-factor
    watermarks grow the table under an insert burst and shrink it back after
    a drain, the hysteresis band keeps the boundary flap-free (``flaps`` is
    the count of resizes fired during the constant-population hold windows —
    STRUCTURAL, baseline 0), and the throughput cliff through the whole
    round trip stays above 0.5x steady state (``cliff_ratio`` — RATIO
    gated).  The jaxpr section proves the telemetry is free: the counted
    lookup and the policy step add ZERO sorts / pallas_calls over the plain
    fused lookup (STRUCTURAL).

    Emits BENCH_elastic.json for the CI perf gate.
    """
    import jax
    import jax.numpy as jnp

    from benchmarks.common import count_primitives
    from repro.core import dhash, engine, policy as elastic

    rng = np.random.default_rng(0)
    pol = elastic.make(min_capacity=64)
    eng = engine.DHashEngine(
        dhash.make("linear", capacity=capacity0, chunk=256, seed=1,
                   fused=False),
        policy=pol, poll_every=1)

    base = rng.choice(UNIVERSE, size=700, replace=False).astype(np.int32)
    burst = rng.choice(
        np.setdiff1d(rng.integers(1, UNIVERSE, 40_000).astype(np.int32),
                     base),
        size=phase_steps * q, replace=False).astype(np.int32)
    none_k = np.zeros(q, np.int32)
    none_m = np.zeros(q, bool)

    # resize event log: "G"/"S" in firing order (poll_every=1 -> exact).
    # A FLAP is a direction reversal beyond the one expected grow->shrink
    # turn of the round trip; same-direction repeats (capacity chase under
    # a continuing burst) are legitimate.
    events: list[str] = []
    seen = [0, 0]

    def record():
        g, s = eng.stats.grows, eng.stats.shrinks
        events.extend("G" * (g - seen[0]) + "S" * (s - seen[1]))
        seen[0], seen[1] = g, s

    def drive(batch):
        out = eng.step(*batch)
        record()
        return out

    for i in range(0, base.size, q):          # populate + compile warmup
        pad = np.resize(base[i:i + q], q)
        m = np.zeros(q, bool)
        m[:min(q, base.size - i)] = True
        drive((pad, pad, pad, none_k, m, none_m))

    def phase(n_steps, make_batch):
        """Drive n_steps (lookup(q) + insert(q) + delete(q) each), timing
        every step individually; the phase throughput is the MIN-of-steps
        wall clock (the suite's min-of-N protocol): a resize mid-phase
        retraces the jitted step for the new table shape, and that one-time
        compile stall is not the steady per-step cost under test."""
        best = float("inf")
        for s in range(n_steps):
            t0 = time.perf_counter()
            jax.block_until_ready(drive(make_batch(s)))
            best = min(best, time.perf_counter() - t0)
        return 3 * q / best / 1e6, best   # Mops/s, seconds/step

    def lookups_only(s):
        lk = rng.choice(base, q).astype(np.int32)
        return (lk, none_k, none_k, none_k, none_m, none_m)

    phases = {}
    mops, dt = phase(phase_steps, lookups_only)          # steady state
    phases["steady"] = {"mops": mops}

    def burst_batch(s):
        ik = burst[s * q:(s + 1) * q]
        return (rng.choice(base, q).astype(np.int32), ik, ik, none_k,
                np.ones(q, bool), none_m)

    mops, dt = phase(phase_steps, burst_batch)           # insert burst
    phases["burst"] = {"mops": mops}
    phase(phase_steps, lookups_only)                     # hold at burst load
    grows_burst = eng.stats.grows

    def drain_batch(s):
        dk = burst[s * q:(s + 1) * q]
        return (rng.choice(base, q).astype(np.int32), none_k, none_k, dk,
                none_m, np.ones(q, bool))

    mops, dt = phase(phase_steps, drain_batch)           # delete the burst
    # drain base too, down to a population far below the low watermark
    for i in range(0, 512, q):
        dk = base[i:i + q]
        drive((rng.choice(base, q).astype(np.int32), none_k, none_k,
               np.resize(dk, q), none_m,
               np.arange(q) < min(q, 512 - i)))
    phases["drain"] = {"mops": mops}

    # settle: the drain's tombstones first fire an on-device reclaim rehash
    # (same-shape, holds the rebuild trylock), and only then can the shrink
    # start + complete its own migration -- drive until it lands
    for _ in range(200):
        drive(lookups_only(0))
        if eng.stats.shrinks >= 1 and not bool(
                jax.device_get(eng.state.rebuilding)):
            break
    shrinks = eng.stats.shrinks
    mops, dt = phase(phase_steps, lookups_only)          # recovered steady
    phases["recovered"] = {"mops": mops}

    turns = sum(1 for a, b in zip(events, events[1:]) if a != b)
    flaps = max(0, turns - 1)   # one G->S turn IS the round trip
    cliff = min(p["mops"] for p in phases.values()) / phases["steady"]["mops"]
    from repro.core import backend as backends
    be = backends.get(eng.state.backend)
    final_slots = int(be.capacity_of(eng.state.old))
    final_live = int(jax.device_get(be.count_live(eng.state.old)))

    # -- jaxpr proof: telemetry + policy are pass-free (fused linear) -------
    df = dhash.make("linear", capacity=capacity0, seed=3, fused=True)
    ks = jnp.zeros((q,), jnp.int32)
    plain = count_primitives(
        jax.make_jaxpr(dhash.lookup)(df, ks), ("sort", "pallas_call"))
    counted = count_primitives(
        jax.make_jaxpr(lambda d, k: dhash.lookup_counted(d, k, probe_hi=7))(
            df, ks), ("sort", "pallas_call"))
    pol_f = elastic.make(in_place=True)
    pstep = count_primitives(
        jax.make_jaxpr(elastic.policy_step)(pol_f, df),
        ("sort", "pallas_call"))
    assert counted == plain, (counted, plain)
    assert pstep == {"sort": 0, "pallas_call": 0}, pstep

    result = {
        "q": q, "capacity0": capacity0, "phase_steps": phase_steps,
        "interpret": True, **phases,
        "cliff_ratio": cliff,
        "grows": int(eng.stats.grows), "shrinks": int(shrinks),
        "flaps": int(flaps), "resize_events": "".join(events),
        "final_slots": final_slots,
        "final_load": final_live / final_slots,
        "counted_lookup": counted, "plain_lookup": plain,
        "policy_step": pstep,
    }
    out = pathlib.Path(out_path) if out_path else _REPO_ROOT / "BENCH_elastic.json"
    out.write_text(json.dumps(result, indent=2) + "\n")
    assert grows_burst >= 1, "burst never triggered a grow"
    assert shrinks >= 1, "drain never triggered a shrink"
    assert flaps == 0, f"{flaps} resize flap(s): events {''.join(events)}"
    assert cliff >= 0.5, f"throughput cliff {cliff:.2f}x below 0.5x steady"
    if not quiet:
        for name, p in phases.items():
            print(f"elastic/{name:10s} {p['mops']:8.3f} Mops/s")
        print(f"[summary] cliff {cliff:.2f}x, {eng.stats.grows} grow(s) / "
              f"{shrinks} shrink(s), {flaps} flap(s), final load "
              f"{result['final_load']:.3f} @ {final_slots} slots -> {out}")
    return result


if __name__ == "__main__":
    run()                  # uniform keys (the paper's §6.2 setup)
    run(skew=1.2)          # hot-key zipf via the shared skew source
    run_elastic()          # elastic burst round trip (BENCH_elastic.json)
