"""Blockwise GQA attention: one implementation covering every arch variant.

Variants are driven by (possibly per-layer traced) scalars so heterogeneous
stacks (gemma2/3 local:global alternation) lower as ONE scanned layer body:

* ``window``  — 0 = global; >0 = sliding-window (traced per-layer scalar)
* ``softcap`` — gemma2 attn-logit tanh cap (0 = off)
* ``causal``  — static (False for hubert's bidirectional encoder)
* GQA         — n_kv_heads <= n_heads, query heads grouped over kv heads

Memory safety: queries are processed in chunks of ``chunk`` via lax.scan, so
peak score memory is [B, Hkv, G, chunk, S_k] instead of S_q x S_k — required
for the 32k-prefill shapes (a dense 32k x 32k score tensor would be ~4 GiB
per head).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32
NEG_INF = -2.0e38


def _mask(qp, kp, *, causal: bool, window) -> jax.Array:
    """qp: [..., C], kp: [..., Sk] -> bool [..., C, Sk]. window traced ok."""
    d = qp[..., :, None] - kp[..., None, :]
    m = jnp.ones(d.shape, bool)
    if causal:
        m &= d >= 0
    m &= (window <= 0) | (d < window)      # sliding window (both sides capped
    if not causal:                          # for bidirectional local attn)
        m &= (window <= 0) | (d > -window)
    return m


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              q_pos: jax.Array, k_pos: jax.Array, causal: bool = True,
              window=0, softcap: float = 0.0, chunk: int = 1024) -> jax.Array:
    """q: [B,Sq,Hq,hd], k/v: [B,Sk,Hkv,hd], q_pos: [B,Sq], k_pos: [B,Sk]."""
    b, sq, hq, hd = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, sq, hkv, g, hd)

    def one_chunk(qc, qpc):
        # qc: [B,C,Hkv,G,hd] -> scores [B,Hkv,G,C,Sk]
        s = jnp.einsum("bchgd,bshd->bhgcs", qc, k).astype(F32) * scale
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        m = _mask(qpc, k_pos, causal=causal, window=window)  # [B,C,Sk]
        s = jnp.where(m[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        # fully-masked rows (can happen with windows) -> zero out
        p = jnp.where(m[:, None, None].any(-1, keepdims=True), p, 0.0)
        return jnp.einsum("bhgcs,bshd->bchgd", p.astype(v.dtype), v)

    if sq <= chunk:
        out = one_chunk(qg, q_pos)
    else:
        assert sq % chunk == 0, (sq, chunk)
        n = sq // chunk
        qcs = qg.reshape(b, n, chunk, hkv, g, hd).swapaxes(0, 1)
        qps = q_pos.reshape(b, n, chunk).swapaxes(0, 1)
        _, outs = jax.lax.scan(lambda c, inp: (c, one_chunk(*inp)), None, (qcs, qps))
        out = outs.swapaxes(0, 1).reshape(b, sq, hkv, g, hd)
    return out.reshape(b, sq, hq, hd)


# ---------------------------------------------------------------------------
# block-level wrappers (projection weights live in transformer.py's stacks)
# ---------------------------------------------------------------------------

def project_qkv(x, wq, wk, wv, *, qk_norm_scale=None):
    """x: [B,S,D]; wq: [D,Hq,hd]; wk/wv: [D,Hkv,hd]."""
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bsd,dhk->bshk", x, wk)
    v = jnp.einsum("bsd,dhk->bshk", x, wv)
    if qk_norm_scale is not None:  # qwen3: per-head RMS on q and k
        qs, ks = qk_norm_scale
        from repro.models.layers import rms_norm
        q = rms_norm(q, qs)
        k = rms_norm(k, ks)
    return q, k, v


def decode_attention(q1, k_cache, v_cache, cache_len, *, window=0,
                     softcap: float = 0.0) -> jax.Array:
    """One-token decode: q1 [B,1,Hq,hd] vs cache [B,Smax,Hkv,hd].

    Entries at position >= cache_len are masked; sliding windows mask
    positions older than cache_len - window."""
    b, smax, hkv, hd = k_cache.shape
    hq = q1.shape[2]
    g = hq // hkv
    scale = 1.0 / np.sqrt(hd)
    qg = q1.reshape(b, 1, hkv, g, hd)
    s = jnp.einsum("bchgd,bshd->bhgcs", qg, k_cache).astype(F32) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    pos = jnp.arange(smax, dtype=jnp.int32)[None, :]          # [1, Smax]
    valid = pos < cache_len[:, None]
    valid &= (window <= 0) | (pos >= cache_len[:, None] - window)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgcs,bshd->bchgd", p, v_cache)
    return out.reshape(b, 1, hq, hd)
