"""RWKV6 ("Finch") block: data-dependent per-channel decay.

Time-mix uses the exact recurrence  S_t = diag(w_t) S_{t-1} + k_t v_t^T,
out_t = r_t (S_{t-1} + diag(u) k_t v_t^T),  run as a lax.scan over time
(vectorized over batch x heads; numerically exact — the per-channel decay
makes the chunked factorization fp32-unsafe, see DESIGN.md).  Decode is the
same recurrence for one step.

Simplifications vs. the reference (noted in DESIGN.md): static token-shift
lerp for r/k/v/g (the decay w keeps its data-dependent LoRA, which is the
paper's defining feature), per-head RMS instead of GroupNorm on the output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm

F32 = jnp.float32


def _token_shift(x: jax.Array, prev: jax.Array | None = None):
    """x: [B,S,D] -> x shifted right by one (first position gets ``prev`` or 0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddw(xm: jax.Array, p: dict) -> jax.Array:
    """Data-dependent decay: w = exp(-exp(w0 + tanh(x @ w1) @ w2)) in (0,1)."""
    lora = jnp.einsum("bsd,dr->bsr", xm, p["w_lora_a"])
    wraw = p["w0"].astype(F32) + jnp.einsum(
        "bsr,rd->bsd", jnp.tanh(lora.astype(F32)), p["w_lora_b"].astype(F32))
    return -jnp.exp(jnp.clip(wraw, -10.0, 4.0))          # log w  (<= 0)


def wkv_scan(r, k, v, logw, u, s0=None):
    """r/k/v: [B,S,NH,HS]; logw: [B,S,NH,HS]; u: [NH,HS].
    Returns out [B,S,NH,HS] and final state [B,NH,HS,HS]."""
    b, s, nh, hs = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, nh, hs, hs), F32)

    def body(state, inp):
        rt, kt, vt, lwt = inp                             # [B,NH,HS]
        kv = kt[..., :, None] * vt[..., None, :]          # [B,NH,HS,HS]
        out = jnp.einsum("bhk,bhkv->bhv", rt, state + u[None, :, :, None] * kv)
        state = jnp.exp(lwt)[..., None] * state + kv
        return state, out

    xs = tuple(t.swapaxes(0, 1).astype(F32) for t in (r, k, v, logw))
    state, outs = jax.lax.scan(body, s0, xs)
    return outs.swapaxes(0, 1), state


def wkv_scan_chunked(r, k, v, logw, u, s0=None, *, chunk: int = 128):
    """Time-chunked wkv: outer scan over chunks of ``chunk`` steps with the
    inner recurrence rematerialized (jax.checkpoint).

    Identical numerics to wkv_scan (it IS the same recurrence); the win is
    the backward-pass memory profile: states are stashed only at chunk
    boundaries (S/chunk saves instead of S), the §Perf fix for the
    rwkv6 train_4k memory wall.
    """
    b, s, nh, hs = r.shape
    if s % chunk != 0 or s <= chunk:
        return wkv_scan(r, k, v, logw, u, s0)
    n = s // chunk
    if s0 is None:
        s0 = jnp.zeros((b, nh, hs, hs), F32)

    def ck(t):
        return t.reshape(b, n, chunk, nh, hs).swapaxes(0, 1)

    @jax.checkpoint
    def one_chunk(state, inp):
        rc, kc, vc, lwc = inp
        out, state = wkv_scan(rc, kc, vc, lwc, u, state)
        return state, out

    state, outs = jax.lax.scan(one_chunk, s0, (ck(r), ck(k), ck(v), ck(logw)))
    return outs.swapaxes(0, 1).reshape(b, s, nh, hs), state


def rwkv6_time_mix(x: jax.Array, p: dict, *, n_heads: int, head_size: int,
                   prev_token: jax.Array | None = None, s0=None,
                   chunk: int = 0, tp_state: bool = False):
    b, s, d = x.shape
    xs = _token_shift(x, prev_token)
    def mix(m):   # lerp toward shifted
        return x + (xs - x) * m.astype(x.dtype)
    xr, xk, xv, xg, xw = (mix(p[f"mu_{n}"]) for n in ("r", "k", "v", "g", "w"))
    if "w_rkvg" in p:
        # §Perf rwkv6 fused projections: ONE matmul (stacked [4,d,d] weight,
        # split on the unsharded stack axis) -> one bwd dx all-reduce
        # instead of four — same trick as gemma3's stacked gate/up.
        xs4 = jnp.stack([xr, xk, xv, xg], axis=2)          # [B,S,4,D]
        rkvg = jnp.einsum("bskd,kde->bske", xs4, p["w_rkvg"])
        r = rkvg[:, :, 0].reshape(b, s, n_heads, head_size)
        k = rkvg[:, :, 1].reshape(b, s, n_heads, head_size)
        v = rkvg[:, :, 2].reshape(b, s, n_heads, head_size)
        g = jax.nn.silu(rkvg[:, :, 3].astype(F32))
    else:
        r = jnp.einsum("bsd,de->bse", xr, p["w_r"]).reshape(b, s, n_heads, head_size)
        k = jnp.einsum("bsd,de->bse", xk, p["w_k"]).reshape(b, s, n_heads, head_size)
        v = jnp.einsum("bsd,de->bse", xv, p["w_v"]).reshape(b, s, n_heads, head_size)
        g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["w_g"]).astype(F32))
    logw = _ddw(xw, p).reshape(b, s, n_heads, head_size)
    uu = p["u"].reshape(n_heads, head_size)
    if tp_state == "value":
        # §Perf rwkv6 iteration 3 (REFUTED, kept for the record): shard the
        # VALUE axis of v / the state over "model" — SPMD fought the
        # constraint inside the loop ("involuntary full rematerialization")
        # and the collective term got WORSE.
        from repro.models.sharding import constrain
        r = constrain(r, "dp", None, None, None)
        k = constrain(k, "dp", None, None, None)
        logw = constrain(logw, "dp", None, None, None)
        v = constrain(v, "dp", None, None, "tp")
    elif tp_state == "replicated":
        # §Perf rwkv6 iteration 4: replicate ALL recurrence inputs over the
        # model axis (one all-gather outside the loop); every chip runs all
        # heads — the recurrence is tiny compute, and the in-loop per-step
        # collectives disappear entirely.
        from repro.models.sharding import constrain
        r = constrain(r, "dp", None, None, None)
        k = constrain(k, "dp", None, None, None)
        logw = constrain(logw, "dp", None, None, None)
        v = constrain(v, "dp", None, None, None)
    if chunk > 0:
        out, state = wkv_scan_chunked(r, k, v, logw, uu, s0, chunk=chunk)
    else:
        out, state = wkv_scan(r, k, v, logw, uu, s0)
    out = rms_norm(out, p["ln_x"]).reshape(b, s, d)
    out = (out.astype(F32) * g).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", out, p["w_o"]), state


def rwkv6_channel_mix(x: jax.Array, p: dict, prev_token=None):
    xs = _token_shift(x, prev_token)
    xk = x + (xs - x) * p["cmu_k"].astype(x.dtype)
    xr = x + (xs - x) * p["cmu_r"].astype(x.dtype)
    kk = jnp.einsum("bsd,df->bsf", xk, p["c_k"])
    kk = jnp.square(jax.nn.relu(kk.astype(F32))).astype(x.dtype)
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["c_r"]).astype(F32)).astype(x.dtype)
    return rr * jnp.einsum("bsf,fd->bsd", kk, p["c_v"])


def rwkv6_init(key, d_model: int, d_ff: int, *, n_heads: int, head_size: int,
               lora_r: int = 64, dtype=jnp.bfloat16, fused_rkvg: bool = False) -> dict:
    ks = jax.random.split(key, 10)
    def init(k, sh, s):
        return (jax.random.normal(k, sh, F32) * s).astype(dtype)
    d = d_model
    p = {f"mu_{n}": jnp.full((d,), 0.5, F32) for n in ("r", "k", "v", "g", "w")}
    p |= {"cmu_k": jnp.full((d,), 0.5, F32), "cmu_r": jnp.full((d,), 0.5, F32)}
    if fused_rkvg:
        p |= {"w_rkvg": init(ks[0], (4, d, d), d ** -0.5)}
    else:
        p |= {"w_r": init(ks[0], (d, d), d ** -0.5),
              "w_k": init(ks[1], (d, d), d ** -0.5),
              "w_v": init(ks[2], (d, d), d ** -0.5),
              "w_g": init(ks[3], (d, d), d ** -0.5)}
    p |= {
        "w_o": init(ks[4], (d, d), d ** -0.5),
        "w0": jnp.full((d,), -2.0, F32),
        "w_lora_a": init(ks[5], (d, lora_r), d ** -0.5),
        "w_lora_b": init(ks[6], (lora_r, d), lora_r ** -0.5),
        "u": jnp.zeros((d,), F32),
        "ln_x": jnp.zeros((head_size,), dtype),
        "c_k": init(ks[7], (d, d_ff), d ** -0.5),
        "c_v": init(ks[8], (d_ff, d), d_ff ** -0.5),
        "c_r": init(ks[9], (d, d), d ** -0.5),
    }
    return p
