"""ElasticPolicy: watermark-driven grow/shrink decisions for DHash tables.

The paper's rebuild machinery can *execute* a capacity change (live
migration, Lemma 4.1 ordered check), but nothing in PR 1-6 *decides*
capacity — rebuild targets were chosen manually.  This module is that
decision layer, a pure-pytree rendering of the trigger set in SNIPPETS.md
snippet 3 (``small_hash.c``):

* **Load-factor watermarks with hysteresis.**  ``small_hash`` sets a
  desired count per anchor and derives a high watermark at
  ``MIN_EXPAND_WATERMARK_FACTOR``x desired (grow above it) and a low
  watermark at ``desired / SHRINK_WATERMARK_FACTOR`` (shrink below it).
  Here the same math runs in load-factor terms over the backend's slot
  capacity: grow when ``live > grow_load * slots``, shrink when
  ``live < grow_load / (expand_headroom * shrink_factor) * slots``.  The
  resize target is ``live * expand_headroom`` entries, which lands the
  post-resize load strictly *between* the watermarks for every power-of-two
  slot rounding the backends' ``make`` applies — grow/shrink cannot flap at
  a boundary by construction (see docs/KERNELS.md for the band arithmetic).

* **Expensive-lookup counter.**  ``small_hash`` enlarges even below the
  watermark when ``expensive_lookup_count`` crosses
  ``ENLARGE_DUE_TO_EXPENSIVE_LOOKUP_AFTER`` per
  ``BETWEEN_LOOKUP_REPORT_COUNT`` lookups (probe chains past
  ``EXPENSIVE_LOOKUP_THRESHOLD`` hops — clustering the load factor alone
  does not see).  ``DHashState`` carries the two counters
  (``lookups`` / ``expensive``); ``dhash.lookup_counted`` feeds them from
  the probe-length telemetry of the backend's loc-emitting lookup (the
  fused kernels' ``loc`` output — zero extra passes), and ``policy_step``
  fires the growth trigger when the expensive fraction crosses
  ``enlarge_after / report_every``.

* **Adaptive nres_cap.**  A grown rebuild target spreads a query tile's
  windows over ~``new_slots / old_slots`` new-table slabs; past the
  two-level tile map's residency cap the fused probe escapes to the jnp
  fallback.  ``adapt_nres_cap`` grows the residency with the planned ratio
  (bounded by ``nres_cap_max``) so a policy-driven resize stays
  kernel-resident instead of escaping — applied host-side by the engine
  when it materializes the resize (nres_cap is static table metadata).

Two execution modes:

* **resize mode** (``in_place=False``, single tables): ``policy_step``
  publishes a *plan* (``want_grow`` / ``want_shrink`` / ``target_capacity``)
  that the engine's host poll turns into a physical ``rebuild_start`` into a
  re-sized table; tombstone pressure alone fires an on-device same-shape
  rehash (``rebuild_autostart``).
* **in-place mode** (``in_place=True``, vmapped stacks / tenant tables —
  static shapes cannot change under vmap): every trigger fires the
  on-device same-shape rehash, reclaiming tombstones and re-randomizing the
  hash function, with an ``armed`` latch providing the hysteresis (a fired
  table must drain below the re-arm watermark before it may fire again).

Everything device-side is shape-stable and vmappable; all configuration is
static aux-data, so a policy travels inside jitted steps for free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backends
from repro.core import dhash
from repro.core.struct_utils import pytree_dataclass, replace

I32 = jnp.int32

# small_hash.c trigger constants (SNIPPETS.md snippet 3)
MIN_EXPAND_WATERMARK_FACTOR = 2.0
SHRINK_WATERMARK_FACTOR = 4.0
EXPENSIVE_LOOKUP_THRESHOLD = 7
ENLARGE_DUE_TO_EXPENSIVE_LOOKUP_AFTER = 2
BETWEEN_LOOKUP_REPORT_COUNT = 10


@pytree_dataclass(meta_fields=("grow_load", "expand_headroom", "shrink_factor",
                               "probe_hi", "enlarge_after", "report_every",
                               "min_lookups", "tomb_load", "min_capacity",
                               "max_capacity", "nres_cap_max", "in_place",
                               "place_headroom"))
class ElasticPolicy:
    """Pure-pytree elastic-capacity policy (configuration static, state
    arrays vmappable — a stack of tables stacks its policies)."""

    # -- static configuration (jit aux-data) --
    grow_load: float        # high watermark as a load factor over slots
    expand_headroom: float  # MIN_EXPAND_WATERMARK_FACTOR: resize target is
                            # live * headroom entries, so the post-resize
                            # load sits 1/headroom under the high watermark
    shrink_factor: float    # SHRINK_WATERMARK_FACTOR: low watermark is
                            # high / (headroom * shrink_factor)
    probe_hi: int           # EXPENSIVE_LOOKUP_THRESHOLD (probe hops)
    enlarge_after: int      # ENLARGE_DUE_TO_EXPENSIVE_LOOKUP_AFTER
    report_every: int       # BETWEEN_LOOKUP_REPORT_COUNT
    min_lookups: int        # sample floor before the probe trigger may fire
    tomb_load: float        # tombstone fraction that fires a reclaim rehash
    min_capacity: int       # entries floor for shrink targets
    max_capacity: int       # entries ceiling for grow targets
    nres_cap_max: int       # adapt_nres_cap upper bound
    in_place: bool          # True: triggers fire same-shape rehashes only
    place_headroom: float   # in-place liveness guard for bounded-placement
                            # backends (``be.bounded_placement``): a
                            # same-shape rehash only fires while
                            # live <= place_headroom * slots, so the reload
                            # into the fresh table cannot strand
                            # unplaceable keys in the hazard buffer
    # -- device state --
    armed: jax.Array            # bool: hysteresis latch for in-place fires
    want_grow: jax.Array        # bool: plan published for the host poll
    want_shrink: jax.Array      # bool
    target_capacity: jax.Array  # i32 entries (be.make units)
    fires: jax.Array            # i32: on-device autostart rehashes fired


def make(*, grow_load: float = 0.7,
         expand_headroom: float = MIN_EXPAND_WATERMARK_FACTOR,
         shrink_factor: float = SHRINK_WATERMARK_FACTOR,
         probe_hi: int = EXPENSIVE_LOOKUP_THRESHOLD,
         enlarge_after: int = ENLARGE_DUE_TO_EXPENSIVE_LOOKUP_AFTER,
         report_every: int = BETWEEN_LOOKUP_REPORT_COUNT,
         min_lookups: int = 256, tomb_load: float = 0.25,
         min_capacity: int = 64, max_capacity: int = 1 << 22,
         nres_cap_max: int = 64, in_place: bool = False,
         place_headroom: float = 0.85) -> ElasticPolicy:
    """Fresh policy with the small_hash.c defaults (armed, no plan)."""
    if not 0.0 < grow_load <= 1.0:
        raise ValueError(f"grow_load must be in (0, 1], got {grow_load}")
    if expand_headroom <= 1.0 or shrink_factor <= 1.0:
        raise ValueError("expand_headroom and shrink_factor must exceed 1 "
                         "(the hysteresis band would be empty)")
    if not 0.0 < place_headroom <= 1.0:
        raise ValueError(f"place_headroom must be in (0, 1], "
                         f"got {place_headroom}")
    return ElasticPolicy(
        grow_load=grow_load, expand_headroom=expand_headroom,
        shrink_factor=shrink_factor, probe_hi=probe_hi,
        enlarge_after=enlarge_after, report_every=report_every,
        min_lookups=min_lookups, tomb_load=tomb_load,
        min_capacity=min_capacity, max_capacity=max_capacity,
        nres_cap_max=nres_cap_max, in_place=in_place,
        place_headroom=place_headroom,
        armed=jnp.asarray(True),
        want_grow=jnp.asarray(False), want_shrink=jnp.asarray(False),
        target_capacity=jnp.asarray(min_capacity, I32),
        fires=jnp.asarray(0, I32))


def stack(pol: ElasticPolicy, n_tables: int) -> ElasticPolicy:
    """[T]-stacked copy of a policy (one latch/plan per table) for use with
    ``dhash.make_stack`` states under ``jax.vmap``."""
    return jax.tree_util.tree_map(
        lambda x: jnp.stack([x] * n_tables), pol)


def watermarks(pol: ElasticPolicy, slots: int) -> tuple[int, int]:
    """(high, low) live-entry watermarks for a table with ``slots`` slots —
    the small_hash.c ``set_watermarks`` math in load-factor terms."""
    high = int(slots * pol.grow_load)
    low = int(slots * pol.grow_load / (pol.expand_headroom * pol.shrink_factor))
    return high, low


def policy_step(pol: ElasticPolicy, d: dhash.DHashState, *,
                allow_autostart: bool = True):
    """One on-device policy evaluation.  Returns ``(pol', d')``.

    Reads the table's occupancy (live / tombstones, exact O(C) reductions)
    and the probe counters ``dhash.lookup_counted`` maintains, evaluates the
    trigger set, and either fires a same-shape ``rebuild_autostart``
    (in-place mode, or tombstone reclaim in resize mode) or publishes a
    grow/shrink plan for the engine's host poll.  All decisions are gated on
    ``~d.rebuilding`` — a table mid-epoch never re-triggers.

    ``allow_autostart=False`` suppresses the on-device rehash (plan only) —
    the engine passes this while old/new are shape-mismatched mid-resize,
    when an autostart would target the wrong geometry.
    """
    be = backends.get(d.backend)
    slots = be.capacity_of(d.old)          # static int (table metadata)
    live = be.count_live(d.old).astype(I32)
    tombs = be.count_tomb(d.old).astype(I32)
    high, low = watermarks(pol, slots)

    idle = ~d.rebuilding
    over = live > high
    under = live < low
    sampled = d.lookups >= pol.min_lookups
    # expensive/lookups >= enlarge_after/report_every, in integers
    probe_hot = sampled & (d.expensive * pol.report_every
                           >= d.lookups * pol.enlarge_after)
    tomb_hot = tombs > I32(int(slots * pol.tomb_load))
    # re-arm once the load has drained back inside the band (and the probe
    # telemetry is quiet) — the fired->drained->fired cycle of small_hash.
    # Gated on idle: mid-epoch extraction empties the OLD table, and that
    # transient low count must not re-arm the latch (a still-hot table
    # would refire the instant its rehash lands, churning forever).
    rearm = idle & (live <= I32(int(high / pol.expand_headroom))) & ~probe_hot
    armed = pol.armed | rearm

    target = jnp.clip(
        jnp.ceil(live.astype(jnp.float32) * pol.expand_headroom).astype(I32),
        pol.min_capacity, pol.max_capacity)

    if pol.in_place:
        # vmapped stacks cannot change static shape: every trigger becomes a
        # same-shape rehash (tombstone reclaim + fresh hash function), with
        # the armed latch as the hysteresis
        fire = idle & armed & (over | probe_hot | tomb_hot)
        if be.bounded_placement:
            # liveness guard: a same-shape rehash of a near-saturated
            # bounded-placement table (twochoice row pairs, cuckoo kick
            # exhaustion) can fail to place every extracted key under the
            # fresh hash functions, parking the remainder in the hazard
            # buffer indefinitely.  Hold the trigger until the load drains
            # below the placement headroom — the grow plan below still
            # publishes, so a host that CAN resize escapes the pressure.
            fire = fire & (live <= I32(int(slots * pol.place_headroom)))
        want_grow = idle & (over | probe_hot)
        want_shrink = idle & under
    else:
        # grow/shrink are host-applied resizes (the plan below); only
        # tombstone pressure fires the on-device same-shape rehash
        fire = idle & armed & tomb_hot & ~over & ~under
        want_grow = idle & (over | probe_hot)
        want_shrink = idle & under & ~probe_hot

    if allow_autostart:
        d = jax.lax.cond(fire, dhash.rebuild_autostart, lambda x: x, d)
    # a fire consumes the probe sample window (small_hash zeroes the
    # counters at every report boundary; we zero on action)
    d = replace(d,
                lookups=jnp.where(fire, 0, d.lookups).astype(I32),
                expensive=jnp.where(fire, 0, d.expensive).astype(I32))
    pol = replace(pol, armed=armed & ~fire,
                  want_grow=want_grow, want_shrink=want_shrink,
                  target_capacity=target,
                  fires=pol.fires + fire.astype(I32))
    return pol, d


def stack_policy_step(pol: ElasticPolicy, d: dhash.DHashState):
    """Vmapped ``policy_step`` over a [T] table stack + [T] policy stack
    (in-place mode: per-table same-shape rehashes, independent latches)."""
    return jax.vmap(lambda p, dd: policy_step(p, dd, allow_autostart=True)
                    )(pol, d)


# ---------------------------------------------------------------------------
# host-side helpers (plain python / numpy — used at poll boundaries)
# ---------------------------------------------------------------------------

def adapt_nres_cap(pol: ElasticPolicy, old_slots: int, new_slots: int, *,
                   base: int) -> int:
    """Tile-map residency for a rebuild into ``new_slots``: a query tile of
    old-sorted queries spans ~1 old slab, whose keys rehash into
    ~``new_slots/old_slots`` new-table blocks (+1 for window straddle).
    Growing the residency keeps the fused probe kernel-resident instead of
    escaping to the jnp fallback past the default 16 slabs; bounded by the
    policy's ``nres_cap_max``.  Never shrinks below the descriptor default
    ``base`` (shrink rebuilds concentrate, they don't spread)."""
    ratio = -(-int(new_slots) // max(int(old_slots), 1))
    return int(min(max(base, ratio + 1), pol.nres_cap_max))


def resolve_slots(be: backends.BucketBackend, target_entries: int) -> int:
    """Host: slot count ``be.make(target_entries)`` would allocate."""
    if be.slots_for is not None:
        return int(be.slots_for(int(target_entries)))
    probe = be.make(int(target_entries), 0)
    return int(be.capacity_of(probe))


def rehash_wanted(live_load, tomb_load, armed, rebuilding, *,
                  grow_load: float,
                  expand_headroom: float = MIN_EXPAND_WATERMARK_FACTOR,
                  tomb_load_hi: float = 0.25):
    """Host-side armed rehash trigger over load factors (numpy arrays or
    scalars — the serving engine's per-tenant poll).  Returns
    ``(want, armed')``: fire when armed and either the live load crossed
    ``grow_load`` or tombstones crossed ``tomb_load_hi``; re-arm only once
    the live load drains below ``grow_load / expand_headroom`` — the same
    hysteresis as the device-side latch, so a hot tenant rehashes once per
    excursion instead of every poll."""
    live_load = np.asarray(live_load)
    tomb_load = np.asarray(tomb_load)
    armed = np.asarray(armed, bool)
    rebuilding = np.asarray(rebuilding, bool)
    hot = (live_load > grow_load) | (tomb_load > tomb_load_hi)
    want = armed & hot & ~rebuilding
    rearm = live_load <= grow_load / expand_headroom
    return want, (armed | rearm) & ~want


class RouteCapController:
    """Spill-feedback adaptive routing cap (host-side, poll boundaries).

    The watermark+hysteresis idiom above applied to the tenant router:
    the controller watches the cumulative ``route_spill`` / ``route_drop``
    counters (``kvcache.table_load(with_spill=True)``), maintains an EWMA
    of **slab occupancy** — spill per poll over the slab width the current
    cap implies at the reference batch size ``q_ref`` — and walks
    ``cap_factor`` along a geometric ladder:

    * occupancy EWMA above ``occ_hi``: grow the cap by ``step`` (traffic
      keeps leaning on the slab; a wider primary absorbs it);
    * any dropped keys: grow IMMEDIATELY (a compact slab overflowed — the
      one signal that must never wait out a cooldown);
    * occupancy EWMA below ``occ_lo``: shrink the cap by ``step`` (the
      slab sits idle; narrower buffers win back the wire-bytes ratio).

    No-flap by construction: ``occ_hi / occ_lo`` (default 0.85 / 0.15 ≈
    5.7) exceeds the ladder ratio ``step`` (1.5), so a single move lands
    the post-move occupancy strictly inside the band — the opposite
    watermark cannot fire on the next poll; a watermark additionally only
    fires after the EWMA holds beyond it for ``cooldown`` CONSECUTIVE
    polls (persistence — one spiky poll of a bursty serving trace never
    moves the cap), and ``cooldown`` quiet polls must pass after any move
    (drops bypass both, never the ladder).  Ladder values are the finite
    set
    ``cap0 · step^k`` clamped to [cap_min, cap_max], and ``cap_factor``
    is static table metadata, so the jitted steps it parameterizes
    recompile a bounded number of times over any run.
    """

    def __init__(self, *, n_shards: int, q_ref: int,
                 cap_factor: float = 2.0, spill_slack: float = 1.0,
                 occ_hi: float = 0.85, occ_lo: float = 0.15,
                 ewma: float = 0.5, step: float = 1.5,
                 cap_min: float = 1.0, cap_max: float | None = None,
                 cooldown: int = 2):
        if not 0.0 < occ_lo < occ_hi <= 1.0:
            raise ValueError(f"need 0 < occ_lo < occ_hi <= 1, "
                             f"got ({occ_lo}, {occ_hi})")
        if step <= 1.0:
            raise ValueError(f"ladder step must exceed 1, got {step}")
        if occ_hi / occ_lo <= step:
            raise ValueError("watermark band occ_hi/occ_lo must exceed the "
                             "ladder step or moves could flap")
        self.n_shards = int(n_shards)
        self.q_ref = int(q_ref)
        self.cap_factor = float(cap_factor)
        self.spill_slack = float(spill_slack)
        self.occ_hi, self.occ_lo = float(occ_hi), float(occ_lo)
        self.ewma_alpha = float(ewma)
        self.step = float(step)
        self.cap_min = float(cap_min)
        # cap_factor = S means cap = Q: the overflow-proof full width
        self.cap_max = float(n_shards if cap_max is None else cap_max)
        self.cooldown = int(cooldown)
        self.occ = 0.0              # slab-occupancy EWMA (reseeds on a move)
        self.grows = self.shrinks = self.flaps = 0
        self._seeded = False
        self._spill_prev = self._drop_prev = 0
        self._since_move = self.cooldown + 1    # free to move at first poll
        self._last_dir = 0
        self._hi_streak = self._lo_streak = 0   # consecutive beyond-watermark

    def _slab_width(self) -> int:
        from repro.core.distributed import route_cap, route_spill_cap
        cap = route_cap(self.cap_factor, self.q_ref, self.n_shards)
        return route_spill_cap(self.q_ref, cap, self.spill_slack)

    def update(self, spill_total, dropped_total=0) -> float:
        """Feed one poll of the CUMULATIVE spill/drop counters (scalars —
        sum the per-tenant vectors); returns the cap_factor to run with
        (a static meta field: apply via ``replace(kv, cap_factor=...)``
        only when it changed)."""
        spill_total, dropped_total = int(spill_total), int(dropped_total)
        d_spill = spill_total - self._spill_prev
        d_drop = dropped_total - self._drop_prev
        self._spill_prev, self._drop_prev = spill_total, dropped_total
        occ = d_spill / max(self._slab_width(), 1)
        a = self.ewma_alpha
        self.occ = occ if not self._seeded else (1 - a) * self.occ + a * occ
        self._seeded = True
        self._since_move += 1

        # Persistence streaks: serving traffic is bursty poll-to-poll (zero
        # deltas between allocation events, spikes on sequence frees), so a
        # watermark only fires once the EWMA holds beyond it for `cooldown`
        # consecutive polls.  One spike never moves the cap; drops do.
        if self.occ > self.occ_hi:
            self._hi_streak += 1
            self._lo_streak = 0
        elif self.occ < self.occ_lo:
            self._lo_streak += 1
            self._hi_streak = 0
        else:
            self._hi_streak = self._lo_streak = 0

        direction = 0
        if d_drop > 0:
            direction = +1                       # bypasses cooldown + streak
        elif self._since_move > self.cooldown:
            if self._hi_streak >= max(self.cooldown, 1):
                direction = +1
            elif self._lo_streak >= max(self.cooldown, 1):
                direction = -1
        if direction > 0:
            new = min(self.cap_factor * self.step, self.cap_max)
        elif direction < 0:
            new = max(self.cap_factor / self.step, self.cap_min)
        else:
            new = self.cap_factor
        if new != self.cap_factor:
            # a flap is a REVERSAL at the first eligible poll after a move
            # — the no-flap construction promises the post-move occupancy
            # lands inside the band, so the opposite watermark cannot fire
            # the moment the cooldown expires.  (A reversal after a long
            # in-band stretch is a workload change, not a flap.)
            if direction == -self._last_dir and \
                    self._since_move <= self.cooldown + 1:
                self.flaps += 1
            if direction > 0:
                self.grows += 1
            else:
                self.shrinks += 1
            self._last_dir = direction
            self._since_move = 0
            self._hi_streak = self._lo_streak = 0
            self._seeded = False   # occupancy is defined by the NEW widths
            self.cap_factor = new
        return self.cap_factor

    def in_band(self) -> bool:
        """Host poll convenience: the occupancy EWMA sits inside the
        watermark band (converged — no move pending)."""
        return self.occ_lo <= self.occ <= self.occ_hi
