"""BucketBackend descriptor protocol: ONE registry entry per backend.

The paper's headline modularity claim ("DHash ... allows programmers to
select a variety of lock-free/wait-free set algorithms as the implementation
of hash table buckets") lives here.  A backend is a frozen ``BucketBackend``
descriptor bundling everything the DHash layer needs to drive it:

* its table constructor and sizing policy (``make``), the same-geometry
  rebuild-target constructor (``fresh_like``), and the on-device hash
  refresh (``reseed``);
* the plain jnp op set (``lookup``/``insert``/``delete``/``extract_chunk``/
  ``count_live``/``clear`` — the oracle surface, always present);
* the fused Pallas op set (``*_fused`` + the rebuild-epoch
  ``ordered_lookup_fused``/``ordered_delete_fused`` — ``None`` when the
  backend has no kernel path);
* layout metadata: ``nres_cap`` (resident new-table blocks of the two-level
  tile map, see kernels/ops.py) and ``dirty_cap`` (the chain arena's
  dense-window dirty-tail budget), promoted from kernels/ops.py module
  constants to descriptor fields and threaded through ``dhash.make()``;
* optional hooks: ``freeze_old`` (pre-epoch maintenance — the chain arena
  compaction), ``lookup_fwd`` (the linear backend's MIGRATED-slot hazard
  forwarding).

``core/dhash.py`` contains ZERO per-backend branches: every public op
dispatches through the descriptor looked up by ``DHashState.backend``.
Because the descriptor holds all statics, every backend's table state is a
uniform pytree — which is what makes ``dhash.make_stack`` + ``jax.vmap``
batching over a leading table axis possible (multi-tenant serving).

Adding a backend is one ``register()`` call: implement the jnp op set over a
pytree table class, optionally the fused adapters over kernels/ops.py, and
nothing in dhash/engine/distributed/serving changes.

The ``*_fused`` adapters in this module are the thin descriptor-bound glue
over ``kernels/ops.py`` (hash the keys, call the op, reassemble the table
pytree) that previously lived as per-backend wrapper triplets in
``core/buckets.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import buckets, hashing
from repro.core.buckets import (ChainTable, CuckooTable, LinearTable,
                                TwoChoiceTable, _chain_parts, _ck_rows,
                                _tc_rows, batch_winners, chain_dirty)
from repro.core.struct_utils import replace
# Eager (not in-function like the adapters' ops imports): the registry
# entries below need the cap values at registration time.  Cost is ~0.2s of
# pallas machinery on top of jax's own import — paid once by anything that
# touches repro.core.
from repro.kernels.ops import DIRTY_CAP, NRES_CAP


# ---------------------------------------------------------------------------
# the descriptor
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketBackend:
    """Registry entry: everything DHash needs to drive one bucket backend.

    Uniform call surface (``t`` is the backend's table pytree):

      make(capacity, seed, **kw) -> t          empty table sized for capacity
      fresh_like(t, seed) -> t'                empty same-geometry table with
                                               fresh hash function(s) (host)
      reseed(t, salt) -> t'                    on-device hash refresh
      capacity_of(t) -> int                    static scan-order capacity
      with_state(t, state') -> t'              reattach a slot/node state
                                               array (ordered-delete landing)
      lookup(t, keys) -> (found, vals, loc)
      insert(t, keys, vals, mask) -> (t', ok)
      delete(t, keys, mask) -> (t', ok)
      extract_chunk(t, cursor, n) -> (t', hkeys, hvals, hlive, cursor')
      count_live(t) -> scalar
      count_tomb(t) -> scalar                  tombstoned slots/nodes (the
                                               elastic policy's reclaim
                                               trigger, core/policy.py)
      clear(t) -> t'
      probe_cost(t, keys, found, loc) -> i32[Q]  probe-length cost of each
                                               hit, from the loc output of
                                               the backend's lookup (probe
                                               telemetry for the policy's
                                               expensive-lookup counter)
      slots_for(capacity) -> int               slot count make(capacity)
                                               would allocate (host-side
                                               resize planning; None =
                                               derive by building a table)

    Fused set (``None`` = no kernel path; all-or-none per backend):

      lookup_fused(t, keys) -> (found, vals)
      lookup_fused_loc(t, keys) -> (found, vals, loc)   the same single
                                               kernel pass with its loc
                                               output kept (probe
                                               telemetry; no extra pass)
      insert_fused(t, keys, vals, mask) -> (t', ok)   folds the backend's
                                               post-insert maintenance (chain
                                               re-sorts past its dirty_cap)
      delete_fused(t, keys, mask) -> (t', ok)
      extract_chunk_fused(t, cursor, n) -> like extract_chunk
      ordered_lookup_fused(t_old, t_new, hk, hv, hl, keys, *, nres_cap)
          -> (found, vals)                     whole Lemma-4.1 ordered check
      ordered_delete_fused(t_old, t_new, hk, hv, hl, keys, mask, *, nres_cap)
          -> (old_state', new_state', hl', ok)
    """

    name: str
    table_cls: type
    # layout caps: descriptor-held defaults, threaded through dhash.make()
    # (nres_cap lands on DHashState, dirty_cap on the chain table itself)
    nres_cap: int
    dirty_cap: int
    # construction & maintenance
    make: Callable[..., Any]
    fresh_like: Callable[..., Any]
    reseed: Callable[..., Any]
    capacity_of: Callable[[Any], int]
    with_state: Callable[..., Any]
    # plain jnp ops (the oracle surface)
    lookup: Callable[..., Any]
    insert: Callable[..., Any]
    delete: Callable[..., Any]
    extract_chunk: Callable[..., Any]
    count_live: Callable[..., Any]
    clear: Callable[..., Any]
    # occupancy / probe telemetry (elastic policy inputs, core/policy.py)
    count_tomb: Callable[..., Any] = None
    probe_cost: Callable[..., Any] = None
    slots_for: Callable[[int], int] | None = None
    # True for backends whose placement can fail below physical capacity
    # (twochoice row pairs, cuckoo kick exhaustion): the elastic policy's
    # in-place mode holds same-shape rehashes until the load drains below
    # its placement headroom, so a rehash can never park unplaceable keys
    # in the hazard buffer indefinitely (core/policy.py)
    bounded_placement: bool = False
    # fused kernel ops
    lookup_fused: Callable[..., Any] | None = None
    lookup_fused_loc: Callable[..., Any] | None = None
    insert_fused: Callable[..., Any] | None = None
    delete_fused: Callable[..., Any] | None = None
    extract_chunk_fused: Callable[..., Any] | None = None
    ordered_lookup_fused: Callable[..., Any] | None = None
    ordered_delete_fused: Callable[..., Any] | None = None
    # optional hooks
    freeze_old: Callable[..., Any] | None = None
    lookup_fwd: Callable[..., Any] | None = None

    @property
    def fused(self) -> bool:
        """True iff this backend has the full fused kernel op set."""
        return self.lookup_fused is not None

    def __post_init__(self):
        fused_set = (self.lookup_fused, self.lookup_fused_loc,
                     self.insert_fused, self.delete_fused,
                     self.extract_chunk_fused, self.ordered_lookup_fused,
                     self.ordered_delete_fused)
        have = [f is not None for f in fused_set]
        if any(have) and not all(have):
            raise ValueError(f"backend {self.name!r}: fused ops must be "
                             f"all-or-none, got {have}")


REGISTRY: dict[str, BucketBackend] = {}


def register(be: BucketBackend) -> BucketBackend:
    """Add a descriptor to the registry (last registration wins, so a user
    backend may shadow a built-in)."""
    REGISTRY[be.name] = be
    return be


def get(name: str) -> BucketBackend:
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; registered: "
                         f"{tuple(REGISTRY)}") from None


def names() -> tuple[str, ...]:
    return tuple(REGISTRY)


def of_table(t) -> BucketBackend:
    """Descriptor for a table pytree instance (type-keyed reverse lookup)."""
    for be in REGISTRY.values():
        if isinstance(t, be.table_cls):
            return be
    raise TypeError(f"no registered backend for table type {type(t)!r}")


# ---------------------------------------------------------------------------
# linear: fused adapters (kernels/ops.py probe/claim/extract kernels)
# ---------------------------------------------------------------------------

def linear_lookup_fused(t: LinearTable, keys: jax.Array, *,
                        interpret: bool = True):
    """Kernel-backed lookup.  Returns (found, vals)."""
    from repro.kernels import ops
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    return ops.probe_lookup(t.key, t.val, t.state, h0, keys,
                            max_probes=t.max_probes, interpret=interpret)


def linear_lookup_fused_loc(t: LinearTable, keys: jax.Array, *,
                            interpret: bool = True):
    """Kernel-backed lookup keeping the kernel's loc output: the SAME single
    sort + pallas_call as ``linear_lookup_fused``, returning
    (found, vals, loc) for probe telemetry (core/policy.py)."""
    from repro.kernels import ops
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    return ops.probe_lookup(t.key, t.val, t.state, h0, keys,
                            max_probes=t.max_probes, with_loc=True,
                            interpret=interpret)


def linear_insert_fused(t: LinearTable, keys: jax.Array, vals: jax.Array,
                        mask: jax.Array, *, interpret: bool = True):
    """Kernel-backed insert: batch_winners dedup (the kernel's caller
    contract), then one claim pass + one scatter."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    tk, tv, ts, ok = ops.probe_insert(t.key, t.val, t.state, h0, keys, vals,
                                      winner, max_probes=t.max_probes,
                                      interpret=interpret)
    return replace(t, key=tk, val=tv, state=ts), ok


def linear_delete_fused(t: LinearTable, keys: jax.Array, mask: jax.Array, *,
                        interpret: bool = True):
    """Kernel-backed delete: the location-emitting probe kernel tombstones
    in ONE pass (one sort + one pallas_call + one scatter)."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    state, ok = ops.probe_delete(t.key, t.val, t.state, h0, keys, winner,
                                 max_probes=t.max_probes, interpret=interpret)
    return replace(t, state=state), ok


def linear_extract_chunk_fused(t: LinearTable, cursor: jax.Array, n: int, *,
                               interpret: bool = True):
    """Kernel-backed rebuild chunk scan: one pallas_call over the resident
    slab window + one MIGRATED scatter; hazard entries come back COMPACTED
    (live entries first) — identical as a set, which is all the hazard
    protocol observes."""
    from repro.kernels import ops
    if n > ops.SLAB:   # window contract; fall back to the jnp scan
        return buckets.linear_extract_chunk(t, cursor, n)
    state, hk, hv, hl, cur = ops.extract_chunk_fused(
        t.key, t.val, t.state, cursor, chunk=n, interpret=interpret)
    return replace(t, state=state), hk, hv, hl, cur


def linear_ordered_lookup_fused(t_old: LinearTable, t_new: LinearTable,
                                hazard_key: jax.Array, hazard_val: jax.Array,
                                hazard_live: jax.Array, keys: jax.Array, *,
                                nres_cap: int = NRES_CAP,
                                interpret: bool = True):
    """Kernel-backed rebuild-epoch lookup: the whole ordered check
    (old -> hazard -> new, Lemma 4.1) in ONE argsort + ONE probe2
    pallas_call, the two-level tile map (up to ``nres_cap`` resident blocks
    per tile) covering grown new tables.  Returns (found, vals)."""
    from repro.kernels import ops
    h0_old = hashing.bucket_of(t_old.hfn, keys, t_old.capacity)
    h0_new = hashing.bucket_of(t_new.hfn, keys, t_new.capacity)
    return ops.ordered_lookup_fused(
        (t_old.key, t_old.val, t_old.state),
        (t_new.key, t_new.val, t_new.state),
        hazard_key, hazard_val, hazard_live, h0_old, h0_new, keys,
        max_probes=t_old.max_probes, nres_cap=nres_cap, interpret=interpret)


def linear_ordered_delete_fused(t_old: LinearTable, t_new: LinearTable,
                                hazard_key: jax.Array, hazard_val: jax.Array,
                                hazard_live: jax.Array, keys: jax.Array,
                                mask: jax.Array, *, nres_cap: int = NRES_CAP,
                                interpret: bool = True):
    """Kernel-backed rebuild-epoch delete (paper Alg. 5): the SAME single
    probe2 pass resolves old-slot / hazard-index / new-slot; three scatters
    land the result.  Returns (old_state', new_state', hazard_live', ok)."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    h0_old = hashing.bucket_of(t_old.hfn, keys, t_old.capacity)
    h0_new = hashing.bucket_of(t_new.hfn, keys, t_new.capacity)
    return ops.ordered_delete_fused(
        (t_old.key, t_old.val, t_old.state),
        (t_new.key, t_new.val, t_new.state),
        hazard_key, hazard_val, hazard_live, h0_old, h0_new, keys, winner,
        max_probes=t_old.max_probes, nres_cap=nres_cap, interpret=interpret)


# ---------------------------------------------------------------------------
# twochoice: fused adapters (2Q-entry one-sort row-gather kernels)
# ---------------------------------------------------------------------------

def twochoice_lookup_fused(t: TwoChoiceTable, keys: jax.Array, *,
                           interpret: bool = True):
    """Kernel-backed 2-choice lookup.  Returns (found, vals, loc) — the same
    triple as ``buckets.twochoice_lookup`` so the delete path can reuse
    ``loc``."""
    from repro.kernels import ops
    ba, bb = _tc_rows(t, keys)
    return ops.twochoice_lookup(t.key, t.val, t.state, ba, bb, keys,
                                interpret=interpret)


def twochoice_insert_fused(t: TwoChoiceTable, keys: jax.Array,
                           vals: jax.Array, mask: jax.Array, *,
                           interpret: bool = True):
    """Kernel-backed 2-choice insert: batch_winners dedup, then one claim
    pass + one scatter (a-row claims shadow b-row claims of the same
    query)."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    ba, bb = _tc_rows(t, keys)
    tk, tv, ts, ok = ops.twochoice_insert(t.key, t.val, t.state, ba, bb,
                                          keys, vals, winner,
                                          max_rounds=t.max_rounds,
                                          interpret=interpret)
    return replace(t, key=tk, val=tv, state=ts), ok


def twochoice_delete_fused(t: TwoChoiceTable, keys: jax.Array,
                           mask: jax.Array, *, interpret: bool = True):
    """Kernel-backed 2-choice delete: reuses the fused lookup's location
    output — one kernel pass + one tombstone scatter."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    ba, bb = _tc_rows(t, keys)
    state, ok = ops.twochoice_delete(t.key, t.val, t.state, ba, bb, keys,
                                     winner, interpret=interpret)
    return replace(t, state=state), ok


def twochoice_ordered_lookup_fused(t_old: TwoChoiceTable,
                                   t_new: TwoChoiceTable,
                                   hazard_key: jax.Array,
                                   hazard_val: jax.Array,
                                   hazard_live: jax.Array,
                                   keys: jax.Array, *,
                                   nres_cap: int = NRES_CAP,
                                   interpret: bool = True):
    """Kernel-backed twochoice rebuild-epoch lookup: the whole ordered check
    in ONE argsort + ONE tc_probe2 pallas_call.  Returns (found, vals)."""
    from repro.kernels import ops
    ba_o, bb_o = _tc_rows(t_old, keys)
    ba_n, bb_n = _tc_rows(t_new, keys)
    return ops.twochoice_ordered_lookup(
        (t_old.key, t_old.val, t_old.state),
        (t_new.key, t_new.val, t_new.state),
        hazard_key, hazard_val, hazard_live,
        ba_o, bb_o, ba_n, bb_n, keys, nres_cap=nres_cap, interpret=interpret)


def twochoice_ordered_delete_fused(t_old: TwoChoiceTable,
                                   t_new: TwoChoiceTable,
                                   hazard_key: jax.Array,
                                   hazard_val: jax.Array,
                                   hazard_live: jax.Array,
                                   keys: jax.Array, mask: jax.Array, *,
                                   nres_cap: int = NRES_CAP,
                                   interpret: bool = True):
    """Kernel-backed twochoice rebuild-epoch delete (paper Alg. 5): the SAME
    single tc_probe2 pass resolves old-slot / hazard-index / new-slot.
    Returns the raw (old_state', new_state', hazard_live', ok[Q])."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    ba_o, bb_o = _tc_rows(t_old, keys)
    ba_n, bb_n = _tc_rows(t_new, keys)
    return ops.twochoice_ordered_delete(
        (t_old.key, t_old.val, t_old.state),
        (t_new.key, t_new.val, t_new.state),
        hazard_key, hazard_val, hazard_live,
        ba_o, bb_o, ba_n, bb_n, keys, winner, nres_cap=nres_cap,
        interpret=interpret)


def twochoice_extract_chunk_fused(t: TwoChoiceTable, cursor: jax.Array,
                                  n: int, *, interpret: bool = True):
    """Kernel-backed 2-choice rebuild chunk scan: the extract kernel runs on
    the row-major flattened arrays (the scan order is identical)."""
    from repro.kernels import ops
    if n > ops.SLAB:
        return buckets.twochoice_extract_chunk(t, cursor, n)
    state, hk, hv, hl, cur = ops.extract_chunk_fused(
        t.key.reshape(-1), t.val.reshape(-1), t.state.reshape(-1), cursor,
        chunk=n, interpret=interpret)
    return replace(t, state=state.reshape(t.nbuckets, t.width)), \
        hk, hv, hl, cur


# ---------------------------------------------------------------------------
# cuckoo: fused adapters — the twochoice row-gather kernels verbatim, fed
# side-offset candidate rows (a-rows [0, B), b-rows [B, 2B) of the [2B, W]
# array).  Same ONE sort + ONE pallas_call per op; only the insert grows a
# cond-gated bounded kick-out (pure jnp — zero extra kernel launches)
# ---------------------------------------------------------------------------

def cuckoo_lookup_fused(t: CuckooTable, keys: jax.Array, *,
                        interpret: bool = True):
    """Kernel-backed cuckoo lookup via the twochoice row-gather kernel over
    side-offset rows.  Returns (found, vals, loc)."""
    from repro.kernels import ops
    ra, rb = _ck_rows(t, keys)
    return ops.twochoice_lookup(t.key, t.val, t.state, ra, rb, keys,
                                interpret=interpret)


def cuckoo_insert_fused(t: CuckooTable, keys: jax.Array, vals: jax.Array,
                        mask: jax.Array, *, interpret: bool = True):
    """Kernel-backed cuckoo insert: the twochoice claim kernel places every
    key whose candidate rows have room (max_rounds=2 — one try per side);
    anything still unplaced escapes to the cond-gated bounded kick-out
    (kernels/ref.py::cuckoo_kick_ref) — free when nothing overflows."""
    from repro.kernels import ops, ref
    winner = batch_winners(keys, mask)
    ra, rb = _ck_rows(t, keys)
    tk, tv, ts, ok = ops.twochoice_insert(t.key, t.val, t.state, ra, rb,
                                          keys, vals, winner,
                                          max_rounds=2, interpret=interpret)
    maybe = winner & ~ok

    def kick(op):
        k, v, s, ok0 = op
        # re-check presence inside the branch (ok=False means present OR
        # both rows full; only the latter may relocate)
        fa, _, _ = ref.tc_row_lookup_ref(k, v, s, ra, keys)
        fb, _, _ = ref.tc_row_lookup_ref(k, v, s, rb, keys)
        pend = maybe & ~(fa | fb)
        k2, v2, s2, done = ref.cuckoo_kick_ref(
            k, v, s, ra, rb, t.hfn_a, t.hfn_b, t.nbuckets,
            keys, vals, pend, t.max_kick)
        return k2, v2, s2, ok0 | done

    tk, tv, ts, ok = jax.lax.cond(maybe.any(), kick, lambda op: op,
                                  (tk, tv, ts, ok))
    return replace(t, key=tk, val=tv, state=ts), ok


def cuckoo_delete_fused(t: CuckooTable, keys: jax.Array, mask: jax.Array, *,
                        interpret: bool = True):
    """Kernel-backed cuckoo delete: the twochoice location-emitting pass +
    one tombstone scatter."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    ra, rb = _ck_rows(t, keys)
    state, ok = ops.twochoice_delete(t.key, t.val, t.state, ra, rb, keys,
                                     winner, interpret=interpret)
    return replace(t, state=state), ok


def cuckoo_ordered_lookup_fused(t_old: CuckooTable, t_new: CuckooTable,
                                hazard_key: jax.Array, hazard_val: jax.Array,
                                hazard_live: jax.Array, keys: jax.Array, *,
                                nres_cap: int = NRES_CAP,
                                interpret: bool = True):
    """Kernel-backed cuckoo rebuild-epoch lookup: the twochoice tc_probe2
    pass (ONE argsort + ONE pallas_call) over side-offset rows."""
    from repro.kernels import ops
    ra_o, rb_o = _ck_rows(t_old, keys)
    ra_n, rb_n = _ck_rows(t_new, keys)
    return ops.twochoice_ordered_lookup(
        (t_old.key, t_old.val, t_old.state),
        (t_new.key, t_new.val, t_new.state),
        hazard_key, hazard_val, hazard_live,
        ra_o, rb_o, ra_n, rb_n, keys, nres_cap=nres_cap, interpret=interpret)


def cuckoo_ordered_delete_fused(t_old: CuckooTable, t_new: CuckooTable,
                                hazard_key: jax.Array, hazard_val: jax.Array,
                                hazard_live: jax.Array, keys: jax.Array,
                                mask: jax.Array, *, nres_cap: int = NRES_CAP,
                                interpret: bool = True):
    """Kernel-backed cuckoo rebuild-epoch delete (paper Alg. 5) via the
    twochoice probe2 pass over side-offset rows.  Returns the raw
    (old_state', new_state', hazard_live', ok[Q])."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    ra_o, rb_o = _ck_rows(t_old, keys)
    ra_n, rb_n = _ck_rows(t_new, keys)
    return ops.twochoice_ordered_delete(
        (t_old.key, t_old.val, t_old.state),
        (t_new.key, t_new.val, t_new.state),
        hazard_key, hazard_val, hazard_live,
        ra_o, rb_o, ra_n, rb_n, keys, winner, nres_cap=nres_cap,
        interpret=interpret)


def cuckoo_extract_chunk_fused(t: CuckooTable, cursor: jax.Array, n: int, *,
                               interpret: bool = True):
    """Kernel-backed cuckoo rebuild chunk scan on the row-major flattened
    [2B*W] arrays (the scan order is identical)."""
    from repro.kernels import ops
    if n > ops.SLAB:
        return buckets.cuckoo_extract_chunk(t, cursor, n)
    state, hk, hv, hl, cur = ops.extract_chunk_fused(
        t.key.reshape(-1), t.val.reshape(-1), t.state.reshape(-1), cursor,
        chunk=n, interpret=interpret)
    return replace(t, state=state.reshape(2 * t.nbuckets, t.width)), \
        hk, hv, hl, cur


# ---------------------------------------------------------------------------
# chain: fused adapters over the arena-sorted node layout
# ---------------------------------------------------------------------------

def chain_lookup_fused(t: ChainTable, keys: jax.Array, *,
                       interpret: bool = True):
    """Kernel-backed chain lookup over the arena-sorted layout.  Returns
    (found, vals, loc) — ``loc`` is the arena node index (-1 if absent)."""
    from repro.kernels import ops
    b = hashing.bucket_of(t.hfn, keys, t.nbuckets)
    return ops.chain_lookup_fused(*_chain_parts(t), b, keys,
                                  max_chain=t.max_chain,
                                  dirty_cap=t.dirty_cap, interpret=interpret)


def chain_insert_fused(t: ChainTable, keys: jax.Array, vals: jax.Array,
                       mask: jax.Array, *, interpret: bool = True):
    """Kernel-backed chain insert: batch_winners dedup, ONE sort keyed on
    the bucket, one presence pallas_call, then vectorized tail allocation +
    segmented head relink — no pointer chasing.  New nodes extend the dirty
    tail; ``chain_maybe_compact`` restores the sorted invariant."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    b = hashing.bucket_of(t.hfn, keys, t.nbuckets)
    arena_t, links, seg = _chain_parts(t)
    akey, aval, astate, anext, heads, free_top, ok = ops.chain_insert_fused(
        arena_t, links, seg, t.free_stack, t.free_top, b, keys, vals, winner,
        max_chain=t.max_chain, dirty_cap=t.dirty_cap, interpret=interpret)
    return replace(t, akey=akey, aval=aval, astate=astate, anext=anext,
                   heads=heads, free_top=free_top), ok


def chain_delete_fused(t: ChainTable, keys: jax.Array, mask: jax.Array, *,
                       interpret: bool = True):
    """Kernel-backed chain delete: the location-emitting probe (sorted
    segment window + dirty-tail compare) tombstones in ONE pass."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    b = hashing.bucket_of(t.hfn, keys, t.nbuckets)
    astate, ok = ops.chain_delete_fused(*_chain_parts(t), b, keys, winner,
                                        max_chain=t.max_chain,
                                        dirty_cap=t.dirty_cap,
                                        interpret=interpret)
    return replace(t, astate=astate), ok


def chain_ordered_lookup_fused(t_old: ChainTable, t_new: ChainTable,
                               hazard_key: jax.Array, hazard_val: jax.Array,
                               hazard_live: jax.Array, keys: jax.Array, *,
                               nres_cap: int = NRES_CAP,
                               interpret: bool = True):
    """Kernel-backed chain rebuild-epoch lookup: the whole ordered check in
    ONE sort + ONE chain_probe2 pallas_call.  Returns (found, vals)."""
    from repro.kernels import ops
    b_old = hashing.bucket_of(t_old.hfn, keys, t_old.nbuckets)
    b_new = hashing.bucket_of(t_new.hfn, keys, t_new.nbuckets)
    return ops.chain_ordered_lookup(
        *_chain_parts(t_old), *_chain_parts(t_new),
        hazard_key, hazard_val, hazard_live, b_old, b_new, keys,
        max_chain=max(t_old.max_chain, t_new.max_chain),
        nres_cap=nres_cap, dirty_cap=max(t_old.dirty_cap, t_new.dirty_cap),
        interpret=interpret)


def chain_ordered_delete_fused(t_old: ChainTable, t_new: ChainTable,
                               hazard_key: jax.Array, hazard_val: jax.Array,
                               hazard_live: jax.Array, keys: jax.Array,
                               mask: jax.Array, *, nres_cap: int = NRES_CAP,
                               interpret: bool = True):
    """Kernel-backed chain rebuild-epoch delete (paper Alg. 5).  Returns the
    raw (old_astate', new_astate', hazard_live', ok[Q])."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    b_old = hashing.bucket_of(t_old.hfn, keys, t_old.nbuckets)
    b_new = hashing.bucket_of(t_new.hfn, keys, t_new.nbuckets)
    return ops.chain_ordered_delete(
        *_chain_parts(t_old), *_chain_parts(t_new),
        hazard_key, hazard_val, hazard_live, b_old, b_new, keys, winner,
        max_chain=max(t_old.max_chain, t_new.max_chain),
        nres_cap=nres_cap, dirty_cap=max(t_old.dirty_cap, t_new.dirty_cap),
        interpret=interpret)


def chain_extract_chunk_fused(t: ChainTable, cursor: jax.Array, n: int, *,
                              interpret: bool = True):
    """Kernel-backed rebuild chunk scan: the arena is a flat array, so the
    extract kernel runs verbatim (positions are scan order)."""
    from repro.kernels import ops
    if n > ops.SLAB:   # window contract; fall back to the jnp scan
        return buckets.chain_extract_chunk(t, cursor, n)
    astate, hk, hv, hl, cur = ops.extract_chunk_fused(
        t.akey, t.aval, t.astate, cursor, chunk=n, interpret=interpret)
    return replace(t, astate=astate), hk, hv, hl, cur


def chain_compact_fused(t: ChainTable) -> ChainTable:
    """Restore the arena-sorted invariant: ONE segmented sort keyed on
    (bucket, arena index) with dead nodes pushed to the end, the compaction
    gather, per-bucket (start, len) offsets, and a vectorized pointer
    rebuild.  Physically reclaims tombstones; dirty count drops to 0."""
    from repro.kernels import ops
    b = hashing.bucket_of(t.hfn, t.akey, t.nbuckets)
    (akey, aval, astate, anext, heads, free_stack, free_top, bstart, blen,
     sorted_upto) = ops.chain_compact_fused(t.akey, t.aval, t.astate, b,
                                            nbuckets=t.nbuckets)
    return replace(t, akey=akey, aval=aval, astate=astate, anext=anext,
                   heads=heads, free_stack=free_stack, free_top=free_top,
                   bstart=bstart, blen=blen, sorted_upto=sorted_upto)


def chain_maybe_compact(t: ChainTable, *,
                        threshold: int | None = None) -> ChainTable:
    """Compaction trigger: re-sort the arena iff the dirty tail has outgrown
    the dense-window coverage (the table's ``dirty_cap`` by default — a
    descriptor field threaded through construction).  cond-gated, so the
    clean steady state never pays the sort."""
    thresh = t.dirty_cap if threshold is None else threshold
    return jax.lax.cond(chain_dirty(t) > thresh, chain_compact_fused,
                        lambda tt: tt, t)


def _chain_insert_fused_compacting(t: ChainTable, keys, vals, mask, *,
                                   interpret: bool = True):
    """The descriptor-bound chain insert: the fused insert plus the
    cond-gated arena re-sort that keeps subsequent probes kernel-resident —
    what the DHash layer (user inserts AND hazard landings) runs."""
    t2, ok = chain_insert_fused(t, keys, vals, mask, interpret=interpret)
    return chain_maybe_compact(t2), ok


# ---------------------------------------------------------------------------
# construction / maintenance adapters
# ---------------------------------------------------------------------------

def _next_pow2(x: int) -> int:
    return 1 << (int(x) - 1).bit_length()


def _make_linear(capacity: int, seed, *, load_factor: float = 0.75,
                 max_probes: int = 64) -> LinearTable:
    rng = np.random.default_rng(seed)
    slots = _next_pow2(int(capacity / load_factor) + 1)
    return buckets.linear_make(slots, hashing.fresh("mix32", rng),
                               max_probes=max_probes)


def _make_twochoice(capacity: int, seed, *, load_factor: float = 0.75,
                    bucket_width: int = 8) -> TwoChoiceTable:
    rng = np.random.default_rng(seed)
    nb = _next_pow2(int(capacity / (load_factor * bucket_width)) + 1)
    return buckets.twochoice_make(nb, hashing.fresh("mix32", rng),
                                  hashing.fresh("mix32", rng),
                                  width=bucket_width)


def _make_cuckoo(capacity: int, seed, *, load_factor: float = 0.75,
                 bucket_width: int = 8, max_kick: int = 32) -> CuckooTable:
    rng = np.random.default_rng(seed)
    nb = _next_pow2(int(capacity / (load_factor * 2 * bucket_width)) + 1)
    return buckets.cuckoo_make(nb, hashing.fresh("mix32", rng),
                               hashing.fresh("mix32", rng),
                               width=bucket_width, max_kick=max_kick)


def _make_chain(capacity: int, seed, *, load_factor: float = 0.75,
                max_chain: int = 64, nbuckets: int | None = None,
                dirty_cap: int | None = None) -> ChainTable:
    rng = np.random.default_rng(seed)
    nb = nbuckets if nbuckets is not None else _next_pow2(max(capacity // 16, 1))
    # dirty_cap=None passes through: chain_make resolves it from the
    # registry ("chain" entry), the ONE place that default lives — so a
    # user descriptor shadowing "chain" wins on every construction path
    return buckets.chain_make(nb, capacity, hashing.fresh("mix32", rng),
                              max_chain=max_chain, dirty_cap=dirty_cap)


def _fresh_linear(t: LinearTable, seed) -> LinearTable:
    return buckets.linear_make(t.capacity, hashing.fresh("mix32", seed),
                               t.max_probes)


def _fresh_twochoice(t: TwoChoiceTable, seed) -> TwoChoiceTable:
    rng = np.random.default_rng(seed)
    return buckets.twochoice_make(t.nbuckets, hashing.fresh("mix32", rng),
                                  hashing.fresh("mix32", rng), width=t.width,
                                  max_rounds=t.max_rounds)


def _fresh_cuckoo(t: CuckooTable, seed) -> CuckooTable:
    rng = np.random.default_rng(seed)
    return buckets.cuckoo_make(t.nbuckets, hashing.fresh("mix32", rng),
                               hashing.fresh("mix32", rng), width=t.width,
                               max_kick=t.max_kick)


def _fresh_chain(t: ChainTable, seed) -> ChainTable:
    return buckets.chain_make(t.nbuckets, t.arena,
                              hashing.fresh("mix32", seed),
                              max_chain=t.max_chain, dirty_cap=t.dirty_cap)


def _reseed_one(t, salt: jax.Array):
    return replace(t, hfn=hashing.reseed(t.hfn, salt))


def _reseed_twochoice(t: TwoChoiceTable, salt: jax.Array) -> TwoChoiceTable:
    return replace(t, hfn_a=hashing.reseed(t.hfn_a, salt),
                   hfn_b=hashing.reseed(t.hfn_b, salt + 0x5851F42))


def _reseed_cuckoo(t: CuckooTable, salt: jax.Array) -> CuckooTable:
    return replace(t, hfn_a=hashing.reseed(t.hfn_a, salt),
                   hfn_b=hashing.reseed(t.hfn_b, salt + 0x5851F42))


# ---------------------------------------------------------------------------
# occupancy / probe telemetry (elastic policy inputs)
# ---------------------------------------------------------------------------

def _linear_count_tomb(t: LinearTable) -> jax.Array:
    return (t.state == buckets.TOMB).sum(dtype=jnp.int32)


def _twochoice_count_tomb(t: TwoChoiceTable) -> jax.Array:
    return (t.state == buckets.TOMB).sum(dtype=jnp.int32)


def _cuckoo_count_tomb(t: CuckooTable) -> jax.Array:
    return (t.state == buckets.TOMB).sum(dtype=jnp.int32)


def _chain_count_tomb(t: ChainTable) -> jax.Array:
    return (t.astate == buckets.TOMB).sum(dtype=jnp.int32)


def _linear_probe_cost(t: LinearTable, keys, found, loc) -> jax.Array:
    """Probe distance of each hit.  Works for BOTH loc conventions: the
    plain lookup's wrapped table coordinate and the fused kernel's unwrapped
    padded coordinate (``loc >= h0``) — the mod folds either to the probe
    index."""
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    dist = jnp.mod(loc - h0, t.capacity)
    return jnp.where(found & (loc >= 0), dist, 0).astype(jnp.int32)


def _twochoice_probe_cost(t: TwoChoiceTable, keys, found, loc) -> jax.Array:
    """Cost = lane depth within the hit's row (both the plain and fused
    lookups emit loc = row * width + lane).  Two-choice inserts target the
    LESS loaded of the two candidate rows, so which row hit carries no
    signal — but a hit deep in its row means that row is saturating, the
    clustering symptom the expensive-lookup trigger exists to catch."""
    cost = loc % t.width
    return jnp.where(found & (loc >= 0), cost, 0).astype(jnp.int32)


def _cuckoo_probe_cost(t: CuckooTable, keys, found, loc) -> jax.Array:
    """Cost = lane depth within the hit's row (loc = row * width + lane),
    exactly as for twochoice — and here the depth is also the WORST-CASE
    bound: a key is only ever in one of its two candidate rows, so no
    lookup, adversarial or not, can cost more than ``width - 1``.  This is
    the number ``BENCH_attack.json`` gates as ``attack_probe_bound``."""
    cost = loc % t.width
    return jnp.where(found & (loc >= 0), cost, 0).astype(jnp.int32)


def _chain_probe_cost(t: ChainTable, keys, found, loc) -> jax.Array:
    """Chain depth of the hit: exact offset inside the sorted-arena segment;
    a dirty-tail hit (appended since the last compaction) is charged the
    full chain length + 1 — it IS the end of its chain."""
    b = hashing.bucket_of(t.hfn, keys, t.nbuckets)
    in_sorted = loc < t.sorted_upto
    depth = jnp.where(in_sorted, loc - t.bstart[b], t.blen[b] + 1)
    return jnp.where(found & (loc >= 0), depth, 0).astype(jnp.int32)


def _linear_slots_for(capacity: int) -> int:
    return _next_pow2(int(capacity / 0.75) + 1)          # mirrors _make_linear


def _twochoice_slots_for(capacity: int) -> int:
    return _next_pow2(int(capacity / (0.75 * 8)) + 1) * 8   # _make_twochoice


def _cuckoo_slots_for(capacity: int) -> int:
    return 2 * _next_pow2(int(capacity / (0.75 * 2 * 8)) + 1) * 8  # _make_cuckoo


def _chain_slots_for(capacity: int) -> int:
    return int(capacity)                                 # arena = capacity


def _drop_loc(fn):
    """Normalize a loc-returning lookup to the descriptor's (found, vals)."""
    def wrapped(t, keys, **kw):
        f, v, _loc = fn(t, keys, **kw)
        return f, v
    return wrapped


# ---------------------------------------------------------------------------
# the built-in registry
# ---------------------------------------------------------------------------

LINEAR = register(BucketBackend(
    name="linear",
    table_cls=LinearTable,
    nres_cap=NRES_CAP,
    dirty_cap=0,                       # no deferred-maintenance tail
    make=_make_linear,
    fresh_like=_fresh_linear,
    reseed=_reseed_one,
    capacity_of=lambda t: t.capacity,
    with_state=lambda t, s: replace(t, state=s),
    lookup=buckets.linear_lookup,
    insert=buckets.linear_insert,
    delete=buckets.linear_delete,
    extract_chunk=buckets.linear_extract_chunk,
    count_live=buckets.linear_count_live,
    clear=buckets.linear_clear,
    count_tomb=_linear_count_tomb,
    probe_cost=_linear_probe_cost,
    slots_for=_linear_slots_for,
    lookup_fused=linear_lookup_fused,
    lookup_fused_loc=linear_lookup_fused_loc,
    insert_fused=linear_insert_fused,
    delete_fused=linear_delete_fused,
    extract_chunk_fused=linear_extract_chunk_fused,
    ordered_lookup_fused=linear_ordered_lookup_fused,
    ordered_delete_fused=linear_ordered_delete_fused,
    lookup_fwd=buckets.linear_lookup_fwd,
))

TWOCHOICE = register(BucketBackend(
    name="twochoice",
    table_cls=TwoChoiceTable,
    nres_cap=NRES_CAP,
    dirty_cap=0,
    make=_make_twochoice,
    fresh_like=_fresh_twochoice,
    reseed=_reseed_twochoice,
    capacity_of=lambda t: t.nbuckets * t.width,
    with_state=lambda t, s: replace(t, state=s),
    lookup=buckets.twochoice_lookup,
    insert=buckets.twochoice_insert,
    delete=buckets.twochoice_delete,
    extract_chunk=buckets.twochoice_extract_chunk,
    count_live=buckets.twochoice_count_live,
    clear=buckets.twochoice_clear,
    count_tomb=_twochoice_count_tomb,
    probe_cost=_twochoice_probe_cost,
    slots_for=_twochoice_slots_for,
    lookup_fused=_drop_loc(twochoice_lookup_fused),
    lookup_fused_loc=twochoice_lookup_fused,
    insert_fused=twochoice_insert_fused,
    delete_fused=twochoice_delete_fused,
    extract_chunk_fused=twochoice_extract_chunk_fused,
    ordered_lookup_fused=twochoice_ordered_lookup_fused,
    ordered_delete_fused=twochoice_ordered_delete_fused,
    bounded_placement=True,
))

CUCKOO = register(BucketBackend(
    name="cuckoo",
    table_cls=CuckooTable,
    nres_cap=NRES_CAP,
    dirty_cap=0,
    make=_make_cuckoo,
    fresh_like=_fresh_cuckoo,
    reseed=_reseed_cuckoo,
    capacity_of=lambda t: 2 * t.nbuckets * t.width,
    with_state=lambda t, s: replace(t, state=s),
    lookup=buckets.cuckoo_lookup,
    insert=buckets.cuckoo_insert,
    delete=buckets.cuckoo_delete,
    extract_chunk=buckets.cuckoo_extract_chunk,
    count_live=buckets.cuckoo_count_live,
    clear=buckets.cuckoo_clear,
    count_tomb=_cuckoo_count_tomb,
    probe_cost=_cuckoo_probe_cost,
    slots_for=_cuckoo_slots_for,
    lookup_fused=_drop_loc(cuckoo_lookup_fused),
    lookup_fused_loc=cuckoo_lookup_fused,
    insert_fused=cuckoo_insert_fused,
    delete_fused=cuckoo_delete_fused,
    extract_chunk_fused=cuckoo_extract_chunk_fused,
    ordered_lookup_fused=cuckoo_ordered_lookup_fused,
    ordered_delete_fused=cuckoo_ordered_delete_fused,
    bounded_placement=True,
))

CHAIN = register(BucketBackend(
    name="chain",
    table_cls=ChainTable,
    nres_cap=NRES_CAP,
    dirty_cap=DIRTY_CAP,
    make=_make_chain,
    fresh_like=_fresh_chain,
    reseed=_reseed_one,
    capacity_of=lambda t: t.arena,
    with_state=lambda t, s: replace(t, astate=s),
    lookup=buckets.chain_lookup,
    insert=buckets.chain_insert,
    delete=buckets.chain_delete,
    extract_chunk=buckets.chain_extract_chunk,
    count_live=buckets.chain_count_live,
    clear=buckets.chain_clear,
    count_tomb=_chain_count_tomb,
    probe_cost=_chain_probe_cost,
    slots_for=_chain_slots_for,
    lookup_fused=_drop_loc(chain_lookup_fused),
    lookup_fused_loc=chain_lookup_fused,
    insert_fused=_chain_insert_fused_compacting,
    delete_fused=chain_delete_fused,
    extract_chunk_fused=chain_extract_chunk_fused,
    ordered_lookup_fused=chain_ordered_lookup_fused,
    ordered_delete_fused=chain_ordered_delete_fused,
    freeze_old=chain_compact_fused,
))
