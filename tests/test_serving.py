"""Serving tests: paged KV == dense decode, page accounting, prefix cache,
live rehash under load (the paper's non-blocking property on the serving
path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import backend as backends
from repro.core import dhash
from repro.models import model, transformer
from repro.serving import eviction, kvcache, prefix_cache
from repro.serving.engine import ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def small():
    cfg = ArchConfig("t-serve", "dense", n_layers=2, d_model=64, n_heads=4,
                     n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
                     attn_chunk=32, loss_chunk=32)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_end_to_end_and_page_reclaim(small):
    cfg, params = small
    eng = ServingEngine(params, cfg, ServeConfig(
        max_seqs=4, page_size=8, n_pages=64, max_blocks=8, max_new_tokens=6))
    rng = np.random.default_rng(0)
    sids = [eng.submit(list(rng.integers(1, 255, size=rng.integers(3, 10))))
            for _ in range(6)]
    eng.run(max_steps=500)
    assert len(eng.finished) == 6
    for sid in sids:
        assert len(eng.finished[sid]) == 6
    assert int(eng.kv.free_top) == 64, "pages leaked"
    # table fully empty again
    assert int(jax.device_get(dhash.count_items(eng.kv.table))) == 0


def test_paged_decode_matches_dense(small):
    cfg, params = small
    prompt = [5, 9, 17, 3]
    eng = ServingEngine(params, cfg, ServeConfig(
        max_seqs=2, page_size=8, n_pages=64, max_blocks=8, max_new_tokens=4))
    sid = eng.submit(prompt)
    eng.run()
    cache = transformer.init_cache(cfg, 1, 64)
    toks, outs = list(prompt), []
    for i in range(len(prompt) + 3):
        t = jnp.asarray([[toks[i]]], jnp.int32)
        logits, cache = jax.jit(model.decode_logits, static_argnums=1)(
            params, cfg, t, cache)
        if i >= len(prompt) - 1:
            outs.append(int(jnp.argmax(logits[0])))
            toks.append(outs[-1])
    assert outs == eng.finished[sid]


def test_live_rehash_during_serving(small):
    """Force the page table past its rehash threshold mid-serving: requests
    keep completing and the table rebuilds at least once (non-blocking)."""
    cfg, params = small
    eng = ServingEngine(params, cfg, ServeConfig(
        max_seqs=4, page_size=4, n_pages=256, max_blocks=16,
        max_new_tokens=24, rehash_load_factor=0.02))
    rng = np.random.default_rng(1)
    for _ in range(8):
        eng.submit(list(rng.integers(1, 255, size=12)))
    eng.run(max_steps=2000)
    assert len(eng.finished) == 8
    assert eng.rehashes >= 1, "rehash threshold never triggered"
    for out in eng.finished.values():
        assert len(out) == 24


def test_prefix_cache_chain_semantics():
    toks = jnp.asarray(np.random.default_rng(0).integers(1, 100, (2, 64)),
                       jnp.int32)
    fps = prefix_cache.prefix_fingerprints(toks, page_size=16)
    assert fps.shape == (2, 4)
    # chained: changing block 1 changes fps for blocks >= 1 but not block 0
    toks2 = toks.at[0, 20].set(99)
    fps2 = prefix_cache.prefix_fingerprints(toks2, page_size=16)
    assert int(fps2[0, 0]) == int(fps[0, 0])
    assert int(fps2[0, 1]) != int(fps[0, 1])
    assert int(fps2[0, 3]) != int(fps[0, 3])

    table = dhash.make("linear", capacity=256, chunk=32, seed=0)
    pages = jnp.arange(8, dtype=jnp.int32).reshape(2, 4)
    table, ok = prefix_cache.publish_prefix(table, fps, pages,
                                            jnp.ones((2, 4), bool))
    assert bool(np.asarray(ok).all())
    nhit, got = prefix_cache.match_prefix(table, fps)
    assert (np.asarray(nhit) == 4).all()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(pages))
    # partial prefix: row with one diverged block matches only the prefix
    nhit2, got2 = prefix_cache.match_prefix(table, fps2)
    assert int(nhit2[0]) == 1 and int(nhit2[1]) == 4
    assert int(got2[0, 0]) == 0 and int(got2[0, 1]) == -1


def test_match_prefix_edge_contracts():
    """Pinned edge behavior: a first-block miss is a clean miss (n_hit=0,
    every page -1 — the run never restarts after a gap), ragged token tails
    are never fingerprinted, and a zero-block batch short-circuits."""
    table = dhash.make("linear", capacity=64, chunk=32, seed=0)
    fps = jnp.asarray([[11, 12, 13], [21, 22, 23]], jnp.int32)
    pages = jnp.asarray([[0, 1, 2], [3, 4, 5]], jnp.int32)
    # publish only row 1 — row 0's first block stays unknown
    table, _ = prefix_cache.publish_prefix(
        table, fps, pages, jnp.asarray([[False, True, True],
                                        [True, True, True]]))
    nhit, got = prefix_cache.match_prefix(table, fps)
    assert int(nhit[0]) == 0, "first-block miss must yield n_hit=0"
    np.testing.assert_array_equal(np.asarray(got[0]), [-1, -1, -1])
    assert int(nhit[1]) == 3
    # ragged tail: 10 tokens at page_size=4 -> exactly 2 blocks, and the
    # fingerprints must not see the tail tokens
    toks = jnp.asarray(np.random.default_rng(0).integers(1, 99, (1, 10)),
                       jnp.int32)
    f1 = prefix_cache.prefix_fingerprints(toks, page_size=4)
    assert f1.shape == (1, 2)
    f2 = prefix_cache.prefix_fingerprints(toks.at[0, 9].set(7), page_size=4)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    # prompts shorter than one page: zero blocks, no table access
    short = prefix_cache.prefix_fingerprints(toks[:, :3], page_size=4)
    assert short.shape == (1, 0)
    nhit0, got0 = prefix_cache.match_prefix(table, short)
    assert int(nhit0[0]) == 0 and got0.shape == (1, 0)
    # zero-hit batch: fingerprints never published all miss cleanly
    nhitz, gotz = prefix_cache.match_prefix(
        table, jnp.asarray([[91, 92], [93, 94]], jnp.int32))
    assert (np.asarray(nhitz) == 0).all()
    assert (np.asarray(gotz) == -1).all()


_EVICT_BACKENDS = [(b, f) for b in ("linear", "twochoice", "chain")
                   for f in (False, True)]
# jitted once at module scope: the op-by-op eager path recompiles every
# lax.cond per call, which is both slow and (deep into a full-suite
# process with hundreds of cached executables) has segfaulted XLA's CPU
# compiler; the jitted path is also what production callers use
_EV = {"publish": jax.jit(eviction.publish),
       "evict": jax.jit(eviction.evict, static_argnums=1),
       "lookup": jax.jit(dhash.lookup)}


@pytest.mark.parametrize("backend,fused", _EVICT_BACKENDS)
def test_eviction_pinning_and_lru_order(backend, fused):
    """The acceptance property, per backend x fused: a refcount-pinned page
    is NEVER victimized; victims come coldest-first; evicted fingerprints
    miss on the next lookup; duplicate republish keeps the original page."""
    if fused and not backends.get(backend).fused:
        pytest.skip(f"{backend} has no fused kernels")
    ps = eviction.make(8, backend=backend, chunk=32, seed=3, fused=fused)
    fps = jnp.asarray([100, 200, 300, 400, 500, 600, 700, 800], jnp.int32)
    pages = jnp.arange(8, dtype=jnp.int32)
    # publish in two batches -> two stamp generations (0-3 colder than 4-7)
    ps, ok = _EV["publish"](ps, fps[:4], pages[:4], jnp.ones((4,), bool))
    assert bool(np.asarray(ok).all())
    ps, ok = _EV["publish"](ps, fps[4:], pages[4:], jnp.ones((4,), bool))
    assert bool(np.asarray(ok).all())
    # duplicate-fingerprint republish: fp 100 from a NEW page 7 must lose
    ps2, okd = _EV["publish"](ps, fps[:1], jnp.asarray([7], jnp.int32),
                              jnp.ones((1,), bool))
    assert not bool(np.asarray(okd)[0])
    _, got = _EV["lookup"](ps2.table, fps[:1])
    assert int(got[0]) == 0, "existing mapping must win"
    # masked publish: mask=False inserts nothing
    ps3, okm = _EV["publish"](ps, jnp.asarray([999], jnp.int32),
                              jnp.asarray([3], jnp.int32),
                              jnp.zeros((1,), bool))
    assert not bool(np.asarray(okm)[0])
    assert not bool(np.asarray(_EV["lookup"](ps3.table,
                                             jnp.asarray([999]))[0])[0])
    # pin the two coldest pages — eviction must skip PAST them
    ps = eviction.acquire(ps, pages[:2], jnp.ones((2,), bool))
    ps, victims, vok = _EV["evict"](ps, 4, jnp.asarray(3, jnp.int32))
    vset = set(np.asarray(victims)[np.asarray(vok)].tolist())
    assert len(vset) == 3
    assert vset.isdisjoint({0, 1}), f"pinned page victimized: {vset}"
    assert vset == {2, 3, 4}, "victims must be coldest-first, index-stable"
    # evicted fingerprints now miss; pinned survivors still hit
    fnd, _ = _EV["lookup"](ps.table, fps)
    np.testing.assert_array_equal(
        np.asarray(fnd), [True, True, False, False, False, True, True, True])
    # reverse index shrank in lockstep with the forward index
    assert int(jax.device_get(dhash.count_items(ps.rev))) == 5
    assert int(jax.device_get(dhash.count_items(ps.table))) == 5
    # fully pinned cache: eviction wants pages but must return none
    ps = eviction.acquire(ps, jnp.asarray([5, 6, 7], jnp.int32),
                          jnp.ones((3,), bool))
    ps, _, vok2 = _EV["evict"](ps, 4, jnp.asarray(4, jnp.int32))
    assert not bool(np.asarray(vok2).any()), "all pages pinned: no victims"
    # release makes them victims again
    ps = eviction.release(ps, jnp.asarray([5, 6, 7], jnp.int32),
                          jnp.ones((3,), bool))
    ps, _, vok3 = _EV["evict"](ps, 4, jnp.asarray(4, jnp.int32))
    assert int(np.asarray(vok3).sum()) == 3


def test_paged_attention_vs_reference_random_pages():
    """paged_decode_attention == dense attention when pages are scattered."""
    rng = np.random.default_rng(3)
    L, PS, NP, KV, HD, B, HQ = 1, 4, 32, 2, 8, 3, 4
    kv = kvcache.make(L, PS, NP, KV, HD, dtype=jnp.float32, seed=1)
    slen = jnp.asarray([9, 5, 12], jnp.int32)
    seq_ids = jnp.asarray([1, 2, 3], jnp.int32)
    dense_k = jnp.asarray(rng.normal(size=(B, 16, KV, HD)).astype(np.float32))
    dense_v = jnp.asarray(rng.normal(size=(B, 16, KV, HD)).astype(np.float32))
    # fill the paged pool token by token
    for b in range(B):
        for t in range(int(slen[b])):
            kv = kvcache.append_token(
                kv, seq_ids[b: b + 1], jnp.asarray([t], jnp.int32),
                dense_k[None, b: b + 1, t], dense_v[None, b: b + 1, t])
    q = jnp.asarray(rng.normal(size=(B, HQ, HD)).astype(np.float32))
    out = kvcache.paged_decode_attention(kv, jnp.asarray(0), q, seq_ids, slen,
                                         n_blocks=4)
    from repro.models.attention import decode_attention
    ref = decode_attention(q[:, None], dense_k, dense_v, slen)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_multi_tenant_page_tables_independent_rehash():
    """Tenant page-table stack: routing isolates tenants' mappings, and a
    rehash started on a subset of tenants advances ONLY their epochs while
    every tenant keeps resolving pages mid-flight."""
    kv = kvcache.make(layers=1, page_size=4, n_pages=64, kv_heads=1,
                      head_dim=8, max_blocks=8, n_tenants=4)
    sids = jnp.asarray([1, 2, 3, 4, 5, 6, 7, 8], jnp.int32)  # 2 seqs/tenant
    blk = jnp.zeros((8,), jnp.int32)
    kv, pages = jax.jit(kvcache.alloc_pages)(kv, sids, blk,
                                             jnp.ones((8,), bool))
    assert bool((np.asarray(pages) >= 0).all())
    # per-tenant tables: each tenant's table holds exactly its own 2 keys
    counts = np.asarray(jax.device_get(dhash.stack_count_items(kv.table)))
    np.testing.assert_array_equal(counts, np.full(4, 2))
    # rehash tenants 0 and 2 only; run it to completion mid-serving
    kv = kvcache.start_rehash(kv, jnp.asarray([True, False, True, False]))
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(kv.table.rebuilding)),
        np.array([True, False, True, False]))
    step = jax.jit(kvcache.rehash_step)
    for _ in range(40):
        kv = step(kv)
        pg, fnd = kvcache.resolve_blocks_at(kv, sids, blk)
        assert bool(np.asarray(fnd).all()), "resolution must never block"
        np.testing.assert_array_equal(np.asarray(pg), np.asarray(pages))
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(kv.table.epoch)), np.array([1, 0, 1, 0]))
    # freeing one tenant's sequences leaves the others' mappings intact
    kv = jax.jit(kvcache.free_sequences, static_argnums=2)(
        kv, jnp.asarray([4, 8], jnp.int32), 8)       # tenant 0's seqs
    pg, fnd = kvcache.resolve_blocks_at(kv, sids, blk)
    np.testing.assert_array_equal(
        np.asarray(fnd), np.array([True, True, True, False,
                                   True, True, True, False]))
    assert int(kv.free_top) == 64 - 6


def test_capped_router_adversarial_skew_slab_exact():
    """The acceptance adversarial case: EVERY key lands in one tenant
    (100% skew), so the capped router overflows hard — the spill slab must
    serve the spill exactly IN THE SAME single pass (no retry exists any
    more), the spill must be accounted in ``route_spill`` (distinct from
    table rejections), and the outcome must be bit-identical to a
    full-width (cap_factor <= 0) run."""
    def run(cap_factor):
        kv = kvcache.make(layers=1, page_size=4, n_pages=64, kv_heads=1,
                          head_dim=8, max_blocks=8, n_tenants=8,
                          cap_factor=cap_factor)
        # 16 sequences, ALL in tenant 3 (seq_id % 8 == 3):
        # cap = ceil(2*16/8) = 4 slots for 16 keys -> overflow 12, carried
        # by the overflow-proof slab (spill_slack=1.0 default -> 12 cols)
        sids = jnp.asarray([3 + 8 * i for i in range(16)], jnp.int32)
        blk = jnp.zeros((16,), jnp.int32)
        kv, pages = jax.jit(kvcache.alloc_pages)(kv, sids, blk,
                                                 jnp.ones((16,), bool))
        return kv, sids, blk, np.asarray(pages)

    kv, sids, blk, pages = run(cap_factor=2.0)
    # nothing dropped: every seq got a page, all distinct
    assert (pages >= 0).all()
    assert len(set(pages.tolist())) == 16
    # spill exercised and accounted on exactly the hot tenant
    spill = np.asarray(jax.device_get(kv.route_spill))
    assert spill[3] == 12 and (spill[np.arange(8) != 3] == 0).all(), spill
    load, spill2, drop = (np.asarray(x) for x in
                          jax.device_get(kvcache.table_load(kv,
                                                            with_spill=True)))
    np.testing.assert_array_equal(spill2, spill)
    assert (drop == 0).all(), "overflow-proof slab must never drop"
    assert load[3] > 0 and (load[np.arange(8) != 3] == 0).all()
    # slab lookups are exact: every skewed key resolves to its page
    pg, fnd = kvcache.resolve_blocks_at(kv, sids, blk)
    assert bool(np.asarray(fnd).all())
    np.testing.assert_array_equal(np.asarray(pg), pages)
    # capped + slab is bit-identical to the overflow-proof full width
    _, _, _, pages_full = run(cap_factor=0.0)
    np.testing.assert_array_equal(pages, pages_full)
    # slab deletes: freeing routes 16*8 = 128 keys into tenant 3
    # (cap 32 -> spill 96); every page must come home
    kv = jax.jit(kvcache.free_sequences, static_argnums=2)(kv, sids, 8)
    assert int(kv.free_top) == 64, "router spill must not leak pages"
    _, fnd2 = kvcache.resolve_blocks_at(kv, sids, blk)
    assert not bool(np.asarray(fnd2).any())
    spill3 = np.asarray(jax.device_get(kv.route_spill))
    assert spill3[3] > spill[3], "slab deletes must also be accounted"


def test_capped_router_no_cond_retry_in_jaxpr():
    """The tentpole's structural half at the kvcache level: a 100%-skew
    ``table_insert`` lowers with ZERO ``cond`` primitives on the routed
    path — the spilling batch IS the single pass."""
    kv = kvcache.make(layers=1, page_size=4, n_pages=64, kv_heads=1,
                      head_dim=8, max_blocks=8, n_tenants=8)
    sids = jnp.asarray([3 + 8 * i for i in range(16)], jnp.int32)
    keys = kvcache.block_key(sids, jnp.zeros((16,), jnp.int32))
    tenant = kvcache.tenant_of(kv, sids)
    vals = jnp.arange(16, dtype=jnp.int32)
    ones = jnp.ones((16,), bool)
    jaxpr = jax.make_jaxpr(kvcache.table_insert)(kv, tenant, keys, vals, ones)
    prims = [eq.primitive.name for eq in jaxpr.jaxpr.eqns]
    assert "cond" not in prims, prims


def test_capped_router_compact_slab_drops_exactly():
    """Opt-in compact slab (spill_slack < 1): keys past primary+slab are
    dropped with EXACT accounting — ``route_drop`` counts them per tenant,
    alloc_pages refuses them (no leak, no phantom page), and the free
    stack stays conserved."""
    kv = kvcache.make(layers=1, page_size=4, n_pages=64, kv_heads=1,
                      head_dim=8, max_blocks=8, n_tenants=8,
                      cap_factor=2.0, spill_slack=0.5)
    # q=16, cap=4, slab=ceil(0.5*16)=8: tenant 3 gets 16 keys ->
    # 4 primary + 8 slab = 12 served, 4 dropped
    sids = jnp.asarray([3 + 8 * i for i in range(16)], jnp.int32)
    blk = jnp.zeros((16,), jnp.int32)
    kv, pages = jax.jit(kvcache.alloc_pages)(kv, sids, blk,
                                             jnp.ones((16,), bool))
    pages = np.asarray(pages)
    assert (pages >= 0).sum() == 12 and (pages == -1).sum() == 4
    assert len(set(pages[pages >= 0].tolist())) == 12
    _, spill, drop = jax.device_get(kvcache.table_load(kv, with_spill=True))
    drop = np.asarray(drop)
    assert drop[3] == 4 and (drop[np.arange(8) != 3] == 0).all(), drop
    assert np.asarray(spill)[3] == 12
    # dropped allocations are failures, not silent losses
    assert int(kv.alloc_fail) == 4
    assert int(kv.free_top) == 64 - 12, "only served pages leave the stack"


def test_multi_tenant_engine_matches_single_tenant(small):
    """ServingEngine with a tenant stack decodes EXACTLY like the
    single-table engine (page-table layout is invisible to the model), while
    per-tenant rehash epochs advance independently under a low trigger."""
    cfg, params = small
    outs = {}
    for tenants in (1, 3):
        eng = ServingEngine(params, cfg, ServeConfig(
            max_seqs=4, page_size=8, n_pages=64, max_blocks=8,
            max_new_tokens=6, n_tenants=tenants,
            rehash_load_factor=0.01 if tenants > 1 else 0.7))
        rng = np.random.default_rng(0)
        sids = [eng.submit(list(rng.integers(1, 255,
                                             size=rng.integers(3, 10))))
                for _ in range(6)]
        eng.run(max_steps=500)
        assert len(eng.finished) == 6
        assert int(eng.kv.free_top) == 64, "pages leaked"
        outs[tenants] = [eng.finished[s] for s in sids]
        if tenants > 1:
            assert eng.rehashes >= 1, "low trigger must start tenant rehashes"
    assert outs[1] == outs[3], "tenant partition must not change decoding"


def test_adaptive_cap_engine_wiring_and_decode_identity(small):
    """``ServeConfig.adaptive_cap``: the RouteCapController closes the loop
    inside the engine's tenant poll.  With the overflow-proof slab
    (spill_slack=1.0) cap moves are semantics-free, so an adaptive run
    must decode bit-identically to a static full-width run while the
    controller actually consumes the spill/drop counters and keeps
    ``kv.cap_factor`` on its geometric ladder.  (No-flap convergence is a
    property of SUSTAINED traffic — asserted on the burst replay in
    test_policy — not of this toy trace, whose prefill-burst/quiet-decode
    alternation legitimately reverses the cap.)"""
    cfg, params = small
    outs = {}
    for adaptive in (False, True):
        eng = ServingEngine(params, cfg, ServeConfig(
            max_seqs=4, page_size=8, n_pages=64, max_blocks=8,
            max_new_tokens=6, n_tenants=8, cap_factor=0.0 if not adaptive
            else 2.0, adaptive_cap=adaptive, rehash_load_factor=0.9))
        rng = np.random.default_rng(3)
        # every request pinned to ONE tenant: sustained adversarial skew
        sids = [eng.submit(list(rng.integers(1, 255,
                                             size=rng.integers(3, 10))),
                           tenant=5)
                for _ in range(6)]
        eng.run(max_steps=500)
        assert len(eng.finished) == 6
        assert int(eng.kv.free_top) == 64, "pages leaked"
        outs[adaptive] = [eng.finished[s] for s in sids]
        if adaptive:
            ctl = eng.cap_ctl
            assert ctl is not None
            # the poll fed the controller the cumulative counters
            assert eng.router_spills > 0
            assert ctl._spill_prev == eng.router_spills
            assert eng.router_drops == 0, "overflow-proof slab cannot drop"
            # the applied cap IS the controller's, the loop actually
            # moved it, and every value it took sits on the ladder
            assert eng.kv.cap_factor == ctl.cap_factor
            assert ctl.grows + ctl.shrinks > 0, "controller never moved"
            assert ctl.cap_min <= ctl.cap_factor <= ctl.cap_max
            ladder = {min(2.0 * 1.5 ** k, ctl.cap_max) for k in range(-8, 9)}
            assert any(abs(ctl.cap_factor - v) < 1e-9 for v in ladder)
        else:
            assert eng.cap_ctl is None
    assert outs[True] == outs[False], \
        "adaptive cap moves must not change decoding (overflow-proof slab)"


def test_prefix_cache_decode_identity(small):
    """Prefix-cache adoption must be invisible to decoding: shared-prefix
    prompts produce bit-identical outputs with the cache on and off, and the
    second wave of each family actually adopts (hits > 0)."""
    cfg, params = small
    rng = np.random.default_rng(7)
    fam = [rng.integers(1, 255, size=16).tolist() for _ in range(2)]
    prompts = [f + rng.integers(1, 255, size=4).tolist() + [1]
               for f in fam for _ in range(3)]
    outs = {}
    for on in (False, True):
        eng = ServingEngine(params, cfg, ServeConfig(
            max_seqs=2, page_size=4, n_pages=64, max_blocks=8,
            max_new_tokens=4, prefix_cache=on, prefix_capacity=256))
        sids = [eng.submit(list(p)) for p in prompts]
        eng.run(max_steps=2000)
        assert len(eng.finished) == len(prompts)
        outs[on] = [eng.finished[s] for s in sids]
        if on:
            assert eng.cache_hits > 0, "second wave never adopted"
            assert eng.publishes > 0
    assert outs[True] == outs[False], "prefix adoption changed decoding"


@pytest.mark.slow
def test_replay_past_pool_capacity_evicts_not_fails(small):
    """End-to-end churn replay: publish far more distinct blocks than the
    page pool holds.  Eviction (never allocation failure) must absorb the
    pressure, and outputs must stay bit-identical to an unpressured
    cache-off run — which also proves no in-use (pinned) page was ever
    victimised and recycled mid-decode."""
    cfg, params = small
    rng = np.random.default_rng(11)
    fam = [rng.integers(1, 255, size=16).tolist() for _ in range(6)]
    prompts = [fam[int(i)] + rng.integers(1, 255, size=8).tolist() + [1]
               for i in np.repeat(np.arange(6), 3)]
    outs = {}
    for on, n_pages in ((False, 256), (True, 32)):
        eng = ServingEngine(params, cfg, ServeConfig(
            max_seqs=4, page_size=4, n_pages=n_pages, max_blocks=8,
            max_new_tokens=4, prefix_cache=on, prefix_capacity=512,
            evict_batch=8))
        sids = [eng.submit(list(p)) for p in prompts]
        eng.run(max_steps=5000)
        assert len(eng.finished) == len(prompts)
        outs[on] = [eng.finished[s] for s in sids]
    assert outs[True] == outs[False], (
        "pool pressure corrupted decoding — an in-use page was evicted")
    assert eng.publishes > 32, "replay too small to pressure the pool"
    assert eng.alloc_fails == 0, "eviction failed to absorb pool pressure"
    assert eng.evictions > 0
    ps = eng.kv.prefix
    # all sequences freed: every surviving pin released, indexes in lockstep
    assert int(jax.device_get(ps.refcnt.sum())) == 0
    n_cached = int(jax.device_get(ps.cached.sum()))
    assert int(jax.device_get(dhash.count_items(ps.table))) == n_cached
    assert int(jax.device_get(dhash.count_items(ps.rev))) == n_cached
    assert int(eng.kv.free_top) + n_cached == 32, "pages leaked"
