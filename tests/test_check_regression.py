"""The perf gate's own regression tests: doctored BENCH_*.json pairs.

check_regression.py is the only thing standing between a broken fused
path and a green CI run, so its failure modes are pinned here the same
way the kernels' are: a baseline/current artifact pair is written to tmp
dirs and ``main()`` is invoked directly, asserting on the exit status.

The doctored cases cover the silent-skip bugs this gate has grown
defenses against:

* a gated metric (STRUCTURAL ``attack_probe_bound``) missing from the
  fresh artifact must fail — a bench that stops emitting a gated number
  must not pass by omission;
* a gated metric emitted with the wrong TYPE (``null``, a string, a
  nested object) must fail, not skip — the old leaf comparison only
  type-checked the baseline side;
* a structural increase must fail and a descriptive drift must not.
"""
from __future__ import annotations

import json

from benchmarks import check_regression

BASE = {
    "band": 3.0,
    "recover_ratio": 4.0,
    "attack_probe_bound": 7,
    "split_stuck_x": 1.14,          # descriptive: not in any gate class
    "throughput_mlups": {"dhash_before": 6.6},
}


def _run(tmp_path, base, cur):
    bdir, cdir = tmp_path / "base", tmp_path / "cur"
    bdir.mkdir(exist_ok=True), cdir.mkdir(exist_ok=True)
    (bdir / "BENCH_attack.json").write_text(json.dumps(base))
    if cur is not None:
        (cdir / "BENCH_attack.json").write_text(json.dumps(cur))
    return check_regression.main(
        ["--baseline-dir", str(bdir), "--current-dir", str(cdir)])


def test_identical_artifacts_pass(tmp_path):
    assert _run(tmp_path, BASE, BASE) == 0


def test_missing_gated_key_fails(tmp_path):
    cur = {k: v for k, v in BASE.items() if k != "attack_probe_bound"}
    assert _run(tmp_path, BASE, cur) == 1


def test_gated_key_with_wrong_type_fails(tmp_path):
    for bad in (None, "n/a", {"max": 7}, True):
        assert _run(tmp_path, BASE, dict(BASE, attack_probe_bound=bad)) == 1


def test_structural_increase_fails(tmp_path):
    assert _run(tmp_path, BASE, dict(BASE, attack_probe_bound=8)) == 1


def test_structural_decrease_passes(tmp_path):
    assert _run(tmp_path, BASE, dict(BASE, attack_probe_bound=3)) == 0


def test_ratio_regression_fails_and_band_is_honoured(tmp_path):
    # recover_ratio is a higher-is-better RATIO under the default 15% band
    assert _run(tmp_path, BASE, dict(BASE, recover_ratio=1.0)) == 1
    assert _run(tmp_path, BASE, dict(BASE, recover_ratio=3.7)) == 0


def test_descriptive_drift_passes(tmp_path):
    # split_stuck_x is reported, not gated; throughput rows likewise
    cur = dict(BASE, split_stuck_x=99.0,
               throughput_mlups={"dhash_before": 0.001})
    assert _run(tmp_path, BASE, cur) == 0


def test_missing_artifact_fails(tmp_path):
    assert _run(tmp_path, BASE, None) == 1
