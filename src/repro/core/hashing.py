"""Seeded hash-function families.

The whole point of DHash is that the *hash function is data*: a rebuild swaps
it live.  A ``HashFn`` is therefore a pytree (kind is static, seeds are
arrays), and ``fresh(kind, rng)`` draws a brand-new function from the family.

Three families, mirroring the paper's discussion of defending against
collision attacks (§1):

* ``multiply_shift`` — Dietzfelbinger's 2-universal scheme; cheapest.
* ``mix32``          — murmur3 finalizer with seed folding; good avalanche.
* ``tabulation``     — 3-independent tabulation hashing; strongest guarantees,
                       one 4x256 u32 table of entropy.

All arithmetic is uint32 (wrap-around is intentional); keys are int32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.struct_utils import pytree_dataclass

HASH_KINDS = ("multiply_shift", "mix32", "tabulation")

_U32 = jnp.uint32


@pytree_dataclass(meta_fields=("kind",))
class HashFn:
    kind: str
    seeds: jax.Array  # multiply_shift: [2] u32 (a|1, b); mix32: [2] u32; tabulation: [4,256] u32


def fresh(kind: str, rng: np.random.Generator | int) -> HashFn:
    """Draw a new hash function from family ``kind``."""
    if isinstance(rng, (int, np.integer)):
        rng = np.random.default_rng(int(rng))
    if kind == "multiply_shift":
        a = np.uint32(rng.integers(0, 2**32, dtype=np.uint32) | np.uint32(1))
        b = np.uint32(rng.integers(0, 2**32, dtype=np.uint32))
        seeds = jnp.asarray(np.stack([a, b]), dtype=_U32)
    elif kind == "mix32":
        seeds = jnp.asarray(rng.integers(0, 2**32, size=(2,), dtype=np.uint32), dtype=_U32)
    elif kind == "tabulation":
        seeds = jnp.asarray(rng.integers(0, 2**32, size=(4, 256), dtype=np.uint32), dtype=_U32)
    else:  # pragma: no cover - guarded by HASH_KINDS
        raise ValueError(f"unknown hash kind {kind!r}; choose from {HASH_KINDS}")
    return HashFn(kind=kind, seeds=seeds)


def reseed(fn: HashFn, salt: jax.Array) -> HashFn:
    """Derive a fresh function of the same family from ``fn`` and a scalar
    ``salt`` — fully jittable (no host RNG), so an engine can start a new
    rebuild epoch entirely on-device.  Distinct salts give decorrelated seed
    vectors via the mix32 finalizer over (seed, position, salt)."""
    s = fn.seeds
    pos = jnp.arange(s.size, dtype=_U32).reshape(s.shape)
    salt32 = (jnp.asarray(salt).astype(jnp.int32).view(_U32)
              * _U32(0x9E3779B1) + _U32(0x85EBCA77))
    seeds = _mix32(s ^ salt32, _U32(0x27D4EB2F) ^ pos, _U32(0x165667B1))
    if fn.kind == "multiply_shift":
        seeds = seeds.at[0].set(seeds[0] | _U32(1))  # multiplier must be odd
    return HashFn(kind=fn.kind, seeds=seeds)


def _mix32(x: jax.Array, s0: jax.Array, s1: jax.Array) -> jax.Array:
    x = x ^ s0
    x = x ^ (x >> 16)
    x = x * _U32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * _U32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x ^ s1


def hash_u32(fn: HashFn, keys: jax.Array) -> jax.Array:
    """Full-width u32 hash of int32 keys."""
    k = keys.astype(jnp.int32).view(jnp.uint32) if keys.dtype != _U32 else keys
    s = fn.seeds
    if fn.kind == "multiply_shift":
        return k * s[0] + s[1]
    if fn.kind == "mix32":
        return _mix32(k, s[0], s[1])
    # tabulation
    b0 = (k & _U32(0xFF)).astype(jnp.int32)
    b1 = ((k >> 8) & _U32(0xFF)).astype(jnp.int32)
    b2 = ((k >> 16) & _U32(0xFF)).astype(jnp.int32)
    b3 = ((k >> 24) & _U32(0xFF)).astype(jnp.int32)
    return s[0][b0] ^ s[1][b1] ^ s[2][b2] ^ s[3][b3]


def bucket_of(fn: HashFn, keys: jax.Array, nbuckets: int) -> jax.Array:
    """Bucket index in [0, nbuckets) as int32. Power-of-two sizes use a mask."""
    h = hash_u32(fn, keys)
    if nbuckets & (nbuckets - 1) == 0:
        return (h & _U32(nbuckets - 1)).astype(jnp.int32)
    return (h % _U32(nbuckets)).astype(jnp.int32)


def hash_combine(h: jax.Array, x: jax.Array) -> jax.Array:
    """Order-dependent u32 combine (for content hashing, e.g. prefix-cache block ids)."""
    h = h.astype(_U32)
    x = x.astype(jnp.int32).view(jnp.uint32)
    return _mix32(x ^ (h * _U32(0x9E3779B1) + _U32(0x85EBCA77)), _U32(0x27D4EB2F), h)
