"""Serving driver: ``python -m repro.launch.serve --arch <id> --smoke``.

Continuous batching over the DHash-paged KV cache (serving/engine.py) with
prefix-cache admission and live page-table rehash.  At laptop scale this
serves a reduced config end-to-end; at cluster scale the same engine runs
per-data-shard with the model axis handling TP (DESIGN.md §6).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer
from repro.serving.engine import ServeConfig, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch)
    if not any(k in ("attn", "local") for k in cfg.blocks):
        raise SystemExit(f"{args.arch}: paged-KV serving engine targets "
                         "attention archs; use examples/quickstart.py for SSM decode")
    params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = ServingEngine(params, cfg, ServeConfig(
        max_seqs=8, page_size=16, n_pages=1024, max_blocks=32,
        max_new_tokens=args.max_new))

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    ids = [eng.submit(list(rng.integers(1, cfg.vocab_size - 1,
                                        size=rng.integers(4, 24))))
           for _ in range(args.requests)]
    steps = eng.run()
    dt = time.time() - t0
    done = len(eng.finished)
    toks = sum(len(v) for v in eng.finished.values())
    print(f"served {done}/{args.requests} requests, {toks} tokens, "
          f"{steps} engine steps, {dt:.1f}s ({toks/max(dt,1e-9):.1f} tok/s), "
          f"page-table rehashes: {eng.rehashes}")
    return eng


if __name__ == "__main__":
    main()
