"""Paper §6.2 robustness claim: throughput past core saturation.

"When the number of worker threads exceeds the number of CPU cores, the
performance of DHASH increases slightly ... The performance of other
alternatives becomes flat or decreases due to the increased contention on
bucket locks."

SPMD mapping: batch width Q grows far beyond any fixed parallel resource;
DHash's per-op cost amortizes (vectorization), while the lock-modelled
tables' serialization rounds grow with Q/B and their throughput flattens or
falls.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ALGOS, UNIVERSE, Workload, run_throughput


def run(alpha=200, qs=(512, 2048, 8192, 16384), *, quiet=False):
    nbuckets = 64
    n = alpha * nbuckets
    rng = np.random.default_rng(0)
    present = rng.choice(UNIVERSE, size=n, replace=False).astype(np.int32)
    rows = []
    for name in ("DHash", "HT-RHT", "HT-Xu"):
        drv = ALGOS[name](nbuckets, n, seed=1)
        drv.populate(present)
        series = []
        for q in qs:
            wl = Workload(q=q, mix=(80, 10, 10))
            mops = run_throughput(drv, wl, present, steps=4,
                                  rng=np.random.default_rng(q)) / 1e6
            series.append(mops)
            rows.append((drv.name, q, mops))
            if not quiet:
                print(f"{drv.name:14s} Q={q:<6d} {mops:8.3f} Mops/s")
        trend = series[-1] / series[0]
        print(f"[summary] {drv.name}: Q x{qs[-1]//qs[0]} -> throughput x{trend:.2f} "
              f"({'scales' if trend > 1.5 else 'flat/degrades'})")
    return rows


if __name__ == "__main__":
    run()
