"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle,
exactly as specified — assert_allclose per cell (exact for int compare)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import count_primitives as _count_primitives
from repro.core import buckets, hashing
from repro.kernels import ops, ref


def _table(capacity, n_items, seed, max_probes=32, deletes=0):
    rng = np.random.default_rng(seed)
    t = buckets.linear_make(capacity, hashing.fresh("mix32", seed),
                            max_probes=max_probes)
    keys = jnp.asarray(rng.choice(10_000_000, size=n_items, replace=False)
                       .astype(np.int32))
    t, ok = jax.jit(buckets.linear_insert)(t, keys, keys * 3,
                                           jnp.ones(keys.shape, bool))
    if deletes:
        t, _ = jax.jit(buckets.linear_delete)(t, keys[:deletes],
                                              jnp.ones(deletes, bool))
    return t, keys, np.asarray(ok)


@pytest.mark.parametrize("capacity,n_items,n_queries", [
    (1 << 10, 500, 333),          # small, non-tile-aligned query count
    (1 << 14, 9_000, 4_096),      # multi-tile
    (1 << 15, 20_000, 10_001),    # odd query count, several slabs
])
def test_probe_lookup_matches_ref(capacity, n_items, n_queries):
    t, keys, ok = _table(capacity, n_items, seed=capacity % 97)
    rng = np.random.default_rng(1)
    qs = jnp.concatenate([
        keys[: min(n_items, n_queries // 2)],
        jnp.asarray(rng.integers(10_000_000, 2**31 - 1, n_queries)
                    .astype(np.int32))])[:n_queries]
    h0 = hashing.bucket_of(t.hfn, qs, t.capacity)
    f_ref, v_ref = ref.probe_lookup_ref(t.key, t.val, t.state, h0, qs, 32)
    f_k, v_k = ops.probe_lookup(t.key, t.val, t.state, h0, qs, max_probes=32)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))


def test_probe_lookup_with_tombstones():
    t, keys, _ = _table(1 << 13, 4_000, seed=3, deletes=1_000)
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    f_ref, v_ref = ref.probe_lookup_ref(t.key, t.val, t.state, h0, keys, 64)
    f_k, v_k = ops.probe_lookup(t.key, t.val, t.state, h0, keys, max_probes=64)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    assert int(f_k.sum()) == 3_000


def test_probe_lookup_adversarial_skew():
    """All queries hash into one region (the paper's collision attack):
    the slab fallback path must stay exact."""
    t = buckets.linear_make(1 << 14, hashing.fresh("mix32", 0), max_probes=64)
    # force a dense contiguous run by inserting colliding-by-construction keys
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.choice(1_000_000, 3000, replace=False).astype(np.int32))
    t, _ = jax.jit(buckets.linear_insert)(t, keys, keys, jnp.ones(3000, bool))
    qs = jnp.tile(keys[:128], 32)                     # heavy duplicate queries
    h0 = hashing.bucket_of(t.hfn, qs, t.capacity)
    f_ref, v_ref = ref.probe_lookup_ref(t.key, t.val, t.state, h0, qs, 64)
    f_k, v_k = ops.probe_lookup(t.key, t.val, t.state, h0, qs, max_probes=64)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))


def _ordered_args(n_old=1_500, n_new=1_200, n_q=4_096, hazard=64, seed=7):
    rng = np.random.default_rng(seed)
    told, keys, _ = _table(1 << 12, n_old, seed=11)
    tnew, keys2, _ = _table(1 << 12, n_new, seed=12)
    hk = jnp.asarray(rng.choice(10_000_000, hazard, replace=False).astype(np.int32))
    hv = hk * 7
    hl = jnp.asarray(rng.random(hazard) < 0.7)
    qs = jnp.concatenate([keys, keys2, hk,
                          jnp.asarray(rng.integers(2**30, 2**31 - 1, n_q)
                                      .astype(np.int32))])[:n_q]
    h0_old = hashing.bucket_of(told.hfn, qs, told.capacity)
    h0_new = hashing.bucket_of(tnew.hfn, qs, tnew.capacity)
    return ((told.key, told.val, told.state), (tnew.key, tnew.val, tnew.state),
            hk, hv, hl, h0_old, h0_new, qs)


def test_fused_rebuild_lookup_single_sort_single_pallas_call():
    """Acceptance: during an active rebuild the fused lookup path executes
    exactly ONE argsort and ONE pallas_call per batch; the unfused path pays
    at least two of each (old pass + new pass)."""
    args = _ordered_args(n_q=4_096)
    fused = jax.make_jaxpr(
        lambda *a: ops.ordered_lookup_fused(*a, max_probes=32))(*args)
    unfused = jax.make_jaxpr(
        lambda *a: ops.ordered_lookup(*a, max_probes=32))(*args)
    nf = _count_primitives(fused, ("sort", "pallas_call"))
    nu = _count_primitives(unfused, ("sort", "pallas_call"))
    assert nf == {"sort": 1, "pallas_call": 1}, nf
    assert nu["sort"] >= 2 and nu["pallas_call"] >= 2, nu
    # pass-count reduction is the interpret-mode proxy for the >=1.5x
    # rebuild-epoch throughput criterion (see bench_rebuild --fused)
    passes_u = nu["sort"] + nu["pallas_call"]
    passes_f = nf["sort"] + nf["pallas_call"]
    assert passes_u / passes_f >= 1.5


def test_probe2_matches_ref():
    """Fused two-table+hazard kernel == ordered oracle (multi-tile batch with
    duplicates and hazard hits)."""
    args = _ordered_args(n_q=4_096)
    f_ref, v_ref = ref.ordered_lookup_ref(*args, max_probes=32)
    f_k, v_k = ops.ordered_lookup_fused(*args, max_probes=32)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))


def test_probe2_skew_forced_fallback():
    """A large new table makes per-tile new-slab windows miss (h0_new is
    scattered while the shared sort is keyed on h0_old): complete=False
    queries must be recovered exactly by the gated fallback; duplicate query
    keys ride along."""
    rng = np.random.default_rng(3)
    told, keys, _ = _table(1 << 12, 1_000, seed=21)
    tnew = buckets.linear_make(1 << 15, hashing.fresh("mix32", 22), max_probes=32)
    k2 = jnp.asarray(rng.choice(10_000_000, 5_000, replace=False).astype(np.int32))
    tnew, _ = jax.jit(buckets.linear_insert)(tnew, k2, k2 * 9,
                                             jnp.ones(k2.shape, bool))
    hz = jnp.zeros(32, jnp.int32)
    qs = jnp.concatenate([k2[:2000], jnp.tile(k2[:128], 8), keys])
    h0_old = hashing.bucket_of(told.hfn, qs, told.capacity)
    h0_new = hashing.bucket_of(tnew.hfn, qs, tnew.capacity)
    args = ((told.key, told.val, told.state), (tnew.key, tnew.val, tnew.state),
            hz, hz, jnp.zeros(32, bool), h0_old, h0_new, qs)
    f_ref, v_ref = ref.ordered_lookup_ref(*args, max_probes=32)
    f_k, v_k = ops.ordered_lookup_fused(*args, max_probes=32)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))


def test_probe_insert_matches_oracle_low_load():
    """Claim kernel == insert oracle at low load: identical ok flags, every
    inserted key readable with its value, live-count conserved."""
    rng = np.random.default_rng(5)
    t = buckets.linear_make(1 << 13, hashing.fresh("mix32", 5), max_probes=32)
    keys = jnp.asarray(rng.choice(1_000_000, 3_000, replace=False).astype(np.int32))
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    mask = jnp.ones(keys.shape, bool)
    tk, tv, ts, ok = ops.probe_insert(t.key, t.val, t.state, h0, keys,
                                      keys * 5, mask, max_probes=32)
    _, _, ts_ref, ok_ref = ref.probe_insert_ref(t.key, t.val, t.state, h0,
                                                keys, keys * 5, mask, 32)
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(ok_ref))
    assert bool(ok.all())
    assert int((ts == 1).sum()) == int((ts_ref == 1).sum()) == 3_000
    f, v = ref.probe_lookup_ref(tk, tv, ts, h0, keys, 32)
    assert bool(f.all()) and bool((v == keys * 5).all())


def test_probe_insert_duplicates_and_existing():
    """buckets.linear_insert_fused (winner dedup + kernel) must agree with
    the jnp linear_insert on every observable: ok counts per key, final
    membership, values."""
    rng = np.random.default_rng(9)
    base = jnp.asarray(rng.choice(1_000_000, 500, replace=False).astype(np.int32))
    t0 = buckets.linear_make(1 << 12, hashing.fresh("mix32", 1), max_probes=32)
    t0, _ = jax.jit(buckets.linear_insert)(t0, base, base * 2,
                                           jnp.ones(base.shape, bool))
    # batch: duplicates of new keys, re-inserts of existing keys, masked-out
    fresh = jnp.asarray(rng.choice(np.arange(2_000_000, 3_000_000), 400,
                                   replace=False).astype(np.int32))
    batch = jnp.concatenate([fresh, fresh[:200], base[:100]])
    vals = batch * 3
    mask = jnp.ones(batch.shape, bool).at[-50:].set(False)
    t_j, ok_j = jax.jit(buckets.linear_insert)(t0, batch, vals, mask)
    t_k, ok_k = jax.jit(buckets.linear_insert_fused)(t0, batch, vals, mask)
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_j))
    assert int(buckets.linear_count_live(t_k)) == int(buckets.linear_count_live(t_j))
    probe = jnp.concatenate([base, fresh])
    f_j, v_j, _ = buckets.linear_lookup(t_j, probe)
    f_k, v_k, _ = buckets.linear_lookup(t_k, probe)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_j))
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_j))


def test_probe_insert_full_table_pressure():
    """Near-capacity insert with a short probe bound: successful claims are
    readable, failures genuinely exhausted their windows, no slot double-
    claimed (live count == ok count)."""
    rng = np.random.default_rng(4)
    t = buckets.linear_make(1 << 10, hashing.fresh("mix32", 5), max_probes=16)
    keys = jnp.asarray(rng.choice(1_000_000, 1_200, replace=False).astype(np.int32))
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    mask = jnp.ones(keys.shape, bool)
    tk, tv, ts, ok = ops.probe_insert(t.key, t.val, t.state, h0, keys, keys,
                                      mask, max_probes=16)
    _, _, _, ok_ref = ref.probe_insert_ref(t.key, t.val, t.state, h0, keys,
                                           keys, mask, 16)
    # claim order is a different (equally legal) linearization than the
    # oracle's, so the totals may differ by a whisker under contention
    assert abs(int(ok.sum()) - int(ok_ref.sum())) <= 5
    assert int((ts == 1).sum()) == int(ok.sum())       # no double-claims
    f, v = ref.probe_lookup_ref(tk, tv, ts, h0, keys, 16)
    assert bool(f[ok].all()) and bool((v[ok] == keys[ok]).all())
    assert not bool(f[~ok].any())                       # failures not inserted


def test_ordered_lookup_fused_matches_ref():
    """The fused old->hazard->new kernel path == ordered_lookup_ref."""
    rng = np.random.default_rng(7)
    told, keys, _ = _table(1 << 12, 1_500, seed=11)
    tnew, keys2, _ = _table(1 << 12, 1_200, seed=12)
    hk = jnp.asarray(rng.choice(10_000_000, 64, replace=False).astype(np.int32))
    hv = hk * 7
    hl = jnp.asarray(rng.random(64) < 0.7)
    qs = jnp.concatenate([keys[:500], keys2[:500], hk,
                          jnp.asarray(rng.integers(2**30, 2**31 - 1, 300)
                                      .astype(np.int32))])
    h0_old = hashing.bucket_of(told.hfn, qs, told.capacity)
    h0_new = hashing.bucket_of(tnew.hfn, qs, tnew.capacity)
    args = ((told.key, told.val, told.state), (tnew.key, tnew.val, tnew.state),
            hk, hv, hl, h0_old, h0_new, qs)
    f_ref, v_ref = ref.ordered_lookup_ref(*args, max_probes=32)
    f_k, v_k = ops.ordered_lookup(*args, max_probes=32)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_ref))
