"""Functional analogues of the paper's comparison systems (§2, §6.1).

The paper benchmarks DHash against three practical hash tables.  Each is
reproduced here with its *cost structure* mapped faithfully into the SPMD
model (a batch of Q ops = Q concurrent threads):

* ``HTXu``   — Herbert Xu's dynamic table (Linux IGMP, 2010).  Two pointer
  sets per node -> modelled as two chain structures; while a rebuild is in
  progress every update maintains BOTH structures, and updates take
  per-bucket locks.  Lock serialization is modelled exactly: each "round"
  grants at most one pending op per bucket (cross-bucket ops proceed in
  parallel, same-bucket ops serialize), so wall-time grows with the max
  per-bucket collision count — precisely how lock contention behaves.
  Rebuild itself is cheap (single traversal relinking the passive set);
  memory footprint is 2x (the drawback the paper notes).

* ``HTRHT``  — Linux rhashtable (Graf, 2014).  Single pointer set; rebuild
  must walk to the TAIL of a bucket chain to distribute one node (O(len)
  walk per node -> O(len^2) per bucket), per-bucket locks for updates,
  lookups during rebuild probe old then new.

* ``HTSplit`` — split-ordered lists (Shalev & Shavit, 2006).  Lock-free but
  only *resizable*: bucket index is ``key & (2^i - 1)`` — the hash function
  can never change, so adversarial key sets cannot be rebuilt away (the
  paper's motivating weakness).  Resize republishes bucket pointers without
  moving nodes (cheap, modelled as one vectorized rechain pass).

All three share the arena/chain machinery from ``buckets.py`` so that the
per-hop traversal cost is identical across contenders; only the algorithmic
structure differs — which is what the paper measures.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import buckets, hashing
from repro.core.struct_utils import pytree_dataclass, replace

I32 = jnp.int32


# ---------------------------------------------------------------------------
# lock serialization model (shared by HT-Xu and HT-RHT)
# ---------------------------------------------------------------------------

def lock_serialized(op: Callable, t, keys, vals, mask, nbuckets: int,
                    bucket_fn: Callable):
    """Apply a batched update under per-bucket mutexes.

    Each while-loop round grants the lock of every contended bucket to the
    lowest-index pending op and applies all granted ops in parallel; the rest
    retry next round.  Rounds executed == max ops targeting one bucket, which
    is the exact serialization a per-bucket mutex imposes.
    """
    q = keys.shape[0]
    idx = jnp.arange(q, dtype=I32)

    def cond(carry):
        _, pending, _, _ = carry
        return pending.any()

    def body(carry):
        t, pending, ok, rounds = carry
        b = bucket_fn(t, keys)
        claim = jnp.full((nbuckets,), q, I32).at[jnp.where(pending, b, nbuckets)].min(idx, mode="drop")
        grant = pending & (claim[b] == idx)
        t, got = op(t, keys, vals, grant)
        return t, pending & ~grant, ok | got, rounds + 1

    t, _, ok, rounds = jax.lax.while_loop(
        cond, body, (t, mask, jnp.zeros((q,), bool), jnp.asarray(0, I32)))
    return t, ok, rounds


# ---------------------------------------------------------------------------
# HT-Xu: two pointer sets per node
# ---------------------------------------------------------------------------

@pytree_dataclass(meta_fields=("chunk",))
class HTXu:
    chunk: int
    t0: buckets.ChainTable
    t1: buckets.ChainTable
    active: jax.Array       # scalar i32: which structure serves lookups
    rebuilding: jax.Array   # scalar bool
    cursor: jax.Array       # scalar i32 (arena scan of active table)


def xu_make(nbuckets: int, arena: int, *, chunk: int = 256, seed: int = 0,
            max_chain: int = 64) -> HTXu:
    rng = np.random.default_rng(seed)
    t0 = buckets.chain_make(nbuckets, arena, hashing.fresh("mix32", rng), max_chain)
    t1 = buckets.chain_make(nbuckets, arena, hashing.fresh("mix32", rng), max_chain)
    return HTXu(chunk=chunk, t0=t0, t1=t1, active=jnp.asarray(0, I32),
                rebuilding=jnp.asarray(False), cursor=jnp.asarray(0, I32))


def _xu_pick(x: HTXu):
    return jax.lax.cond(x.active == 0, lambda: (x.t0, x.t1), lambda: (x.t1, x.t0))


def xu_lookup(x: HTXu, keys):
    act, _ = _xu_pick(x)
    f, v, _ = buckets.chain_lookup(act, keys)
    return f, v


def _xu_apply(x: HTXu, op, keys, vals, mask):
    """Update under per-bucket locks; during rebuild, maintain BOTH sets.
    The lock is taken ONCE per op (Xu's design: one bucket lock covers the
    node's entry in both pointer sets); the passive-set maintenance is the
    extra single pass, not extra lock rounds."""
    act, pas = _xu_pick(x)
    def bfn(t, k):
        return hashing.bucket_of(t.hfn, k, t.nbuckets)
    act, ok, _ = lock_serialized(op, act, keys, vals, mask, act.nbuckets, bfn)

    def also_passive(pas):
        pas2, _ = op(pas, keys, vals, mask)
        return pas2

    pas = jax.lax.cond(x.rebuilding, also_passive, lambda p: p, pas)
    t0, t1 = jax.lax.cond(x.active == 0, lambda: (act, pas), lambda: (pas, act))
    return replace(x, t0=t0, t1=t1), ok


def xu_insert(x: HTXu, keys, vals, mask=None):
    mask = jnp.ones(keys.shape, bool) if mask is None else mask
    return _xu_apply(x, buckets.chain_insert, keys, vals, mask)


def xu_delete(x: HTXu, keys, mask=None):
    mask = jnp.ones(keys.shape, bool) if mask is None else mask
    def op(t, k, v, m):
        return buckets.chain_delete(t, k, m)
    return _xu_apply(x, op, keys, vals=keys, mask=mask)


def xu_rebuild_start(x: HTXu, *, seed: int) -> HTXu:
    """Reset the passive structure with a fresh hash function."""
    act, pas = _xu_pick(x)
    fresh = buckets.chain_make(pas.nbuckets, pas.arena, hashing.fresh("mix32", seed),
                               pas.max_chain)
    t0, t1 = jax.lax.cond(x.active == 0, lambda: (act, fresh), lambda: (fresh, act))
    return replace(x, t0=t0, t1=t1, rebuilding=jnp.asarray(True), cursor=jnp.asarray(0, I32))


def xu_rebuild_chunk(x: HTXu) -> HTXu:
    """Relink one arena chunk of the active set into the passive set.
    Cheap: one pass, no hazard period (nodes stay reachable via the active
    set the whole time — Xu's two-pointer-set advantage)."""
    act, pas = _xu_pick(x)
    pos = x.cursor + jnp.arange(x.chunk, dtype=I32)
    valid = pos < act.arena
    cpos = jnp.where(valid, pos, 0)
    live = valid & (act.astate[cpos] == buckets.LIVE)
    ks = jnp.where(live, act.akey[cpos], 0)
    vs = jnp.where(live, act.aval[cpos], 0)
    pas, _ = buckets.chain_insert(pas, ks, vs, live)
    t0, t1 = jax.lax.cond(x.active == 0, lambda: (act, pas), lambda: (pas, act))
    return replace(x, t0=t0, t1=t1, cursor=jnp.minimum(x.cursor + x.chunk, act.arena))


def xu_rebuild_done(x: HTXu):
    act, _ = _xu_pick(x)
    return x.rebuilding & (x.cursor >= act.arena)


def xu_rebuild_finish(x: HTXu) -> HTXu:
    return replace(x, active=1 - x.active, rebuilding=jnp.asarray(False),
                   cursor=jnp.asarray(0, I32))


# ---------------------------------------------------------------------------
# HT-RHT: Linux rhashtable
# ---------------------------------------------------------------------------

@pytree_dataclass(meta_fields=("bchunk",))
class HTRHT:
    bchunk: int             # buckets processed per rebuild chunk
    old: buckets.ChainTable
    new: buckets.ChainTable
    rebuilding: jax.Array
    bcursor: jax.Array      # bucket scan position (wraps)


def rht_make(nbuckets: int, arena: int, *, bchunk: int = 256, seed: int = 0,
             max_chain: int = 64) -> HTRHT:
    rng = np.random.default_rng(seed)
    old = buckets.chain_make(nbuckets, arena, hashing.fresh("mix32", rng), max_chain)
    new = buckets.chain_make(nbuckets, arena, hashing.fresh("mix32", rng), max_chain)
    return HTRHT(bchunk=bchunk, old=old, new=new,
                 rebuilding=jnp.asarray(False), bcursor=jnp.asarray(0, I32))


def rht_lookup(r: HTRHT, keys):
    f_old, v_old, _ = buckets.chain_lookup(r.old, keys)

    def slow(_):
        f_new, v_new, _ = buckets.chain_lookup(r.new, keys)
        return f_old | f_new, jnp.where(f_old, v_old, v_new)

    return jax.lax.cond(r.rebuilding, slow, lambda _: (f_old, v_old), None)


def rht_insert(r: HTRHT, keys, vals, mask=None):
    mask = jnp.ones(keys.shape, bool) if mask is None else mask
    def bfn(t, k):
        return hashing.bucket_of(t.hfn, k, t.nbuckets)

    def idle(r):
        t, ok, _ = lock_serialized(buckets.chain_insert, r.old, keys, vals, mask,
                                   r.old.nbuckets, bfn)
        return replace(r, old=t), ok

    def rebuilding(r):
        t, ok, _ = lock_serialized(buckets.chain_insert, r.new, keys, vals, mask,
                                   r.new.nbuckets, bfn)
        return replace(r, new=t), ok

    return jax.lax.cond(r.rebuilding, rebuilding, idle, r)


def rht_delete(r: HTRHT, keys, mask=None):
    mask = jnp.ones(keys.shape, bool) if mask is None else mask
    def bfn(t, k):
        return hashing.bucket_of(t.hfn, k, t.nbuckets)
    def op(t, k, v, m):
        return buckets.chain_delete(t, k, m)
    t_old, ok_old, _ = lock_serialized(op, r.old, keys, keys, mask, r.old.nbuckets, bfn)

    def slow(r):
        t_new, ok_new, _ = lock_serialized(op, r.new, keys, keys, mask & ~ok_old,
                                           r.new.nbuckets, bfn)
        return replace(r, old=t_old, new=t_new), ok_old | ok_new

    return jax.lax.cond(r.rebuilding, slow, lambda r: (replace(r, old=t_old), ok_old), r)


def rht_rebuild_start(r: HTRHT, *, seed: int) -> HTRHT:
    fresh = buckets.chain_make(r.new.nbuckets, r.new.arena, hashing.fresh("mix32", seed),
                               r.new.max_chain)
    return replace(r, new=fresh, rebuilding=jnp.asarray(True), bcursor=jnp.asarray(0, I32))


def rht_rebuild_chunk(r: HTRHT) -> HTRHT:
    """Distribute the TAIL node of each of the next ``bchunk`` buckets.

    Graf's algorithm must re-traverse the chain to reach the tail for every
    single node it moves — the O(len) walk modelled here (the paper's stated
    drawback #1, and why DHash wins Fig 3)."""
    old = r.old
    nb = old.nbuckets
    b = (r.bcursor + jnp.arange(r.bchunk, dtype=I32)) % nb
    cur0 = old.heads[b]

    def body(_, carry):
        cur, prev = carry
        valid = cur >= 0
        c = jnp.where(valid, cur, 0)
        nxt = old.anext[c]
        stop = valid & (nxt < 0)           # cur is the tail
        prev = jnp.where(valid & ~stop, cur, prev)
        cur = jnp.where(valid & ~stop, nxt, cur)
        return cur, prev

    tail, prev = jax.lax.fori_loop(0, old.max_chain, body,
                                   (cur0, jnp.full_like(cur0, -1)))
    has = tail >= 0
    tc = jnp.where(has, tail, 0)
    was_live = has & (old.astate[tc] == buckets.LIVE)
    ks = jnp.where(was_live, old.akey[tc], 0)
    vs = jnp.where(was_live, old.aval[tc], 0)
    # unlink the tail: prev.next = -1, or head = -1 if the tail was the head
    anext = old.anext.at[jnp.where(has & (prev >= 0), prev, old.arena)].set(-1, mode="drop")
    heads = old.heads.at[jnp.where(has & (prev < 0), b, nb)].set(-1, mode="drop")
    astate = old.astate.at[jnp.where(has, tc, old.arena)].set(buckets.EMPTY, mode="drop")
    old = replace(old, anext=anext, heads=heads, astate=astate)
    new, _ = buckets.chain_insert(r.new, ks, vs, was_live)
    return replace(r, old=old, new=new, bcursor=(r.bcursor + r.bchunk) % nb)


def rht_rebuild_done(r: HTRHT):
    return r.rebuilding & (buckets.chain_count_live(r.old) == 0)


def rht_rebuild_finish(r: HTRHT) -> HTRHT:
    return replace(r, old=r.new, new=r.old, rebuilding=jnp.asarray(False),
                   bcursor=jnp.asarray(0, I32))


# ---------------------------------------------------------------------------
# HT-Split: split-ordered resizable table (lock-free, fixed hash)
# ---------------------------------------------------------------------------

@pytree_dataclass(meta_fields=("max_buckets",))
class HTSplit:
    max_buckets: int        # static head-array capacity (max 2^i)
    t: buckets.ChainTable   # nbuckets == max_buckets; active count is dynamic
    nactive: jax.Array      # scalar i32: current 2^i bucket count


def split_make(max_buckets: int, arena: int, *, init_buckets: int = 64, seed: int = 0,
               max_chain: int = 64) -> HTSplit:
    t = buckets.chain_make(max_buckets, arena, hashing.fresh("mix32", seed), max_chain)
    return HTSplit(max_buckets=max_buckets, t=t, nactive=jnp.asarray(init_buckets, I32))


def _split_bucket(s: HTSplit, keys):
    # THE structural constraint: bucket = key mod 2^i. No seed, no defense.
    return (keys & (s.nactive - 1)).astype(I32)


def split_lookup(s: HTSplit, keys):
    f, v, _ = buckets.chain_lookup(s.t, keys, bucket=_split_bucket(s, keys))
    return f, v


def split_insert(s: HTSplit, keys, vals, mask=None):
    mask = jnp.ones(keys.shape, bool) if mask is None else mask
    t, ok = buckets.chain_insert(s.t, keys, vals, mask, bucket=_split_bucket(s, keys))
    return replace(s, t=t), ok


def split_delete(s: HTSplit, keys, mask=None):
    mask = jnp.ones(keys.shape, bool) if mask is None else mask
    t, ok = buckets.chain_delete(s.t, keys, mask, bucket=_split_bucket(s, keys))
    return replace(s, t=t), ok


def split_resize(s: HTSplit, grow: bool) -> HTSplit:
    """Double/halve the bucket count.  Split-ordered lists republish bucket
    pointers without moving nodes; the vectorized analogue is one rechain
    pass over live nodes (no per-node distribution, no hazard period)."""
    nact = jnp.where(grow, jnp.minimum(s.nactive * 2, s.max_buckets),
                     jnp.maximum(s.nactive // 2, 1))
    s2 = replace(s, nactive=nact)
    t = s.t
    live = t.astate == buckets.LIVE
    keys = jnp.where(live, t.akey, 0)
    fresh = buckets.chain_make(t.nbuckets, t.arena, t.hfn, t.max_chain)
    t2, _ = buckets.chain_insert(fresh, keys, t.aval, live,
                                 bucket=_split_bucket(s2, keys))
    return replace(s2, t=t2)
