"""Mixture-of-Experts: top-k learned routing and DHash-backed hash routing.

The DHash integration (DESIGN.md §3.1): hash routing assigns token->expert by
seeded hashes (Roller et al. hash layers).  Token-frequency drift makes
experts hot — the paper's hash-collision scenario — so the router consults a
DHash *override table* first: ``lookup(token_id)`` returning a packed expert
assignment.  Rebalancing inserts overrides / rebuilds the table with a new
seed **live**, while training or serving steps keep routing at full rate;
the rebuild never blocks a step (the paper's non-blocking property).

Dispatch is capacity-based gather/scatter (sparse compute: FLOPs scale with
top_k, not n_experts), EP-shardable on the expert axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dhash, hashing

F32 = jnp.float32
I32 = jnp.int32


def topk_route(x: jax.Array, w_router: jax.Array, k: int):
    """x: [T,D] -> (expert_id [T,k], gate [T,k], aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x, w_router).astype(F32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_id = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    e = w_router.shape[1]
    # Switch-style load-balance loss
    frac_tokens = jnp.mean(jax.nn.one_hot(expert_id[:, 0], e, dtype=F32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return expert_id, gate.astype(x.dtype), aux


def hash_route(token_ids: jax.Array, table: dhash.DHashState | None,
               seeds: jax.Array, n_experts: int, k: int):
    """DHash-backed hash routing. token_ids: [T] int32.

    Default: expert_j = mix32(token, seed_j) % E.  The override table maps
    token -> packed assignment (15 bits per slot, k <= 2).  aux = 0.
    """
    outs = []
    for j in range(k):
        fn = hashing.HashFn(kind="mix32", seeds=seeds[j])
        outs.append((hashing.hash_u32(fn, token_ids) % np.uint32(n_experts)).astype(I32))
    expert_id = jnp.stack(outs, axis=-1)                  # [T,k]
    if table is not None:
        found, packed = dhash.lookup(table, token_ids)
        ov = jnp.stack([packed & 0x7FFF, (packed >> 15) & 0x7FFF], axis=-1)[:, :k]
        expert_id = jnp.where(found[:, None], ov.astype(I32), expert_id)
    gate = jnp.full(expert_id.shape, 1.0 / k, F32)
    return expert_id, gate, jnp.zeros((), F32)


def pack_assignment(e1: jax.Array, e2: jax.Array | None = None) -> jax.Array:
    """Pack up to two expert ids into the DHash value payload."""
    v = e1.astype(I32)
    if e2 is not None:
        v = v | (e2.astype(I32) << 15)
    return v


def moe_ffn(x: jax.Array, expert_id: jax.Array, gate: jax.Array,
            wg: jax.Array, wu: jax.Array, wd: jax.Array,
            *, capacity_factor: float = 1.25):
    """Capacity-based sparse expert FFN, batch-sharding-preserving.

    x: [B,S,D]; expert_id/gate: [B,S,K]; wg/wu: [E,D,F]; wd: [E,F,D].

    Dispatch positions are computed PER BATCH ROW (cumsum along the token
    axis only): a global cumsum over a flattened [B*S*K] axis would create a
    cross-shard sequential dependency and force GSPMD to replicate the whole
    block (observed: arctic attention lost its batch sharding).  Row-local
    capacity keeps the batch axis sharded end-to-end; the [B,E,cap,*]
    dispatch tensors reshard batch->expert exactly where EP's all-to-all
    belongs.  Tokens over per-row capacity drop (standard).
    """
    b, s, d = x.shape
    k = expert_id.shape[-1]
    e = wg.shape[0]
    cap = int(np.ceil(s * k / e * capacity_factor))
    t = s * k
    ecap = e * cap
    flat_e = expert_id.reshape(b, t)                      # [B,T]
    tok = jnp.broadcast_to(jnp.arange(s, dtype=I32)[:, None], (s, k)).reshape(t)

    # sort assignments by expert per row; rank within expert group
    order = jnp.argsort(flat_e, axis=1, stable=True)      # [B,T]
    se = jnp.take_along_axis(flat_e, order, axis=1)
    ar = jnp.broadcast_to(jnp.arange(t, dtype=I32), (b, t))
    run_start = jnp.concatenate(
        [jnp.ones((b, 1), bool), se[:, 1:] != se[:, :-1]], axis=1)
    start_idx = jax.lax.cummax(jnp.where(run_start, ar, 0), axis=1)
    rank = ar - start_idx                                 # [B,T]
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, ecap)         # [B,T] in sorted order

    # small int scatters only: slot -> token (for dispatch gather) and
    # assignment -> slot (for combine gather)
    bidx = jnp.arange(b, dtype=I32)[:, None]
    slot_tok = jnp.full((b, ecap + 1), t, I32).at[bidx, slot].set(
        order, mode="drop")                               # [B,Ecap+1]
    asg_slot = jnp.full((b, t), ecap, I32).at[bidx, order].set(
        slot, mode="drop")                                # [B,T]

    # heavy movement is gathers (batch sharding preserved)
    from repro.models.sharding import constrain
    src = jnp.take_along_axis(
        jnp.concatenate([x[:, tok], jnp.zeros((b, 1, d), x.dtype)], axis=1),
        slot_tok[:, :ecap, None], axis=1)                 # [B,Ecap,D]
    disp = constrain(src.reshape(b, e, cap, d), "dp", "tp", None, None)
    h = jnp.einsum("becd,edf->becf", disp, wg)
    u = jnp.einsum("becd,edf->becf", disp, wu)
    h = jax.nn.silu(h.astype(F32)).astype(x.dtype) * u
    y_e = jnp.einsum("becf,efd->becd", h, wd)             # [B,E,cap,D]
    y_e = constrain(y_e, "dp", "tp", None, None)
    y_flat = jnp.concatenate(
        [y_e.reshape(b, ecap, d), jnp.zeros((b, 1, d), x.dtype)], axis=1)
    contrib = jnp.take_along_axis(y_flat, asg_slot[..., None], axis=1)  # [B,T,D]
    w = gate.reshape(b, t, 1).astype(x.dtype)
    contrib = contrib * w
    out = contrib.reshape(b, s, k, d).sum(axis=2)
    load = jnp.zeros((e + 1,), I32).at[jnp.where(keep, se, e)].add(
        1, mode="drop")[:e]
    return out, load
