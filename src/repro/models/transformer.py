"""Model assembly: stacked-weight scan blocks for every architecture family.

Design rules (MaxText-style):
* weights for the repeated block are stacked on a leading layer axis and the
  stack is consumed by ONE lax.scan — HLO stays compact regardless of depth;
* per-layer heterogeneity that preserves parameter shapes (gemma local vs
  global attention, per-layer rope theta, hash-router seeds) is expressed as
  *scanned flag arrays*, not separate scans;
* heterogeneity that changes parameter structure (zamba2's weight-shared
  attention block between mamba groups) lives outside the scan.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import dhash
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import embed, rms_norm, swiglu
from repro.models.sharding import constrain

F32 = jnp.float32
I32 = jnp.int32


# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------

def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, F32) * scale).astype(dtype)


def _attn_block_init(key, cfg: ArchConfig, n: int, dtype) -> dict:
    """n stacked attention(+ffn/moe) blocks."""
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    ks = iter(jax.random.split(key, 16))
    s = d ** -0.5
    p = {
        "ln1": jnp.zeros((n, d), dtype),
        "wo": _init(next(ks), (n, hq, hd, d), (hq * hd) ** -0.5, dtype),
        "ln2": jnp.zeros((n, d), dtype),
    }
    if cfg.fused_qkv:
        p["wqkv"] = _init(next(ks), (n, d, hq + 2 * hkv, hd), s, dtype)
    else:
        p["wq"] = _init(next(ks), (n, d, hq, hd), s, dtype)
        p["wk"] = _init(next(ks), (n, d, hkv, hd), s, dtype)
        p["wv"] = _init(next(ks), (n, d, hkv, hd), s, dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((n, hd), dtype)
        p["k_norm"] = jnp.zeros((n, hd), dtype)
    if cfg.n_experts:
        fe = cfg.moe_dff
        p["router"] = _init(next(ks), (n, d, cfg.n_experts), s, dtype)
        p["we_g"] = _init(next(ks), (n, cfg.n_experts, d, fe), s, dtype)
        p["we_u"] = _init(next(ks), (n, cfg.n_experts, d, fe), s, dtype)
        p["we_d"] = _init(next(ks), (n, cfg.n_experts, fe, d), fe ** -0.5, dtype)
        if cfg.dense_ff_residual:
            p |= _mlp_init(ks, cfg, n, d, f, s, dtype)
    else:
        p |= _mlp_init(ks, cfg, n, d, f, s, dtype)
    return p


def _mlp_init(ks, cfg, n, d, f, s, dtype) -> dict:
    if cfg.fused_gate_up:
        # [2, d, f] (stacked), NOT [d, 2f] (concatenated): splitting a
        # concatenated layout along the model-sharded f axis would place g
        # and u on disjoint device halves -> resharding collectives
        # (measured: refuted hypothesis in §Perf iteration 2 of gemma3)
        return {"wgu": _init(next(ks), (n, 2, d, f), s, dtype),
                "wd": _init(next(ks), (n, f, d), f ** -0.5, dtype)}
    return {"wg": _init(next(ks), (n, d, f), s, dtype),
            "wu": _init(next(ks), (n, d, f), s, dtype),
            "wd": _init(next(ks), (n, f, d), f ** -0.5, dtype)}


def _attn_flags(cfg: ArchConfig) -> dict:
    """Per-layer window / rope-theta arrays for the scanned attn stack."""
    kinds = [k for k in cfg.blocks if k in ("attn", "local")]
    window = np.array([cfg.window if k == "local" else 0 for k in kinds], np.int32)
    tg = cfg.rope_theta_global or cfg.rope_theta
    theta = np.array([cfg.rope_theta if k == "local" else tg for k in kinds], np.float32)
    return {"window": jnp.asarray(window), "theta": jnp.asarray(theta)}


def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    ks = iter(jax.random.split(key, 8))
    d, v = cfg.d_model, cfg.vocab_size
    params: dict[str, Any] = {
        "embed": _init(next(ks), (v, d), 1.0, dtype),
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = _init(next(ks), (d, v), d ** -0.5, dtype)

    kinds = cfg.blocks
    n_attn = sum(k in ("attn", "local") for k in kinds)
    n_mamba = sum(k == "mamba2" for k in kinds)
    n_rwkv = sum(k == "rwkv6" for k in kinds)
    if n_attn:
        params["attn_stack"] = _attn_block_init(next(ks), cfg, n_attn, dtype)
    if n_mamba:
        d_in = cfg.ssm_expand * d
        nh = d_in // cfg.ssm_headdim
        sub = jax.random.split(next(ks), n_mamba)
        per = [dict(ssm_lib.mamba2_init(sub[i], d, d_inner=d_in, n_heads=nh,
                                        d_state=cfg.ssm_state, conv_k=cfg.ssm_conv,
                                        dtype=dtype),
                    ln=jnp.zeros((d,), dtype)) for i in range(n_mamba)]
        params["mamba_stack"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)
    if n_rwkv:
        nh = d // cfg.rwkv_head_size
        sub = jax.random.split(next(ks), n_rwkv)
        per = [dict(rwkv_lib.rwkv6_init(sub[i], d, cfg.d_ff, n_heads=nh,
                                        head_size=cfg.rwkv_head_size, dtype=dtype,
                                        fused_rkvg=cfg.rwkv_fused_rkvg),
                    ln1=jnp.zeros((d,), dtype), ln2=jnp.zeros((d,), dtype))
               for i in range(n_rwkv)]
        params["rwkv_stack"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)
    if cfg.shared_attn_every:
        shared_cfg = cfg.scaled(n_experts=0, block_pattern=("attn",))
        params["shared_attn"] = jax.tree_util.tree_map(
            lambda x: x[0], _attn_block_init(next(ks), shared_cfg, 1, dtype))
    return params


# ---------------------------------------------------------------------------
# block bodies
# ---------------------------------------------------------------------------

def _mlp_fwd(h: jax.Array, p: dict) -> jax.Array:
    if "wgu" in p:
        gu = jnp.einsum("bsd,kdf->bskf", h, p["wgu"])   # one matmul, one dx AR
        g, u = gu[:, :, 0], gu[:, :, 1]                 # split on UNsharded k
        act = jax.nn.silu(g.astype(F32)).astype(h.dtype) * u
        return jnp.einsum("bsf,fd->bsd", act, p["wd"])
    return swiglu(h, p["wg"], p["wu"], p["wd"])


def _project_qkv_cfg(h: jax.Array, p: dict, cfg: ArchConfig):
    if "wqkv" in p:
        qkv = jnp.einsum("bsd,dhk->bshk", h, p["wqkv"])
        q, k, v = jnp.split(qkv, [cfg.n_heads, cfg.n_heads + cfg.n_kv_heads],
                            axis=2)
        if cfg.qk_norm:
            from repro.models.layers import rms_norm as _rn
            q, k = _rn(q, p["q_norm"]), _rn(k, p["k_norm"])
        return q, k, v
    qkn = (p["q_norm"], p["k_norm"]) if cfg.qk_norm else None
    return attn_lib.project_qkv(h, p["wq"], p["wk"], p["wv"], qk_norm_scale=qkn)


def _ckpt(body, cfg: ArchConfig):
    if not cfg.remat:
        return body
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body)


def _ffn_or_moe(h: jax.Array, p: dict, cfg: ArchConfig, token_ids, router_override,
                hash_seeds):
    """Feed-forward half of an attention block. Returns (y, aux, load)."""
    b, s, d = h.shape
    if not cfg.n_experts:
        return _mlp_fwd(h, p), jnp.zeros((), F32), None
    if cfg.use_hash_router:
        eid, gate, aux = moe_lib.hash_route(token_ids.reshape(-1), None,
                                            hash_seeds, cfg.n_experts, cfg.top_k)
        if router_override is not None:
            found, packed = router_override
            ov = jnp.stack([packed & 0x7FFF, (packed >> 15) & 0x7FFF], -1)[:, :cfg.top_k]
            eid = jnp.where(found[:, None], ov.astype(I32), eid)
        eid = eid.reshape(b, s, -1)
        gate = gate.reshape(b, s, -1)
    else:
        eid, gate, aux = moe_lib.topk_route(h.reshape(b * s, d), p["router"],
                                            cfg.top_k)
        eid = eid.reshape(b, s, -1)
        gate = gate.reshape(b, s, -1)
    y, load = moe_lib.moe_ffn(h, eid, gate, p["we_g"], p["we_u"], p["we_d"])
    if cfg.dense_ff_residual:
        y = y + _mlp_fwd(h, p)
    return y, aux, load


def _attn_body(x, p, flags, cfg: ArchConfig, positions, token_ids,
               router_override, decode_cache=None, cache_len=None):
    """One attention block. positions: [B,S] or [3,B,S] (mrope)."""
    x = constrain(x, "dp", None, None)
    h = rms_norm(x, p["ln1"])
    q, k, v = _project_qkv_cfg(h, p, cfg)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)
    theta = flags["theta"]
    if cfg.mrope_sections is not None:
        from repro.models.layers import apply_mrope
        rope = partial(apply_mrope, theta=cfg.rope_theta, sections=cfg.mrope_sections)
        q, k = rope(q, positions), rope(k, positions)
    else:
        from repro.models.layers import apply_rope
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    if decode_cache is None:
        qp = positions[0] if cfg.mrope_sections is not None else positions
        o = attn_lib.attention(q, k, v, q_pos=qp, k_pos=qp, causal=cfg.causal,
                               window=flags["window"], softcap=cfg.attn_softcap,
                               chunk=cfg.attn_chunk)
        new_cache = None
    else:
        kc, vc = decode_cache
        idx = cache_len  # [B]
        bidx = jnp.arange(kc.shape[0], dtype=I32)
        kc = kc.at[bidx, idx].set(k[:, 0])
        vc = vc.at[bidx, idx].set(v[:, 0])
        o = attn_lib.decode_attention(q, kc, vc, cache_len + 1,
                                      window=flags["window"],
                                      softcap=cfg.attn_softcap)
        new_cache = (kc, vc)
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    x = constrain(x + o, "dp", None, None)
    h2 = rms_norm(x, p["ln2"])
    y, aux, load = _ffn_or_moe(h2, p, cfg, token_ids, router_override, flags.get("hash_seeds"))
    return constrain(x + y, "dp", None, None), aux, load, new_cache


def _mamba_body(x, p, cfg: ArchConfig, decode_state=None):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_headdim
    x = constrain(x, "dp", None, None)
    h = rms_norm(x, p["ln"])
    kw = dict(d_inner=d_in, n_heads=nh, headdim=cfg.ssm_headdim,
              d_state=cfg.ssm_state, conv_k=cfg.ssm_conv)
    if decode_state is None:
        y = ssm_lib.mamba2_forward(h, p, chunk=min(128, h.shape[1]), **kw)
        return x + y, None
    y, st = ssm_lib.mamba2_decode(h, decode_state, p, **kw)
    return x + y, st


def _rwkv_body(x, p, cfg: ArchConfig, decode_state=None):
    nh = cfg.d_model // cfg.rwkv_head_size
    x = constrain(x, "dp", None, None)
    h = rms_norm(x, p["ln1"])
    if decode_state is None:
        y, _ = rwkv_lib.rwkv6_time_mix(h, p, n_heads=nh,
                                       head_size=cfg.rwkv_head_size,
                                       chunk=cfg.rwkv_chunk,
                                       tp_state=cfg.rwkv_tp_state)
        x = x + y
        y2 = rwkv_lib.rwkv6_channel_mix(rms_norm(x, p["ln2"]), p)
        return x + y2, None
    y, s1 = rwkv_lib.rwkv6_time_mix(h, p, n_heads=nh, head_size=cfg.rwkv_head_size,
                                    prev_token=decode_state["tm_prev"],
                                    s0=decode_state["wkv"])
    x = x + y
    h2 = rms_norm(x, p["ln2"])
    y2 = rwkv_lib.rwkv6_channel_mix(h2, p, prev_token=decode_state["cm_prev"])
    st = {"wkv": s1, "tm_prev": h, "cm_prev": h2}
    return x + y2, st


# ---------------------------------------------------------------------------
# training forward
# ---------------------------------------------------------------------------

def _scan_attn(x, stack, cfg: ArchConfig, positions, token_ids, router_override):
    flags = _attn_flags(cfg)
    n = sum(k in ("attn", "local") for k in cfg.blocks)
    if cfg.use_hash_router and cfg.n_experts:
        key = jax.random.PRNGKey(0)
        seeds = jax.random.randint(key, (n, cfg.top_k, 2), 0, 2**31 - 1).astype(jnp.uint32)
        flags = dict(flags, hash_seeds=seeds)

    def body(carry, sl):
        p, fl = sl
        y, aux, load, _ = _attn_body(carry[0], p, fl, cfg, positions, token_ids,
                                     router_override)
        new_load = carry[2] + (load if load is not None else 0)
        return (y, carry[1] + aux, new_load), None

    body = _ckpt(body, cfg)
    load0 = jnp.zeros((cfg.n_experts,), I32) if cfg.n_experts else jnp.zeros((1,), I32)
    (x, aux, load), _ = jax.lax.scan(body, (x, jnp.zeros((), F32), load0),
                                     (stack, flags))
    return x, aux, load


def forward_train(params: dict, cfg: ArchConfig, batch: dict,
                  router_table: dhash.DHashState | None = None):
    """Returns (hidden [B,S,D], aux dict). batch: tokens [B,S] (or embeds),
    positions [B,S] / [3,B,S]."""
    if cfg.frontend == "stub_embed":
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        token_ids = batch.get("tokens", jnp.zeros(x.shape[:2], I32))
    else:
        token_ids = batch["tokens"]
        x = embed(token_ids, params["embed"], scale=cfg.embed_scale)
    x = constrain(x, "dp", None, None)
    positions = batch.get("positions")
    if positions is None:
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=I32), (b, s))

    router_override = None
    if cfg.use_hash_router and router_table is not None:
        router_override = dhash.lookup(router_table, token_ids.reshape(-1))

    aux_total = jnp.zeros((), F32)
    load_total = jnp.zeros((max(cfg.n_experts, 1),), I32)

    kinds = cfg.blocks
    if cfg.shared_attn_every:                      # zamba2: groups + shared attn
        stack = params["mamba_stack"]
        n = sum(k == "mamba2" for k in kinds)
        g = cfg.shared_attn_every
        shared_flags = {"window": jnp.asarray(0, I32),
                        "theta": jnp.asarray(cfg.rope_theta, F32)}

        def mamba_scan(x, sub):
            def body(c, p):
                y, _ = _mamba_body(c, p, cfg)
                return y, None
            body = _ckpt(body, cfg)
            x, _ = jax.lax.scan(body, x, sub)
            return x

        for start in range(0, n, g):
            stop = min(start + g, n)
            sub = jax.tree_util.tree_map(lambda a: a[start:stop], stack)
            x = mamba_scan(x, sub)
            x, aux, _, _ = _attn_body(x, params["shared_attn"], shared_flags,
                                      cfg.scaled(n_experts=0), positions,
                                      token_ids, None)
    elif "mamba2" in kinds:
        def body(c, p):
            y, _ = _mamba_body(c, p, cfg)
            return y, None
        body = _ckpt(body, cfg)
        x, _ = jax.lax.scan(body, x, params["mamba_stack"])
    elif "rwkv6" in kinds:
        def body(c, p):
            y, _ = _rwkv_body(c, p, cfg)
            return y, None
        body = _ckpt(body, cfg)
        x, _ = jax.lax.scan(body, x, params["rwkv_stack"])
    else:
        x, aux_total, load_total = _scan_attn(x, params["attn_stack"], cfg,
                                              positions, token_ids, router_override)

    x = rms_norm(x, params["final_norm"])
    return x, {"moe_aux": aux_total, "expert_load": load_total}


def unembed_matrix(params: dict, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


# ---------------------------------------------------------------------------
# decode (single new token against caches)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    kinds = cfg.blocks
    cache: dict[str, Any] = {"len": jnp.zeros((batch,), I32)}
    n_attn = sum(k in ("attn", "local") for k in kinds)
    d_in = cfg.ssm_expand * cfg.d_model
    nh_m = d_in // cfg.ssm_headdim
    if n_attn:
        shp = (n_attn, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        cache["k"] = jnp.zeros(shp, dtype)
        cache["v"] = jnp.zeros(shp, dtype)
    n_mamba = sum(k == "mamba2" for k in kinds)
    if n_mamba:
        cache["ssm_h"] = jnp.zeros((n_mamba, batch, nh_m, cfg.ssm_state,
                                    cfg.ssm_headdim), F32)
        cache["ssm_conv"] = jnp.zeros((n_mamba, batch, cfg.ssm_conv - 1,
                                       d_in + 2 * cfg.ssm_state), dtype)
    n_rwkv = sum(k == "rwkv6" for k in kinds)
    if n_rwkv:
        nh = cfg.d_model // cfg.rwkv_head_size
        cache["wkv"] = jnp.zeros((n_rwkv, batch, nh, cfg.rwkv_head_size,
                                  cfg.rwkv_head_size), F32)
        cache["tm_prev"] = jnp.zeros((n_rwkv, batch, 1, cfg.d_model), dtype)
        cache["cm_prev"] = jnp.zeros((n_rwkv, batch, 1, cfg.d_model), dtype)
    if cfg.shared_attn_every:
        n_apps = -(-sum(k == "mamba2" for k in kinds) // cfg.shared_attn_every)
        shp = (n_apps, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        cache["k"] = jnp.zeros(shp, dtype)
        cache["v"] = jnp.zeros(shp, dtype)
    return cache


def forward_decode(params: dict, cfg: ArchConfig, tokens1: jax.Array,
                   cache: dict, router_table=None):
    """tokens1: [B,1] (or embeds [B,1,D] for stub frontends).
    Returns (hidden [B,1,D], cache')."""
    if cfg.frontend == "stub_embed" and tokens1.ndim == 3:
        x = tokens1.astype(jnp.dtype(cfg.dtype))
        token_ids = jnp.zeros(x.shape[:2], I32)
    else:
        token_ids = tokens1
        x = embed(tokens1, params["embed"], scale=cfg.embed_scale)
    b = x.shape[0]
    clen = cache["len"]
    positions = clen[:, None]
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(positions, (3, b, 1))

    router_override = None
    if cfg.use_hash_router and router_table is not None:
        router_override = dhash.lookup(router_table, token_ids.reshape(-1))

    kinds = cfg.blocks
    new_cache = dict(cache)

    if cfg.shared_attn_every:
        n = sum(k == "mamba2" for k in kinds)
        g = cfg.shared_attn_every
        shared_flags = {"window": jnp.asarray(0, I32),
                        "theta": jnp.asarray(cfg.rope_theta, F32)}
        hs, convs, ks_, vs_ = cache["ssm_h"], cache["ssm_conv"], cache["k"], cache["v"]
        app = 0
        for start in range(0, n, g):
            stop = min(start + g, n)
            for i in range(start, stop):
                p = jax.tree_util.tree_map(lambda a: a[i], params["mamba_stack"])
                st = {"h": hs[i], "conv": convs[i]}
                x, st = _mamba_body(x, p, cfg, decode_state=st)
                hs = hs.at[i].set(st["h"])
                convs = convs.at[i].set(st["conv"])
            x, _, _, kv = _attn_body(x, params["shared_attn"], shared_flags,
                                     cfg.scaled(n_experts=0), positions, token_ids,
                                     None, decode_cache=(ks_[app], vs_[app]),
                                     cache_len=clen)
            ks_, vs_ = ks_.at[app].set(kv[0]), vs_.at[app].set(kv[1])
            app += 1
        new_cache |= {"ssm_h": hs, "ssm_conv": convs, "k": ks_, "v": vs_}
    elif "mamba2" in kinds:
        def body(c, sl):
            p, h, cv = sl
            y, st = _mamba_body(c, p, cfg, decode_state={"h": h, "conv": cv})
            return y, (st["h"], st["conv"])
        x, (hs, convs) = jax.lax.scan(body, x, (params["mamba_stack"],
                                                cache["ssm_h"], cache["ssm_conv"]))
        new_cache |= {"ssm_h": hs, "ssm_conv": convs}
    elif "rwkv6" in kinds:
        def body(c, sl):
            p, w, tp, cp = sl
            y, st = _rwkv_body(c, p, cfg, decode_state={"wkv": w, "tm_prev": tp,
                                                        "cm_prev": cp})
            return y, (st["wkv"], st["tm_prev"], st["cm_prev"])
        x, (w, tp, cp) = jax.lax.scan(body, x, (params["rwkv_stack"], cache["wkv"],
                                                cache["tm_prev"], cache["cm_prev"]))
        new_cache |= {"wkv": w, "tm_prev": tp, "cm_prev": cp}
    else:
        flags = _attn_flags(cfg)
        if cfg.use_hash_router and cfg.n_experts:
            n = len(flags["window"])
            seeds = jax.random.randint(jax.random.PRNGKey(0), (n, cfg.top_k, 2),
                                       0, 2**31 - 1).astype(jnp.uint32)
            flags = dict(flags, hash_seeds=seeds)

        def body(c, sl):
            p, fl, kc, vc = sl
            y, _, _, kv = _attn_body(c, p, fl, cfg, positions, token_ids,
                                     router_override, decode_cache=(kc, vc),
                                     cache_len=clen)
            return y, (kv[0], kv[1])

        x, (ks_, vs_) = jax.lax.scan(body, x, (params["attn_stack"], flags,
                                               cache["k"], cache["v"]))
        new_cache |= {"k": ks_, "v": vs_}

    new_cache["len"] = clen + 1
    x = rms_norm(x, params["final_norm"])
    return x, new_cache
