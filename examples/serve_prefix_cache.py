"""Serving scenario: batched requests over the DHash-paged KV cache with
prefix-cache admission and a live page-table rehash mid-serving.

    PYTHONPATH=src python examples/serve_prefix_cache.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import dhash
from repro.models import transformer
from repro.serving import prefix_cache
from repro.serving.engine import ServeConfig, ServingEngine


def main():
    cfg = ArchConfig("serve-demo", "dense", n_layers=4, d_model=256,
                     n_heads=8, n_kv_heads=4, d_ff=512, vocab_size=8192,
                     dtype="float32", attn_chunk=64, loss_chunk=64)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(params, cfg, ServeConfig(
        max_seqs=8, page_size=16, n_pages=512, max_blocks=16,
        max_new_tokens=12, rehash_load_factor=0.08))

    rng = np.random.default_rng(0)
    shared_prefix = list(rng.integers(1, 8000, size=24))     # common system prompt
    t0 = time.time()
    for i in range(12):
        eng.submit(shared_prefix + list(rng.integers(1, 8000,
                                                     size=rng.integers(2, 8))))
    steps = eng.run()
    dt = time.time() - t0
    toks = sum(len(v) for v in eng.finished.values())
    print(f"served {len(eng.finished)} requests / {toks} tokens in {dt:.1f}s "
          f"({steps} steps), page-table rehashes: {eng.rehashes}")

    # prefix fingerprints: the shared prompt yields identical block chains
    toks2 = jnp.asarray(np.stack([shared_prefix + [1] * 8,
                                  shared_prefix + [2] * 8]), jnp.int32)
    fps = prefix_cache.prefix_fingerprints(toks2, page_size=16)
    same = int((fps[0] == fps[1]).sum())
    print(f"prefix cache: {same}/{fps.shape[1]} shared-block fingerprints "
          f"match across requests (block-granular reuse)")

    # show the table state
    t = eng.kv.table
    print(f"page-table epoch {int(t.epoch)}, live entries "
          f"{int(jax.device_get(dhash.count_items(t)))}, "
          f"free pages {int(eng.kv.free_top)}/{eng.kv.n_pages}")


if __name__ == "__main__":
    main()
