"""Hash-family property tests: determinism, seed sensitivity, range, and
rough uniformity — the statistical basis for the paper's rebuild defence
(a fresh seed must actually disperse an adversarial key set)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import hashing


@pytest.mark.parametrize("kind", hashing.HASH_KINDS)
def test_deterministic_and_seed_sensitive(kind):
    keys = jnp.arange(1, 4097, dtype=jnp.int32)
    f1, f2 = hashing.fresh(kind, 1), hashing.fresh(kind, 2)
    a = np.asarray(hashing.hash_u32(f1, keys))
    b = np.asarray(hashing.hash_u32(f1, keys))
    c = np.asarray(hashing.hash_u32(f2, keys))
    np.testing.assert_array_equal(a, b)
    assert (a != c).mean() > 0.99, kind


@pytest.mark.parametrize("kind", hashing.HASH_KINDS)
@pytest.mark.parametrize("nbuckets", [64, 100, 1024])
def test_bucket_range_and_uniformity(kind, nbuckets):
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.choice(10_000_000, 1 << 14, replace=False)
                       .astype(np.int32))
    b = np.asarray(hashing.bucket_of(hashing.fresh(kind, 7), keys, nbuckets))
    assert b.min() >= 0 and b.max() < nbuckets
    counts = np.bincount(b, minlength=nbuckets)
    mean = counts.mean()
    # chi-square-ish sanity: no bucket grossly over/under-loaded
    assert counts.max() < 3 * mean, (kind, nbuckets, counts.max(), mean)
    assert (counts > 0).mean() > 0.95


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**20), x=st.integers(-2**31, 2**31 - 1))
def test_rebuild_disperses_collisions(seed, x):
    """Keys colliding under one seed must (w.h.p.) spread under another —
    the paper's whole premise."""
    rng = np.random.default_rng(seed)
    f1 = hashing.fresh("mix32", rng)
    f2 = hashing.fresh("mix32", rng)
    keys = jnp.asarray(
        np.random.default_rng(seed + 1).choice(2**30, 512, replace=False)
        .astype(np.int32))
    b1 = np.asarray(hashing.bucket_of(f1, keys, 64))
    collide = keys[b1 == b1[0]]
    if collide.size < 4:
        return
    b2 = np.asarray(hashing.bucket_of(f2, jnp.asarray(collide), 64))
    assert len(np.unique(b2)) > 1, "new seed failed to disperse"


def test_hash_combine_order_dependent():
    h0 = jnp.full((1,), jnp.uint32(1))
    a = hashing.hash_combine(hashing.hash_combine(h0, jnp.asarray([3])),
                             jnp.asarray([5]))
    b = hashing.hash_combine(hashing.hash_combine(h0, jnp.asarray([5])),
                             jnp.asarray([3]))
    assert int(a[0]) != int(b[0])
