"""Shared benchmark harness.

Uniform drivers over DHASH and the three baseline algorithms so every figure
script measures identical workloads: batched op mixes ("worker threads" of
the paper = SPMD batch width), with a continuous rebuild/resize running —
the paper's §6.2 setup.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as bl
from repro.core import dhash

I32 = jnp.int32
UNIVERSE = 10_000_000          # key range U, paper §6.1


def count_primitives(closed_jaxpr, names):
    """Recursively count jaxpr primitives (incl. cond/scan/while bodies) —
    the interpret-mode proxy for per-batch pass counts (sorts, pallas_calls)."""
    from collections import Counter
    ctr = Counter()

    def rec(jaxpr):
        for eq in jaxpr.eqns:
            ctr[eq.primitive.name] += 1
            for p in eq.params.values():
                if hasattr(p, "jaxpr"):
                    rec(p.jaxpr if hasattr(p.jaxpr, "eqns") else p.jaxpr.jaxpr)
                if isinstance(p, (list, tuple)):
                    for q in p:
                        if hasattr(q, "jaxpr"):
                            rec(q.jaxpr if hasattr(q.jaxpr, "eqns") else q.jaxpr.jaxpr)

    rec(closed_jaxpr.jaxpr)
    return {n: ctr.get(n, 0) for n in names}


def zipf_owners(rng, q: int, n: int, a: float = 1.2) -> np.ndarray:
    """Zipf-skewed owner ids in [0, n): rank r carries mass ~ 1/r^a, with
    the ranks shuffled so the hot owner is not always id 0.  The SHARED
    skew source of the suite — tenant load for the routed-stack bench
    (bench_rebuild.run_routed_stack) and key popularity for the
    oversubscription sweep (bench_oversubscribe) draw from this one
    generator so "under zipf skew" means the same thing everywhere."""
    ranks = np.minimum(rng.zipf(a, size=q) - 1, n - 1).astype(np.int64)
    perm = rng.permutation(n)
    return perm[ranks].astype(np.int32)


def timeit(fn, *args, warmup=3, iters=5):
    """Min-of-N wall clock (default min-of-5): each iteration is timed
    individually (block_until_ready per repeat) and the MINIMUM is
    returned.  The min is the noise-robust estimator the perf gate's
    calibrated band assumes — scheduler contention and GC pauses only ever
    ADD time, so the fastest repeat is the closest observable to the true
    cost, and run-to-run jitter of the committed BENCH_*.json baselines
    shrinks accordingly (ROADMAP perf-gate item)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


class Driver:
    """Uniform step API: one batched (lookup, insert, delete) round + one
    rebuild transition; host-side epoch management."""

    name: str

    def step(self, lk, ik, dk):
        raise NotImplementedError

    def drive_rebuild(self):
        """Advance the continuous rebuild/resize at the host level."""

    def full_rebuild(self) -> float:
        """Time one complete rebuild, returns seconds."""
        raise NotImplementedError


class DHashDriver(Driver):
    def __init__(self, nbuckets, n_items, *, backend="chain", seed=0,
                 max_chain=None, chunk=1024, fused=False):
        self.backend = backend
        self.name = f"DHash-{backend}" + ("-fused" if fused else "")
        alpha = n_items / nbuckets
        mc = max_chain or int(alpha * 2 + 32)
        if backend == "chain":
            self.d = dhash.make("chain", capacity=int(n_items * 1.3),
                                nbuckets=nbuckets, chunk=chunk, seed=seed,
                                max_chain=mc, fused=fused)
        else:
            self.d = dhash.make(backend, capacity=int(n_items * 1.3),
                                chunk=chunk, seed=seed, fused=fused)
        self._seed = seed

        def step_body(d, lk, ik, dk):   # distinct from the `fused` bool arg
            found, _ = dhash.lookup(d, lk)
            d, ok_i = dhash.insert(d, ik, ik)
            d, ok_d = dhash.delete(d, dk)
            d = dhash.rebuild_step(d)
            return d, (found.sum(), ok_i.sum(), ok_d.sum())

        self._step = jax.jit(step_body)
        self._done = jax.jit(dhash.rebuild_done)
        self._chunk = jax.jit(dhash.rebuild_chunk)

    def populate(self, keys):
        ins = jax.jit(dhash.insert)
        for i in range(0, len(keys), 4096):
            self.d, _ = ins(self.d, jnp.asarray(keys[i:i + 4096], I32),
                            jnp.asarray(keys[i:i + 4096], I32))

    def step(self, lk, ik, dk):
        self.d, out = self._step(self.d, lk, ik, dk)
        return out

    def drive_rebuild(self):
        if bool(jax.device_get(self._done(self.d))):
            self.d = dhash.rebuild_finish(self.d)
            self._seed += 1
            self.d = dhash.rebuild_start(self.d, seed=self._seed)
        elif not bool(jax.device_get(self.d.rebuilding)):
            self.d = dhash.rebuild_start(self.d, seed=self._seed)

    def full_rebuild(self) -> float:
        self.d = dhash.rebuild_start(self.d, seed=self._seed + 99)
        t0 = time.perf_counter()
        while not bool(jax.device_get(self._done(self.d))):
            self.d = self._chunk(self.d)
        jax.block_until_ready(jax.tree_util.tree_leaves(self.d.new)[0])
        dt = time.perf_counter() - t0
        self.d = dhash.rebuild_finish(self.d)
        return dt


class XuDriver(Driver):
    name = "HT-Xu"

    def __init__(self, nbuckets, n_items, *, seed=0, max_chain=None, chunk=1024):
        mc = max_chain or int(n_items / nbuckets * 2 + 32)
        self.x = bl.xu_make(nbuckets, int(n_items * 1.3), seed=seed,
                            max_chain=mc, chunk=chunk)
        self._seed = seed

        def fused(x, lk, ik, dk):
            found, _ = bl.xu_lookup(x, lk)
            x, ok_i = bl.xu_insert(x, ik, ik)
            x, ok_d = bl.xu_delete(x, dk)
            x = jax.lax.cond(x.rebuilding, bl.xu_rebuild_chunk, lambda x: x, x)
            return x, (found.sum(), ok_i.sum(), ok_d.sum())

        self._step = jax.jit(fused)
        self._done = jax.jit(bl.xu_rebuild_done)
        self._chunk = jax.jit(bl.xu_rebuild_chunk)

    def populate(self, keys):
        ins = jax.jit(bl.xu_insert)
        for i in range(0, len(keys), 4096):
            self.x, _ = ins(self.x, jnp.asarray(keys[i:i + 4096], I32),
                            jnp.asarray(keys[i:i + 4096], I32))

    def step(self, lk, ik, dk):
        self.x, out = self._step(self.x, lk, ik, dk)
        return out

    def drive_rebuild(self):
        if bool(jax.device_get(bl.xu_rebuild_done(self.x))):
            self.x = bl.xu_rebuild_finish(self.x)
            self._seed += 1
            self.x = bl.xu_rebuild_start(self.x, seed=self._seed)
        elif not bool(jax.device_get(self.x.rebuilding)):
            self.x = bl.xu_rebuild_start(self.x, seed=self._seed)

    def full_rebuild(self) -> float:
        self.x = bl.xu_rebuild_start(self.x, seed=self._seed + 99)
        t0 = time.perf_counter()
        while not bool(jax.device_get(self._done(self.x))):
            self.x = self._chunk(self.x)
        jax.block_until_ready(self.x.t0.akey)
        dt = time.perf_counter() - t0
        self.x = bl.xu_rebuild_finish(self.x)
        return dt


class RHTDriver(Driver):
    name = "HT-RHT"

    def __init__(self, nbuckets, n_items, *, seed=0, max_chain=None, bchunk=256):
        mc = max_chain or int(n_items / nbuckets * 2 + 32)
        self.r = bl.rht_make(nbuckets, int(n_items * 1.3), seed=seed,
                             max_chain=mc, bchunk=bchunk)
        self._seed = seed

        def fused(r, lk, ik, dk):
            found, _ = bl.rht_lookup(r, lk)
            r, ok_i = bl.rht_insert(r, ik, ik)
            r, ok_d = bl.rht_delete(r, dk)
            r = jax.lax.cond(r.rebuilding, bl.rht_rebuild_chunk, lambda r: r, r)
            return r, (found.sum(), ok_i.sum(), ok_d.sum())

        self._step = jax.jit(fused)
        self._done = jax.jit(bl.rht_rebuild_done)
        self._chunk = jax.jit(bl.rht_rebuild_chunk)

    def populate(self, keys):
        ins = jax.jit(bl.rht_insert)
        for i in range(0, len(keys), 4096):
            self.r, _ = ins(self.r, jnp.asarray(keys[i:i + 4096], I32),
                            jnp.asarray(keys[i:i + 4096], I32))

    def step(self, lk, ik, dk):
        self.r, out = self._step(self.r, lk, ik, dk)
        return out

    def drive_rebuild(self):
        if bool(jax.device_get(bl.rht_rebuild_done(self.r))):
            self.r = bl.rht_rebuild_finish(self.r)
            self._seed += 1
            self.r = bl.rht_rebuild_start(self.r, seed=self._seed)
        elif not bool(jax.device_get(self.r.rebuilding)):
            self.r = bl.rht_rebuild_start(self.r, seed=self._seed)

    def full_rebuild(self) -> float:
        self.r = bl.rht_rebuild_start(self.r, seed=self._seed + 99)
        t0 = time.perf_counter()
        n = 0
        while not bool(jax.device_get(self._done(self.r))) and n < 100_000:
            self.r = self._chunk(self.r)
            n += 1
        jax.block_until_ready(self.r.old.akey)
        dt = time.perf_counter() - t0
        self.r = bl.rht_rebuild_finish(self.r)
        return dt


class SplitDriver(Driver):
    name = "HT-Split"

    def __init__(self, nbuckets, n_items, *, seed=0, max_chain=None, **_):
        mc = max_chain or int(n_items / nbuckets * 2 + 32)
        self.s = bl.split_make(max(nbuckets * 4, 64), int(n_items * 1.3),
                               init_buckets=nbuckets, seed=seed, max_chain=mc)
        self._grow = True

        def fused(s, lk, ik, dk):
            found, _ = bl.split_lookup(s, lk)
            s, ok_i = bl.split_insert(s, ik, ik)
            s, ok_d = bl.split_delete(s, dk)
            return s, (found.sum(), ok_i.sum(), ok_d.sum())

        self._step = jax.jit(fused)
        self._resize = jax.jit(bl.split_resize, static_argnums=1)

    def populate(self, keys):
        ins = jax.jit(bl.split_insert)
        for i in range(0, len(keys), 4096):
            self.s, _ = ins(self.s, jnp.asarray(keys[i:i + 4096], I32),
                            jnp.asarray(keys[i:i + 4096], I32))

    def step(self, lk, ik, dk):
        self.s, out = self._step(self.s, lk, ik, dk)
        return out

    def drive_rebuild(self):
        # continuous resize: grow to the alternative size and back (§6.2)
        self.s = self._resize(self.s, self._grow)
        self._grow = not self._grow

    def full_rebuild(self) -> float:
        t0 = time.perf_counter()
        self.s = self._resize(self.s, self._grow)
        jax.block_until_ready(self.s.t.akey)
        self._grow = not self._grow
        return time.perf_counter() - t0


ALGOS = {"DHash": DHashDriver, "HT-Xu": XuDriver, "HT-RHT": RHTDriver,
         "HT-Split": SplitDriver}


@dataclass
class Workload:
    q: int                 # batch width ("worker threads")
    mix: tuple[int, int, int]   # percent lookup/insert/delete
    skew: float = 0.0      # > 0: zipf exponent for key POPULARITY — lookups
                           # and deletes concentrate on hot keys via
                           # ``zipf_owners`` (the suite's shared skew source)

    def batches(self, rng, present: np.ndarray):
        nl = self.q * self.mix[0] // 100
        ni = self.q * self.mix[1] // 100
        nd = self.q * self.mix[2] // 100
        if self.skew > 0:
            lk = present[zipf_owners(rng, max(nl, 1), len(present), self.skew)]
            dk = present[zipf_owners(rng, max(nd, 1), len(present), self.skew)]
        else:
            lk = rng.choice(present, max(nl, 1))
            dk = rng.choice(present, max(nd, 1))
        ik = rng.integers(1, UNIVERSE, max(ni, 1)).astype(np.int32)
        return (jnp.asarray(lk, I32), jnp.asarray(ik, I32), jnp.asarray(dk, I32))


def run_throughput(driver: Driver, wl: Workload, present: np.ndarray,
                   *, steps=8, warmup=3, rng=None, continuous_rebuild=True):
    """ops/sec over `steps` measured steps."""
    rng = rng or np.random.default_rng(0)
    batches = [wl.batches(rng, present) for _ in range(steps + warmup)]
    if continuous_rebuild:
        driver.drive_rebuild()
    for b in batches[:warmup]:
        out = driver.step(*b)
        if continuous_rebuild:
            driver.drive_rebuild()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for b in batches[warmup:]:
        out = driver.step(*b)
        if continuous_rebuild:
            driver.drive_rebuild()
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    total_ops = sum(sum(x.size for x in b) for b in batches[warmup:])
    return total_ops / dt
