"""zamba2-1.2b [hybrid]: Mamba2 backbone + weight-shared attention block
every 6 layers [arXiv:2411.15242; hf]. long_500k RUNS (SSM O(1) state)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    block_pattern=("mamba2",), shared_attn_every=6,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_conv=4,
)

def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                         head_dim=16, d_ff=128, vocab_size=512,
                         shared_attn_every=2, ssm_state=16, ssm_headdim=16,
                         dtype="float32", attn_chunk=32, loss_chunk=32)
