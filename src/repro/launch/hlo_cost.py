"""Trip-count-aware HLO cost walker.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scan-over-layers model under-reports FLOPs/bytes/collectives by ~n_layers.
This walker parses the optimized HLO text, recovers each while loop's trip
count from its condition computation, and accumulates

  * dot/convolution FLOPs            (the compute roofline term)
  * operand+result bytes of HBM-crossing instructions (memory term;
    fusion-internal instructions excluded — only fusion boundaries move HBM)
  * collective result bytes by kind  (collective term)

through the call graph (entry -> fusions/calls/whiles x trips).

This is text parsing of a well-defined IR, validated against closed-form
6ND accounting in tests/test_roofline.py (agreement within tens of %).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# ops that do not move HBM data themselves
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "domain",
    "opt-barrier", "copy-start", "copy-done", "iota",
}

# ops that genuinely materialize an HBM buffer on TPU.  The CPU backend
# leaves long elementwise chains unfused at top level; on TPU those fuse into
# the neighbouring matmul/fusion, so counting every top-level elementwise op
# would overstate the memory term ~5-10x.  We count one write+read (2x result
# bytes) per materializing op and treat elementwise/broadcast/convert/select
# as fused epilogues.
_MEM_OPS = {
    "dot", "convolution", "fusion", "copy", "transpose", "gather", "scatter",
    "dynamic-slice", "reduce", "reduce-window", "sort", "select-and-scatter",
    "concatenate", "pad", "custom-call", "rng", "cholesky", "triangular-solve",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "exp",  # exp kept: softmax materialization point
}

_SHAPE_TOK = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOK.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> list[int]:
    m = _SHAPE_TOK.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=lambda: {k: 0.0 for k in _COLL_OPS})
    coll_counts: dict = field(default_factory=lambda: {k: 0 for k in _COLL_OPS})
    # (opcode, shape, jax op_name) -> bytes, for perf-loop attribution
    contrib: dict = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in _COLL_OPS:
            self.coll[k] += other.coll[k] * mult
            self.coll_counts[k] += other.coll_counts[k] * mult
        for k, v in other.contrib.items():
            self.contrib[k] = self.contrib.get(k, 0.0) + v * mult

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())

    def top_bytes(self, n: int = 12) -> list:
        rows = sorted(self.contrib.items(), key=lambda kv: -kv[1])[:n]
        return [{"bytes": v, "op": k[0], "shape": k[1], "src": k[2]}
                for k, v in rows]


@dataclass
class Instr:
    name: str
    opcode: str
    result_shape: str
    operand_shapes: str
    raw: str
    called: list[str]


_CALLS_RE = re.compile(r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)"
                       r"\s*%?([\w.\-]+(?:\s*,\s*%?[\w.\-]+)*)")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")


def _balanced(text: str, start: int) -> int:
    """Index just past the paren group opening at text[start] == '('."""
    depth = 0
    for i in range(start, len(text)):
        if text[i] == "(":
            depth += 1
        elif text[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(text)


def _parse_instr(line: str) -> Instr | None:
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():].lstrip()
    # result shape: balanced-paren tuple or single token
    if rest.startswith("("):
        end = _balanced(rest, 0)
        shape = rest[:end]
        rest = rest[end:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape = rest[:sp]
        rest = rest[sp + 1:].lstrip()
    # opcode directly precedes its operand list
    mo = re.match(r"([\w\-]+)\(", rest)
    if not mo:
        return None
    opcode = mo.group(1)
    oend = _balanced(rest, mo.end() - 1)
    operand_str = rest[mo.end() - 1: oend]
    called = [c.strip().lstrip("%")
              for mc in _CALLS_RE.finditer(rest)
              for c in mc.group(1).split(",")]
    return Instr(name, opcode, shape, operand_str, rest, called)


def parse_hlo(text: str) -> dict[str, list[Instr]]:
    """computation name -> instruction list."""
    comps: dict[str, list[Instr]] = {}
    cur: list[Instr] | None = None
    for line in text.splitlines():
        ls = line.strip()
        if cur is None or (ls.endswith("{") and "=" not in ls.split("->")[0]):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", ls)
            if m and ls.endswith("{"):
                comps[m.group(1)] = cur = []
                continue
        if ls.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        ins = _parse_instr(line)
        if ins is not None:
            cur.append(ins)
    return comps


_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")


def _operand_shapes(ins: Instr, shapes: dict[str, str]) -> list[str]:
    """Operand shapes, inline if printed, else resolved from definitions."""
    inline = _SHAPE_TOK.findall(ins.operand_shapes)
    if inline:
        return [f"{dt}[{dims}]" for dt, dims in inline]
    return [shapes.get(n, "") for n in
            _OPERAND_NAME_RE.findall(ins.operand_shapes)]


def _dot_flops(ins: Instr, shapes: dict[str, str]) -> float:
    """2 x prod(result dims) x contraction size."""
    res = _shape_dims(ins.result_shape)
    ops = _operand_shapes(ins, shapes)
    if not ops or not ops[0]:
        return 0.0
    lhs_dims = _shape_dims(ops[0])
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.raw)
    contract = 1
    if mc and mc.group(1):
        for d in mc.group(1).split(","):
            if int(d) < len(lhs_dims):
                contract *= lhs_dims[int(d)]
    n = 1
    for d in res:
        n *= d
    return 2.0 * n * contract


# matmuls that XLA lowered to library calls instead of a `dot` op: oneDNN /
# Eigen on CPU (the legacy non-thunk runtime does this for every big GEMM),
# cuBLAS on GPU.  Substring match against custom_call_target.
_MATMUL_CC = ("__onednn$matmul", "EigenMatMul", "__cublas$gemm",
              "cublas$lt$matmul")
_CC_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')


def _matmul_cc_flops(ins: Instr, shapes: dict[str, str]) -> float:
    """2 x prod(result dims) x contraction size for a GEMM custom-call.

    The call carries no contracting-dims attribute, so recover k from the
    operand: lhs holds batch x m x k elements and the result batch x m x n,
    hence k = numel(lhs) / prod(result dims without the last).  This is
    invariant to transpose flags (numel is) and to batching (lhs and result
    share the leading dims).  Result may be a (buffer, scratch) tuple —
    _shape_dims reads the first shape token, which is the real output.
    """
    res = _shape_dims(ins.result_shape)
    ops = _operand_shapes(ins, shapes)
    if len(res) < 2 or len(ops) < 2 or not ops[0]:
        return 0.0
    lhs_n = 1
    for d in _shape_dims(ops[0]):
        lhs_n *= d
    rows = 1
    for d in res[:-1]:
        rows *= d
    n = 1
    for d in res:
        n *= d
    return 2.0 * n * max(lhs_n // max(rows, 1), 1)


def _conv_flops(ins: Instr, shapes: dict[str, str]) -> float:
    res = _shape_dims(ins.result_shape)
    ops = _operand_shapes(ins, shapes)
    if len(ops) < 2 or not ops[1]:
        return 0.0
    rhs = _shape_dims(ops[1])
    n = 1
    for d in res:
        n *= d
    k = 1
    for d in rhs:
        k *= d
    out_feat = res[-1] if res else 1
    return 2.0 * n * (k / max(out_feat, 1))


def _dus_update_bytes(fusion: Instr, comps: dict) -> float | None:
    """If the fusion's computation is dominated by a dynamic-update-slice of
    (essentially) the whole result buffer, return the update-slice bytes;
    else None.  Matches XLA's in-place DUS fusion semantics on TPU."""
    fres = _shape_bytes(fusion.result_shape)
    if not fres:
        return None
    for cname in fusion.called:
        body = comps.get(cname, [])
        local = {i.name: i.result_shape for i in body}
        for ins in body:
            if ins.opcode != "dynamic-update-slice":
                continue
            if _shape_bytes(ins.result_shape) < 0.9 * fres:
                continue
            names = _OPERAND_NAME_RE.findall(ins.operand_shapes)
            if len(names) > 1 and names[1] in local:
                return float(_shape_bytes(local[names[1]]))
            return 0.0  # update shape unknown: in-place, negligible vs buffer
    return None


def _trip_count(cond: list[Instr]) -> int:
    """Largest s32 constant in the loop condition (induction bound)."""
    best = 1
    for ins in cond:
        if ins.opcode == "constant" and ins.result_shape.startswith("s32"):
            m = re.search(r"constant\((-?\d+)\)", ins.raw)
            if m:
                best = max(best, int(m.group(1)))
    return best


def analyze(text: str, entry: str | None = None) -> Cost:
    comps = parse_hlo(text)
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        entry = m.group(1) if m else next(iter(comps))
    # name -> result shape, for resolving unprinted operand shapes.
    # instruction names are unique per computation; keep per-comp maps with a
    # global fallback (cross-computation references are parameters anyway).
    shapes_by_comp: dict[str, dict[str, str]] = {
        cname: {i.name: i.result_shape for i in instrs}
        for cname, instrs in comps.items()}
    global_shapes: dict[str, str] = {}
    for m_ in shapes_by_comp.values():
        global_shapes.update(m_)
    memo: dict[tuple[str, bool], Cost] = {}

    def walk(name: str, fused: bool) -> Cost:
        key = (name, fused)
        if key in memo:
            return memo[key]
        memo[key] = Cost()          # break cycles defensively
        total = Cost()
        local = shapes_by_comp.get(name, {})
        shapes = {**global_shapes, **local}
        for ins in comps.get(name, []):
            # flops count everywhere (fused or not)
            if ins.opcode == "dot":
                total.flops += _dot_flops(ins, shapes)
            elif ins.opcode == "convolution":
                total.flops += _conv_flops(ins, shapes)
            elif ins.opcode == "custom-call":
                mt = _CC_TARGET_RE.search(ins.raw)
                if mt and any(s in mt.group(1) for s in _MATMUL_CC):
                    total.flops += _matmul_cc_flops(ins, shapes)
            for op in _COLL_OPS:
                if ins.opcode in (op, op + "-start"):
                    total.coll[op] += _shape_bytes(ins.result_shape)
                    total.coll_counts[op] += 1
            # bytes: only at non-fused level, for data-moving ops.
            # Model: every materialized buffer is written once and read once
            # (2x result bytes). dynamic-update-slice is in-place: only the
            # update slice moves. while/call results alias their carries.
            # Per-trip slice reads of loop-invariant stacks are counted as
            # slices (x trips == one full pass over the stack), not as the
            # whole stack per trip.
            if not fused:
                nb = 0
                if ins.opcode == "dynamic-update-slice":
                    ops_ = _operand_shapes(ins, shapes)
                    nb = 2 * _shape_bytes(ops_[1] if len(ops_) > 1 else "")
                elif ins.opcode == "fusion":
                    # DUS-rooted fusions (scan-stash writes, possibly wrapped
                    # in converts) update in place on TPU: count the update
                    # slice, not the whole accumulator buffer.
                    upd = _dus_update_bytes(ins, comps)
                    nb = 2 * upd if upd is not None \
                        else 2 * _shape_bytes(ins.result_shape)
                elif ins.opcode in _MEM_OPS:
                    nb = 2 * _shape_bytes(ins.result_shape)
                if nb:
                    total.bytes += nb
                    mm = re.search(r'op_name="([^"]*)"', ins.raw)
                    src = (mm.group(1) if mm else "")[-120:]
                    key = (ins.opcode, ins.result_shape.split("{")[0], src)
                    total.contrib[key] = total.contrib.get(key, 0.0) + nb
            # recurse
            if ins.opcode == "while":
                body = ins.called[0] if ins.called else None
                trips = 1
                if len(ins.called) >= 2:
                    mb = re.search(r"body=%?([\w.\-]+)", ins.raw)
                    mc = re.search(r"condition=%?([\w.\-]+)", ins.raw)
                    if mb and mc:
                        body = mb.group(1)
                        trips = _trip_count(comps.get(mc.group(1), []))
                if body:
                    total.add(walk(body, fused), trips)
            elif ins.opcode == "fusion":
                for c in ins.called:
                    total.add(walk(c, True))
            elif ins.opcode in ("call", "conditional", "async-start"):
                for c in ins.called:
                    # conditional: assume each branch executes once (upper
                    # bound mildly pessimistic; cond branches here are tiny)
                    total.add(walk(c, fused))
            # reduce/scatter/sort to_apply bodies are per-element scalar ops:
            # negligible flops, no HBM — skip.
        memo[key] = total
        return total

    return walk(entry, False)
