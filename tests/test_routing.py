"""Router unit tests: the two-pass counting-sort layout behind every
routed op.

The contract under test:

* ``_route`` produces the SAME owner-grouped [S, cap] layout as a stable
  reference (keys placed in batch order within their owner), with EXACT
  per-owner overflow counts — never a silent drop;
* route → unroute is the identity on kept keys, and dropped keys come back
  as an unmistakable fill (0/False for ints/bools, NaN for floats);
* the router lowers with ZERO ``sort`` primitives, so a routed fused
  ``stack_lookup`` keeps the single-op kernel budget:
  ONE sort + ONE pallas_call total (the fused kernel's own bucket sort is
  the only sort in the op).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend, dhash
from repro.core import distributed as dd

FUSED_BACKENDS = [b for b in backend.names() if backend.get(b).fused]


def _count_primitives(closed_jaxpr, names):
    from collections import Counter
    ctr = Counter()

    def rec(jaxpr):
        for eq in jaxpr.eqns:
            ctr[eq.primitive.name] += 1
            for p in eq.params.values():
                if hasattr(p, "jaxpr"):
                    rec(p.jaxpr if hasattr(p.jaxpr, "eqns") else p.jaxpr.jaxpr)

    rec(closed_jaxpr.jaxpr)
    return {n: ctr.get(n, 0) for n in names}


def _ref_route(keys, owner, nshards, cap):
    """Stable counting-sort reference in plain NumPy."""
    keys, owner = np.asarray(keys), np.asarray(owner)
    send = np.zeros((nshards, cap), keys.dtype)
    smask = np.zeros((nshards, cap), bool)
    kept = np.zeros(keys.shape[0], bool)
    fill = np.zeros(nshards, np.int64)
    for i in range(keys.shape[0]):
        o = int(owner[i])
        r = fill[o]
        fill[o] += 1
        if r < cap:
            send[o, r] = keys[i]
            smask[o, r] = True
            kept[i] = True
    return send, smask, kept, np.maximum(fill - cap, 0)


def test_route_cap_math():
    # cap = ceil(c*Q/S)
    assert dd.route_cap(2.0, 64, 8) == 16
    assert dd.route_cap(1.0, 64, 8) == 8
    assert dd.route_cap(1.0, 65, 8) == 9          # ceil, not floor
    assert dd.route_cap(2.0, 128, 64) == 4
    # <= 0 means the overflow-proof full width
    assert dd.route_cap(0.0, 64, 8) == 64
    assert dd.route_cap(-1.0, 64, 8) == 64
    # clamped to [1, Q]
    assert dd.route_cap(0.01, 4, 64) == 1
    assert dd.route_cap(100.0, 8, 2) == 8


def test_route_cap_exact_ceil_boundaries():
    """ceil(c·Q/S) is computed on the full product: the old
    ``int(c*q)`` idiom truncated the float product BEFORE the
    ceil-division, understating the cap whenever it carried a fraction."""
    # 1.1*9 = 9.9 -> ceil 10, then clamped to Q=9 (cap never exceeds Q)
    assert dd.route_cap(1.1, 9, 1) == 9
    # 1.25*10/4 = 3.125 -> 4 (old: int(12.5)=12 -> ceil(12/4)=3)
    assert dd.route_cap(1.25, 10, 4) == 4
    # 1.5*3/2 = 2.25 -> 3 (old: int(4.5)=4 -> ceil(4/2)=2)
    assert dd.route_cap(1.5, 3, 2) == 3
    # exact products are untouched by the fix
    assert dd.route_cap(2.0, 1024, 8) == 256
    assert dd.route_cap(2.0, 1024, 64) == 32
    assert dd.route_cap(2.0, 48, 6) == 16
    assert dd.route_cap(2.0, 16, 8) == 4


def test_route_spill_cap_math():
    # default (None): the overflow-proof bound Q - cap (total spill over
    # any batch is <= Q - cap, see the docstring's k-owner argument)
    assert dd.route_spill_cap(64, 16) == 48
    assert dd.route_spill_cap(64, 64) == 0        # cap >= Q: nothing spills
    assert dd.route_spill_cap(64, 100) == 0
    # slack budget: ceil(slack*Q), clamped to the overflow-proof bound
    assert dd.route_spill_cap(64, 16, 0.25) == 16
    assert dd.route_spill_cap(64, 16, 1.0) == 48  # >= 1: overflow-proof
    assert dd.route_spill_cap(64, 16, 5.0) == 48
    assert dd.route_spill_cap(64, 16, 0.001) == 1  # ceil, never 0 rounding
    assert dd.route_spill_cap(64, 16, 0.0) == 0   # <= 0 disables the slab
    assert dd.route_spill_cap(64, 16, -1.0) == 0
    assert dd.route_spill_cap(1024, 640, 0.375) == 384


@pytest.mark.parametrize("skew", ["uniform", "zipfish", "one_owner"])
def test_route_matches_stable_reference(skew):
    rng = np.random.default_rng(11)
    q, s = 96, 8
    keys = jnp.asarray(rng.choice(10_000, q, replace=False).astype(np.int32))
    if skew == "uniform":
        owner = rng.integers(0, s, q)
    elif skew == "zipfish":
        owner = np.minimum(rng.zipf(1.5, q) - 1, s - 1)
    else:
        owner = np.full(q, 3)
    owner = jnp.asarray(owner.astype(np.int32))
    for cap in (q, dd.route_cap(2.0, q, s), 3):
        rt = dd._route(keys, owner, s, cap)
        send, smask, kept, over = _ref_route(keys, owner, s, cap)
        np.testing.assert_array_equal(np.asarray(rt.send), send)
        np.testing.assert_array_equal(np.asarray(rt.smask), smask)
        np.testing.assert_array_equal(np.asarray(rt.kept), kept)
        np.testing.assert_array_equal(np.asarray(rt.overflow), over)
        # overflow is EXACT: hist - cap, never saturated or approximated
        hist = np.bincount(np.asarray(owner), minlength=s)
        np.testing.assert_array_equal(np.asarray(rt.overflow),
                                      np.maximum(hist - cap, 0))


def test_route_unroute_roundtrip_including_drops():
    rng = np.random.default_rng(5)
    q, s = 64, 4
    cap = dd.route_cap(1.0, q, s)                 # tight: guarantees drops
    keys = jnp.asarray(rng.choice(10_000, q, replace=False).astype(np.int32))
    owner = jnp.asarray((np.asarray(keys) * 7 % s).astype(np.int32))
    rt = dd._route(keys, owner, s, cap)
    assert int(rt.overflow.sum()) > 0, "cap must actually drop keys here"
    # a shard-side response derived from the routed keys round-trips to
    # batch order exactly on kept keys; dropped keys take the fill
    resp = rt.send * 3
    back = dd._unroute(resp, rt, fill=-1)
    expect = np.where(np.asarray(rt.kept), np.asarray(keys) * 3, -1)
    np.testing.assert_array_equal(np.asarray(back), expect)
    # payload scatter uses the same coordinates as the key scatter
    pay = dd._route_payload(keys * 3, rt)
    np.testing.assert_array_equal(np.asarray(pay), np.asarray(rt.send) * 3)
    # full-width route keeps EVERY key: round-trip is the identity
    full = dd._route(keys, owner, s)
    assert bool(np.asarray(full.kept).all())
    assert int(full.overflow.sum()) == 0
    np.testing.assert_array_equal(
        np.asarray(dd._unroute(full.send, full)), np.asarray(keys))


def test_unroute_float_fill_is_nan_safe():
    keys = jnp.arange(1, 9, dtype=jnp.int32)
    owner = jnp.asarray([0, 0, 0, 0, 1, 1, 1, 1], jnp.int32)
    rt = dd._route(keys, owner, 2, cap=2)         # half of each owner spills
    resp = rt.send.astype(jnp.float32) * 0.5
    back = dd._unroute(resp, rt)                  # default fill
    back = np.asarray(back)
    kept = np.asarray(rt.kept)
    # dropped float payloads are NaN — NEVER a fake 0.0
    assert np.isnan(back[~kept]).all()
    np.testing.assert_allclose(back[kept], np.arange(1, 9)[kept] * 0.5)
    # integer/bool responses default to 0/False instead
    backi = np.asarray(dd._unroute(rt.send, rt))
    assert (backi[~kept] == 0).all()


def test_router_lowers_with_zero_sorts():
    """The tentpole claim at the router level: the counting-sort layout
    contains NO ``sort`` primitive (pass 1 is a one-hot histogram +
    cumsum, pass 2 a 2-D scatter)."""
    keys = jnp.arange(128, dtype=jnp.int32)
    owner = keys % 8

    def route(k, o):
        rt = dd._route(k, o, 8, cap=32)
        return rt.send, rt.smask, rt.overflow

    counts = _count_primitives(jax.make_jaxpr(route)(keys, owner),
                               ("sort", "pallas_call"))
    assert counts == {"sort": 0, "pallas_call": 0}, counts


@pytest.mark.parametrize("name", FUSED_BACKENDS)
def test_routed_fused_stack_lookup_budget(name):
    """The acceptance budget: route (capped) + fused stack lookup lowers to
    exactly 1 sort + 1 pallas_call TOTAL — the fused kernel's own bucket
    sort is the only sort; the router adds none."""
    be = backend.get(name)
    s, q = 4, 64
    st = dhash.make_stack(s, name, 256, chunk=64, seed=0, fused=True)
    keys = jnp.arange(1, q + 1, dtype=jnp.int32)
    owner = keys % s
    cap = dd.route_cap(2.0, q, s)

    def routed_fast(st, k, o):
        rt = dd._route(k, o, s, cap)
        f, v = jax.vmap(lambda d, kk: be.lookup_fused(d.old, kk))(st, rt.send)
        return dd._unroute(f & rt.smask, rt, fill=False)

    counts = _count_primitives(jax.make_jaxpr(routed_fast)(st, keys, owner),
                               ("sort", "pallas_call"))
    assert counts == {"sort": 1, "pallas_call": 1}, (name, counts)

    def routed_ordered(st, k, o):
        rt = dd._route(k, o, s, cap)
        f, v = jax.vmap(lambda d, kk: be.ordered_lookup_fused(
            d.old, d.new, d.hazard_key, d.hazard_val, d.hazard_live, kk,
            nres_cap=d.nres_cap))(st, rt.send)
        return dd._unroute(f & rt.smask, rt, fill=False)

    counts = _count_primitives(jax.make_jaxpr(routed_ordered)(st, keys, owner),
                               ("sort", "pallas_call"))
    assert counts == {"sort": 1, "pallas_call": 1}, (name, counts)


def test_capped_stack_lookup_exact_on_kept_keys():
    """End-to-end at the stack level (no mesh): capped routed lookups agree
    key-for-key with per-table lookups; spilled keys come back not-found
    (and are exactly the ones ``overflow`` counts)."""
    rng = np.random.default_rng(9)
    s, q = 4, 64
    st = dhash.make_stack(s, "linear", 256, chunk=64, seed=1)
    keys = jnp.asarray(rng.choice(100_000, q, replace=False).astype(np.int32))
    owner = jnp.asarray(rng.integers(0, s, q).astype(np.int32))
    # populate via a FULL-width route (no drops), then read back capped
    full = dd._route(keys, owner, s)
    st, ok = dhash.stack_insert(st, full.send, full.send * 5, full.smask)
    assert bool(np.asarray(dd._unroute(ok, full, fill=False)).all())
    rt = dd._route(keys, owner, s, dd.route_cap(1.0, q, s))
    f, v = dhash.stack_lookup(st, rt.send, rt.smask)
    found = np.asarray(dd._unroute(f, rt, fill=False).astype(bool))
    vals = np.asarray(dd._unroute(v, rt, fill=0))
    kept = np.asarray(rt.kept)
    np.testing.assert_array_equal(found, kept)    # kept ⇒ hit, spilled ⇒ miss
    np.testing.assert_array_equal(vals[kept], np.asarray(keys)[kept] * 5)
    assert int(rt.overflow.sum()) == int((~kept).sum())


# -- two-level spill slab ----------------------------------------------------


def _ref_slab_route(keys, owner, nshards, cap, spill_cap):
    """Stable two-level reference in plain NumPy: primary columns by
    owner rank, slab columns shared across owners by global spill rank
    (batch order), exact per-owner drop counts past the slab."""
    keys, owner = np.asarray(keys), np.asarray(owner)
    send = np.zeros((nshards, cap + spill_cap), keys.dtype)
    smask = np.zeros((nshards, cap + spill_cap), bool)
    served = np.zeros(keys.shape[0], bool)
    slab_owner = np.full(spill_cap, -1, np.int64)
    fill = np.zeros(nshards, np.int64)
    dropped = np.zeros(nshards, np.int64)
    nspill = 0
    for i in range(keys.shape[0]):
        o = int(owner[i])
        r = fill[o]
        fill[o] += 1
        if r < cap:
            send[o, r] = keys[i]
            smask[o, r] = True
            served[i] = True
        else:
            j = nspill
            nspill += 1
            if j < spill_cap:
                send[o, cap + j] = keys[i]
                smask[o, cap + j] = True
                slab_owner[j] = o
                served[i] = True
            else:
                dropped[o] += 1
    return send, smask, served, slab_owner, dropped


def _owner_batch(skew, rng, q, s):
    if skew == "uniform":
        owner = rng.integers(0, s, q)
    elif skew == "zipfish":
        owner = np.minimum(rng.zipf(1.5, q) - 1, s - 1)
    elif skew == "one_owner":
        owner = np.full(q, s - 1)
    else:                                          # all_spill: cap=1 regime
        owner = np.repeat(np.arange(s), q // s)
    return jnp.asarray(owner.astype(np.int32))


@pytest.mark.parametrize("skew", ["uniform", "zipfish", "one_owner",
                                  "all_spill"])
def test_slab_route_matches_reference(skew):
    rng = np.random.default_rng(17)
    q, s = 96, 8
    keys = jnp.asarray(rng.choice(10_000, q, replace=False).astype(np.int32))
    owner = _owner_batch(skew, rng, q, s)
    cap = 1 if skew == "all_spill" else dd.route_cap(1.0, q, s)
    for spill_cap in (dd.route_spill_cap(q, cap),          # overflow-proof
                      dd.route_spill_cap(q, cap, 0.1),     # compact: drops
                      0):                                   # slab disabled
        rt = dd._route(keys, owner, s, cap, spill_cap)
        send, smask, served, slab_owner, dropped = _ref_slab_route(
            np.asarray(keys), np.asarray(owner), s, cap, spill_cap)
        assert rt.send.shape == (s, cap + spill_cap)
        np.testing.assert_array_equal(np.asarray(rt.send), send)
        np.testing.assert_array_equal(np.asarray(rt.smask), smask)
        np.testing.assert_array_equal(np.asarray(rt.served), served)
        np.testing.assert_array_equal(np.asarray(rt.slab_owner), slab_owner)
        np.testing.assert_array_equal(np.asarray(rt.dropped), dropped)
        # exact accounting closes: every key is served, spilled-but-slabbed,
        # or dropped — and overflow still counts ALL spill (slab + dropped)
        hist = np.bincount(np.asarray(owner), minlength=s)
        np.testing.assert_array_equal(np.asarray(rt.overflow),
                                      np.maximum(hist - cap, 0))
        assert int(rt.served.sum()) + int(rt.dropped.sum()) == q
        assert int(rt.dropped.sum()) == max(
            int(rt.overflow.sum()) - spill_cap, 0)
    # the overflow-proof slab NEVER drops, under any skew
    rt = dd._route(keys, owner, s, cap, dd.route_spill_cap(q, cap))
    assert bool(np.asarray(rt.served).all())
    assert int(rt.dropped.sum()) == 0


@pytest.mark.parametrize("name", FUSED_BACKENDS)
@pytest.mark.parametrize("skew", ["uniform", "zipfish", "one_owner",
                                  "all_spill"])
def test_slab_route_bit_identical_to_full_width(name, skew):
    """The acceptance differential: with the overflow-proof slab, a capped
    route serves EVERY key — lookups and inserts through the slab layout
    return bit-identical results to full-width routing, on every fused
    backend, under every skew."""
    rng = np.random.default_rng(23)
    q, s = 96, 8
    st0 = dhash.make_stack(s, name, 512, chunk=64, seed=3, fused=True)
    keys = jnp.asarray(rng.choice(100_000, q, replace=False).astype(np.int32))
    owner = _owner_batch(skew, rng, q, s)
    cap = 1 if skew == "all_spill" else dd.route_cap(1.0, q, s)
    spill_cap = dd.route_spill_cap(q, cap)
    ones = jnp.ones(q, bool)

    # insert differential: slab-routed insert vs full-width insert
    full = dd._route(keys, owner, s)
    rt = dd._route(keys, owner, s, cap, spill_cap)
    assert int(rt.dropped.sum()) == 0
    st_f, ok_f = dhash.stack_insert(st0, full.send, full.send * 5, full.smask)
    st_r, ok_r = dhash.stack_insert(st0, rt.send, rt.send * 5,
                                    dd._route_payload(ones, rt) & rt.smask)
    np.testing.assert_array_equal(
        np.asarray(dd._unroute(ok_r, rt, fill=False)),
        np.asarray(dd._unroute(ok_f, full, fill=False)))

    # lookup differential on BOTH resulting tables
    for st in (st_f, st_r):
        f_f, v_f = dhash.stack_lookup(st, full.send, full.smask)
        f_r, v_r = dhash.stack_lookup(st, rt.send, rt.smask)
        np.testing.assert_array_equal(
            np.asarray(dd._unroute(f_r, rt, fill=False)),
            np.asarray(dd._unroute(f_f, full, fill=False)))
        np.testing.assert_array_equal(
            np.asarray(dd._unroute(v_r, rt, fill=0)),
            np.asarray(dd._unroute(v_f, full, fill=0)))
        found = np.asarray(dd._unroute(f_r, rt, fill=False).astype(bool))
        assert found.all(), (name, skew)          # every key served and hit


def test_slab_compact_drop_accounting_end_to_end():
    """A compact slab that runs out: dropped keys come back not-found with
    the unmistakable fill, and ``dropped`` counts them exactly per owner."""
    rng = np.random.default_rng(29)
    q, s = 64, 4
    st = dhash.make_stack(s, "linear", 256, chunk=64, seed=5)
    keys = jnp.asarray(rng.choice(100_000, q, replace=False).astype(np.int32))
    owner = jnp.zeros(q, jnp.int32)               # 100% skew
    full = dd._route(keys, owner, s)
    st, _ = dhash.stack_insert(st, full.send, full.send * 7, full.smask)
    cap = dd.route_cap(1.0, q, s)                 # 16: 48 keys spill
    spill_cap = dd.route_spill_cap(q, cap, 0.25)  # 16: 32 keys dropped
    rt = dd._route(keys, owner, s, cap, spill_cap)
    assert int(rt.dropped.sum()) == 32 and int(rt.dropped[0]) == 32
    f, v = dhash.stack_lookup(st, rt.send, rt.smask)
    found = np.asarray(dd._unroute(f, rt, fill=False).astype(bool))
    served = np.asarray(rt.served)
    assert served.sum() == q - 32
    np.testing.assert_array_equal(found, served)  # served ⇒ hit, dropped ⇒ miss
    vals = np.asarray(dd._unroute(v, rt, fill=0))
    np.testing.assert_array_equal(vals[served], np.asarray(keys)[served] * 7)
    assert (vals[~served] == 0).all()


@pytest.mark.parametrize("name", FUSED_BACKENDS)
def test_adversarial_slab_routed_budget(name):
    """The acceptance pin: a 100%-skew adversarial batch routed through the
    spill-slab layout lowers to exactly 1 sort + 1 pallas_call TOTAL — no
    cond-gated second pass anywhere, the spilling batch costs the same op
    as a balanced one."""
    be = backend.get(name)
    s, q = 8, 64
    st = dhash.make_stack(s, name, 256, chunk=64, seed=0, fused=True)
    keys = jnp.arange(1, q + 1, dtype=jnp.int32)
    owner = jnp.full(q, 3, jnp.int32)             # every key one owner
    cap = dd.route_cap(2.0, q, s)
    spill_cap = dd.route_spill_cap(q, cap)        # overflow-proof

    def routed(st, k, o):
        rt = dd._route(k, o, s, cap, spill_cap)
        f, v = jax.vmap(lambda d, kk: be.lookup_fused(d.old, kk))(st, rt.send)
        return dd._unroute(f & rt.smask, rt, fill=False), rt.dropped

    counts = _count_primitives(jax.make_jaxpr(routed)(st, keys, owner),
                               ("sort", "pallas_call", "cond"))
    assert counts == {"sort": 1, "pallas_call": 1, "cond": 0}, (name, counts)


def test_grid_owner_flat_ids():
    keys = jnp.arange(1, 33, dtype=jnp.int32)
    tenant = keys % 3
    from repro.core import hashing
    hfn = hashing.fresh("tabulation", 7)
    own = dd.grid_owner(keys, tenant, 4, 3, hfn)
    shard = dd.shard_of(keys, 4, hfn)
    np.testing.assert_array_equal(np.asarray(own),
                                  np.asarray(shard) * 3 + np.asarray(tenant))
    assert int(own.min()) >= 0 and int(own.max()) < 12
