"""Fault-tolerant checkpointing with elastic restore.

* **Atomic**: state is written to ``step_XXXX.tmp/`` then renamed — a crash
  mid-write can never corrupt the latest checkpoint (restart-safe).
* **Manifest**: step, wall-time, mesh topology, and a content digest per leaf
  (restore verifies integrity; a flipped bit fails loudly, not silently).
* **Elastic**: arrays are saved logically (full array per leaf); restore
  re-device_puts onto the *current* mesh's shardings, so a run checkpointed
  on mesh A restarts on mesh B (fewer/more hosts) unchanged.  On a real
  multi-host cluster each host writes only its addressable shards with the
  same manifest format (process_index staging documented in launch/train.py).
* **GC**: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from typing import Any

import jax
import numpy as np


def _leaf_paths(tree: Any):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        name = "__".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        yield name, leaf


def save(ckpt_dir: str, step: int, state: Any, *, extra: dict | None = None,
         keep: int = 3) -> str:
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "time": time.time(), "leaves": {},
                "mesh": extra or {}}
    for name, leaf in _leaf_paths(state):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"][name] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha1": hashlib.sha1(arr.tobytes()).hexdigest()[:16]}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                                  # atomic publish
    _gc(ckpt_dir, keep)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, *, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``template``; place leaves with
    ``shardings`` (pytree of NamedSharding) when given — the elastic path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    names = [name for name, _ in _leaf_paths(template)]
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(names))
    loaded = []
    for name, sh in zip(names, shard_leaves):
        arr = np.load(os.path.join(d, name + ".npy"))
        meta = manifest["leaves"][name]
        digest = hashlib.sha1(arr.tobytes()).hexdigest()[:16]
        if digest != meta["sha1"]:
            raise IOError(f"checkpoint leaf {name} corrupt "
                          f"({digest} != {meta['sha1']})")
        loaded.append(jax.device_put(arr, sh) if sh is not None
                      else jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, loaded), step


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
