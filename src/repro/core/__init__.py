"""DHash core: dynamic hash tables with live hash-function rebuild (the
paper's contribution), modular bucket backends, baselines, and the
shard_map-distributed table."""

from repro.core import baselines, buckets, dhash, distributed, engine, hashing

__all__ = ["baselines", "buckets", "dhash", "distributed", "engine", "hashing"]
