"""Pallas TPU kernel: batched linear-probe lookup over VMEM-resident slabs.

TPU adaptation of the paper's hot path.  On CPUs the per-op cost at load
factor alpha is pointer chasing; on TPU the equivalent hot loop is the probe
sequence, and the roofline term is HBM traffic: a naive gather streams
table lines per query.  This kernel restructures the access pattern:

  1. ops.py sorts the query batch by start slot h0 (one XLA sort), so each
     query tile touches a *contiguous slab* of the table;
  2. a scalar-prefetch BlockSpec (`pltpu.PrefetchScalarGridSpec`) picks the
     two consecutive table blocks covering the tile's slab — data-dependent
     block indexing, the canonical TPU pattern for sorted gathers;
  3. the probe loop then runs entirely in VMEM on the VPU: each of the
     ``max_probes`` rounds is a vectorized compare of the query tile against
     dynamically-indexed slab lanes.

Queries whose probe window escapes the 2-block slab (hash skew) raise a
`complete=False` flag and are re-run by the jnp fallback in ops.py — the
kernel is exact, never wrong, occasionally partial.

Tiling: query tile QT=1024 (8x128 vregs), slab block SLAB=4096 i32 words
-> VMEM residency = 2 blocks x 3 arrays x 16 KiB = 96 KiB per step, well
under the ~16 MiB v5e VMEM budget; the MXU is idle (this is a VPU/memory
kernel) so the matmul pipeline of a co-scheduled layer is undisturbed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

I32 = jnp.int32
EMPTY, LIVE = 0, 1

QT = 1024     # queries per tile
SLAB = 4096   # table words per block (2 consecutive blocks resident)


def _probe_kernel(slab_ref,              # scalar-prefetch: [tiles] block index
                  h0_ref, qk_ref,        # [QT] query start slots / keys
                  tk0, tk1, tv0, tv1, ts0, ts1,   # [SLAB] table key/val/state
                  found_ref, val_ref, complete_ref,
                  *, max_probes: int):
    i = pl.program_id(0)
    base = slab_ref[i] * SLAB
    off = h0_ref[...] - base                      # [QT] offset into 2*SLAB window
    qk = qk_ref[...]

    keys = jnp.concatenate([tk0[...], tk1[...]])    # [2*SLAB]
    vals = jnp.concatenate([tv0[...], tv1[...]])
    stat = jnp.concatenate([ts0[...], ts1[...]])

    # a probe sequence is complete iff it fits the resident window
    complete = (off >= 0) & (off + max_probes <= 2 * SLAB)
    safe_off = jnp.clip(off, 0, 2 * SLAB - max_probes)

    def body(p, carry):
        active, found, val = carry
        idx = safe_off + p
        k = jnp.take(keys, idx, axis=0)
        v = jnp.take(vals, idx, axis=0)
        s = jnp.take(stat, idx, axis=0)
        hit = active & (s == LIVE) & (k == qk)
        stop = active & (s == EMPTY)
        val = jnp.where(hit, v, val)
        found = found | hit
        active = active & ~hit & ~stop
        return active, found, val

    init = (jnp.ones((QT,), bool), jnp.zeros((QT,), bool), jnp.zeros((QT,), I32))
    _, found, val = jax.lax.fori_loop(0, max_probes, body, init)

    found_ref[...] = found & complete
    val_ref[...] = jnp.where(complete, val, 0)
    complete_ref[...] = complete


def probe_lookup_tiles(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                       h0_sorted: jax.Array, qk_sorted: jax.Array,
                       slab_base: jax.Array, *, max_probes: int,
                       interpret: bool = True):
    """Run the kernel over pre-sorted, pre-tiled queries.

    tkey/tval/tstate: padded table arrays, length a multiple of SLAB and at
    least ``max(h0)+max_probes`` (ops.py pads with a wrapped copy so probes
    never wrap inside the kernel).
    h0_sorted/qk_sorted: [Q] sorted by h0, Q a multiple of QT.
    slab_base: [Q/QT] block index (h0_min of the tile // SLAB), clipped so
    block+1 stays in range.
    """
    q = h0_sorted.shape[0]
    assert q % QT == 0 and tkey.shape[0] % SLAB == 0
    tiles = q // QT

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((QT,), lambda i, s: (i,)),
            pl.BlockSpec((QT,), lambda i, s: (i,)),
            pl.BlockSpec((SLAB,), lambda i, s: (s[i],)),
            pl.BlockSpec((SLAB,), lambda i, s: (s[i] + 1,)),
            pl.BlockSpec((SLAB,), lambda i, s: (s[i],)),
            pl.BlockSpec((SLAB,), lambda i, s: (s[i] + 1,)),
            pl.BlockSpec((SLAB,), lambda i, s: (s[i],)),
            pl.BlockSpec((SLAB,), lambda i, s: (s[i] + 1,)),
        ],
        out_specs=[
            pl.BlockSpec((QT,), lambda i, s: (i,)),
            pl.BlockSpec((QT,), lambda i, s: (i,)),
            pl.BlockSpec((QT,), lambda i, s: (i,)),
        ],
    )
    out_shape = [
        jax.ShapeDtypeStruct((q,), jnp.bool_),
        jax.ShapeDtypeStruct((q,), I32),
        jax.ShapeDtypeStruct((q,), jnp.bool_),
    ]
    kernel = functools.partial(_probe_kernel, max_probes=max_probes)
    # each table array is passed twice: block s and block s+1 of the same
    # buffer (XLA aliases the operand; no copy)
    return pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret)(
        slab_base, h0_sorted, qk_sorted, tkey, tkey, tval, tval, tstate, tstate)
