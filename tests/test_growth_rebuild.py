"""Growth-factor rebuild parity: the fused rebuild-epoch ops vs the jnp
oracle at 1x/4x/16x new-table growth (the two-level tile-map acceptance).

The shared query sort is keyed on the OLD table's start slots, so a grown
new table scatters each tile's new-table windows across many slabs; the
two-level tile map (per-tile resident blocks, ``ops.NRES_CAP`` of them) must
keep the ordered check exact AND fused at every growth factor — including
non-power-of-two capacities and non-tile-multiple batches, where the edge
padding and block clipping are most exposed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import count_primitives
from repro.core import buckets, dhash, hashing
from repro.kernels import ops, ref

GROWTHS = (1, 4, 16)


def _linear_pair(c_old, c_new, n_old, n_new, seed, max_probes=32):
    rng = np.random.default_rng(seed)
    told = buckets.linear_make(c_old, hashing.fresh("mix32", seed),
                               max_probes=max_probes)
    k1 = jnp.asarray(rng.choice(10_000_000, n_old, replace=False)
                     .astype(np.int32))
    told, _ = jax.jit(buckets.linear_insert)(told, k1, k1 * 3,
                                             jnp.ones(k1.shape, bool))
    tnew = buckets.linear_make(c_new, hashing.fresh("mix32", seed + 1),
                               max_probes=max_probes)
    k2 = jnp.asarray(rng.choice(np.arange(30_000_000, 40_000_000), n_new,
                                replace=False).astype(np.int32))
    tnew, _ = jax.jit(buckets.linear_insert)(tnew, k2, k2 * 9,
                                             jnp.ones(k2.shape, bool))
    hk = jnp.asarray(rng.choice(np.arange(20_000_000, 21_000_000), 64,
                                replace=False).astype(np.int32))
    hv = hk * 7
    hl = jnp.asarray(rng.random(64) < 0.7)
    return told, tnew, k1, k2, hk, hv, hl, rng


@pytest.mark.parametrize("growth", GROWTHS)
def test_linear_growth_lookup_parity(growth):
    """Fused ordered lookup == oracle with a grown, NON-power-of-two new
    table and a non-tile-multiple batch; budget stays 1 sort + 1 pallas."""
    c_old = 3000                                   # non-power-of-two
    c_new = c_old * growth + 37                    # non-pow2, non-multiple
    told, tnew, k1, k2, hk, hv, hl, rng = _linear_pair(
        c_old, c_new, 1_500, 1_500, seed=growth)
    qs = jnp.concatenate([k1[:700], k2[:700], hk,
                          jnp.asarray(rng.integers(2**30, 2**31 - 1, 572)
                                      .astype(np.int32))])  # 2033 queries
    h0o = hashing.bucket_of(told.hfn, qs, c_old)
    h0n = hashing.bucket_of(tnew.hfn, qs, c_new)
    args = ((told.key, told.val, told.state), (tnew.key, tnew.val, tnew.state),
            hk, hv, hl, h0o, h0n, qs)
    f_r, v_r = ref.ordered_lookup_ref(*args, max_probes=32)
    f_k, v_k = ops.ordered_lookup_fused(*args, max_probes=32)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_r))
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_r))

    jx = jax.make_jaxpr(
        lambda *a: ops.ordered_lookup_fused(*a, max_probes=32))(*args)
    assert count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}


@pytest.mark.parametrize("growth", GROWTHS)
def test_linear_growth_delete_parity(growth):
    """Fused ordered delete == the staged jnp ordered delete (old tombstone /
    hazard kill / new tombstone) under growth."""
    c_old = 2_900
    c_new = c_old * growth + 51
    told, tnew, k1, k2, hk, hv, hl, rng = _linear_pair(
        c_old, c_new, 1_200, 1_200, seed=10 + growth)
    dels = jnp.concatenate([k1[::4], k2[::4], hk[:24],
                            jnp.asarray(rng.integers(2**29, 2**30, 101)
                                        .astype(np.int32))])
    win = buckets.batch_winners(dels, jnp.ones(dels.shape, bool))
    h0o = hashing.bucket_of(told.hfn, dels, c_old)
    h0n = hashing.bucket_of(tnew.hfn, dels, c_new)
    old_t = (told.key, told.val, told.state)
    new_t = (tnew.key, tnew.val, tnew.state)
    os_k, ns_k, hl_k, ok_k = ops.ordered_delete_fused(
        old_t, new_t, hk, hv, hl, h0o, h0n, dels, win, max_probes=32)

    # staged oracle: old -> hazard -> new
    os_r, ok_o = ref.probe_delete_ref(told.key, told.val, told.state,
                                      h0o, dels, win, 32)
    pend = win & ~ok_o
    eq = (dels[:, None] == hk[None, :]) & hl[None, :]
    hz_hit = eq.any(-1) & pend
    kill = jnp.zeros_like(hl).at[
        jnp.where(hz_hit, jnp.argmax(eq, axis=-1), 64)].set(True, mode="drop")
    ns_r, ok_n = ref.probe_delete_ref(tnew.key, tnew.val, tnew.state,
                                      h0n, dels, pend & ~hz_hit, 32)
    np.testing.assert_array_equal(np.asarray(ok_k),
                                  np.asarray(ok_o | hz_hit | ok_n))
    np.testing.assert_array_equal(np.asarray(os_k), np.asarray(os_r))
    np.testing.assert_array_equal(np.asarray(ns_k), np.asarray(ns_r))
    np.testing.assert_array_equal(np.asarray(hl_k), np.asarray(hl & ~kill))


def test_linear_16x_escape_rate_under_5pct():
    """Tentpole acceptance: at 16x growth the fused probe resolves >95% of
    rebuild-epoch queries in-kernel (the pre-tile-map behaviour was a
    majority escaping to the fallback)."""
    c_old = 4096
    told, tnew, k1, k2, hk, hv, hl, rng = _linear_pair(
        c_old, c_old * 16, 3_000, 2_000, seed=3)
    qs = jnp.concatenate([k1[:1000], k2[:1000], hk,
                          jnp.asarray(rng.integers(2**30, 2**31 - 1, 2033)
                                      .astype(np.int32))])
    h0o = hashing.bucket_of(told.hfn, qs, c_old)
    h0n = hashing.bucket_of(tnew.hfn, qs, c_old * 16)
    rate = float(ops.rebuild_escape_rate(
        (told.key, told.val, told.state), (tnew.key, tnew.val, tnew.state),
        hk, hv, hl, h0o, h0n, qs, max_probes=32))
    assert rate < 0.05, f"escape rate {rate:.3f} at 16x growth"


def _tc_pair(nb_old, nb_new, n_old, n_new, seed, width=8):
    rng = np.random.default_rng(seed)
    to = buckets.twochoice_make(nb_old, hashing.fresh("mix32", seed),
                                hashing.fresh("mix32", seed + 1), width=width)
    k1 = jnp.asarray(rng.choice(1_000_000, n_old, replace=False)
                     .astype(np.int32))
    to, _ = jax.jit(buckets.twochoice_insert)(to, k1, k1 * 5,
                                              jnp.ones(k1.shape, bool))
    tn = buckets.twochoice_make(nb_new, hashing.fresh("mix32", seed + 2),
                                hashing.fresh("mix32", seed + 3), width=width)
    k2 = jnp.asarray(rng.choice(np.arange(2_000_000, 3_000_000), n_new,
                                replace=False).astype(np.int32))
    tn, _ = jax.jit(buckets.twochoice_insert)(tn, k2, k2 * 9,
                                              jnp.ones(k2.shape, bool))
    hk = jnp.asarray(rng.choice(np.arange(5_000_000, 6_000_000), 64,
                                replace=False).astype(np.int32))
    hv = hk * 7
    hl = jnp.asarray(rng.random(64) < 0.7)
    return to, tn, k1, k2, hk, hv, hl, rng


def _tc_ordered_oracle_lookup(to, tn, hk, hv, hl, rows, qs):
    (bao, bbo), (ban, bbn) = rows
    fa, va, _ = ref.tc_row_lookup_ref(to.key, to.val, to.state, bao, qs)
    fb, vb, _ = ref.tc_row_lookup_ref(to.key, to.val, to.state, bbo, qs)
    fo, vo = fa | fb, jnp.where(fa, va, vb)
    eq = (qs[:, None] == hk[None, :]) & hl[None, :]
    fh = eq.any(-1)
    vh = jnp.take(hv, jnp.argmax(eq, axis=-1))
    fna, vna, _ = ref.tc_row_lookup_ref(tn.key, tn.val, tn.state, ban, qs)
    fnb, vnb, _ = ref.tc_row_lookup_ref(tn.key, tn.val, tn.state, bbn, qs)
    fnw, vnw = fna | fnb, jnp.where(fna, vna, vnb)
    found = fo | fh | fnw
    val = jnp.where(fo, vo, jnp.where(fh, vh, jnp.where(fnw, vnw, 0)))
    return found, jnp.where(found, val, 0)


@pytest.mark.parametrize("growth", GROWTHS)
def test_twochoice_growth_lookup_parity(growth):
    """Single-pass fused twochoice ordered lookup == the staged jnp oracle
    at growth, non-pow2 bucket counts, odd batch size; budget 1 sort +
    1 pallas_call."""
    nb_old = 509                                   # non-power-of-two rows
    nb_new = nb_old * growth + 3
    to, tn, k1, k2, hk, hv, hl, rng = _tc_pair(nb_old, nb_new, 1_200, 1_200,
                                               seed=20 + growth)
    qs = jnp.concatenate([k1[:500], k2[:500], hk,
                          jnp.asarray(rng.integers(2**30, 2**31 - 1, 401)
                                      .astype(np.int32))])
    rows = (buckets._tc_rows(to, qs), buckets._tc_rows(tn, qs))
    args = ((to.key, to.val, to.state), (tn.key, tn.val, tn.state),
            hk, hv, hl, *rows[0], *rows[1], qs)
    f_k, v_k = ops.twochoice_ordered_lookup(*args)
    f_r, v_r = _tc_ordered_oracle_lookup(to, tn, hk, hv, hl, rows, qs)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_r))
    fm = np.asarray(f_r)
    np.testing.assert_array_equal(np.asarray(v_k)[fm], np.asarray(v_r)[fm])

    jx = jax.make_jaxpr(lambda *a: ops.twochoice_ordered_lookup(*a))(*args)
    assert count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}


@pytest.mark.parametrize("growth", GROWTHS)
def test_twochoice_growth_delete_parity(growth):
    """Single-pass fused twochoice ordered delete == the staged jnp ordered
    delete on states, hazard kills, and ok flags, under growth."""
    nb_old = 487
    nb_new = nb_old * growth + 5
    to, tn, k1, k2, hk, hv, hl, rng = _tc_pair(nb_old, nb_new, 1_000, 1_000,
                                               seed=30 + growth)
    dels = jnp.concatenate([k1[::5], k2[::5], hk[:20],
                            jnp.asarray(rng.integers(2**29, 2**30, 77)
                                        .astype(np.int32))])
    win = buckets.batch_winners(dels, jnp.ones(dels.shape, bool))
    rows = (buckets._tc_rows(to, dels), buckets._tc_rows(tn, dels))
    args = ((to.key, to.val, to.state), (tn.key, tn.val, tn.state),
            hk, hv, hl, *rows[0], *rows[1], dels, win)
    os_k, ns_k, hl_k, ok_k = ops.twochoice_ordered_delete(*args)

    os_r, ok_o = ref.tc_delete_ref(to.key, to.val, to.state,
                                   *rows[0], dels, win)
    pend = win & ~ok_o
    eq = (dels[:, None] == hk[None, :]) & hl[None, :]
    hz_hit = eq.any(-1) & pend
    kill = jnp.zeros_like(hl).at[
        jnp.where(hz_hit, jnp.argmax(eq, axis=-1), 64)].set(True, mode="drop")
    ns_r, ok_n = ref.tc_delete_ref(tn.key, tn.val, tn.state,
                                   *rows[1], dels, pend & ~hz_hit)
    np.testing.assert_array_equal(np.asarray(ok_k),
                                  np.asarray(ok_o | hz_hit | ok_n))
    np.testing.assert_array_equal(np.asarray(os_k), np.asarray(os_r))
    np.testing.assert_array_equal(np.asarray(ns_k), np.asarray(ns_r))
    np.testing.assert_array_equal(np.asarray(hl_k), np.asarray(hl & ~kill))

    jx = jax.make_jaxpr(lambda *a: ops.twochoice_ordered_delete(*a))(*args)
    assert count_primitives(jx, ("sort", "pallas_call")) == \
        {"sort": 1, "pallas_call": 1}


@pytest.mark.parametrize("backend", ["linear", "twochoice"])
def test_dhash_grown_rebuild_interleaved(backend):
    """End-to-end: a fused DHashState rebuilding into a 4x GROWN user-
    supplied new table, with deletes and lookups interleaved mid-rebuild,
    matches its unfused twin on every observable."""
    rng = np.random.default_rng(42)
    mk = lambda fused: dhash.make(backend, capacity=600, chunk=128, seed=5,  # noqa: E731
                                  fused=fused)
    d_j, d_k = mk(False), mk(True)
    keys = jnp.asarray(rng.choice(100_000, 473, replace=False)
                       .astype(np.int32))
    ins = jax.jit(dhash.insert)
    d_j, _ = ins(d_j, keys, keys * 2)
    d_k, _ = ins(d_k, keys, keys * 2)

    if backend == "linear":
        grown = buckets.linear_make(buckets.capacity_of(d_j.old) * 4,
                                    hashing.fresh("mix32", 77),
                                    max_probes=d_j.old.max_probes)
    else:
        grown = buckets.twochoice_make(d_j.old.nbuckets * 4,
                                       hashing.fresh("mix32", 77),
                                       hashing.fresh("mix32", 78),
                                       width=d_j.old.width)
    d_j = dhash.rebuild_start(d_j, jax.tree_util.tree_map(jnp.copy, grown))
    d_k = dhash.rebuild_start(d_k, grown)
    step = jax.jit(dhash.rebuild_step)
    dl = jax.jit(dhash.delete)
    look = jax.jit(dhash.lookup)
    i = 0
    while bool(jax.device_get(d_k.rebuilding)) and i < 64:
        d_j, d_k = step(d_j), step(d_k)
        dels = keys[i::16][:5]
        d_j, ok_j = dl(d_j, jnp.asarray(dels))
        d_k, ok_k = dl(d_k, jnp.asarray(dels))
        np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_j))
        f_j, v_j = look(d_j, keys[:101])
        f_k, v_k = look(d_k, keys[:101])
        np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_j))
        fm = np.asarray(f_j)
        np.testing.assert_array_equal(np.asarray(v_k)[fm],
                                      np.asarray(v_j)[fm])
        if bool(jax.device_get(dhash.rebuild_done(d_k))):
            d_j, d_k = dhash.rebuild_finish(d_j), dhash.rebuild_finish(d_k)
        i += 1
    assert int(d_k.epoch) == 1, "grown rebuild did not complete"
    assert int(dhash.count_items(d_j)) == int(dhash.count_items(d_k))
