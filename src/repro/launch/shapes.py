"""The assigned input-shape matrix and per-cell ShapeDtypeStruct builders.

Every (arch x shape) cell resolves here to either a (step_fn, abstract
inputs, shardings) triple or an explicit skip with the DESIGN.md reason.
Nothing in this module allocates device memory — inputs are
jax.ShapeDtypeStruct stand-ins.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import sharding as shard_lib
from repro.optim.optimizer import OptConfig

I32 = jnp.int32


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic sequence mixing)
_LONG_OK = {"zamba2-1.2b", "rwkv6-3b"}


def applicability(cfg: ArchConfig, shape: str) -> str | None:
    """None if runnable, else the skip reason (recorded in EXPERIMENTS.md)."""
    sp = SHAPES[shape]
    if cfg.encoder_only and sp.kind == "decode":
        return "encoder-only arch: no decode step"
    if shape == "long_500k" and cfg.arch_id not in _LONG_OK:
        return "full-attention arch: long_500k needs sub-quadratic mixing (DESIGN.md)"
    return None


def _dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _sized(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _ns(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def batch_struct(cfg: ArchConfig, sp: ShapeSpec, mesh: Mesh):
    """(abstract batch, batch shardings) for a train/prefill step."""
    dp = _dp_axes(mesh)
    dpspec = dp if len(dp) > 1 else dp[0]
    sizes = _sized(mesh)
    ndp = 1
    for a in dp:
        ndp *= sizes[a]
    b, s = sp.global_batch, sp.seq_len
    shard_b = b % ndp == 0
    bspec = dpspec if shard_b else None
    # batch=1 long-context: shard the sequence axis instead (SP)
    sspec = None if shard_b else "data"
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), I32),
             "labels": jax.ShapeDtypeStruct((b, s), I32)}
    shards = {"tokens": _ns(mesh, bspec, sspec),
              "labels": _ns(mesh, bspec, sspec)}
    if cfg.frontend == "stub_embed":
        batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                               jnp.dtype(cfg.dtype))
        shards["embeds"] = _ns(mesh, bspec, sspec, None)
    if cfg.mrope_sections is not None:
        batch["positions"] = jax.ShapeDtypeStruct((3, b, s), I32)
        shards["positions"] = _ns(mesh, None, bspec, sspec)
    return batch, shards


def state_struct(cfg: ArchConfig, mesh: Mesh, opt_cfg: OptConfig):
    """Abstract train state + shardings (params/opt via the rule table)."""
    from repro.train import train_step as ts
    state = jax.eval_shape(partial(ts.init_state, cfg, opt_cfg),
                           jax.random.PRNGKey(0))
    pshard = shard_lib.param_shardings(state["params"], mesh, fsdp=cfg.fsdp)
    oshard = {
        "m": jax.tree_util.tree_map(
            lambda s: s, shard_lib.param_shardings(state["opt"]["m"], mesh,
                                                   fsdp=cfg.fsdp)),
        "v": shard_lib.param_shardings(state["opt"]["v"], mesh, fsdp=cfg.fsdp),
        "step": _ns(mesh),
    }
    shards = {"params": pshard, "opt": oshard}
    if "router_table" in state:
        shards["router_table"] = jax.tree_util.tree_map(
            lambda _: _ns(mesh), state["router_table"])
    return state, shards


def cache_struct(cfg: ArchConfig, sp: ShapeSpec, mesh: Mesh):
    """Abstract decode cache + shardings. Dense stacked cache; the KV seq
    axis shards over 'data' when the batch axis cannot (long_500k)."""
    from repro.models import transformer
    dp = _dp_axes(mesh)
    dpspec = dp if len(dp) > 1 else dp[0]
    sizes = _sized(mesh)
    ndp = 1
    for a in dp:
        ndp *= sizes[a]
    b = sp.global_batch
    shard_b = b % ndp == 0
    bspec = dpspec if shard_b else None
    sspec = None if shard_b else "data"
    nm = sizes.get("model", 1)
    kvspec = "model" if cfg.n_kv_heads % nm == 0 else None
    cache = jax.eval_shape(partial(transformer.init_cache, cfg, b, sp.seq_len))
    shards = {}
    for k, leaf in cache.items():
        if k in ("k", "v"):
            shards[k] = _ns(mesh, None, bspec, sspec, kvspec, None)
        elif k == "len":
            shards[k] = _ns(mesh, bspec)
        elif k in ("ssm_h", "ssm_conv", "wkv", "tm_prev", "cm_prev"):
            shards[k] = _ns(mesh, None, bspec, *([None] * (leaf.ndim - 2)))
        else:
            shards[k] = _ns(mesh, *([None] * leaf.ndim))
    return cache, shards


def decode_inputs(cfg: ArchConfig, sp: ShapeSpec, mesh: Mesh):
    dp = _dp_axes(mesh)
    dpspec = dp if len(dp) > 1 else dp[0]
    sizes = _sized(mesh)
    ndp = 1
    for a in dp:
        ndp *= sizes[a]
    b = sp.global_batch
    bspec = dpspec if b % ndp == 0 else None
    if cfg.frontend == "stub_embed":
        tok = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.dtype(cfg.dtype))
        tsh = _ns(mesh, bspec, None, None)
    else:
        tok = jax.ShapeDtypeStruct((b, 1), I32)
        tsh = _ns(mesh, bspec, None)
    return tok, tsh
