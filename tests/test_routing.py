"""Router unit tests: the two-pass counting-sort layout behind every
routed op.

The contract under test:

* ``_route`` produces the SAME owner-grouped [S, cap] layout as a stable
  reference (keys placed in batch order within their owner), with EXACT
  per-owner overflow counts — never a silent drop;
* route → unroute is the identity on kept keys, and dropped keys come back
  as an unmistakable fill (0/False for ints/bools, NaN for floats);
* the router lowers with ZERO ``sort`` primitives, so a routed fused
  ``stack_lookup`` keeps the single-op kernel budget:
  ONE sort + ONE pallas_call total (the fused kernel's own bucket sort is
  the only sort in the op).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend, dhash
from repro.core import distributed as dd

FUSED_BACKENDS = [b for b in backend.names() if backend.get(b).fused]


def _count_primitives(closed_jaxpr, names):
    from collections import Counter
    ctr = Counter()

    def rec(jaxpr):
        for eq in jaxpr.eqns:
            ctr[eq.primitive.name] += 1
            for p in eq.params.values():
                if hasattr(p, "jaxpr"):
                    rec(p.jaxpr if hasattr(p.jaxpr, "eqns") else p.jaxpr.jaxpr)

    rec(closed_jaxpr.jaxpr)
    return {n: ctr.get(n, 0) for n in names}


def _ref_route(keys, owner, nshards, cap):
    """Stable counting-sort reference in plain NumPy."""
    keys, owner = np.asarray(keys), np.asarray(owner)
    send = np.zeros((nshards, cap), keys.dtype)
    smask = np.zeros((nshards, cap), bool)
    kept = np.zeros(keys.shape[0], bool)
    fill = np.zeros(nshards, np.int64)
    for i in range(keys.shape[0]):
        o = int(owner[i])
        r = fill[o]
        fill[o] += 1
        if r < cap:
            send[o, r] = keys[i]
            smask[o, r] = True
            kept[i] = True
    return send, smask, kept, np.maximum(fill - cap, 0)


def test_route_cap_math():
    # cap = ceil(c*Q/S)
    assert dd.route_cap(2.0, 64, 8) == 16
    assert dd.route_cap(1.0, 64, 8) == 8
    assert dd.route_cap(1.0, 65, 8) == 9          # ceil, not floor
    assert dd.route_cap(2.0, 128, 64) == 4
    # <= 0 means the overflow-proof full width
    assert dd.route_cap(0.0, 64, 8) == 64
    assert dd.route_cap(-1.0, 64, 8) == 64
    # clamped to [1, Q]
    assert dd.route_cap(0.01, 4, 64) == 1
    assert dd.route_cap(100.0, 8, 2) == 8


@pytest.mark.parametrize("skew", ["uniform", "zipfish", "one_owner"])
def test_route_matches_stable_reference(skew):
    rng = np.random.default_rng(11)
    q, s = 96, 8
    keys = jnp.asarray(rng.choice(10_000, q, replace=False).astype(np.int32))
    if skew == "uniform":
        owner = rng.integers(0, s, q)
    elif skew == "zipfish":
        owner = np.minimum(rng.zipf(1.5, q) - 1, s - 1)
    else:
        owner = np.full(q, 3)
    owner = jnp.asarray(owner.astype(np.int32))
    for cap in (q, dd.route_cap(2.0, q, s), 3):
        rt = dd._route(keys, owner, s, cap)
        send, smask, kept, over = _ref_route(keys, owner, s, cap)
        np.testing.assert_array_equal(np.asarray(rt.send), send)
        np.testing.assert_array_equal(np.asarray(rt.smask), smask)
        np.testing.assert_array_equal(np.asarray(rt.kept), kept)
        np.testing.assert_array_equal(np.asarray(rt.overflow), over)
        # overflow is EXACT: hist - cap, never saturated or approximated
        hist = np.bincount(np.asarray(owner), minlength=s)
        np.testing.assert_array_equal(np.asarray(rt.overflow),
                                      np.maximum(hist - cap, 0))


def test_route_unroute_roundtrip_including_drops():
    rng = np.random.default_rng(5)
    q, s = 64, 4
    cap = dd.route_cap(1.0, q, s)                 # tight: guarantees drops
    keys = jnp.asarray(rng.choice(10_000, q, replace=False).astype(np.int32))
    owner = jnp.asarray((np.asarray(keys) * 7 % s).astype(np.int32))
    rt = dd._route(keys, owner, s, cap)
    assert int(rt.overflow.sum()) > 0, "cap must actually drop keys here"
    # a shard-side response derived from the routed keys round-trips to
    # batch order exactly on kept keys; dropped keys take the fill
    resp = rt.send * 3
    back = dd._unroute(resp, rt, fill=-1)
    expect = np.where(np.asarray(rt.kept), np.asarray(keys) * 3, -1)
    np.testing.assert_array_equal(np.asarray(back), expect)
    # payload scatter uses the same coordinates as the key scatter
    pay = dd._route_payload(keys * 3, rt)
    np.testing.assert_array_equal(np.asarray(pay), np.asarray(rt.send) * 3)
    # full-width route keeps EVERY key: round-trip is the identity
    full = dd._route(keys, owner, s)
    assert bool(np.asarray(full.kept).all())
    assert int(full.overflow.sum()) == 0
    np.testing.assert_array_equal(
        np.asarray(dd._unroute(full.send, full)), np.asarray(keys))


def test_unroute_float_fill_is_nan_safe():
    keys = jnp.arange(1, 9, dtype=jnp.int32)
    owner = jnp.asarray([0, 0, 0, 0, 1, 1, 1, 1], jnp.int32)
    rt = dd._route(keys, owner, 2, cap=2)         # half of each owner spills
    resp = rt.send.astype(jnp.float32) * 0.5
    back = dd._unroute(resp, rt)                  # default fill
    back = np.asarray(back)
    kept = np.asarray(rt.kept)
    # dropped float payloads are NaN — NEVER a fake 0.0
    assert np.isnan(back[~kept]).all()
    np.testing.assert_allclose(back[kept], np.arange(1, 9)[kept] * 0.5)
    # integer/bool responses default to 0/False instead
    backi = np.asarray(dd._unroute(rt.send, rt))
    assert (backi[~kept] == 0).all()


def test_router_lowers_with_zero_sorts():
    """The tentpole claim at the router level: the counting-sort layout
    contains NO ``sort`` primitive (pass 1 is a one-hot histogram +
    cumsum, pass 2 a 2-D scatter)."""
    keys = jnp.arange(128, dtype=jnp.int32)
    owner = keys % 8

    def route(k, o):
        rt = dd._route(k, o, 8, cap=32)
        return rt.send, rt.smask, rt.overflow

    counts = _count_primitives(jax.make_jaxpr(route)(keys, owner),
                               ("sort", "pallas_call"))
    assert counts == {"sort": 0, "pallas_call": 0}, counts


@pytest.mark.parametrize("name", FUSED_BACKENDS)
def test_routed_fused_stack_lookup_budget(name):
    """The acceptance budget: route (capped) + fused stack lookup lowers to
    exactly 1 sort + 1 pallas_call TOTAL — the fused kernel's own bucket
    sort is the only sort; the router adds none."""
    be = backend.get(name)
    s, q = 4, 64
    st = dhash.make_stack(s, name, 256, chunk=64, seed=0, fused=True)
    keys = jnp.arange(1, q + 1, dtype=jnp.int32)
    owner = keys % s
    cap = dd.route_cap(2.0, q, s)

    def routed_fast(st, k, o):
        rt = dd._route(k, o, s, cap)
        f, v = jax.vmap(lambda d, kk: be.lookup_fused(d.old, kk))(st, rt.send)
        return dd._unroute(f & rt.smask, rt, fill=False)

    counts = _count_primitives(jax.make_jaxpr(routed_fast)(st, keys, owner),
                               ("sort", "pallas_call"))
    assert counts == {"sort": 1, "pallas_call": 1}, (name, counts)

    def routed_ordered(st, k, o):
        rt = dd._route(k, o, s, cap)
        f, v = jax.vmap(lambda d, kk: be.ordered_lookup_fused(
            d.old, d.new, d.hazard_key, d.hazard_val, d.hazard_live, kk,
            nres_cap=d.nres_cap))(st, rt.send)
        return dd._unroute(f & rt.smask, rt, fill=False)

    counts = _count_primitives(jax.make_jaxpr(routed_ordered)(st, keys, owner),
                               ("sort", "pallas_call"))
    assert counts == {"sort": 1, "pallas_call": 1}, (name, counts)


def test_capped_stack_lookup_exact_on_kept_keys():
    """End-to-end at the stack level (no mesh): capped routed lookups agree
    key-for-key with per-table lookups; spilled keys come back not-found
    (and are exactly the ones ``overflow`` counts)."""
    rng = np.random.default_rng(9)
    s, q = 4, 64
    st = dhash.make_stack(s, "linear", 256, chunk=64, seed=1)
    keys = jnp.asarray(rng.choice(100_000, q, replace=False).astype(np.int32))
    owner = jnp.asarray(rng.integers(0, s, q).astype(np.int32))
    # populate via a FULL-width route (no drops), then read back capped
    full = dd._route(keys, owner, s)
    st, ok = dhash.stack_insert(st, full.send, full.send * 5, full.smask)
    assert bool(np.asarray(dd._unroute(ok, full, fill=False)).all())
    rt = dd._route(keys, owner, s, dd.route_cap(1.0, q, s))
    f, v = dhash.stack_lookup(st, rt.send, rt.smask)
    found = np.asarray(dd._unroute(f, rt, fill=False).astype(bool))
    vals = np.asarray(dd._unroute(v, rt, fill=0))
    kept = np.asarray(rt.kept)
    np.testing.assert_array_equal(found, kept)    # kept ⇒ hit, spilled ⇒ miss
    np.testing.assert_array_equal(vals[kept], np.asarray(keys)[kept] * 5)
    assert int(rt.overflow.sum()) == int((~kept).sum())


def test_grid_owner_flat_ids():
    keys = jnp.arange(1, 33, dtype=jnp.int32)
    tenant = keys % 3
    from repro.core import hashing
    hfn = hashing.fresh("tabulation", 7)
    own = dd.grid_owner(keys, tenant, 4, 3, hfn)
    shard = dd.shard_of(keys, 4, hfn)
    np.testing.assert_array_equal(np.asarray(own),
                                  np.asarray(shard) * 3 + np.asarray(tenant))
    assert int(own.min()) >= 0 and int(own.max()) < 12
