"""Paper Figure 3: rebuild time vs number of nodes.

Claims reproduced:
  * HT-Split resize is cheapest (bucket pointers only, no node movement);
  * HT-Xu rebuilds in one traversal (two-pointer-set advantage);
  * DHash and HT-RHT distribute every node -> time linear in N;
  * DHash beats HT-RHT because RHT re-walks each chain to its TAIL per node
    distributed (O(len^2) per bucket) while DHash distributes scan-order
    chunks;
  * the op mix running concurrently does not materially change rebuild time
    (predictability claim, §6.3).
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import ALGOS, UNIVERSE


def run(ns=(2_000, 8_000, 32_000), alpha=20, *, quiet=False):
    rows = []
    for n in ns:
        nbuckets = max(n // alpha, 16)
        rng = np.random.default_rng(0)
        present = rng.choice(UNIVERSE, size=n, replace=False).astype(np.int32)
        for name, cls in ALGOS.items():
            drv = cls(nbuckets, n, seed=1)
            drv.populate(present)
            drv.full_rebuild()            # warmup (compile)
            dt = min(drv.full_rebuild() for _ in range(2))
            rows.append((drv.name, n, dt))
            if not quiet:
                print(f"{drv.name:14s} N={n:<8d} rebuild {dt*1e3:9.1f} ms")
    # linearity check for DHash (paper: predictable, linear in N)
    ds = [(n, dt) for nm, n, dt in rows if nm.startswith("DHash")]
    if len(ds) >= 2:
        r = (ds[-1][1] / ds[0][1]) / (ds[-1][0] / ds[0][0])
        print(f"[summary] DHash rebuild-time linearity ratio "
              f"(time-growth / N-growth): {r:.2f} (1.0 = perfectly linear)")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ns", type=int, nargs="*", default=[2_000, 8_000, 32_000])
    ap.add_argument("--alpha", type=int, default=20)
    args = ap.parse_args(argv)
    return run(tuple(args.ns), args.alpha)


if __name__ == "__main__":
    main()
