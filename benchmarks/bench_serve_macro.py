"""Serving macro-benchmark: live decode under attack and churn (§1, end-to-end).

The micro-benches each prove one claim in isolation; this harness drives
``serving/engine.py`` + ``prefix_cache.py`` + the kvcache tenant stacks as
ONE system through a four-phase traffic replay:

* **steady** — continuous batching with zipf prefix reuse (a family of
  shared prompt prefixes, zipf-weighted) and zipf tenant skew.  Prefix
  admission adopts cached blocks; published tails exceed the page pool, so
  the LRU eviction policy (``serving/eviction.py`` — itself a DHash
  client) is churning from the start.
* **attack** — a collision attack on the FINGERPRINT index: junk
  fingerprints that all hash into bucket 0 of the chain-backend prefix
  table (``bench_attack._attack_keys_for`` — the attacker knows the
  seed; with ``prefix_backend="cuckoo"`` the flood targets one side-A
  row of the bounded-probe backend instead, where it cannot build a
  chain).  Admission lookups and publishes that touch the hot bucket pay
  the long traversal, so tail latency (p99 = admission steps) degrades
  while p50 (pure decode) stays flat — the paper's motivating scenario in
  its serving role.
* **rebuild** — the response fires WHILE decode streams: a fresh-seed
  live rehash of the fingerprint index (``start_prefix_rehash``) plus
  per-tenant ``start_rehash`` on the hot tenants' page tables.  Every
  decode step advances both epochs (``kvcache.rehash_step``).
* **recovered** — the new hash function has redistributed the attacker's
  keys; tail latency and hit rate return to the steady band.

Artifact: ``BENCH_serve_macro.json`` (CI perf gate, ``check_regression``):
per-phase p50/p99 latency at both layers + miss rate + eviction/spill
counters.  Gated keys: ``attack_p50_ratio``/``recovered_p50_ratio``
(decode-flatness floors, RATIO, under a per-artifact ``ratio_band`` of
0.35 — same-run medians common-mode out hardware speed but still swing
run to run in interpret mode, measured 0.81–1.26 across idle-box runs,
so the COMMITTED baseline carries the median ratio of several
calibration runs rather than one sample; the failure this floors, a
blocking rehash, moves them ~50x), per-phase
``miss_rate`` and the replay-wide
``alloc_fail_rate`` (RATE — bit-deterministic for the pinned seeds), and
the per-step sort/pallas_call budgets (STRUCTURAL).  A second, compact
``cuckoo`` leg re-runs the whole replay with
``prefix_backend="cuckoo"`` and gates ITS attack-phase decode-flatness
ratios too (``cuckoo/attack_p50_ratio``, ``cuckoo/recovered_p50_ratio``)
— the bounded-probe backend must stay flat under the same flood without
relying on the chain geometry.  The p99 and cacheop
figures (``recovered_p99_ratio``, ``attack_cacheop_x``,
``recovered_cacheop_x``) are reported but NOT gated: a p99 of ~200
samples swings ~2x run-to-run, which no fixed tolerance separates from
regression.  The replay publishes more distinct blocks than ``n_pages``
and asserts ``alloc_fail == 0``: eviction, not allocation failure,
absorbs the pressure.
"""
from __future__ import annotations

import json
import pathlib
import time
from functools import partial

import numpy as np

from benchmarks.common import count_primitives, zipf_owners

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# chain-backend geometry of the fingerprint index: few buckets so the
# attacked bucket is hit by a meaningful share of admission batches, and a
# max_chain that admits the whole junk flood (the attack must LAND to hurt)
NBUCKETS = 16
N_ATTACK = 2048
MAX_CHAIN = N_ATTACK + 128


def _build(seed=0, *, prefix_backend="chain"):
    import jax

    from repro.configs.base import ArchConfig
    from repro.models import transformer
    from repro.serving.engine import ServeConfig, ServingEngine

    cfg = ArchConfig("bench-serve-macro", "dense", n_layers=2, d_model=32,
                     n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=128,
                     dtype="float32", attn_chunk=32, loss_chunk=32)
    params = transformer.init_params(cfg, jax.random.PRNGKey(seed))
    # chain gets the attack-friendly geometry above; any other fingerprint
    # backend (e.g. cuckoo, whose probe cost is bounded by construction)
    # sizes itself from prefix_capacity alone
    prefix_kw = ((("nbuckets", NBUCKETS), ("max_chain", MAX_CHAIN))
                 if prefix_backend == "chain" else ())
    sc = ServeConfig(max_seqs=4, page_size=4, n_pages=48, max_blocks=8,
                     max_new_tokens=4, n_tenants=4, prefix_cache=True,
                     prefix_backend=prefix_backend, prefix_capacity=4096,
                     evict_batch=8,
                     prefix_kw=prefix_kw)
    return ServingEngine(params, cfg, sc), cfg, sc


def _make_requests(rng, n, families, sc):
    """Zipf family reuse x zipf tenant skew; every prompt = shared family
    prefix (4 blocks) + unique tail (1-2 blocks) + 1 trigger token."""
    fam_idx = zipf_owners(rng, n, len(families), a=1.2)
    tenants = zipf_owners(rng, n, sc.n_tenants, a=1.2)
    reqs = []
    for f, t in zip(fam_idx, tenants):
        tail = rng.integers(1, 127, size=int(rng.integers(1, 3)) * sc.page_size)
        reqs.append((list(families[f]) + tail.tolist() + [1], int(t)))
    return reqs


class _Probe:
    """Timing instrumentation at the two layers that matter:

    * ``decode``: every ``_run_slots`` call — one model step for all slots
      (prefill micro-steps included).  This is the flatness claim: its p50
      AND p99 must not move through attack or rebuild, because decode never
      touches the fingerprint index.
    * ``cacheop``: every jitted adopt/publish call — the admission ops that
      walk the (attacked) chain buckets.  This is where the collision
      attack lands and where the live rehash must restore the tail.
    """

    def __init__(self, eng):
        import jax

        self.decode: list[float] = []
        self.cacheop: list[float] = []
        orig_run = eng._run_slots
        orig_adopt, orig_pub = eng._adopt, eng._publish

        def run_slots(sample=True):
            t0 = time.perf_counter()
            r = orig_run(sample=sample)
            jax.block_until_ready(eng.kv.free_top)
            self.decode.append(time.perf_counter() - t0)
            return r

        def timed(fn, sink):
            def go(*a):
                t0 = time.perf_counter()
                r = fn(*a)
                jax.block_until_ready(r)
                sink.append(time.perf_counter() - t0)
                return r
            return go

        eng._run_slots = run_slots
        eng._adopt = timed(orig_adopt, self.cacheop)
        eng._publish = timed(orig_pub, self.cacheop)

    def take(self):
        # the timed closures hold references to these exact lists, so clear
        # in place rather than rebinding
        d, c = np.asarray(self.decode), np.asarray(self.cacheop)
        del self.decode[:], self.cacheop[:]
        return d, c


def _drain(eng):
    while eng.queue or eng.active.any():
        eng.step()


def _phase(eng, probe, reqs, counters0):
    """Submit + drain one phase; returns (stats, counters_after)."""
    for prompt, tenant in reqs:
        eng.submit(prompt, tenant=tenant)
    _drain(eng)
    c1 = _counters(eng)
    lk = c1["lookups"] - counters0["lookups"]
    hits = c1["hits"] - counters0["hits"]
    dec, cop = probe.take()
    stats = {
        "decode_steps": int(dec.size),
        "p50_ms": float(np.percentile(dec, 50) * 1e3),
        "p99_ms": float(np.percentile(dec, 99) * 1e3),
        "cacheop_p50_ms": float(np.percentile(cop, 50) * 1e3),
        "cacheop_p99_ms": float(np.percentile(cop, 99) * 1e3),
        "miss_rate": float((lk - hits) / max(lk, 1)),
        "blocks_probed": int(lk),
        "evictions": c1["evictions"] - counters0["evictions"],
        "route_spill": c1["route_spill"] - counters0["route_spill"],
        "alloc_fail": c1["alloc_fail"] - counters0["alloc_fail"],
    }
    return stats, c1


def _counters(eng):
    return {"lookups": eng.cache_lookups, "hits": eng.cache_hits,
            "publishes": eng.publishes, "evictions": eng.evictions,
            "route_spill": eng.router_spills, "alloc_fail": eng.alloc_fails}


def _budgets(eng, cfg, sc):
    """Per-step structural op budget from jaxpr inspection (deterministic,
    machine-independent): the jitted decode step (alloc + evict-on-pressure
    + L layers of paged attention) and the admission pair (adopt+publish)."""
    import jax
    import jax.numpy as jnp

    from repro.serving import kvcache
    from repro.serving.engine import paged_decode_step

    b = sc.max_seqs
    sids = jnp.arange(b, dtype=jnp.int32)
    toks = jnp.zeros((b,), jnp.int32)
    lens = jnp.zeros((b,), jnp.int32)
    act = jnp.ones((b,), bool)
    step_j = jax.make_jaxpr(partial(paged_decode_step, cfg=cfg,
                                    n_blocks=sc.max_blocks))(
        eng.params, kv=eng.kv, seq_ids=sids, tokens=toks, lengths=lens,
        active=act)
    fps = jnp.zeros((sc.max_blocks,), jnp.int32)
    valid = jnp.zeros((sc.max_blocks,), bool)
    sid = jnp.asarray(1, jnp.int32)
    adopt_j = jax.make_jaxpr(kvcache.adopt_prefix)(eng.kv, sid, fps, valid)
    pub_j = jax.make_jaxpr(kvcache.publish_blocks)(eng.kv, sid, fps, valid)
    names = ("sort", "pallas_call")
    adm = count_primitives(adopt_j, names)
    for k, v in count_primitives(pub_j, names).items():
        adm[k] += v
    return {"step_budget": count_primitives(step_j, names),
            "admission_budget": adm}


def _replay(*, n_per_phase=16, n_families=12, prefix_backend="chain",
            quiet=False):
    """One full four-phase replay against the given fingerprint-index
    backend; returns the per-phase stats + ratios + budgets (no artifact
    I/O — ``run`` composes the chain and cuckoo legs into one file)."""
    import jax
    import jax.numpy as jnp

    from benchmarks.bench_attack import _attack_keys_for
    from repro.core import dhash
    from repro.core.struct_utils import replace
    from repro.serving import kvcache

    rng = np.random.default_rng(0)
    eng, cfg, sc = _build(prefix_backend=prefix_backend)
    families = [rng.integers(1, 127, size=4 * sc.page_size).tolist()
                for _ in range(n_families)]

    t_start = time.perf_counter()
    probe = _Probe(eng)
    # warmup: compile every path (decode, adopt, publish, evict, rehash)
    for prompt, tenant in _make_requests(rng, 4, families, sc):
        eng.submit(prompt, tenant=tenant)
    _drain(eng)
    probe.take()

    result = {}
    phases = {}
    c = _counters(eng)

    phases["steady"], c = _phase(
        eng, probe, _make_requests(rng, n_per_phase, families, sc), c)

    # collision attack on the fingerprint index: junk fingerprints that all
    # hash into bucket 0 of the CURRENT seed (attacker knows it); they carry
    # a sentinel page and are never adopted — their damage is the bucket-0
    # chain every admission lookup/publish must traverse
    ps = eng.kv.prefix
    tbl = ps.table.old
    if hasattr(tbl, "hfn_a"):   # two-hash backends: flood one side-A bucket
        atk = _attack_keys_for(tbl.hfn_a, int(tbl.nbuckets), N_ATTACK, rng)
    else:
        atk = _attack_keys_for(tbl.hfn, NBUCKETS, N_ATTACK, rng)
    table = ps.table
    ins = jax.jit(dhash.insert)
    for i in range(0, len(atk), 256):
        chunk = jnp.asarray(atk[i:i + 256], jnp.int32)
        table, _ = ins(table, chunk,
                       jnp.full(chunk.shape, 0x40000000, jnp.int32))
    eng.kv = replace(eng.kv, prefix=replace(ps, table=table))

    phases["attack"], c = _phase(
        eng, probe, _make_requests(rng, n_per_phase, families, sc), c)

    # response, live: fresh-seed rehash of the fingerprint index + page-table
    # rehash on the hot tenants — decode streams while both epochs advance
    eng.prefix_rehash(seed=20260809)
    eng.kv = kvcache.start_rehash(
        eng.kv, jnp.ones((sc.n_tenants,), bool))
    phases["rebuild"], c = _phase(
        eng, probe, _make_requests(rng, n_per_phase, families, sc), c)

    # force both rebuilds to quiescence before measuring the recovered band
    rehash = jax.jit(kvcache.rehash_step)
    for _ in range(2 * (4096 // eng.kv.prefix.table.chunk + sc.n_pages)):
        if not bool(jax.device_get(eng.kv.prefix.table.rebuilding)):
            break
        eng.kv = rehash(eng.kv)

    phases["recovered"], c = _phase(
        eng, probe, _make_requests(rng, n_per_phase, families, sc), c)

    wall = time.perf_counter() - t_start
    steady, attack, rec = (phases["steady"], phases["attack"],
                           phases["recovered"])
    result.update({
        "phases": phases,
        # decode-flatness floors (RATIO, higher is better): the model step
        # never touches the fingerprint index, so its p50 must not degrade
        # under attack or after recovery
        "attack_p50_ratio": steady["p50_ms"] / attack["p50_ms"],
        "recovered_p50_ratio": steady["p50_ms"] / rec["p50_ms"],
        # tail recovery (reported, NOT gated — extreme-quantile jitter):
        # the recovered decode p99 relative to the steady band
        "recovered_p99_ratio": steady["p99_ms"] / rec["p99_ms"],
        # descriptive (ungated): how hard the attack hit the cache-op tail
        # and how far the live rehash brought it back — the serving analogue
        # of bench_attack's before/under/after curve
        "attack_cacheop_x": attack["cacheop_p99_ms"] / steady["cacheop_p99_ms"],
        "recovered_cacheop_x": rec["cacheop_p99_ms"] / steady["cacheop_p99_ms"],
        "prefix_epochs": eng.prefix_epoch,
        "page_table_rehashes": eng.rehashes,
        "published_blocks": eng.publishes,
        "pool_exceeded": bool(eng.publishes > sc.n_pages),
        "alloc_fail_rate": eng.alloc_fails / max(
            sum(p["decode_steps"] for p in phases.values()), 1),
        "wall_us": wall * 1e6,
    })
    result.update(_budgets(eng, cfg, sc))

    # acceptance self-checks (the bench is the test for its own claims)
    assert result["pool_exceeded"], (
        "replay too short: published blocks must exceed n_pages so the "
        "eviction policy is actually exercised")
    assert eng.alloc_fails == 0, (
        f"{eng.alloc_fails} page allocations failed — eviction did not "
        f"keep up with pool pressure")
    assert eng.prefix_epoch >= 1, "fingerprint-index rehash never completed"
    assert (eng.kv.prefix.refcnt >= 0).all(), "refcount went negative"

    if not quiet:
        for name, p in phases.items():
            print(f"{prefix_backend}/{name:10s} decode p50 "
                  f"{p['p50_ms']:6.1f}ms p99 "
                  f"{p['p99_ms']:6.1f}ms | cacheop p50 "
                  f"{p['cacheop_p50_ms']:7.1f}ms p99 "
                  f"{p['cacheop_p99_ms']:7.1f}ms | miss {p['miss_rate']:.3f} "
                  f"evict {p['evictions']:3d}")
        victims = sum(p["evictions"] for p in phases.values())
        print(f"[{prefix_backend}] attack hits the cache-op tail "
              f"{result['attack_cacheop_x']:.1f}x; live rehash brings it to "
              f"{result['recovered_cacheop_x']:.1f}x of steady while decode "
              f"p50 stays {result['recovered_p50_ratio']:.2f}x; "
              f"{eng.publishes} blocks published into {sc.n_pages} pages "
              f"({victims} victims), 0 alloc failures; wall {wall:.0f}s")
    return result


def run(*, n_per_phase=16, n_families=12, prefix_backend="chain",
        quiet=False, out_path=None):
    result = {"band": 3.0, "ratio_band": 0.35}
    result.update(_replay(n_per_phase=n_per_phase, n_families=n_families,
                          prefix_backend=prefix_backend, quiet=quiet))

    # cuckoo leg: the SAME replay against the bounded-probe fingerprint
    # index.  The attack floods one side-A row where it cannot build a
    # chain, so decode flatness must hold there too — its attack-phase p50
    # ratio is gated (RATIOS, under this artifact's ratio_band) alongside
    # the chain leg's; cacheop figures stay descriptive.
    cuck = _replay(n_per_phase=n_per_phase, n_families=n_families,
                   prefix_backend="cuckoo", quiet=True)
    result["cuckoo"] = {
        "attack_p50_ratio": cuck["attack_p50_ratio"],
        "recovered_p50_ratio": cuck["recovered_p50_ratio"],
        "attack_cacheop_x": cuck["attack_cacheop_x"],
        "recovered_cacheop_x": cuck["recovered_cacheop_x"],
        "alloc_fail_rate": cuck["alloc_fail_rate"],
        "prefix_epochs": cuck["prefix_epochs"],
    }
    if not quiet:
        print(f"[summary] cuckoo leg decode flatness: attack p50 "
              f"{result['cuckoo']['attack_p50_ratio']:.2f}x, recovered "
              f"{result['cuckoo']['recovered_p50_ratio']:.2f}x")

    out = (pathlib.Path(out_path) if out_path
           else _REPO_ROOT / "BENCH_serve_macro.json")
    out.write_text(json.dumps(result, indent=2) + "\n")
    return result


if __name__ == "__main__":
    run()
