"""The paper's motivating scenario (§1): a hash-collision attack, live.

An adversary who knows the hash function floods keys that collide into one
bucket; lookups degrade from O(alpha) to O(N).  The dynamic response —
REBUILD with a fresh seeded function while serving continues — restores
throughput.  HT-Split structurally cannot respond: its bucket index is
``key mod 2^i`` forever (the paper's §2 criticism), so the attack sticks.

Measures per-phase lookup throughput: before attack / under attack /
after DHash's live rebuild (vs HT-Split which has no rebuild).

A third arm runs the same flood against the cuckoo backend, whose
two-table layout bounds EVERY lookup at width-1 lane probes — the
defense is structural, not reactive — and gates the measured worst-case
probe depth (``attack_probe_bound``) in the committed artifact.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import UNIVERSE
from repro.core import backend as backends
from repro.core import baselines as bl
from repro.core import dhash, hashing

I32 = jnp.int32
_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# the raw recovery factor is O(chain length) ~ 50-100x and jitters with it;
# the paper's claim is 1.4-6.2x, so the GATED ratio saturates at this cap —
# any healthy run pins it and only a recovery collapse moves the number
RECOVER_CAP = 4.0


def _tput(lookup_fn, keys, iters=5):
    out = lookup_fn(keys)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = lookup_fn(keys)
    jax.block_until_ready(out)
    return keys.size * iters / (time.perf_counter() - t0) / 1e6


def _attack_keys_for(hfn, nbuckets, count, rng):
    """Keys that all hash to bucket 0 under hfn (attacker knows the seed).

    Dedupe happens BEFORE truncation: sampling with replacement means the
    raw hit list can repeat a key, and ``unique(got[:count])`` used to
    return fewer than ``count`` keys on such draws — silently shrinking
    the attack (and the phase workloads derived from it) run-to-run.
    """
    got = np.empty((0,), np.int32)
    while got.size < count:
        cand = jnp.asarray(rng.integers(1, UNIVERSE, 1 << 16).astype(np.int32))
        b = hashing.bucket_of(hfn, cand, nbuckets)
        hit = np.asarray(cand)[np.asarray(b) == 0]
        got = np.unique(np.concatenate([got, hit.astype(np.int32)]))
    out = got[:count]
    assert out.size == count, (out.size, count)
    return out


def run(*, nbuckets=256, n_normal=4096, n_attack=2048, quiet=False,
        out_path=None):
    rng = np.random.default_rng(0)
    normal = rng.choice(UNIVERSE, n_normal, replace=False).astype(np.int32)
    rows = {}

    # --- DHash (chain backend: the paper's list buckets) ------------------
    d = dhash.make("chain", capacity=n_normal + n_attack + 1024,
                   nbuckets=nbuckets, chunk=1024, seed=1,
                   max_chain=n_attack + 64)
    ins = jax.jit(dhash.insert)
    for i in range(0, n_normal, 2048):
        d, _ = ins(d, jnp.asarray(normal[i:i + 2048], I32),
                   jnp.asarray(normal[i:i + 2048], I32))
    look = jax.jit(lambda d, k: dhash.lookup(d, k)[0])
    qk = jnp.asarray(rng.choice(normal, 4096), I32)
    rows["dhash_before"] = _tput(lambda k: look(d, k), qk)

    atk = _attack_keys_for(d.old.hfn, nbuckets, n_attack, rng)
    for i in range(0, len(atk), 2048):
        d, _ = ins(d, jnp.asarray(atk[i:i + 2048], I32),
                   jnp.asarray(atk[i:i + 2048], I32))
    mixed = jnp.asarray(np.concatenate([rng.choice(normal, 2048),
                                        rng.choice(atk, 2048)]), I32)
    rows["dhash_under_attack"] = _tput(lambda k: look(d, k), mixed)

    # live rebuild with a fresh secret seed; lookups keep running mid-rebuild
    d = dhash.rebuild_start(d, seed=20260714)
    step = jax.jit(dhash.rebuild_chunk)
    mid = None
    while not bool(jax.device_get(dhash.rebuild_done(d))):
        d = step(d)
        if mid is None:
            mid = _tput(lambda k: look(d, k), mixed, iters=2)
    d = dhash.rebuild_finish(d)
    rows["dhash_mid_rebuild"] = mid
    rows["dhash_after_rebuild"] = _tput(lambda k: look(d, k), mixed)

    # --- HT-Split: cannot change its function ------------------------------
    s = bl.split_make(1024, n_normal + n_attack + 1024, init_buckets=nbuckets,
                      seed=1, max_chain=n_attack + 64)
    sins = jax.jit(bl.split_insert)
    for i in range(0, n_normal, 2048):
        s, _ = sins(s, jnp.asarray(normal[i:i + 2048], I32),
                    jnp.asarray(normal[i:i + 2048], I32))
    slook = jax.jit(lambda s, k: bl.split_lookup(s, k)[0])
    rows["split_before"] = _tput(lambda k: slook(s, k), qk)
    # attacker keys for split: key = m * nbuckets (all land in bucket 0,
    # forever, regardless of resizes that keep i buckets pow2)
    atk_s = (np.arange(1, n_attack + 1, dtype=np.int32) * nbuckets * 4)
    for i in range(0, len(atk_s), 2048):
        s, _ = sins(s, jnp.asarray(atk_s[i:i + 2048], I32),
                    jnp.asarray(atk_s[i:i + 2048], I32))
    mixed_s = jnp.asarray(np.concatenate([rng.choice(normal, 2048),
                                          rng.choice(atk_s, 2048)]), I32)
    rows["split_under_attack"] = _tput(lambda k: slook(s, k), mixed_s)
    resize = jax.jit(bl.split_resize, static_argnums=1)
    s = resize(s, True)     # its only defence: double the buckets
    rows["split_after_resize"] = _tput(lambda k: slook(s, k), mixed_s)

    # --- cuckoo: the worst-case-BOUNDED arm ---------------------------------
    # Flooding one side-A bucket cannot build a chain: kick-out relocation
    # spreads the colliders across their side-B rows, and every lookup costs
    # at most width-1 lane probes BY CONSTRUCTION.  Measured, not assumed:
    # the max loc-derived probe depth over the mixed workload is gated below
    # as the structural `attack_probe_bound` row.
    c = dhash.make("cuckoo", capacity=n_normal + n_attack + 1024,
                   chunk=1024, seed=1)
    for i in range(0, n_normal, 2048):
        c, _ = ins(c, jnp.asarray(normal[i:i + 2048], I32),
                   jnp.asarray(normal[i:i + 2048], I32))
    rows["cuckoo_before"] = _tput(lambda k: look(c, k), qk)
    atk_c = _attack_keys_for(c.old.hfn_a, int(c.old.nbuckets), n_attack, rng)
    for i in range(0, len(atk_c), 2048):
        c, _ = ins(c, jnp.asarray(atk_c[i:i + 2048], I32),
                   jnp.asarray(atk_c[i:i + 2048], I32))
    mixed_c = jnp.asarray(np.concatenate([rng.choice(normal, 2048),
                                          rng.choice(atk_c, 2048)]), I32)
    rows["cuckoo_under_attack"] = _tput(lambda k: look(c, k), mixed_c)
    be = backends.get("cuckoo")
    found, _, loc = jax.jit(be.lookup)(c.old, mixed_c)
    cost = np.asarray(jax.device_get(be.probe_cost(c.old, mixed_c, found,
                                                   loc)))
    probe_bound = int(cost[np.asarray(jax.device_get(found))].max())

    # BENCH_attack.json: the before/under/after-rebuild recovery curve as
    # GATED ratios.  recover_ratio (RATIO leaf, capped — see RECOVER_CAP)
    # is the acceptance criterion: DHash's live rebuild must keep restoring
    # throughput after the collision attack.  The HT-Split arm is recorded
    # descriptively (its resize provably cannot recover — mod-2^i keys
    # re-collide — so gating it would pin a number we claim is meaningless).
    artifact = {
        "band": 3.0,
        "recover_ratio": min(
            rows["dhash_after_rebuild"] / rows["dhash_under_attack"],
            RECOVER_CAP),
        "mid_rebuild_x": (rows["dhash_mid_rebuild"]
                          / rows["dhash_under_attack"]),
        "attack_degrade_x": rows["dhash_before"] / rows["dhash_under_attack"],
        "split_stuck_x": (rows["split_after_resize"]
                          / rows["split_under_attack"]),
        # STRUCTURAL (exact, not banded): the cuckoo arm's measured
        # worst-case probe depth under the collision flood.  The layout
        # bounds it at width-1 lane probes; any increase is a layout
        # regression, not noise.
        "attack_probe_bound": probe_bound,
        "throughput_mlups": dict(rows),
    }
    out = (pathlib.Path(out_path) if out_path
           else _REPO_ROOT / "BENCH_attack.json")
    out.write_text(json.dumps(artifact, indent=2) + "\n")

    if not quiet:
        for k, v in rows.items():
            print(f"{k:24s} {v:9.3f} Mlookups/s")
        print(f"[summary] DHash recovers {rows['dhash_after_rebuild']/rows['dhash_under_attack']:.1f}x "
              f"via live rebuild; HT-Split stuck at "
              f"{rows['split_after_resize']/rows['split_under_attack']:.1f}x after resize "
              f"(mod-2^i keys re-collide); cuckoo probe depth capped at "
              f"{probe_bound} under the same flood")
    return rows


if __name__ == "__main__":
    run()
