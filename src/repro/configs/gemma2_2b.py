"""gemma2-2b [dense]: local/global alternating, attn+logit softcaps
[arXiv:2408.00118; hf]. long_500k SKIPPED (global layers full attention)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256000,
    block_pattern=("local", "attn"), window=4096,
    attn_softcap=50.0, logit_softcap=30.0, embed_scale=True,
)

def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, vocab_size=512, window=16,
                         dtype="float32", attn_chunk=32, loss_chunk=32)
