"""The paper's own workload as a config: a distributed DHash service
(lookup/insert/delete batches + continuous rebuild) sharded over the
production mesh. This is the arch the dry-run uses to lower the paper's
technique itself at scale."""
from dataclasses import dataclass


@dataclass(frozen=True)
class DHashServiceConfig:
    arch_id: str = "dhash-paper"
    backend: str = "linear"
    capacity_per_shard: int = 1 << 20     # ~1M entries per model shard
    chunk: int = 4096                     # rebuild chunk (hazard buffer)
    lookups_per_step: int = 1 << 16       # per shard
    updates_per_step: int = 1 << 13       # per shard (insert + delete each)
    route_cap_factor: float = 0.0         # 0 = overflow-proof cap=Q (baseline);
                                          # >0: cap = factor*Q/S (see §Perf)
    fwd_hazard: bool = False              # hazard via MIGRATED-slot forwarding


CONFIG = DHashServiceConfig()


def smoke() -> DHashServiceConfig:
    return DHashServiceConfig(capacity_per_shard=4096, chunk=256,
                              lookups_per_step=1024, updates_per_step=256)
