"""Serving engine: continuous batching over a DHash-paged KV cache.

Host-side driver (the framework's serve driver, deliverable (b)):
* fixed-slot continuous batching: finished sequences free their pages and
  the slot is re-admitted from the queue on the same step boundary;
* prefix-cache admission: longest cached block-prefix is reused;
* **live rehash**: when the page table's load factor or probe-length stats
  degrade (bursty admission / adversarial patterns), the engine starts a
  DHash rebuild; every decode step advances it one transition — serving
  latency is flat through the entire rehash (measured in
  benchmarks/bench_kvcache.py);
* **multi-tenant page tables** (``ServeConfig.n_tenants > 1``): the page
  table is a per-tenant ``dhash.make_stack`` (tenant = seq_id % n_tenants);
  decode resolves every tenant in one vmapped stack op and rehash epochs
  run independently per tenant — only the tenants whose load degraded pay a
  rebuild, with on-device epoch swaps.

The jitted step is fully paged: per layer, K/V of the new token are written
to the page pool and attention runs flash-decoding over DHash-resolved pages.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import dhash
from repro.core import policy as elastic
from repro.models import transformer
from repro.models.attention import project_qkv
from repro.models.layers import apply_rope, rms_norm, swiglu
from repro.serving import kvcache, prefix_cache
from repro.serving.kvcache import PagedKV

F32 = jnp.float32
I32 = jnp.int32


@dataclass(frozen=True)
class ServeConfig:
    max_seqs: int = 8
    page_size: int = 16
    n_pages: int = 512
    max_blocks: int = 64          # per-seq block bound (= max_len / page_size)
    max_new_tokens: int = 32
    rehash_load_factor: float = 0.7
    n_tenants: int = 1            # > 1: per-tenant page-table stack
                                  # (tenant = seq_id % n_tenants) with
                                  # INDEPENDENT live rehash epochs
    cap_factor: float = 2.0       # tenant-router cap c: send buffers are
                                  # [T, ceil(c*N/T) + spill_cap] (<= 0:
                                  # full width); overflow under skew rides
                                  # the spill slab in the same single pass
    spill_slack: float = 1.0      # spill-slab budget (kvcache.make):
                                  # 1.0 = overflow-proof; < 1 = compact
                                  # slab, drops counted exactly
    adaptive_cap: bool = False    # close the loop: a RouteCapController
                                  # (core.policy) adapts cap_factor off the
                                  # route_spill/route_drop feedback at poll
                                  # boundaries, replacing the static value
                                  # (multi-tenant only)
    prefix_cache: bool = False    # block-prefix reuse + LRU page eviction
                                  # (serving/eviction.py); opt-in — off,
                                  # admission always prefills from scratch
                                  # and frees reclaim every page
    prefix_backend: str = "linear"  # fingerprint-index backend (the macro
                                  # bench runs "chain" — bench_attack's
                                  # collision surface)
    prefix_capacity: int = 0      # fingerprint-index capacity (0: 4*n_pages)
    evict_batch: int = 8          # max victims per evict-on-pressure pass
    prefix_kw: tuple = ()         # extra backend kwargs as (key, value)
                                  # pairs (frozen-hashable), e.g.
                                  # (("nbuckets", 64),) for chain


def paged_decode_step(params: dict, cfg: ArchConfig, kv: PagedKV,
                      seq_ids: jax.Array, tokens: jax.Array,
                      lengths: jax.Array, active: jax.Array,
                      n_blocks: int):
    """One decode step for all slots. tokens/lengths/active: [B].
    Returns (logits [B, V], kv')."""
    x = transformer.embed(tokens[:, None], params["embed"], scale=cfg.embed_scale)
    positions = lengths[:, None]                            # [B,1]
    stack = params["attn_stack"]
    flags = transformer._attn_flags(cfg)
    safe_ids = jnp.where(active, seq_ids, 0)

    # page-table work is layer-independent: allocate the new block (if the
    # position opens one) and resolve the write target ONCE
    ps = kv.page_size
    blk, off = lengths // ps, lengths % ps
    kv, _ = kvcache.alloc_pages(kv, safe_ids, blk, active & (off == 0))
    pages_w, found_w = kvcache.resolve_blocks_at(kv, safe_ids, blk)
    pg = jnp.where(found_w & active, pages_w, kv.n_pages)   # OOB -> dropped

    def body(carry, sl):
        x, pool_k, pool_v = carry
        p, fl, layer = sl
        h = rms_norm(x, p["ln1"])
        qkn = (p["q_norm"], p["k_norm"]) if cfg.qk_norm else None
        q, k, v = project_qkv(h, p["wq"], p["wk"], p["wv"], qk_norm_scale=qkn)
        q = apply_rope(q, positions, fl["theta"])
        k = apply_rope(k, positions, fl["theta"])
        pool_k = pool_k.at[layer, pg, off].set(k[:, 0], mode="drop")
        pool_v = pool_v.at[layer, pg, off].set(v[:, 0], mode="drop")
        kv2 = kvcache.replace(kv, pool_k=pool_k, pool_v=pool_v)
        o = kvcache.paged_decode_attention(
            kv2, layer, q[:, 0], safe_ids, lengths + 1, n_blocks,
            window=fl["window"], softcap=cfg.attn_softcap)
        x = x + jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None]
        h2 = rms_norm(x, p["ln2"])
        y = swiglu(h2, p["wg"], p["wu"], p["wd"])
        return (x + y, pool_k, pool_v), None

    n = len(flags["window"])
    (x, pool_k, pool_v), _ = jax.lax.scan(
        body, (x, kv.pool_k, kv.pool_v),
        (stack, flags, jnp.arange(n, dtype=I32)))
    kv = kvcache.replace(kv, pool_k=pool_k, pool_v=pool_v)
    x = rms_norm(x, params["final_norm"])
    w = transformer.unembed_matrix(params, cfg)
    logits = jnp.einsum("bsd,dv->bsv", x, w).astype(F32)[:, 0]
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, kv


@dataclass
class ServingEngine:
    params: dict
    cfg: ArchConfig
    sc: ServeConfig
    kv: PagedKV = None
    queue: list = field(default_factory=list)     # list[(seq_id, prompt np.array)]
    finished: dict = field(default_factory=dict)  # seq_id -> list[int]
    rehashes: int = 0
    router_spills: int = 0        # cumulative tenant-router overflow keys
    router_drops: int = 0         # cumulative keys a compact slab dropped
    cap_ctl: elastic.RouteCapController | None = None  # adaptive cap loop
    cache_lookups: int = 0        # prefix-cache: blocks probed at admission
    cache_hits: int = 0           # prefix-cache: blocks adopted
    publishes: int = 0            # prefix-cache: blocks published
    _next_id: int = 1

    def __post_init__(self):
        c, s = self.cfg, self.sc
        self.kv = kvcache.make(c.n_layers, s.page_size, s.n_pages,
                               c.n_kv_heads, c.head_dim,
                               max_blocks=s.max_blocks, dtype=jnp.dtype(c.dtype),
                               n_tenants=s.n_tenants, cap_factor=s.cap_factor,
                               spill_slack=s.spill_slack,
                               prefix_cache=s.prefix_cache,
                               prefix_backend=s.prefix_backend,
                               prefix_capacity=s.prefix_capacity or None,
                               evict_batch=s.evict_batch,
                               prefix_kw=dict(s.prefix_kw))
        self._tenant_epochs0 = (np.asarray(
            jax.device_get(self.kv.table.epoch)) if s.n_tenants > 1 else None)
        # armed hysteresis latches for the elastic rehash trigger
        # (core.policy.rehash_wanted): a hot tenant rehashes once per load
        # excursion instead of on every poll above the threshold
        self._armed = True
        self._tenant_armed = np.ones((s.n_tenants,), bool)
        if s.n_tenants > 1:
            # one fused poll -> ONE host sync per decode step (live/tomb
            # loads + router spill/drop counters + rebuilding flags +
            # epochs)
            self._tenant_poll = jax.jit(lambda kv: (
                *kvcache.table_health(kv), kv.route_spill, kv.route_drop,
                kv.table.rebuilding, kv.table.epoch))
            if s.adaptive_cap:
                # spill-feedback adaptive cap: the controller walks
                # cap_factor along its geometric ladder off the SAME poll;
                # q_ref is the worst routed batch the engine issues
                # (free_sequences routes max_blocks keys per finished seq)
                self.cap_ctl = elastic.RouteCapController(
                    n_shards=s.n_tenants,
                    q_ref=s.max_seqs * s.max_blocks,
                    cap_factor=s.cap_factor, spill_slack=s.spill_slack)
        else:
            self._single_poll = jax.jit(lambda kv: (
                *kvcache.table_health(kv), kv.table.rebuilding,
                dhash.rebuild_done(kv.table)))
        b = s.max_seqs
        self.seq_ids = np.zeros((b,), np.int32)
        self.lengths = np.zeros((b,), np.int32)
        self.active = np.zeros((b,), bool)
        self.cur_tok = np.zeros((b,), np.int32)
        self.new_count = np.zeros((b,), np.int32)
        self.outputs: dict[int, list[int]] = {}
        self._step = jax.jit(partial(paged_decode_step, cfg=self.cfg,
                                     n_blocks=s.max_blocks))
        self._rehash = jax.jit(kvcache.rehash_step)
        self._free = jax.jit(kvcache.free_sequences, static_argnums=2)
        if s.prefix_cache:
            self._adopt = jax.jit(kvcache.adopt_prefix)
            self._publish = jax.jit(kvcache.publish_blocks)
            # fixed [max_blocks*ps] token pad -> [max_blocks] fingerprints:
            # one compile regardless of prompt length
            self._fps = jax.jit(lambda toks: prefix_cache.prefix_fingerprints(
                toks[None, :], s.page_size)[0])

    # -- request lifecycle ---------------------------------------------------
    def submit(self, prompt: list[int], tenant: int | None = None) -> int:
        """Queue a prompt; optional ``tenant`` pins the request to a tenant
        by advancing the id to the right residue class (ids stay unique and
        increasing — the partition is still ``seq_id % n_tenants``)."""
        sid = self._next_id
        if tenant is not None and self.sc.n_tenants > 1:
            sid += (tenant - sid) % self.sc.n_tenants
        self._next_id = sid + 1
        self.queue.append((sid, np.asarray(prompt, np.int32)))
        return sid

    def _admit(self):
        for slot in np.where(~self.active)[0]:
            if not self.queue:
                break
            sid, prompt = self.queue.pop(0)
            self._prefill(slot, sid, prompt)

    def _prefill(self, slot: int, sid: int, prompt: np.ndarray):
        """Prefill token-by-token through the paged step (simple, exact).
        Only THIS slot is active during its prefill — other in-flight
        sequences must not advance (their KV writes are masked and their
        lengths untouched).

        With the prefix cache enabled, admission first adopts the longest
        cached block-prefix (pages mapped + pinned, prefill skips those
        tokens — the cache hit is paid back as admission latency), and the
        freshly prefilled full blocks are published at the end.  Only
        blocks covered by ``prompt[:-1]`` take part: the last prompt token
        always runs through the decode step, so a published block is
        always fully written."""
        self.seq_ids[slot] = sid
        self.new_count[slot] = 0
        self.outputs[sid] = []
        start, fps, valid = 0, None, None
        if self.kv.prefix is not None:
            ps = self.sc.page_size
            n_pub = (len(prompt) - 1) // ps
            pad = np.zeros((self.sc.max_blocks * ps,), np.int32)
            pad[:len(prompt)] = prompt
            fps = self._fps(jnp.asarray(pad))
            valid = jnp.arange(self.sc.max_blocks) < n_pub
            self.kv, n_adopt, _ = self._adopt(
                self.kv, jnp.asarray(sid, np.int32), fps, valid)
            n_adopt = int(jax.device_get(n_adopt))
            self.cache_lookups += n_pub
            self.cache_hits += n_adopt
            start = n_adopt * ps
        self.lengths[slot] = start
        saved = self.active.copy()
        self.active[:] = False
        self.active[slot] = True
        for t in prompt[start:-1]:
            self.cur_tok[slot] = t
            self._run_slots(sample=False)
        if self.kv.prefix is not None:
            self.kv, n_ok = self._publish(
                self.kv, jnp.asarray(sid, np.int32), fps, valid)
            self.publishes += int(jax.device_get(n_ok))
        self.active = saved
        self.active[slot] = True
        self.cur_tok[slot] = prompt[-1]

    # -- stepping -------------------------------------------------------------
    def _run_slots(self, sample: bool = True):
        sids = jnp.asarray(self.seq_ids)
        toks = jnp.asarray(self.cur_tok)
        lens = jnp.asarray(self.lengths)
        act = jnp.asarray(self.active)
        logits, self.kv = self._step(self.params, kv=self.kv, seq_ids=sids,
                                     tokens=toks, lengths=lens, active=act)
        self.lengths = np.where(self.active, self.lengths + 1, self.lengths)
        self.kv = self._rehash(self.kv)            # background rebuild progress
        if sample:
            nxt = np.asarray(jax.device_get(jnp.argmax(logits, -1)), np.int32)
            return nxt
        return None

    def step(self):
        """One engine step: decode all active slots, harvest, admit."""
        self._admit()
        if not self.active.any():
            return False
        nxt = self._run_slots(sample=True)
        for slot in np.where(self.active)[0]:
            sid = int(self.seq_ids[slot])
            self.outputs[sid].append(int(nxt[slot]))
            self.cur_tok[slot] = nxt[slot]
            self.new_count[slot] += 1
            done = (self.new_count[slot] >= self.sc.max_new_tokens
                    or int(self.lengths[slot]) >= self.sc.max_blocks * self.sc.page_size - 1)
            if done:
                self.finished[sid] = self.outputs.pop(sid)
                self.kv = self._free(self.kv, jnp.asarray([sid], np.int32),
                                     self.sc.max_blocks)
                self.active[slot] = False
        self._maybe_rehash()
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or self.active.any()) and steps < max_steps:
            self.step()
            steps += 1
        return steps

    # -- live rehash ----------------------------------------------------------
    def _maybe_rehash(self):
        """Elastic rehash trigger (``core.policy.rehash_wanted``): fire when
        the live load crosses ``sc.rehash_load_factor`` OR tombstone churn
        (freed sequences) crosses the reclaim threshold, latched by an
        armed-hysteresis bit so a hot table rehashes once per excursion —
        the manual always-refire load check this replaces restarted a
        same-shape rehash on every poll while the load stayed high."""
        if self.sc.n_tenants > 1:
            return self._maybe_rehash_tenants()
        live, tomb, rebuilding, done = (
            np.asarray(x)
            for x in jax.device_get(self._single_poll(self.kv)))
        if bool(rebuilding):
            if bool(done):
                self.kv = kvcache.replace(
                    self.kv, table=dhash.rebuild_finish(self.kv.table))
                self.rehashes += 1
            return
        want, self._armed = elastic.rehash_wanted(
            float(live), float(tomb), self._armed, False,
            grow_load=self.sc.rehash_load_factor)
        if want:
            self.kv = kvcache.replace(
                self.kv, table=dhash.rebuild_start(self.kv.table,
                                                   seed=self.rehashes + 1))

    def _maybe_rehash_tenants(self):
        """Per-tenant elastic rehash over the page-table stack: each tenant
        has its own armed latch, so only tenants whose load/tombstone churn
        degraded start an epoch — and only once per excursion.  Completed
        epochs swap on-device inside ``kvcache.rehash_step``; no host-side
        finish is needed.  ``rehashes`` counts COMPLETIONS (epoch deltas
        across the stack) — the same semantics as the single-tenant path.
        The same poll surfaces the router spill/drop counters
        (``router_spills`` / ``router_drops``) so skewed tenant traffic
        leaning on the spill slab is observable separately from table
        load — and, with ``sc.adaptive_cap``, FEEDS the
        ``RouteCapController``: the controller walks ``cap_factor`` along
        its watermarked ladder and the new cap (static table metadata) is
        applied via ``kvcache.replace`` — recompiles are bounded by the
        ladder's finite value set."""
        loads, tombs, spill, drop, rebuilding, epochs = (
            np.asarray(x) for x in jax.device_get(self._tenant_poll(self.kv)))
        self.router_spills = int(spill.sum())
        self.router_drops = int(drop.sum())
        self.rehashes = int((epochs - self._tenant_epochs0).sum())
        if self.cap_ctl is not None:
            new_cap = self.cap_ctl.update(self.router_spills,
                                          self.router_drops)
            if new_cap != self.kv.cap_factor:
                self.kv = kvcache.replace(self.kv, cap_factor=new_cap)
        want, self._tenant_armed = elastic.rehash_wanted(
            loads, tombs, self._tenant_armed, rebuilding,
            grow_load=self.sc.rehash_load_factor)
        if want.any():
            self.kv = kvcache.start_rehash(self.kv, jnp.asarray(want))

    # -- prefix cache ---------------------------------------------------------
    def prefix_rehash(self, seed: int | None = None):
        """Start a live re-seed rehash of the fingerprint index (collision
        attack response); decode steps drive it via ``kvcache.rehash_step``
        and the epoch swaps on-device when done."""
        self.kv = kvcache.start_prefix_rehash(self.kv, seed=seed)

    @property
    def prefix_epoch(self) -> int:
        """Completed fingerprint-index rehash epochs."""
        return int(jax.device_get(self.kv.prefix.table.epoch))

    @property
    def evictions(self) -> int:
        """Cumulative prefix-cache pages evicted under pool pressure."""
        return int(jax.device_get(self.kv.prefix.evictions))

    @property
    def alloc_fails(self) -> int:
        """Masked page allocations that found no free page (must stay 0
        while eviction keeps up with demand)."""
        return int(jax.device_get(self.kv.alloc_fail))
