# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark aggregator: one entry per paper artifact + framework benches.

  fig2_throughput     paper Fig 2  (throughput vs batch width x load factor)
  fig3_rebuild        paper Fig 3  (rebuild time vs N)
  fig4_portability    paper Fig 4  (implementation-variant axis, see module)
  s62_oversubscribe   paper §6.2   (scaling past saturation)
  s1_attack           paper §1     (collision attack + live rebuild recovery
                                    + the bounded-probe cuckoo arm)
  moe_router          framework    (DHash hash-router rebalancing)
  kvcache_rehash      framework    (decode latency through live rehash)

CSV contract: name,us_per_call,derived
"""
from __future__ import annotations

import sys
import time


def _row(name: str, us: float, derived: str):
    print(f"{name},{us:.2f},{derived}")
    sys.stdout.flush()


def fig2_throughput():
    from benchmarks.bench_throughput import run
    for alpha in (20, 200):
        for mix in ((90, 5, 5), (80, 10, 10)):
            rows = run(alpha, mix, qs=(1024, 4096), steps=5, quiet=True)
            for name, a, m0, q, mops in rows:
                _row(f"fig2/{name}/a{a}/m{m0}/q{q}", 1.0 / mops,
                     f"{mops:.3f}Mops_s")


def fig3_rebuild():
    from benchmarks.bench_rebuild import run
    for name, n, dt in run(ns=(2_000, 8_000, 32_000), quiet=True):
        _row(f"fig3/{name}/n{n}", dt * 1e6, f"{dt*1e3:.1f}ms_full_rebuild")


def fig4_portability():
    from benchmarks.bench_portability import run
    for name, q, mops in run(alpha=20, qs=(1024, 4096), quiet=True):
        _row(f"fig4/{name}/q{q}", 1.0 / mops if mops else 0.0,
             f"{mops:.3f}Mops_s")


def s62_oversubscribe():
    from benchmarks.bench_oversubscribe import run
    for name, q, mops in run(qs=(512, 4096, 16384), quiet=True):
        _row(f"s62/{name}/q{q}", 1.0 / mops, f"{mops:.3f}Mops_s")


def elastic():
    from benchmarks.bench_oversubscribe import run_elastic
    r = run_elastic(quiet=True)
    for ph in ("steady", "burst", "drain", "recovered"):
        _row(f"elastic/{ph}/q{r['q']}", 1.0 / r[ph]["mops"],
             f"{r[ph]['mops']:.3f}Mops_s")
    _row("elastic/cliff", 0.0, f"{r['cliff_ratio']:.2f}x_of_steady")
    _row("elastic/resizes", 0.0,
         f"{r['grows']}grows_{r['shrinks']}shrinks_{r['flaps']}flaps_"
         f"load{r['final_load']:.2f}")


def s1_attack():
    from benchmarks.bench_attack import run
    r = run(quiet=True)
    for k, v in r.items():
        _row(f"attack/{k}", 1.0 / max(v, 1e-9), f"{v:.3f}Mlookups_s")


def moe_router():
    from benchmarks.bench_moe_router import run
    r = run(quiet=True)
    _row("moe_router/plain", r["t_plain"] * 1e6,
         f"imbalance{r['imb_before']:.2f}")
    _row("moe_router/dhash_overrides", r["t_table"] * 1e6,
         f"imbalance{r['imb_after']:.2f}")


def kvcache_rehash():
    from benchmarks.bench_kvcache import run
    r = run(quiet=True)
    _row("kvcache/decode_baseline", r["baseline_p50"] * 1e3, "p50")
    _row("kvcache/decode_during_rehash", r["during_p50"] * 1e3,
         f"p50_over_{r['rehash_steps']}steps")


def serve_macro():
    from benchmarks.bench_serve_macro import run
    r = run(quiet=True)
    for ph, p in r["phases"].items():
        _row(f"serve_macro/{ph}/p50", p["p50_ms"] * 1e3,
             f"p99_{p['p99_ms']:.1f}ms_miss{p['miss_rate']:.3f}_"
             f"evict{p['evictions']}")
    _row("serve_macro/attack_cacheop", 0.0,
         f"{r['attack_cacheop_x']:.1f}x_of_steady")
    _row("serve_macro/recovered_p99_ratio", 0.0,
         f"{r['recovered_p99_ratio']:.2f}")


def fused_probe():
    from benchmarks.bench_rebuild import run_fused_probe
    r = run_fused_probe(batch=4096, n_items=3_000, quiet=True)
    for name in ("fused", "unfused"):
        _row(f"fused_probe/{name}/q{r['batch']}", r[name]["wall_us"],
             f"{r[name]['sort']}sorts_{r[name]['pallas_call']}pallas")
    _row("fused_probe/pass_ratio", 0.0, f"{r['pass_ratio']:.2f}x_fewer_passes")


def fused_writes():
    from benchmarks.bench_rebuild import run_fused_writes
    r = run_fused_writes(batch=4096, n_items=3_000, quiet=True)
    for name in ("fused", "jnp"):
        _row(f"fused_writes/{name}/q{r['batch']}", r[name]["wall_us"],
             f"{r[name]['passes']}passes")
    _row("fused_writes/pass_ratio", 0.0,
         f"{r['pass_ratio']:.2f}x_fewer_passes")


def growth_escape():
    from benchmarks.bench_rebuild import run_growth_escape
    r = run_growth_escape(batch=4096, n_items=3_000, quiet=True)
    for g in (1, 4, 16):
        row = r[f"growth_{g}x"]
        _row(f"growth_escape/{g}x/q{r['batch']}", row["wall_us"],
             f"{row['escape_rate']:.4f}_escape_rate")


def chain_fused():
    from benchmarks.bench_rebuild import run_chain_fused
    r = run_chain_fused(batch=4096, n_items=3_000, quiet=True)
    for name in ("fused", "jnp"):
        _row(f"chain_fused/{name}/q{r['batch']}", r[name]["wall_us"],
             f"{r[name]['passes']}passes")
    _row("chain_fused/pass_ratio", 0.0,
         f"{r['pass_ratio']:.2f}x_fewer_passes")


def table_stack():
    from benchmarks.bench_rebuild import run_table_stack
    r = run_table_stack(quiet=True)
    for name in ("stacked", "looped"):
        _row(f"table_stack/{name}/t{r['n_tables']}", r[name]["wall_us"],
             f"{r[name]['passes']}launches")
    _row("table_stack/pass_ratio", 0.0,
         f"{r['pass_ratio']:.2f}x_fewer_launches")


def routed_stack():
    from benchmarks.bench_rebuild import run_routed_stack
    r = run_routed_stack(quiet=True)
    for t in (8, 64):
        row = r[f"t{t}"]
        _row(f"routed_stack/t{t}/cap{row['cap']}+slab{row['spill_cap']}",
             row["wall_us"],
             f"{row['send_bytes_ratio']:.2f}x_fewer_send_bytes_"
             f"{row['overflow_rate']:.4f}_overflow_"
             f"{row['dropped_rate']:.4f}_dropped")
        # the adversarial 100%-skew arm: single-pass even under total skew
        _row(f"routed_stack/t{t}/adversarial", 0.0,
             f"{row['adversarial_sorts']}sorts_"
             f"{row['adversarial_pallas_calls']}pallas_no_retry")


TABLES = [fig2_throughput, fig3_rebuild, fig4_portability, s62_oversubscribe,
          elastic, s1_attack, moe_router, kvcache_rehash, serve_macro,
          fused_probe, fused_writes, chain_fused, growth_escape, table_stack,
          routed_stack]


def quick() -> None:
    """CI smoke mode: exercises the perf harness end-to-end in minutes —
    the fused-probe, fused-writes, chain-fused, growth-escape, table-stack,
    routed-stack (zipf + adversarial 100%-skew slab arms), elastic-burst,
    collision-attack, and serving-macro
    acceptance checks (pass counts + escape rates + resize/flap counts +
    recovery/latency ratios + their BENCH_*.json artifacts) plus a tiny
    fig3 rebuild sweep and a shrunk §6.2 oversubscription sweep so perf
    code can't silently rot."""
    print("name,us_per_call,derived")
    t0 = time.time()
    fused_probe()
    fused_writes()
    chain_fused()
    growth_escape()
    table_stack()
    routed_stack()
    elastic()
    s1_attack()                 # writes BENCH_attack.json (recover_ratio)
    serve_macro()               # writes BENCH_serve_macro.json
    from benchmarks.bench_oversubscribe import run as oversub_run
    for name, q, mops in oversub_run(alpha=20, qs=(512, 2048), quiet=True):
        _row(f"s62/{name}/q{q}", 1.0 / mops, f"{mops:.3f}Mops_s")
    from benchmarks.bench_rebuild import run as rebuild_run
    for name, n, dt in rebuild_run(ns=(2_000,), quiet=True):
        _row(f"fig3/{name}/n{n}", dt * 1e6, f"{dt*1e3:.1f}ms_full_rebuild")
    print(f"# quick done in {time.time()-t0:.0f}s", flush=True)


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fused-probe acceptance + tiny fig3")
    args = ap.parse_args(argv)
    if args.quick:
        quick()
        return
    print("name,us_per_call,derived")
    for fn in TABLES:
        t0 = time.time()
        fn()
        print(f"# {fn.__name__} done in {time.time()-t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
