"""Jit'd wrappers around the Pallas kernels: padding, sorting, fallback.

``probe_lookup`` is a drop-in accelerated equivalent of
``ref.probe_lookup_ref`` (and of ``buckets.linear_lookup``'s inner loop);
``ordered_lookup_fused`` is the accelerated rebuild-epoch path (one sort +
one pallas_call for the whole old->hazard->new ordered check);
``probe_insert`` / ``probe_delete`` are the accelerated write paths (claim
or location kernel + one scatter); ``ordered_delete_fused`` is the
rebuild-epoch delete (the same probe2 kernel's location outputs drive the
old/new tombstones and the hazard kill); ``extract_chunk_fused`` is the
rebuild chunk scan; ``twochoice_lookup`` / ``twochoice_insert`` /
``twochoice_delete`` bring the 2-choice backend onto the same
sort + scalar-prefetch treatment (both row choices of a query expand into
two entries of ONE sorted batch).

Exactness contract shared by all of them: queries whose probe window escapes
the VMEM-resident slab (hash skew), or whose insert claim collides across
tiles, are recomputed by the jnp oracle fallback — which is gated behind
``jax.lax.cond`` so the steady state (no escapes) never pays for it.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.probe import (QT, SLAB, _tc_rowslab, extract_tiles,
                                 probe2_tiles, probe_insert_tiles,
                                 probe_lookup_tiles, tc_insert_tiles,
                                 tc_lookup_tiles)

I32 = jnp.int32
LIVE, TOMB, MIGRATED = 1, 2, 3


def _pad_to(x: jax.Array, n: int, fill=0):
    return jnp.pad(x, (0, n - x.shape[0]), constant_values=fill)


def _pad_table(arrays, c: int, max_probes: int):
    """Pad table arrays with a wrapped copy (probes never wrap in-kernel),
    then to a SLAB multiple plus one spare block (block s+1 always valid);
    padding slots are EMPTY so probes terminate there."""
    cpad = -(-(c + max_probes) // SLAB) * SLAB + SLAB
    return tuple(_pad_to(jnp.concatenate([a, a[:max_probes]]), cpad)
                 for a in arrays)


def _sort_pad_queries(order, qpad, *arrays):
    """Apply the shared sort and pad to a QT multiple by REPLICATING the last
    sorted element (edge padding).  Padding with a constant sentinel would
    break the slab math: an h0=0 pad in a tile whose slab base is > 0 reads
    complete=False and drags min-based tile bases to block 0, firing the
    oracle fallback on every non-QT-multiple batch.  Edge pads stay inside
    their tile's slab, and their results land in the discarded tail of the
    unsort (positions >= q)."""
    return tuple(jnp.pad(a[order], (0, qpad - a.shape[0]), mode="edge")
                 for a in arrays)


def _tile_base(h0_sorted: jax.Array, tiles: int, cpad: int, *,
               already_sorted: bool) -> jax.Array:
    """Per-tile slab block index, clipped so block s+1 stays in range."""
    t = h0_sorted.reshape(tiles, QT)
    base = (t[:, 0] if already_sorted else t.min(axis=1)) // SLAB
    return jnp.minimum(base.astype(I32), cpad // SLAB - 2)


@partial(jax.jit, static_argnames=("max_probes", "interpret"))
def probe_lookup(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                 h0: jax.Array, qkey: jax.Array, *, max_probes: int = 64,
                 interpret: bool = True):
    """Batched linear-probe lookup. Returns (found[Q], val[Q]).

    Args:
      tkey/tval/tstate: table arrays [C].
      h0: start slot per query (hash(key) % C), [Q].
      qkey: query keys [Q].
    """
    c = tkey.shape[0]
    q = qkey.shape[0]
    tk, tv, ts = _pad_table((tkey, tval, tstate), c, max_probes)

    # ONE sort: queries ordered by start slot so tiles hit contiguous slabs
    order = jnp.argsort(h0)
    qpad = -(-q // QT) * QT
    h0s, qks = _sort_pad_queries(order, qpad, h0, qkey)
    tiles = qpad // QT
    slab_base = _tile_base(h0s, tiles, tk.shape[0], already_sorted=True)

    found_s, val_s, _loc_s, complete_s = probe_lookup_tiles(
        tk, tv, ts, h0s, qks, slab_base, max_probes=max_probes,
        interpret=interpret)

    # fallback: recompute incomplete queries with the jnp oracle — gated so
    # the no-skew steady state skips the oracle pass entirely (h0s is already
    # in [0, C), so no re-mod either; the oracle wraps internally).
    need = ~complete_s

    def fallback(fv):
        f0, v0 = fv
        fb_f, fb_v = ref.probe_lookup_ref(tkey, tval, tstate, h0s, qks,
                                          max_probes)
        return jnp.where(need, fb_f, f0), jnp.where(need, fb_v, v0)

    found_s, val_s = jax.lax.cond(need.any(), fallback, lambda fv: fv,
                                  (found_s, val_s))

    # unsort (order permutes [0, q); tail positions are padding)
    found = jnp.zeros((q,), jnp.bool_).at[order].set(found_s[:q])
    val = jnp.zeros((q,), I32).at[order].set(val_s[:q])
    return found, val


@partial(jax.jit, static_argnames=("max_probes", "interpret"))
def ordered_lookup(old_tables, new_tables, hazard_key, hazard_val, hazard_live,
                   h0_old, h0_new, qkey, *, max_probes: int = 64,
                   interpret: bool = True):
    """UNFUSED rebuild-epoch lookup: old table -> hazard buffer -> new table
    (the paper's Lemma 4.1 order), each table pass via its own sort +
    pallas_call.  Kept as the comparison baseline for ``ordered_lookup_fused``
    (see bench_rebuild's fused=on|off axis)."""
    f_old, v_old = probe_lookup(*old_tables, h0_old, qkey,
                                max_probes=max_probes, interpret=interpret)
    eq = (qkey[:, None] == hazard_key[None, :]) & hazard_live[None, :]
    f_hz = eq.any(-1)
    v_hz = jnp.take(hazard_val, jnp.argmax(eq, axis=-1))
    f_new, v_new = probe_lookup(*new_tables, h0_new, qkey,
                                max_probes=max_probes, interpret=interpret)
    found = f_old | f_hz | f_new
    val = jnp.where(f_old, v_old, jnp.where(f_hz, v_hz, v_new))
    return found, val


@partial(jax.jit, static_argnames=("max_probes", "interpret"))
def ordered_lookup_fused(old_tables, new_tables, hazard_key, hazard_val,
                         hazard_live, h0_old, h0_new, qkey, *,
                         max_probes: int = 64, interpret: bool = True):
    """FUSED rebuild-epoch lookup: ONE argsort (keyed on h0_old) and ONE
    pallas_call emit the Lemma-4.1-ordered result for both tables plus the
    hazard buffer.  The new-table slab is anchored per tile at the tile's min
    h0_new; queries whose new-table window escapes it AND that the old table
    / hazard buffer did not resolve fall back to the jnp oracle (gated —
    free when nothing escapes)."""
    c_old = old_tables[0].shape[0]
    c_new = new_tables[0].shape[0]
    q = qkey.shape[0]
    old_p = _pad_table(old_tables, c_old, max_probes)
    new_p = _pad_table(new_tables, c_new, max_probes)

    # the ONE shared sort, keyed on the old table's start slot
    order = jnp.argsort(h0_old)
    qpad = -(-q // QT) * QT
    h0os, h0ns, qks = _sort_pad_queries(order, qpad, h0_old, h0_new, qkey)
    tiles = qpad // QT
    slab2 = jnp.stack([
        _tile_base(h0os, tiles, old_p[0].shape[0], already_sorted=True),
        _tile_base(h0ns, tiles, new_p[0].shape[0], already_sorted=False),
    ])

    found_s, val_s, complete_s, *_write_outs = probe2_tiles(
        old_p, new_p, hazard_key, hazard_val, hazard_live.astype(I32),
        h0os, h0ns, qks, slab2, max_probes=max_probes, interpret=interpret)

    need = ~complete_s

    def fallback(fv):
        f0, v0 = fv
        fb_f, fb_v = ref.ordered_lookup_ref(
            old_tables, new_tables, hazard_key, hazard_val, hazard_live,
            h0os, h0ns, qks, max_probes)
        return jnp.where(need, fb_f, f0), jnp.where(need, fb_v, v0)

    found_s, val_s = jax.lax.cond(need.any(), fallback, lambda fv: fv,
                                  (found_s, val_s))

    found = jnp.zeros((q,), jnp.bool_).at[order].set(found_s[:q])
    val = jnp.zeros((q,), I32).at[order].set(val_s[:q])
    return found, val


@partial(jax.jit, static_argnames=("max_probes", "interpret"))
def probe_insert(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                 h0: jax.Array, keys: jax.Array, vals: jax.Array,
                 mask: jax.Array, *, max_probes: int = 64,
                 interpret: bool = True):
    """Batched linear-probe INSERT via the claim kernel + one scatter.

    Caller contract: ``mask`` is winner-filtered (at most one True per
    distinct key; use ``buckets.batch_winners``).  Set semantics: ok=False if
    the key is already LIVE or no free slot exists within ``max_probes``.

    Escape hatches (all exact, resolved by the gated jnp fallback):
      * probe window escapes the 2-block slab (``complete=False``);
      * two tiles claim the same physical slot (the padded table holds a
        wrapped copy of the first ``max_probes`` slots, so the same physical
        slot can be claimed under two padded positions) — first claimant in
        sort order keeps it, the loser escapes.

    Returns (tkey', tval', tstate', ok[Q]).
    """
    c = tkey.shape[0]
    q = keys.shape[0]
    tk, ts = _pad_table((tkey, tstate), c, max_probes)

    order = jnp.argsort(h0)
    qpad = -(-q // QT) * QT
    h0s, qks, qvs = _sort_pad_queries(order, qpad, h0, keys, vals)
    qms = _pad_to(mask[order], qpad, fill=False)
    tiles = qpad // QT
    slab_base = _tile_base(h0s, tiles, tk.shape[0], already_sorted=True)

    present_s, claim_s, complete_s = probe_insert_tiles(
        tk, ts, h0s, qks, qms.astype(I32), slab_base,
        max_probes=max_probes, interpret=interpret)

    # resolve claims globally: claims live in padded coordinates within
    # [h0, h0 + max_probes) ⊂ [0, C + max_probes), so % C maps the wrapped
    # region back onto the physical table; first claimant (sort order) wins.
    claimed = complete_s & (claim_s >= 0)
    phys = jnp.where(claimed, claim_s % c, c)
    sidx = jnp.arange(qpad, dtype=I32)
    first = jnp.full((c,), qpad, I32).at[phys].min(sidx, mode="drop")
    keep = claimed & (first[jnp.clip(phys, 0, c - 1)] == sidx)
    conflict = claimed & ~keep

    wp = jnp.where(keep, phys, c)
    tkey2 = tkey.at[wp].set(qks, mode="drop")
    tval2 = tval.at[wp].set(qvs, mode="drop")
    tstate2 = tstate.at[wp].set(LIVE, mode="drop")
    ok_s = keep

    need = qms & (~complete_s | conflict)

    def fallback(op):
        k, v, s, ok = op
        fb_k, fb_v, fb_s, fb_ok = ref.probe_insert_ref(
            k, v, s, h0s, qks, qvs, need, max_probes)
        return fb_k, fb_v, fb_s, ok | fb_ok

    tkey2, tval2, tstate2, ok_s = jax.lax.cond(
        need.any(), fallback, lambda op: op, (tkey2, tval2, tstate2, ok_s))

    ok = jnp.zeros((q,), jnp.bool_).at[order].set(ok_s[:q])
    return tkey2, tval2, tstate2, ok


@partial(jax.jit, static_argnames=("max_probes", "interpret"))
def probe_delete(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                 h0: jax.Array, keys: jax.Array, mask: jax.Array, *,
                 max_probes: int = 64, interpret: bool = True):
    """Batched linear-probe DELETE: the location-emitting lookup kernel +
    ONE tombstone scatter (no second probe pass).

    Caller contract: ``mask`` is winner-filtered (at most one True per
    distinct key; use ``buckets.batch_winners``), so distinct masked keys
    occupy distinct slots and the scatter cannot conflict.  Queries whose
    probe window escapes the resident slab fall back to the jnp oracle
    (gated — free when nothing escapes).

    Returns (tstate', ok[Q]).
    """
    c = tkey.shape[0]
    q = keys.shape[0]
    tk, tv, ts = _pad_table((tkey, tval, tstate), c, max_probes)

    order = jnp.argsort(h0)
    qpad = -(-q // QT) * QT
    h0s, qks = _sort_pad_queries(order, qpad, h0, keys)
    qms = _pad_to(mask[order], qpad, fill=False)
    tiles = qpad // QT
    slab_base = _tile_base(h0s, tiles, tk.shape[0], already_sorted=True)

    found_s, _val_s, loc_s, complete_s = probe_lookup_tiles(
        tk, tv, ts, h0s, qks, slab_base, max_probes=max_probes,
        interpret=interpret)

    # loc is in padded coordinates within [h0, h0 + max_probes); % C maps the
    # wrapped region back onto the physical table
    ok_s = qms & found_s
    tstate2 = tstate.at[jnp.where(ok_s, loc_s % c, c)].set(TOMB, mode="drop")

    need = qms & ~complete_s

    def fallback(op):
        s, ok = op
        fb_s, fb_ok = ref.probe_delete_ref(tkey, tval, s, h0s, qks, need,
                                           max_probes)
        return fb_s, ok | fb_ok

    tstate2, ok_s = jax.lax.cond(need.any(), fallback, lambda op: op,
                                 (tstate2, ok_s))

    ok = jnp.zeros((q,), jnp.bool_).at[order].set(ok_s[:q])
    return tstate2, ok


@partial(jax.jit, static_argnames=("max_probes", "interpret"))
def ordered_delete_fused(old_tables, new_tables, hazard_key, hazard_val,
                         hazard_live, h0_old, h0_new, keys, mask, *,
                         max_probes: int = 64, interpret: bool = True):
    """FUSED rebuild-epoch delete (paper Alg. 5): ONE argsort + ONE
    pallas_call (the probe2 kernel's location outputs) resolve the ordered
    check, then three scatters land the result — tombstone the old-table
    slot, or clear the hazard live bit (LOGICALLY_REMOVED on an in-flight
    entry; landing drops it), or tombstone the new-table slot.

    Caller contract: ``mask`` is winner-filtered.  Returns
    (old_state', new_state', hazard_live', ok[Q]).
    """
    c_old = old_tables[0].shape[0]
    c_new = new_tables[0].shape[0]
    ch = hazard_key.shape[0]
    q = keys.shape[0]
    old_p = _pad_table(old_tables, c_old, max_probes)
    new_p = _pad_table(new_tables, c_new, max_probes)

    order = jnp.argsort(h0_old)
    qpad = -(-q // QT) * QT
    h0os, h0ns, qks = _sort_pad_queries(order, qpad, h0_old, h0_new, keys)
    qms = _pad_to(mask[order], qpad, fill=False)
    tiles = qpad // QT
    slab2 = jnp.stack([
        _tile_base(h0os, tiles, old_p[0].shape[0], already_sorted=True),
        _tile_base(h0ns, tiles, new_p[0].shape[0], already_sorted=False),
    ])

    (_found_s, _val_s, complete_s, fold_s, locold_s, hzidx_s,
     locnew_s) = probe2_tiles(
        old_p, new_p, hazard_key, hazard_val, hazard_live.astype(I32),
        h0os, h0ns, qks, slab2, max_probes=max_probes, interpret=interpret)

    # ordered landing: old hit > hazard hit > new hit (at most one fires)
    f_hz = hzidx_s >= 0
    ok_old = qms & fold_s
    ok_hz = qms & complete_s & ~fold_s & f_hz
    ok_new = qms & complete_s & ~fold_s & ~f_hz & (locnew_s >= 0)

    old_state = old_tables[2].at[
        jnp.where(ok_old, locold_s % c_old, c_old)].set(TOMB, mode="drop")
    new_state = new_tables[2].at[
        jnp.where(ok_new, locnew_s % c_new, c_new)].set(TOMB, mode="drop")
    kill = jnp.zeros_like(hazard_live).at[
        jnp.where(ok_hz, hzidx_s, ch)].set(True, mode="drop")
    hz_live = hazard_live & ~kill
    ok_s = ok_old | ok_hz | ok_new

    need = qms & ~complete_s

    def fallback(op):
        os_, ns_, hl_, ok = op
        fb_os, ok_o = ref.probe_delete_ref(old_tables[0], old_tables[1],
                                           os_, h0os, qks, need, max_probes)
        pend = need & ~ok_o
        eq = (qks[:, None] == hazard_key[None, :]) & hl_[None, :]
        hz_hit = eq.any(-1) & pend
        kill2 = jnp.zeros_like(hl_).at[
            jnp.where(hz_hit, jnp.argmax(eq, axis=-1), ch)].set(
            True, mode="drop")
        fb_ns, ok_n = ref.probe_delete_ref(new_tables[0], new_tables[1],
                                           ns_, h0ns, qks, pend & ~hz_hit,
                                           max_probes)
        return fb_os, fb_ns, hl_ & ~kill2, ok | ok_o | hz_hit | ok_n

    old_state, new_state, hz_live, ok_s = jax.lax.cond(
        need.any(), fallback, lambda op: op,
        (old_state, new_state, hz_live, ok_s))

    ok = jnp.zeros((q,), jnp.bool_).at[order].set(ok_s[:q])
    return old_state, new_state, hz_live, ok


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def extract_chunk_fused(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                        cursor: jax.Array, *, chunk: int,
                        interpret: bool = True):
    """Rebuild chunk scan via the extract kernel: ONE pallas_call reads the
    slab window at ``cursor`` and compacts the live entries on-device; ONE
    scatter marks them MIGRATED.  Requires ``chunk <= SLAB`` (the caller
    gates; dhash chunks default to 256).

    Returns (tstate', hkeys[chunk], hvals[chunk], hlive[chunk] bool,
    new_cursor) — identical set contents to the jnp scan, with the hazard
    entries compacted to the front.
    """
    assert chunk <= SLAB, f"chunk {chunk} exceeds slab window {SLAB}"
    c = tkey.shape[0]
    cpad = -(-c // SLAB) * SLAB + SLAB
    tk, tv, ts = (_pad_to(a, cpad) for a in (tkey, tval, tstate))
    block = jnp.minimum(cursor // SLAB, cpad // SLAB - 2).astype(I32)

    hk, hv, hl, mig = extract_tiles(tk, tv, ts, block, cursor, chunk=chunk,
                                    capacity=c, interpret=interpret)

    pos = cursor + jnp.arange(chunk, dtype=I32)
    tstate2 = tstate.at[jnp.where(mig != 0, pos, c)].set(
        MIGRATED, mode="drop")
    new_cursor = jnp.minimum(cursor + chunk, c).astype(I32)
    return tstate2, hk, hv, hl != 0, new_cursor


# ---------------------------------------------------------------------------
# twochoice: both row choices expand into one sorted entry batch
# ---------------------------------------------------------------------------

def _tc_pad_rows(arrays, b: int, slab_r: int):
    """Row-pad [B, W] tables to a SLAB_R multiple plus one spare block
    (pad rows are EMPTY, so they can never satisfy a lookup or a claim)."""
    bpad = -(-b // slab_r) * slab_r + slab_r
    return tuple(jnp.pad(a, ((0, bpad - b), (0, 0))) for a in arrays)


def _tc_expand_sort(rows_a, rows_b, bpad: int, slab_r: int, *arrays):
    """Expand per-query arrays into the [2Q] entry batch (a-rows first, then
    b-rows), apply the ONE shared row-index sort + edge pad, and derive the
    per-tile row-block map.  Returns (order, epad, rows_sorted,
    sorted_arrays, slab_base) — the lookup and insert paths share this so
    their slab math can never diverge."""
    rows = jnp.concatenate([rows_a, rows_b])
    dup = [jnp.concatenate([a, a]) for a in arrays]
    e = rows.shape[0]
    order = jnp.argsort(rows)
    epad = -(-e // QT) * QT
    rs, *sorted_arrays = _sort_pad_queries(order, epad, rows, *dup)
    tiles = epad // QT
    base = rs.reshape(tiles, QT)[:, 0] // slab_r
    slab_base = jnp.minimum(base.astype(I32), bpad // slab_r - 2)
    return order, epad, rs, sorted_arrays, slab_base


@partial(jax.jit, static_argnames=("interpret",))
def twochoice_lookup(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                     rows_a: jax.Array, rows_b: jax.Array, qkey: jax.Array,
                     *, interpret: bool = True):
    """Fused twochoice lookup: the 2Q entry expansion (each query's two row
    choices), ONE argsort keyed on the row index, ONE pallas_call of the
    W-wide row-gather kernel, then a per-query recombine (a-row priority —
    the same tie-break as ``buckets.twochoice_lookup``).

    Returns (found[Q], val[Q], loc[Q] flat slot index or -1) — ``loc`` is
    reused by ``twochoice_delete`` so deleting never probes twice.
    """
    b, w = tkey.shape
    q = qkey.shape[0]
    e = 2 * q
    slab_r = _tc_rowslab(w)
    tk, tv, ts = _tc_pad_rows((tkey, tval, tstate), b, slab_r)
    order, epad, rs, (qks,), slab_base = _tc_expand_sort(
        rows_a, rows_b, tk.shape[0], slab_r, qkey)

    found_s, val_s, loc_s, complete_s = tc_lookup_tiles(
        tk, tv, ts, rs, qks, slab_base, interpret=interpret)

    need = ~complete_s

    def fallback(fvl):
        f0, v0, l0 = fvl
        fb_f, fb_v, fb_l = ref.tc_row_lookup_ref(tkey, tval, tstate, rs, qks)
        return (jnp.where(need, fb_f, f0), jnp.where(need, fb_v, v0),
                jnp.where(need, fb_l, l0))

    found_s, val_s, loc_s = jax.lax.cond(need.any(), fallback, lambda x: x,
                                         (found_s, val_s, loc_s))

    fe = jnp.zeros((e,), jnp.bool_).at[order].set(found_s[:e])
    ve = jnp.zeros((e,), I32).at[order].set(val_s[:e])
    le = jnp.full((e,), -1, I32).at[order].set(loc_s[:e])
    f_a, f_b = fe[:q], fe[q:]
    found = f_a | f_b
    val = jnp.where(f_a, ve[:q], ve[q:])
    loc = jnp.where(f_a, le[:q], jnp.where(f_b, le[q:], -1))
    return found, val, loc


@partial(jax.jit, static_argnames=("max_rounds", "interpret"))
def twochoice_insert(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                     rows_a: jax.Array, rows_b: jax.Array, keys: jax.Array,
                     vals: jax.Array, mask: jax.Array, *,
                     max_rounds: int = 8, interpret: bool = True):
    """Batched twochoice INSERT via the claim kernel + one scatter.

    Caller contract: ``mask`` is winner-filtered.  Set semantics: ok=False
    if the key is LIVE in either row or both rows are full.  The kernel
    claims per row-entry; here the a-claim shadows the b-claim of the same
    query, cross-tile slot collisions keep the first claimant (batch order),
    and everything else — escaped windows, lost claims, locally-full rows —
    re-runs on the jnp oracle (gated).

    Returns (tkey', tval', tstate', ok[Q]).
    """
    b, w = tkey.shape
    q = keys.shape[0]
    e = 2 * q
    nslots = b * w
    slab_r = _tc_rowslab(w)
    tk, ts = _tc_pad_rows((tkey, tstate), b, slab_r)
    order, epad, rs, (qks,), slab_base = _tc_expand_sort(
        rows_a, rows_b, tk.shape[0], slab_r, keys)
    qms = _pad_to(jnp.concatenate([mask, mask])[order], epad, fill=False)

    present_s, claim_s, complete_s = tc_insert_tiles(
        tk, ts, rs, qks, qms.astype(I32), slab_base, interpret=interpret)

    pe = jnp.zeros((e,), jnp.bool_).at[order].set(present_s[:e])
    ce = jnp.full((e,), -1, I32).at[order].set(claim_s[:e])
    cpl = jnp.zeros((e,), jnp.bool_).at[order].set(complete_s[:e])
    present = pe[:q] | pe[q:]
    compl2 = cpl[:q] & cpl[q:]     # presence known for BOTH rows
    c_a, c_b = ce[:q], ce[q:]
    cand = jnp.where(compl2 & ~present,
                     jnp.where(c_a >= 0, c_a, c_b), -1)

    claimed = cand >= 0
    phys = jnp.where(claimed, cand, nslots)
    idx = jnp.arange(q, dtype=I32)
    first = jnp.full((nslots,), q, I32).at[phys].min(idx, mode="drop")
    keep = claimed & (first[jnp.clip(phys, 0, nslots - 1)] == idx)

    wp = jnp.where(keep, phys, nslots)
    tkey2 = tkey.reshape(-1).at[wp].set(keys, mode="drop").reshape(b, w)
    tval2 = tval.reshape(-1).at[wp].set(vals, mode="drop").reshape(b, w)
    tstate2 = tstate.reshape(-1).at[wp].set(LIVE, mode="drop").reshape(b, w)
    ok = keep

    need = mask & ~keep & ~present

    def fallback(op):
        k, v, s, ok0 = op
        fb_k, fb_v, fb_s, fb_ok = ref.tc_insert_ref(
            k, v, s, rows_a, rows_b, keys, vals, need, max_rounds)
        return fb_k, fb_v, fb_s, ok0 | fb_ok

    tkey2, tval2, tstate2, ok = jax.lax.cond(
        need.any(), fallback, lambda op: op, (tkey2, tval2, tstate2, ok))
    return tkey2, tval2, tstate2, ok


@partial(jax.jit, static_argnames=("interpret",))
def twochoice_delete(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                     rows_a: jax.Array, rows_b: jax.Array, keys: jax.Array,
                     mask: jax.Array, *, interpret: bool = True):
    """Batched twochoice DELETE: reuses the fused lookup's location output —
    one kernel pass, one tombstone scatter, never a second probe (the jnp
    ``twochoice_delete`` re-gathers both rows to find the slot again).

    Caller contract: ``mask`` is winner-filtered.  Returns (tstate', ok[Q]).
    """
    b, w = tkey.shape
    found, _val, loc = twochoice_lookup(tkey, tval, tstate, rows_a, rows_b,
                                        keys, interpret=interpret)
    ok = mask & found
    tstate2 = tstate.reshape(-1).at[jnp.where(ok, loc, b * w)].set(
        TOMB, mode="drop").reshape(b, w)
    return tstate2, ok
