"""The paper's headline scenario as a narrative demo: a hash-collision
attack on a serving-critical table, detected and defused by a live rebuild.

    PYTHONPATH=src python examples/attack_defense.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dhash, hashing
from repro.core.engine import DHashEngine


def tput(eng, keys, iters=5):
    f, _ = eng.lookup(keys)
    t0 = time.perf_counter()
    for _ in range(iters):
        f, _ = eng.lookup(keys)
    jax.block_until_ready(f)
    return keys.size * iters / (time.perf_counter() - t0) / 1e6


def main():
    rng = np.random.default_rng(0)
    eng = DHashEngine(dhash.make("chain", capacity=16384, nbuckets=256,
                                 chunk=1024, seed=1, max_chain=4096))
    normal = np.unique(rng.integers(1, 10_000_000, 6000).astype(np.int32))
    for i in range(0, len(normal), 1024):
        ks = normal[i:i + 1024]
        eng.step(ks[:16], ks, ks * 2, np.zeros(1, np.int32),
                 del_mask=np.zeros(1, bool))
    q = jnp.asarray(rng.choice(normal, 4096), jnp.int32)
    print(f"[healthy ] {tput(eng, q):8.2f} Mlookups/s "
          f"({eng.count()} items over 256 buckets)")

    # the adversary knows the seed: craft keys for bucket 0
    cand = jnp.asarray(np.unique(rng.integers(10_000_000, 2**31 - 1, 1 << 18)
                                 .astype(np.int32)))
    b = np.asarray(hashing.bucket_of(eng.state.old.hfn, cand, 256))
    atk = np.asarray(cand)[b == 0][:3000]
    for i in range(0, len(atk), 1024):
        ks = atk[i:i + 1024]
        eng.step(ks[:16], ks, ks, np.zeros(1, np.int32),
                 del_mask=np.zeros(1, bool))
    qm = jnp.asarray(np.concatenate([rng.choice(normal, 2048),
                                     rng.choice(atk, 2048)]), jnp.int32)
    print(f"[attacked] {tput(eng, qm):8.2f} Mlookups/s "
          f"({len(atk)} adversarial keys in one bucket)")

    # defense: live rebuild with a fresh secret seed
    eng.request_rebuild(seed=int(time.time()) | 1)
    n = 0
    while bool(jax.device_get(eng.state.rebuilding)):
        eng.step(qm[:64], np.zeros(1, np.int32), np.zeros(1, np.int32),
                 np.zeros(1, np.int32), ins_mask=np.zeros(1, bool),
                 del_mask=np.zeros(1, bool))
        n += 1
        if bool(jax.device_get(dhash.rebuild_done(eng.state))):
            eng.state = dhash.rebuild_finish(eng.state)
            break
    print(f"[rebuild ] completed across {n} serving steps — no step blocked")
    print(f"[defended] {tput(eng, qm):8.2f} Mlookups/s (epoch {int(eng.state.epoch)})")


if __name__ == "__main__":
    main()
