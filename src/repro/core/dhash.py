"""DHash: a dynamic hash table whose hash function can be rebuilt live.

This is the paper's core contribution (§3-§4) mapped to the SPMD/XLA model:

* The table state is a pytree carrying the *old* table, the *new* table
  (pre-allocated with the replacement hash function), and a **hazard buffer**
  — the batched analogue of the paper's ``rebuild_cur`` global pointer.  A
  rebuild migrates a *chunk* of entries per transition instead of one node
  (single-node granularity would waste the vector units; the hazard period is
  a chunk-sized window).

* ``rebuild_extract`` removes a chunk from the old table into the hazard
  buffer (entries are then in *neither* table — the hazard period, Fig 1c);
  ``rebuild_land`` inserts the hazard entries into the new table and clears
  the buffer (Fig 1d).  The engine interleaves full-rate lookup/insert/delete
  batches between these transitions, which is exactly the concurrency
  structure of the paper; dataflow ordering plays the role of the paper's
  smp_wmb/smp_rmb pairs.

* Every operation performs the paper's **ordered check** (Lemma 4.1/4.2):
      old table  →  hazard buffer  →  new table.
  Lookup priority is old > hazard > new; delete tries old, then marks hazard
  entries dead (the LOGICALLY_REMOVED bit on an in-flight node, Alg. 5 line
  75 — a killed hazard entry is silently dropped at landing), then tries new.
  Insert targets the new table iff a rebuild is in progress (Lemma 4.3/4.4);
  duplicate keys discovered at landing are dropped in favour of the new
  table's copy (Alg. 3 lines 34-36).

* The epoch swap (Alg. 3 lines 41-46) is a host-level transition
  (``rebuild_finish``) because old/new may differ in static shape; for
  shape-preserving rebuilds there is a fully-jitted ``finish_same_shape``.
  The paper's ``synchronize_rcu`` grace periods are step boundaries: a
  transition consumes state_t and produces state_{t+1}, so no reader of
  state_t can observe state_{t+1} — the grace period is free.

Progress-guarantee analogue (DESIGN.md §2): a step's latency is bounded and
independent of rebuild progress — rebuild costs O(chunk) per transition,
never a stop-the-world O(N) pause.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import buckets, hashing
from repro.core.struct_utils import pytree_dataclass, replace

I32 = jnp.int32


@pytree_dataclass(meta_fields=("backend", "chunk", "fwd_hazard", "fused"))
class DHashState:
    backend: str
    chunk: int                  # hazard buffer capacity (entries per rebuild chunk)
    fwd_hazard: bool            # linear backend: resolve hazard hits via
                                # MIGRATED-slot forwarding (zero extra passes)
    fused: bool                 # route the FULL op surface (lookup/insert/
                                # delete + rebuild extract and land) through
                                # the Pallas kernels (kernels/ops.py) for
                                # ALL THREE backends; every backend's
                                # rebuild-epoch lookup AND delete is ONE
                                # sort + ONE pallas_call (old+hazard+new in
                                # one pass, two-level tile map for grown new
                                # tables; chain probes its arena-sorted
                                # segments and compacts when the dirty tail
                                # outgrows the dense window)
    old: Any                    # active table (backend pytree)
    new: Any                    # target table; meaningful only while rebuilding
    hazard_key: jax.Array       # [chunk] i32
    hazard_val: jax.Array       # [chunk] i32
    hazard_live: jax.Array      # [chunk] bool
    cursor: jax.Array           # scalar i32 - scan position in old table
    rebuilding: jax.Array       # scalar bool
    epoch: jax.Array            # scalar i32


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------

def _make_table(backend: str, capacity: int, seed, *, load_factor: float = 0.75,
                max_probes: int = 64, bucket_width: int = 8, max_chain: int = 64,
                nbuckets: int | None = None):
    """Build an empty backend table sized for ``capacity`` live entries."""
    rng = np.random.default_rng(seed)
    if backend == "linear":
        slots = _next_pow2(int(capacity / load_factor) + 1)
        return buckets.linear_make(slots, hashing.fresh("mix32", rng), max_probes=max_probes)
    if backend == "twochoice":
        nb = _next_pow2(int(capacity / (load_factor * bucket_width)) + 1)
        return buckets.twochoice_make(nb, hashing.fresh("mix32", rng),
                                      hashing.fresh("mix32", rng), width=bucket_width)
    if backend == "chain":
        nb = nbuckets if nbuckets is not None else _next_pow2(max(capacity // 16, 1))
        return buckets.chain_make(nb, capacity, hashing.fresh("mix32", rng), max_chain=max_chain)
    raise ValueError(f"unknown backend {backend!r}")


def _next_pow2(x: int) -> int:
    return 1 << (int(x) - 1).bit_length()


FUSED_BACKENDS = ("linear", "twochoice", "chain")


def _fused_default(backend: str) -> bool:
    """Resolve ``fused=None``: the DHASH_FUSED env var (``on``/``1``/``true``)
    turns the Pallas kernels on for every backend that supports them — the
    hook CI's fused=on|off test matrix uses to drive the whole suite through
    the fused paths without touching call sites."""
    flag = os.environ.get("DHASH_FUSED", "off").lower()
    return flag in ("1", "on", "true") and backend in FUSED_BACKENDS


def make(backend: str = "linear", capacity: int = 1024, *, chunk: int = 256,
         seed: int = 0, fwd_hazard: bool = False, fused: bool | None = None,
         **kw) -> DHashState:
    if fused is None:
        # fwd_hazard is the alternative (jnp) hazard-resolution strategy; the
        # env default must not silently shadow it with the fused branch
        fused = _fused_default(backend) and not fwd_hazard
    if fused and backend not in FUSED_BACKENDS:
        raise ValueError(f"fused kernels are not implemented for backend "
                         f"{backend!r}; choose from {FUSED_BACKENDS}")
    old = _make_table(backend, capacity, seed, **kw)
    new = _make_table(backend, capacity, seed + 1, **kw)
    # distinct buffers per field (aliased leaves break jit buffer donation)
    return DHashState(backend=backend, chunk=chunk, fwd_hazard=fwd_hazard,
                      fused=fused, old=old, new=new,
                      hazard_key=jnp.zeros((chunk,), I32),
                      hazard_val=jnp.zeros((chunk,), I32),
                      hazard_live=jnp.zeros((chunk,), bool),
                      cursor=jnp.asarray(0, I32), rebuilding=jnp.asarray(False),
                      epoch=jnp.asarray(0, I32))


# ---------------------------------------------------------------------------
# the ordered check: old -> hazard -> new (Lemma 4.1)
# ---------------------------------------------------------------------------

def _hazard_probe(d: DHashState, keys: jax.Array):
    eq = (keys[:, None] == d.hazard_key[None, :]) & d.hazard_live[None, :]
    found = eq.any(-1)
    val, _ = buckets._argpick(eq, jnp.broadcast_to(d.hazard_val[None, :], eq.shape))
    return found, jnp.where(found, val, 0)


def lookup(d: DHashState, keys: jax.Array):
    """Batched lookup honouring the rebuild protocol. Returns (found, vals).

    With ``fused`` both branches run on the Pallas kernels; the
    rebuild-epoch branch is the fused probe2 kernel (linear) or its
    twochoice analogue: ONE argsort + ONE pallas_call cover the whole
    old -> hazard -> new ordered check, with a two-level tile map keeping
    grown new tables resident."""

    def fast(dd: DHashState):
        if dd.fused:
            if dd.backend == "twochoice":
                f, v, _ = buckets.twochoice_lookup_fused(dd.old, keys)
                return f, v
            if dd.backend == "chain":
                f, v, _ = buckets.chain_lookup_fused(dd.old, keys)
                return f, v
            return buckets.linear_lookup_fused(dd.old, keys)
        f, v, _ = buckets.lookup(dd.old, keys)
        return f, v

    def slow(dd: DHashState):
        if dd.fused and dd.backend == "chain":
            # single-pass chain_probe2 over the arena-sorted segments: one
            # sort + one pallas_call for the whole ordered check, dirty
            # tails of both arenas resolved by dense windows
            return buckets.chain_ordered_lookup_fused(
                dd.old, dd.new, dd.hazard_key, dd.hazard_val,
                dd.hazard_live, keys)
        if dd.fused and dd.backend == "twochoice":
            # single-pass probe2 analogue: one sort + one tc_probe2
            # pallas_call for the whole ordered check (was two composed
            # fused row-gather passes around a separate hazard compare)
            return buckets.twochoice_ordered_lookup_fused(
                dd.old, dd.new, dd.hazard_key, dd.hazard_val,
                dd.hazard_live, keys)
        if dd.fused:
            from repro.kernels import ops
            h0_old = hashing.bucket_of(dd.old.hfn, keys, dd.old.capacity)
            h0_new = hashing.bucket_of(dd.new.hfn, keys, dd.new.capacity)
            return ops.ordered_lookup_fused(
                (dd.old.key, dd.old.val, dd.old.state),
                (dd.new.key, dd.new.val, dd.new.state),
                dd.hazard_key, dd.hazard_val, dd.hazard_live,
                h0_old, h0_new, keys, max_probes=dd.old.max_probes)
        if dd.fwd_hazard and dd.backend == "linear":
            # beyond-paper: the old-table probe already passes over the
            # MIGRATED slots of the in-flight chunk, so the hazard check is
            # a forwarding index, not a second pass (§Perf dhash-service)
            f_old, v_old, _, mig = buckets.linear_lookup_fwd(dd.old, keys)
            base = dd.cursor - dd.chunk
            hz_idx = mig - base
            inwin = (mig >= 0) & (hz_idx >= 0) & (hz_idx < dd.chunk)
            safe = jnp.clip(hz_idx, 0, dd.chunk - 1)
            f_hz = inwin & dd.hazard_live[safe] & (dd.hazard_key[safe] == keys)
            v_hz = dd.hazard_val[safe]
        else:
            f_old, v_old, _ = buckets.lookup(dd.old, keys)   # (1) old table
            f_hz, v_hz = _hazard_probe(dd, keys)             # (2) rebuild_cur
        f_new, v_new, _ = buckets.lookup(dd.new, keys)       # (3) new table
        found = f_old | f_hz | f_new
        val = jnp.where(f_old, v_old, jnp.where(f_hz, v_hz, v_new))
        return found, val

    return jax.lax.cond(d.rebuilding, slow, fast, d)


def _ins_table(dd: DHashState, t, kk, vv, mm):
    """Backend-dispatched insert (shared by user inserts and hazard
    landing, so a fused state's rebuild landing runs the claim kernel).
    A fused chain table additionally re-sorts its arena when the insert
    pushes the dirty tail past the dense-window coverage
    (``chain_maybe_compact`` — cond-gated, free on the clean steady state),
    which is what keeps chain landings and user inserts on the kernel
    path."""
    if dd.fused and dd.backend == "twochoice":
        return buckets.twochoice_insert_fused(t, kk, vv, mm)
    if dd.fused and dd.backend == "chain":
        t2, ok = buckets.chain_insert_fused(t, kk, vv, mm)
        return buckets.chain_maybe_compact(t2), ok
    if dd.fused:
        return buckets.linear_insert_fused(t, kk, vv, mm)
    return buckets.insert(t, kk, vv, mm)


def insert(d: DHashState, keys: jax.Array, vals: jax.Array, mask: jax.Array | None = None):
    """Batched insert (set semantics: ok=False if key already present in the
    *target* table — Alg. 6). Returns (state', ok)."""
    if mask is None:
        mask = jnp.ones(keys.shape, bool)

    def fast(dd: DHashState):
        t, ok = _ins_table(dd, dd.old, keys, vals, mask)
        return replace(dd, old=t), ok

    def slow(dd: DHashState):
        t, ok = _ins_table(dd, dd.new, keys, vals, mask)
        return replace(dd, new=t), ok

    return jax.lax.cond(d.rebuilding, slow, fast, d)


def delete(d: DHashState, keys: jax.Array, mask: jax.Array | None = None):
    """Batched delete honouring the ordered check (Alg. 5). Returns (state', ok).

    With ``fused`` the write path is kernel-backed end to end: the fast
    branch tombstones via the location-emitting probe kernel, and BOTH
    fused backends' rebuild-epoch branches are ONE argsort + ONE
    pallas_call (``ops.ordered_delete_fused`` for linear,
    ``ops.twochoice_ordered_delete`` for twochoice — the probe2 kernels'
    slot/hazard-index outputs drive the old tombstone, the hazard kill, and
    the new tombstone in a single pass)."""
    if mask is None:
        mask = jnp.ones(keys.shape, bool)

    def _del(dd: DHashState, t, kk, mm):
        if dd.fused:
            if dd.backend == "twochoice":
                return buckets.twochoice_delete_fused(t, kk, mm)
            if dd.backend == "chain":
                return buckets.chain_delete_fused(t, kk, mm)
            return buckets.linear_delete_fused(t, kk, mm)
        return buckets.delete(t, kk, mm)

    def fast(dd: DHashState):
        t, ok = _del(dd, dd.old, keys, mask)
        return replace(dd, old=t), ok

    def slow_fused_linear(dd: DHashState):
        from repro.kernels import ops
        winner = buckets.batch_winners(keys, mask)
        h0_old = hashing.bucket_of(dd.old.hfn, keys, dd.old.capacity)
        h0_new = hashing.bucket_of(dd.new.hfn, keys, dd.new.capacity)
        os_, ns_, hl, ok = ops.ordered_delete_fused(
            (dd.old.key, dd.old.val, dd.old.state),
            (dd.new.key, dd.new.val, dd.new.state),
            dd.hazard_key, dd.hazard_val, dd.hazard_live,
            h0_old, h0_new, keys, winner, max_probes=dd.old.max_probes)
        return replace(dd, old=replace(dd.old, state=os_),
                       new=replace(dd.new, state=ns_), hazard_live=hl), ok

    def slow_fused_twochoice(dd: DHashState):
        os_, ns_, hl, ok = buckets.twochoice_ordered_delete_fused(
            dd.old, dd.new, dd.hazard_key, dd.hazard_val, dd.hazard_live,
            keys, mask)
        return replace(dd, old=replace(dd.old, state=os_),
                       new=replace(dd.new, state=ns_), hazard_live=hl), ok

    def slow_fused_chain(dd: DHashState):
        os_, ns_, hl, ok = buckets.chain_ordered_delete_fused(
            dd.old, dd.new, dd.hazard_key, dd.hazard_val, dd.hazard_live,
            keys, mask)
        return replace(dd, old=replace(dd.old, astate=os_),
                       new=replace(dd.new, astate=ns_), hazard_live=hl), ok

    def slow(dd: DHashState):
        if dd.fused and dd.backend == "linear":
            return slow_fused_linear(dd)
        if dd.fused and dd.backend == "twochoice":
            return slow_fused_twochoice(dd)
        if dd.fused and dd.backend == "chain":
            return slow_fused_chain(dd)
        t_old, ok_old = _del(dd, dd.old, keys, mask)                   # (1) old
        pending = mask & ~ok_old
        # (2) hazard buffer: clear the live bit (LOGICALLY_REMOVED on the
        # in-flight node) - landing will drop it.
        eq = (keys[:, None] == dd.hazard_key[None, :]) & dd.hazard_live[None, :]
        hit_hz = eq.any(-1) & pending
        win_hz = buckets.batch_winners(keys, hit_hz) & hit_hz
        kill = (eq & win_hz[:, None]).any(0)
        hazard_live = dd.hazard_live & ~kill
        pending2 = pending & ~hit_hz
        t_new, ok_new = _del(dd, dd.new, keys, pending2)               # (3) new
        ok = ok_old | win_hz | ok_new
        return replace(dd, old=t_old, new=t_new, hazard_live=hazard_live), ok

    return jax.lax.cond(d.rebuilding, slow, fast, d)


# ---------------------------------------------------------------------------
# rebuild protocol
# ---------------------------------------------------------------------------

def rebuild_start(d: DHashState, new_table=None, *, seed: int | None = None) -> DHashState:
    """Host-level: begin a rebuild into ``new_table`` (fresh hash function).

    Caller contract (paper's rebuild_lock): no rebuild may be in progress.
    """
    if new_table is None:
        cap = buckets.capacity_of(d.old)
        if seed is None:
            seed = int(np.random.default_rng().integers(1 << 31))
        if d.backend == "linear":
            new_table = buckets.linear_make(cap, hashing.fresh("mix32", seed), d.old.max_probes)
        elif d.backend == "twochoice":
            rng = np.random.default_rng(seed)
            new_table = buckets.twochoice_make(d.old.nbuckets, hashing.fresh("mix32", rng),
                                               hashing.fresh("mix32", rng), width=d.old.width)
        else:
            new_table = buckets.chain_make(d.old.nbuckets, d.old.arena,
                                           hashing.fresh("mix32", seed), d.old.max_chain)
    if d.fused and d.backend == "chain":
        # freeze the old arena fully sorted (and tombstone-reclaimed) before
        # the cursor scan starts: the old side stays dirt-free for the whole
        # epoch (inserts target the new table), so every rebuild-epoch probe
        # keeps its segments kernel-resident.  Safe exactly here — the
        # cursor resets to 0, so node movement cannot skip the scan.
        d = replace(d, old=buckets.chain_compact_fused(d.old))
    return replace(d, new=new_table, cursor=jnp.asarray(0, I32),
                   rebuilding=jnp.asarray(True))


def rebuild_extract(d: DHashState) -> DHashState:
    """Pull the next chunk out of the old table into the hazard buffer.

    No-op unless rebuilding with an empty hazard buffer.  With ``fused`` the
    scan is the extract kernel (one pallas_call over the resident slab
    window + one MIGRATED scatter; hazard entries compacted on-device)
    instead of the jnp gather scan."""

    def go(dd: DHashState):
        if dd.fused and dd.backend == "linear":
            t, hk, hv, hl, cur = buckets.linear_extract_chunk_fused(
                dd.old, dd.cursor, dd.chunk)
        elif dd.fused and dd.backend == "twochoice":
            t, hk, hv, hl, cur = buckets.twochoice_extract_chunk_fused(
                dd.old, dd.cursor, dd.chunk)
        elif dd.fused and dd.backend == "chain":
            t, hk, hv, hl, cur = buckets.chain_extract_chunk_fused(
                dd.old, dd.cursor, dd.chunk)
        else:
            t, hk, hv, hl, cur = buckets.extract_chunk(dd.old, dd.cursor,
                                                       dd.chunk)
        return replace(dd, old=t, hazard_key=hk, hazard_val=hv,
                       hazard_live=hl, cursor=cur)

    can = d.rebuilding & ~d.hazard_live.any()
    return jax.lax.cond(can, go, lambda dd: dd, d)


def rebuild_land(d: DHashState) -> DHashState:
    """Insert hazard entries into the new table; duplicates lose to the copy
    already in the new table (Alg. 3 lines 34-36); entries killed while in
    hazard (delete during the hazard period) are dropped.

    With ``fused`` the landing runs through the SAME claim kernel as user
    inserts (``probe_insert`` / ``tc_insert``), so the whole rebuild epoch —
    extract -> land -> swap — stays on-device inside the jitted engine
    step."""

    def go(dd: DHashState):
        if dd.fused:
            t, _ok = _ins_table(dd, dd.new, dd.hazard_key, dd.hazard_val,
                                dd.hazard_live)
        else:
            t, _ok = buckets.insert(dd.new, dd.hazard_key, dd.hazard_val,
                                    dd.hazard_live)
        return replace(dd, new=t, hazard_live=jnp.zeros_like(dd.hazard_live))

    return jax.lax.cond(d.rebuilding, go, lambda dd: dd, d)


def rebuild_chunk(d: DHashState) -> DHashState:
    """extract + land in one transition (hazard window not externally visible).
    Engines that want the observable hazard period call the two halves."""
    return rebuild_land(rebuild_extract(d))


def rebuild_done(d: DHashState) -> jax.Array:
    """Scalar bool: all chunks migrated and landed."""
    return d.rebuilding & (d.cursor >= buckets.capacity_of(d.old)) & ~d.hazard_live.any()


def rebuild_finish(d: DHashState) -> DHashState:
    """Host-level epoch swap (Alg. 3 lines 41-46). old/new may differ in
    static shape, so this is not jittable in general; O(1) pytree shuffle."""
    assert bool(jax.device_get(rebuild_done(d))), "rebuild not complete"
    return replace(d, old=d.new, new=d.old, cursor=jnp.asarray(0, I32),
                   rebuilding=jnp.asarray(False), epoch=d.epoch + 1)


def finish_same_shape(d: DHashState) -> DHashState:
    """Fully-jitted epoch swap, valid when old/new share static shapes
    (continuous-rebuild benchmarks; router rebalancing)."""
    done = rebuild_done(d)
    old_leaves, treedef = jax.tree_util.tree_flatten(d.old)
    new_leaves = jax.tree_util.tree_leaves(d.new)
    sw_old = [jnp.where(done, n, o) for o, n in zip(old_leaves, new_leaves)]
    sw_new = [jnp.where(done, o, n) for o, n in zip(old_leaves, new_leaves)]
    return replace(d,
                   old=jax.tree_util.tree_unflatten(treedef, sw_old),
                   new=jax.tree_util.tree_unflatten(treedef, sw_new),
                   cursor=jnp.where(done, 0, d.cursor).astype(I32),
                   rebuilding=d.rebuilding & ~done,
                   epoch=d.epoch + done.astype(I32))


def rebuild_step(d: DHashState) -> DHashState:
    """One rebuild transition per call: land if hazard pending, else extract.
    Interleave with op batches for concurrent-rebuild execution."""
    return jax.lax.cond(d.hazard_live.any(), rebuild_land, rebuild_extract, d)


def _reseed_table(t, salt: jax.Array):
    """Shape-preserving on-device hash refresh for any backend table."""
    if isinstance(t, buckets.LinearTable):
        return replace(t, hfn=hashing.reseed(t.hfn, salt))
    if isinstance(t, buckets.TwoChoiceTable):
        return replace(t, hfn_a=hashing.reseed(t.hfn_a, salt),
                       hfn_b=hashing.reseed(t.hfn_b, salt + 0x5851F42))
    return replace(t, hfn=hashing.reseed(t.hfn, salt))


def rebuild_autostart(d: DHashState) -> DHashState:
    """Fully-jitted rebuild start: when NOT rebuilding, clear the (drained)
    standby table, reseed its hash function on-device from the epoch counter
    (hashing.reseed — no host RNG), and raise ``rebuilding``.

    This is the continuous-rebuild engine's device-side replacement for the
    host-level ``rebuild_start``: combined with ``finish_same_shape`` the
    steady state never leaves the accelerator.  Valid when old/new share
    static shapes (same-capacity rebuilds)."""

    def go(dd: DHashState):
        new = buckets.clear(dd.new)
        new = _reseed_table(new, dd.epoch + 1)
        old = dd.old
        if dd.fused and dd.backend == "chain":
            # same old-arena freeze as the host-level rebuild_start: sort +
            # reclaim once per epoch, before the cursor scan begins
            old = buckets.chain_compact_fused(old)
        return replace(dd, old=old, new=new, cursor=jnp.asarray(0, I32),
                       rebuilding=jnp.asarray(True))

    return jax.lax.cond(d.rebuilding, lambda dd: dd, go, d)


# ---------------------------------------------------------------------------
# convenience drivers
# ---------------------------------------------------------------------------

def rebuild_all(d: DHashState, *, finish: bool = True) -> DHashState:
    """Run a complete rebuild to quiescence (host loop; used by tests/benches
    that don't care about interleaving)."""
    cap = buckets.capacity_of(d.old)
    steps = -(-cap // d.chunk) + 1  # +1 in case a hazard chunk is already pending
    chunk_fn = jax.jit(rebuild_chunk)
    done_fn = jax.jit(rebuild_done)
    for _ in range(steps):
        if bool(jax.device_get(done_fn(d))):
            break
        d = chunk_fn(d)
    return rebuild_finish(d) if finish else d


def count_items(d: DHashState) -> jax.Array:
    return (buckets.count_live(d.old) + buckets.count_live(d.new)
            + d.hazard_live.sum(dtype=I32))
