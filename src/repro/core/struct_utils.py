"""Pytree dataclass helpers.

Every state object in this codebase is a frozen dataclass registered as a JAX
pytree, with *array* fields as data and *configuration* fields as static
aux-data (so jit caches key on them and Python control flow may branch on
them).
"""
from __future__ import annotations

import dataclasses
from typing import TypeVar

import jax

_T = TypeVar("_T")


def pytree_dataclass(cls: type[_T] | None = None, *, meta_fields: tuple[str, ...] = ()) -> type[_T]:
    """Decorator: frozen dataclass registered as a pytree.

    ``meta_fields`` become static aux-data; everything else is a leaf/subtree.
    """

    def wrap(c: type[_T]) -> type[_T]:
        c = dataclasses.dataclass(frozen=True)(c)
        data_fields = tuple(f.name for f in dataclasses.fields(c) if f.name not in meta_fields)
        jax.tree_util.register_dataclass(c, data_fields=data_fields, meta_fields=meta_fields)
        return c

    if cls is None:
        return wrap  # type: ignore[return-value]
    return wrap(cls)


def replace(obj: _T, **kw) -> _T:
    return dataclasses.replace(obj, **kw)  # type: ignore[type-var]
