"""llama4-scout-17b-16e [moe]: 16 experts top-1, early-fusion multimodal
(text path only; vision stub shares the qwen2-vl pattern)
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]. DHash hash-router
enabled. long_500k SKIPPED (full attention)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    n_experts=16, top_k=1, moe_dff=8192,
    use_hash_router=True, fsdp=True,
)

def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, vocab_size=512,
                         n_experts=4, top_k=1, moe_dff=64,
                         dtype="float32", attn_chunk=32, loss_chunk=32,
                         fsdp=False)
