"""AdamW with ZeRO-style sharded moments, global-norm clipping, cosine/linear
schedules, and optional int8 error-feedback gradient compression.

The compression path models the cross-pod gradient exchange: quantize to int8
with a per-leaf scale, accumulate the quantization error into a feedback
buffer added to the next step's gradient (Seide et al. / 1-bit Adam family).
On the dry-run mesh this bounds the "pod"-axis all-reduce bytes at 1/4 of
bf16; quality impact is regression-tested in tests/test_optim.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"           # "cosine" | "linear" | "const"
    grad_compression: bool = False     # int8 error-feedback


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    s = step.astype(F32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def init_opt_state(params: Any, cfg: OptConfig) -> dict:
    def zeros(p):
        return jnp.zeros(p.shape, F32)
    state = {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.grad_compression:
        state["err"] = jax.tree_util.tree_map(zeros, params)
    return state


def _global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def _compress_ef(g: jax.Array, err: jax.Array):
    """int8 quantize with error feedback. Returns (g_hat, new_err)."""
    gq = g.astype(F32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gq)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gq / scale), -127, 127)
    g_hat = q * scale
    return g_hat, gq - g_hat


def apply_updates(params: Any, grads: Any, state: dict, cfg: OptConfig):
    """One AdamW step. Returns (params', state', metrics)."""
    step = state["step"] + 1
    if cfg.grad_compression:
        pairs = jax.tree_util.tree_map(_compress_ef, grads, state["err"])
        grads = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                       is_leaf=lambda x: isinstance(x, tuple))
        err = jax.tree_util.tree_map(lambda p: p[1], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(F32)
    b2c = 1.0 - cfg.b2 ** step.astype(F32)

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only, not norms/scalars
            u = u + cfg.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * u).astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    def leaf3(i):
        return jax.tree_util.tree_map(
            lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))
    new_params, m, v = leaf3(0), leaf3(1), leaf3(2)
    new_state = {"m": m, "v": v, "step": step}
    if cfg.grad_compression:
        new_state["err"] = err
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
