"""qwen2-vl-2b [vlm]: M-RoPE (t/h/w position streams), dynamic-resolution
vision frontend STUBBED to precomputed patch embeddings per spec
[arXiv:2409.12191; hf]. long_500k SKIPPED (full attention)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936,
    mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
    frontend="stub_embed",
)

def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, vocab_size=512,
                         mrope_sections=(4, 2, 2),
                         dtype="float32", attn_chunk=32, loss_chunk=32)
