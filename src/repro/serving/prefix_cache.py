"""Prefix cache: content-hash -> cached KV page, backed by DHash.

Block-granular prefix reuse (vLLM/SGLang style): the fingerprint of token
block i is hash(fingerprint(i-1), tokens[i*ps:(i+1)*ps]), so a chain of
fingerprints identifies a unique prefix.  Admission looks up the longest
cached prefix; published prefixes insert their (fingerprint -> page) pairs.

This is the serving surface where the paper's *dynamic* property earns its
keep: adversarial/bursty request mixes skew the fingerprint distribution
(hash collision attack), and the engine responds by REBUILDING the prefix
index with a fresh seed — lookups keep streaming mid-rebuild.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dhash, hashing

I32 = jnp.int32


def prefix_fingerprints(tokens: jax.Array, page_size: int) -> jax.Array:
    """tokens: [B, S] -> chained block fingerprints [B, S // page_size]."""
    b, s = tokens.shape
    n = s // page_size
    blocks = tokens[:, : n * page_size].reshape(b, n, page_size)

    def chain(h, blk):             # blk: [B, ps]
        for i in range(page_size):
            h = hashing.hash_combine(h, blk[:, i])
        return h, (h & jnp.uint32(0x7FFFFFFF)).astype(I32)

    h0 = jnp.full((b,), jnp.uint32(0x811C9DC5))
    _, fps = jax.lax.scan(chain, h0, blocks.swapaxes(0, 1))
    return fps.swapaxes(0, 1)                              # [B, n]


def match_prefix(table: dhash.DHashState, fps: jax.Array):
    """Longest cached prefix per row. fps: [B, n].
    Returns (n_hit [B], pages [B, n] with -1 past the hit length).

    Edge contracts (pinned by tests): a row whose FIRST block misses is a
    clean miss — ``n_hit == 0`` and every page ``-1`` (the cumprod run
    never restarts after a gap); a zero-block batch (``n == 0``, prompts
    shorter than a page — ``prefix_fingerprints`` never fingerprints the
    ragged tail) short-circuits without touching the table."""
    b, n = fps.shape
    if n == 0:
        return jnp.zeros((b,), I32), jnp.full((b, 0), -1, I32)
    found, pages = dhash.lookup(table, fps.reshape(-1))
    found = found.reshape(b, n)
    pages = pages.reshape(b, n)
    run = jnp.cumprod(found.astype(I32), axis=1)           # 1 while contiguous
    n_hit = run.sum(axis=1)
    return n_hit, jnp.where(run.astype(bool), pages, -1)


def publish_prefix(table: dhash.DHashState, fps: jax.Array, pages: jax.Array,
                   mask: jax.Array):
    """Insert fingerprint->page pairs for freshly computed blocks."""
    t, ok = dhash.insert(table, fps.reshape(-1), pages.reshape(-1),
                         mask.reshape(-1))
    return t, ok.reshape(fps.shape)
