"""Paged KV cache with a DHash page table (vLLM-style, TPU-native).

The page table is the paper's structure in its natural serving role:
``(seq_id, block_idx) -> physical page`` lives in a DHash instance, so the
cache can be *rehashed/resized live* (bursty admission, fragmentation, or
adversarial request patterns) while decode steps keep resolving pages at
full rate — lookups follow the ordered old->hazard->new check and never
block on the rebuild.

**Multi-tenant mode** (``make(..., n_tenants=T)``): the page table becomes a
``dhash.make_stack`` of T per-tenant tables (tenant = ``seq_id % T``, the
engine's default partition), batched by the vmapped ``stack_*`` ops — one
kernel launch resolves every tenant's pages, and each tenant's table runs
its OWN live rehash epoch (``start_rehash(kv, mask)`` targets exactly the
tenants whose load degraded; a noisy neighbour's rebuild never touches the
others' tables).  The page POOL stays shared — pages are fungible; only the
mapping is isolated per tenant.

**Capped tenant routing**: table ops group a flat [N] key batch by tenant
through the counting-sort router (``distributed._route``) into a
``[T, ceil(c·N/T) + spill_cap]`` send buffer (``c = cap_factor``) instead
of the full-width ``[T, N]`` baseline — fewer buffer bytes and scatter
work, and the sort-free router keeps the fused stack op at its single
1-sort/1-pallas_call budget.  Keys past a tenant's cap (zipf skew,
adversarial single-tenant batches) ride the **spill slab**: extra columns
of the SAME buffer, shared across tenants by global spill rank, filled in
the same single pass — a spilling batch costs exactly one routed op, the
same as a balanced one (the ``lax.cond``-gated full-width retry this
replaces is gone).  ``spill_slack`` sizes the slab
(``distributed.route_spill_cap``): the default 1.0 is overflow-PROOF
(total spill is bounded by ``N - cap``, so every key is always served); a
compact slack < 1 trades width for exactly-accounted drops.
``PagedKV.route_spill`` accumulates the per-tenant spill counts (slab
pressure — the adaptive cap controller's signal) and
``PagedKV.route_drop`` the per-tenant keys a compact slab could not carry
(insert/delete report them ok=False; never a SILENT drop), so both are
observable and distinct from "the table rejected the insert" (``ok``).

Attention over pages is flash-decoding style: a scan over blocks with a
running (max, denominator) accumulator — no materialization of the gathered
KV, so the memory roofline term stays at one pass over the live pages.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import buckets, dhash
from repro.core.distributed import (_route, _route_payload, _unroute,
                                    route_cap, route_spill_cap)
from repro.core.struct_utils import pytree_dataclass, replace
from repro.serving import eviction, prefix_cache

F32 = jnp.float32
I32 = jnp.int32
NEG_INF = -2.0e38


def block_key(seq_id: jax.Array, block_idx: jax.Array) -> jax.Array:
    """Pack the page-table key; 15 bits of block index."""
    return (seq_id.astype(I32) << 15) | block_idx.astype(I32)


@pytree_dataclass(meta_fields=("layers", "page_size", "n_pages", "kv_heads",
                               "head_dim", "max_blocks", "n_tenants",
                               "cap_factor", "spill_slack", "evict_batch"))
class PagedKV:
    layers: int
    page_size: int
    n_pages: int
    kv_heads: int
    head_dim: int
    max_blocks: int              # blocks per sequence bound
    n_tenants: int               # 1 = single shared page table; T > 1 = a
                                 # dhash stack of per-tenant tables
    cap_factor: float            # tenant-router cap c: send buffers are
                                 # [T, ceil(c*N/T) + spill_cap]; <= 0 = full
                                 # width (no slab needed)
    spill_slack: float           # spill-slab budget (route_spill_cap):
                                 # 1.0 = overflow-proof (default — every key
                                 # always served); < 1 = compact slab with
                                 # exactly-counted drops
    evict_batch: int             # max victims per evict-on-pressure pass;
                                 # must cover the worst per-step block
                                 # demand (>= batch size) for alloc_fail==0
    pool_k: jax.Array            # [L, n_pages, page, KV, HD]
    pool_v: jax.Array
    table: dhash.DHashState      # block_key -> page id ([T]-stacked if T > 1)
    free_stack: jax.Array        # [n_pages] i32
    free_top: jax.Array          # scalar i32
    route_spill: jax.Array       # [T] i32 cumulative router overflow (keys
                                 # past a tenant's cap, served by the spill
                                 # slab — the cap controller's signal)
    route_drop: jax.Array        # [T] i32 cumulative keys a compact slab
                                 # could not carry (0 under the default
                                 # overflow-proof spill_slack=1.0)
    alloc_fail: jax.Array        # scalar i32: masked allocations that found
                                 # no free page (after eviction, if enabled)
    prefix: eviction.PrefixState | None  # prefix-cache + eviction state
                                 # (None = caching disabled, zero overhead)


def make(layers: int, page_size: int, n_pages: int, kv_heads: int,
         head_dim: int, *, max_blocks: int = 4096, dtype=jnp.bfloat16,
         table_chunk: int = 256, seed: int = 3,
         n_tenants: int = 1, cap_factor: float = 2.0,
         spill_slack: float = 1.0,
         prefix_cache: bool = False, prefix_backend: str = "linear",
         prefix_capacity: int | None = None, prefix_seed: int = 11,
         prefix_fused: bool | None = None, evict_batch: int = 8,
         prefix_kw: dict | None = None) -> PagedKV:
    shp = (layers, n_pages, page_size, kv_heads, head_dim)
    if n_tenants == 1:
        table = dhash.make("linear", capacity=2 * n_pages, chunk=table_chunk,
                           seed=seed)
    else:
        # every tenant's table is sized for the full pool (pages are shared,
        # so in the worst case one tenant holds them all)
        table = dhash.make_stack(n_tenants, "linear", capacity=2 * n_pages,
                                 chunk=table_chunk, seed=seed)
    prefix = None
    if prefix_cache:
        prefix = eviction.make(n_pages, backend=prefix_backend,
                               capacity=prefix_capacity, chunk=table_chunk,
                               seed=prefix_seed, fused=prefix_fused,
                               **(prefix_kw or {}))
    return PagedKV(
        layers=layers, page_size=page_size, n_pages=n_pages, kv_heads=kv_heads,
        head_dim=head_dim, max_blocks=max_blocks, n_tenants=n_tenants,
        cap_factor=cap_factor, spill_slack=spill_slack,
        evict_batch=evict_batch,
        pool_k=jnp.zeros(shp, dtype), pool_v=jnp.zeros(shp, dtype),
        table=table,
        free_stack=jnp.arange(n_pages, dtype=I32),
        free_top=jnp.asarray(n_pages, I32),
        route_spill=jnp.zeros((n_tenants,), I32),
        route_drop=jnp.zeros((n_tenants,), I32),
        alloc_fail=jnp.asarray(0, I32),
        prefix=prefix)


def tenant_of(kv: PagedKV, seq_ids: jax.Array) -> jax.Array:
    """Owning tenant of each sequence (the engine's default partition)."""
    return (seq_ids.astype(I32) % kv.n_tenants).astype(I32)


# -- tenant-routed table access: group a flat key batch by owning tenant
# through the counting-sort router into CAPPED [T, ceil(c*N/T) + spill_cap]
# buffers, run ONE vmapped stack op, scatter results back to batch order.
# Keys past a tenant's cap (skewed batches) ride the spill-slab columns of
# the SAME buffer in the SAME pass — a spilling batch costs one routed op,
# exactly like a balanced one; there is no second pass.  n_tenants == 1
# short-circuits to the plain single-table op — the historical layout,
# zero overhead -----------------------------------------------------------

def _tenant_route(kv: PagedKV, tenant: jax.Array, keys: jax.Array):
    """Single-pass two-level route of a [N] batch by owning tenant."""
    cap = route_cap(kv.cap_factor, keys.shape[0], kv.n_tenants)
    return _route(keys, tenant, kv.n_tenants, cap,
                  route_spill_cap(keys.shape[0], cap, kv.spill_slack))


def table_lookup(kv: PagedKV, tenant: jax.Array, keys: jax.Array):
    """(found[N], vals[N]) across the tenant stack; ``tenant`` aligns with
    ``keys``.  Exact under any skew with the default overflow-proof slab
    (``spill_slack=1.0``): spilled keys resolve through the slab columns
    of the same single op.  Under a compact slab, slab-exhausted keys come
    back not-found (lookup is read-only, so they are counted in
    ``route_drop`` by the insert/delete of the same batch, not here)."""
    if kv.n_tenants == 1:
        return dhash.lookup(kv.table, keys)
    rt = _tenant_route(kv, tenant, keys)
    f, v = dhash.stack_lookup(kv.table, rt.send, rt.smask)
    return _unroute(f, rt, fill=False).astype(bool), _unroute(v, rt, fill=0)


def table_insert(kv: PagedKV, tenant: jax.Array, keys: jax.Array,
                 vals: jax.Array, mask: jax.Array):
    """(kv', ok[N]) across the tenant stack.  Spilled keys insert through
    the slab in the same op; with the default overflow-proof slab
    ``ok=False`` always means the TABLE rejected (or the key was masked
    out).  A compact slab's shortfall reports ok=False AND lands in
    ``kv.route_drop`` — never a silent drop; slab pressure itself
    accumulates in ``kv.route_spill`` (see ``table_load``)."""
    if kv.n_tenants == 1:
        table, ok = dhash.insert(kv.table, keys, vals, mask)
        return replace(kv, table=table), ok
    rt = _tenant_route(kv, tenant, keys)
    table, ok = dhash.stack_insert(kv.table, rt.send, _route_payload(vals, rt),
                                   _route_payload(mask, rt))
    okb = _unroute(ok, rt, fill=False).astype(bool)
    return replace(kv, table=table,
                   route_spill=kv.route_spill + rt.overflow,
                   route_drop=kv.route_drop + rt.dropped), okb


def table_delete(kv: PagedKV, tenant: jax.Array, keys: jax.Array,
                 mask: jax.Array):
    """(kv', ok[N]) across the tenant stack — same single-pass spill-slab
    contract as ``table_insert``."""
    if kv.n_tenants == 1:
        table, ok = dhash.delete(kv.table, keys, mask)
        return replace(kv, table=table), ok
    rt = _tenant_route(kv, tenant, keys)
    table, ok = dhash.stack_delete(kv.table, rt.send, _route_payload(mask, rt))
    okb = _unroute(ok, rt, fill=False).astype(bool)
    return replace(kv, table=table,
                   route_spill=kv.route_spill + rt.overflow,
                   route_drop=kv.route_drop + rt.dropped), okb


def resolve_blocks(kv: PagedKV, seq_ids: jax.Array, n_blocks: int):
    """DHash-resolve the page of every (seq, block) pair.
    seq_ids: [B] -> (pages [B, n_blocks] i32, found [B, n_blocks])."""
    b = seq_ids.shape[0]
    blk = jnp.arange(n_blocks, dtype=I32)
    keys = block_key(seq_ids[:, None], blk[None, :]).reshape(-1)
    tenant = jnp.broadcast_to(tenant_of(kv, seq_ids)[:, None],
                              (b, n_blocks)).reshape(-1)
    found, page = table_lookup(kv, tenant, keys)
    return page.reshape(b, n_blocks), found.reshape(b, n_blocks)


def _evict_for(kv: PagedKV, shortage: jax.Array) -> PagedKV:
    """Evict up to ``shortage`` cold unpinned cached pages into the free
    stack (cond-gated: the pressure-free path pays nothing at runtime)."""

    def go(args):
        ps, free_stack, free_top = args
        ps, pages, ok = eviction.evict(ps, kv.evict_batch, shortage)
        rank = jnp.cumsum(ok.astype(I32)) - 1
        dst = jnp.where(ok, free_top + rank, kv.n_pages)
        free_stack = free_stack.at[dst].set(pages, mode="drop")
        return ps, free_stack, free_top + ok.sum(dtype=I32)

    ps, free_stack, free_top = lax.cond(
        shortage > 0, go, lambda a: a,
        (kv.prefix, kv.free_stack, kv.free_top))
    return replace(kv, prefix=ps, free_stack=free_stack, free_top=free_top)


def alloc_pages(kv: PagedKV, seq_ids: jax.Array, block_idx: jax.Array,
                mask: jax.Array):
    """Allocate one page per masked (seq, block) and insert into the table.
    Idempotent: pairs already mapped keep their page (no leak).

    With the prefix cache enabled, pool pressure evicts cold unpinned
    cached pages first (``eviction.evict``) instead of failing the
    allocation; ``kv.alloc_fail`` counts masked requests that STILL found
    no page — the macro-bench asserts it stays zero over a replay that
    exceeds ``n_pages``.  Returns (kv', pages [B])."""
    keys = block_key(seq_ids, block_idx)
    tenant = tenant_of(kv, seq_ids)
    present, _ = table_lookup(kv, tenant, keys)
    # drop-robust: a compact spill slab can drop a key from BOTH the
    # lookup (present=False even if mapped — no double allocation) and the
    # insert (the new mapping would be lost — no page handed out without a
    # mapping, no free-stack leak), so router-dropped keys are excluded
    # from allocation entirely.  The route is identical to table_lookup's
    # (same keys/tenants/caps), so this costs nothing extra under CSE, and
    # under the default overflow-proof slab ``served`` is all-True.
    servable = (_tenant_route(kv, tenant, keys).served
                if kv.n_tenants > 1 else jnp.ones(keys.shape, bool))
    want = mask & servable & ~present
    if kv.prefix is not None:
        need = jnp.sum(want.astype(I32))
        kv = _evict_for(kv, need - kv.free_top)
    rank = jnp.cumsum(want.astype(I32)) - 1
    can = want & (rank < kv.free_top)
    page = kv.free_stack[jnp.where(can, kv.free_top - 1 - rank, 0)]
    kv, ok = table_insert(kv, tenant, keys, page, can)
    used = jnp.sum((can & ok).astype(I32))
    fail = jnp.sum(((mask & ~servable) | (want & ~can) | (can & ~ok))
                   .astype(I32))
    return replace(kv, free_top=kv.free_top - used,
                   alloc_fail=kv.alloc_fail + fail), \
        jnp.where(can & ok, page, -1)


def append_token(kv: PagedKV, seq_ids: jax.Array, positions: jax.Array,
                 k_new: jax.Array, v_new: jax.Array):
    """Write one token's K/V for every layer.

    k_new/v_new: [L, B, KV, HD]; positions: [B] (0-based index of the new
    token). Allocates a fresh page when the position opens a new block."""
    ps = kv.page_size
    blk, off = positions // ps, positions % ps
    kv, pages_new = alloc_pages(kv, seq_ids, blk, off == 0)
    pages, found = resolve_blocks_at(kv, seq_ids, blk)
    page = jnp.where(found, pages, pages_new)
    lidx = jnp.arange(kv.layers, dtype=I32)[:, None]
    pool_k = kv.pool_k.at[lidx, page[None, :], off[None, :]].set(k_new)
    pool_v = kv.pool_v.at[lidx, page[None, :], off[None, :]].set(v_new)
    return replace(kv, pool_k=pool_k, pool_v=pool_v)


def resolve_blocks_at(kv: PagedKV, seq_ids: jax.Array, block_idx: jax.Array):
    keys = block_key(seq_ids, block_idx)
    found, page = table_lookup(kv, tenant_of(kv, seq_ids), keys)
    return page, found


def paged_decode_attention(kv: PagedKV, layer: jax.Array, q1: jax.Array,
                           seq_ids: jax.Array, cache_len: jax.Array,
                           n_blocks: int, *, window=0, softcap: float = 0.0):
    """Flash-decoding over pages for ONE layer slice of the pool.

    q1: [B, Hq, HD]; returns [B, Hq, HD].  ``layer`` may be traced (scan).
    """
    b, hq, hd = q1.shape
    hkv, ps = kv.kv_heads, kv.page_size
    g = hq // hkv
    scale = 1.0 / np.sqrt(hd)
    pages, found = resolve_blocks(kv, seq_ids, n_blocks)    # [B, n_blocks]
    qg = q1.reshape(b, hkv, g, hd)
    pool_k = jax.lax.dynamic_index_in_dim(kv.pool_k, layer, 0, keepdims=False)
    pool_v = jax.lax.dynamic_index_in_dim(kv.pool_v, layer, 0, keepdims=False)

    def body(carry, blk):
        m, l, acc = carry
        pg = pages[:, blk]                                   # [B]
        kb = pool_k[jnp.where(pg >= 0, pg, 0)]               # [B, ps, KV, HD]
        vb = pool_v[jnp.where(pg >= 0, pg, 0)]
        s = jnp.einsum("bhgd,bphd->bhgp", qg, kb).astype(F32) * scale
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        pos = blk * ps + jnp.arange(ps, dtype=I32)[None, :]  # [1, ps]
        ok = (pos < cache_len[:, None]) & found[:, blk][:, None] & (pg >= 0)[:, None]
        ok &= (window <= 0) | (pos >= cache_len[:, None] - window)
        s = jnp.where(ok[:, None, None, :], s, NEG_INF)
        m2 = jnp.maximum(m, s.max(-1))
        w = jnp.exp(s - m2[..., None])
        corr = jnp.exp(m - m2)
        l2 = l * corr + w.sum(-1)
        acc2 = acc * corr[..., None] + jnp.einsum(
            "bhgp,bphd->bhgd", w.astype(vb.dtype), vb).astype(F32)
        return (m2, l2, acc2), None

    m0 = jnp.full((b, hkv, g), -jnp.inf, F32)
    l0 = jnp.zeros((b, hkv, g), F32)
    a0 = jnp.zeros((b, hkv, g, hd), F32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  jnp.arange(n_blocks, dtype=I32))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, hd).astype(q1.dtype)


def free_sequences(kv: PagedKV, seq_ids: jax.Array, max_blocks: int):
    """Release all pages of finished sequences back to the free list and
    delete their table entries (batched).

    With the prefix cache enabled, a finished sequence's CACHED pages
    (adopted shared pages and its own published blocks — exactly the pages
    it holds a pin on) are unpinned instead of freed: they stay in the
    cache for future hits and return to the pool only through eviction.
    Uncached pages (unpublished tails, failed publishes) are exclusively
    owned and go straight back to the free stack as before."""
    b = seq_ids.shape[0]
    blk = jnp.arange(max_blocks, dtype=I32)
    keys = block_key(seq_ids[:, None], blk[None, :]).reshape(-1)
    tenant = jnp.broadcast_to(tenant_of(kv, seq_ids)[:, None],
                              (b, max_blocks)).reshape(-1)
    found, pages = table_lookup(kv, tenant, keys)
    kv, ok = table_delete(kv, tenant, keys, found)
    push = ok
    if kv.prefix is not None:
        tgt = jnp.clip(pages, 0, kv.n_pages - 1)
        pinned = ok & kv.prefix.cached[tgt]
        kv = replace(kv, prefix=eviction.release(kv.prefix, pages, pinned))
        push = ok & ~pinned
    # push freed pages (deterministic order)
    rank = jnp.cumsum(push.astype(I32)) - 1
    dst = jnp.where(push, kv.free_top + rank, kv.n_pages)
    free_stack = kv.free_stack.at[dst].set(pages, mode="drop")
    freed = jnp.sum(push.astype(I32))
    return replace(kv, free_stack=free_stack,
                   free_top=kv.free_top + freed)


def adopt_prefix(kv: PagedKV, seq_id: jax.Array, fps: jax.Array,
                 valid: jax.Array):
    """Adopt the longest cached prefix for ONE admitted sequence.

    ``fps``: [n] padded block fingerprints, ``valid``: [n] bool (False past
    the prompt's full blocks).  The contiguous run of cached fingerprints
    is resolved through the prefix index, its pages are mapped into the
    sequence's page table under its own block keys, pinned (``acquire``)
    and re-warmed (``touch``).  Page-table inserts that fail truncate the
    adopted run (and roll back their stragglers) so the mapped prefix is
    always contiguous from block 0.  Returns ``(kv', n_adopt, pages [n])``
    with ``-1`` past the adopted length."""
    ps = kv.prefix
    found, pages = dhash.lookup(ps.table, fps)
    run = jnp.cumprod((found & valid).astype(I32)).astype(bool)
    blk = jnp.arange(fps.shape[0], dtype=I32)
    keys = block_key(jnp.broadcast_to(seq_id, blk.shape), blk)
    tenant = jnp.broadcast_to(tenant_of(kv, jnp.asarray(seq_id, I32)),
                              blk.shape)
    kv, ok = table_insert(kv, tenant, keys, pages, run)
    keep = jnp.cumprod((run & ok).astype(I32)).astype(bool)
    kv, _ = table_delete(kv, tenant, keys, run & ok & ~keep)
    ps = eviction.touch(eviction.acquire(ps, pages, keep), pages, keep)
    return replace(kv, prefix=ps), keep.sum(dtype=I32), \
        jnp.where(keep, pages, -1)


def publish_blocks(kv: PagedKV, seq_id: jax.Array, fps: jax.Array,
                   mask: jax.Array):
    """Publish ONE sequence's fully-written blocks into the prefix cache.

    ``fps``: [n] fingerprints, ``mask``: [n] bool (blocks to publish).  The
    pages come from the sequence's OWN page-table entries; successfully
    published pages become cached and the sequence takes a pin on them
    (released by ``free_sequences`` — a cached page is never recycled
    under a reader).  Duplicate fingerprints keep the existing mapping and
    the local page stays exclusively owned.  Returns ``(kv', n_pub)``."""
    blk = jnp.arange(fps.shape[0], dtype=I32)
    keys = block_key(jnp.broadcast_to(seq_id, blk.shape), blk)
    tenant = jnp.broadcast_to(tenant_of(kv, jnp.asarray(seq_id, I32)),
                              blk.shape)
    found, pages = table_lookup(kv, tenant, keys)
    ps, ok = eviction.publish(kv.prefix, fps, pages, mask & found)
    ps = eviction.acquire(ps, pages, ok)
    return replace(kv, prefix=ps), ok.sum(dtype=I32)


def rehash_step(kv: PagedKV) -> PagedKV:
    """One live rebuild transition on the page table (engine interleaves).

    In multi-tenant mode every tenant advances its own epoch and swaps
    on-device the moment ITS rebuild completes (``finish_same_shape`` under
    vmap) — rehashes stay fully independent across the stack.  The prefix
    index and its reverse index (when enabled) advance their own epochs
    the same way — a fingerprint-index rehash (collision attack response)
    streams alongside decode exactly like a page-table rehash."""
    if kv.n_tenants == 1:
        kv = replace(kv, table=dhash.rebuild_step(kv.table))
    else:
        kv = replace(kv, table=dhash.stack_finish_same_shape(
            dhash.stack_rebuild_step(kv.table)))
    if kv.prefix is not None:
        ps = kv.prefix
        kv = replace(kv, prefix=replace(
            ps,
            table=dhash.finish_same_shape(dhash.rebuild_step(ps.table)),
            rev=dhash.finish_same_shape(dhash.rebuild_step(ps.rev))))
    return kv


def start_prefix_rehash(kv: PagedKV, *, seed: int | None = None) -> PagedKV:
    """Begin a live same-shape rehash of the prefix (fingerprint) index with
    a fresh hash seed — the engine's response to a collision attack on the
    fingerprint distribution.  Host-side helper: a no-op if a rebuild is
    already in flight (``rehash_step`` drives it to completion)."""
    ps = kv.prefix
    if ps is None:
        raise ValueError("prefix cache is disabled (make(prefix_cache=True))")
    if bool(jax.device_get(ps.table.rebuilding)):
        return kv
    table = dhash.rebuild_start(ps.table, seed=seed)
    return replace(kv, prefix=replace(ps, table=table))


def start_rehash(kv: PagedKV, mask: jax.Array | None = None) -> PagedKV:
    """Begin a live rehash on the selected tenants' tables ([T] bool; all by
    default).  Tables mid-rebuild are untouched.  Multi-tenant only — the
    single-table engine drives ``dhash.rebuild_start`` directly (it may
    resize, which a stack cannot)."""
    if kv.n_tenants == 1:
        raise ValueError("start_rehash targets a tenant stack; use "
                         "dhash.rebuild_start on kv.table for n_tenants=1")
    return replace(kv, table=dhash.stack_autostart(kv.table, mask))


def table_load(kv: PagedKV, *, with_spill: bool = False):
    """Active-table load factor per tenant table ([T] f32; scalar for a
    single table) — the serving engine's rehash trigger.  Both shapes use
    the SAME metric, live entries in the active (old) table over its
    capacity, so a trigger threshold means one thing regardless of
    tenancy.

    ``with_spill=True`` returns ``(load, route_spill, route_drop)`` — the
    cumulative per-tenant router counters alongside the loads, so a caller
    polling table health can tell "this tenant's traffic keeps spilling
    past the routing cap (slab pressure — the ``RouteCapController``'s
    grow signal)" and "a compact slab actually dropped keys (grow NOW)"
    apart from "this tenant's TABLE is filling up (rehash)"."""
    if kv.n_tenants == 1:
        cap = buckets.capacity_of(kv.table.old)
        load = buckets.count_live(kv.table.old) / cap
    else:
        peel = jax.tree_util.tree_map(lambda x: x[0], kv.table)
        cap = buckets.capacity_of(peel.old)
        load = jax.vmap(lambda d: buckets.count_live(d.old))(kv.table) / cap
    return (load, kv.route_spill, kv.route_drop) if with_spill else load


def table_health(kv: PagedKV):
    """(live_load, tomb_load) per tenant table ([T] f32 pair; scalars for a
    single table) — the elastic rehash trigger's inputs
    (``core.policy.rehash_wanted``).  ``tomb_load`` is the tombstoned
    fraction of the active table: page churn (sequences freed) leaves
    tombstones that degrade probe lengths without raising the live load,
    so the trigger needs both."""
    from repro.core import backend as backends
    be = backends.get(kv.table.backend)
    if kv.n_tenants == 1:
        cap = buckets.capacity_of(kv.table.old)
        return (be.count_live(kv.table.old) / cap,
                be.count_tomb(kv.table.old) / cap)
    peel = jax.tree_util.tree_map(lambda x: x[0], kv.table)
    cap = buckets.capacity_of(peel.old)
    return (jax.vmap(lambda d: be.count_live(d.old))(kv.table) / cap,
            jax.vmap(lambda d: be.count_tomb(d.old))(kv.table) / cap)
