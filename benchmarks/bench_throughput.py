"""Paper Figure 2: throughput vs batch width under continuous rebuild.

DHash vs HT-Xu / HT-RHT / HT-Split at load factors 20 and 200, op mixes
90/5/5 and 80/10/10.  "Worker threads" maps to the SPMD batch width Q (a
batch of Q ops = Q concurrent threads, DESIGN.md §2); all contenders run the
paper's §6.2 setup — a rebuild/resize cycling continuously while the op
stream runs at full rate.

Expected reproduction of the paper's claims:
  * alpha=20: DHash comparable or slightly ahead;
  * alpha=200: lock-based tables (Xu, RHT) collapse as per-bucket collision
    counts grow (their wall time multiplies by the lock-serialization round
    count), DHash scales with Q -> the paper's 2.3-6.2x band.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import (ALGOS, UNIVERSE, DHashDriver, Workload,
                               run_throughput)


def run(alpha: int, mix: tuple[int, int, int], qs=(256, 1024, 4096), *,
        nbuckets=None, steps=6, quiet=False, algos=None):
    nbuckets = nbuckets or (512 if alpha <= 20 else 64)
    n_items = alpha * nbuckets
    rng = np.random.default_rng(0)
    present = rng.choice(UNIVERSE, size=n_items, replace=False).astype(np.int32)
    rows = []
    for name in (algos or ALGOS):
        drv = ALGOS[name](nbuckets, n_items, seed=1)
        drv.populate(present)
        for q in qs:
            wl = Workload(q=q, mix=mix)
            mops = run_throughput(drv, wl, present, steps=steps,
                                  rng=np.random.default_rng(q)) / 1e6
            rows.append((drv.name, alpha, mix[0], q, mops))
            if not quiet:
                print(f"{drv.name:14s} alpha={alpha:<4d} mix={mix[0]}% "
                      f"Q={q:<6d} {mops:8.3f} Mops/s")
    return rows


def run_fused(alpha=20, mix=(90, 5, 5), qs=(1024, 4096), *, steps=4,
              quiet=False):
    """fused=on|off continuous-rebuild throughput for the linear backend
    (interpret-mode wall clock — trend data only; the op-count acceptance
    lives in bench_rebuild.run_fused_probe)."""
    nbuckets = 128
    n_items = alpha * nbuckets
    rng = np.random.default_rng(0)
    present = rng.choice(UNIVERSE, size=n_items, replace=False).astype(np.int32)
    rows = []
    for fused in (False, True):
        drv = DHashDriver(nbuckets, n_items, backend="linear", seed=1,
                          fused=fused)
        drv.populate(present)
        for q in qs:
            wl = Workload(q=q, mix=mix)
            mops = run_throughput(drv, wl, present, steps=steps,
                                  rng=np.random.default_rng(q)) / 1e6
            rows.append((drv.name, alpha, mix[0], q, mops))
            if not quiet:
                print(f"{drv.name:20s} alpha={alpha:<4d} mix={mix[0]}% "
                      f"Q={q:<6d} {mops:8.3f} Mops/s")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--alpha", type=int, nargs="*", default=[20, 200])
    ap.add_argument("--qs", type=int, nargs="*", default=[256, 1024, 4096])
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--fused", action="store_true",
                    help="also run the fused=on|off linear-backend variants")
    args = ap.parse_args(argv)
    all_rows = []
    for alpha in args.alpha:
        for mix in ((90, 5, 5), (80, 10, 10)):
            all_rows += run(alpha, mix, tuple(args.qs), steps=args.steps)
    if args.fused:
        all_rows += run_fused(qs=tuple(args.qs), steps=args.steps)
    # paper-style summary: DHash speedup over each contender at max Q
    qmax = max(args.qs)
    for alpha in args.alpha:
        for mix0 in (90, 80):
            sel = {r[0]: r[4] for r in all_rows
                   if r[1] == alpha and r[2] == mix0 and r[3] == qmax}
            if "DHash-chain" in sel:
                ref = sel["DHash-chain"]
                ratios = {k: ref / v for k, v in sel.items()
                          if not k.startswith("DHash")}
                print(f"[summary] alpha={alpha} mix={mix0}%: DHash speedup "
                      + ", ".join(f"{k}: {v:.1f}x" for k, v in ratios.items()))
    return all_rows


if __name__ == "__main__":
    main()
