"""DHash core: dynamic hash tables with live hash-function rebuild (the
paper's contribution), the BucketBackend descriptor registry, modular
bucket backends, baselines, and the shard_map-distributed table."""

from repro.core import (backend, baselines, buckets, dhash, distributed,
                        engine, hashing)

__all__ = ["backend", "baselines", "buckets", "dhash", "distributed",
           "engine", "hashing"]
