"""Shared neural layers: norms, rotary embeddings (incl. M-RoPE), MLPs,
embeddings, and memory-safe chunked cross-entropy."""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(F32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(F32))
    return y.astype(dt)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta) -> jax.Array:
    """x: [..., S, H, hd]; positions: [..., S] (ints). theta may be a traced
    scalar (per-layer theta arrays for gemma3 local/global)."""
    hd = x.shape[-1]
    exp = jnp.arange(0, hd, 2, dtype=F32) / hd
    freqs = 1.0 / (theta ** exp)                       # [hd/2]
    ang = positions[..., None].astype(F32) * freqs      # [..., S, hd/2]
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]  # [..., S, 1, hd/2]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE: positions [3, ..., S] (t/h/w streams);
    ``sections`` split the rotary dim (pairs) among the three streams."""
    hd = x.shape[-1]
    exp = jnp.arange(0, hd, 2, dtype=F32) / hd
    freqs = 1.0 / (theta ** exp)                       # [hd/2]
    ang = positions[..., None].astype(F32) * freqs      # [3, ..., S, hd/2]
    # select stream per frequency-pair according to sections
    sec = np.zeros((hd // 2,), np.int32)
    s0, s1, _ = sections
    sec[s0:s0 + s1] = 1
    sec[s0 + s1:] = 2
    idx = jnp.asarray(sec)
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang, 0, -1), idx[(None,) * (ang.ndim - 2) + (..., None)], axis=-1
    )[..., 0]                                           # [..., S, hd/2]
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings and loss
# ---------------------------------------------------------------------------

def embed(tokens: jax.Array, table: jax.Array, *, scale: bool) -> jax.Array:
    x = jnp.take(table, tokens, axis=0)
    if scale:
        x = x * jnp.asarray(np.sqrt(table.shape[1]), x.dtype)
    return x


def chunked_cross_entropy(x: jax.Array, unembed: jax.Array, targets: jax.Array,
                          *, chunk: int, logit_softcap: float = 0.0,
                          mask: jax.Array | None = None) -> jax.Array:
    """Next-token CE without materializing [B, S, V] logits: scan over
    sequence chunks; logits per chunk are vocab-shardable.

    x: [B, S, D]; unembed: [D, V]; targets: [B, S] int32.
    """
    b, s, d = x.shape
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    xc = x.reshape(b, n, chunk, d).swapaxes(0, 1)        # [n, B, c, D]
    tc = targets.reshape(b, n, chunk).swapaxes(0, 1)     # [n, B, c]
    mc = (jnp.ones((b, s), bool) if mask is None else mask)
    mc = mc.reshape(b, n, chunk).swapaxes(0, 1)

    def body(carry, inp):
        tot, cnt = carry
        xx, tt, mm = inp
        from repro.models.sharding import constrain
        logits = jnp.einsum("bcd,dv->bcv", xx, unembed).astype(F32)
        logits = constrain(logits, "dp", None, "tp")
        if logit_softcap > 0:
            logits = jnp.tanh(logits / logit_softcap) * logit_softcap
        lse = jax.nn.logsumexp(logits, axis=-1)
        # mask-select instead of take_along_axis: a vocab-sharded gather
        # would force GSPMD to all-gather full-vocab cotangents in bwd
        # (measured: f32[B,c,V] AGs in the rwkv6 §Perf cell); the iota
        # compare + partial sum shards cleanly.
        vio = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        gold = jnp.where(vio == tt[..., None], logits, 0.0).sum(-1)
        nll = jnp.where(mm, lse - gold, 0.0)
        return (tot + nll.sum(), cnt + mm.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), F32), jnp.zeros((), jnp.int32)),
                                 (xc, tc, mc))
    return tot / jnp.maximum(cnt, 1).astype(F32)
