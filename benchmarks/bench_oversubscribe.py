"""Paper §6.2 robustness claim: throughput past core saturation.

"When the number of worker threads exceeds the number of CPU cores, the
performance of DHASH increases slightly ... The performance of other
alternatives becomes flat or decreases due to the increased contention on
bucket locks."

SPMD mapping: batch width Q grows far beyond any fixed parallel resource;
DHash's per-op cost amortizes (vectorization), while the lock-modelled
tables' serialization rounds grow with Q/B and their throughput flattens or
falls.

``skew > 0`` draws lookup/delete keys from the suite's SHARED zipf skew
source (``common.zipf_owners`` — the same generator the routed-stack bench
uses for tenant load): hot-key concentration models the adversarial
popularity distribution the capped tenant router is gated under.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ALGOS, UNIVERSE, Workload, run_throughput


def run(alpha=200, qs=(512, 2048, 8192, 16384), *, skew=0.0, quiet=False):
    nbuckets = 64
    n = alpha * nbuckets
    rng = np.random.default_rng(0)
    present = rng.choice(UNIVERSE, size=n, replace=False).astype(np.int32)
    tag = f" zipf(a={skew})" if skew > 0 else ""
    rows = []
    for name in ("DHash", "HT-RHT", "HT-Xu"):
        drv = ALGOS[name](nbuckets, n, seed=1)
        drv.populate(present)
        series = []
        for q in qs:
            wl = Workload(q=q, mix=(80, 10, 10), skew=skew)
            mops = run_throughput(drv, wl, present, steps=4,
                                  rng=np.random.default_rng(q)) / 1e6
            series.append(mops)
            rows.append((drv.name, q, mops))
            if not quiet:
                print(f"{drv.name:14s} Q={q:<6d}{tag} {mops:8.3f} Mops/s")
        trend = series[-1] / series[0]
        print(f"[summary] {drv.name}{tag}: Q x{qs[-1]//qs[0]} -> "
              f"throughput x{trend:.2f} "
              f"({'scales' if trend > 1.5 else 'flat/degrades'})")
    return rows


if __name__ == "__main__":
    run()                  # uniform keys (the paper's §6.2 setup)
    run(skew=1.2)          # hot-key zipf via the shared skew source
