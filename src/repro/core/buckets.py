"""Modular bucket backends (the paper's pluggable "set algorithms", §3 goal 2).

The paper chains nodes in lock-free linked lists; pointer chasing is hostile
to TPUs, so each backend here is an *array-native* reformulation with the same
observable set semantics:

* ``linear``    — open-addressing, linear probing.  The TPU-native default:
                  bounded vectorized probe sequences, no pointers at all.
* ``twochoice`` — bucketed 2-choice hashing (cuckoo family without eviction):
                  exactly two vector-width bucket reads per lookup.
* ``chain``     — arena-based chained buckets: the faithful analogue of the
                  paper's Michael-list buckets (insert-at-head, logical
                  deletion via state tags, deferred physical reclamation).
                  Traversal is lock-step across the query batch: one gather
                  per hop, bounded by ``max_chain``.

Slot/node states mirror the paper's two flag bits:
  LIVE                ~ reachable node
  TOMB                ~ LOGICALLY_REMOVED      (delete; reclaim deferred)
  MIGRATED            ~ IS_BEING_DISTRIBUTED   (rebuild pulled it into hazard)

All operations are *batched*: a batch of Q independent operations is the SPMD
analogue of Q concurrent threads.  Intra-batch conflicts are resolved
deterministically (lowest original index wins), which is one legal
linearization of the paper's concurrent execution.

Every backend exposes:
  make(...) -> Table
  lookup(t, keys)                -> (found[Q], vals[Q], loc[Q])
  insert(t, keys, vals, mask)    -> (t', ok[Q])     # ok=False if present/full
  delete(t, keys, mask)          -> (t', ok[Q])
  extract_chunk(t, cursor, n)    -> (t', hkeys, hvals, hlive, new_cursor)
  count_live(t) -> scalar
  capacity_of(t) -> int (static)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core.struct_utils import pytree_dataclass

I32 = jnp.int32
EMPTY, LIVE, TOMB, MIGRATED = I32(0), I32(1), I32(2), I32(3)

BACKENDS = ("linear", "twochoice", "chain")


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def batch_winners(keys: jax.Array, mask: jax.Array) -> jax.Array:
    """First masked occurrence of each distinct key wins (deterministic
    linearization of intra-batch duplicate ops)."""
    q = keys.shape[0]
    idx = jnp.arange(q, dtype=I32)
    order = jnp.lexsort((idx, (~mask).astype(I32), keys))
    ks, ms = keys[order], mask[order]
    first = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    win_sorted = ms & first
    return jnp.zeros((q,), bool).at[order].set(win_sorted)


def _argpick(hit: jax.Array, vals: jax.Array, axis: int = -1):
    """Select value at the first True along axis (undefined if none)."""
    i = jnp.argmax(hit, axis=axis)
    return jnp.take_along_axis(vals, i[..., None], axis=axis)[..., 0], i


# ---------------------------------------------------------------------------
# linear: open addressing with linear probing
# ---------------------------------------------------------------------------

@pytree_dataclass(meta_fields=("capacity", "max_probes"))
class LinearTable:
    capacity: int
    max_probes: int
    hfn: hashing.HashFn
    key: jax.Array    # [C] i32
    val: jax.Array    # [C] i32
    state: jax.Array  # [C] i32 (EMPTY/LIVE/TOMB/MIGRATED)


def linear_make(capacity: int, hfn: hashing.HashFn, max_probes: int = 64) -> LinearTable:
    # distinct buffers per field (aliased leaves break jit buffer donation)
    def z():
        return jnp.zeros((capacity,), I32)
    return LinearTable(capacity=capacity, max_probes=max_probes, hfn=hfn,
                       key=z(), val=z(), state=z())


def linear_lookup(t: LinearTable, keys: jax.Array):
    found, val, loc, _ = linear_lookup_fwd(t, keys)
    return found, val, loc


def linear_lookup_fwd(t: LinearTable, keys: jax.Array):
    """Lookup that ALSO reports a MIGRATED-slot key match ("tombstone
    forwarding"): a slot whose entry was pulled into the rebuild's hazard
    buffer still holds its key, so the probe that passes over it identifies
    the hazard entry at zero extra cost — the beyond-paper replacement for
    the O(Q x chunk) hazard broadcast compare (EXPERIMENTS.md §Perf).
    Returns (found, val, loc, mig_loc) with mig_loc = -1 if none."""
    c = t.capacity
    h0 = hashing.bucket_of(t.hfn, keys, c)
    q = keys.shape[0]

    def cond(carry):
        active, i = carry[0], carry[5]
        return active.any() & (i < t.max_probes)

    def body(carry):
        active, found, val, loc, mig, i = carry
        pos = (h0 + i) % c
        st = t.state[pos]
        kmatch = t.key[pos] == keys
        hit = active & (st == LIVE) & kmatch
        mig = jnp.where(active & (st == MIGRATED) & kmatch & (mig < 0),
                        pos, mig)
        stop = active & (st == EMPTY)
        val = jnp.where(hit, t.val[pos], val)
        loc = jnp.where(hit, pos, loc)
        found = found | hit
        active = active & ~hit & ~stop
        return active, found, val, loc, mig, i + 1

    init = (jnp.ones((q,), bool), jnp.zeros((q,), bool),
            jnp.zeros((q,), I32), jnp.full((q,), -1, I32),
            jnp.full((q,), -1, I32), jnp.asarray(0, I32))
    _, found, val, loc, mig, _ = jax.lax.while_loop(cond, body, init)
    return found, val, loc, mig


def linear_insert(t: LinearTable, keys: jax.Array, vals: jax.Array, mask: jax.Array):
    c, q = t.capacity, keys.shape[0]
    winner = batch_winners(keys, mask)
    present, _, _ = linear_lookup(t, keys)
    pending0 = winner & ~present
    h0 = hashing.bucket_of(t.hfn, keys, c)
    idx = jnp.arange(q, dtype=I32)

    def body(_, carry):
        key, val, state, pending, off, done = carry
        pos = (h0 + off) % c
        free = pending & (state[pos] != LIVE)
        wpos = jnp.where(free, pos, c)
        claim = jnp.full((c,), q, I32).at[wpos].min(idx, mode="drop")
        won = free & (claim[pos % c] == idx) & (wpos < c)
        wp = jnp.where(won, pos, c)
        key = key.at[wp].set(keys, mode="drop")
        val = val.at[wp].set(vals, mode="drop")
        state = state.at[wp].set(LIVE, mode="drop")
        done = done | won
        pending = pending & ~won
        off = jnp.where(pending, off + 1, off)
        return key, val, state, pending, off, done

    init = (t.key, t.val, t.state, pending0, jnp.zeros((q,), I32), jnp.zeros((q,), bool))
    key, val, state, _, _, done = jax.lax.fori_loop(0, t.max_probes, body, init)
    t = LinearTable(capacity=c, max_probes=t.max_probes, hfn=t.hfn, key=key, val=val, state=state)
    return t, done


def linear_delete(t: LinearTable, keys: jax.Array, mask: jax.Array):
    winner = batch_winners(keys, mask)
    found, _, loc = linear_lookup(t, keys)
    ok = winner & found
    wloc = jnp.where(ok, loc, t.capacity)
    state = t.state.at[wloc].set(TOMB, mode="drop")
    return LinearTable(capacity=t.capacity, max_probes=t.max_probes, hfn=t.hfn,
                       key=t.key, val=t.val, state=state), ok


def linear_extract_chunk(t: LinearTable, cursor: jax.Array, n: int):
    pos = cursor + jnp.arange(n, dtype=I32)
    valid = pos < t.capacity
    cpos = jnp.where(valid, pos, 0)
    live = valid & (t.state[cpos] == LIVE)
    hkeys = jnp.where(live, t.key[cpos], 0)
    hvals = jnp.where(live, t.val[cpos], 0)
    state = t.state.at[jnp.where(live, cpos, t.capacity)].set(MIGRATED, mode="drop")
    new_cursor = jnp.minimum(cursor + n, t.capacity)
    t = LinearTable(capacity=t.capacity, max_probes=t.max_probes, hfn=t.hfn,
                    key=t.key, val=t.val, state=state)
    return t, hkeys, hvals, live, new_cursor


def linear_count_live(t: LinearTable):
    return jnp.sum(t.state == LIVE)


def linear_clear(t: LinearTable) -> LinearTable:
    z = jnp.zeros((t.capacity,), I32)
    return LinearTable(capacity=t.capacity, max_probes=t.max_probes, hfn=t.hfn,
                       key=z, val=z, state=z)


# -- Pallas-accelerated linear paths (kernels/ops.py): same observable set
# semantics as linear_lookup/linear_insert/linear_delete/linear_extract_chunk,
# hot loop in VMEM ----------------------------------------------------------

def linear_lookup_fused(t: LinearTable, keys: jax.Array, *,
                        interpret: bool = True):
    """Kernel-backed lookup.  Returns (found, vals)."""
    from repro.kernels import ops
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    return ops.probe_lookup(t.key, t.val, t.state, h0, keys,
                            max_probes=t.max_probes, interpret=interpret)


def linear_insert_fused(t: LinearTable, keys: jax.Array, vals: jax.Array,
                        mask: jax.Array, *, interpret: bool = True):
    """Kernel-backed insert: batch_winners dedup (the kernel's caller
    contract), then one claim pass + one scatter instead of the
    O(Q x max_probes) jnp claim loop."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    tk, tv, ts, ok = ops.probe_insert(t.key, t.val, t.state, h0, keys, vals,
                                      winner, max_probes=t.max_probes,
                                      interpret=interpret)
    return LinearTable(capacity=t.capacity, max_probes=t.max_probes,
                       hfn=t.hfn, key=tk, val=tv, state=ts), ok


def linear_delete_fused(t: LinearTable, keys: jax.Array, mask: jax.Array, *,
                        interpret: bool = True):
    """Kernel-backed delete: the location-emitting probe kernel tombstones
    in ONE pass (one sort + one pallas_call + one scatter) instead of the
    jnp lookup-then-scatter double walk."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    state, ok = ops.probe_delete(t.key, t.val, t.state, h0, keys, winner,
                                 max_probes=t.max_probes, interpret=interpret)
    return LinearTable(capacity=t.capacity, max_probes=t.max_probes,
                       hfn=t.hfn, key=t.key, val=t.val, state=state), ok


def linear_extract_chunk_fused(t: LinearTable, cursor: jax.Array, n: int, *,
                               interpret: bool = True):
    """Kernel-backed rebuild chunk scan: one pallas_call over the resident
    slab window + one MIGRATED scatter; hazard entries come back COMPACTED
    (live entries first) rather than position-aligned — identical as a set,
    which is all the hazard protocol observes."""
    from repro.kernels import ops
    if n > ops.SLAB:   # window contract; fall back to the jnp scan
        return linear_extract_chunk(t, cursor, n)
    state, hk, hv, hl, cur = ops.extract_chunk_fused(
        t.key, t.val, t.state, cursor, chunk=n, interpret=interpret)
    t = LinearTable(capacity=t.capacity, max_probes=t.max_probes, hfn=t.hfn,
                    key=t.key, val=t.val, state=state)
    return t, hk, hv, hl, cur


# ---------------------------------------------------------------------------
# twochoice: bucketed 2-choice hashing (W-wide vector buckets)
# ---------------------------------------------------------------------------

@pytree_dataclass(meta_fields=("nbuckets", "width", "max_rounds"))
class TwoChoiceTable:
    nbuckets: int
    width: int
    max_rounds: int
    hfn_a: hashing.HashFn
    hfn_b: hashing.HashFn
    key: jax.Array    # [B, W] i32
    val: jax.Array    # [B, W] i32
    state: jax.Array  # [B, W] i32


def twochoice_make(nbuckets: int, hfn_a: hashing.HashFn, hfn_b: hashing.HashFn,
                   width: int = 8, max_rounds: int = 8) -> TwoChoiceTable:
    def z():
        return jnp.zeros((nbuckets, width), I32)
    return TwoChoiceTable(nbuckets=nbuckets, width=width, max_rounds=max_rounds,
                          hfn_a=hfn_a, hfn_b=hfn_b, key=z(), val=z(), state=z())


def _tc_rows(t: TwoChoiceTable, keys: jax.Array):
    ba = hashing.bucket_of(t.hfn_a, keys, t.nbuckets)
    bb = hashing.bucket_of(t.hfn_b, keys, t.nbuckets)
    return ba, bb


def twochoice_lookup(t: TwoChoiceTable, keys: jax.Array):
    ba, bb = _tc_rows(t, keys)
    hit_a = (t.key[ba] == keys[:, None]) & (t.state[ba] == LIVE)   # [Q, W]
    hit_b = (t.key[bb] == keys[:, None]) & (t.state[bb] == LIVE)
    fa, fb = hit_a.any(-1), hit_b.any(-1)
    va, sa = _argpick(hit_a, t.val[ba])
    vb, sb = _argpick(hit_b, t.val[bb])
    found = fa | fb
    val = jnp.where(fa, va, vb)
    loc = jnp.where(fa, ba * t.width + sa, jnp.where(fb, bb * t.width + sb, -1))
    return found, val, loc


def twochoice_insert(t: TwoChoiceTable, keys: jax.Array, vals: jax.Array, mask: jax.Array):
    b, w, q = t.nbuckets, t.width, keys.shape[0]
    winner = batch_winners(keys, mask)
    present, _, _ = twochoice_lookup(t, keys)
    pending0 = winner & ~present
    ba, bb = _tc_rows(t, keys)
    idx = jnp.arange(q, dtype=I32)
    nslots = b * w

    def body(r, carry):
        key, val, state, pending, done = carry
        bkt = jnp.where(r % 2 == 0, ba, bb)
        row_free = state[bkt] != LIVE                       # [Q, W]
        has_free = pending & row_free.any(-1)
        slot = jnp.argmax(row_free, axis=-1)
        flat = bkt * w + slot
        wflat = jnp.where(has_free, flat, nslots)
        claim = jnp.full((nslots,), q, I32).at[wflat].min(idx, mode="drop")
        won = has_free & (claim[flat % nslots] == idx) & (wflat < nslots)
        wp = jnp.where(won, flat, nslots)
        key = key.reshape(-1).at[wp].set(keys, mode="drop").reshape(b, w)
        val = val.reshape(-1).at[wp].set(vals, mode="drop").reshape(b, w)
        state = state.reshape(-1).at[wp].set(LIVE, mode="drop").reshape(b, w)
        done = done | won
        pending = pending & ~won
        return key, val, state, pending, done

    init = (t.key, t.val, t.state, pending0, jnp.zeros((q,), bool))
    key, val, state, _, done = jax.lax.fori_loop(0, t.max_rounds, body, init)
    t = TwoChoiceTable(nbuckets=b, width=w, max_rounds=t.max_rounds,
                       hfn_a=t.hfn_a, hfn_b=t.hfn_b, key=key, val=val, state=state)
    return t, done


def twochoice_delete(t: TwoChoiceTable, keys: jax.Array, mask: jax.Array):
    winner = batch_winners(keys, mask)
    found, _, loc = twochoice_lookup(t, keys)
    ok = winner & found
    wloc = jnp.where(ok, loc, t.nbuckets * t.width)
    state = t.state.reshape(-1).at[wloc].set(TOMB, mode="drop").reshape(t.nbuckets, t.width)
    return TwoChoiceTable(nbuckets=t.nbuckets, width=t.width, max_rounds=t.max_rounds,
                          hfn_a=t.hfn_a, hfn_b=t.hfn_b, key=t.key, val=t.val, state=state), ok


def twochoice_extract_chunk(t: TwoChoiceTable, cursor: jax.Array, n: int):
    nslots = t.nbuckets * t.width
    pos = cursor + jnp.arange(n, dtype=I32)
    valid = pos < nslots
    cpos = jnp.where(valid, pos, 0)
    ks, vs, ss = t.key.reshape(-1), t.val.reshape(-1), t.state.reshape(-1)
    live = valid & (ss[cpos] == LIVE)
    hkeys = jnp.where(live, ks[cpos], 0)
    hvals = jnp.where(live, vs[cpos], 0)
    ss = ss.at[jnp.where(live, cpos, nslots)].set(MIGRATED, mode="drop")
    new_cursor = jnp.minimum(cursor + n, nslots)
    t = TwoChoiceTable(nbuckets=t.nbuckets, width=t.width, max_rounds=t.max_rounds,
                       hfn_a=t.hfn_a, hfn_b=t.hfn_b, key=t.key, val=t.val,
                       state=ss.reshape(t.nbuckets, t.width))
    return t, hkeys, hvals, live, new_cursor


def twochoice_count_live(t: TwoChoiceTable):
    return jnp.sum(t.state == LIVE)


def twochoice_clear(t: TwoChoiceTable) -> TwoChoiceTable:
    z = jnp.zeros((t.nbuckets, t.width), I32)
    return TwoChoiceTable(nbuckets=t.nbuckets, width=t.width,
                          max_rounds=t.max_rounds, hfn_a=t.hfn_a,
                          hfn_b=t.hfn_b, key=z, val=z, state=z)


# -- Pallas-accelerated twochoice paths (kernels/ops.py): both row choices
# of a query become two entries of ONE sorted batch — one argsort + one
# pallas_call replace the [Q, W] double-row gathers --------------------------

def twochoice_lookup_fused(t: TwoChoiceTable, keys: jax.Array, *,
                           interpret: bool = True):
    """Kernel-backed 2-choice lookup.  Returns (found, vals, loc) — the same
    triple as ``twochoice_lookup`` so the delete path can reuse ``loc``."""
    from repro.kernels import ops
    ba, bb = _tc_rows(t, keys)
    return ops.twochoice_lookup(t.key, t.val, t.state, ba, bb, keys,
                                interpret=interpret)


def twochoice_insert_fused(t: TwoChoiceTable, keys: jax.Array,
                           vals: jax.Array, mask: jax.Array, *,
                           interpret: bool = True):
    """Kernel-backed 2-choice insert: batch_winners dedup, then one claim
    pass + one scatter (a-row claims shadow b-row claims of the same
    query)."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    ba, bb = _tc_rows(t, keys)
    tk, tv, ts, ok = ops.twochoice_insert(t.key, t.val, t.state, ba, bb,
                                          keys, vals, winner,
                                          max_rounds=t.max_rounds,
                                          interpret=interpret)
    return TwoChoiceTable(nbuckets=t.nbuckets, width=t.width,
                          max_rounds=t.max_rounds, hfn_a=t.hfn_a,
                          hfn_b=t.hfn_b, key=tk, val=tv, state=ts), ok


def twochoice_delete_fused(t: TwoChoiceTable, keys: jax.Array,
                           mask: jax.Array, *, interpret: bool = True):
    """Kernel-backed 2-choice delete: reuses the fused lookup's location
    output — one kernel pass + one tombstone scatter, instead of the jnp
    path's full second ``twochoice_lookup`` row-gather probe."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    ba, bb = _tc_rows(t, keys)
    state, ok = ops.twochoice_delete(t.key, t.val, t.state, ba, bb, keys,
                                     winner, interpret=interpret)
    return TwoChoiceTable(nbuckets=t.nbuckets, width=t.width,
                          max_rounds=t.max_rounds, hfn_a=t.hfn_a,
                          hfn_b=t.hfn_b, key=t.key, val=t.val, state=state), ok


def twochoice_ordered_lookup_fused(t_old: TwoChoiceTable,
                                   t_new: TwoChoiceTable,
                                   hazard_key: jax.Array,
                                   hazard_val: jax.Array,
                                   hazard_live: jax.Array,
                                   keys: jax.Array, *,
                                   interpret: bool = True):
    """Kernel-backed twochoice rebuild-epoch lookup: the whole ordered check
    (old -> hazard -> new, Lemma 4.1) in ONE argsort + ONE probe2-style
    pallas_call — previously two composed fused single-table passes.
    Returns (found, vals)."""
    from repro.kernels import ops
    ba_o, bb_o = _tc_rows(t_old, keys)
    ba_n, bb_n = _tc_rows(t_new, keys)
    return ops.twochoice_ordered_lookup(
        (t_old.key, t_old.val, t_old.state),
        (t_new.key, t_new.val, t_new.state),
        hazard_key, hazard_val, hazard_live,
        ba_o, bb_o, ba_n, bb_n, keys, interpret=interpret)


def twochoice_ordered_delete_fused(t_old: TwoChoiceTable,
                                   t_new: TwoChoiceTable,
                                   hazard_key: jax.Array,
                                   hazard_val: jax.Array,
                                   hazard_live: jax.Array,
                                   keys: jax.Array, mask: jax.Array, *,
                                   interpret: bool = True):
    """Kernel-backed twochoice rebuild-epoch delete (paper Alg. 5): the SAME
    single tc_probe2 pass resolves old-slot / hazard-index / new-slot;
    three scatters land the result.  Returns the raw
    (old_state', new_state', hazard_live', ok[Q]) — the dhash layer
    reassembles its pytrees."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    ba_o, bb_o = _tc_rows(t_old, keys)
    ba_n, bb_n = _tc_rows(t_new, keys)
    return ops.twochoice_ordered_delete(
        (t_old.key, t_old.val, t_old.state),
        (t_new.key, t_new.val, t_new.state),
        hazard_key, hazard_val, hazard_live,
        ba_o, bb_o, ba_n, bb_n, keys, winner, interpret=interpret)


def twochoice_extract_chunk_fused(t: TwoChoiceTable, cursor: jax.Array,
                                  n: int, *, interpret: bool = True):
    """Kernel-backed 2-choice rebuild chunk scan: the extract kernel runs on
    the row-major flattened arrays (the scan order is identical)."""
    from repro.kernels import ops
    if n > ops.SLAB:
        return twochoice_extract_chunk(t, cursor, n)
    state, hk, hv, hl, cur = ops.extract_chunk_fused(
        t.key.reshape(-1), t.val.reshape(-1), t.state.reshape(-1), cursor,
        chunk=n, interpret=interpret)
    t = TwoChoiceTable(nbuckets=t.nbuckets, width=t.width,
                       max_rounds=t.max_rounds, hfn_a=t.hfn_a, hfn_b=t.hfn_b,
                       key=t.key, val=t.val,
                       state=state.reshape(t.nbuckets, t.width))
    return t, hk, hv, hl, cur


# ---------------------------------------------------------------------------
# chain: arena-based chained buckets (paper-faithful Michael-list analogue)
# ---------------------------------------------------------------------------

@pytree_dataclass(meta_fields=("nbuckets", "arena", "max_chain"))
class ChainTable:
    nbuckets: int
    arena: int        # node capacity N
    max_chain: int    # traversal bound (>= max expected chain incl. tombstones)
    hfn: hashing.HashFn
    akey: jax.Array   # [N] i32
    aval: jax.Array   # [N] i32
    anext: jax.Array  # [N] i32 (-1 terminates)
    astate: jax.Array # [N] i32
    heads: jax.Array  # [B] i32 (-1 empty)
    free_stack: jax.Array  # [N] i32 - free node indices live at [0, free_top)
    free_top: jax.Array    # scalar i32


def chain_make(nbuckets: int, arena: int, hfn: hashing.HashFn, max_chain: int = 64) -> ChainTable:
    n = arena
    return ChainTable(
        nbuckets=nbuckets, arena=n, max_chain=max_chain, hfn=hfn,
        akey=jnp.zeros((n,), I32), aval=jnp.zeros((n,), I32),
        anext=jnp.full((n,), -1, I32), astate=jnp.zeros((n,), I32),
        heads=jnp.full((nbuckets,), -1, I32),
        free_stack=jnp.arange(n, dtype=I32), free_top=jnp.asarray(n, I32))


def chain_lookup(t: ChainTable, keys: jax.Array, bucket: jax.Array | None = None):
    """Lock-step batched traversal with DYNAMIC termination: the step cost is
    the longest still-active chain in the batch, not the static bound — so
    collision attacks show up in wall time exactly as they do on the paper's
    pointer-chasing implementations."""
    q = keys.shape[0]
    b = hashing.bucket_of(t.hfn, keys, t.nbuckets) if bucket is None else bucket
    cur0 = t.heads[b]

    def cond(carry):
        cur, found, _, _, fuel = carry
        return ((cur >= 0) & ~found).any() & (fuel > 0)

    def body(carry):
        cur, found, val, loc, fuel = carry
        valid = cur >= 0
        c = jnp.where(valid, cur, 0)
        hit = valid & (t.astate[c] == LIVE) & (t.akey[c] == keys) & ~found
        val = jnp.where(hit, t.aval[c], val)
        loc = jnp.where(hit, cur, loc)
        found = found | hit
        step = valid & ~found
        cur = jnp.where(step, t.anext[c], jnp.where(found, cur, -1))
        return cur, found, val, loc, fuel - 1

    init = (cur0, jnp.zeros((q,), bool), jnp.zeros((q,), I32),
            jnp.full((q,), -1, I32), jnp.asarray(t.max_chain, I32))
    _, found, val, loc, _ = jax.lax.while_loop(cond, body, init)
    return found, val, loc


def _chain_link(t: ChainTable, keys, node, can, bucket: jax.Array | None = None):
    """Insert nodes ``node`` (where can) at the heads of their buckets,
    preserving original-index order within each bucket group."""
    q = keys.shape[0]
    b = hashing.bucket_of(t.hfn, keys, t.nbuckets) if bucket is None else bucket
    sortkey = jnp.where(can, b, t.nbuckets)
    idx = jnp.arange(q, dtype=I32)
    order = jnp.lexsort((idx, sortkey))
    sb, snode, scan = sortkey[order], node[order], can[order]
    nxt_same = jnp.concatenate([snode[1:], jnp.full((1,), -1, I32)])
    same_bucket = jnp.concatenate([sb[1:] == sb[:-1], jnp.zeros((1,), bool)])
    old_head = t.heads[jnp.where(scan, sb, 0)]
    nxt = jnp.where(same_bucket, nxt_same, jnp.where(scan, old_head, -1))
    anext = t.anext.at[jnp.where(scan, snode, t.arena)].set(nxt, mode="drop")
    is_start = jnp.concatenate([jnp.ones((1,), bool), sb[1:] != sb[:-1]])
    heads = t.heads.at[jnp.where(scan & is_start, sb, t.nbuckets)].set(snode, mode="drop")
    return anext, heads


def chain_insert(t: ChainTable, keys: jax.Array, vals: jax.Array, mask: jax.Array,
                 bucket: jax.Array | None = None):
    q, n = keys.shape[0], t.arena
    winner = batch_winners(keys, mask)
    present, _, _ = chain_lookup(t, keys, bucket)
    want = winner & ~present
    rank = jnp.cumsum(want.astype(I32)) - 1
    can = want & (rank < t.free_top)
    node = t.free_stack[jnp.where(can, t.free_top - 1 - rank, 0)]
    wnode = jnp.where(can, node, n)
    akey = t.akey.at[wnode].set(keys, mode="drop")
    aval = t.aval.at[wnode].set(vals, mode="drop")
    astate = t.astate.at[wnode].set(LIVE, mode="drop")
    t1 = ChainTable(nbuckets=t.nbuckets, arena=n, max_chain=t.max_chain, hfn=t.hfn,
                    akey=akey, aval=aval, anext=t.anext, astate=astate,
                    heads=t.heads, free_stack=t.free_stack, free_top=t.free_top)
    anext, heads = _chain_link(t1, keys, node, can, bucket)
    free_used = jnp.sum(can.astype(I32))
    t2 = ChainTable(nbuckets=t.nbuckets, arena=n, max_chain=t.max_chain, hfn=t.hfn,
                    akey=akey, aval=aval, anext=anext, astate=astate,
                    heads=heads, free_stack=t.free_stack, free_top=t.free_top - free_used)
    return t2, can


def chain_delete(t: ChainTable, keys: jax.Array, mask: jax.Array,
                 bucket: jax.Array | None = None):
    winner = batch_winners(keys, mask)
    found, _, loc = chain_lookup(t, keys, bucket)
    ok = winner & found
    wloc = jnp.where(ok, loc, t.arena)
    astate = t.astate.at[wloc].set(TOMB, mode="drop")
    return ChainTable(nbuckets=t.nbuckets, arena=t.arena, max_chain=t.max_chain, hfn=t.hfn,
                      akey=t.akey, aval=t.aval, anext=t.anext, astate=astate,
                      heads=t.heads, free_stack=t.free_stack, free_top=t.free_top), ok


def chain_extract_chunk(t: ChainTable, cursor: jax.Array, n: int):
    pos = cursor + jnp.arange(n, dtype=I32)
    valid = pos < t.arena
    cpos = jnp.where(valid, pos, 0)
    live = valid & (t.astate[cpos] == LIVE)
    hkeys = jnp.where(live, t.akey[cpos], 0)
    hvals = jnp.where(live, t.aval[cpos], 0)
    astate = t.astate.at[jnp.where(live, cpos, t.arena)].set(MIGRATED, mode="drop")
    new_cursor = jnp.minimum(cursor + n, t.arena)
    t = ChainTable(nbuckets=t.nbuckets, arena=t.arena, max_chain=t.max_chain, hfn=t.hfn,
                   akey=t.akey, aval=t.aval, anext=t.anext, astate=astate,
                   heads=t.heads, free_stack=t.free_stack, free_top=t.free_top)
    return t, hkeys, hvals, live, new_cursor


def chain_compact(t: ChainTable) -> ChainTable:
    """Physically reclaim tombstones: rebuild all chains from live nodes.

    The paper defers physical unlinking to later traversals / call_rcu; the
    batched analogue is a periodic vectorized compaction (also doubles as the
    post-rebuild reclamation of the old arena)."""
    live = t.astate == LIVE
    fresh = chain_make(t.nbuckets, t.arena, t.hfn, t.max_chain)
    t2, _ = chain_insert(fresh, jnp.where(live, t.akey, 0), t.aval, live)
    return t2


def chain_count_live(t: ChainTable):
    return jnp.sum(t.astate == LIVE)


def chain_clear(t: ChainTable) -> ChainTable:
    n = t.arena
    return ChainTable(
        nbuckets=t.nbuckets, arena=n, max_chain=t.max_chain, hfn=t.hfn,
        akey=jnp.zeros((n,), I32), aval=jnp.zeros((n,), I32),
        anext=jnp.full((n,), -1, I32), astate=jnp.zeros((n,), I32),
        heads=jnp.full((t.nbuckets,), -1, I32),
        free_stack=jnp.arange(n, dtype=I32), free_top=jnp.asarray(n, I32))


# ---------------------------------------------------------------------------
# dispatch facade
# ---------------------------------------------------------------------------

_OPS: dict[str, dict[str, Any]] = {
    "linear": dict(lookup=linear_lookup, insert=linear_insert, delete=linear_delete,
                   extract_chunk=linear_extract_chunk, count_live=linear_count_live,
                   clear=linear_clear),
    "twochoice": dict(lookup=twochoice_lookup, insert=twochoice_insert, delete=twochoice_delete,
                      extract_chunk=twochoice_extract_chunk, count_live=twochoice_count_live,
                      clear=twochoice_clear),
    "chain": dict(lookup=chain_lookup, insert=chain_insert, delete=chain_delete,
                  extract_chunk=chain_extract_chunk, count_live=chain_count_live,
                  clear=chain_clear),
}


def backend_of(table) -> str:
    if isinstance(table, LinearTable):
        return "linear"
    if isinstance(table, TwoChoiceTable):
        return "twochoice"
    if isinstance(table, ChainTable):
        return "chain"
    raise TypeError(type(table))


def lookup(t, keys):
    return _OPS[backend_of(t)]["lookup"](t, keys)


def insert(t, keys, vals, mask):
    return _OPS[backend_of(t)]["insert"](t, keys, vals, mask)


def delete(t, keys, mask):
    return _OPS[backend_of(t)]["delete"](t, keys, mask)


def extract_chunk(t, cursor, n):
    return _OPS[backend_of(t)]["extract_chunk"](t, cursor, n)


def count_live(t):
    return _OPS[backend_of(t)]["count_live"](t)


def clear(t):
    """Empty the table in place (shape/hash-function preserving, jittable) —
    the on-device reset of a drained table before it becomes the next rebuild
    target."""
    return _OPS[backend_of(t)]["clear"](t)


def capacity_of(t) -> int:
    if isinstance(t, LinearTable):
        return t.capacity
    if isinstance(t, TwoChoiceTable):
        return t.nbuckets * t.width
    if isinstance(t, ChainTable):
        return t.arena
    raise TypeError(type(t))
