"""Per-architecture smoke tests (deliverable f): every assigned arch's
REDUCED config runs one forward/train step on CPU — output shapes + no NaNs —
plus one decode step where the family has one."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model, transformer
from repro.optim.optimizer import OptConfig
from repro.train import train_step as ts

B, S = 2, 64


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "stub_embed":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.float32)
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch["positions"] = jnp.stack([pos, pos, pos])
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = configs.get_smoke(arch)
    opt_cfg = OptConfig(total_steps=10, warmup_steps=1)
    state = ts.init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    from functools import partial
    step = jax.jit(partial(ts.train_step, cfg=cfg, opt_cfg=opt_cfg))
    state, metrics = step(state, batch)
    loss = float(jax.device_get(metrics["loss"]))
    assert np.isfinite(loss), (arch, loss)
    gn = float(jax.device_get(metrics["grad_norm"]))
    assert np.isfinite(gn) and gn > 0, (arch, gn)
    # params updated, no NaNs anywhere
    for leaf in jax.tree_util.tree_leaves(state["params"]):
        assert np.isfinite(np.asarray(leaf)).all(), arch
    # second step: loss still finite (optimizer state sane)
    state, metrics = step(state, _batch(cfg, jax.random.PRNGKey(2)))
    assert np.isfinite(float(jax.device_get(metrics["loss"]))), arch


@pytest.mark.parametrize("arch", [a for a in configs.ARCH_IDS
                                  if not configs.get_smoke(a).encoder_only])
def test_decode_step_smoke(arch):
    cfg = configs.get_smoke(arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    cache = transformer.init_cache(cfg, B, 32)
    if cfg.frontend == "stub_embed":
        tok = jax.random.normal(jax.random.PRNGKey(1), (B, 1, cfg.d_model),
                                jnp.float32)
    else:
        tok = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0,
                                 cfg.vocab_size)
    logits, cache = jax.jit(model.decode_logits, static_argnums=1)(
        params, cfg, tok, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch
    assert int(cache["len"][0]) == 1
    # a second token advances the caches
    logits2, cache = jax.jit(model.decode_logits, static_argnums=1)(
        params, cfg, tok, cache)
    assert int(cache["len"][0]) == 2
    assert np.isfinite(np.asarray(logits2)).all(), arch


@pytest.mark.parametrize("arch", ["deepseek-67b", "zamba2-1.2b", "rwkv6-3b"])
def test_decode_matches_train_forward(arch):
    """Teacher-forced decode must reproduce the training forward's logits
    (cache correctness, causality)."""
    cfg = configs.get_smoke(arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 1, cfg.vocab_size)
    hidden, _ = transformer.forward_train(params, cfg, {"tokens": toks})
    w = transformer.unembed_matrix(params, cfg)
    ref_logits = jnp.einsum("bsd,dv->bsv", hidden, w)
    cache = transformer.init_cache(cfg, 1, 16)
    outs = []
    for i in range(8):
        lg, cache = jax.jit(model.decode_logits, static_argnums=1)(
            params, cfg, toks[:, i: i + 1], cache)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(ref_logits),
                               rtol=2e-2, atol=2e-2)
