"""Distributed DHash: the table sharded over a mesh axis.

Ownership is by a *fixed* owner hash (never rebuilt): shard s owns key k iff
``owner_hash(k) % S == s``.  Rebuilds swap each shard's *local* hash function;
because every shard executes the same transition stream (SPMD), the epoch
swap is collectively synchronized for free — the multi-host analogue of the
paper's ``synchronize_rcu`` grace period.

Query routing is one all_to_all pair (there and back), the same dispatch
pattern as MoE token routing.  The send-buffer layout is a **two-pass
counting sort** (HashGraph's idiom): pass 1 histograms keys per owner and
ranks each key within its owner; pass 2 scatters keys into exactly-sized
per-owner segments of a ``[S, cap]`` buffer.  With a fixed per-owner cap
the exclusive prefix sum over the capped histogram is the affine map
``base[s] = s * cap`` — i.e. the row offsets of the 2-D buffer — so no
argsort is ever needed: the router contributes ZERO ``sort`` primitives
and the owner-grouped buffer feeds the fused kernels' own bucket sort
directly (a routed fused ``stack_lookup`` stays at ONE sort + ONE
pallas_call total, the same budget as an unrouted op).

``cap=None`` (baseline) uses cap=Q — overflow-proof even under a fully
adversarial key set (every key owned by one shard — the paper's collision
attack) at S x the wire bytes.  The capped path uses
``cap = ceil(c·Q/S)``; keys past an owner's cap are reported via EXACT
per-owner overflow counts so callers can run a bounded full-width retry
(see serving/kvcache.py) instead of silently dropping them.

These functions are written to be called INSIDE ``jax.shard_map`` with the
table sharded (one leaf-shard per device along ``axis``) and queries sharded
along their batch dim.  Every shard-local table op dispatches through the
``BucketBackend`` descriptor registry (core/backend.py), so any registered
backend — fused or jnp — shards without changes here.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import dhash, hashing

I32 = jnp.int32


def _axis_size(axis) -> int:
    """Static mesh-axis size, tolerant of the jax API move: ``lax.axis_size``
    arrived after 0.5; on older releases ``psum(1, axis)`` constant-folds to
    the same Python int."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return lax.psum(1, axis)


class Route(NamedTuple):
    """The routing layout of one batch: the [S, cap] send buffers plus the
    per-key coordinates that invert them, and exact overflow accounting."""
    send: jax.Array      # [S, cap] keys, owner-grouped, zero-padded
    smask: jax.Array     # [S, cap] bool: slot carries a kept key
    owner: jax.Array     # [Q] i32 owner of each key (batch order)
    rank: jax.Array      # [Q] i32 arrival rank within its owner (stable)
    kept: jax.Array      # [Q] bool: rank < cap (routed on the first pass)
    overflow: jax.Array  # [S] i32 EXACT per-owner spill: max(hist - cap, 0)


def route_cap(cap_factor: float, q: int, nshards: int) -> int:
    """The capped-dispatch buffer width ``cap = ceil(c·Q/S)``, clamped to
    [1, Q].  ``cap_factor <= 0`` means the overflow-proof full width."""
    if cap_factor <= 0:
        return q
    return min(q, max(1, -(-int(cap_factor * q) // nshards)))


def _route(keys: jax.Array, owner: jax.Array, nshards: int,
           cap: int | None = None) -> Route:
    """Group keys by owner into a [S, cap] send buffer — two-pass counting
    sort, no ``sort`` primitive:

    * pass 1: per-owner histogram + stable rank-within-owner via a running
      one-hot count (O(Q·S) vectorized work, the MoE dispatch idiom —
      cheap for mesh/tenant-scale S, and it removes the router's argsort
      from every routed op's budget);
    * pass 2: scatter key i to ``send[owner[i], rank[i]]`` — with a fixed
      cap the exclusive prefix sum of the capped histogram is the row
      stride, so the 2-D scatter IS the prefix-summed placement.

    Keys with ``rank >= cap`` are NOT silently zeroed: ``kept`` marks them
    and ``overflow[s] = max(hist[s] - cap, 0)`` counts them exactly, so
    callers can cond-gate a full-width retry on ``overflow.sum() > 0``.
    """
    q = keys.shape[0]
    cap = q if cap is None else cap
    owner = owner.astype(I32)
    onehot = (owner[:, None] == jnp.arange(nshards, dtype=I32)[None, :]
              ).astype(I32)
    hist = onehot.sum(axis=0)                                     # [S]
    rank = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                               owner[:, None], axis=1)[:, 0]      # [Q]
    kept = rank < cap
    # out-of-cap ranks scatter out of bounds and mode="drop" discards them
    send = jnp.zeros((nshards, cap), keys.dtype).at[owner, rank].set(
        keys, mode="drop")
    smask = jnp.zeros((nshards, cap), bool).at[owner, rank].set(
        kept, mode="drop")
    overflow = jnp.maximum(hist - cap, 0)
    return Route(send, smask, owner, rank, kept, overflow)


def _route_payload(payload: jax.Array, rt: Route) -> jax.Array:
    """Scatter a per-key payload (values, masks) into the [S, cap] layout
    of a ``Route`` computed for the same batch — spilled keys (beyond an
    owner's cap) stay zero.  Shared by the distributed router and the
    serving tenant router."""
    nshards, cap = rt.send.shape
    return jnp.zeros((nshards, cap), payload.dtype).at[rt.owner, rt.rank].set(
        payload, mode="drop")


def _unroute(resp_local: jax.Array, rt: Route, fill=None) -> jax.Array:
    """Invert a ``Route`` for a [S, cap] response: gather each key's slot
    back to batch order.  Spilled keys take ``fill`` — by default 0 for
    integer/bool responses and NaN for floats, so a dropped float payload
    can never be mistaken for a real 0.0 value."""
    if fill is None:
        fill = jnp.nan if jnp.issubdtype(resp_local.dtype, jnp.floating) else 0
    gathered = resp_local[rt.owner, jnp.where(rt.kept, rt.rank, 0)]
    return jnp.where(rt.kept, gathered, jnp.asarray(fill, resp_local.dtype))


def shard_of(keys: jax.Array, nshards: int,
             owner_hfn: hashing.HashFn) -> jax.Array:
    """Owning shard of each key under the FIXED (never-rebuilt) owner hash."""
    return (hashing.hash_u32(owner_hfn, keys) % jnp.uint32(nshards)).astype(I32)


def routed_lookup(d: dhash.DHashState, keys: jax.Array, axis: str,
                  owner_hfn: hashing.HashFn, cap: int | None = None):
    """DHash lookup across shards. Call inside shard_map."""
    s = _axis_size(axis)
    owner = shard_of(keys, s, owner_hfn)
    rt = _route(keys, owner, s, cap)
    c = rt.send.shape[1]
    rk = lax.all_to_all(rt.send, axis, split_axis=0, concat_axis=0)
    rm = lax.all_to_all(rt.smask, axis, split_axis=0, concat_axis=0)
    found, vals = dhash.lookup(d, rk.reshape(-1))
    found = found & rm.reshape(-1)
    rf = lax.all_to_all(found.reshape(s, c), axis, split_axis=0, concat_axis=0)
    rv = lax.all_to_all(vals.reshape(s, c), axis, split_axis=0, concat_axis=0)
    return _unroute(rf, rt, fill=False).astype(bool), _unroute(rv, rt, fill=0)


def routed_update(d: dhash.DHashState, keys: jax.Array, vals: jax.Array,
                  mask: jax.Array, axis: str, owner_hfn: hashing.HashFn,
                  op: Callable = dhash.insert, cap: int | None = None):
    """DHash insert/delete across shards. Returns (d', ok). Call inside shard_map."""
    s = _axis_size(axis)
    owner = shard_of(keys, s, owner_hfn)
    rt = _route(keys, owner, s, cap)
    c = rt.send.shape[1]
    sendv = _route_payload(vals, rt)
    sm2 = _route_payload(mask, rt)
    rk = lax.all_to_all(rt.send, axis, split_axis=0, concat_axis=0)
    rv = lax.all_to_all(sendv, axis, split_axis=0, concat_axis=0)
    rm = lax.all_to_all(sm2, axis, split_axis=0, concat_axis=0)
    if op is dhash.insert:
        d, ok = op(d, rk.reshape(-1), rv.reshape(-1), rm.reshape(-1))
    else:
        d, ok = op(d, rk.reshape(-1), rm.reshape(-1))
    rok = lax.all_to_all(ok.reshape(s, c), axis, split_axis=0, concat_axis=0)
    return d, _unroute(rok, rt, fill=False).astype(bool)


def routed_rebuild_step(d: dhash.DHashState, axis: str) -> dhash.DHashState:
    """One rebuild transition on every shard (SPMD-synchronized epochs)."""
    return dhash.rebuild_step(d)


# -- mesh x stack: the [S shards x T tenants] grid ---------------------------
#
# Owner of a key is the PAIR (shard_of(key), tenant): flat owner id
# ``shard * T + tenant`` routes through ONE capped all_to_all pair into
# per-shard tenant stacks.  Each shard holds a ``dhash.make_stack(T, ...)``
# whose per-tenant rebuild epochs stay fully independent (the stack ops
# don't change under routing); the received buffer is reshaped
# tenant-major so one vmapped stack op serves every (source shard, tenant)
# cell at once.  The router itself is sort-free, so the whole routed fused
# stack op keeps the single-op kernel budget: ONE sort + ONE pallas_call.


def grid_owner(keys: jax.Array, tenant: jax.Array, nshards: int,
               ntenants: int, owner_hfn: hashing.HashFn) -> jax.Array:
    """Flat [S·T] owner id of each key: ``shard_of(key) * T + tenant``."""
    return shard_of(keys, nshards, owner_hfn) * ntenants + tenant.astype(I32)


def _grid_exchange(buf: jax.Array, axis: str, s: int, t: int, cap: int):
    """all_to_all a [S*T, cap] owner-major buffer and return it tenant-major
    [T, S*cap] for the stack op (each row = one tenant's queries from every
    source shard)."""
    rx = lax.all_to_all(buf.reshape(s, t, cap), axis,
                        split_axis=0, concat_axis=0)      # [src S, T, cap]
    return rx.transpose(1, 0, 2).reshape(t, s * cap)


def _grid_return(resp: jax.Array, axis: str, s: int, t: int, cap: int):
    """Inverse of ``_grid_exchange`` for a [T, S*cap] response: back to the
    querying shards, owner-major [S*T, cap]."""
    tx = resp.reshape(t, s, cap).transpose(1, 0, 2)       # [src S, T, cap]
    return lax.all_to_all(tx, axis, split_axis=0,
                          concat_axis=0).reshape(s * t, cap)


def routed_stack_lookup(d: dhash.DHashState, keys: jax.Array,
                        tenant: jax.Array, axis: str,
                        owner_hfn: hashing.HashFn,
                        cap_factor: float = 2.0):
    """Lookup a [Q] batch against the S×T grid.  ``d`` is THIS shard's
    T-table tenant stack; call inside shard_map.  Returns
    (found[Q], vals[Q], overflow[S·T]) — ``overflow`` is this shard's exact
    per-owner spill count (keys past ``cap = ceil(c·Q/(S·T))``, reported
    not silently dropped; spilled keys come back not-found)."""
    s = _axis_size(axis)
    t = dhash.stack_size(d)
    q = keys.shape[0]
    cap = route_cap(cap_factor, q, s * t)
    rt = _route(keys, grid_owner(keys, tenant, s, t, owner_hfn), s * t, cap)
    qk = _grid_exchange(rt.send, axis, s, t, cap)
    qm = _grid_exchange(rt.smask, axis, s, t, cap)
    f, v = dhash.stack_lookup(d, qk, qm)
    rf = _grid_return(f, axis, s, t, cap)
    rv = _grid_return(v, axis, s, t, cap)
    return (_unroute(rf, rt, fill=False).astype(bool),
            _unroute(rv, rt, fill=0), rt.overflow)


def routed_stack_update(d: dhash.DHashState, keys: jax.Array,
                        vals: jax.Array, mask: jax.Array, tenant: jax.Array,
                        axis: str, owner_hfn: hashing.HashFn,
                        op: Callable = dhash.stack_insert,
                        cap_factor: float = 2.0):
    """Insert/delete a [Q] batch into the S×T grid (``op`` is
    ``dhash.stack_insert`` or ``dhash.stack_delete``).  Returns
    (d', ok[Q], overflow[S·T]); spilled keys report ok=False and are
    counted in ``overflow``.  Call inside shard_map."""
    s = _axis_size(axis)
    t = dhash.stack_size(d)
    q = keys.shape[0]
    cap = route_cap(cap_factor, q, s * t)
    rt = _route(keys, grid_owner(keys, tenant, s, t, owner_hfn), s * t, cap)
    qk = _grid_exchange(rt.send, axis, s, t, cap)
    qm = _grid_exchange(_route_payload(mask, rt) & rt.smask, axis, s, t, cap)
    if op is dhash.stack_insert:
        qv = _grid_exchange(_route_payload(vals, rt), axis, s, t, cap)
        d, ok = op(d, qk, qv, qm)
    else:
        d, ok = op(d, qk, qm)
    rok = _grid_return(ok, axis, s, t, cap)
    return d, _unroute(rok, rt, fill=False).astype(bool), rt.overflow


def make_stacked(nshards: int, backend: str = "linear", capacity: int = 1024,
                 *, chunk: int = 256, seed: int = 0, **kw) -> dhash.DHashState:
    """Build ``nshards`` independent shard tables stacked on a leading axis
    (``dhash.make_stack`` — the same uniform-pytree stack the vmap ops
    batch; here the leading axis is sharded over the mesh instead).

    Shard the leading axis over the mesh axis, then inside shard_map peel it
    with ``tree_map(lambda x: x[0], stacked)`` — see ``shardwise``.
    """
    return dhash.make_stack(nshards, backend, capacity, chunk=chunk,
                            seed=seed, **kw)


def peel(stacked):
    """Inside shard_map: view this shard's table (leading axis is size 1)."""
    return jax.tree_util.tree_map(lambda x: x[0], stacked)


def unpeel(d):
    """Inverse of peel for returning the updated shard."""
    return jax.tree_util.tree_map(lambda x: x[None], d)


def routed_service_step(d: dhash.DHashState, lookup_keys: jax.Array,
                        ins_keys: jax.Array, ins_vals: jax.Array,
                        del_keys: jax.Array, axis: str,
                        owner_hfn: hashing.HashFn, cap_factor: float = 0.0):
    """The paper's steady-state workload as one fused distributed step:
    a lookup batch + insert batch + delete batch + one rebuild transition.
    This is what the dry-run lowers for the dhash_paper 'architecture'.

    cap_factor > 0 bounds the routing buffers at cap = ceil(cap_factor*Q/S)
    (§Perf lever: S x fewer wire bytes and S x smaller remote batches)."""
    s = _axis_size(axis)
    capof = (lambda q: route_cap(cap_factor, q, s)) if cap_factor > 0 \
        else (lambda q: None)
    found, vals = routed_lookup(d, lookup_keys, axis, owner_hfn,
                                cap=capof(lookup_keys.shape[0]))
    d, ok_i = routed_update(d, ins_keys, ins_vals,
                            jnp.ones(ins_keys.shape, bool), axis, owner_hfn,
                            op=dhash.insert, cap=capof(ins_keys.shape[0]))
    d, ok_d = routed_update(d, del_keys, del_keys,
                            jnp.ones(del_keys.shape, bool), axis, owner_hfn,
                            op=dhash.delete, cap=capof(del_keys.shape[0]))
    d = dhash.rebuild_step(d)
    stats = jnp.stack([found.sum(dtype=I32), ok_i.sum(dtype=I32), ok_d.sum(dtype=I32)])
    return d, (found, vals, stats)
