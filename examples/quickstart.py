"""Quickstart: the DHash public API in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Covers: building a table, batched ops, a live hash-function rebuild with
traffic flowing, and the modular backends (the paper's three design goals).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dhash
from repro.core.engine import DHashEngine


def main():
    # --- a table with the default TPU-native linear backend ---------------
    d = dhash.make("linear", capacity=4096, chunk=256, seed=0)
    keys = jnp.arange(1, 1001, dtype=jnp.int32)
    d, ok = jax.jit(dhash.insert)(d, keys, keys * 7)
    print(f"inserted {int(ok.sum())} keys")
    found, vals = jax.jit(dhash.lookup)(d, keys[:5])
    print("lookup(1..5) ->", np.asarray(vals))
    d, ok = jax.jit(dhash.delete)(d, keys[:500])
    print(f"deleted {int(ok.sum())}; live items = {int(dhash.count_items(d))}")

    # --- the paper's feature: swap the hash function LIVE ------------------
    d = dhash.rebuild_start(d, seed=1234)          # fresh seeded function
    step = jax.jit(dhash.rebuild_chunk)
    while not bool(jax.device_get(dhash.rebuild_done(d))):
        d = step(d)                                # one chunk per step...
        f, _ = jax.jit(dhash.lookup)(d, keys[500:505])
        assert bool(f.all())                       # ...lookups never blocked
    d = dhash.rebuild_finish(d)
    print(f"rebuilt live -> epoch {int(d.epoch)}, items {int(dhash.count_items(d))}")

    # --- modular backends (paper goal 2) -----------------------------------
    for backend in ("linear", "twochoice", "chain", "cuckoo"):
        e = DHashEngine(dhash.make(backend, capacity=2048, chunk=128, seed=1),
                        continuous_rebuild=True)
        for s in range(5):
            ks = jnp.arange(s * 10 + 1, s * 10 + 11, dtype=jnp.int32)
            e.step(ks, ks, ks * 2, jnp.zeros((1,), jnp.int32),
                   del_mask=jnp.zeros((1,), bool))
        print(f"backend {backend:10s}: {e.count()} items, "
              f"{e.stats.rebuilds_completed} background rebuilds")


if __name__ == "__main__":
    main()
