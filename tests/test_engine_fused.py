"""Engine acceptance tests for the on-device steady state: K-step deferred
polling, buffer donation without retraces, zero host syncs between polls,
on-device epoch swap + continuous-rebuild autostart, and the fused
(Pallas-kernel) state driven end-to-end against a dict oracle."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import dhash
from repro.core.engine import DHashEngine

I32 = np.int32


def _z1():
    return np.zeros(1, I32)


def _quiet_step(eng, look):
    """An op batch that only looks up (masked-out insert/delete)."""
    return eng.step(look, _z1(), _z1(), _z1(),
                    ins_mask=np.zeros(1, bool), del_mask=np.zeros(1, bool))


def test_zero_host_sync_between_polls(monkeypatch):
    """Steady state: zero device_get for K-1 of every K steps (the poll step
    itself performs exactly one batched device_get)."""
    eng = DHashEngine(dhash.make("linear", capacity=512, chunk=32, seed=7),
                      poll_every=8)
    keys = np.arange(1, 65, dtype=I32)
    eng.step(keys, keys, keys * 2, _z1(), del_mask=np.zeros(1, bool))

    calls = {"n": 0}
    orig = jax.device_get

    def counting(x):
        calls["n"] += 1
        return orig(x)

    monkeypatch.setattr(jax, "device_get", counting)
    for _ in range(16):
        _quiet_step(eng, keys)
    monkeypatch.undo()
    # steps 2..17 -> polls at steps 8 and 16 only
    assert calls["n"] == 2, calls
    assert eng._stats.host_syncs >= 2


def test_donation_no_retrace():
    """The donated step stays on one compiled executable across many steps
    (one cache entry per batch-shape signature, none added by stepping)."""
    eng = DHashEngine(dhash.make("linear", capacity=512, chunk=32, seed=7))
    keys = np.arange(1, 65, dtype=I32)
    for _ in range(12):
        eng.step(keys, keys, keys * 2, keys[:8])
    assert eng._step_cache_size() == 1


def test_deferred_poll_never_misses_epoch_swap():
    """K-step deferred polling: the swap happens on-device the step the
    rebuild completes; item counts are conserved and every key stays
    readable through the whole rebuild window."""
    rng = np.random.default_rng(0)
    eng = DHashEngine(dhash.make("linear", capacity=512, chunk=32, seed=3),
                      poll_every=32)
    keys = rng.choice(100_000, 300, replace=False).astype(I32)
    for i in range(0, 300, 64):
        b = keys[i:i + 64]
        eng.step(b, b, b * 2, _z1(), del_mask=np.zeros(1, bool))
    assert eng.count() == 300
    epoch0 = int(jax.device_get(eng.state.epoch))
    assert eng.request_rebuild(seed=5)
    syncs0 = eng._stats.host_syncs
    steps = 0
    while bool(jax.device_get(eng.state.rebuilding)):
        f, v, _, _ = _quiet_step(eng, keys[:64])
        assert bool(np.asarray(f).all()), "lookup missed mid-rebuild"
        assert bool((np.asarray(v) == keys[:64] * 2).all())
        steps += 1
        assert steps < 500
    # swap happened on-device (possibly between host polls) and lost nothing
    assert int(jax.device_get(eng.state.epoch)) == epoch0 + 1
    # the host only polled every K steps during the whole rebuild
    assert eng._stats.host_syncs - syncs0 <= steps // eng.poll_every + 1
    assert eng.count() == 300
    assert eng.stats.rebuilds_completed == 1


def test_continuous_autostart_on_device_and_reseed():
    """Continuous mode cycles rebuilds with ZERO host involvement between
    polls; each epoch gets a fresh on-device-derived hash function."""
    eng = DHashEngine(dhash.make("linear", capacity=256, chunk=64, seed=1),
                      continuous_rebuild=True, poll_every=32)
    keys = np.arange(1, 101, dtype=I32)
    seeds0 = np.asarray(jax.device_get(eng.state.old.hfn.seeds))
    eng.step(keys, keys, keys * 2, _z1(), del_mask=np.zeros(1, bool))
    for _ in range(40):
        f, _, _, _ = _quiet_step(eng, keys)
        assert bool(np.asarray(f).all())
    assert eng.stats.rebuilds_completed >= 1
    assert eng.count() == 100
    seeds1 = np.asarray(jax.device_get(eng.state.old.hfn.seeds))
    assert not np.array_equal(seeds0, seeds1), "autostart did not reseed"


def test_fused_engine_matches_dict_oracle():
    """End-to-end: fused (Pallas kernel) state in a continuous-rebuild engine
    against a dict oracle — mixed inserts/deletes/lookups across epochs."""
    rng = np.random.default_rng(2)
    eng = DHashEngine(dhash.make("linear", capacity=256, chunk=32, seed=4,
                                 fused=True),
                      continuous_rebuild=True, poll_every=8)
    oracle: dict[int, int] = {}
    universe = np.arange(1, 200)
    for step in range(24):
        ins = rng.choice(universe, 6, replace=False)
        ins = np.array([k for k in ins if k not in oracle] or [0], I32)
        dels = np.array([k for k in rng.choice(list(oracle) or [0], 3)
                         if k in oracle] or [0], I32)
        dels = np.unique(dels)
        look = rng.choice(universe, 16, replace=False).astype(I32)
        pre = dict(oracle)
        found, vals, ok_i, ok_d = eng.step(look, ins, ins * 3, dels,
                                           ins_mask=ins > 0,
                                           del_mask=dels > 0)
        for k in ins[ins > 0]:
            oracle[int(k)] = int(k) * 3
        for k in dels[dels > 0]:
            oracle.pop(int(k), None)
        fn, vn = np.asarray(found), np.asarray(vals)
        for i, k in enumerate(look):
            assert fn[i] == (int(k) in pre), (step, k)
            if int(k) in pre:
                assert vn[i] == pre[int(k)]
    assert eng.count() == len(oracle)


def test_zero_host_sync_full_fused_write_epoch(monkeypatch):
    """Acceptance (PR 2): a FUSED state driving complete rebuild epochs —
    extract kernel -> landing via the claim kernel -> on-device swap — with
    interleaved lookup/insert/DELETE batches performs ZERO host syncs
    between poll intervals: exactly one batched device_get per poll_every
    steps, while at least one full epoch completes entirely on-device."""
    eng = DHashEngine(dhash.make("linear", capacity=256, chunk=64, seed=9,
                                 fused=True),
                      continuous_rebuild=True, poll_every=8)
    rng = np.random.default_rng(0)
    keys = rng.choice(50_000, 128, replace=False).astype(I32)
    eng.step(keys, keys, keys * 2, _z1(), del_mask=np.zeros(1, bool))

    calls = {"n": 0}
    orig = jax.device_get

    def counting(x):
        calls["n"] += 1
        return orig(x)

    monkeypatch.setattr(jax, "device_get", counting)
    for i in range(24):
        # mixed traffic: lookups + fresh inserts + deletes of earlier keys
        ins = rng.integers(100_000, 200_000, 8).astype(I32)
        dels = keys[(i * 4) % 128:][:4]
        eng.step(keys[:32], ins, ins * 2, dels)
    monkeypatch.undo()
    # steps 2..25 -> polls at steps 8, 16, 24 only
    assert calls["n"] == 3, calls
    # the epochs cycled on-device while the host stayed silent
    assert eng.stats.rebuilds_completed >= 1


def test_fused_twochoice_engine_matches_dict_oracle():
    """The twochoice backend on the fused kernels, driven end-to-end in a
    continuous-rebuild engine against a dict oracle (PR 2 brought twochoice
    onto the fused path; the chain backend's engine-level coverage lives in
    tests/test_differential.py)."""
    rng = np.random.default_rng(6)
    eng = DHashEngine(dhash.make("twochoice", capacity=256, chunk=32, seed=4,
                                 fused=True),
                      continuous_rebuild=True, poll_every=8)
    oracle: dict[int, int] = {}
    universe = np.arange(1, 200)
    for step in range(16):
        ins = rng.choice(universe, 6, replace=False)
        ins = np.array([k for k in ins if k not in oracle] or [0], I32)
        dels = np.array([k for k in rng.choice(list(oracle) or [0], 3)
                         if k in oracle] or [0], I32)
        dels = np.unique(dels)
        look = rng.choice(universe, 16, replace=False).astype(I32)
        pre = dict(oracle)
        found, vals, ok_i, ok_d = eng.step(look, ins, ins * 3, dels,
                                           ins_mask=ins > 0,
                                           del_mask=dels > 0)
        for k in ins[ins > 0]:
            oracle[int(k)] = int(k) * 3
        for k in dels[dels > 0]:
            oracle.pop(int(k), None)
        fn, vn = np.asarray(found), np.asarray(vals)
        for i, k in enumerate(look):
            assert fn[i] == (int(k) in pre), (step, k)
            if int(k) in pre:
                assert vn[i] == pre[int(k)]
    assert eng.count() == len(oracle)
