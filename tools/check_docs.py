"""Docs gate: markdown link check + README snippet smoke runs.

Two checks, both stdlib-only:

1. **Link check** — every RELATIVE link target in every tracked ``*.md``
   (root and ``docs/``) must exist on disk.  External (``http(s)://``,
   ``mailto:``) and pure-anchor (``#...``) links are skipped; a relative
   link's own ``#fragment`` is stripped before the existence check.

2. **Snippet smoke** — every fenced ``bash``/``sh``/``python`` block in
   ``README.md`` is EXECUTED from the repo root (bash via ``bash -c``,
   python via the current interpreter) and must exit 0, so the quickstart
   can never rot.  A block immediately preceded by the HTML comment
   ``<!-- docs-smoke: skip (reason) -->`` is not executed — reserved for
   commands another CI job already runs end-to-end (e.g. the full tier-1
   suite, which IS the test job), never for convenience.

Exit status: 0 clean, 1 failure(s).  Run from the repo root:

    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(
    r"(?P<prefix>(?:<!--\s*docs-smoke:\s*skip[^>]*-->\s*\n)?)"
    r"```(?P<lang>bash|sh|python)\n(?P<body>.*?)```",
    re.DOTALL)
SKIP_DIRS = {".git", "__pycache__", ".ruff_cache", ".pytest_cache", "node_modules"}
SNIPPET_TIMEOUT_S = 1800


def iter_markdown():
    for root, dirs, files in os.walk(REPO):
        dirs[:] = [d for d in dirs if d not in SKIP_DIRS]
        for f in files:
            if f.endswith(".md"):
                yield pathlib.Path(root) / f


def check_links(failures: list[str]) -> int:
    checked = 0
    for md in iter_markdown():
        text = md.read_text()
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            checked += 1
            if not (md.parent / rel).exists():
                failures.append(
                    f"{md.relative_to(REPO)}: broken link -> {target}")
    return checked


def run_snippets(failures: list[str]) -> int:
    readme = REPO / "README.md"
    if not readme.exists():
        failures.append("README.md missing")
        return 0
    env = dict(os.environ)
    env["PYTHONPATH"] = f"src{os.pathsep}" + env.get("PYTHONPATH", "")
    ran = 0
    for m in FENCE_RE.finditer(readme.read_text()):
        lang, body = m.group("lang"), m.group("body")
        head = body.strip().splitlines()[0] if body.strip() else "<empty>"
        if m.group("prefix"):
            print(f"  skip  [{lang}] {head}")
            continue
        ran += 1
        if lang in ("bash", "sh"):
            cmd = ["bash", "-euo", "pipefail", "-c", body]
        else:
            cmd = [sys.executable, "-c", body]
        print(f"  run   [{lang}] {head}")
        try:
            proc = subprocess.run(cmd, cwd=REPO, env=env,
                                  capture_output=True, text=True,
                                  timeout=SNIPPET_TIMEOUT_S)
        except subprocess.TimeoutExpired:
            failures.append(f"README.md snippet timed out: {head}")
            continue
        if proc.returncode != 0:
            tail = (proc.stderr or proc.stdout).strip().splitlines()[-8:]
            failures.append(
                f"README.md snippet failed (rc={proc.returncode}): {head}\n"
                + "\n".join(f"      {ln}" for ln in tail))
    return ran


def main() -> int:
    failures: list[str] = []
    nlinks = check_links(failures)
    print(f"link check: {nlinks} relative links checked")
    nsnips = run_snippets(failures)
    print(f"snippet smoke: {nsnips} snippet(s) executed")
    if failures:
        print(f"\nDOCS CHECK FAILED: {len(failures)} problem(s)",
              file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("docs check clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
