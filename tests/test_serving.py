"""Serving tests: paged KV == dense decode, page accounting, prefix cache,
live rehash under load (the paper's non-blocking property on the serving
path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import dhash
from repro.models import model, transformer
from repro.serving import kvcache, prefix_cache
from repro.serving.engine import ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def small():
    cfg = ArchConfig("t-serve", "dense", n_layers=2, d_model=64, n_heads=4,
                     n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
                     attn_chunk=32, loss_chunk=32)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_end_to_end_and_page_reclaim(small):
    cfg, params = small
    eng = ServingEngine(params, cfg, ServeConfig(
        max_seqs=4, page_size=8, n_pages=64, max_blocks=8, max_new_tokens=6))
    rng = np.random.default_rng(0)
    sids = [eng.submit(list(rng.integers(1, 255, size=rng.integers(3, 10))))
            for _ in range(6)]
    eng.run(max_steps=500)
    assert len(eng.finished) == 6
    for sid in sids:
        assert len(eng.finished[sid]) == 6
    assert int(eng.kv.free_top) == 64, "pages leaked"
    # table fully empty again
    assert int(jax.device_get(dhash.count_items(eng.kv.table))) == 0


def test_paged_decode_matches_dense(small):
    cfg, params = small
    prompt = [5, 9, 17, 3]
    eng = ServingEngine(params, cfg, ServeConfig(
        max_seqs=2, page_size=8, n_pages=64, max_blocks=8, max_new_tokens=4))
    sid = eng.submit(prompt)
    eng.run()
    cache = transformer.init_cache(cfg, 1, 64)
    toks, outs = list(prompt), []
    for i in range(len(prompt) + 3):
        t = jnp.asarray([[toks[i]]], jnp.int32)
        logits, cache = jax.jit(model.decode_logits, static_argnums=1)(
            params, cfg, t, cache)
        if i >= len(prompt) - 1:
            outs.append(int(jnp.argmax(logits[0])))
            toks.append(outs[-1])
    assert outs == eng.finished[sid]


def test_live_rehash_during_serving(small):
    """Force the page table past its rehash threshold mid-serving: requests
    keep completing and the table rebuilds at least once (non-blocking)."""
    cfg, params = small
    eng = ServingEngine(params, cfg, ServeConfig(
        max_seqs=4, page_size=4, n_pages=256, max_blocks=16,
        max_new_tokens=24, rehash_load_factor=0.02))
    rng = np.random.default_rng(1)
    for _ in range(8):
        eng.submit(list(rng.integers(1, 255, size=12)))
    eng.run(max_steps=2000)
    assert len(eng.finished) == 8
    assert eng.rehashes >= 1, "rehash threshold never triggered"
    for out in eng.finished.values():
        assert len(out) == 24


def test_prefix_cache_chain_semantics():
    toks = jnp.asarray(np.random.default_rng(0).integers(1, 100, (2, 64)),
                       jnp.int32)
    fps = prefix_cache.prefix_fingerprints(toks, page_size=16)
    assert fps.shape == (2, 4)
    # chained: changing block 1 changes fps for blocks >= 1 but not block 0
    toks2 = toks.at[0, 20].set(99)
    fps2 = prefix_cache.prefix_fingerprints(toks2, page_size=16)
    assert int(fps2[0, 0]) == int(fps[0, 0])
    assert int(fps2[0, 1]) != int(fps[0, 1])
    assert int(fps2[0, 3]) != int(fps[0, 3])

    table = dhash.make("linear", capacity=256, chunk=32, seed=0)
    pages = jnp.arange(8, dtype=jnp.int32).reshape(2, 4)
    table, ok = prefix_cache.publish_prefix(table, fps, pages,
                                            jnp.ones((2, 4), bool))
    assert bool(np.asarray(ok).all())
    nhit, got = prefix_cache.match_prefix(table, fps)
    assert (np.asarray(nhit) == 4).all()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(pages))
    # partial prefix: row with one diverged block matches only the prefix
    nhit2, got2 = prefix_cache.match_prefix(table, fps2)
    assert int(nhit2[0]) == 1 and int(nhit2[1]) == 4
    assert int(got2[0, 0]) == 0 and int(got2[0, 1]) == -1


def test_paged_attention_vs_reference_random_pages():
    """paged_decode_attention == dense attention when pages are scattered."""
    rng = np.random.default_rng(3)
    L, PS, NP, KV, HD, B, HQ = 1, 4, 32, 2, 8, 3, 4
    kv = kvcache.make(L, PS, NP, KV, HD, dtype=jnp.float32, seed=1)
    slen = jnp.asarray([9, 5, 12], jnp.int32)
    seq_ids = jnp.asarray([1, 2, 3], jnp.int32)
    dense_k = jnp.asarray(rng.normal(size=(B, 16, KV, HD)).astype(np.float32))
    dense_v = jnp.asarray(rng.normal(size=(B, 16, KV, HD)).astype(np.float32))
    # fill the paged pool token by token
    for b in range(B):
        for t in range(int(slen[b])):
            kv = kvcache.append_token(
                kv, seq_ids[b: b + 1], jnp.asarray([t], jnp.int32),
                dense_k[None, b: b + 1, t], dense_v[None, b: b + 1, t])
    q = jnp.asarray(rng.normal(size=(B, HQ, HD)).astype(np.float32))
    out = kvcache.paged_decode_attention(kv, jnp.asarray(0), q, seq_ids, slen,
                                         n_blocks=4)
    from repro.models.attention import decode_attention
    ref = decode_attention(q[:, None], dense_k, dense_v, slen)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_multi_tenant_page_tables_independent_rehash():
    """Tenant page-table stack: routing isolates tenants' mappings, and a
    rehash started on a subset of tenants advances ONLY their epochs while
    every tenant keeps resolving pages mid-flight."""
    kv = kvcache.make(layers=1, page_size=4, n_pages=64, kv_heads=1,
                      head_dim=8, max_blocks=8, n_tenants=4)
    sids = jnp.asarray([1, 2, 3, 4, 5, 6, 7, 8], jnp.int32)  # 2 seqs/tenant
    blk = jnp.zeros((8,), jnp.int32)
    kv, pages = jax.jit(kvcache.alloc_pages)(kv, sids, blk,
                                             jnp.ones((8,), bool))
    assert bool((np.asarray(pages) >= 0).all())
    # per-tenant tables: each tenant's table holds exactly its own 2 keys
    counts = np.asarray(jax.device_get(dhash.stack_count_items(kv.table)))
    np.testing.assert_array_equal(counts, np.full(4, 2))
    # rehash tenants 0 and 2 only; run it to completion mid-serving
    kv = kvcache.start_rehash(kv, jnp.asarray([True, False, True, False]))
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(kv.table.rebuilding)),
        np.array([True, False, True, False]))
    step = jax.jit(kvcache.rehash_step)
    for _ in range(40):
        kv = step(kv)
        pg, fnd = kvcache.resolve_blocks_at(kv, sids, blk)
        assert bool(np.asarray(fnd).all()), "resolution must never block"
        np.testing.assert_array_equal(np.asarray(pg), np.asarray(pages))
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(kv.table.epoch)), np.array([1, 0, 1, 0]))
    # freeing one tenant's sequences leaves the others' mappings intact
    kv = jax.jit(kvcache.free_sequences, static_argnums=2)(
        kv, jnp.asarray([4, 8], jnp.int32), 8)       # tenant 0's seqs
    pg, fnd = kvcache.resolve_blocks_at(kv, sids, blk)
    np.testing.assert_array_equal(
        np.asarray(fnd), np.array([True, True, True, False,
                                   True, True, True, False]))
    assert int(kv.free_top) == 64 - 6


def test_multi_tenant_engine_matches_single_tenant(small):
    """ServingEngine with a tenant stack decodes EXACTLY like the
    single-table engine (page-table layout is invisible to the model), while
    per-tenant rehash epochs advance independently under a low trigger."""
    cfg, params = small
    outs = {}
    for tenants in (1, 3):
        eng = ServingEngine(params, cfg, ServeConfig(
            max_seqs=4, page_size=8, n_pages=64, max_blocks=8,
            max_new_tokens=6, n_tenants=tenants,
            rehash_load_factor=0.01 if tenants > 1 else 0.7))
        rng = np.random.default_rng(0)
        sids = [eng.submit(list(rng.integers(1, 255,
                                             size=rng.integers(3, 10))))
                for _ in range(6)]
        eng.run(max_steps=500)
        assert len(eng.finished) == 6
        assert int(eng.kv.free_top) == 64, "pages leaked"
        outs[tenants] = [eng.finished[s] for s in sids]
        if tenants > 1:
            assert eng.rehashes >= 1, "low trigger must start tenant rehashes"
    assert outs[1] == outs[3], "tenant partition must not change decoding"
