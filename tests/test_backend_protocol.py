"""Backend-conformance suite: every ``BucketBackend`` registry entry, fused
on and off, through ONE shared op-contract checklist against a dict oracle.

This file is the executable form of the descriptor protocol
(core/backend.py): a new backend that passes here composes with everything
the DHash layer builds on top (rebuild epochs, engines, stacks, serving).
It replaces the per-backend fused-vs-jnp parity copies that used to
accumulate in test_kernels.py (one twochoice copy, one chain copy, ...) —
kernel-specific tests (budgets, layouts, fallbacks) stay there.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend, dhash

ALL_BACKENDS = backend.names()
FUSED_AXIS = [(b, f) for b in ALL_BACKENDS
              for f in ((False, True) if backend.get(b).fused else (False,))]

PLAIN_OPS = ("make", "fresh_like", "reseed", "capacity_of", "with_state",
             "lookup", "insert", "delete", "extract_chunk", "count_live",
             "clear")
FUSED_OPS = ("lookup_fused", "insert_fused", "delete_fused",
             "extract_chunk_fused", "ordered_lookup_fused",
             "ordered_delete_fused")


# ---------------------------------------------------------------------------
# registry shape
# ---------------------------------------------------------------------------

def test_registry_wellformed():
    assert set(ALL_BACKENDS) >= {"linear", "twochoice", "chain"}
    for name in ALL_BACKENDS:
        be = backend.get(name)
        assert be.name == name
        assert isinstance(be.table_cls, type)
        assert be.nres_cap > 0
        assert be.dirty_cap >= 0
        for op in PLAIN_OPS:
            assert callable(getattr(be, op)), f"{name}.{op}"
        have = [getattr(be, op) is not None for op in FUSED_OPS]
        assert all(have) == be.fused and (all(have) or not any(have))
        t = be.make(128, seed=0)
        assert isinstance(t, be.table_cls)
        assert isinstance(be.capacity_of(t), int)
        assert backend.of_table(t) is be
        assert dhash.make(name, 128, chunk=32).backend == name


def test_registry_rejects_partial_fused_set():
    be = backend.get("linear")
    with pytest.raises(ValueError, match="all-or-none"):
        dataclasses.replace(be, ordered_delete_fused=None)
    with pytest.raises(ValueError):
        backend.get("no-such-backend")


def test_caps_are_threaded_from_descriptor():
    """The layout caps live on the descriptor and flow through make():
    nres_cap onto the DHash state, dirty_cap onto the chain table."""
    d = dhash.make("linear", 128, chunk=32)
    assert d.nres_cap == backend.get("linear").nres_cap
    assert dhash.make("linear", 128, chunk=32, nres_cap=4).nres_cap == 4
    c = dhash.make("chain", 128, chunk=32)
    assert c.old.dirty_cap == backend.get("chain").dirty_cap
    c2 = dhash.make("chain", 128, chunk=32, dirty_cap=64)
    assert c2.old.dirty_cap == 64 and c2.new.dirty_cap == 64


# ---------------------------------------------------------------------------
# the shared op-contract checklist
# ---------------------------------------------------------------------------

def _mixed_batches(rng, n_live=300, n_absent=100):
    live = rng.choice(1_000_000, n_live, replace=False).astype(np.int32) + 1
    absent = (rng.choice(1_000_000, n_absent, replace=False)
              .astype(np.int32) + 1_000_001)
    return jnp.asarray(live), jnp.asarray(absent)


@pytest.mark.parametrize("name,fused", FUSED_AXIS)
def test_table_op_contract(name, fused):
    """Descriptor-level checklist on a bare table: insert (dups, masks,
    re-inserts), lookup (hits, misses, loc contract), delete (absent keys,
    dups), extract -> land round trip, clear, count_live — all against a
    dict oracle; the fused adapters must agree with the plain ops on every
    observable."""
    rng = np.random.default_rng(11)
    be = backend.get(name)
    t = be.make(600, seed=5)
    live, absent = _mixed_batches(rng)
    n = live.shape[0]

    ins = be.insert_fused if fused else be.insert
    dele = be.delete_fused if fused else be.delete
    ext = be.extract_chunk_fused if fused else be.extract_chunk

    def look(tt, keys):
        if fused:
            return be.lookup_fused(tt, keys)
        f, v, _ = be.lookup(tt, keys)
        return f, v

    # -- insert: duplicates lose, masked-out entries never land
    batch = jnp.concatenate([live, live[:50]])           # 50 in-batch dups
    vals = batch * 3
    mask = jnp.ones(batch.shape, bool).at[n - 20:n].set(False)
    t, ok = jax.jit(ins)(t, batch, vals, mask)
    oracle = {int(k): int(k) * 3 for k in live[:n - 20]}
    assert int(ok.sum()) == len(oracle)
    assert not bool(ok[n:].any()), "duplicate insert must lose"
    assert int(be.count_live(t)) == len(oracle)

    # -- re-insert of present keys fails (set semantics)
    t, ok2 = jax.jit(ins)(t, live[:40], live[:40] * 9,
                          jnp.ones((40,), bool))
    assert not bool(ok2.any())

    # -- lookup: hits with values, misses, loc contract on the plain op
    qs = jnp.concatenate([live, absent])
    f, v = jax.jit(look)(t, qs)
    expect_f = np.array([int(k) in oracle for k in np.asarray(qs)])
    np.testing.assert_array_equal(np.asarray(f), expect_f)
    np.testing.assert_array_equal(
        np.asarray(v)[expect_f],
        np.array([oracle[int(k)] for k in np.asarray(qs)[expect_f]]))
    _, _, loc = jax.jit(be.lookup)(t, qs)
    np.testing.assert_array_equal(np.asarray(loc) >= 0, expect_f)

    # -- delete: absent keys and duplicates report False
    dels = jnp.concatenate([live[:60], absent[:30], live[:10]])
    t, okd = jax.jit(dele)(t, dels, jnp.ones(dels.shape, bool))
    expect_d = np.array([int(k) in oracle for k in np.asarray(dels)])
    expect_d[-10:] = False                               # in-batch dup delete
    np.testing.assert_array_equal(np.asarray(okd), expect_d)
    for k in np.asarray(dels[:60]):
        oracle.pop(int(k), None)
    assert int(be.count_live(t)) == len(oracle)

    # -- extract sweep -> land into a fresh table: membership preserved
    fresh = be.fresh_like(t, seed=77)
    assert (jax.tree_util.tree_structure(fresh)
            == jax.tree_util.tree_structure(t))
    assert int(be.count_live(fresh)) == 0
    cursor = jnp.asarray(0, jnp.int32)
    cap = be.capacity_of(t)
    seen = {}
    for _ in range(-(-cap // 128)):
        t, hk, hv, hl, cursor = jax.jit(ext, static_argnums=2)(t, cursor, 128)
        for k, v2, alive in zip(np.asarray(hk), np.asarray(hv),
                                np.asarray(hl)):
            if alive:
                seen[int(k)] = int(v2)
        fresh, _ = jax.jit(ins)(fresh, hk, hv, hl)
    assert int(cursor) == cap
    assert seen == oracle, "extract sweep must surface exactly the live set"
    assert int(be.count_live(t)) == 0
    f, v = jax.jit(look)(fresh, live)
    expect_f = np.array([int(k) in oracle for k in np.asarray(live)])
    np.testing.assert_array_equal(np.asarray(f), expect_f)

    # -- clear: empty, geometry preserved
    cleared = jax.jit(be.clear)(fresh)
    assert int(be.count_live(cleared)) == 0
    assert not bool(jax.jit(look)(cleared, live)[0].any())

    # -- reseed: pytree structure intact, table still usable
    reseeded = jax.jit(be.reseed)(be.make(600, seed=5), jnp.asarray(3))
    r2, okr = jax.jit(ins)(reseeded, live[:50], live[:50] * 3,
                           jnp.ones((50,), bool))
    assert bool(okr.all())
    assert bool(jax.jit(look)(r2, live[:50])[0].all())


@pytest.mark.parametrize("name,fused", FUSED_AXIS)
def test_ordered_ops_contract(name, fused):
    """Rebuild-epoch surface through dhash (the descriptor's ordered ops
    when fused): mid-epoch lookup and delete honour old > hazard > new
    against a dict oracle, including keys landed in the new table."""
    rng = np.random.default_rng(23)
    d = dhash.make(name, 400, chunk=64, seed=3, fused=fused)
    live, absent = _mixed_batches(rng, n_live=250)
    d, ok = jax.jit(dhash.insert)(d, live, live * 3)
    assert bool(ok.all())
    oracle = {int(k): int(k) * 3 for k in np.asarray(live)}
    d = dhash.rebuild_start(d, seed=41)
    d = jax.jit(dhash.rebuild_chunk)(d)          # one chunk landed in new
    d = jax.jit(dhash.rebuild_extract)(d)        # one chunk in hazard
    ins_new = jnp.asarray(
        rng.choice(1_000_000, 40, replace=False).astype(np.int32) + 2_000_002)
    d, ok_i = jax.jit(dhash.insert)(d, ins_new, ins_new * 3)
    assert bool(ok_i.all())
    oracle.update({int(k): int(k) * 3 for k in np.asarray(ins_new)})

    qs = jnp.concatenate([live, ins_new, absent])
    f, v = jax.jit(dhash.lookup)(d, qs)
    expect_f = np.array([int(k) in oracle for k in np.asarray(qs)])
    np.testing.assert_array_equal(np.asarray(f), expect_f)
    np.testing.assert_array_equal(
        np.asarray(v)[expect_f],
        np.array([oracle[int(k)] for k in np.asarray(qs)[expect_f]]))

    dels = jnp.concatenate([live[::5], ins_new[:10], absent[:20]])
    d, okd = jax.jit(dhash.delete)(d, dels)
    expect_d = np.array([int(k) in oracle for k in np.asarray(dels)])
    np.testing.assert_array_equal(np.asarray(okd), expect_d)
    for k in np.asarray(dels):
        oracle.pop(int(k), None)

    d = dhash.rebuild_all(d)
    assert int(dhash.count_items(d)) == len(oracle)
    f, v = jax.jit(dhash.lookup)(d, qs)
    expect_f = np.array([int(k) in oracle for k in np.asarray(qs)])
    np.testing.assert_array_equal(np.asarray(f), expect_f)


# ---------------------------------------------------------------------------
# bounded probe depth (the cuckoo defense contract)
# ---------------------------------------------------------------------------

def _colliding_keys(hfn, nbuckets, want, rng, bucket=0):
    """``want`` distinct keys that all hash into ``bucket`` under hfn."""
    from repro.core import hashing
    got = np.empty((0,), np.int32)
    while got.size < want:
        cand = rng.integers(1, 1_000_000_000, 1 << 14).astype(np.int32)
        b = np.asarray(hashing.bucket_of(hfn, jnp.asarray(cand), nbuckets))
        got = np.unique(np.concatenate([got, cand[b == bucket]]))
    return jnp.asarray(got[:want], jnp.int32)


def test_cuckoo_probe_depth_bounded_under_collision_flood():
    """The defense claim as an op contract: flood ONE side-A bucket with
    3x more colliders than it has lanes.  Kick-out relocation must place
    them all, and every lookup's loc-derived probe depth stays strictly
    below the row width (and so trivially below the kick bound) — an
    adversary cannot build a chain, only fill two rows."""
    rng = np.random.default_rng(7)
    be = backend.get("cuckoo")
    t = be.make(1500, seed=9)
    normal = jnp.asarray(rng.choice(500_000, 600, replace=False)
                         .astype(np.int32) + 1)
    t, ok = jax.jit(be.insert)(t, normal, normal * 3,
                               jnp.ones(normal.shape, bool))
    assert bool(ok.all())
    atk = _colliding_keys(t.hfn_a, int(t.nbuckets), 3 * t.width, rng)
    t, ok = jax.jit(be.insert)(t, atk, atk * 3, jnp.ones(atk.shape, bool))
    assert bool(ok.all()), "kick-out must place a modest collider flood"

    qs = jnp.concatenate([normal, atk])
    f, _, loc = jax.jit(be.lookup)(t, qs)
    assert bool(f.all())
    cost = np.asarray(be.probe_cost(t, qs, f, loc))
    assert int(cost.max()) < t.width, cost.max()
    assert int(cost.max()) <= t.max_kick


def test_probe_cost_extraction_stays_exact_for_linear():
    """The telemetry the policy trigger feeds on: keys colliding into one
    home slot of a linear table, inserted in order, must report probe
    distances exactly 0, 1, 2, ... — not approximations."""
    be = backend.get("linear")
    t = be.make(64, seed=2)
    rng = np.random.default_rng(3)
    ks = _colliding_keys(t.hfn, t.capacity, 4, rng, bucket=5)
    for i in range(4):                 # sequential: each lands one deeper
        t, ok = jax.jit(be.insert)(t, ks[i:i + 1], ks[i:i + 1],
                                   jnp.ones((1,), bool))
        assert bool(ok.all())
    f, _, loc = jax.jit(be.lookup)(t, ks)
    cost = np.asarray(be.probe_cost(t, ks, f, loc))
    np.testing.assert_array_equal(cost, np.arange(4))
