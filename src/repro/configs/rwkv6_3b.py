"""rwkv6-3b "Finch" [ssm]: attention-free, data-dependent per-channel decay
[arXiv:2404.05892; hf]. long_500k RUNS (O(1) recurrent state)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab_size=65536,
    block_pattern=("rwkv6",), rwkv_head_size=64, tie_embeddings=False,
)

def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                         d_ff=128, vocab_size=512, rwkv_head_size=16,
                         dtype="float32", attn_chunk=32, loss_chunk=32)
