"""End-to-end behaviour tests for the paper's system: the full DHash stack
exercised the way the framework uses it — training driver, serving driver,
and the paper's core scenario (attack -> live rebuild -> recovery) through
public APIs only."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def test_train_driver_end_to_end(tmp_path):
    """launch.train main(): smoke arch, checkpoints, restart, resume."""
    from repro.launch import train as train_main
    args = ["--arch", "gemma2-2b", "--smoke", "--steps", "8", "--batch", "2",
            "--seq", "64", "--ckpt-dir", str(tmp_path), "--ckpt-every", "4"]
    train_main.main(args)
    from repro.train import checkpoint as ck
    assert ck.latest_step(str(tmp_path)) == 8
    # restart resumes from the checkpoint (prints [restore])
    train_main.main(args + ["--steps", "10"])
    assert ck.latest_step(str(tmp_path)) == 8  # next save would be step 12


def test_serve_driver_end_to_end():
    from repro.launch import serve as serve_main
    eng = serve_main.main(["--arch", "qwen3-8b", "--requests", "4",
                           "--max-new", "4"])
    assert len(eng.finished) == 4
    assert all(len(v) == 4 for v in eng.finished.values())


def test_paper_scenario_attack_rebuild_recover():
    """The paper's §1 story through the public engine API."""
    from repro.core import dhash, hashing
    from repro.core.engine import DHashEngine

    rng = np.random.default_rng(0)
    eng = DHashEngine(dhash.make("chain", capacity=4096, nbuckets=64,
                                 chunk=256, seed=1, max_chain=2048))
    normal = rng.choice(100_000, 1000, replace=False).astype(np.int32)
    eng.step(normal[:16], normal, normal * 2, np.zeros(1, np.int32),
             del_mask=np.zeros(1, bool))
    assert eng.count() == 1000

    # adversary: keys colliding under the CURRENT function
    hfn = eng.state.old.hfn
    cand = jnp.asarray(np.unique(rng.integers(100_000, 10_000_000, 1 << 16)
                                 .astype(np.int32)))
    b = np.asarray(hashing.bucket_of(hfn, cand, 64))
    atk = np.asarray(cand)[b == 0][:800]
    eng.step(atk[:16], atk, atk, np.zeros(1, np.int32),
             del_mask=np.zeros(1, bool))
    assert eng.count() == 1800

    # live rebuild; traffic keeps flowing every step
    assert eng.request_rebuild(seed=777)
    while bool(jax.device_get(eng.state.rebuilding)):
        look = np.concatenate([rng.choice(normal, 8), rng.choice(atk, 8)])
        found, vals, _, _ = eng.step(look, np.zeros(1, np.int32),
                                     np.zeros(1, np.int32),
                                     np.zeros(1, np.int32),
                                     ins_mask=np.zeros(1, bool),
                                     del_mask=np.zeros(1, bool))
        assert bool(np.asarray(found).all()), "lookup missed mid-rebuild"
    assert eng.stats.rebuilds_completed == 1
    assert eng.count() == 1800
    # post-rebuild: attacked keys no longer share a bucket
    hfn2 = eng.state.old.hfn
    b2 = np.asarray(hashing.bucket_of(hfn2, jnp.asarray(atk), 64))
    assert len(np.unique(b2)) > 16, "rebuild did not disperse the attack"
